//! Quickstart: the paper's idea in 80 lines.
//!
//! Builds `shared [4] int A[N]` over 4 UPC threads (the paper's Figure 2
//! layout), writes a kernel that sums it through a shared pointer, and
//! compiles it twice: with the software Algorithm 1 (the unmodified
//! compiler) and with the PGAS instructions (Table 1).  Both validate;
//! the cycle counts show the gap the hardware closes.
//!
//!     cargo run --release --example quickstart

use pgas_hw::compiler::{compile, CompileOpts, IrBuilder, Lowering, Val};
use pgas_hw::cpu::CpuModel;
use pgas_hw::isa::{Cond, IntOp, MemWidth};
use pgas_hw::sim::{Machine, MachineCfg};
use pgas_hw::upc::UpcRuntime;
use pgas_hw::util::table::Table;

const N: u64 = 4096;
const THREADS: u32 = 4;

fn build_and_run(lowering: Lowering, model: CpuModel) -> (u64, u64, u64) {
    let mut rt = UpcRuntime::new(THREADS);
    // the paper's Figure 2: shared [4] int arrayA[...]
    let arr = rt.alloc_shared("arrayA", 4, 4, N);

    let mut b = IrBuilder::new(&mut rt);
    // every thread sums the whole array (forall-style traversal);
    // thread 0 stores its result to private space for checking
    let acc = b.iconst(0);
    let p = b.sptr_init(arr, Val::I(0));
    b.for_range(Val::I(0), Val::I(N as i64), 1, |b, _| {
        let v = b.it();
        b.sptr_ld(MemWidth::U32, v, p, 0);
        b.bin(IntOp::Add, acc, acc, Val::R(v));
        b.sptr_inc(p, arr, Val::I(1));
        b.free_i(v);
    });
    let myt = b.mythread();
    b.iff(Cond::Eq, myt, |b| {
        let pb = b.priv_base();
        b.st(MemWidth::U64, acc, pb, 0);
        b.free_i(pb);
    });
    let module = b.finish("quickstart");

    let ck = compile(
        &module,
        &rt,
        &CompileOpts {
            lowering,
            static_threads: false,
            numthreads: THREADS,
            volatile_stores: true,
        },
    );
    let mut m = Machine::new(MachineCfg::new(THREADS, model));
    for i in 0..N {
        rt.write_u64(m.mem_mut(), arr, i, i % 97);
    }
    let res = m.run(&ck.program);
    let got = m
        .mem
        .read(MemWidth::U64, pgas_hw::mem::seg_base(0) + pgas_hw::mem::PRIV_OFF);
    let want: u64 = (0..N).map(|i| i % 97).sum();
    assert_eq!(got, want, "simulated sum must be correct");
    (res.cycles, res.total.instructions, got)
}

fn main() {
    println!("pgas-hw quickstart: shared [4] int A[{N}] over {THREADS} threads\n");
    let mut t = Table::new(
        "software Algorithm 1 vs PGAS hardware instructions",
        &["model", "variant", "cycles", "instructions", "speedup"],
    );
    for model in [CpuModel::Atomic, CpuModel::Timing, CpuModel::Detailed] {
        let (soft_c, soft_i, _) = build_and_run(Lowering::Soft, model);
        let (hw_c, hw_i, _) = build_and_run(Lowering::Hw, model);
        t.row(&[
            model.name().into(),
            "soft".into(),
            soft_c.to_string(),
            soft_i.to_string(),
            "1.00x".into(),
        ]);
        t.row(&[
            model.name().into(),
            "hw".into(),
            hw_c.to_string(),
            hw_i.to_string(),
            format!("{:.2}x", soft_c as f64 / hw_c as f64),
        ]);
    }
    println!("{}", t.render());
    println!("(both variants validated the same sum — the hardware only\n changes *how fast* shared pointers move, never what they mean)");
}
