//! Quickstart: the paper's idea in ~100 lines, through the unified
//! `AddressEngine` API.
//!
//! One address-mapping contract — Algorithm 1 incrementation + base-LUT
//! translation + locality — served by interchangeable backends:
//!
//! 1. the **engine view**: an [`EngineSelector`] walks the paper's
//!    Figure-2 array (`shared [4] int A[..]` over 4 threads) with the
//!    backend the layout allows — shift/mask `pow2` here, software
//!    divide/modulo for non-pow2 geometry — and both agree bit-for-bit;
//! 2. the **compiled view**: the same contract lowered by the mini-UPC
//!    compiler twice, with software Algorithm 1 and with the paper's
//!    PGAS instructions.  Both validate; the cycle counts show the gap
//!    the hardware closes.
//!
//!     cargo run --release --example quickstart

use pgas_hw::compiler::{compile, CompileOpts, IrBuilder, Lowering, Val};
use pgas_hw::cpu::CpuModel;
use pgas_hw::engine::{AddressEngine, BatchOut, EngineCtx, EngineSelector};
use pgas_hw::isa::{Cond, IntOp, MemWidth};
use pgas_hw::sim::{Machine, MachineCfg};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::upc::UpcRuntime;
use pgas_hw::util::table::Table;

const N: u64 = 4096;
const THREADS: u32 = 4;

/// Part 1: one contract, pluggable backends.
fn engine_demo() {
    let sel = EngineSelector::new();
    let table = BaseTable::regular(THREADS, 1 << 32, 1 << 32);

    // the paper's Figure 2: shared [4] int A[..] — pow2 geometry, so
    // the selector picks the hardware fast path
    let fig2 = ArrayLayout::new(4, 4, THREADS);
    let engine = sel.select(&fig2, 16);
    let ctx = EngineCtx::new(fig2, &table, 0).unwrap();
    let mut out = BatchOut::new();
    engine
        .walk(&ctx, SharedPtr::NULL, 1, 16, &mut out)
        .unwrap();
    let threads: Vec<u32> = out.ptrs.iter().map(|p| p.thread).collect();
    println!("`{}` engine walks A[0..16]: threads {threads:?}", engine.name());

    // CG's w_tmp-style non-pow2 element: same call, software backend
    let odd = ArrayLayout::new(1, 56016, THREADS);
    let engine = sel.select(&odd, 16);
    let ctx = EngineCtx::new(odd, &table, 0).unwrap();
    engine
        .walk(&ctx, SharedPtr::NULL, 1, 4, &mut out)
        .unwrap();
    println!(
        "`{}` engine serves the non-pow2 layout the hardware refuses\n",
        engine.name()
    );
}

/// Part 2: the same contract, compiled and simulated.
fn build_and_run(lowering: Lowering, model: CpuModel) -> (u64, u64, u64) {
    let mut rt = UpcRuntime::new(THREADS);
    // the paper's Figure 2: shared [4] int arrayA[...]
    let arr = rt.alloc_shared("arrayA", 4, 4, N);

    let mut b = IrBuilder::new(&mut rt);
    // every thread sums the whole array (forall-style traversal);
    // thread 0 stores its result to private space for checking
    let acc = b.iconst(0);
    let p = b.sptr_init(arr, Val::I(0));
    b.for_range(Val::I(0), Val::I(N as i64), 1, |b, _| {
        let v = b.it();
        b.sptr_ld(MemWidth::U32, v, p, 0);
        b.bin(IntOp::Add, acc, acc, Val::R(v));
        b.sptr_inc(p, arr, Val::I(1));
        b.free_i(v);
    });
    let myt = b.mythread();
    b.iff(Cond::Eq, myt, |b| {
        let pb = b.priv_base();
        b.st(MemWidth::U64, acc, pb, 0);
        b.free_i(pb);
    });
    let module = b.finish("quickstart");

    let ck = compile(
        &module,
        &rt,
        &CompileOpts {
            lowering,
            static_threads: false,
            numthreads: THREADS,
            volatile_stores: true,
        },
    );
    let mut m = Machine::new(MachineCfg::new(THREADS, model));
    // host-side init goes through the runtime's engine in one batch
    let vals: Vec<u64> = (0..N).map(|i| i % 97).collect();
    rt.write_u64_seq(m.mem_mut(), arr, 0, &vals);
    let res = m.run(&ck.program);
    let got = m
        .mem
        .read(MemWidth::U64, pgas_hw::mem::seg_base(0) + pgas_hw::mem::PRIV_OFF);
    let want: u64 = (0..N).map(|i| i % 97).sum();
    assert_eq!(got, want, "simulated sum must be correct");
    (res.cycles, res.total.instructions, got)
}

fn main() {
    println!("pgas-hw quickstart: shared [4] int A[{N}] over {THREADS} threads\n");
    engine_demo();
    let mut t = Table::new(
        "software Algorithm 1 vs PGAS hardware instructions",
        &["model", "variant", "cycles", "instructions", "speedup"],
    );
    for model in [CpuModel::Atomic, CpuModel::Timing, CpuModel::Detailed] {
        let (soft_c, soft_i, _) = build_and_run(Lowering::Soft, model);
        let (hw_c, hw_i, _) = build_and_run(Lowering::Hw, model);
        t.row(&[
            model.name().into(),
            "soft".into(),
            soft_c.to_string(),
            soft_i.to_string(),
            "1.00x".into(),
        ]);
        t.row(&[
            model.name().into(),
            "hw".into(),
            hw_c.to_string(),
            hw_i.to_string(),
            format!("{:.2}x", soft_c as f64 / hw_c as f64),
        ]);
    }
    println!("{}", t.render());
    println!("(both variants validated the same sum — the backends only\n change *how fast* shared pointers move, never what they mean)");
}
