//! The FPGA-prototype experiments (paper Section 6.2): vector addition
//! (Figure 15) and matrix multiplication (Figure 16) on the modeled
//! 4-core Leon3 SMP @75 MHz, plus Tables 2 and 3.
//!
//!     cargo run --release --example leon3_microbench

use pgas_hw::leon3::microbench::{
    run_matmul, run_vecadd, MatmulVariant, VecAddVariant,
};
use pgas_hw::leon3::{table2, table3};
use pgas_hw::util::table::{fnum, Table};

fn main() {
    println!("{}", table2());
    println!("{}", table3());

    // ---- Figure 15: vector addition ----
    let n = 8192;
    let mut fig15 = Table::new(
        &format!("Figure 15: Leon 3 — Vector Addition ({n} x u32, ms @75MHz)"),
        &["threads", "dynamic", "static", "privatized", "hw", "hw speedup vs dynamic"],
    );
    for threads in [1u32, 2, 4] {
        let dy = run_vecadd(threads, VecAddVariant::Dynamic, n);
        let st = run_vecadd(threads, VecAddVariant::Static, n);
        let pv = run_vecadd(threads, VecAddVariant::Privatized, n);
        let hw = run_vecadd(threads, VecAddVariant::Hw, n);
        fig15.row(&[
            threads.to_string(),
            fnum(dy.runtime_ms(), 3),
            fnum(st.runtime_ms(), 3),
            fnum(pv.runtime_ms(), 3),
            fnum(hw.runtime_ms(), 3),
            format!("{:.1}x", dy.cycles as f64 / hw.cycles as f64),
        ]);
    }
    println!("{}", fig15.render());
    println!(
        "note: the hw executable needs no static recompilation — the\n\
         `threads` special register is set at run time (paper 6.2).\n"
    );

    // ---- Figure 16: matrix multiplication ----
    let n = 32;
    let mut fig16 = Table::new(
        &format!("Figure 16: Leon 3 — Matrix Multiplication ({n}x{n} u32, ms @75MHz)"),
        &["threads", "static", "privatization 1", "privatization 2", "hw", "hw/priv2"],
    );
    for threads in [1u32, 2, 4] {
        let st = run_matmul(threads, MatmulVariant::Static, n);
        let p1 = run_matmul(threads, MatmulVariant::Priv1, n);
        let p2 = run_matmul(threads, MatmulVariant::Priv2, n);
        let hw = run_matmul(threads, MatmulVariant::Hw, n);
        fig16.row(&[
            threads.to_string(),
            fnum(st.runtime_ms(), 3),
            fnum(p1.runtime_ms(), 3),
            fnum(p2.runtime_ms(), 3),
            fnum(hw.runtime_ms(), 3),
            format!("{:.2}", hw.cycles as f64 / p2.cycles as f64),
        ]);
    }
    println!("{}", fig16.render());
    println!("all runs validated element-exact against host references.");
}
