//! End-to-end driver (the deliverable-(b) headline example): run the
//! paper's full Gem5 evaluation — the NAS kernels EP/IS/CG/MG/FT, three
//! variants each, across CPU models and core counts — on the simulated
//! machine, validate every run's numerics against host references, and
//! print every figure's table plus the headline summary.
//!
//!     cargo run --release --example npb_campaign             # full
//!     cargo run --release --example npb_campaign -- --quick  # smoke
//!
//! Results are archived to results/npb_campaign.csv.

use pgas_hw::coordinator::{self, Campaign};
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let campaign = if quick {
        Campaign::quick()
    } else {
        Campaign {
            kernels: Kernel::ALL.to_vec(),
            // atomic up to 64 cores (Figs 6-10); timing (Figs 11-14
            // series) up to 16; detailed runs are the slowest, matching
            // the paper's "multiple days are needed for a detailed run"
            models: vec![CpuModel::Atomic, CpuModel::Timing, CpuModel::Detailed],
            cores: vec![1, 2, 4, 8, 16, 32, 64],
            variants: pgas_hw::npb::PaperVariant::ALL.to_vec(),
            scale: Scale { factor: 128 },
            jobs: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    };
    eprintln!(
        "NPB campaign: {} validated simulation points (scale 1/{})",
        campaign.points().len(),
        campaign.scale.factor
    );
    // which AddressEngine backend serves each kernel's arrays (the
    // runtime mirror of the compiler's variant choice)
    println!(
        "{}",
        coordinator::engine_report(&campaign.kernels, 4, &campaign.scale).render()
    );
    let t0 = std::time::Instant::now();
    let outs = campaign.run(true);
    eprintln!("campaign wall time: {:.1}s", t0.elapsed().as_secs_f64());

    for &(k, fig) in &[
        (Kernel::Ep, "Figure 6"),
        (Kernel::Cg, "Figure 7"),
        (Kernel::Ft, "Figure 8"),
        (Kernel::Is, "Figure 9"),
        (Kernel::Mg, "Figure 10"),
    ] {
        let t = coordinator::figure_table(&outs, k, CpuModel::Atomic, fig);
        if !t.is_empty() {
            println!("{}", t.render());
        }
    }
    for &(k, fig) in &[
        (Kernel::Cg, "Figure 11"),
        (Kernel::Ft, "Figure 12"),
        (Kernel::Is, "Figure 13"),
        (Kernel::Mg, "Figure 14"),
    ] {
        for model in [CpuModel::Timing, CpuModel::Detailed] {
            let t = coordinator::figure_table(&outs, k, model, fig);
            if !t.is_empty() {
                println!("{}", t.render());
            }
        }
    }
    println!("{}", coordinator::headline_summary(&outs).render());
    // how each run's dynamic PGAS increments were served (batched
    // lookahead windows per backend vs scalar) against its speedup
    println!("{}", coordinator::engine_mix_table(&outs).render());

    std::fs::create_dir_all("results").expect("mkdir results");
    std::fs::write("results/npb_campaign.csv", coordinator::outcomes_csv(&outs))
        .expect("write csv");
    eprintln!("wrote results/npb_campaign.csv ({} rows)", outs.len());
    println!("ALL RUNS VALIDATED against host references.");
}
