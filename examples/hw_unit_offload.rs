//! The three-layer bridge in action: load the AOT-compiled batched
//! address-mapping unit (Pallas kernel -> JAX -> HLO text ->
//! PJRT executable) and stream a million shared-pointer increments
//! through it, cross-checking every batch against the scalar Rust
//! implementation and reporting throughput.
//!
//! Requires `make artifacts` (build-time Python; never run here).
//!
//!     cargo run --release --example hw_unit_offload

use std::time::Instant;

use pgas_hw::runtime::{unit_batch_scalar, UnitCfg, XlaUnit, UNIT_BATCH};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let unit = XlaUnit::load("artifacts")?;
    println!("PJRT platform: {}", unit.platform());

    let threads = 16u32;
    let layout = ArrayLayout::new(64, 8, threads); // shared [64] double
    let cfg = UnitCfg {
        log2_blocksize: 6,
        log2_elemsize: 3,
        log2_numthreads: 4,
        mythread: 0,
        log2_threads_per_mc: 1,
        log2_threads_per_node: 6,
    };
    let table = BaseTable::regular(threads, 1 << 32, 1 << 32);

    let total: usize = 1 << 20; // a million pointer increments
    let mut rng = Xoshiro256::new(42);
    let ptrs: Vec<SharedPtr> = (0..UNIT_BATCH)
        .map(|_| SharedPtr::for_index(&layout, 0, rng.below(1 << 20)))
        .collect();
    let incs: Vec<u32> = (0..UNIT_BATCH).map(|_| rng.below(1 << 12) as u32).collect();

    // correctness first: XLA unit vs scalar oracle, bit-exact
    let got = unit.unit_batch(&cfg, &table, &ptrs, &incs)?;
    let want = unit_batch_scalar(&cfg, &table, &ptrs, &incs);
    assert_eq!(got.thread, want.thread);
    assert_eq!(got.sysva, want.sysva);
    assert_eq!(got.loc, want.loc);
    println!("correctness: XLA unit == scalar oracle on {UNIT_BATCH} pointers");

    // throughput: stream `total` pointers through the unit
    let batches = total / UNIT_BATCH;
    let t0 = Instant::now();
    let mut checksum = 0i64;
    for _ in 0..batches {
        let out = unit.unit_batch(&cfg, &table, &ptrs, &incs)?;
        checksum ^= out.sysva[0];
    }
    let dt = t0.elapsed().as_secs_f64();
    let xla_rate = total as f64 / dt / 1e6;
    println!(
        "XLA unit:    {total} increments+translations in {dt:.3}s = {xla_rate:.2} M ptr/s \
         (checksum {checksum:#x})"
    );

    // same stream through the scalar hot path
    let t0 = Instant::now();
    let mut checksum2 = 0i64;
    for _ in 0..batches {
        let out = unit_batch_scalar(&cfg, &table, &ptrs, &incs);
        checksum2 ^= out.sysva[0];
    }
    let dt2 = t0.elapsed().as_secs_f64();
    println!(
        "scalar Rust: {total} increments+translations in {dt2:.3}s = {:.2} M ptr/s",
        total as f64 / dt2 / 1e6
    );
    assert_eq!(checksum, checksum2);

    // the walker artifact: one pointer traced 4096 steps on-device
    let (sysva, thread, _loc) = unit.walk(&cfg, &table, &SharedPtr::NULL, 1)?;
    // cross-check against scalar walk
    let mut p = SharedPtr::NULL;
    for i in 0..sysva.len() {
        assert_eq!(sysva[i], (table.base(p.thread) + p.va) as i64, "step {i}");
        assert_eq!(thread[i] as u32, p.thread, "step {i}");
        p = pgas_hw::sptr::increment_pow2(&p, 1, 6, 3, 4);
    }
    println!("walker: 4096-step on-device trace matches the scalar walk");
    Ok(())
}
