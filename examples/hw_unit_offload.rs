//! The three-layer bridge in action, through the unified `AddressEngine`
//! API: load the AOT-compiled batched address-mapping unit (Pallas
//! kernel -> JAX -> HLO text -> PJRT executable) as the `XlaBatchEngine`
//! backend, stream a million shared-pointer increments through it in one
//! trait call (the adapter chunks through the fixed `UNIT_BATCH`
//! artifact shape), and cross-check bit-for-bit against the software and
//! pow2 backends serving the *same* contract.
//!
//! Requires `make artifacts` and `--features xla-unit`.
//!
//!     cargo run --release --features xla-unit --example hw_unit_offload

use std::time::Instant;

use pgas_hw::engine::{
    AddressEngine, BatchOut, EngineCtx, EngineSelector, Pow2Engine, PtrBatch,
    SoftwareEngine, XlaBatchEngine,
};
use pgas_hw::runtime::{UNIT_BATCH, WALK_LEN};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let xla = XlaBatchEngine::load("artifacts")?;
    println!("PJRT platform: {}", xla.platform());

    let threads = 16u32;
    let layout = ArrayLayout::new(64, 8, threads); // shared [64] double
    let table = BaseTable::regular(threads, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 0).unwrap();

    // one request batch of a million pointers: the engine chunks it
    // through the artifacts' fixed 8192-wide shape internally
    let total: usize = 1 << 20;
    let mut rng = Xoshiro256::new(42);
    let mut req = PtrBatch::with_capacity(total);
    for _ in 0..total {
        req.push(
            SharedPtr::for_index(&layout, 0, rng.below(1 << 20)),
            rng.below(1 << 12),
        );
    }

    // correctness first: all three backends, bit-exact on the contract
    let (mut xla_out, mut soft_out, mut pow2_out) =
        (BatchOut::new(), BatchOut::new(), BatchOut::new());
    xla.translate(&ctx, &req, &mut xla_out)?;
    SoftwareEngine.translate(&ctx, &req, &mut soft_out)?;
    Pow2Engine.translate(&ctx, &req, &mut pow2_out)?;
    assert_eq!(xla_out, soft_out);
    assert_eq!(xla_out, pow2_out);
    println!(
        "correctness: xla-batch == software == pow2 on {total} pointers \
         ({} UNIT_BATCH chunks)",
        total.div_ceil(UNIT_BATCH)
    );

    // throughput of the same translate through each backend
    for engine in [&xla as &dyn AddressEngine, &Pow2Engine, &SoftwareEngine] {
        let t0 = Instant::now();
        engine.translate(&ctx, &req, &mut xla_out)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<10} {total} increments+translations in {dt:.3}s = {:.2} M ptr/s",
            engine.name(),
            total as f64 / dt / 1e6
        );
    }

    // the selector routes this big pow2 batch to the unit automatically
    let sel = EngineSelector::new().with_xla(xla);
    assert_eq!(sel.select(&layout, req.len()).name(), "xla-batch");
    println!("selector: {}-ptr pow2 batch -> `xla-batch`", req.len());

    // the walker artifact through the trait: one pointer traced
    // WALK_LEN steps on-device, checked against the software walk
    let mut walk_out = BatchOut::new();
    sel.walk(&ctx, SharedPtr::NULL, 1, WALK_LEN, &mut walk_out)?;
    let mut soft_walk = BatchOut::new();
    SoftwareEngine.walk(&ctx, SharedPtr::NULL, 1, WALK_LEN, &mut soft_walk)?;
    assert_eq!(walk_out, soft_walk);
    println!("walker: {WALK_LEN}-step on-device trace matches the software walk");
    Ok(())
}
