//! Branch-on-locality in action (paper §4.2/§5.2, Table 3's `cb`):
//! "testing for the locality of a shared pointer … can be used to
//! quickly call a communication sub-routine if the data is off-node."
//!
//! A thread walks a cyclic shared array; **local** elements take the
//! fast in-line path, **remote** elements take a slow path (standing in
//! for a communication call).  The dispatch itself is compared two
//! ways:
//!
//! * software: unpack the thread field, compare with MYTHREAD, branch
//!   (4 instructions per element);
//! * hardware: the PGAS increment already set the locality condition
//!   code — one `pgas_brloc` does the dispatch.
//!
//!     cargo run --release --example locality_dispatch

use pgas_hw::cpu::{AtomicCpu, Cpu, HierLatency, SharedLevel};
use pgas_hw::isa::{Cond, Inst, IntOp, MemWidth, Program};
use pgas_hw::mem::MemSystem;
use pgas_hw::sptr::{pack, ArrayLayout, SharedPtr, VA_BITS};
use pgas_hw::util::table::Table;

const N: i64 = 4096;
const THREADS: u32 = 4;

/// Build the walk with hardware locality dispatch: counts local
/// elements in r2 and remote ones in r3.
fn hw_dispatch() -> Program {
    let layout = ArrayLayout::new(1, 8, THREADS);
    let start = pack(&SharedPtr::for_index(&layout, 0, 0)) as i64;
    Program::new(
        "hw_dispatch",
        vec![
            Inst::Ldi { rd: 1, imm: start },
            Inst::Ldi { rd: 4, imm: N },
            // loop: 2
            Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 0, l2inc: 0 },
            // cc was set by the increment: branch if anything non-local
            Inst::PgasBrLoc { mask: 0b1110, target: 6 },
            Inst::Opi { op: IntOp::Add, rd: 2, ra: 2, imm: 1 }, // local++
            Inst::Jmp { target: 7 },
            Inst::Opi { op: IntOp::Add, rd: 3, ra: 3, imm: 1 }, // remote++ (6)
            // 7:
            Inst::Opi { op: IntOp::Add, rd: 4, ra: 4, imm: -1 },
            Inst::Br { cond: Cond::Gt, ra: 4, target: 2 },
            Inst::Halt,
        ],
    )
}

/// The same walk with the software locality test: unpack + compare.
fn soft_dispatch() -> Program {
    let layout = ArrayLayout::new(1, 8, THREADS);
    let start = pack(&SharedPtr::for_index(&layout, 0, 0)) as i64;
    Program::new(
        "soft_dispatch",
        vec![
            Inst::Ldi { rd: 1, imm: start },
            Inst::Ldi { rd: 4, imm: N },
            // loop: 2  (hardware inc, software locality test)
            Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 0, l2inc: 0 },
            Inst::Opi { op: IntOp::Srl, rd: 5, ra: 1, imm: VA_BITS as i32 },
            Inst::Opi { op: IntOp::And, rd: 5, ra: 5, imm: 0x3FF },
            Inst::Opr { op: IntOp::CmpEq, rd: 5, ra: 5, rb: 28 /* MYTHREAD */ },
            Inst::Br { cond: Cond::Eq, ra: 5, target: 9 },
            Inst::Opi { op: IntOp::Add, rd: 2, ra: 2, imm: 1 }, // local++
            Inst::Jmp { target: 10 },
            Inst::Opi { op: IntOp::Add, rd: 3, ra: 3, imm: 1 }, // remote++ (9)
            // 10:
            Inst::Opi { op: IntOp::Add, rd: 4, ra: 4, imm: -1 },
            Inst::Br { cond: Cond::Gt, ra: 4, target: 2 },
            Inst::Halt,
        ],
    )
}

fn run(prog: &Program) -> (u64, u64, u64) {
    let mut cpu = AtomicCpu::new(0, THREADS);
    cpu.state_mut().set_r(28, 0);
    cpu.state_mut().set_r(29, THREADS as u64);
    let mut mem = MemSystem::new(THREADS);
    let mut sh = SharedLevel::new(1, HierLatency::default());
    cpu.run(prog, &mut mem, &mut sh, u64::MAX);
    (cpu.stats().cycles, cpu.state().r(2), cpu.state().r(3))
}

fn main() {
    let (hw_cyc, hw_local, hw_remote) = run(&hw_dispatch());
    let (sw_cyc, sw_local, sw_remote) = run(&soft_dispatch());
    assert_eq!((hw_local, hw_remote), (sw_local, sw_remote));
    // cyclic layout over 4 threads: 1/4 of elements are local to t0
    assert_eq!(hw_local, (N as u64) / THREADS as u64);
    assert_eq!(hw_remote, (N as u64) * 3 / THREADS as u64);

    let mut t = Table::new(
        "locality dispatch: walk 4096 cyclic elements, branch local/remote",
        &["dispatch", "cycles (atomic)", "local", "remote", "vs software"],
    );
    t.row(&[
        "software (unpack+cmp+branch)".into(),
        sw_cyc.to_string(),
        sw_local.to_string(),
        sw_remote.to_string(),
        "1.00x".into(),
    ]);
    t.row(&[
        "hardware (pgas_brloc on cc)".into(),
        hw_cyc.to_string(),
        hw_local.to_string(),
        hw_remote.to_string(),
        format!("{:.2}x", sw_cyc as f64 / hw_cyc as f64),
    ]);
    println!("{}", t.render());
    println!(
        "the increment's condition code makes the local/remote dispatch\n\
         a single branch — the mechanism the paper proposes for fast\n\
         communication-call gating (condition codes 0..3, Table 3)."
    );
    // also demonstrate a read via the MemWidth to silence unused import
    let _ = MemWidth::U64;
}
