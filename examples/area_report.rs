//! Regenerate the paper's Table 4 (FPGA area cost of the PGAS support)
//! from the structural component model, plus the per-component
//! breakdown and scaling beyond the paper (1–16 cores).
//!
//!     cargo run --release --example area_report

use pgas_hw::area;
use pgas_hw::util::table::Table;

fn main() {
    println!("{}", area::table4().render());
    println!("{}", area::component_breakdown().render());

    // beyond the paper: how the support scales with core count
    let dev = area::virtex6_capacity();
    let mut t = Table::new(
        "Scaling: PGAS support area vs core count (same Virtex-6)",
        &["cores", "registers", "luts", "bram18", "dsp48", "% of chip LUTs"],
    );
    for cores in [1u32, 2, 4, 8, 16] {
        let r = area::pgas_support_total(cores);
        t.row(&[
            cores.to_string(),
            r.registers.to_string(),
            r.luts.to_string(),
            r.bram18.to_string(),
            r.dsp48.to_string(),
            format!("{:.2}%", 100.0 * r.luts as f64 / dev.luts as f64),
        ]);
    }
    println!("{}", t.render());
    println!(
        "The proposed hardware support mechanism for 4 cores utilizes \
         less than 2.4% of the overall FPGA chip (paper Section 6.2)."
    );
}
