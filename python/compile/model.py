"""L2: the JAX address-unit compute graph, calling the L1 Pallas kernels.

The paper's "model" is not a neural network -- its compute graph is the
PGAS address-mapping unit.  Two graphs are lowered to AOT artifacts:

* ``address_unit`` -- batched fused increment + translate + locality over
  UNIT_BATCH shared pointers (wraps the Pallas kernel).  The Rust
  coordinator offloads bulk pointer streams to this executable and uses it
  as the batch verification oracle against its own scalar implementation.
* ``trace_walker`` -- a ``lax.scan`` that walks one shared pointer
  WALK_LEN steps through a block-cyclic array, emitting the system virtual
  address at every step: the address trace of a UPC loop nest, produced
  entirely on-device.  This is what the simulator replays to validate the
  address streams its compiled NPB kernels generate.

Everything here runs at *build* time only (``make artifacts``); the Rust
binary loads the resulting HLO text and never touches Python.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import sptr_unit as k  # noqa: E402

# Fixed AOT shapes (PJRT executables are monomorphic; Rust pads batches).
UNIT_BATCH = 8192
WALK_LEN = 4096


def address_unit(cfg, base_table, thread, phase, va, inc):
    """Fused batched address-mapping unit (see kernels.sptr_unit).

    Returns a 5-tuple ``(nthread, nphase, nva, sysva, loc)``.
    """
    return tuple(k.sptr_unit(cfg, base_table, thread, phase, va, inc))


def _inc_pow2(cfg, thread, phase, va, inc):
    """Scalar power-of-2 Algorithm 1 in plain jnp (scan-body form).

    Identical arithmetic to the Pallas kernel's pipeline; kept in jnp so it
    can live inside ``lax.scan`` without a per-step pallas_call.
    """
    l2bs, l2es, l2nt = cfg[0], cfg[1], cfg[2]
    bs_mask = (jnp.int32(1) << l2bs) - 1
    nt_mask = (jnp.int32(1) << l2nt) - 1
    phinc = phase + inc
    thinc = phinc >> l2bs
    nphase = phinc & bs_mask
    tsum = thread + thinc
    blockinc = tsum >> l2nt
    nthread = tsum & nt_mask
    eaddrinc = (nphase - phase).astype(jnp.int64) + (
        blockinc.astype(jnp.int64) << l2bs.astype(jnp.int64))
    nva = va + (eaddrinc << l2es.astype(jnp.int64))
    return nthread, nphase, nva


def trace_walker(cfg, base_table, thread0, phase0, va0, inc):
    """Walk a shared pointer WALK_LEN steps; emit the sysva trace.

    Args:
      cfg:        int32[8] config registers (see kernels.sptr_unit).
      base_table: int64[64] per-thread base-address LUT.
      thread0, phase0: int32 scalars -- starting pointer fields.
      va0:        int64 scalar -- starting pointer va.
      inc:        int32 scalar -- per-step element increment.
    Returns:
      (sysva int64[WALK_LEN], thread int32[WALK_LEN], loc int32[WALK_LEN])
      where entry i is the state *after* i increments (entry 0 is the
      starting pointer itself).
    """
    mythread = cfg[3]
    l2mc, l2node = cfg[4], cfg[5]

    def emit(thread, va):
        sysva = jnp.take(base_table, thread) + va
        same = thread == mythread
        same_mc = (thread >> l2mc) == (mythread >> l2mc)
        same_node = (thread >> l2node) == (mythread >> l2node)
        loc = jnp.where(same, 0, jnp.where(same_mc, 1,
                        jnp.where(same_node, 2, 3))).astype(jnp.int32)
        return sysva, loc

    def step(carry, _):
        thread, phase, va = carry
        sysva, loc = emit(thread, va)
        out = (sysva, thread, loc)
        nthread, nphase, nva = _inc_pow2(cfg, thread, phase, va, inc)
        return (nthread, nphase, nva), out

    _, (sysva, thread, loc) = jax.lax.scan(
        step, (thread0, phase0, va0), None, length=WALK_LEN)
    return sysva, thread, loc


def sptr_increment(cfg, thread, phase, va, inc):
    """Increment-only batched kernel (no translation)."""
    return tuple(k.sptr_increment(cfg, thread, phase, va, inc))


def unit_example_args():
    """ShapeDtypeStructs for lowering ``address_unit``."""
    i32, i64, s = jnp.int32, jnp.int64, jax.ShapeDtypeStruct
    return (
        s((k.CFG_LEN,), i32),
        s((k.MAX_THREADS,), i64),
        s((UNIT_BATCH,), i32),
        s((UNIT_BATCH,), i32),
        s((UNIT_BATCH,), i64),
        s((UNIT_BATCH,), i32),
    )


def inc_example_args():
    """ShapeDtypeStructs for lowering the increment-only kernel."""
    i32, i64, s = jnp.int32, jnp.int64, jax.ShapeDtypeStruct
    return (
        s((k.CFG_LEN,), i32),
        s((UNIT_BATCH,), i32),
        s((UNIT_BATCH,), i32),
        s((UNIT_BATCH,), i64),
        s((UNIT_BATCH,), i32),
    )


def walker_example_args():
    """ShapeDtypeStructs for lowering ``trace_walker``."""
    i32, i64, s = jnp.int32, jnp.int64, jax.ShapeDtypeStruct
    return (
        s((k.CFG_LEN,), i32),
        s((k.MAX_THREADS,), i64),
        s((), i32),
        s((), i32),
        s((), i64),
        s((), i32),
    )
