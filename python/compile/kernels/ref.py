"""Pure-jnp oracle for the PGAS address-mapping unit.

This is the *general* software path: Algorithm 1 of the paper implemented
with true integer division/modulo, valid for any (blocksize, elemsize,
numthreads) -- including the non-power-of-2 cases the hardware does not
support (e.g. CG's ``w``/``w_tmp`` arrays with elemsize 56016).  The Pallas
kernel (``sptr_unit.py``) implements only the power-of-2 fast path with
shifts and masks, exactly like the paper's 2-stage pipelined datapath; on
power-of-2 configurations the two must agree bit-for-bit, which is the core
correctness signal checked by ``python/tests/``.

All threads/phases are int32; virtual addresses are int64 (the paper's
64-bit shared-pointer ``va`` field).
"""

import jax.numpy as jnp

# Locality condition codes (paper 5.2): 0 = local, 1 = same memory
# controller, 2 = reachable by shared load/store instructions (same node),
# 3 = other node.
LOC_LOCAL = 0
LOC_SAME_MC = 1
LOC_SAME_NODE = 2
LOC_REMOTE = 3


def sptr_increment_ref(thread, phase, va, increment, blocksize, elemsize,
                       numthreads):
    """Algorithm 1 (shared pointer incrementation), general path.

    input : blocksize, elemsize, increment, numthreads, shptr
    output: nshptr
      phinc        = shptr.phase + increment
      thinc        = phinc / blocksize
      nshptr.phase = phinc % blocksize
      blockinc     = (shptr.thread + thinc) / numthreads
      nshptr.thread= (shptr.thread + thinc) % numthreads
      eaddrinc     = (nshptr.phase - shptr.phase) + blockinc * blocksize
      nshptr.va    = shptr.va + eaddrinc * elemsize

    All array args broadcast; scalar config args may be python ints or
    jnp scalars.  ``increment`` must be non-negative (the paper's
    immediate form encodes powers of two; the register form is used with
    non-negative strides by the prototype compiler).
    """
    thread = jnp.asarray(thread, jnp.int32)
    phase = jnp.asarray(phase, jnp.int32)
    va = jnp.asarray(va, jnp.int64)
    increment = jnp.asarray(increment, jnp.int32)
    blocksize = jnp.asarray(blocksize, jnp.int32)
    elemsize = jnp.asarray(elemsize, jnp.int64)
    numthreads = jnp.asarray(numthreads, jnp.int32)

    phinc = phase + increment
    thinc = phinc // blocksize
    nphase = phinc % blocksize
    tsum = thread + thinc
    blockinc = tsum // numthreads
    nthread = tsum % numthreads
    eaddrinc = (nphase - phase).astype(jnp.int64) + (
        blockinc.astype(jnp.int64) * blocksize.astype(jnp.int64))
    nva = va + eaddrinc * elemsize
    return nthread, nphase, nva


def translate_ref(thread, va, base_table):
    """Shared pointer -> system virtual address.

    ``base_table`` is the per-thread base-address lookup table (the paper's
    second, LUT-based translation option, used by both their prototypes):
    sysva = base_table[thread] + va.
    """
    thread = jnp.asarray(thread, jnp.int32)
    va = jnp.asarray(va, jnp.int64)
    base_table = jnp.asarray(base_table, jnp.int64)
    return jnp.take(base_table, thread, axis=0) + va


def locality_ref(thread, mythread, log2_threads_per_mc, log2_threads_per_node):
    """Coprocessor condition code for the incremented address (paper 5.2).

    0 if the pointed data is owned by the current thread, 1 if it lives on
    the same memory controller, 2 if it is on the same node (reachable by
    the shared load/store instructions), 3 otherwise.
    """
    thread = jnp.asarray(thread, jnp.int32)
    mythread = jnp.asarray(mythread, jnp.int32)
    same = thread == mythread
    same_mc = (thread >> log2_threads_per_mc) == (mythread >> log2_threads_per_mc)
    same_node = (thread >> log2_threads_per_node) == (mythread >> log2_threads_per_node)
    return jnp.where(same, LOC_LOCAL,
                     jnp.where(same_mc, LOC_SAME_MC,
                               jnp.where(same_node, LOC_SAME_NODE,
                                         LOC_REMOTE))).astype(jnp.int32)


def address_unit_ref(thread, phase, va, increment, log2_blocksize,
                     log2_elemsize, log2_numthreads, base_table, mythread,
                     log2_threads_per_mc, log2_threads_per_node):
    """Full address-unit reference: increment + translate + locality.

    Takes log2 config values (the hardware's 5-bit one-hot immediates of
    Figure 3) so its interface matches the Pallas kernel exactly.
    """
    blocksize = jnp.int32(1) << jnp.asarray(log2_blocksize, jnp.int32)
    elemsize = jnp.int64(1) << jnp.asarray(log2_elemsize, jnp.int64)
    numthreads = jnp.int32(1) << jnp.asarray(log2_numthreads, jnp.int32)
    nthread, nphase, nva = sptr_increment_ref(
        thread, phase, va, increment, blocksize, elemsize, numthreads)
    sysva = translate_ref(nthread, nva, base_table)
    loc = locality_ref(nthread, mythread, log2_threads_per_mc,
                       log2_threads_per_node)
    return nthread, nphase, nva, sysva, loc
