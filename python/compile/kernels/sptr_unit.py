"""L1 Pallas kernels: the PGAS address-mapping datapath, batched.

The paper's hardware is a 2-stage pipelined shift/mask/add network that
(1) increments a UPC shared pointer through a block-cyclic layout
(Algorithm 1, power-of-2 fast path), (2) translates the resulting pointer
to a system virtual address via a per-thread base-address LUT, and
(3) emits a 2-bit locality condition code.  Here that datapath is realized
as a batched Pallas kernel: one lane per shared pointer.

TPU adaptation (DESIGN.md section "Hardware-Adaptation"): the paper's
per-cycle pipeline throughput becomes per-lane VPU throughput; the
coprocessor register file becomes a VMEM-resident tile; BlockSpec expresses
the HBM<->VMEM schedule that the paper expressed with its register file and
2-stage pipeline.  The base-address LUT gather is realized as a
broadcast-compare-select reduction (TPU-safe: no dynamic gather inside the
kernel), mirroring how a hardware LUT is a mux tree rather than indexed
DRAM.

Kernels must be lowered with ``interpret=True`` -- real TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).

Config layout (``cfg``, int32[8], one value per hardware config register /
Figure-3 immediate field):
  cfg[0] = log2(blocksize)    -- Bsize immediate (5-bit one-hot encoded)
  cfg[1] = log2(elemsize)     -- Esize immediate
  cfg[2] = log2(numthreads)   -- the special 'threads' register
  cfg[3] = mythread           -- executing thread id (for locality)
  cfg[4] = log2(threads per memory controller)
  cfg[5] = log2(threads per node)
  cfg[6], cfg[7]              -- reserved (0)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One pointer per lane; 8x128-style tiles would apply on real TPU VMEM.
# 1024 lanes keeps the per-block VMEM footprint at
# (3 outputs + 4 inputs) * 1024 * 8B ~ 56 KiB << 16 MiB VMEM.
BLOCK = 1024

# Fixed LUT capacity: the unit supports up to 64 threads (the paper's
# BigTsunami limit).  Smaller thread counts pad the table with zeros.
MAX_THREADS = 64

CFG_LEN = 8


def _inc_body(cfg_ref, thread_ref, phase_ref, va_ref, inc_ref,
              nthread_ref, nphase_ref, nva_ref):
    """Power-of-2 Algorithm 1: pure shift/mask/add (the hardware pipeline).

    Stage 1 of the paper's pipeline computes phinc/thinc/nphase;
    stage 2 computes the thread wraparound and the address increment.
    """
    l2bs = cfg_ref[0]
    l2es = cfg_ref[1]
    l2nt = cfg_ref[2]
    bs_mask = (jnp.int32(1) << l2bs) - 1
    nt_mask = (jnp.int32(1) << l2nt) - 1

    thread = thread_ref[...]
    phase = phase_ref[...]
    va = va_ref[...]
    inc = inc_ref[...]

    # -- pipeline stage 1 --
    phinc = phase + inc
    thinc = phinc >> l2bs          # phinc / blocksize
    nphase = phinc & bs_mask       # phinc % blocksize
    # -- pipeline stage 2 --
    tsum = thread + thinc
    blockinc = tsum >> l2nt        # tsum / numthreads
    nthread = tsum & nt_mask       # tsum % numthreads
    eaddrinc = (nphase - phase).astype(jnp.int64) + (
        blockinc.astype(jnp.int64) << l2bs.astype(jnp.int64))
    nva = va + (eaddrinc << l2es.astype(jnp.int64))

    nthread_ref[...] = nthread
    nphase_ref[...] = nphase
    nva_ref[...] = nva


def _lut_select(base_block, thread):
    """Hardware LUT as a mux tree: sum_t (thread == t) * base[t].

    ``base_block`` is int64[MAX_THREADS]; ``thread`` is int32[B].  The
    broadcast compare/select avoids dynamic gather inside the kernel
    (TPU-unfriendly); on MAX_THREADS=64 this is a 64-way select, the same
    structure as the FPGA prototype's BRAM-backed LUT read port.
    """
    tids = jax.lax.broadcasted_iota(jnp.int32, (MAX_THREADS,), 0)
    onehot = (thread[:, None] == tids[None, :])
    return jnp.sum(jnp.where(onehot, base_block[None, :], jnp.int64(0)),
                   axis=1)


def _unit_body(cfg_ref, base_ref, thread_ref, phase_ref, va_ref, inc_ref,
               nthread_ref, nphase_ref, nva_ref, sysva_ref, loc_ref):
    """Fused increment + translate + locality (the full coprocessor op).

    Fusing keeps each pointer's intermediate state in registers/VMEM for
    the whole round trip -- the paper's point that the unit sits *inside*
    the processor pipeline rather than out by the NIC (T3E centrifuge).
    """
    _inc_body(cfg_ref, thread_ref, phase_ref, va_ref, inc_ref,
              nthread_ref, nphase_ref, nva_ref)
    nthread = nthread_ref[...]
    nva = nva_ref[...]

    # Translation: sysva = base_table[nthread] + nva.
    sysva_ref[...] = _lut_select(base_ref[...], nthread) + nva

    # Locality condition code (00 local / 01 same-MC / 10 same-node /
    # 11 remote), used by the Coprocessor Branch instruction.
    mythread = cfg_ref[3]
    l2mc = cfg_ref[4]
    l2node = cfg_ref[5]
    same = nthread == mythread
    same_mc = (nthread >> l2mc) == (mythread >> l2mc)
    same_node = (nthread >> l2node) == (mythread >> l2node)
    loc_ref[...] = jnp.where(
        same, jnp.int32(0),
        jnp.where(same_mc, jnp.int32(1),
                  jnp.where(same_node, jnp.int32(2), jnp.int32(3))))


def _whole(shape=None):
    """BlockSpec pinning a small operand (cfg / LUT) into every block."""
    return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))


@functools.partial(jax.jit, static_argnames=())
def sptr_increment(cfg, thread, phase, va, inc):
    """Batched power-of-2 shared-pointer increment.

    Args:
      cfg:    int32[8]  config registers (see module docstring).
      thread: int32[N]  pointer thread fields.
      phase:  int32[N]  pointer phase fields.
      va:     int64[N]  pointer virtual-address fields.
      inc:    int32[N]  element increments (non-negative).
    Returns:
      (nthread int32[N], nphase int32[N], nva int64[N]).
    N must be a multiple of BLOCK (callers pad).
    """
    n = thread.shape[0]
    assert n % BLOCK == 0, f"batch {n} not a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    lane = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _inc_body,
        grid=grid,
        in_specs=[_whole((CFG_LEN,)), lane, lane, lane, lane],
        out_specs=[lane, lane, lane],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int64),
        ],
        interpret=True,
    )(cfg, thread, phase, va, inc)


@functools.partial(jax.jit, static_argnames=())
def sptr_unit(cfg, base_table, thread, phase, va, inc):
    """Batched fused increment + translate + locality.

    Args are as in :func:`sptr_increment` plus ``base_table`` int64[64]
    (the per-thread base-address LUT, zero-padded past numthreads).
    Returns ``(nthread, nphase, nva, sysva, loc)``.
    """
    n = thread.shape[0]
    assert n % BLOCK == 0, f"batch {n} not a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    lane = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _unit_body,
        grid=grid,
        in_specs=[_whole((CFG_LEN,)), _whole((MAX_THREADS,)),
                  lane, lane, lane, lane],
        out_specs=[lane, lane, lane, lane, lane],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int64),
            jax.ShapeDtypeStruct((n,), jnp.int64),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(cfg, base_table, thread, phase, va, inc)
