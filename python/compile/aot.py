"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text -- NOT ``.serialize()`` -- is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits:
  sptr_unit.hlo.txt    -- batched fused increment+translate+locality
  sptr_inc.hlo.txt     -- batched increment only
  trace_walker.hlo.txt -- scan-based address-trace generator
  manifest.txt         -- shapes/dtypes the Rust side asserts against
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (id-safe round trip)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# (artifact name, graph fn, example-args fn, human description)
ARTIFACTS = [
    ("sptr_unit", model.address_unit, model.unit_example_args,
     "fused increment+translate+locality over UNIT_BATCH pointers"),
    ("sptr_inc", model.sptr_increment, model.inc_example_args,
     "increment-only over UNIT_BATCH pointers"),
    ("trace_walker", model.trace_walker, model.walker_example_args,
     "WALK_LEN-step address-trace scan"),
]


def emit_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = [
        f"UNIT_BATCH={model.UNIT_BATCH}",
        f"WALK_LEN={model.WALK_LEN}",
        f"MAX_THREADS={model.k.MAX_THREADS}",
        f"CFG_LEN={model.k.CFG_LEN}",
    ]
    for name, fn, args_fn, desc in ARTIFACTS:
        lowered = jax.jit(fn).lower(*args_fn())
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}: {desc} ({len(text)} chars)")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts",
                   help="directory for the .hlo.txt artifacts")
    p.add_argument("--out", default=None,
                   help="(compat) single-file target; emits all artifacts "
                        "into its directory")
    args = p.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    emit_all(out_dir or ".")


if __name__ == "__main__":
    main()
