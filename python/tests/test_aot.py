"""AOT emission smoke tests: artifacts are valid, parseable HLO text."""

import os

from compile import aot, model


def test_emit_all_artifacts(tmp_path):
    out = str(tmp_path)
    aot.emit_all(out)
    for name, _, _, _ in aot.ARTIFACTS:
        path = os.path.join(out, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        text = open(path).read()
        # HLO text header + an ENTRY computation
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        # interchange must be text, never a serialized proto blob
        assert text.isprintable() or "\n" in text
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert f"UNIT_BATCH={model.UNIT_BATCH}" in manifest
    assert f"WALK_LEN={model.WALK_LEN}" in manifest


def test_unit_artifact_has_expected_parameters(tmp_path):
    out = str(tmp_path)
    aot.emit_all(out)
    text = open(os.path.join(out, "sptr_unit.hlo.txt")).read()
    # 6 parameters: cfg, base_table, thread, phase, va, inc
    for want in (f"s32[{model.UNIT_BATCH}]", "s64[64]", "s32[8]"):
        assert want in text, want
