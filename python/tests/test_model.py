"""L2 model tests: trace walker vs step-by-step oracle, shape contracts."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402
from compile.kernels import sptr_unit as k  # noqa: E402


def make_cfg(l2bs, l2es, l2nt, mythread=0, l2mc=1, l2node=3):
    return jnp.array([l2bs, l2es, l2nt, mythread, l2mc, l2node, 0, 0],
                     jnp.int32)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 6), st.integers(0, 3), st.integers(0, 4),
       st.integers(1, 9), st.integers(0, 2**31 - 1))
def test_walker_matches_stepwise_reference(l2bs, l2es, l2nt, inc, seed):
    rng = np.random.default_rng(seed)
    t = 1 << l2nt
    mythread = int(rng.integers(0, t))
    cfg = make_cfg(l2bs, l2es, l2nt, mythread,
                   max(0, l2nt - 2), max(0, l2nt - 1))
    tbl = np.zeros(k.MAX_THREADS, np.int64)
    tbl[:t] = rng.integers(0, 1 << 40, t)
    tbl = jnp.asarray(tbl)

    sysva, thread, loc = model.trace_walker(
        cfg, tbl, jnp.int32(0), jnp.int32(0), jnp.int64(0), jnp.int32(inc))
    assert sysva.shape == (model.WALK_LEN,)

    # step-by-step with the general-path oracle
    th, ph, va = jnp.int32(0), jnp.int32(0), jnp.int64(0)
    check = min(200, model.WALK_LEN)
    for i in range(check):
        want_sysva = ref.translate_ref(th, va, tbl)
        assert int(sysva[i]) == int(want_sysva), i
        assert int(thread[i]) == int(th), i
        th, ph, va = ref.sptr_increment_ref(
            th, ph, va, inc, 1 << l2bs, 1 << l2es, 1 << l2nt)


def test_walker_locality_against_ref():
    cfg = make_cfg(2, 2, 3, mythread=2, l2mc=1, l2node=2)
    tbl = jnp.zeros(k.MAX_THREADS, jnp.int64)
    _, thread, loc = model.trace_walker(
        cfg, tbl, jnp.int32(0), jnp.int32(0), jnp.int64(0), jnp.int32(1))
    want = ref.locality_ref(thread, 2, 1, 2)
    np.testing.assert_array_equal(np.asarray(loc), np.asarray(want))


def test_address_unit_full_batch_shapes_and_values():
    n = model.UNIT_BATCH
    rng = np.random.default_rng(3)
    l2bs, l2es, l2nt = 5, 3, 4
    t = 1 << l2nt
    cfg = make_cfg(l2bs, l2es, l2nt, mythread=3, l2mc=2, l2node=3)
    tbl = np.zeros(k.MAX_THREADS, np.int64)
    tbl[:t] = rng.integers(0, 1 << 44, t)
    thread = jnp.asarray(rng.integers(0, t, n, dtype=np.int32))
    phase = jnp.asarray(rng.integers(0, 1 << l2bs, n, dtype=np.int32))
    va = jnp.asarray(
        (rng.integers(0, 1 << 8, n).astype(np.int64) * (1 << l2bs)
         + np.asarray(phase)) << l2es)
    inc = jnp.asarray(rng.integers(0, 4096, n, dtype=np.int32))

    nt, nph, nva, sysva, loc = model.address_unit(
        cfg, jnp.asarray(tbl), thread, phase, va, inc)
    assert nt.shape == (n,) and sysva.dtype == jnp.int64

    want = ref.address_unit_ref(thread, phase, va, inc, l2bs, l2es, l2nt,
                                jnp.asarray(tbl), 3, 2, 3)
    for got, w in zip((nt, nph, nva, sysva, loc), want):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(w))
