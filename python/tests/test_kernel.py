"""Pallas kernel vs pure-jnp oracle -- the CORE correctness signal.

The Pallas kernel implements only the power-of-2 fast path (shift/mask,
like the paper's pipeline); the oracle implements general Algorithm 1 with
division/modulo.  On power-of-2 configurations they must agree exactly
(integer outputs -> bit equality, not allclose).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref  # noqa: E402
from compile.kernels import sptr_unit as k  # noqa: E402

N = k.BLOCK  # single-block batches keep hypothesis runs fast


def make_cfg(l2bs, l2es, l2nt, mythread=0, l2mc=1, l2node=3):
    return jnp.array([l2bs, l2es, l2nt, mythread, l2mc, l2node, 0, 0],
                     jnp.int32)


def random_pointers(rng, l2bs, l2es, l2nt, n=N):
    """Valid pointers: thread < T, 0 <= phase < blocksize, va consistent.

    va is block-aligned with the phase: va = (blocks_so_far * bs + phase)
    * esize for some small non-negative block count per thread.
    """
    bs, es, t = 1 << l2bs, 1 << l2es, 1 << l2nt
    thread = rng.integers(0, t, n, dtype=np.int32)
    phase = rng.integers(0, bs, n, dtype=np.int32)
    nblocks = rng.integers(0, 1 << 10, n).astype(np.int64)
    va = (nblocks * bs + phase) * es
    return (jnp.asarray(thread), jnp.asarray(phase), jnp.asarray(va))


def base_table(rng, t):
    tbl = np.zeros(k.MAX_THREADS, np.int64)
    # 0xff0b000000000-style distinct per-thread bases (paper 4.2 example)
    tbl[:t] = (0xFF0B << 36) + rng.integers(0, 1 << 20, t) * 0x10000
    return jnp.asarray(tbl)


cfg_strategy = st.tuples(
    st.integers(0, 10),   # log2 blocksize  (1 .. 1024 elements/block)
    st.integers(0, 6),    # log2 elemsize   (1 .. 64 bytes)
    st.integers(0, 6),    # log2 numthreads (1 .. 64 threads)
    st.integers(0, 2**31 - 1),  # rng seed
    st.integers(0, 1 << 16),    # max increment magnitude
)


@settings(max_examples=40, deadline=None)
@given(cfg_strategy)
def test_increment_kernel_matches_general_algorithm(params):
    l2bs, l2es, l2nt, seed, max_inc = params
    rng = np.random.default_rng(seed)
    thread, phase, va = random_pointers(rng, l2bs, l2es, l2nt)
    inc = jnp.asarray(rng.integers(0, max_inc + 1, N, dtype=np.int32))
    cfg = make_cfg(l2bs, l2es, l2nt)

    nt_k, np_k, nva_k = k.sptr_increment(cfg, thread, phase, va, inc)
    nt_r, np_r, nva_r = ref.sptr_increment_ref(
        thread, phase, va, inc, 1 << l2bs, 1 << l2es, 1 << l2nt)

    np.testing.assert_array_equal(np.asarray(nt_k), np.asarray(nt_r))
    np.testing.assert_array_equal(np.asarray(np_k), np.asarray(np_r))
    np.testing.assert_array_equal(np.asarray(nva_k), np.asarray(nva_r))


@settings(max_examples=25, deadline=None)
@given(cfg_strategy, st.integers(0, 63))
def test_fused_unit_matches_reference(params, myt):
    l2bs, l2es, l2nt, seed, max_inc = params
    t = 1 << l2nt
    mythread = myt % t
    rng = np.random.default_rng(seed)
    thread, phase, va = random_pointers(rng, l2bs, l2es, l2nt)
    inc = jnp.asarray(rng.integers(0, max_inc + 1, N, dtype=np.int32))
    l2mc = max(0, l2nt - 2)
    l2node = max(0, l2nt - 1)
    cfg = make_cfg(l2bs, l2es, l2nt, mythread, l2mc, l2node)
    tbl = base_table(rng, t)

    outs_k = k.sptr_unit(cfg, tbl, thread, phase, va, inc)
    outs_r = ref.address_unit_ref(
        thread, phase, va, inc, l2bs, l2es, l2nt, tbl, mythread, l2mc,
        l2node)
    for got, want in zip(outs_k, outs_r):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_zero_increment_is_identity_on_fields():
    cfg = make_cfg(4, 3, 2)
    rng = np.random.default_rng(7)
    thread, phase, va = random_pointers(rng, 4, 3, 2)
    z = jnp.zeros(N, jnp.int32)
    nt, nph, nva = k.sptr_increment(cfg, thread, phase, va, z)
    np.testing.assert_array_equal(np.asarray(nt), np.asarray(thread))
    np.testing.assert_array_equal(np.asarray(nph), np.asarray(phase))
    np.testing.assert_array_equal(np.asarray(nva), np.asarray(va))


def test_unit_increment_walks_figure2_layout():
    """shared [4] int arrayA[32] over 4 threads (paper Figure 2).

    Walking the array element-by-element must visit threads
    0,0,0,0,1,1,1,1,2,2,2,2,3,3,3,3,0,... and bump va by 4 bytes within a
    block and by 16 bytes when wrapping back to a thread.
    """
    l2bs, l2es, l2nt = 2, 2, 2  # blocksize 4, int (4 bytes), 4 threads
    cfg = make_cfg(l2bs, l2es, l2nt)
    thread = jnp.zeros(N, jnp.int32)
    phase = jnp.zeros(N, jnp.int32)
    va = jnp.zeros(N, jnp.int64)
    one = jnp.ones(N, jnp.int32)

    seen = []
    t, ph, v = thread, phase, va
    for _ in range(32):
        seen.append((int(t[0]), int(ph[0]), int(v[0])))
        t, ph, v = k.sptr_increment(cfg, t, ph, v, one)

    for i, (ti, pi, vi) in enumerate(seen):
        blk, off = divmod(i, 4)
        assert ti == blk % 4, (i, seen[i])
        assert pi == off, (i, seen[i])
        assert vi == (blk // 4) * 16 + off * 4, (i, seen[i])


def test_locality_codes_all_four_levels():
    # 8 threads, 2 per MC, 4 per node, mythread = 0
    cfg = make_cfg(0, 0, 3, mythread=0, l2mc=1, l2node=2)
    tbl = jnp.zeros(k.MAX_THREADS, jnp.int64)
    thread = jnp.asarray(np.arange(N, dtype=np.int32) % 8)
    phase = jnp.zeros(N, jnp.int32)
    va = jnp.zeros(N, jnp.int64)
    z = jnp.zeros(N, jnp.int32)
    *_, loc = k.sptr_unit(cfg, tbl, thread, phase, va, z)
    loc = np.asarray(loc)
    want = {0: 0, 1: 1, 2: 2, 3: 2, 4: 3, 5: 3, 6: 3, 7: 3}
    for tid, code in want.items():
        assert (loc[np.asarray(thread) == tid] == code).all(), (tid, code)


def test_translation_matches_paper_example():
    """ptrC of Figure 2: base(thread 1) + 0x3f00 = 0xff0b00003f00.

    (The paper prints the base as 0xff0b000000000, one zero too many for
    its own sum 0xff0b00003f00; we use the self-consistent reading.)
    """
    tbl = np.zeros(k.MAX_THREADS, np.int64)
    tbl[1] = 0xFF0B00000000
    got = ref.translate_ref(jnp.int32(1), jnp.int64(0x3F00),
                            jnp.asarray(tbl))
    assert int(got) == 0xFF0B00003F00


@pytest.mark.parametrize("l2nt", [0, 2, 6])
def test_many_wraparounds(l2nt):
    """Incrementing past the end of many blocks stays consistent with a
    step-by-step walk (inc(a) o inc(b) == inc(a+b))."""
    l2bs, l2es = 3, 2
    cfg = make_cfg(l2bs, l2es, l2nt)
    rng = np.random.default_rng(42)
    thread, phase, va = random_pointers(rng, l2bs, l2es, l2nt)
    a = jnp.asarray(rng.integers(0, 1000, N, dtype=np.int32))
    b = jnp.asarray(rng.integers(0, 1000, N, dtype=np.int32))

    t1, p1, v1 = k.sptr_increment(cfg, thread, phase, va, a)
    t2, p2, v2 = k.sptr_increment(cfg, t1, p1, v1, b)
    t3, p3, v3 = k.sptr_increment(cfg, thread, phase, va, a + b)
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(t3))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p3))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v3))
