"""Boundary-condition sweeps for the Pallas kernels: the edges the
hardware's bit-widths define — max threads (64), max blocksize exponent,
zero-length effective configs, and increments that cross many blocks and
wrap the thread ring many times."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref  # noqa: E402
from compile.kernels import sptr_unit as k  # noqa: E402

N = k.BLOCK


def cfg(l2bs, l2es, l2nt, myt=0, l2mc=1, l2node=6):
    return jnp.array([l2bs, l2es, l2nt, myt, l2mc, l2node, 0, 0], jnp.int32)


def test_max_threads_boundary():
    """64 threads (the artifact LUT capacity, the paper's core limit)."""
    l2nt = 6
    thread = jnp.asarray(np.arange(N, dtype=np.int32) % 64)
    phase = jnp.zeros(N, jnp.int32)
    va = jnp.zeros(N, jnp.int64)
    inc = jnp.full((N,), 1, jnp.int32)
    nt, nph, nva = k.sptr_increment(cfg(0, 3, l2nt), thread, phase, va, inc)
    want = ref.sptr_increment_ref(thread, phase, va, inc, 1, 8, 64)
    np.testing.assert_array_equal(np.asarray(nt), np.asarray(want[0]))
    # thread 63 + 1 wraps to 0 with a va bump
    idx63 = np.where(np.asarray(thread) == 63)[0][0]
    assert int(nt[idx63]) == 0
    assert int(nva[idx63]) == 8


def test_single_thread_is_linear_memory():
    """THREADS=1: the shared array degenerates to a private array."""
    thread = jnp.zeros(N, jnp.int32)
    phase = jnp.asarray(np.arange(N, dtype=np.int32) % 16)
    va = (jnp.asarray(np.arange(N, dtype=np.int64))) * 4
    inc = jnp.full((N,), 5, jnp.int32)
    nt, _, nva = k.sptr_increment(cfg(4, 2, 0), thread, phase, va, inc)
    assert (np.asarray(nt) == 0).all()
    np.testing.assert_array_equal(np.asarray(nva), np.asarray(va) + 20)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_huge_increments_cross_many_rings(seed):
    """Increments up to 2^20 elements: many block and ring wraps."""
    rng = np.random.default_rng(seed)
    l2bs, l2es, l2nt = 3, 3, 4
    thread = jnp.asarray(rng.integers(0, 16, N, dtype=np.int32))
    phase = jnp.asarray(rng.integers(0, 8, N, dtype=np.int32))
    va = jnp.asarray(
        ((rng.integers(0, 1 << 10, N).astype(np.int64) * 8)
         + np.asarray(phase)) << l2es)
    inc = jnp.asarray(rng.integers(0, 1 << 20, N, dtype=np.int32))
    got = k.sptr_increment(cfg(l2bs, l2es, l2nt), thread, phase, va, inc)
    want = ref.sptr_increment_ref(thread, phase, va, inc, 8, 8, 16)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_locality_mc_equals_node_granularity():
    """When MC == node granularity, code 1 absorbs code 2."""
    thread = jnp.asarray(np.arange(N, dtype=np.int32) % 8)
    loc = ref.locality_ref(thread, 0, 2, 2)
    loc = np.asarray(loc)
    th = np.asarray(thread)
    assert (loc[th == 0] == 0).all()
    assert (loc[(th > 0) & (th < 4)] == 1).all()
    assert (loc[th >= 4] == 3).all()


def test_unit_batch_full_lut_padding():
    """Threads < 64 leave LUT tail zero; sysva must never read the tail."""
    t = 4
    tbl = np.zeros(k.MAX_THREADS, np.int64)
    tbl[:t] = [0x1_0000_0000 * (i + 1) for i in range(t)]
    thread = jnp.asarray(np.arange(N, dtype=np.int32) % t)
    phase = jnp.zeros(N, jnp.int32)
    va = jnp.full((N,), 0x100, jnp.int64)
    inc = jnp.zeros(N, jnp.int32)
    *_, sysva, _ = k.sptr_unit(
        cfg(2, 2, 2), jnp.asarray(tbl), thread, phase, va, inc)
    sysva = np.asarray(sysva)
    for i in range(64):
        th = i % t
        assert sysva[i] == tbl[th] + 0x100, i
