//! Hot-path micro-bench: the shared-pointer algebra itself — the
//! operations the simulator executes billions of times. §Perf L3 target:
//! the simulator's per-instruction cost must not be dominated by
//! Algorithm 1 bookkeeping.

use pgas_hw::sptr::{increment_general, increment_pow2, pack, unpack, ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::bench::{bench, black_box};

fn main() {
    let layout = ArrayLayout::new(64, 8, 16);
    let table = BaseTable::regular(16, 1 << 32, 1 << 32);
    let n = 1_000_000u64;

    let r = bench("increment_general x1M", 2, 10, || {
        let mut p = SharedPtr::NULL;
        for i in 0..n {
            p = increment_general(&p, (i & 7) + 1, &layout);
        }
        black_box(p);
    });
    println!("  -> {:.1} M inc/s", n as f64 / r.mean_secs() / 1e6);

    let r = bench("increment_pow2 x1M (the hw datapath)", 2, 10, || {
        let mut p = SharedPtr::NULL;
        for i in 0..n {
            p = increment_pow2(&p, (i & 7) + 1, 6, 3, 4);
        }
        black_box(p);
    });
    println!("  -> {:.1} M inc/s", n as f64 / r.mean_secs() / 1e6);

    let r = bench("pack/unpack roundtrip x1M", 2, 10, || {
        let mut acc = 0u64;
        for i in 0..n {
            let p = unpack(i.wrapping_mul(0x9E3779B97F4A7C15) & ((1 << 62) - 1));
            acc ^= pack(&p);
        }
        black_box(acc);
    });
    println!("  -> {:.1} M roundtrips/s", n as f64 / r.mean_secs() / 1e6);

    let r = bench("translate x1M", 2, 10, || {
        let mut acc = 0u64;
        let mut p = SharedPtr::NULL;
        for _ in 0..n {
            p = increment_pow2(&p, 3, 6, 3, 4);
            acc ^= p.translate(&table);
        }
        black_box(acc);
    });
    println!("  -> {:.1} M translations/s", n as f64 / r.mean_secs() / 1e6);
}
