//! `cargo bench` target regenerating the paper's Figure 14.
//! Shape expectation: timing/detailed MG
use pgas_hw::coordinator::bench_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_figure(
        "Figure 14",
        Kernel::Mg,
        &[CpuModel::Timing, CpuModel::Detailed],
        &[1, 2, 4, 8, 16],
        Scale { factor: 2048 },
    );
}
