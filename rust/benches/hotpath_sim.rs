//! Hot-path micro-bench: raw simulator speed (simulated instructions
//! per host second) per CPU model — the §Perf L3 metric. The atomic
//! model is the campaign's workhorse; its M instr/s bound the wall time
//! of every figure sweep.

use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{run, Kernel, PaperVariant, Scale};
use pgas_hw::util::bench::{bench, black_box};

fn main() {
    let scale = Scale { factor: 256 };
    for model in CpuModel::ALL {
        let mut insts = 0u64;
        let r = bench(&format!("MG unopt x4 [{model}]"), 1, 3, || {
            let out = run(Kernel::Mg, PaperVariant::Unopt, model, 4, &scale);
            insts = out.result.total.instructions;
            black_box(out);
        });
        println!(
            "  -> {:.1} M simulated instr/s ({} instrs)",
            insts as f64 / r.mean_secs() / 1e6,
            insts
        );
    }
    // pure-ISA interpreter ceiling: EP (no shared ops, no validation
    // overhead beyond the reduction)
    let mut insts = 0u64;
    let r = bench("EP unopt x4 [atomic] (interpreter ceiling)", 1, 3, || {
        let out = run(Kernel::Ep, PaperVariant::Unopt, CpuModel::Atomic, 4, &scale);
        insts = out.result.total.instructions;
        black_box(out);
    });
    println!(
        "  -> {:.1} M simulated instr/s",
        insts as f64 / r.mean_secs() / 1e6
    );
}
