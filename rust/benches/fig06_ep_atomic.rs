//! `cargo bench` target regenerating the paper's Figure 6.
//! Shape expectation: EP gains ~nothing from HW (no shared pointers in the main loop)
use pgas_hw::coordinator::bench_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_figure(
        "Figure 6",
        Kernel::Ep,
        &[CpuModel::Atomic],
        &[1, 2, 4, 8, 16, 32, 64],
        Scale { factor: 1024 },
    );
}
