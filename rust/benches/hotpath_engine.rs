//! Hot-path micro-bench: the `AddressEngine` backends head-to-head on
//! the increment/translate contract — the operation count that bounds
//! every host-side array init/validation and any future engine service.
//! Emits a `BENCH_engine.json` trajectory point.
//!
//! The xla-batch backend joins automatically when built with
//! `--features xla-unit` and artifacts are present.

use pgas_hw::engine::{AddressEngine, BatchOut, EngineCtx, Pow2Engine, PtrBatch, SoftwareEngine};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::bench::{bench, black_box};
use pgas_hw::util::rng::Xoshiro256;

fn main() {
    let layout = ArrayLayout::new(64, 8, 16); // shared [64] double over 16 threads
    let table = BaseTable::regular(16, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 0);

    let n: usize = 1 << 16;
    let mut rng = Xoshiro256::new(0xBE7C);
    let mut batch = PtrBatch::with_capacity(n);
    for _ in 0..n {
        batch.push(
            SharedPtr::for_index(&layout, 0, rng.below(1 << 20)),
            rng.below(1 << 12),
        );
    }

    let mut engines: Vec<&dyn AddressEngine> = vec![&SoftwareEngine, &Pow2Engine];
    #[cfg(feature = "xla-unit")]
    let xla = match pgas_hw::engine::XlaBatchEngine::load("artifacts") {
        Ok(x) => Some(x),
        Err(e) => {
            eprintln!("xla-batch backend skipped: {e}");
            None
        }
    };
    #[cfg(feature = "xla-unit")]
    if let Some(x) = &xla {
        engines.push(x);
    }

    let mut rows = Vec::new();
    for engine in engines {
        let mut out = BatchOut::new();
        let r = bench(
            &format!("engine::{} translate x{n}", engine.name()),
            2,
            10,
            || {
                engine.translate(&ctx, &batch, &mut out).unwrap();
                black_box(&out);
            },
        );
        let translate_mptr_s = n as f64 / r.mean_secs() / 1e6;
        println!("  -> {translate_mptr_s:.1} M ptr/s (increment+translate+locality)");

        let mut incs = Vec::new();
        let r = bench(
            &format!("engine::{} increment x{n}", engine.name()),
            2,
            10,
            || {
                engine.increment(&ctx, &batch, &mut incs).unwrap();
                black_box(&incs);
            },
        );
        let increment_mptr_s = n as f64 / r.mean_secs() / 1e6;
        println!("  -> {increment_mptr_s:.1} M ptr/s (increment only)");

        rows.push(format!(
            "    {{\"name\": \"{}\", \"translate_mptr_s\": {translate_mptr_s:.2}, \
             \"increment_mptr_s\": {increment_mptr_s:.2}}}",
            engine.name()
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"hotpath_engine\",\n  \"batch\": {n},\n  \
         \"layout\": {{\"blocksize\": 64, \"elemsize\": 8, \"numthreads\": 16}},\n  \
         \"backends\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_engine.json", &json).expect("write BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}
