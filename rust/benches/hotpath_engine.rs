//! Hot-path micro-bench: the `AddressEngine` backends head-to-head on
//! the increment/translate contract — the operation count that bounds
//! every host-side array init/validation and any future engine service.
//! Emits a `BENCH_engine.json` trajectory point with three sections:
//!
//! * `backends` — scalar translate/increment throughput per backend;
//! * `walk` — the O(1) `WalkCursor` stepper vs the old per-step
//!   divide/modulo walk;
//! * `sharded` — `ShardedEngine` (software inner) vs single-threaded
//!   `SoftwareEngine` on a large batch;
//! * `leon3` — the coprocessor-model replay: host throughput (the
//!   measured `CostModel::leon3_ns_per_ptr` coefficient) and the
//!   deterministic simulated cycles/pointer at 75 MHz;
//! * `remote` — the worker-process pool over Unix-domain sockets: the
//!   measured `remote_dispatch_ns`/`remote_ns_per_ptr` cost-model legs
//!   plus throughput head-to-head with the thread tier on the same
//!   batch (the honest record of what the socket hop costs);
//! * `daemon` — epoch sessions vs snapshot-per-request against one
//!   in-process daemon on a wide (4096-thread) base table: the
//!   per-request dispatch overhead `InstallCtx{epoch}` amortizes away,
//!   gated so steady state never costs more than re-shipping the ctx.
//! * `resilience` — the degradation ladder's price: a healthy selector
//!   vs one whose every dispatch draws an injected fault and is
//!   transparently re-served by the chaos-exempt fallback floor.
//! * `gather` — the inspector/executor tier: per-owner aggregated
//!   dispatch (`GatherPlan`) vs naive per-element `translate_one`,
//!   plus the measured bucketing cost the selector's gather threshold
//!   is priced off.
//! * `simd` — the vectorized software tier: lane-wise shift/mask
//!   (pow2) and multiply-by-reciprocal (general) translation vs the
//!   scalar `SoftwareEngine` on the same batches, plus the measured
//!   `CostModel::simd_ns_per_ptr` coefficient.  The acceptance gate
//!   asserts the lanes beat scalar on *both* geometries at >= 1k ptrs.
//! * `plan` — the cache-blocked batch planner: `TilePlan`-tiled
//!   execution (affinity-sorted L1/L2-sized tiles) vs direct
//!   single-pass dispatch, single-threaded and over the shard pool.
//!
//! `--quick` (the CI smoke leg) shrinks batch sizes and iteration
//! counts.  The xla-batch backend joins automatically when built with
//! `--features xla-unit` and artifacts are present.

use pgas_hw::engine::{
    AddressEngine, BatchOut, EngineCtx, Leon3Engine, Pow2Engine, PtrBatch,
    RemoteEngine, ShardedEngine, SoftwareEngine,
};
use pgas_hw::sptr::{
    increment_general, locality, ArrayLayout, BaseTable, SharedPtr,
};
use pgas_hw::util::bench::{bench, black_box};
use pgas_hw::util::rng::Xoshiro256;

/// The pre-stepper baseline: the complete divide/modulo Algorithm 1
/// paid on every step (what `SoftwareEngine::walk` did before
/// `WalkCursor`).  Kept here so the bench records the win per PR.
fn divmod_walk(
    ctx: &EngineCtx,
    start: SharedPtr,
    inc: u64,
    steps: usize,
    out: &mut BatchOut,
) {
    out.clear();
    out.reserve(steps);
    let mut p = start;
    for _ in 0..steps {
        out.push(
            p,
            p.translate(ctx.table()),
            locality(p.thread, ctx.mythread(), ctx.topo()),
        );
        p = increment_general(&p, inc, ctx.layout());
    }
}

fn random_batch(layout: &ArrayLayout, n: usize, seed: u64) -> PtrBatch {
    let mut rng = Xoshiro256::new(seed);
    let mut batch = PtrBatch::with_capacity(n);
    for _ in 0..n {
        batch.push(
            SharedPtr::for_index(layout, 0, rng.below(1 << 20)),
            rng.below(1 << 12),
        );
    }
    batch
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (warmup, iters) = if quick { (1, 3) } else { (2, 10) };

    let layout = ArrayLayout::new(64, 8, 16); // shared [64] double over 16 threads
    let table = BaseTable::regular(16, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 0).unwrap();

    // ---- scalar backends: translate / increment ----
    let n: usize = if quick { 1 << 13 } else { 1 << 16 };
    let batch = random_batch(&layout, n, 0xBE7C);

    let mut engines: Vec<&dyn AddressEngine> = vec![&SoftwareEngine, &Pow2Engine];
    #[cfg(feature = "xla-unit")]
    let xla = match pgas_hw::engine::XlaBatchEngine::load("artifacts") {
        Ok(x) => Some(x),
        Err(e) => {
            eprintln!("xla-batch backend skipped: {e}");
            None
        }
    };
    #[cfg(feature = "xla-unit")]
    if let Some(x) = &xla {
        engines.push(x);
    }

    let mut rows = Vec::new();
    for engine in engines {
        let mut out = BatchOut::new();
        let r = bench(
            &format!("engine::{} translate x{n}", engine.name()),
            warmup,
            iters,
            || {
                engine.translate(&ctx, &batch, &mut out).unwrap();
                black_box(&out);
            },
        );
        let translate_mptr_s = n as f64 / r.mean_secs() / 1e6;
        println!("  -> {translate_mptr_s:.1} M ptr/s (increment+translate+locality)");

        let mut incs = Vec::new();
        let r = bench(
            &format!("engine::{} increment x{n}", engine.name()),
            warmup,
            iters,
            || {
                engine.increment(&ctx, &batch, &mut incs).unwrap();
                black_box(&incs);
            },
        );
        let increment_mptr_s = n as f64 / r.mean_secs() / 1e6;
        println!("  -> {increment_mptr_s:.1} M ptr/s (increment only)");

        rows.push(format!(
            "    {{\"name\": \"{}\", \"translate_mptr_s\": {translate_mptr_s:.2}, \
             \"increment_mptr_s\": {increment_mptr_s:.2}}}",
            engine.name()
        ));
    }

    // ---- walk: O(1) stepper vs per-step divide/modulo ----
    let steps: usize = if quick { 1 << 13 } else { 1 << 16 };
    let start = SharedPtr::for_index(&layout, 0, 17);
    let inc = 3u64;
    let mut out = BatchOut::new();
    let r = bench(
        &format!("walk(div/mod baseline) x{steps}"),
        warmup,
        iters,
        || {
            divmod_walk(&ctx, start, inc, steps, &mut out);
            black_box(&out);
        },
    );
    let divmod_msteps_s = steps as f64 / r.mean_secs() / 1e6;
    let r = bench(
        &format!("walk(WalkCursor stepper) x{steps}"),
        warmup,
        iters,
        || {
            SoftwareEngine.walk(&ctx, start, inc, steps, &mut out).unwrap();
            black_box(&out);
        },
    );
    let stepper_msteps_s = steps as f64 / r.mean_secs() / 1e6;
    let walk_speedup = stepper_msteps_s / divmod_msteps_s;
    println!(
        "  -> walk: {divmod_msteps_s:.1} -> {stepper_msteps_s:.1} M step/s \
         ({walk_speedup:.2}x stepper speedup)"
    );

    // ---- sharded pool vs single-threaded software on a large batch ----
    let workers = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    let big_n: usize = if quick { 1 << 15 } else { 1 << 18 };
    let big = random_batch(&layout, big_n, 0x5AAD);
    let sharded = ShardedEngine::new(SoftwareEngine, workers);
    let r = bench(
        &format!("engine::software translate x{big_n}"),
        warmup,
        iters,
        || {
            SoftwareEngine.translate(&ctx, &big, &mut out).unwrap();
            black_box(&out);
        },
    );
    let single_mptr_s = big_n as f64 / r.mean_secs() / 1e6;
    let r = bench(
        &format!("engine::sharded(software x{workers}) translate x{big_n}"),
        warmup,
        iters,
        || {
            sharded.translate(&ctx, &big, &mut out).unwrap();
            black_box(&out);
        },
    );
    let sharded_mptr_s = big_n as f64 / r.mean_secs() / 1e6;
    let sharded_speedup = sharded_mptr_s / single_mptr_s;
    println!(
        "  -> sharded: {single_mptr_s:.1} -> {sharded_mptr_s:.1} M ptr/s \
         ({sharded_speedup:.2}x over single-threaded software, {workers} workers)"
    );

    // ---- leon3 coprocessor model: instruction replay on the
    // functional core (much slower on the host — that is the point:
    // this measures the CostModel coefficient that keeps it honest) ----
    let l3_n: usize = if quick { 1 << 11 } else { 1 << 13 };
    let l3_batch = random_batch(&layout, l3_n, 0x1E03);
    let leon3 = Leon3Engine::new();
    let r = bench(
        &format!("engine::leon3 translate x{l3_n}"),
        warmup,
        iters,
        || {
            leon3.translate(&ctx, &l3_batch, &mut out).unwrap();
            black_box(&out);
        },
    );
    let leon3_mptr_s = l3_n as f64 / r.mean_secs() / 1e6;
    let leon3_ns_per_ptr = r.mean_secs() * 1e9 / l3_n as f64;
    let leon3_cyc_per_ptr = leon3.last_cycles() as f64 / l3_n as f64;
    println!(
        "  -> leon3: {leon3_mptr_s:.2} M ptr/s host ({leon3_ns_per_ptr:.0} \
         ns/ptr — the measured cost-model coefficient), \
         {leon3_cyc_per_ptr:.1} simulated cycles/ptr @75MHz"
    );

    // ---- remote process pool: measured dispatch + per-ptr legs and
    // throughput vs the thread tier on the same batch (cargo builds
    // the CLI for benches, so the worker binary is always at hand) ----
    let rworkers = workers.min(4);
    let remote = RemoteEngine::spawn_with_bin(
        env!("CARGO_BIN_EXE_pgas-hw"),
        rworkers,
    )
    .expect("spawn remote worker pool");
    let (remote_ns_per_ptr, remote_dispatch_ns) =
        remote.calibrate().expect("calibrate remote pool");
    let r = bench(
        &format!("engine::remote(auto x{rworkers}) translate x{big_n}"),
        warmup,
        iters,
        || {
            remote.translate(&ctx, &big, &mut out).unwrap();
            black_box(&out);
        },
    );
    let remote_mptr_s = big_n as f64 / r.mean_secs() / 1e6;
    let remote_vs_sharded = remote_mptr_s / sharded_mptr_s;
    println!(
        "  -> remote: {remote_dispatch_ns:.0} ns dispatch, \
         {remote_ns_per_ptr:.1} ns/ptr (the measured cost-model legs); \
         {remote_mptr_s:.1} M ptr/s vs sharded {sharded_mptr_s:.1} \
         ({remote_vs_sharded:.2}x, {rworkers} workers)"
    );

    // ---- daemon tier: epoch sessions vs snapshot-per-request against
    // one in-process daemon.  A wide table (many threads) makes the
    // per-request ctx snapshot expensive, so this measures exactly what
    // `InstallCtx{epoch}` amortizes: steady-state frames carry only
    // `epoch + batch`, the v1-style client re-ships the table every
    // time.  Small batches × many requests = per-request dispatch cost,
    // not per-pointer throughput (the `remote` section above owns that).
    use pgas_hw::daemon::{scratch_socket, Daemon, DaemonCfg};
    let dthreads: u32 = if quick { 512 } else { 4096 };
    let dlayout = ArrayLayout::new(8, 8, dthreads);
    let dtable = BaseTable::regular(dthreads, 1 << 32, 1 << 32);
    let dctx = EngineCtx::new(dlayout, &dtable, 0).unwrap();
    let reqs: usize = if quick { 64 } else { 256 };
    let req_n: usize = 64;
    let req_batch = random_batch(&dlayout, req_n, 0xDAE1);
    let cfg = DaemonCfg::new(scratch_socket("bench"));
    let dsock = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    let (steady_ns_per_req, snapshot_ns_per_req, steady_hits, steady_installs);
    {
        let steady = RemoteEngine::connect(&dsock, 1).expect("connect steady");
        let r = bench(
            &format!("daemon steady (epoch sessions) {reqs} reqs x{req_n}"),
            warmup,
            iters,
            || {
                for _ in 0..reqs {
                    steady.translate(&dctx, &req_batch, &mut out).unwrap();
                    black_box(&out);
                }
            },
        );
        steady_ns_per_req = r.mean_secs() * 1e9 / reqs as f64;
        steady_hits = steady.epoch_hits();
        steady_installs = steady.installs();
        let snap = RemoteEngine::connect(&dsock, 1)
            .expect("connect snapshot")
            .with_reinstall_every_request(true);
        let r = bench(
            &format!("daemon snapshot-per-request {reqs} reqs x{req_n}"),
            warmup,
            iters,
            || {
                for _ in 0..reqs {
                    snap.translate(&dctx, &req_batch, &mut out).unwrap();
                    black_box(&out);
                }
            },
        );
        snapshot_ns_per_req = r.mean_secs() * 1e9 / reqs as f64;
    }
    let dstats = daemon.shutdown().expect("daemon shutdown");
    let epoch_speedup = snapshot_ns_per_req / steady_ns_per_req;
    println!(
        "  -> daemon: {steady_ns_per_req:.0} ns/req steady (installs \
         {steady_installs}, epoch hits {steady_hits}) vs \
         {snapshot_ns_per_req:.0} ns/req snapshot-per-request \
         ({epoch_speedup:.2}x; {dthreads}-thread table, {} sessions)",
        dstats.sessions
    );
    // The acceptance gate: epoch sessions must not cost more per
    // request than re-shipping the snapshot (10% noise headroom —
    // steady state does strictly less work per frame).
    assert!(
        steady_ns_per_req <= snapshot_ns_per_req * 1.10,
        "epoch sessions slower than snapshot-per-request: \
         {steady_ns_per_req:.0} vs {snapshot_ns_per_req:.0} ns/req"
    );

    // ---- resilience: what the degradation ladder costs.  A healthy
    // selector serves the batch on its cost-model argmin; a chaos-armed
    // one (`error=1.0`) sees every primary dispatch fail injected and
    // transparently re-serves it on the chaos-exempt fallback floor.
    // `reset_health()` each iteration keeps the breaker closed so every
    // iteration measures the same inject -> fail -> re-serve path, not
    // a quarantined steady state.  Batch below the shard threshold so
    // both sides stay on the scalar tiers. ----
    use pgas_hw::engine::{EngineSelector, FaultPlan, FaultSpec};
    use std::sync::Arc;
    let res_n: usize = if quick { 1 << 11 } else { 1 << 12 };
    let res_batch = random_batch(&layout, res_n, 0xFA11);
    let mut rincs = Vec::new();
    let healthy = EngineSelector::new();
    let r = bench(
        &format!("selector healthy increment x{res_n}"),
        warmup,
        iters,
        || {
            healthy.increment(&ctx, &res_batch, &mut rincs).unwrap();
            black_box(&rincs);
        },
    );
    let healthy_ns_per_ptr = r.mean_secs() * 1e9 / res_n as f64;
    let storm = Arc::new(FaultPlan::new(
        FaultSpec::parse("0xFA11:error=1.0").unwrap(),
    ));
    let degraded = EngineSelector::new().with_chaos(Arc::clone(&storm));
    let r = bench(
        &format!("selector degraded (error=1.0) increment x{res_n}"),
        warmup,
        iters,
        || {
            degraded.reset_health();
            degraded.increment(&ctx, &res_batch, &mut rincs).unwrap();
            black_box(&rincs);
        },
    );
    let fallback_ns_per_ptr = r.mean_secs() * 1e9 / res_n as f64;
    let fallback_overhead = fallback_ns_per_ptr / healthy_ns_per_ptr;
    println!(
        "  -> resilience: {healthy_ns_per_ptr:.1} ns/ptr healthy vs \
         {fallback_ns_per_ptr:.1} ns/ptr re-served through the fallback \
         floor ({fallback_overhead:.2}x; {} faults absorbed)",
        storm.injected()
    );
    assert!(storm.injected() > 0, "chaos selector never drew a fault");

    // ---- gather: the inspector/executor tier vs per-element
    // dispatch.  The per-element leg is what a naive executor pays for
    // a data-dependent gather: one engine dispatch per pointer
    // (`translate_one`, a 1-element batch each).  The planned leg runs
    // the full inspector/executor path — bucket by owner, one
    // aggregated dispatch per owner, splice back to request order —
    // with the plan construction cost *included* every iteration.
    // Bit-identical results are the conformance suite's job
    // (`tests/gather_conformance.rs`); this records what aggregation
    // buys at production batch sizes. ----
    use pgas_hw::engine::GatherPlan;
    let g_n: usize = if quick { 1 << 12 } else { 1 << 15 };
    let g_batch = random_batch(&layout, g_n, 0x6A7E);
    let r = bench(
        &format!("gather per-element (translate_one) x{g_n}"),
        warmup,
        iters,
        || {
            out.clear();
            out.reserve(g_n);
            for i in 0..g_batch.len() {
                let (p, va, loc) = Pow2Engine
                    .translate_one(&ctx, g_batch.ptrs[i], g_batch.incs[i])
                    .unwrap();
                out.push(p, va, loc);
            }
            black_box(&out);
        },
    );
    let per_element_ns_per_ptr = r.mean_secs() * 1e9 / g_n as f64;
    let gplan = GatherPlan::from_batch(&ctx, &g_batch).unwrap();
    let g_owners = gplan.bucket_count();
    let r = bench(
        &format!("gather planned (inspector/executor) x{g_n}"),
        warmup,
        iters,
        || {
            let plan = GatherPlan::from_batch(&ctx, &g_batch).unwrap();
            plan.execute(&Pow2Engine, &ctx, &mut out).unwrap();
            black_box(&out);
        },
    );
    let planned_ns_per_ptr = r.mean_secs() * 1e9 / g_n as f64;
    let gather_speedup = per_element_ns_per_ptr / planned_ns_per_ptr;
    let (bucket_ns_per_ptr, plan_setup_ns) = GatherPlan::calibrate();
    println!(
        "  -> gather: {per_element_ns_per_ptr:.1} ns/ptr per-element vs \
         {planned_ns_per_ptr:.1} ns/ptr planned ({gather_speedup:.2}x, \
         {g_owners} owner buckets; bucketing {bucket_ns_per_ptr:.2} ns/ptr, \
         plan setup {plan_setup_ns:.0} ns)"
    );
    // The acceptance gate: aggregated dispatch must beat per-element
    // translate at production batch sizes (10% noise headroom).
    assert!(
        planned_ns_per_ptr <= per_element_ns_per_ptr * 1.10,
        "planned gather slower than per-element dispatch: \
         {planned_ns_per_ptr:.1} vs {per_element_ns_per_ptr:.1} ns/ptr"
    );

    // ---- simd: the vectorized software tier vs scalar software on
    // both geometries.  The pow2 side runs the shift/mask lanes, the
    // non-pow2 side (CG's 112-byte struct rows) the reciprocal lanes;
    // both must beat the scalar `map_one` loop at production batch
    // sizes — that is this PR's headline claim, so the gate is a hard
    // assert, not a recorded regression. ----
    use pgas_hw::engine::SimdEngine;
    let s_n: usize = if quick { 1 << 12 } else { 1 << 14 };
    let mut simd_legs = Vec::new();
    let np_layout = ArrayLayout::new(3, 112, 5);
    let np_table = BaseTable::regular(5, 1 << 32, 1 << 32);
    let np_ctx = EngineCtx::new(np_layout, &np_table, 0).unwrap();
    for (tag, lctx, llayout) in
        [("pow2", &ctx, &layout), ("nonpow2", &np_ctx, &np_layout)]
    {
        let s_batch = random_batch(llayout, s_n, 0x51D1);
        let r = bench(
            &format!("engine::software translate [{tag}] x{s_n}"),
            warmup,
            iters,
            || {
                SoftwareEngine.translate(lctx, &s_batch, &mut out).unwrap();
                black_box(&out);
            },
        );
        let scalar_ns_per_ptr = r.mean_secs() * 1e9 / s_n as f64;
        let r = bench(
            &format!("engine::simd translate [{tag}] x{s_n}"),
            warmup,
            iters,
            || {
                SimdEngine.translate(lctx, &s_batch, &mut out).unwrap();
                black_box(&out);
            },
        );
        let simd_ns_per_ptr = r.mean_secs() * 1e9 / s_n as f64;
        let simd_speedup = scalar_ns_per_ptr / simd_ns_per_ptr;
        println!(
            "  -> simd [{tag}]: {scalar_ns_per_ptr:.1} ns/ptr scalar vs \
             {simd_ns_per_ptr:.1} ns/ptr lanes ({simd_speedup:.2}x)"
        );
        // The acceptance gate: the lanes must be strictly faster than
        // scalar software on every geometry at >= 1k pointers.
        assert!(
            simd_ns_per_ptr < scalar_ns_per_ptr,
            "simd lanes slower than scalar software on {tag}: \
             {simd_ns_per_ptr:.1} vs {scalar_ns_per_ptr:.1} ns/ptr"
        );
        simd_legs.push(format!(
            "    {{\"layout\": \"{tag}\", \"batch\": {s_n}, \
             \"scalar_ns_per_ptr\": {scalar_ns_per_ptr:.2}, \
             \"simd_ns_per_ptr\": {simd_ns_per_ptr:.2}, \
             \"simd_speedup\": {simd_speedup:.2}}}"
        ));
    }
    let simd_calibrated_ns = SimdEngine::calibrate();
    println!(
        "  -> simd: calibrate() = {simd_calibrated_ns:.2} ns/ptr \
         (the measured CostModel::simd_ns_per_ptr coefficient)"
    );

    // ---- plan: cache-blocked tiling vs direct dispatch.  The planner
    // pays tile construction + affinity sort + splice; this records
    // what that costs (or buys, once batches outgrow L2) both
    // single-threaded and over the shard pool's tile groups. ----
    use pgas_hw::engine::TilePlan;
    let p_n: usize = if quick { 1 << 14 } else { 1 << 17 };
    let p_batch = random_batch(&layout, p_n, 0x711E);
    let r = bench(
        &format!("plan direct (software) translate x{p_n}"),
        warmup,
        iters,
        || {
            SoftwareEngine.translate(&ctx, &p_batch, &mut out).unwrap();
            black_box(&out);
        },
    );
    let direct_mptr_s = p_n as f64 / r.mean_secs() / 1e6;
    let tile_ptrs = pgas_hw::engine::L2_TILE_PTRS;
    let r = bench(
        &format!("plan tiled (software, tile {tile_ptrs}) translate x{p_n}"),
        warmup,
        iters,
        || {
            let tplan = TilePlan::from_batch(&ctx, &p_batch, tile_ptrs).unwrap();
            SoftwareEngine
                .translate_planned(&ctx, &p_batch, &tplan, &mut out)
                .unwrap();
            black_box(&out);
        },
    );
    let tiled_mptr_s = p_n as f64 / r.mean_secs() / 1e6;
    let sharded_plan = ShardedEngine::new(SoftwareEngine, workers);
    let r = bench(
        &format!("plan tiled (sharded x{workers}) translate x{p_n}"),
        warmup,
        iters,
        || {
            let tplan = TilePlan::from_batch(&ctx, &p_batch, tile_ptrs).unwrap();
            sharded_plan
                .translate_planned(&ctx, &p_batch, &tplan, &mut out)
                .unwrap();
            black_box(&out);
        },
    );
    let tiled_sharded_mptr_s = p_n as f64 / r.mean_secs() / 1e6;
    let plan_ratio = tiled_mptr_s / direct_mptr_s;
    let tiles = TilePlan::from_batch(&ctx, &p_batch, tile_ptrs)
        .unwrap()
        .tile_count();
    println!(
        "  -> plan: {direct_mptr_s:.1} direct vs {tiled_mptr_s:.1} tiled \
         vs {tiled_sharded_mptr_s:.1} tiled+sharded M ptr/s \
         ({plan_ratio:.2}x tiled/direct, {tiles} tiles of {tile_ptrs})"
    );

    // Merge (not overwrite): BENCH_engine.json is shared with the
    // fig11-14 model benches, so each target may run in any order and
    // re-running one replaces only its own sections.
    use pgas_hw::util::bench::merge_bench_json;
    const OUT: &str = "BENCH_engine.json";
    merge_bench_json(OUT, "bench", "\"hotpath_engine\"");
    merge_bench_json(OUT, "batch", &n.to_string());
    merge_bench_json(
        OUT,
        "layout",
        "{\"blocksize\": 64, \"elemsize\": 8, \"numthreads\": 16}",
    );
    merge_bench_json(OUT, "backends", &format!("[\n{}\n  ]", rows.join(",\n")));
    merge_bench_json(
        OUT,
        "walk",
        &format!(
            "{{\"steps\": {steps}, \"divmod_msteps_s\": {divmod_msteps_s:.2}, \
             \"stepper_msteps_s\": {stepper_msteps_s:.2}, \
             \"stepper_speedup\": {walk_speedup:.2}}}"
        ),
    );
    merge_bench_json(
        OUT,
        "sharded",
        &format!(
            "{{\"inner\": \"software\", \"workers\": {workers}, \
             \"batch\": {big_n}, \"software_mptr_s\": {single_mptr_s:.2}, \
             \"sharded_mptr_s\": {sharded_mptr_s:.2}, \
             \"sharded_speedup\": {sharded_speedup:.2}}}"
        ),
    );
    merge_bench_json(
        OUT,
        "leon3",
        &format!(
            "{{\"batch\": {l3_n}, \
             \"translate_mptr_s\": {leon3_mptr_s:.2}, \
             \"host_ns_per_ptr\": {leon3_ns_per_ptr:.1}, \
             \"sim_cycles_per_ptr\": {leon3_cyc_per_ptr:.2}}}"
        ),
    );
    merge_bench_json(
        OUT,
        "remote",
        &format!(
            "{{\"workers\": {rworkers}, \"batch\": {big_n}, \
             \"dispatch_ns\": {remote_dispatch_ns:.0}, \
             \"ns_per_ptr\": {remote_ns_per_ptr:.2}, \
             \"remote_mptr_s\": {remote_mptr_s:.2}, \
             \"sharded_mptr_s\": {sharded_mptr_s:.2}, \
             \"remote_vs_sharded\": {remote_vs_sharded:.2}}}"
        ),
    );
    merge_bench_json(
        OUT,
        "daemon",
        &format!(
            "{{\"threads\": {dthreads}, \"reqs\": {reqs}, \
             \"batch\": {req_n}, \
             \"steady_ns_per_req\": {steady_ns_per_req:.0}, \
             \"snapshot_ns_per_req\": {snapshot_ns_per_req:.0}, \
             \"epoch_speedup\": {epoch_speedup:.2}, \
             \"installs\": {steady_installs}, \
             \"epoch_hits\": {steady_hits}, \
             \"sessions\": {}}}",
            dstats.sessions
        ),
    );
    merge_bench_json(
        OUT,
        "resilience",
        &format!(
            "{{\"batch\": {res_n}, \
             \"healthy_ns_per_ptr\": {healthy_ns_per_ptr:.1}, \
             \"fallback_ns_per_ptr\": {fallback_ns_per_ptr:.1}, \
             \"fallback_overhead\": {fallback_overhead:.2}, \
             \"injected\": {}}}",
            storm.injected()
        ),
    );
    merge_bench_json(
        OUT,
        "gather",
        &format!(
            "{{\"batch\": {g_n}, \"owners\": {g_owners}, \
             \"per_element_ns_per_ptr\": {per_element_ns_per_ptr:.1}, \
             \"planned_ns_per_ptr\": {planned_ns_per_ptr:.1}, \
             \"planned_speedup\": {gather_speedup:.2}, \
             \"bucket_ns_per_ptr\": {bucket_ns_per_ptr:.2}, \
             \"plan_setup_ns\": {plan_setup_ns:.0}}}"
        ),
    );
    merge_bench_json(
        OUT,
        "simd",
        &format!(
            "{{\"lanes\": {}, \
             \"calibrated_ns_per_ptr\": {simd_calibrated_ns:.2}, \
             \"legs\": [\n{}\n  ]}}",
            pgas_hw::engine::SIMD_LANES,
            simd_legs.join(",\n")
        ),
    );
    merge_bench_json(
        OUT,
        "plan",
        &format!(
            "{{\"batch\": {p_n}, \"tile_ptrs\": {tile_ptrs}, \
             \"tiles\": {tiles}, \"workers\": {workers}, \
             \"direct_mptr_s\": {direct_mptr_s:.2}, \
             \"tiled_mptr_s\": {tiled_mptr_s:.2}, \
             \"tiled_sharded_mptr_s\": {tiled_sharded_mptr_s:.2}, \
             \"tiled_vs_direct\": {plan_ratio:.2}}}"
        ),
    );
    println!("merged host sections into BENCH_engine.json");
}
