//! `cargo bench` target regenerating the paper's Figure 16 (Leon3
//! matrix multiplication).  Shape expectation: static slowest, then
//! privatization 1, then privatization 2; the hardware variant matches
//! the fully-privatized code.

use pgas_hw::leon3::microbench::{run_matmul, MatmulVariant};
use pgas_hw::util::bench::{bench, black_box};
use pgas_hw::util::table::{fnum, Table};

fn main() {
    let n = 32;
    let mut t = Table::new(
        "Figure 16: Leon 3 — Matrix Multiplication (ms @75MHz)",
        &["threads", "static", "priv 1", "priv 2", "hw", "hw/priv2"],
    );
    for threads in [1u32, 2, 4] {
        let st = run_matmul(threads, MatmulVariant::Static, n);
        let p1 = run_matmul(threads, MatmulVariant::Priv1, n);
        let p2 = run_matmul(threads, MatmulVariant::Priv2, n);
        let hw = run_matmul(threads, MatmulVariant::Hw, n);
        t.row(&[
            threads.to_string(),
            fnum(st.runtime_ms(), 3),
            fnum(p1.runtime_ms(), 3),
            fnum(p2.runtime_ms(), 3),
            fnum(hw.runtime_ms(), 3),
            format!("{:.2}", hw.cycles as f64 / p2.cycles as f64),
        ]);
    }
    println!("{}", t.render());
    for v in MatmulVariant::ALL {
        bench(&format!("leon3 matmul {} x4", v.label()), 1, 3, || {
            black_box(run_matmul(4, v, n));
        });
    }
}
