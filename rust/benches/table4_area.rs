//! `cargo bench` target regenerating the paper's Table 4 and asserting
//! the reproduced Increase row matches the paper exactly.

use pgas_hw::area;

fn main() {
    println!("{}", area::table4().render());
    println!("{}", area::component_breakdown().render());
    let inc = area::pgas_support_total(4);
    assert_eq!(
        (inc.registers, inc.luts, inc.bram18, inc.dsp48),
        (2607, 3337, 20, 8),
        "Table 4 Increase row must match the paper"
    );
    println!("table4_area: Increase row matches the paper exactly (2607/3337/20/8)");
}
