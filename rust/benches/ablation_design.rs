//! Ablations for the design choices DESIGN.md calls out — each isolates
//! one mechanism and quantifies it:
//!
//!   1. volatile-store reload modeling (paper §6.1): on vs off, for the
//!      store-heavy MG — this is the entire "manual beats HW by ~10%"
//!      effect;
//!   2. the two-immediates increment trick (inc 3 = inc 1 + inc 2) vs
//!      materialize-and-register-increment;
//!   3. Berkeley static vs dynamic THREADS in the *software* path (the
//!      Leon3 Fig-15 effect, here on the Gem5-like machine);
//!   4. a second PGAS unit per core in the detailed model (the paper's
//!      implicit 1-unit choice).

use pgas_hw::compiler::{compile, CompileOpts, IrBuilder, Lowering, Val};
use pgas_hw::cpu::{Cpu, CpuModel, DetailedCfg, DetailedCpu, HierLatency, SharedLevel};
use pgas_hw::isa::{Inst, IntOp, MemWidth, Program};
use pgas_hw::mem::MemSystem;
use pgas_hw::npb::{build, Kernel, Scale};
use pgas_hw::sim::{Machine, MachineCfg};
use pgas_hw::sptr::{pack, SharedPtr};
use pgas_hw::upc::UpcRuntime;
use pgas_hw::util::table::Table;

fn run_mg(volatile_stores: bool) -> u64 {
    let threads = 4;
    let built = build(
        Kernel::Mg,
        threads,
        pgas_hw::compiler::SourceVariant::Unoptimized,
        &Scale { factor: 512 },
    );
    let ck = compile(
        &built.module,
        &built.rt,
        &CompileOpts {
            lowering: Lowering::Hw,
            static_threads: false,
            numthreads: threads,
            volatile_stores,
        },
    );
    let mut m = Machine::new(MachineCfg::new(threads, CpuModel::Atomic));
    (built.setup)(&built.rt, m.mem_mut());
    let res = m.run(&ck.program);
    (built.validate)(&built.rt, m.mem_mut()).expect("must validate");
    res.cycles
}

fn stride3_cycles(lowering: Lowering, two_imm: bool) -> u64 {
    // walk a shared array with stride 3: the hw path either uses the
    // prototype's two-immediates trick or a Ldi+register increment
    let threads = 4;
    let mut rt = UpcRuntime::new(threads);
    let arr = rt.alloc_shared("a", 8, 8, 3 * 4096);
    let mut b = IrBuilder::new(&mut rt);
    let p = b.sptr_init(arr, Val::I(0));
    if two_imm {
        b.for_range(Val::I(0), Val::I(4096), 1, |b, _| {
            let v = b.it();
            b.sptr_ld(MemWidth::U64, v, p, 0);
            b.free_i(v);
            b.sptr_inc(p, arr, Val::I(3)); // compiler: inc 1 + inc 2
        });
    } else {
        let three = b.iconst(3);
        b.for_range(Val::I(0), Val::I(4096), 1, |b, _| {
            let v = b.it();
            b.sptr_ld(MemWidth::U64, v, p, 0);
            b.free_i(v);
            b.sptr_inc(p, arr, Val::R(three)); // register form
        });
        b.free_i(three);
    }
    let m = b.finish("stride3");
    let ck = compile(
        &m,
        &rt,
        &CompileOpts {
            lowering,
            static_threads: false,
            numthreads: threads,
            volatile_stores: false,
        },
    );
    let mut machine = Machine::new(MachineCfg::new(threads, CpuModel::Atomic));
    machine.run(&ck.program).cycles
}

fn soft_threads_mode(static_threads: bool) -> u64 {
    let threads = 4;
    let built = build(
        Kernel::Is,
        threads,
        pgas_hw::compiler::SourceVariant::Unoptimized,
        &Scale { factor: 512 },
    );
    let ck = compile(
        &built.module,
        &built.rt,
        &CompileOpts {
            lowering: Lowering::Soft,
            static_threads,
            numthreads: threads,
            volatile_stores: true,
        },
    );
    // timing model: static-vs-dynamic is a *latency* effect (shift vs
    // divide), invisible to the 1-IPC atomic model
    let mut m = Machine::new(MachineCfg::new(threads, CpuModel::Timing));
    (built.setup)(&built.rt, m.mem_mut());
    let res = m.run(&ck.program);
    (built.validate)(&built.rt, m.mem_mut()).expect("must validate");
    res.cycles
}

fn pgas_unit_count(units: usize) -> u64 {
    // burst of independent increments on the detailed core
    let seed = pack(&SharedPtr::NULL) as i64;
    let mut insts: Vec<Inst> = (0..8).map(|r| Inst::Ldi { rd: r, imm: seed }).collect();
    for k in 0..4096u32 {
        let r = (k % 8) as u8;
        insts.push(Inst::PgasIncI { rd: r, ra: r, l2es: 3, l2bs: 3, l2inc: 0 });
        // independent filler so the inc throughput, not a serial ALU
        // chain, is the bottleneck
        insts.push(Inst::Opi { op: IntOp::Add, rd: 9 + (k % 4) as u8, ra: 31, imm: 1 });
    }
    insts.push(Inst::Halt);
    let prog = Program::new("burst", insts);
    let cfg = DetailedCfg { pgas_units: units, ..DetailedCfg::default() };
    let mut cpu = DetailedCpu::with_cfg(0, 4, cfg);
    let mut mem = MemSystem::new(4);
    let mut sh = SharedLevel::new(1, HierLatency::default());
    cpu.run(&prog, &mut mem, &mut sh, u64::MAX);
    cpu.stats().cycles
}

fn main() {
    let mut t = Table::new(
        "Ablations (atomic model unless noted; cycles, lower is better)",
        &["ablation", "baseline", "variant", "delta"],
    );

    let on = run_mg(true);
    let off = run_mg(false);
    t.row(&[
        "MG hw: volatile-store reload (paper 6.1)".into(),
        format!("{on} (on)"),
        format!("{off} (off)"),
        format!("{:+.1}% from reloads", (on as f64 / off as f64 - 1.0) * 100.0),
    ]);

    let two = stride3_cycles(Lowering::Hw, true);
    let reg = stride3_cycles(Lowering::Hw, false);
    t.row(&[
        "stride-3 walk: two-immediates trick vs Ldi+IncR".into(),
        format!("{two} (2x inci)"),
        format!("{reg} (incr)"),
        format!("{:+.1}%", (reg as f64 / two as f64 - 1.0) * 100.0),
    ]);

    let dynamic = soft_threads_mode(false);
    let static_ = soft_threads_mode(true);
    t.row(&[
        "IS soft: dynamic vs static THREADS".into(),
        format!("{dynamic} (dynamic)"),
        format!("{static_} (static)"),
        format!("static {:.2}x faster", dynamic as f64 / static_ as f64),
    ]);

    let one = pgas_unit_count(1);
    let two_u = pgas_unit_count(2);
    t.row(&[
        "detailed: 1 vs 2 PGAS units (inc burst)".into(),
        format!("{one} (1 unit)"),
        format!("{two_u} (2 units)"),
        format!("{:+.1}% headroom", (one as f64 / two_u as f64 - 1.0) * 100.0),
    ]);

    println!("{}", t.render());
}
