//! `cargo bench` target regenerating the paper's Figure 10.
//! Shape expectation: the headline: HW ~5.5x over unopt, ~10% behind manual
use pgas_hw::coordinator::bench_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_figure(
        "Figure 10",
        Kernel::Mg,
        &[CpuModel::Atomic],
        &[1, 2, 4, 8, 16, 32, 64],
        Scale { factor: 1024 },
    );
}
