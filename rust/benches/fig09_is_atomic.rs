//! `cargo bench` target regenerating the paper's Figure 9.
//! Shape expectation: HW ~3x over unopt but ~13% behind manual (volatile-store reloads)
use pgas_hw::coordinator::bench_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_figure(
        "Figure 9",
        Kernel::Is,
        &[CpuModel::Atomic],
        &[1, 2, 4, 8, 16, 32, 64],
        Scale { factor: 512 },
    );
}
