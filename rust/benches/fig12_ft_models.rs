//! `cargo bench` target regenerating the paper's Figure 12.
//! Shape expectation: timing/detailed FT
//!
//! Also emits the lookahead differential (`sim_batched_cycles` vs
//! `sim_scalar_cycles` per model) into `BENCH_engine.json` and fails
//! if the two cycle totals ever diverge.  `--quick` = CI smoke.
use pgas_hw::coordinator::bench_models_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_models_figure(
        "Figure 12",
        "fig12_ft_models",
        Kernel::Ft,
        &[CpuModel::Timing, CpuModel::Detailed],
        &[1, 2, 4, 8, 16],
        Scale { factor: 1024 },
    );
}
