//! `cargo bench` target regenerating the paper's Figure 8.
//! Shape expectation: HW ~2.3x over unopt, ahead of manual; run capped at 16 cores (class-W slabs)
use pgas_hw::coordinator::bench_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_figure(
        "Figure 8",
        Kernel::Ft,
        &[CpuModel::Atomic],
        &[1, 2, 4, 8, 16],
        Scale { factor: 512 },
    );
}
