//! `cargo bench` target regenerating the paper's Figure 7.
//! Shape expectation: HW ~2.6x over unopt, ~+17% over manual; w/w_tmp incs fall back to software
use pgas_hw::coordinator::bench_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_figure(
        "Figure 7",
        Kernel::Cg,
        &[CpuModel::Atomic],
        &[1, 2, 4, 8, 16, 32, 64],
        Scale { factor: 128 },
    );
}
