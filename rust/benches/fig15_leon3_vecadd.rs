//! `cargo bench` target regenerating the paper's Figure 15 (Leon3
//! vector addition).  Shape expectation: static ~5x over dynamic;
//! privatized and hw ~16x over dynamic and within noise of each other;
//! gains shrink with threads as the AMBA bus saturates.

use pgas_hw::leon3::microbench::{run_vecadd, VecAddVariant};
use pgas_hw::util::bench::{bench, black_box};
use pgas_hw::util::table::{fnum, Table};

fn main() {
    let n = 8192;
    let mut t = Table::new(
        "Figure 15: Leon 3 — Vector Addition (ms @75MHz)",
        &["threads", "dynamic", "static", "privatized", "hw", "dyn/hw"],
    );
    for threads in [1u32, 2, 4] {
        let dy = run_vecadd(threads, VecAddVariant::Dynamic, n);
        let st = run_vecadd(threads, VecAddVariant::Static, n);
        let pv = run_vecadd(threads, VecAddVariant::Privatized, n);
        let hw = run_vecadd(threads, VecAddVariant::Hw, n);
        t.row(&[
            threads.to_string(),
            fnum(dy.runtime_ms(), 3),
            fnum(st.runtime_ms(), 3),
            fnum(pv.runtime_ms(), 3),
            fnum(hw.runtime_ms(), 3),
            format!("{:.1}x", dy.cycles as f64 / hw.cycles as f64),
        ]);
    }
    println!("{}", t.render());
    for v in VecAddVariant::ALL {
        bench(&format!("leon3 vecadd {} x4", v.label()), 1, 5, || {
            black_box(run_vecadd(4, v, n));
        });
    }
}
