//! `cargo bench` target regenerating the paper's Figure 11.
//! Shape expectation: timing/detailed models: smaller relative gains; shared L2 bottleneck from 16 cores
use pgas_hw::coordinator::bench_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_figure(
        "Figure 11",
        Kernel::Cg,
        &[CpuModel::Timing, CpuModel::Detailed],
        &[1, 2, 4, 8, 16],
        Scale { factor: 256 },
    );
}
