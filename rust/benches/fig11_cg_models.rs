//! `cargo bench` target regenerating the paper's Figure 11.
//! Shape expectation: timing/detailed models: smaller relative gains; shared L2 bottleneck from 16 cores
//!
//! Also emits the lookahead differential (`sim_batched_cycles` vs
//! `sim_scalar_cycles` per model) into `BENCH_engine.json` and fails
//! if the two cycle totals ever diverge.  `--quick` = CI smoke.
use pgas_hw::coordinator::bench_models_figure;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{Kernel, Scale};

fn main() {
    bench_models_figure(
        "Figure 11",
        "fig11_cg_models",
        Kernel::Cg,
        &[CpuModel::Timing, CpuModel::Detailed],
        &[1, 2, 4, 8, 16],
        Scale { factor: 256 },
    );
}
