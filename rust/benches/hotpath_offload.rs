//! Hot-path micro-bench: PJRT batch-offload throughput of the XLA
//! address-mapping unit vs the scalar Rust path (§Perf L1/L2 metric on
//! this CPU testbed; the TPU estimate lives in DESIGN.md).
//!
//! Requires `make artifacts`.

use pgas_hw::runtime::{unit_batch_scalar, UnitCfg, XlaUnit, UNIT_BATCH};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::bench::{bench, black_box};
use pgas_hw::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let unit = match XlaUnit::load("artifacts") {
        Ok(u) => u,
        Err(e) => {
            eprintln!("skipping offload bench: {e:#} (run `make artifacts`)");
            return Ok(());
        }
    };
    let cfg = UnitCfg {
        log2_blocksize: 6,
        log2_elemsize: 3,
        log2_numthreads: 4,
        mythread: 0,
        log2_threads_per_mc: 1,
        log2_threads_per_node: 6,
    };
    let table = BaseTable::regular(16, 1 << 32, 1 << 32);
    let layout = ArrayLayout::new(64, 8, 16);
    let mut rng = Xoshiro256::new(1);
    let ptrs: Vec<SharedPtr> = (0..UNIT_BATCH)
        .map(|_| SharedPtr::for_index(&layout, 0, rng.below(1 << 20)))
        .collect();
    let incs: Vec<u32> = (0..UNIT_BATCH).map(|_| rng.below(4096) as u32).collect();

    let r = bench("XLA unit_batch (8192 ptrs)", 3, 20, || {
        black_box(unit.unit_batch(&cfg, &table, &ptrs, &incs).unwrap());
    });
    println!(
        "  -> {:.1} M ptr/s through PJRT",
        UNIT_BATCH as f64 / r.mean_secs() / 1e6
    );

    let r = bench("XLA inc_batch (8192 ptrs)", 3, 20, || {
        black_box(unit.inc_batch(&cfg, &ptrs, &incs).unwrap());
    });
    println!(
        "  -> {:.1} M ptr/s through PJRT (inc only)",
        UNIT_BATCH as f64 / r.mean_secs() / 1e6
    );

    let r = bench("scalar unit_batch (8192 ptrs)", 3, 20, || {
        black_box(unit_batch_scalar(&cfg, &table, &ptrs, &incs));
    });
    println!(
        "  -> {:.1} M ptr/s scalar Rust",
        UNIT_BATCH as f64 / r.mean_secs() / 1e6
    );

    let r = bench("XLA trace_walker (4096 steps)", 3, 20, || {
        black_box(unit.walk(&cfg, &table, &SharedPtr::NULL, 1).unwrap());
    });
    println!(
        "  -> {:.1} M steps/s through PJRT scan",
        4096.0 / r.mean_secs() / 1e6
    );
    Ok(())
}
