//! Golden-file and property tests for the static analyzer.
//!
//! The goldens pin `LintReport::summary_json()` — the fully
//! deterministic one-line form — for the three fixture kernels; the
//! full `to_json()` output is structure-checked but not byte-pinned
//! (site provenance strings are an implementation detail).  The
//! property suite drives the phase partitioner over seeded random op
//! trees and checks its covering invariants at any barrier count.

use pgas_hw::analysis::phases::flat_partition;
use pgas_hw::analysis::{self, Severity};
use pgas_hw::compiler::{Op, Val};
use pgas_hw::isa::{Cond, IntOp};
use pgas_hw::util::rng::Xoshiro256;

// ---------------- golden files ----------------

#[test]
fn racy_summary_matches_golden() {
    let r = analysis::lint_fixture("racy", 4).expect("known fixture");
    assert_eq!(
        r.summary_json(),
        include_str!("golden/lint_racy.json").trim()
    );
}

#[test]
fn oob_summary_matches_golden() {
    let r = analysis::lint_fixture("oob", 4).expect("known fixture");
    assert_eq!(r.summary_json(), include_str!("golden/lint_oob.json").trim());
}

#[test]
fn clean_summary_matches_golden() {
    let r = analysis::lint_fixture("clean", 4).expect("known fixture");
    assert_eq!(
        r.summary_json(),
        include_str!("golden/lint_clean.json").trim()
    );
}

#[test]
fn racy_race_is_phase_localized_with_provenance() {
    let r = analysis::lint_fixture("racy", 4).expect("known fixture");
    let errors: Vec<_> = r
        .diagnostics
        .iter()
        .filter(|d| d.severity == Severity::Error)
        .collect();
    assert_eq!(errors.len(), 1);
    let d = errors[0];
    assert_eq!(d.code, "race/ww");
    assert_eq!(d.phase, 0, "the race is before the barrier");
    assert_eq!(d.array, "racy_a");
    assert!(
        !d.sites.is_empty() && d.sites.iter().all(|s| s.contains("store")),
        "sites: {:?}",
        d.sites
    );
    // the post-barrier read is race-free: phase count must be 2
    assert_eq!(r.phases, 2);
}

#[test]
fn oob_error_has_a_concrete_witness() {
    let r = analysis::lint_fixture("oob", 4).expect("known fixture");
    let d = &r.diagnostics[0];
    assert_eq!(d.code, "bounds/oob");
    assert!(
        d.message.contains("[64]") && d.message.contains("64"),
        "witness element missing: {}",
        d.message
    );
}

#[test]
fn full_json_is_structurally_complete() {
    for name in analysis::fixtures::NAMES {
        let r = analysis::lint_fixture(name, 4).expect("known fixture");
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{name}: {j}");
        for key in [
            "\"kernel\":",
            "\"threads\":",
            "\"phases\":",
            "\"sites\":",
            "\"predicted\":",
            "\"diagnostics\":",
            "\"windows\":",
            "\"scalar_incs\":",
        ] {
            assert!(j.contains(key), "{name}: missing {key} in {j}");
        }
        // balanced quoting — every string literal closed
        assert_eq!(
            j.matches('"').count() % 2,
            0,
            "{name}: unbalanced quotes in {j}"
        );
    }
}

// ---------------- phase-partitioner property suite ----------------

/// Random op tree: leaves, barriers, and nested For/If/DoWhile.
fn gen_ops(rng: &mut Xoshiro256, depth: u32, budget: &mut u32) -> Vec<Op> {
    let mut ops = Vec::new();
    while *budget > 0 && rng.below(5) != 0 {
        *budget -= 1;
        let pick = rng.below(if depth < 3 { 7 } else { 4 });
        match pick {
            0 | 1 => ops.push(Op::Mov { d: 0, v: Val::I(rng.below(9) as i64) }),
            2 => ops.push(Op::Barrier),
            3 => ops.push(Op::Bin {
                op: IntOp::Add,
                d: 1,
                a: 0,
                b: Val::I(1),
            }),
            4 => ops.push(Op::For {
                i: 2,
                from: Val::I(0),
                to: Val::I(rng.below(5) as i64),
                step: 1,
                body: gen_ops(rng, depth + 1, budget),
            }),
            5 => ops.push(Op::If {
                cond: Cond::Eq,
                r: 0,
                then: gen_ops(rng, depth + 1, budget),
                els: gen_ops(rng, depth + 1, budget),
            }),
            _ => ops.push(Op::DoWhile {
                body: gen_ops(rng, depth + 1, budget),
                cond: Cond::Ne,
                r: 0,
            }),
        }
    }
    ops
}

/// Pre-order op count and barrier count, the partitioner's ground truth.
fn census(ops: &[Op]) -> (usize, usize) {
    let mut count = 0;
    let mut barriers = 0;
    for op in ops {
        count += 1;
        match op {
            Op::Barrier => barriers += 1,
            Op::For { body, .. } | Op::DoWhile { body, .. } => {
                let (c, b) = census(body);
                count += c;
                barriers += b;
            }
            Op::If { then, els, .. } => {
                let (c, b) = census(then);
                let (c2, b2) = census(els);
                count += c + c2;
                barriers += b + b2;
            }
            _ => {}
        }
    }
    (count, barriers)
}

#[test]
fn partition_covers_every_op_exactly_once_at_any_barrier_count() {
    let mut rng = Xoshiro256::new(0x11A7);
    for round in 0..200 {
        let mut budget = 40;
        let ops = gen_ops(&mut rng, 0, &mut budget);
        let (count, barriers) = census(&ops);
        let (segs, nsegs) = flat_partition(&ops);
        // every op covered exactly once, in pre-order
        assert_eq!(segs.len(), count, "round {round}");
        // segment count is exactly barriers + 1, no matter the nesting
        assert_eq!(nsegs, barriers + 1, "round {round}");
        // ids are valid and non-decreasing in pre-order
        assert!(segs.iter().all(|&s| s < nsegs), "round {round}");
        assert!(
            segs.windows(2).all(|w| w[0] <= w[1]),
            "round {round}: segment ids must be monotone in pre-order"
        );
        // each segment in 0..nsegs is non-empty whenever any op landed
        // after its opening barrier — the ids seen form a prefix set
        if let Some(&max) = segs.iter().max() {
            let seen: std::collections::BTreeSet<usize> =
                segs.iter().copied().collect();
            assert_eq!(seen.len(), max + 1, "round {round}: gap in segment ids");
        }
    }
}

#[test]
fn barrier_free_tree_is_one_segment() {
    let ops = vec![
        Op::Mov { d: 0, v: Val::I(1) },
        Op::For {
            i: 1,
            from: Val::I(0),
            to: Val::I(4),
            step: 1,
            body: vec![Op::Mov { d: 2, v: Val::I(0) }],
        },
    ];
    let (segs, nsegs) = flat_partition(&ops);
    assert_eq!(nsegs, 1);
    assert!(segs.iter().all(|&s| s == 0));
}
