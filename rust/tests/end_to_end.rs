//! Integration: full campaign slices across models, validated numerics,
//! and the paper's qualitative orderings.

use pgas_hw::coordinator::{figure_table, find, Campaign};
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{self, Kernel, PaperVariant, Scale};

#[test]
fn small_campaign_all_kernels_atomic() {
    let c = Campaign {
        kernels: Kernel::ALL.to_vec(),
        models: vec![CpuModel::Atomic],
        cores: vec![1, 4],
        variants: PaperVariant::ALL.to_vec(),
        scale: Scale { factor: 1024 },
        jobs: 8,
        chaos: None,
    };
    let outs = c.run(false);
    // 5 kernels x 2 core counts x 3 variants (every run validated)
    assert_eq!(outs.len(), 30);
    for k in Kernel::ALL {
        let t = figure_table(&outs, k, CpuModel::Atomic, "fig");
        assert!(!t.is_empty(), "{k}");
    }
}

#[test]
fn all_three_models_agree_functionally() {
    // same kernel, same answer on atomic/timing/detailed (validation
    // inside run() checks numerics against the host reference)
    let scale = Scale { factor: 2048 };
    for model in CpuModel::ALL {
        let out = npb::run(Kernel::Is, PaperVariant::Hw, model, 4, &scale);
        assert!(out.result.cycles > 0, "{model}");
    }
}

#[test]
fn timing_costs_more_than_atomic_and_detailed_between() {
    let scale = Scale { factor: 1024 };
    let atomic = npb::run(Kernel::Mg, PaperVariant::Hw, CpuModel::Atomic, 2, &scale);
    let timing = npb::run(Kernel::Mg, PaperVariant::Hw, CpuModel::Timing, 2, &scale);
    let detailed = npb::run(Kernel::Mg, PaperVariant::Hw, CpuModel::Detailed, 2, &scale);
    assert!(timing.result.cycles > atomic.result.cycles);
    assert!(
        detailed.result.cycles < timing.result.cycles,
        "OoO should beat in-order timing: {} vs {}",
        detailed.result.cycles,
        timing.result.cycles
    );
}

#[test]
fn scaling_with_cores_reduces_runtime() {
    // more cores => fewer max-cycles (atomic model, embarrassingly
    // parallel workload)
    let scale = Scale { factor: 256 };
    let c1 = npb::run(Kernel::Ep, PaperVariant::Unopt, CpuModel::Atomic, 1, &scale);
    let c4 = npb::run(Kernel::Ep, PaperVariant::Unopt, CpuModel::Atomic, 4, &scale);
    let s = c1.result.cycles as f64 / c4.result.cycles as f64;
    assert!(s > 3.0, "EP should scale ~linearly, got {s:.2}x at 4 cores");
}

#[test]
fn hw_variant_reduces_dynamic_instructions_everywhere() {
    let scale = Scale { factor: 1024 };
    for k in Kernel::ALL {
        let u = npb::run(k, PaperVariant::Unopt, CpuModel::Atomic, 4, &scale);
        let h = npb::run(k, PaperVariant::Hw, CpuModel::Atomic, 4, &scale);
        assert!(
            h.result.total.instructions <= u.result.total.instructions,
            "{k}: hw must not execute more instructions than soft"
        );
    }
}

#[test]
fn figure7_qualitative_shape_cg() {
    // the CG story at one point: hw > manual > unopt (in speed)
    let scale = Scale { factor: 128 };
    let c = Campaign {
        kernels: vec![Kernel::Cg],
        models: vec![CpuModel::Atomic],
        cores: vec![4],
        variants: PaperVariant::ALL.to_vec(),
        scale,
        jobs: 3,
        chaos: None,
    };
    let outs = c.run(false);
    let u = find(&outs, Kernel::Cg, PaperVariant::Unopt, CpuModel::Atomic, 4).unwrap();
    let m = find(&outs, Kernel::Cg, PaperVariant::Manual, CpuModel::Atomic, 4).unwrap();
    let h = find(&outs, Kernel::Cg, PaperVariant::Hw, CpuModel::Atomic, 4).unwrap();
    assert!(h.result.cycles < m.result.cycles);
    assert!(m.result.cycles < u.result.cycles);
}
