//! Differential codegen fuzzing: random kernels compiled with the
//! software lowering and the hardware lowering must leave *identical*
//! architectural state — same shared-array contents, same private
//! results — across random layouts, increments and thread counts.
//! This is the strongest whole-stack invariant: it exercises the IR
//! builder, both lowerings, the packed-pointer algebra, the ISA
//! executor and the machine together.

use pgas_hw::compiler::{compile, CompileOpts, IrBuilder, Lowering, Val};
use pgas_hw::cpu::CpuModel;
use pgas_hw::isa::{IntOp, MemWidth};
use pgas_hw::sim::{Machine, MachineCfg};
use pgas_hw::upc::{ArrayId, UpcRuntime};
use pgas_hw::util::rng::Xoshiro256;
use pgas_hw::util::testkit::check;

struct RandomKernel {
    rt: UpcRuntime,
    module: pgas_hw::compiler::IrModule,
    arrays: Vec<(ArrayId, u64)>, // (id, nelems)
}

/// Build a random kernel: each thread walks a random shared array with a
/// random stride, reads, accumulates, writes back, with barriers between
/// phases (so cross-thread writes are race-free: each phase writes only
/// the walker's own slot pattern starting at MYTHREAD).
fn random_kernel(rng: &mut Xoshiro256, threads: u32) -> RandomKernel {
    let mut rt = UpcRuntime::new(threads);
    let n_arrays = 1 + rng.below(3) as usize;
    let mut arrays = Vec::new();
    for a in 0..n_arrays {
        let blocksize = 1u64 << rng.below(5);
        let elemsize = [1u64, 2, 4, 8][rng.below(4) as usize];
        // occasionally a non-pow2 elemsize to exercise the fallback
        let elemsize = if rng.chance(0.25) { 12 } else { elemsize };
        let nelems = (threads as u64) * (1 << (3 + rng.below(4)));
        let id = rt.alloc_shared(&format!("rand{a}"), blocksize, elemsize, nelems);
        arrays.push((id, nelems));
    }

    let mut b = IrBuilder::new(&mut rt);
    let myt = b.mythread();
    let phases = 1 + rng.below(3);
    for _ in 0..phases {
        let (arr, nelems) = *rng.pick(&arrays);
        let stride = 1 + rng.below(7) as i64;
        let iters = (nelems / threads as u64).min(64) as i64;
        // start at A[MYTHREAD], stride `stride`, so threads never write
        // the same element within a phase: element indices are
        // myt + k*stride*threads
        let start = b.it();
        b.bin(IntOp::Mul, start, myt, Val::I(1));
        let p = b.sptr_init(arr, Val::R(start));
        b.free_i(start);
        let acc = b.iconst(0);
        let es = b.rt.array(arr).layout.elemsize;
        let w = match es {
            1 => MemWidth::U8,
            2 => MemWidth::U16,
            4 => MemWidth::U32,
            _ => MemWidth::U64,
        };
        b.for_range(Val::I(0), Val::I(iters), 1, |b, _| {
            let v = b.it();
            b.sptr_ld(w, v, p, 0);
            b.bin(IntOp::Add, acc, acc, Val::R(v));
            b.bin(IntOp::Xor, v, acc, Val::I(0x5A));
            b.sptr_st(w, v, p, 0);
            b.free_i(v);
            b.sptr_inc(p, arr, Val::I(stride * threads as i64));
        });
        // publish the accumulator to private space for comparison
        let pb = b.priv_base();
        b.st(MemWidth::U64, acc, pb, 0x40);
        b.free_i(pb);
        b.free_i(acc);
        b.free_i(p);
        b.barrier();
    }
    let module = b.finish("fuzz");
    RandomKernel { rt, module, arrays }
}

fn run_one(
    k: &RandomKernel,
    lowering: Lowering,
    threads: u32,
    model: CpuModel,
) -> (Vec<u64>, Vec<u64>) {
    let ck = compile(
        &k.module,
        &k.rt,
        &CompileOpts {
            lowering,
            static_threads: false,
            numthreads: threads,
            // reloads are timing-only artifacts; keep streams minimal
            // so state comparison is exact
            volatile_stores: false,
        },
    );
    let mut m = Machine::new(MachineCfg::new(threads, model));
    // deterministic initial contents
    for &(arr, nelems) in &k.arrays {
        for i in 0..nelems {
            k.rt.write_u64(m.mem_mut(), arr, i, (i * 37 + 11) & 0xFF);
        }
    }
    m.run(&ck.program);
    let mut shared_state = Vec::new();
    for &(arr, nelems) in &k.arrays {
        for i in 0..nelems {
            shared_state.push(k.rt.read_u64(m.mem_mut(), arr, i));
        }
    }
    let priv_state: Vec<u64> = (0..threads)
        .map(|t| {
            m.mem.read(
                MemWidth::U64,
                pgas_hw::mem::seg_base(t) + pgas_hw::mem::PRIV_OFF + 0x40,
            )
        })
        .collect();
    (shared_state, priv_state)
}

#[test]
fn soft_and_hw_lowerings_are_semantically_identical() {
    check("codegen differential", 40, |rng| {
        let threads = 1u32 << rng.below(4);
        let k = random_kernel(rng, threads);
        let (soft_mem, soft_priv) = run_one(&k, Lowering::Soft, threads, CpuModel::Atomic);
        let (hw_mem, hw_priv) = run_one(&k, Lowering::Hw, threads, CpuModel::Atomic);
        assert_eq!(soft_mem, hw_mem, "shared state diverged (T={threads})");
        assert_eq!(soft_priv, hw_priv, "private results diverged (T={threads})");
    });
}

#[test]
fn all_cpu_models_reach_identical_architectural_state() {
    check("model differential", 10, |rng| {
        let threads = 1u32 << rng.below(3);
        let k = random_kernel(rng, threads);
        let (a_mem, a_priv) = run_one(&k, Lowering::Hw, threads, CpuModel::Atomic);
        let (t_mem, t_priv) = run_one(&k, Lowering::Hw, threads, CpuModel::Timing);
        let (d_mem, d_priv) = run_one(&k, Lowering::Hw, threads, CpuModel::Detailed);
        assert_eq!(a_mem, t_mem);
        assert_eq!(a_mem, d_mem);
        assert_eq!(a_priv, t_priv);
        assert_eq!(a_priv, d_priv);
    });
}

#[test]
fn hw_lowering_never_slower_in_instructions() {
    check("instruction-count dominance", 20, |rng| {
        let threads = 1u32 << rng.below(3);
        let k = random_kernel(rng, threads);
        let count = |lowering| {
            let ck = compile(
                &k.module,
                &k.rt,
                &CompileOpts {
                    lowering,
                    static_threads: false,
                    numthreads: threads,
                    volatile_stores: false,
                },
            );
            let mut m = Machine::new(MachineCfg::new(threads, CpuModel::Atomic));
            for &(arr, nelems) in &k.arrays {
                for i in 0..nelems {
                    k.rt.write_u64(m.mem_mut(), arr, i, i & 0x7F);
                }
            }
            m.run(&ck.program).total.instructions
        };
        let soft = count(Lowering::Soft);
        let hw = count(Lowering::Hw);
        // the hw prologue runs PgasSetThreads + one PgasSetBase per
        // thread on every core — allow exactly that one-time overhead
        let prologue = (threads as u64) * (threads as u64 + 1);
        assert!(
            hw <= soft + prologue,
            "hw {hw} > soft {soft} + prologue {prologue} dynamic instructions"
        );
    });
}
