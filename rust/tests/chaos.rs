//! Chaos soak: the NPB kernels under seeded fault storms, plus the
//! fault-injection framework's own invariants.
//!
//! The contract under test is the degradation ladder's headline
//! promise: **transient faults never change results and never reach
//! the caller**.  A machine armed with a `FaultPlan` must produce
//! bit-identical simulated results (cycles, instructions, cache
//! traffic, validated numerics) to the fault-free run — only the
//! `health.*` / `degrade.*` telemetry may move.

use std::sync::Arc;

use pgas_hw::cpu::CpuModel;
use pgas_hw::engine::{
    AddressEngine, AutoEngine, BatchOut, BreakerState, ChaosEngine,
    EngineChoice, EngineCtx, EngineSelector, FaultPlan, FaultSpec, PtrBatch,
};
use pgas_hw::npb::{self, Kernel, PaperVariant, RunOutcome, Scale};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;

/// The soak's fixed seeds (also pinned by the CI `chaos-soak` job):
/// deterministic storms, so a failure reproduces from the test name.
const SOAK_SEEDS: [u64; 2] = [0xC0FF_EE42, 0x0DD_BA11];

fn soak_scale() -> Scale {
    Scale { factor: 512 }
}

fn run_point(kernel: Kernel, chaos: Option<&FaultSpec>) -> RunOutcome {
    npb::run_opts_with(
        kernel,
        PaperVariant::Hw,
        CpuModel::Atomic,
        4,
        &soak_scale(),
        true,
        None,
        chaos,
    )
}

/// Assert every simulated (architectural + timing) field matches; the
/// host-side health/degrade telemetry is explicitly *not* compared.
fn assert_results_identical(base: &RunOutcome, got: &RunOutcome, tag: &str) {
    let (b, g) = (&base.result, &got.result);
    assert_eq!(b.cycles, g.cycles, "{tag}: cycles");
    assert_eq!(
        b.total.instructions, g.total.instructions,
        "{tag}: instructions"
    );
    assert_eq!(b.total.mem_reads, g.total.mem_reads, "{tag}: mem reads");
    assert_eq!(b.total.mem_writes, g.total.mem_writes, "{tag}: mem writes");
    assert_eq!(b.total.pgas_incs, g.total.pgas_incs, "{tag}: pgas incs");
    assert_eq!(b.total.pgas_mems, g.total.pgas_mems, "{tag}: pgas mems");
    assert_eq!(b.total.barriers, g.total.barriers, "{tag}: barriers");
    assert_eq!(b.l1d_misses, g.l1d_misses, "{tag}: l1d misses");
    assert_eq!(b.l2_misses, g.l2_misses, "{tag}: l2 misses");
    assert_eq!(b.invalidations, g.invalidations, "{tag}: invalidations");
    let base_pc: Vec<u64> = b.per_core.iter().map(|c| c.cycles).collect();
    let got_pc: Vec<u64> = g.per_core.iter().map(|c| c.cycles).collect();
    assert_eq!(base_pc, got_pc, "{tag}: per-core cycles");
}

/// The soak: every NPB kernel under randomized (seeded) fault storms.
/// Validation runs inside `run_opts_with` (a wrong numeric panics), so
/// completing at all already proves zero user-visible errors; on top,
/// every simulated statistic must match the fault-free run exactly,
/// and the storm must actually have happened (nonzero `degrade.*`).
#[test]
fn npb_soak_under_fault_storms_is_bit_identical() {
    let mut total_injected = 0u64;
    let mut total_fallbacks = 0u64;
    for kernel in Kernel::ALL {
        let base = run_point(kernel, None);
        assert_eq!(
            base.result.health.injected_faults, 0,
            "{kernel}: fault-free run must not record injections"
        );
        for seed in SOAK_SEEDS {
            let spec = FaultSpec::transient(seed);
            let out = run_point(kernel, Some(&spec));
            assert_results_identical(&base, &out, &format!("{kernel}/{seed:#x}"));
            // the software hot-path tiers are telemetry too: storms
            // must not shift which batches the lanes/planner served
            assert_eq!(
                out.result.simd, base.result.simd,
                "{kernel}/{seed:#x}: simd telemetry moved under chaos"
            );
            assert_eq!(
                out.result.plan, base.result.plan,
                "{kernel}/{seed:#x}: plan telemetry moved under chaos"
            );
            total_injected += out.result.health.injected_faults;
            total_fallbacks += out.result.health.fallback_runs;
            // the stats dump carries the degradation telemetry
            let txt = out.result.stats_txt();
            for key in [
                "health.dispatches",
                "health.failures",
                "degrade.fallback_runs",
                "degrade.deadline_misses",
                "degrade.injected_faults",
                "simd.batches",
                "plan.plans",
            ] {
                assert!(txt.contains(key), "{kernel}: stats_txt missing {key}");
            }
        }
    }
    // across 5 kernels x 2 seeds the storm must have landed: the soak
    // is vacuous if no fault was ever injected or absorbed
    assert!(total_injected > 0, "no faults injected across the soak");
    assert!(total_fallbacks > 0, "no fallback re-serves across the soak");
}

/// The soak extended to the irregular-gather kernels (MD neighbor-list
/// traversal, SPMV CSR gather): their data-dependent windows route
/// through the inspector/executor tier (`gather.plans > 0`), and a
/// fault storm must leave both the simulated results *and* the gather
/// telemetry bit-identical — faults are absorbed inside each bucket's
/// dispatch funnel, below the planner.
#[test]
fn irregular_gather_soak_under_fault_storms_is_bit_identical() {
    let mut total_injected = 0u64;
    let mut total_fallbacks = 0u64;
    for kernel in Kernel::IRREGULAR {
        let base = run_point(kernel, None);
        assert!(
            base.result.gather.plans > 0,
            "{kernel}: irregular kernel never engaged the gather planner"
        );
        assert_eq!(
            base.result.health.injected_faults, 0,
            "{kernel}: fault-free run must not record injections"
        );
        for seed in SOAK_SEEDS {
            let spec = FaultSpec::transient(seed);
            let out = run_point(kernel, Some(&spec));
            assert_results_identical(&base, &out, &format!("{kernel}/{seed:#x}"));
            assert_eq!(
                out.result.gather, base.result.gather,
                "{kernel}/{seed:#x}: gather telemetry moved under chaos"
            );
            total_injected += out.result.health.injected_faults;
            total_fallbacks += out.result.health.fallback_runs;
            let txt = out.result.stats_txt();
            for key in
                ["gather.plans", "gather.bucketed_ptrs", "gather.fallback"]
            {
                assert!(txt.contains(key), "{kernel}: stats_txt missing {key}");
            }
            let plans: u64 = txt
                .lines()
                .find(|l| l.starts_with("gather.plans"))
                .unwrap()
                .split_whitespace()
                .nth(1)
                .unwrap()
                .parse()
                .unwrap();
            assert_eq!(plans, out.result.gather.plans);
        }
    }
    assert!(total_injected > 0, "no faults injected across the soak");
    assert!(total_fallbacks > 0, "no fallback re-serves across the soak");
}

/// The nonzero-counter acceptance shape in one place: a chaos run's
/// `stats_txt` reports the injected faults it absorbed.
#[test]
fn chaos_run_reports_nonzero_degrade_counters() {
    let spec = FaultSpec::transient(SOAK_SEEDS[0]);
    let out = run_point(Kernel::Is, Some(&spec));
    let h = &out.result.health;
    assert!(h.dispatches > 0, "IS batched no windows at all");
    assert!(h.injected_faults > 0, "storm never fired on IS");
    assert!(h.fallback_runs > 0, "no injected fault was re-served");
    let txt = out.result.stats_txt();
    let value = |key: &str| -> u64 {
        let line = txt
            .lines()
            .find(|l| l.starts_with(key))
            .unwrap_or_else(|| panic!("stats_txt missing {key}"));
        line.split_whitespace().nth(1).unwrap().parse().unwrap()
    };
    assert_eq!(value("degrade.injected_faults"), h.injected_faults);
    assert!(value("degrade.injected_faults") > 0);
    assert!(value("degrade.fallback_runs") > 0);
}

/// Property: a `ChaosEngine` with an all-rates-zero plan is a
/// bit-identical passthrough — on every one of the five NPB kernels'
/// shared-array layouts, for translate, increment and walk.
#[test]
fn quiet_chaos_engine_is_bit_identical_passthrough() {
    let plan = Arc::new(FaultPlan::quiet(0x51E7));
    let chaos = ChaosEngine::new(AutoEngine, Arc::clone(&plan));
    let mut rng = Xoshiro256::new(0xBEEF);
    let table = BaseTable::regular(4, 1 << 32, 1 << 32);
    for kernel in Kernel::ALL {
        let built =
            npb::build(kernel, 4, PaperVariant::Unopt.source(), &Scale::quick());
        for a in built.rt.arrays() {
            let ctx = EngineCtx::new(a.layout, &table, 0).unwrap();
            let mut batch = PtrBatch::new();
            for _ in 0..257 {
                batch.push(
                    SharedPtr::for_index(&a.layout, 0, rng.below(1 << 12)),
                    rng.below(1 << 10),
                );
            }
            let (mut got, mut want) = (BatchOut::new(), BatchOut::new());
            chaos.translate(&ctx, &batch, &mut got).unwrap();
            AutoEngine.translate(&ctx, &batch, &mut want).unwrap();
            assert_eq!(got, want, "{kernel}/{}: translate", a.name);
            let (mut gi, mut wi) = (Vec::new(), Vec::new());
            chaos.increment(&ctx, &batch, &mut gi).unwrap();
            AutoEngine.increment(&ctx, &batch, &mut wi).unwrap();
            assert_eq!(gi, wi, "{kernel}/{}: increment", a.name);
            chaos.walk(&ctx, SharedPtr::NULL, 3, 129, &mut got).unwrap();
            AutoEngine.walk(&ctx, SharedPtr::NULL, 3, 129, &mut want).unwrap();
            assert_eq!(got, want, "{kernel}/{}: walk", a.name);
        }
    }
    assert_eq!(plan.injected(), 0, "a quiet plan must never inject");
}

/// Property: with every dispatch drawing an injected fault
/// (`error=1.0`), tiers trip and quarantine, yet the selector still
/// serves every request correctly — the fallback floor is chaos-exempt
/// and `SoftwareEngine` is never excluded from the argmin.
#[test]
fn all_tiers_quarantined_selector_still_serves() {
    for (blocksize, label) in [(4u64, "pow2"), (3u64, "non-pow2")] {
        let plan = Arc::new(FaultPlan::new(
            FaultSpec::parse("0xDEAD:error=1.0").unwrap(),
        ));
        let sel = EngineSelector::new().with_chaos(Arc::clone(&plan));
        let layout = ArrayLayout::new(blocksize, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i), i % 7);
        }
        let mut want = Vec::new();
        AutoEngine.increment(&ctx, &batch, &mut want).unwrap();
        const ROUNDS: u64 = 24; // enough for the failure EWMA to trip
        for round in 0..ROUNDS {
            let mut got = Vec::new();
            let served = sel
                .increment_choosing(&ctx, &batch, &mut got)
                .unwrap_or_else(|e| {
                    panic!("{label} round {round}: user-visible error: {e}")
                });
            assert_eq!(got, want, "{label} round {round}: wrong results");
            // the reported tier is the one that actually produced the
            // output — a scalar floor choice, never a phantom success
            assert!(
                matches!(
                    served,
                    EngineChoice::Software | EngineChoice::Pow2
                ),
                "{label} round {round}: served by {served:?}"
            );
        }
        let h = sel.health_stats();
        assert_eq!(h.dispatches, ROUNDS, "{label}: every call funneled");
        assert_eq!(h.injected_faults, ROUNDS, "{label}: every call faulted");
        assert_eq!(h.fallback_runs, ROUNDS, "{label}: every call re-served");
        assert!(h.trips() >= 1, "{label}: no breaker ever tripped");
        assert!(h.quarantined() >= 1, "{label}: nothing quarantined");
        // the scalar tier the argmin leaned on is now open: on pow2
        // geometry the pow2 fast path tripped and software took over
        if blocksize.is_power_of_two() {
            let pow2 = &h.tiers[EngineChoice::Pow2.index()];
            assert_eq!(pow2.state, BreakerState::Open, "pow2 not tripped");
        }
        // recovery knob: a reset closes every breaker again
        sel.reset_health();
        let h = sel.health_stats();
        assert_eq!(h.quarantined(), 0);
        assert_eq!(h.dispatches, 0);
    }
}

/// Property: the vectorized tier honors the same degradation contract
/// as every other backend.  With every dispatch faulting, a batch the
/// argmin routes to the SIMD lanes is re-served through the fallback
/// ladder bit-identically, the simd breaker trips open (and the argmin
/// stops offering the tier), and the lane counters never tally a
/// failed dispatch.
#[test]
fn simd_tier_faults_degrade_through_the_ladder_bit_identically() {
    for seed in SOAK_SEEDS {
        let plan = Arc::new(FaultPlan::new(
            FaultSpec::parse(&format!("{seed:#x}:error=1.0")).unwrap(),
        ));
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            // pin the funnel on the simd leg: no gather bucketing, no
            // tile planning between the argmin and the dispatch
            .with_gather_threshold(usize::MAX)
            .with_plan_threshold(usize::MAX)
            .with_chaos(Arc::clone(&plan));
        let layout = ArrayLayout::new(3, 112, 5); // CG's non-pow2 rows
        assert_eq!(sel.choice(&layout, 64), EngineChoice::Simd);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 3), i % 11);
        }
        let mut want = Vec::new();
        AutoEngine.increment(&ctx, &batch, &mut want).unwrap();
        for round in 0..16u64 {
            let mut got = Vec::new();
            let served = sel
                .increment_choosing(&ctx, &batch, &mut got)
                .unwrap_or_else(|e| {
                    panic!("{seed:#x} round {round}: user-visible error: {e}")
                });
            assert_eq!(got, want, "{seed:#x} round {round}: wrong results");
            assert_ne!(
                served,
                EngineChoice::Simd,
                "{seed:#x} round {round}: a chaos'd simd dispatch was reported served"
            );
        }
        let h = sel.health_stats();
        let simd = &h.tiers[EngineChoice::Simd.index()];
        assert_eq!(
            simd.state,
            BreakerState::Open,
            "{seed:#x}: simd breaker never tripped"
        );
        assert!(h.trips() >= 1, "{seed:#x}: no breaker ever tripped");
        assert!(h.fallback_runs > 0, "{seed:#x}: no fallback re-serves");
        assert_eq!(
            sel.simd_stats().batches,
            0,
            "{seed:#x}: failed dispatches must not tally lanes"
        );
        // quarantined = the argmin stops offering the tier at all
        assert_eq!(sel.choice(&layout, 64), EngineChoice::Software);
    }
}

/// The spec grammar the CLI exposes (`--chaos SEED[:SPEC]`): bare seed
/// means the default transient mix; explicit specs start quiet; junk
/// is refused loudly.
#[test]
fn fault_spec_cli_grammar() {
    let bare = FaultSpec::parse("0xC0FFEE").unwrap();
    assert_eq!(bare.seed, 0xC0FFEE);
    assert!(bare.error > 0.0, "bare seed must carry the transient mix");
    let spec = FaultSpec::parse("7:shed=0.5,spike_ms=3").unwrap();
    assert_eq!(spec.seed, 7);
    assert_eq!(spec.shed, 0.5);
    assert_eq!(spec.spike_ns, 3_000_000);
    assert_eq!(spec.error, 0.0, "explicit specs start from quiet");
    assert!(FaultSpec::parse("notanumber").is_err());
    assert!(FaultSpec::parse("1:bogus=0.5").is_err());
    assert!(FaultSpec::parse("1:drop=1.5").is_err());
}
