//! Differential validation of the static analyzer against runtime
//! telemetry: for each of the seven NPB kernels, (1) `lint` must
//! report zero ERROR diagnostics, and (2) the static engine-mix
//! prediction must agree with the observed `RunOutcome::engine_mix()`
//! categories — batched vs scalar vs gather — at quick scale.
//!
//! The agreement contract lives in
//! `analysis::predict::PredictedMix::check_against` and is categorical
//! (booleans plus a 2% quantum-truncation allowance), not count-exact:
//! the runtime clamps windows to the remaining quantum budget, so raw
//! counts legitimately drift while the categories cannot.

use pgas_hw::analysis;
use pgas_hw::cpu::CpuModel;
use pgas_hw::npb::{self, Kernel, PaperVariant, Scale};

fn all_kernels() -> impl Iterator<Item = Kernel> {
    Kernel::ALL.into_iter().chain(Kernel::IRREGULAR)
}

#[test]
fn npb_kernels_lint_without_errors() {
    let scale = Scale::quick();
    for k in all_kernels() {
        let report = analysis::lint_kernel(k, 4, &scale);
        assert_eq!(
            report.errors(),
            0,
            "{} must lint clean, got: {:?}",
            k.name(),
            report.diagnostics
        );
    }
}

#[test]
fn static_prediction_matches_runtime_engine_mix() {
    let scale = Scale::quick();
    for k in all_kernels() {
        let report = analysis::lint_kernel(k, 4, &scale);
        let out = npb::run(k, PaperVariant::Hw, CpuModel::Atomic, 4, &scale);
        report
            .predicted
            .check_against(out.engine_mix(), &out.result.gather)
            .unwrap_or_else(|e| {
                panic!(
                    "{}: static/runtime engine-mix disagreement: {e} \
                     (predicted {:?}, runtime mix {:?}, gather {:?})",
                    k.name(),
                    report.predicted,
                    out.engine_mix(),
                    out.result.gather
                )
            });
    }
}

#[test]
fn fixture_kernels_are_flagged() {
    // the CI lint-kernels job asserts `lint --fixtures` exits non-zero;
    // this is the same property at the library level
    let racy = analysis::lint_fixture("racy", 4).expect("known fixture");
    let oob = analysis::lint_fixture("oob", 4).expect("known fixture");
    let clean = analysis::lint_fixture("clean", 4).expect("known fixture");
    assert!(racy.errors() > 0);
    assert!(oob.errors() > 0);
    assert_eq!(clean.errors(), 0);
}
