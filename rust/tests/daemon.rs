//! The daemon tier end to end: one multi-tenant `pgas-hw` daemon
//! serving several concurrent `RemoteEngine::connect` sessions.
//!
//! * Soak: three client sessions run concurrently against one
//!   in-process daemon, each mapping a *different* NPB kernel's shared
//!   arrays (different layouts → different epochs per session), and
//!   every reply is bit-identical to the in-process `AutoEngine`.
//!   Steady-state traffic rides installed epochs (`epoch_hits` > 0,
//!   zero reinstalls) and nothing is shed at default quotas.
//! * CLI: the real `pgas-hw daemon --socket S --sessions N` binary
//!   (via `CARGO_BIN_EXE_pgas-hw`) serves N sessions, exits on its
//!   own, and prints the per-tenant stats table on stdout.
//!
//! Unix-domain sockets only — no network — so the suite stays
//! tier-1-safe.

use std::process::{Command, Stdio};

use pgas_hw::compiler::SourceVariant;
use pgas_hw::daemon::{scratch_socket, Daemon, DaemonCfg};
use pgas_hw::engine::{
    AddressEngine, AutoEngine, BatchOut, EngineCtx, PtrBatch, RemoteEngine,
};
use pgas_hw::npb::{self, Kernel, Scale};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;

fn sample_batch(layout: &ArrayLayout, nelems: u64, seed: u64) -> PtrBatch {
    let mut rng = Xoshiro256::new(seed);
    let n = 211;
    let mut batch = PtrBatch::with_capacity(n);
    for _ in 0..n {
        batch.push(
            SharedPtr::for_index(layout, 0, rng.below(nelems.max(1))),
            rng.below(1 << 9),
        );
    }
    batch
}

/// One tenant's workload: map every shared array of `kernel` through
/// the daemon session for `rounds` rounds, checking each reply against
/// the in-process engine.  Round 2+ reuses the epochs installed in
/// round 1 — that is the steady state the telemetry must show.
fn soak_session(socket: &std::path::Path, kernel: Kernel, rounds: usize) {
    let threads = 4;
    let remote = RemoteEngine::connect(socket, 1)
        .expect("client connects")
        .with_min_shard_len(1);
    let built = npb::build(kernel, threads, SourceVariant::Unoptimized, &Scale::quick());
    let table = BaseTable::regular(threads, 1 << 32, 1 << 32);
    for round in 0..rounds {
        for a in built.rt.arrays() {
            let ctx = EngineCtx::new(a.layout, &table, 1).unwrap();
            let batch = sample_batch(&a.layout, a.nelems, 0xD0C5 ^ round as u64);
            let (mut got, mut want) = (BatchOut::new(), BatchOut::new());
            remote.translate(&ctx, &batch, &mut got).unwrap();
            AutoEngine.translate(&ctx, &batch, &mut want).unwrap();
            assert_eq!(got, want, "{kernel} {} translate round {round}", a.name);
            let (mut gp, mut wp) = (Vec::new(), Vec::new());
            remote.increment(&ctx, &batch, &mut gp).unwrap();
            AutoEngine.increment(&ctx, &batch, &mut wp).unwrap();
            assert_eq!(gp, wp, "{kernel} {} increment round {round}", a.name);
            let start = SharedPtr::for_index(&a.layout, a.base_va, 0);
            remote.walk(&ctx, start, 5, 223, &mut got).unwrap();
            AutoEngine.walk(&ctx, start, 5, 223, &mut want).unwrap();
            assert_eq!(got, want, "{kernel} {} walk round {round}", a.name);
        }
    }
    // every layout re-visited after round 1 rode its installed epoch
    assert!(remote.epoch_hits() >= 1, "{kernel}: no steady-state traffic");
    assert_eq!(remote.reinstalls(), 0, "{kernel}: nothing should go stale");
}

#[test]
fn three_concurrent_sessions_soak_bit_identical_to_auto() {
    let cfg = DaemonCfg::new(scratch_socket("soak"));
    let socket = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    let handles: Vec<_> = [Kernel::Is, Kernel::Cg, Kernel::Mg]
        .into_iter()
        .map(|kernel| {
            let socket = socket.clone();
            std::thread::spawn(move || soak_session(&socket, kernel, 3))
        })
        .collect();
    for h in handles {
        h.join().expect("soak session panicked");
    }
    let stats = daemon.shutdown().expect("clean shutdown");
    assert_eq!(stats.sessions, 3, "one tenant per client connection");
    assert_eq!(stats.shed, 0, "default quotas must not shed this load");
    assert_eq!(stats.stale_epochs, 0);
    assert!(stats.epoch_hits >= 3, "each tenant reused installed epochs");
    for t in &stats.tenants {
        assert!(t.served > 0, "tenant {} served nothing", t.id);
        assert!(t.installs > 0, "tenant {} installed no epoch", t.id);
        assert!(t.ptrs > 0, "tenant {} mapped no pointers", t.id);
    }
}

#[test]
fn daemon_cli_exits_after_sessions_and_prints_the_table() {
    let socket = scratch_socket("cli");
    let mut child = Command::new(env!("CARGO_BIN_EXE_pgas-hw"))
        .arg("daemon")
        .arg("--socket")
        .arg(&socket)
        .args(["--sessions", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn daemon CLI");
    // scope the client so both sessions close before we wait on the
    // child; `connect` retries until the daemon has bound the socket
    let outcome = std::panic::catch_unwind(|| {
        let remote = RemoteEngine::connect(&socket, 2)
            .expect("connect to CLI daemon")
            .with_min_shard_len(1); // fan out over both sessions
        let layout = ArrayLayout::new(4, 8, 6);
        let table = BaseTable::regular(6, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..321u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i), i % 7);
        }
        let (mut got, mut want) = (BatchOut::new(), BatchOut::new());
        remote.translate(&ctx, &batch, &mut got).unwrap();
        AutoEngine.translate(&ctx, &batch, &mut want).unwrap();
        assert_eq!(got, want);
    });
    if outcome.is_err() {
        let _ = child.kill(); // don't leak a serve-forever process
        std::panic::resume_unwind(outcome.unwrap_err());
    }
    // both sessions closed: `--sessions 2` means the daemon exits now
    let out = child.wait_with_output().expect("daemon exits");
    assert!(
        out.status.success(),
        "daemon CLI failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("Daemon sessions"), "no stats table:\n{stdout}");
    assert!(stdout.contains("epoch hits"), "missing column:\n{stdout}");
    assert!(stdout.contains("leon3 lease"), "missing lease line:\n{stdout}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("daemon: serving on"), "no banner:\n{stderr}");
}
