//! Integration: the XLA/PJRT address-mapping unit (AOT artifacts from
//! the Python compile path) against the scalar Rust oracle and the
//! simulated machine's own PGAS instructions.
//!
//! Requires `make artifacts`; the Makefile's `test` target guarantees
//! the ordering.

use pgas_hw::runtime::{unit_batch_scalar, UnitCfg, XlaUnit, UNIT_BATCH, WALK_LEN};
use pgas_hw::sptr::{increment_pow2, ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;

fn load() -> XlaUnit {
    XlaUnit::load("artifacts").expect("run `make artifacts` before `cargo test`")
}

fn cfg(l2bs: u32, l2es: u32, l2nt: u32, mythread: u32) -> UnitCfg {
    UnitCfg {
        log2_blocksize: l2bs,
        log2_elemsize: l2es,
        log2_numthreads: l2nt,
        mythread,
        log2_threads_per_mc: 1,
        log2_threads_per_node: 6,
    }
}

#[test]
fn unit_matches_scalar_oracle_on_random_batches() {
    let unit = load();
    let mut rng = Xoshiro256::new(0xA11CE);
    for round in 0..6 {
        let l2bs = rng.below(9) as u32;
        let l2es = rng.below(4) as u32;
        let l2nt = rng.below(7) as u32;
        let t = 1u32 << l2nt;
        let c = cfg(l2bs, l2es, l2nt, rng.below(t as u64) as u32);
        let table = BaseTable::regular(t, 1 << 32, 1 << 32);
        let layout = ArrayLayout::new(1 << l2bs, 1 << l2es, t);
        let n = 1 + rng.below(UNIT_BATCH as u64) as usize;
        let ptrs: Vec<SharedPtr> = (0..n)
            .map(|_| SharedPtr::for_index(&layout, 0, rng.below(1 << 18)))
            .collect();
        let incs: Vec<u32> = (0..n).map(|_| rng.below(1 << 13) as u32).collect();
        let got = unit.unit_batch(&c, &table, &ptrs, &incs).unwrap();
        let want = unit_batch_scalar(&c, &table, &ptrs, &incs);
        assert_eq!(got.thread, want.thread, "round {round}");
        assert_eq!(got.phase, want.phase, "round {round}");
        assert_eq!(got.va, want.va, "round {round}");
        assert_eq!(got.sysva, want.sysva, "round {round}");
        assert_eq!(got.loc, want.loc, "round {round}");
    }
}

#[test]
fn inc_batch_matches_increment_pow2() {
    let unit = load();
    let c = cfg(4, 3, 3, 0);
    let layout = ArrayLayout::new(16, 8, 8);
    let mut rng = Xoshiro256::new(7);
    let ptrs: Vec<SharedPtr> = (0..100)
        .map(|_| SharedPtr::for_index(&layout, 0, rng.below(1 << 12)))
        .collect();
    let incs: Vec<u32> = (0..100).map(|_| rng.below(100) as u32).collect();
    let got = unit.inc_batch(&c, &ptrs, &incs).unwrap();
    for i in 0..100 {
        let want = increment_pow2(&ptrs[i], incs[i] as u64, 4, 3, 3);
        assert_eq!(got[i], want, "ptr {i}");
    }
}

#[test]
fn walker_trace_matches_scalar_walk_and_simulated_machine() {
    let unit = load();
    let c = cfg(2, 2, 2, 0);
    let table = BaseTable::regular(4, 1 << 32, 1 << 32);
    let (sysva, thread, loc) = unit.walk(&c, &table, &SharedPtr::NULL, 1).unwrap();
    assert_eq!(sysva.len(), WALK_LEN);
    // scalar walk
    let mut p = SharedPtr::NULL;
    for i in 0..WALK_LEN {
        assert_eq!(thread[i] as u32, p.thread, "step {i}");
        assert_eq!(sysva[i] as u64, table.base(p.thread) + p.va, "step {i}");
        let want_loc = pgas_hw::sptr::locality(
            p.thread,
            0,
            &pgas_hw::sptr::Topology {
                log2_threads_per_mc: 1,
                log2_threads_per_node: 6,
            },
        ) as i32;
        assert_eq!(loc[i], want_loc, "step {i}");
        p = increment_pow2(&p, 1, 2, 2, 2);
    }
    // the walk visits the Figure-2 pattern: threads 0,0,0,0,1,1,1,1,...
    for (i, &th) in thread.iter().take(32).enumerate() {
        assert_eq!(th as u64, (i as u64 / 4) % 4, "figure-2 pattern at {i}");
    }
}

#[test]
fn unit_agrees_with_simulated_pgas_instructions() {
    // the same semantics three ways: XLA unit, scalar Rust, and the
    // machine executing actual PgasIncI instructions
    use pgas_hw::cpu::{AtomicCpu, Cpu, HierLatency, SharedLevel};
    use pgas_hw::isa::{Inst, Program};
    use pgas_hw::mem::MemSystem;
    use pgas_hw::sptr::{pack, unpack};

    let unit = load();
    let c = cfg(3, 2, 2, 0);
    let layout = ArrayLayout::new(8, 4, 4);
    let start = SharedPtr::for_index(&layout, 0, 5);
    let steps = 64u32;

    // machine path
    let mut insts = vec![Inst::Ldi { rd: 1, imm: pack(&start) as i64 }];
    for _ in 0..steps {
        insts.push(Inst::PgasIncI { rd: 1, ra: 1, l2es: 2, l2bs: 3, l2inc: 0 });
    }
    insts.push(Inst::Halt);
    let prog = Program::new("incs", insts);
    let mut cpu = AtomicCpu::new(0, 4);
    let mut mem = MemSystem::new(4);
    let mut sh = SharedLevel::new(1, HierLatency::default());
    cpu.run(&prog, &mut mem, &mut sh, u64::MAX);
    let machine_result = unpack(cpu.state().r(1));

    // XLA path
    let got = unit
        .inc_batch(&c, &[start], &[steps])
        .unwrap();
    assert_eq!(got[0], machine_result);

    // scalar path
    let scalar = increment_pow2(&start, steps as u64, 3, 2, 2);
    assert_eq!(scalar, machine_result);
}
