//! Randomized differential conformance of the inspector/executor
//! gather tier (`GatherPlan`).
//!
//! The contract under test: bucketing an index vector by owning thread,
//! dispatching one aggregated batch per owner through *any*
//! `AddressEngine`, and splicing the per-owner results back into
//! request order is **bit-identical** to the naive per-element
//! `translate_one` path — for every index-vector shape (duplicates,
//! out-of-order, hot-spots, empty, single-owner), every backend
//! (software, pow2, sharded, remote worker processes, daemon epoch
//! sessions) and every shared-array layout the NPB kernels allocate,
//! and invariant under the sharded tier's worker count.
//!
//! Sockets only — no network — so the suite stays tier-1-safe.

use pgas_hw::compiler::SourceVariant;
use pgas_hw::daemon::{scratch_socket, Daemon, DaemonCfg};
use pgas_hw::engine::{
    AddressEngine, BatchOut, EngineCtx, GatherPlan, Pow2Engine, PtrBatch,
    RemoteEngine, ShardedEngine, SoftwareEngine,
};
use pgas_hw::npb::{self, Kernel, Scale};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;

/// The naive executor: one engine dispatch per element, in request
/// order — the golden reference every planned execution must match
/// bit for bit.
fn per_element(
    engine: &dyn AddressEngine,
    ctx: &EngineCtx,
    batch: &PtrBatch,
) -> BatchOut {
    let mut out = BatchOut::new();
    out.reserve(batch.len());
    for i in 0..batch.len() {
        let (p, va, loc) = engine
            .translate_one(ctx, batch.ptrs[i], batch.incs[i])
            .unwrap();
        out.push(p, va, loc);
    }
    out
}

/// Seeded index-vector shapes: the distributions an irregular kernel
/// actually produces.
fn index_shapes(nelems: u64, seed: u64) -> Vec<(&'static str, Vec<u64>)> {
    let mut rng = Xoshiro256::new(seed);
    let n = 193usize;
    let uniform: Vec<u64> = (0..n).map(|_| rng.below(nelems)).collect();
    let mut descending = uniform.clone();
    descending.sort_unstable_by(|a, b| b.cmp(a));
    let dup = rng.below(nelems);
    let duplicates: Vec<u64> =
        (0..n).map(|i| if i % 3 == 0 { dup } else { rng.below(nelems) }).collect();
    let hot = rng.below(nelems);
    let hotspot: Vec<u64> = (0..n)
        .map(|i| if i % 10 == 0 { rng.below(nelems) } else { hot })
        .collect();
    // every index inside the first block → a single owning thread
    let single_owner: Vec<u64> = (0..n).map(|_| rng.below(nelems.min(4).max(1))).collect();
    vec![
        ("uniform", uniform),
        ("out-of-order", descending),
        ("duplicates", duplicates),
        ("hot-spot", hotspot),
        ("single-owner", single_owner),
        ("empty", Vec::new()),
    ]
}

fn batch_of(layout: &ArrayLayout, base_va: u64, indices: &[u64]) -> PtrBatch {
    let base = SharedPtr::for_index(layout, base_va, 0);
    let mut b = PtrBatch::with_capacity(indices.len());
    for &i in indices {
        b.push(base, i);
    }
    b
}

#[test]
fn planned_execution_matches_per_element_on_all_index_shapes() {
    // one hw-mappable layout (the paper's Fig. 2 shape, scaled) and one
    // non-pow2 layout only the software path serves
    let cases = [
        (ArrayLayout::new(64, 8, 16), 1u64 << 20),
        (ArrayLayout::new(3, 24, 5), 3 * 5 * 7),
    ];
    for (layout, nelems) in cases {
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        for (shape, indices) in index_shapes(nelems, 0x6A7E_0001 ^ nelems) {
            let batch = batch_of(&layout, 0, &indices);
            let plan = GatherPlan::from_batch(&ctx, &batch).unwrap();
            assert_eq!(plan.len(), indices.len(), "{shape}");
            if indices.is_empty() {
                assert!(plan.is_empty(), "{shape}");
            }
            let want = per_element(&SoftwareEngine, &ctx, &batch);
            let mut got = BatchOut::new();
            plan.execute(&SoftwareEngine, &ctx, &mut got).unwrap();
            assert_eq!(got, want, "software, {shape}, T={}", layout.numthreads);
            if layout.hw_supported() {
                plan.execute(&Pow2Engine, &ctx, &mut got).unwrap();
                assert_eq!(got, want, "pow2, {shape}");
            }
            // the increment leg splices identically
            let mut inc_got = Vec::new();
            plan.execute_increment(&SoftwareEngine, &ctx, &mut inc_got)
                .unwrap();
            assert_eq!(inc_got, want.ptrs, "increment splice, {shape}");
        }
    }
}

#[test]
fn planned_execution_matches_across_all_backends_and_npb_layouts() {
    let threads = 4;
    let table = BaseTable::regular(threads, 1 << 32, 1 << 32);
    let sharded = ShardedEngine::new(SoftwareEngine, 3).with_min_shard_len(1);
    let remote = RemoteEngine::spawn_with_bin(env!("CARGO_BIN_EXE_pgas-hw"), 2)
        .expect("spawn remote worker pool")
        .with_min_shard_len(1);
    let cfg = DaemonCfg::new(scratch_socket("gather-conf"));
    let sock = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    {
        let sessions =
            RemoteEngine::connect(&sock, 1).expect("connect daemon session");
        for kernel in Kernel::ALL {
            let built = npb::build(
                kernel,
                threads,
                SourceVariant::Unoptimized,
                &Scale::quick(),
            );
            for a in built.rt.arrays() {
                let ctx = EngineCtx::new(a.layout, &table, 1).unwrap();
                let mut rng = Xoshiro256::new(0x6A7E_0002 ^ a.nelems);
                let indices: Vec<u64> =
                    (0..157).map(|_| rng.below(a.nelems.max(1))).collect();
                let batch = batch_of(&a.layout, a.base_va, &indices);
                let plan = GatherPlan::from_batch(&ctx, &batch).unwrap();
                let want = per_element(&SoftwareEngine, &ctx, &batch);
                let mut backends: Vec<(&str, &dyn AddressEngine)> = vec![
                    ("software", &SoftwareEngine),
                    ("sharded", &sharded),
                    ("remote", &remote),
                    ("daemon", &sessions),
                ];
                if a.layout.hw_supported() {
                    backends.push(("pow2", &Pow2Engine));
                }
                for (name, engine) in backends {
                    let mut got = BatchOut::new();
                    plan.execute(engine, &ctx, &mut got).unwrap();
                    assert_eq!(got, want, "{kernel}/{name} planned gather");
                }
            }
        }
    }
    daemon.shutdown().expect("daemon shutdown");
}

#[test]
fn planned_execution_is_invariant_under_shard_count() {
    let layout = ArrayLayout::new(64, 8, 16);
    let table = BaseTable::regular(16, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 0).unwrap();
    let mut rng = Xoshiro256::new(0x6A7E_0003);
    let indices: Vec<u64> = (0..2048).map(|_| rng.below(1 << 20)).collect();
    let batch = batch_of(&layout, 0, &indices);
    let plan = GatherPlan::from_batch(&ctx, &batch).unwrap();
    let want = per_element(&SoftwareEngine, &ctx, &batch);
    for workers in [1usize, 2, 4, 7] {
        let sharded =
            ShardedEngine::new(SoftwareEngine, workers).with_min_shard_len(1);
        let mut got = BatchOut::new();
        plan.execute(&sharded, &ctx, &mut got).unwrap();
        assert_eq!(got, want, "sharded x{workers}");
    }
}

#[test]
fn buckets_cover_every_request_exactly_once() {
    let layout = ArrayLayout::new(4, 4, 4); // the paper's Fig. 2 layout
    let table = BaseTable::regular(4, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 0).unwrap();
    let mut rng = Xoshiro256::new(0x6A7E_0004);
    let indices: Vec<u64> = (0..117).map(|_| rng.below(64)).collect();
    let batch = batch_of(&layout, 0, &indices);
    let plan = GatherPlan::from_batch(&ctx, &batch).unwrap();
    let total: usize = plan.buckets().iter().map(|b| b.len()).sum();
    assert_eq!(total, indices.len(), "buckets partition the request");
    assert_eq!(plan.owners().len(), plan.bucket_count());
    // every bucket is single-owner: all its pointers land on the
    // bucket's owning thread
    for (owner, bucket) in plan.owners().iter().zip(plan.buckets()) {
        for i in 0..bucket.len() {
            let (p, _, _) = SoftwareEngine
                .translate_one(&ctx, bucket.ptrs[i], bucket.incs[i])
                .unwrap();
            assert_eq!(p.thread, *owner, "bucket owner mismatch");
        }
    }
}
