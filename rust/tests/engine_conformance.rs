//! The shared `AddressEngine` conformance suite.
//!
//! Differential contract: every backend that claims to support a layout
//! must produce identical `(thread, phase, va, sysva, loc)` outputs.
//! [`SoftwareEngine`] (general Algorithm 1) is the reference;
//! [`Pow2Engine`] is checked against it on randomized pow2 layouts,
//! [`Leon3Engine`] (instruction replay on the FPGA-prototype
//! functional core) on the same layouts, and — when built with
//! `--features xla-unit` and artifacts are present — `XlaBatchEngine`
//! too.  (`rust/tests/leon3_engine.rs` extends the Leon3 differentials
//! to the real NPB array layouts and the Fig. 15/16 cycle pins.)
//!
//! Plus the satellite property tests: `pack`/`unpack` round-trips and
//! `ArrayLayout::bytes_on_thread` against a naive per-element reference.

use pgas_hw::engine::{
    AddressEngine, BatchOut, EngineCtx, EngineChoice, EngineSelector,
    Leon3Engine, Pow2Engine, PtrBatch, ShardedEngine, SimdEngine,
    SoftwareEngine, TilePlan,
};
use pgas_hw::sptr::{
    increment_general, pack, unpack, ArrayLayout, BaseTable, SharedPtr,
    Topology, WalkCursor, PHASE_BITS, THREAD_BITS, VA_BITS,
};
use pgas_hw::util::rng::Xoshiro256;
use pgas_hw::util::testkit::{check, check_default};

/// A random pow2 layout + matching table/context inputs.
fn random_pow2_case(
    rng: &mut Xoshiro256,
) -> (ArrayLayout, BaseTable, u32, PtrBatch) {
    let l2bs = rng.below(10) as u32;
    let l2es = rng.below(6) as u32;
    let l2nt = rng.below(7) as u32;
    let layout = ArrayLayout::new(1 << l2bs, 1 << l2es, 1 << l2nt);
    let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
    let mythread = rng.below(layout.numthreads as u64) as u32;
    let n = 1 + rng.below(512) as usize;
    let mut batch = PtrBatch::with_capacity(n);
    for _ in 0..n {
        batch.push(
            SharedPtr::for_index(&layout, 0, rng.below(1 << 16)),
            rng.below(1 << 13),
        );
    }
    (layout, table, mythread, batch)
}

#[test]
fn software_and_pow2_translate_identically_on_pow2_layouts() {
    check("engine conformance: translate", 64, |rng| {
        let (layout, table, mythread, batch) = random_pow2_case(rng);
        let ctx = EngineCtx::new(layout, &table, mythread)
            .unwrap()
            .with_topology(Topology { log2_threads_per_mc: 1, log2_threads_per_node: 3 });
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        SoftwareEngine.translate(&ctx, &batch, &mut a).unwrap();
        Pow2Engine.translate(&ctx, &batch, &mut b).unwrap();
        assert_eq!(a, b, "layout={layout:?}");
    });
}

#[test]
fn software_and_pow2_increment_identically_on_pow2_layouts() {
    check("engine conformance: increment", 64, |rng| {
        let (layout, table, mythread, batch) = random_pow2_case(rng);
        let ctx = EngineCtx::new(layout, &table, mythread).unwrap();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        SoftwareEngine.increment(&ctx, &batch, &mut a).unwrap();
        Pow2Engine.increment(&ctx, &batch, &mut b).unwrap();
        assert_eq!(a, b, "layout={layout:?}");
        // increments also agree with direct index arithmetic
        for (i, q) in a.iter().enumerate() {
            let idx = batch.ptrs[i].to_index(&layout, 0) + batch.incs[i];
            assert_eq!(*q, SharedPtr::for_index(&layout, 0, idx));
        }
    });
}

#[test]
fn software_and_pow2_walk_identically_on_pow2_layouts() {
    check("engine conformance: walk", 48, |rng| {
        let (layout, table, mythread, _) = random_pow2_case(rng);
        let ctx = EngineCtx::new(layout, &table, mythread).unwrap();
        let start = SharedPtr::for_index(&layout, 0, rng.below(1 << 12));
        let inc = 1 + rng.below(64);
        let steps = 1 + rng.below(256) as usize;
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        SoftwareEngine.walk(&ctx, start, inc, steps, &mut a).unwrap();
        Pow2Engine.walk(&ctx, start, inc, steps, &mut b).unwrap();
        assert_eq!(a, b, "layout={layout:?} inc={inc} steps={steps}");
        assert_eq!(a.len(), steps);
        assert_eq!(a.ptrs[0], start, "step 0 must be the start pointer");
    });
}

#[test]
fn selector_output_equals_direct_backend_output() {
    let sel = EngineSelector::new();
    let mut rng = Xoshiro256::new(0xE9E);
    for _ in 0..16 {
        let (layout, table, mythread, batch) = random_pow2_case(&mut rng);
        assert_eq!(sel.choice(&layout, batch.len()), EngineChoice::Pow2);
        let ctx = EngineCtx::new(layout, &table, mythread).unwrap();
        let (mut via_sel, mut direct) = (BatchOut::new(), BatchOut::new());
        sel.translate(&ctx, &batch, &mut via_sel).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via_sel, direct);
    }
}

#[test]
fn nonpow2_layouts_fall_back_to_software_tiers() {
    // A single-worker selector has no shard pool: the cost model
    // degenerates to pow2-else-software, with the vectorized lanes
    // undercutting scalar software once the batch fills them.
    let sel = EngineSelector::new().with_shard_workers(1);
    let layout = ArrayLayout::new(3, 56016, 5); // CG's w/w_tmp shape
    assert_eq!(sel.choice(&layout, 4), EngineChoice::Software);
    assert_eq!(sel.choice(&layout, 1 << 20), EngineChoice::Simd);
    // with enough workers the huge batch amortizes the pool fee past
    // even the vector lanes (12ns/8 + 1.5ns copy < 4ns simd)
    let pooled = EngineSelector::new().with_shard_workers(8);
    assert_eq!(pooled.choice(&layout, 1 << 20), EngineChoice::Sharded);
    let table = BaseTable::regular(5, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 0).unwrap();
    let mut batch = PtrBatch::new();
    batch.push(SharedPtr::for_index(&layout, 0, 7), 11);
    let mut out = BatchOut::new();
    // the selector serves it...
    sel.translate(&ctx, &batch, &mut out).unwrap();
    assert_eq!(out.ptrs[0], SharedPtr::for_index(&layout, 0, 18));
    // ...while the pow2 backend refuses rather than answering wrongly
    assert!(Pow2Engine.translate(&ctx, &batch, &mut out).is_err());
}

// ---- the sharded engine joins the same differential suite ----

/// A random layout from a pool that mixes pow2 geometry with the
/// NPB kernels' awkward element sizes (CG's 112-byte rows, the
/// 56016-byte w_tmp struct).
fn random_any_layout(rng: &mut Xoshiro256) -> ArrayLayout {
    let elemsize: u64 = [1, 2, 4, 8, 24, 112, 56016][rng.below(7) as usize];
    ArrayLayout::new(
        rng.below(64) + 1,
        elemsize,
        rng.below(63) as u32 + 1,
    )
}

#[test]
fn sharded_matches_inner_over_all_layouts() {
    // min_shard_len 1 forces real fan-out + splice even on small
    // batches; the pool persists across all property cases.
    let sharded = ShardedEngine::new(SoftwareEngine, 4).with_min_shard_len(1);
    check("sharded == software (translate/increment/walk)", 48, |rng| {
        let layout = random_any_layout(rng);
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let mythread = rng.below(layout.numthreads as u64) as u32;
        let ctx = EngineCtx::new(layout, &table, mythread).unwrap();
        let n = 1 + rng.below(700) as usize;
        let mut batch = PtrBatch::with_capacity(n);
        for _ in 0..n {
            batch.push(
                SharedPtr::for_index(&layout, 0, rng.below(1 << 16)),
                rng.below(1 << 13),
            );
        }
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        sharded.translate(&ctx, &batch, &mut a).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut b).unwrap();
        assert_eq!(a, b, "translate layout={layout:?} n={n}");
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        sharded.increment(&ctx, &batch, &mut pa).unwrap();
        SoftwareEngine.increment(&ctx, &batch, &mut pb).unwrap();
        assert_eq!(pa, pb, "increment layout={layout:?} n={n}");
        let start = SharedPtr::for_index(&layout, 0, rng.below(1 << 12));
        let inc = rng.below(256);
        let steps = 1 + rng.below(500) as usize;
        sharded.walk(&ctx, start, inc, steps, &mut a).unwrap();
        SoftwareEngine.walk(&ctx, start, inc, steps, &mut b).unwrap();
        assert_eq!(a, b, "walk layout={layout:?} inc={inc} steps={steps}");
    });
}

#[test]
fn sharded_output_is_invariant_across_shard_counts() {
    // CG's non-pow2 112-byte element layout and a pow2 layout, each
    // checked at 1/2/4/7 shards against the unsharded inner engine.
    let cases = [
        (ArrayLayout::new(3, 112, 5), 2u32),
        (ArrayLayout::new(16, 8, 8), 3u32),
    ];
    for (layout, mythread) in cases {
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, mythread).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..501u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 3), i % 113);
        }
        let mut want = BatchOut::new();
        SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();
        let mut want_walk = BatchOut::new();
        SoftwareEngine
            .walk(&ctx, batch.ptrs[0], 7, 501, &mut want_walk)
            .unwrap();
        for shards in [1, 2, 4, 7] {
            let sharded = ShardedEngine::new(SoftwareEngine, shards)
                .with_min_shard_len(1);
            let mut got = BatchOut::new();
            sharded.translate(&ctx, &batch, &mut got).unwrap();
            assert_eq!(got, want, "translate shards={shards} {layout:?}");
            sharded.walk(&ctx, batch.ptrs[0], 7, 501, &mut got).unwrap();
            assert_eq!(got, want_walk, "walk shards={shards} {layout:?}");
        }
    }
}

#[test]
fn sharded_pow2_inner_matches_pow2_on_pow2_layouts() {
    let sharded = ShardedEngine::new(Pow2Engine, 7).with_min_shard_len(1);
    check("sharded(pow2) == pow2", 24, |rng| {
        let (layout, table, mythread, batch) = random_pow2_case(rng);
        let ctx = EngineCtx::new(layout, &table, mythread).unwrap();
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        sharded.translate(&ctx, &batch, &mut a).unwrap();
        Pow2Engine.translate(&ctx, &batch, &mut b).unwrap();
        assert_eq!(a, b, "layout={layout:?}");
    });
}

// ---- the vectorized software tier joins the same differential suite ----

/// The seven NPB-shaped layouts the kernels actually allocate: the
/// pow2 fast-path geometries (EP/IS/MG/FT), CG's two awkward element
/// sizes (112-byte struct rows, the 56016-byte w/w_tmp struct), and
/// the irregular MD/SPMV record shapes — both SIMD code paths (shift/
/// mask lanes and reciprocal lanes) and every scalar-tail length get
/// exercised across this pool.
fn npb_layouts() -> [ArrayLayout; 7] {
    [
        ArrayLayout::new(1024, 8, 16), // EP: pow2 accumulator chunks
        ArrayLayout::new(512, 4, 32),  // IS: pow2 key buckets
        ArrayLayout::new(3, 112, 5),   // CG: non-pow2 struct rows
        ArrayLayout::new(1, 56016, 8), // CG: the w/w_tmp struct
        ArrayLayout::new(8, 8, 8),     // MG/FT: pow2 grids
        ArrayLayout::new(7, 24, 6),    // MD: neighbor-list records
        ArrayLayout::new(13, 12, 10),  // SPMV: CSR row segments
    ]
}

/// A deterministic batch of random in-range pointers over `layout`.
fn batch_for(layout: &ArrayLayout, n: usize, seed: u64) -> PtrBatch {
    let mut rng = Xoshiro256::new(seed);
    let mut batch = PtrBatch::with_capacity(n);
    for _ in 0..n {
        batch.push(
            SharedPtr::for_index(layout, 0, rng.below(1 << 16)),
            rng.below(1 << 13),
        );
    }
    batch
}

#[test]
fn simd_matches_software_over_all_npb_layouts() {
    // Batch lengths straddle the lane width: full-lane multiples,
    // every tail remainder, and a sub-lane batch served tail-only.
    for layout in npb_layouts() {
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1)
            .unwrap()
            .with_topology(Topology {
                log2_threads_per_mc: 1,
                log2_threads_per_node: 2,
            });
        for n in [1, 3, 4, 5, 63, 64, 67, 1021] {
            let batch = batch_for(&layout, n, 0x51D0 + n as u64);
            let (mut v, mut s) = (BatchOut::new(), BatchOut::new());
            SimdEngine.translate(&ctx, &batch, &mut v).unwrap();
            SoftwareEngine.translate(&ctx, &batch, &mut s).unwrap();
            assert_eq!(v, s, "translate layout={layout:?} n={n}");
            let (mut pv, mut ps) = (Vec::new(), Vec::new());
            SimdEngine.increment(&ctx, &batch, &mut pv).unwrap();
            SoftwareEngine.increment(&ctx, &batch, &mut ps).unwrap();
            assert_eq!(pv, ps, "increment layout={layout:?} n={n}");
        }
        // walks ride the shared O(1) stepper: same outputs by the
        // same code, but the contract is worth pinning
        let start = SharedPtr::for_index(&layout, 0, 11);
        let (mut wv, mut ws) = (BatchOut::new(), BatchOut::new());
        SimdEngine.walk(&ctx, start, 13, 200, &mut wv).unwrap();
        SoftwareEngine.walk(&ctx, start, 13, 200, &mut ws).unwrap();
        assert_eq!(wv, ws, "walk layout={layout:?}");
    }
}

// ---- the cache-blocked batch planner joins the differential suite ----

#[test]
fn planned_execution_is_invariant_across_tile_sizes() {
    // Degenerate single-pointer tiles, sub-lane tiles, L1-ish tiles
    // and one-tile-covers-everything must all reproduce the direct
    // translate/increment bit-for-bit — the planner may only reorder
    // *work*, never *results*.
    for layout in npb_layouts() {
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let batch = batch_for(&layout, 777, 0x71E5);
        let mut want = BatchOut::new();
        SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();
        let mut want_inc = Vec::new();
        SoftwareEngine.increment(&ctx, &batch, &mut want_inc).unwrap();
        for tile in [1, 4, 64, 4096] {
            let plan = TilePlan::from_batch(&ctx, &batch, tile).unwrap();
            let mut got = BatchOut::new();
            SoftwareEngine
                .translate_planned(&ctx, &batch, &plan, &mut got)
                .unwrap();
            assert_eq!(got, want, "translate layout={layout:?} tile={tile}");
            let mut got_inc = Vec::new();
            SoftwareEngine
                .increment_planned(&ctx, &batch, &plan, &mut got_inc)
                .unwrap();
            assert_eq!(got_inc, want_inc, "increment layout={layout:?} tile={tile}");
            // the vectorized tier executes the same plan identically
            let mut simd_got = BatchOut::new();
            SimdEngine
                .translate_planned(&ctx, &batch, &plan, &mut simd_got)
                .unwrap();
            assert_eq!(simd_got, want, "simd planned layout={layout:?} tile={tile}");
        }
    }
}

#[test]
fn selector_planned_path_matches_unplanned_selector() {
    // Same batches through a plan-eager selector (tiny threshold +
    // tile) and a plan-never selector: outputs identical, and the
    // eager one's counters prove the tiled path actually ran.
    let planned = EngineSelector::new()
        .with_shard_workers(1)
        .with_plan_threshold(64)
        .with_plan_tile(32);
    let unplanned = EngineSelector::new()
        .with_shard_workers(1)
        .with_plan_threshold(usize::MAX);
    for layout in npb_layouts() {
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let batch = batch_for(&layout, 500, 0xBEEF);
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        planned.translate(&ctx, &batch, &mut a).unwrap();
        unplanned.translate(&ctx, &batch, &mut b).unwrap();
        assert_eq!(a, b, "planned != unplanned on {layout:?}");
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        planned.increment(&ctx, &batch, &mut pa).unwrap();
        unplanned.increment(&ctx, &batch, &mut pb).unwrap();
        assert_eq!(pa, pb, "planned inc != unplanned inc on {layout:?}");
    }
    let stats = planned.plan_stats();
    assert!(stats.plans > 0, "plan-eager selector never planned: {stats:?}");
    assert!(stats.tiles >= 2 * stats.plans);
    assert_eq!(unplanned.plan_stats().plans, 0);
}

// ---- the Leon3 coprocessor model joins the same differential suite ----

#[test]
fn leon3_matches_software_on_pow2_layouts() {
    let leon3 = Leon3Engine::new();
    check("leon3 == software (translate/increment/walk)", 24, |rng| {
        let (layout, table, mythread, batch) = random_pow2_case(rng);
        let ctx = EngineCtx::new(layout, &table, mythread)
            .unwrap()
            .with_topology(Topology {
                log2_threads_per_mc: 1,
                log2_threads_per_node: 3,
            });
        let (mut hw, mut sw) = (BatchOut::new(), BatchOut::new());
        leon3.translate(&ctx, &batch, &mut hw).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut sw).unwrap();
        assert_eq!(hw, sw, "translate layout={layout:?}");
        let (mut ph, mut ps) = (Vec::new(), Vec::new());
        leon3.increment(&ctx, &batch, &mut ph).unwrap();
        SoftwareEngine.increment(&ctx, &batch, &mut ps).unwrap();
        assert_eq!(ph, ps, "increment layout={layout:?}");
        let start = SharedPtr::for_index(&layout, 0, rng.below(1 << 12));
        let inc = rng.below(64);
        let steps = 1 + rng.below(200) as usize;
        leon3.walk(&ctx, start, inc, steps, &mut hw).unwrap();
        SoftwareEngine.walk(&ctx, start, inc, steps, &mut sw).unwrap();
        assert_eq!(hw, sw, "walk layout={layout:?} inc={inc} steps={steps}");
        assert!(leon3.last_cycles() > 0, "walks must bill cycles");
    });
}

#[test]
fn leon3_refuses_what_pow2_refuses() {
    // the hardware gate is shared: any layout Pow2Engine turns down,
    // Leon3Engine must turn down too (never answer wrongly)
    let leon3 = Leon3Engine::new();
    for layout in [
        ArrayLayout::new(3, 8, 4),      // non-pow2 blocksize
        ArrayLayout::new(4, 112, 4),    // CG's 112-byte element rows
        ArrayLayout::new(1, 56016, 8),  // CG's w/w_tmp struct
        ArrayLayout::new(5, 4, 6),      // nothing pow2 at all
    ] {
        assert!(!Pow2Engine.supports(&layout));
        assert!(!leon3.supports(&layout), "layout={layout:?}");
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::for_index(&layout, 0, 1), 2);
        let mut out = BatchOut::new();
        assert!(leon3.translate(&ctx, &batch, &mut out).is_err());
        assert!(leon3.walk(&ctx, SharedPtr::NULL, 1, 4, &mut out).is_err());
    }
}

// ---- satellite: WalkCursor vs increment_general over random strides ----

#[test]
fn walk_cursor_matches_increment_general_over_random_strides() {
    check("WalkCursor == repeated increment_general", 96, |rng| {
        let layout = random_any_layout(rng);
        let start = SharedPtr::for_index(&layout, 0, rng.below(1 << 16));
        let inc = rng.below(1 << 14);
        let mut cursor = WalkCursor::new(start, inc, &layout);
        let mut want = start;
        for step in 0..64 {
            assert_eq!(
                cursor.current(),
                want,
                "layout={layout:?} inc={inc} step={step}"
            );
            cursor.advance();
            want = increment_general(&want, inc, &layout);
        }
    });
}

// ---- satellite: pack/unpack round-trip properties ----

#[test]
fn pack_unpack_roundtrips_both_ways() {
    check_default("pack(unpack(bits)) == bits and back", |rng| {
        // ptr -> bits -> ptr
        let p = SharedPtr {
            thread: rng.below(1 << THREAD_BITS) as u32,
            phase: rng.below(1 << PHASE_BITS),
            va: rng.below(1 << VA_BITS),
        };
        assert_eq!(unpack(pack(&p)), p);
        // bits -> ptr -> bits (any 64-bit pattern is a valid packing)
        let bits = rng.below(u64::MAX);
        assert_eq!(pack(&unpack(bits)), bits);
    });
}

// ---- satellite: bytes_on_thread vs a naive per-element reference ----

/// Count elements 0..n owned by thread `t` one at a time.
fn naive_bytes_on_thread(layout: &ArrayLayout, n: u64, t: u32) -> u64 {
    let mut elems = 0;
    for i in 0..n {
        if SharedPtr::for_index(layout, 0, i).thread == t {
            elems += 1;
        }
    }
    elems * layout.elemsize
}

#[test]
fn bytes_on_thread_matches_naive_reference() {
    check("bytes_on_thread == naive", 64, |rng| {
        let layout = ArrayLayout::new(
            rng.below(9) + 1,
            rng.below(16) + 1,
            rng.below(7) as u32 + 1,
        );
        let round = layout.blocksize * layout.numthreads as u64;
        // exercise the boundaries: around whole rounds, block edges, 0
        let candidates = [
            0,
            1,
            round.saturating_sub(1),
            round,
            round + 1,
            round * 3 + layout.blocksize,
            round * 3 + layout.blocksize + 1,
            rng.below(4 * round + 1),
        ];
        for &n in &candidates {
            for t in 0..layout.numthreads {
                assert_eq!(
                    layout.bytes_on_thread(n, t),
                    naive_bytes_on_thread(&layout, n, t),
                    "layout={layout:?} n={n} t={t}"
                );
            }
        }
    });
}

// ---- the XLA batch backend joins the same suite when compiled in ----

#[cfg(feature = "xla-unit")]
mod xla {
    use super::*;
    use pgas_hw::engine::XlaBatchEngine;

    fn load() -> Option<XlaBatchEngine> {
        match XlaBatchEngine::load("artifacts") {
            Ok(x) => Some(x),
            Err(e) => {
                eprintln!("skipping XLA conformance: {e}");
                None
            }
        }
    }

    #[test]
    fn xla_batch_translate_matches_software() {
        let Some(x) = load() else { return };
        let mut rng = Xoshiro256::new(0xC0FFEE);
        for round in 0..8 {
            let (layout, table, mythread, batch) = random_pow2_case(&mut rng);
            let ctx = EngineCtx::new(layout, &table, mythread).unwrap();
            let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
            SoftwareEngine.translate(&ctx, &batch, &mut a).unwrap();
            x.translate(&ctx, &batch, &mut b).unwrap();
            assert_eq!(a, b, "round {round} layout={layout:?}");
        }
    }

    #[test]
    fn xla_batch_chunks_oversized_batches() {
        use pgas_hw::runtime::UNIT_BATCH;
        let Some(x) = load() else { return };
        let layout = ArrayLayout::new(64, 8, 16);
        let table = BaseTable::regular(16, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let n = UNIT_BATCH * 2 + 37; // forces 3 chunks incl. a partial
        let mut rng = Xoshiro256::new(9);
        let mut batch = PtrBatch::with_capacity(n);
        for _ in 0..n {
            batch.push(
                SharedPtr::for_index(&layout, 0, rng.below(1 << 20)),
                rng.below(1 << 12),
            );
        }
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        SoftwareEngine.translate(&ctx, &batch, &mut a).unwrap();
        x.translate(&ctx, &batch, &mut b).unwrap();
        assert_eq!(a, b);
        assert_eq!(b.len(), n);
    }

    #[test]
    fn xla_batch_walk_matches_software() {
        use pgas_hw::runtime::WALK_LEN;
        let Some(x) = load() else { return };
        let layout = ArrayLayout::new(4, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let steps = WALK_LEN + 100; // forces a chunked walk
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        SoftwareEngine.walk(&ctx, SharedPtr::NULL, 3, steps, &mut a).unwrap();
        x.walk(&ctx, SharedPtr::NULL, 3, steps, &mut b).unwrap();
        assert_eq!(a, b);
    }
}
