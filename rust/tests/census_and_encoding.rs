//! Integration: compiled-kernel instruction censuses (the numbers the
//! paper quotes for CG), and binary encode/decode over every PGAS
//! instruction that appears in real compiled kernels.

use pgas_hw::isa::encoding::{decode, encode};
use pgas_hw::npb::{compile_only, Kernel, PaperVariant, Scale};

#[test]
fn cg_hw_census_mixes_hw_and_soft_fallback() {
    // Paper: "the generated code contained 309 shared address
    // incrementations but 20 of those were using a non-power of 2
    // element size" — structurally: most incs hardware, a few software
    // (the w_tmp array), all loads/stores of pow2 arrays hardware.
    let (_, stats) = compile_only(Kernel::Cg, 4, PaperVariant::Hw, &Scale { factor: 64 });
    assert!(stats.hw_incs > 0, "{stats:?}");
    assert!(stats.soft_incs > 0, "w_tmp fallback missing: {stats:?}");
    assert!(stats.hw_incs > stats.soft_incs, "{stats:?}");
    assert!(stats.hw_mems > 0);
}

#[test]
fn unopt_variants_emit_no_hw_instructions() {
    for k in Kernel::ALL {
        let (_, stats) = compile_only(k, 4, PaperVariant::Unopt, &Scale::quick());
        assert_eq!(stats.hw_incs, 0, "{k}");
        assert_eq!(stats.hw_mems, 0, "{k}");
    }
}

#[test]
fn manual_variants_emit_fewer_shared_ops_than_unopt() {
    for k in [Kernel::Is, Kernel::Mg, Kernel::Cg] {
        let (_, u) = compile_only(k, 4, PaperVariant::Unopt, &Scale::quick());
        let (_, m) = compile_only(k, 4, PaperVariant::Manual, &Scale::quick());
        assert!(
            m.soft_incs + m.soft_mems < u.soft_incs + u.soft_mems,
            "{k}: manual {m:?} vs unopt {u:?}"
        );
    }
}

#[test]
fn every_compiled_pgas_instruction_encodes_and_roundtrips() {
    for k in Kernel::ALL {
        let built = pgas_hw::npb::build(
            k,
            4,
            pgas_hw::compiler::SourceVariant::Unoptimized,
            &Scale::quick(),
        );
        let ck = pgas_hw::compiler::compile(
            &built.module,
            &built.rt,
            &pgas_hw::compiler::CompileOpts::hw(4),
        );
        let mut n = 0;
        for inst in &ck.program.insts {
            if inst.is_pgas() {
                if let pgas_hw::isa::Inst::PgasBrLoc { target, .. } = inst {
                    if *target >= (1 << 12) {
                        continue; // encoding demo limit
                    }
                }
                let word = encode(inst)
                    .unwrap_or_else(|| panic!("{k}: {inst} must encode"));
                assert_eq!(decode(word), Some(*inst), "{k}: {inst}");
                n += 1;
            }
        }
        assert!(n > 0 || k == Kernel::Ep, "{k} should contain PGAS instructions");
    }
}

#[test]
fn disassembly_roundtrip_is_readable() {
    let built = pgas_hw::npb::build(
        Kernel::Is,
        4,
        pgas_hw::compiler::SourceVariant::Unoptimized,
        &Scale::quick(),
    );
    let ck = pgas_hw::compiler::compile(
        &built.module,
        &built.rt,
        &pgas_hw::compiler::CompileOpts::hw(4),
    );
    let dis = ck.program.disassemble();
    assert!(dis.contains("pgas_inci") || dis.contains("pgas_incr"));
    assert!(dis.contains("pgas_ld") || dis.contains("pgas_st"));
    assert!(dis.contains("barrier"));
}
