//! Differential suite for the shared lookahead pipeline: batched
//! PGAS-increment windows must be *cycle-exact* against scalar
//! stepping in every CPU model (the atomic model bit-identical by
//! construction, timing/detailed because event replay issues the same
//! per-instruction sequence), and the window planner must never batch
//! across a dependent register write.

use pgas_hw::cpu::pipeline::{plan_window, MIN_RUN_INCS};
use pgas_hw::cpu::{AtomicCpu, Cpu, CpuModel, HierLatency, SharedLevel, TimingCpu};
use pgas_hw::isa::{Inst, IntOp, Program, ZERO};
use pgas_hw::mem::MemSystem;
use pgas_hw::npb::{self, Kernel, PaperVariant, Scale};
use pgas_hw::sptr::{pack, ArrayLayout, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;

/// Run one kernel point with the lookahead on and off; everything the
/// figures read must be identical.  Returns how many increments the
/// batched leg served through the engine, so callers can assert the
/// acceptance criterion is not vacuous.
///
/// The 1-IPC atomic model runs at quick scale; the timing/detailed
/// differentials shrink a further 4x because `cargo test` builds are
/// unoptimized and each point simulates twice (the batched-increment
/// windows per iteration are scale-independent, so coverage is
/// unchanged).
fn kernel_differential(model: CpuModel, kernel: Kernel) -> u64 {
    let scale = match model {
        CpuModel::Atomic => Scale::quick(),
        _ => Scale { factor: Scale::quick().factor * 4 },
    };
    let cores = 4u32.min(kernel.max_cores());
    let batched =
        npb::run_lookahead(kernel, PaperVariant::Hw, model, cores, &scale, true);
    let scalar =
        npb::run_lookahead(kernel, PaperVariant::Hw, model, cores, &scale, false);
    assert_eq!(
        batched.result.cycles, scalar.result.cycles,
        "{kernel} {model}: batched vs scalar cycle totals"
    );
    assert_eq!(
        batched.result.total.instructions, scalar.result.total.instructions,
        "{kernel} {model}: dynamic instruction counts"
    );
    assert_eq!(
        batched.result.total.pgas_incs, scalar.result.total.pgas_incs,
        "{kernel} {model}: pgas_inc counts"
    );
    assert_eq!(
        batched.result.total.local_shared_accesses,
        scalar.result.total.local_shared_accesses,
        "{kernel} {model}: locality classification"
    );
    // the scalar leg must not have batched anything; the batched leg
    // accounts every dynamic increment one way or the other
    assert_eq!(scalar.engine_mix().batched_incs, 0);
    let mix = batched.engine_mix();
    assert_eq!(
        mix.batched_incs + mix.scalar_incs,
        batched.result.total.pgas_incs,
        "{kernel} {model}: every increment tallied"
    );
    mix.batched_incs
}

/// All five kernels, one model; asserts the acceptance criterion is
/// not vacuous — at least one kernel must actually route an increment
/// run through a batched AddressEngine call.
fn all_kernels_differential(model: CpuModel) {
    let mut total_batched = 0u64;
    for k in Kernel::ALL {
        total_batched += kernel_differential(model, k);
    }
    assert!(
        total_batched > 0,
        "{model}: no kernel batched a single increment"
    );
}

#[test]
fn timing_model_is_cycle_exact_on_all_kernels() {
    all_kernels_differential(CpuModel::Timing);
}

#[test]
fn detailed_model_is_cycle_exact_on_all_kernels() {
    all_kernels_differential(CpuModel::Detailed);
}

#[test]
fn atomic_model_is_cycle_exact_on_all_kernels() {
    all_kernels_differential(CpuModel::Atomic);
}

// ---- randomized property tests ----

/// Generate a random straight-line block of PGAS increments mixed with
/// ALU ops over registers 1..12, ending in Halt.  Geometries vary so
/// runs break; dependencies arise naturally from the small register
/// set.
fn random_block(rng: &mut Xoshiro256, layout: &ArrayLayout) -> (Vec<Inst>, Vec<u64>) {
    let len = 4 + rng.below(24) as usize;
    let mut insts = Vec::with_capacity(len + 1);
    for _ in 0..len {
        let rd = 1 + rng.below(11) as u8;
        let ra = 1 + rng.below(11) as u8;
        match rng.below(5) {
            0 | 1 => insts.push(Inst::PgasIncI {
                rd,
                ra,
                l2es: 3,
                l2bs: 2,
                l2inc: rng.below(3) as u8,
            }),
            2 => insts.push(Inst::PgasIncR {
                rd,
                ra,
                rb: 1 + rng.below(11) as u8,
                l2es: 3,
                l2bs: 2,
            }),
            // an occasional geometry switch ends any window
            3 => insts.push(Inst::PgasIncI { rd, ra, l2es: 2, l2bs: 2, l2inc: 0 }),
            _ => insts.push(Inst::Opi {
                op: IntOp::Add,
                rd,
                ra,
                imm: rng.below(64) as i32,
            }),
        }
    }
    insts.push(Inst::Halt);
    // seed register file: packed pointers in 1..8, small ints above
    let seeds: Vec<u64> = (0..32)
        .map(|r| {
            if (1..8).contains(&r) {
                pack(&SharedPtr::for_index(layout, 0, rng.below(64)))
            } else {
                rng.below(16)
            }
        })
        .collect();
    (insts, seeds)
}

#[test]
fn planner_never_batches_across_a_dependent_register_write() {
    let layout = ArrayLayout::new(4, 8, 4);
    let mut rng = Xoshiro256::new(0xDEADBEA7);
    let mut windows = 0u64;
    for _ in 0..400 {
        let (insts, _) = random_block(&mut rng, &layout);
        for pc in 0..insts.len() {
            let Some(plan) = plan_window(&insts, pc, 32) else {
                continue;
            };
            windows += 1;
            assert!(plan.incs >= MIN_RUN_INCS);
            assert!(plan.len >= plan.incs);
            // invariant: no increment in the window reads a register
            // written by ANY earlier window member (inc or ALU) — that
            // is what makes serving the batch from pre-window register
            // state legal.
            let mut written = [false; 32];
            let mut incs = 0;
            for inst in &insts[pc..pc + plan.len] {
                match *inst {
                    Inst::PgasIncI { rd, ra, .. } => {
                        assert!(!written[ra as usize], "inc reads written reg");
                        if rd != ZERO {
                            written[rd as usize] = true;
                        }
                        incs += 1;
                    }
                    Inst::PgasIncR { rd, ra, rb, .. } => {
                        assert!(!written[ra as usize], "inc reads written ra");
                        assert!(!written[rb as usize], "inc reads written rb");
                        if rd != ZERO {
                            written[rd as usize] = true;
                        }
                        incs += 1;
                    }
                    Inst::Opi { rd, .. } | Inst::Opr { rd, .. } => {
                        if rd != ZERO {
                            written[rd as usize] = true;
                        }
                    }
                    ref other => panic!("non-batchable inst in window: {other:?}"),
                }
            }
            assert_eq!(incs, plan.incs);
            // the window ends at an increment (trailing ALU trimmed)
            assert!(matches!(
                insts[pc + plan.len - 1],
                Inst::PgasIncI { .. } | Inst::PgasIncR { .. }
            ));
        }
    }
    assert!(windows > 100, "property test exercised only {windows} windows");
}

#[test]
fn random_blocks_execute_bit_identically_batched_and_scalar() {
    let layout = ArrayLayout::new(4, 8, 4);
    let mut rng = Xoshiro256::new(0x0B5E55ED);
    for round in 0..200 {
        let (insts, seeds) = random_block(&mut rng, &layout);
        let prog = Program::new("rand", insts);
        let run = |lookahead: bool| {
            let mut cpu = AtomicCpu::new(1, 4);
            cpu.lookahead_mut().set_enabled(lookahead);
            for (r, &v) in seeds.iter().enumerate() {
                cpu.state_mut().set_r(r as u8, v);
            }
            let mut mem = MemSystem::new(4);
            let mut shared = SharedLevel::new(2, HierLatency::default());
            cpu.run(&prog, &mut mem, &mut shared, u64::MAX);
            let regs: Vec<u64> = (0..32).map(|r| cpu.state().r(r)).collect();
            (regs, cpu.state().cc_loc, cpu.stats().cycles)
        };
        let (br, bcc, bcy) = run(true);
        let (sr, scc, scy) = run(false);
        assert_eq!(br, sr, "round {round}: registers diverged");
        assert_eq!(bcc, scc, "round {round}: condition code diverged");
        assert_eq!(bcy, scy, "round {round}: cycles diverged");
    }
}

#[test]
fn timing_model_random_blocks_are_cycle_exact() {
    let layout = ArrayLayout::new(4, 8, 4);
    let mut rng = Xoshiro256::new(0x71A1A6);
    for round in 0..100 {
        let (insts, seeds) = random_block(&mut rng, &layout);
        let prog = Program::new("rand", insts);
        let run = |lookahead: bool| {
            let mut cpu = TimingCpu::new(0, 4);
            cpu.lookahead_mut().set_enabled(lookahead);
            for (r, &v) in seeds.iter().enumerate() {
                cpu.state_mut().set_r(r as u8, v);
            }
            let mut mem = MemSystem::new(4);
            let mut shared = SharedLevel::new(1, HierLatency::default());
            cpu.run(&prog, &mut mem, &mut shared, u64::MAX);
            cpu.stats().cycles
        };
        assert_eq!(run(true), run(false), "round {round}: cycles diverged");
    }
}
