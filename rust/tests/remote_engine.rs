//! The remote (worker-process) AddressEngine tier, end to end: real
//! `pgas-hw serve-engine` subprocesses behind Unix-domain sockets.
//!
//! * Conformance: `RemoteEngine` output is bit-identical to the
//!   in-process `AutoEngine` over every shared-array layout of all
//!   five NPB kernels (including CG's non-pow2 112-byte and
//!   56016-byte elements) at 1, 2 and 4 worker processes.
//! * Failure semantics: killing a worker makes the in-flight request
//!   fail with a loud `EngineError::Backend` (never truncated output)
//!   and only the dead connection is healed (`reconnects()`, not a
//!   whole-pool restart), serving the next request correctly.
//! * Epoch sessions ride along implicitly: every conformance request
//!   installs its ctx once per (connection, layout) and steady-state
//!   frames carry only the epoch — the daemon suite (`tests/daemon.rs`)
//!   and the in-lib protocol tests pin that explicitly.
//! * Stride guards: out-of-range walk strides are refused across the
//!   process boundary exactly like in-process.
//! * Reporting: `engine_report_with` a forced tier renders the
//!   `remote` column with nonzero setup hits, and a simulated run with
//!   the tier installed tallies `remote` lookahead runs in
//!   `engine_mix_table`.
//!
//! Sockets only — no network — so the suite stays tier-1-safe.  The
//! worker binary is the real CLI, resolved via `CARGO_BIN_EXE_pgas-hw`
//! (cargo builds it before running integration tests).

use std::sync::Arc;

use pgas_hw::compiler::SourceVariant;
use pgas_hw::coordinator::{engine_mix_table, engine_report_with};
use pgas_hw::cpu::CpuModel;
use pgas_hw::engine::{
    AddressEngine, AutoEngine, BatchOut, EngineCtx, EngineError, PtrBatch,
    RemoteEngine, RemoteTier, ShardedEngine, SoftwareEngine,
};
use pgas_hw::npb::{self, Kernel, PaperVariant, Scale};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;

/// Spawn a pool running the real CLI binary.
fn spawn(workers: usize) -> RemoteEngine {
    RemoteEngine::spawn_with_bin(env!("CARGO_BIN_EXE_pgas-hw"), workers)
        .expect("spawn remote worker pool")
}

fn sample_batch(layout: &ArrayLayout, base_va: u64, nelems: u64) -> PtrBatch {
    let mut rng = Xoshiro256::new(0xCAFE ^ nelems);
    let n = 257;
    let mut batch = PtrBatch::with_capacity(n);
    for _ in 0..n {
        batch.push(
            SharedPtr::for_index(layout, base_va, rng.below(nelems.max(1))),
            rng.below(1 << 10),
        );
    }
    batch
}

#[test]
fn remote_matches_auto_over_all_npb_layouts_at_1_2_4_workers() {
    let threads = 4;
    let mut saw_nonpow2 = false;
    for workers in [1usize, 2, 4] {
        // min_shard_len 1 forces real multi-process fan-out + splice
        // even on modest batches.
        let remote = spawn(workers).with_min_shard_len(1);
        for kernel in Kernel::ALL {
            let built =
                npb::build(kernel, threads, SourceVariant::Unoptimized, &Scale::quick());
            let table = BaseTable::regular(threads, 1 << 32, 1 << 32);
            for a in built.rt.arrays() {
                saw_nonpow2 |= !a.layout.hw_supported();
                let ctx = EngineCtx::new(a.layout, &table, 1).unwrap();
                let batch = sample_batch(&a.layout, a.base_va, a.nelems);
                let (mut got, mut want) = (BatchOut::new(), BatchOut::new());
                remote.translate(&ctx, &batch, &mut got).unwrap();
                AutoEngine.translate(&ctx, &batch, &mut want).unwrap();
                assert_eq!(
                    got, want,
                    "{kernel} {} translate, {workers} workers",
                    a.name
                );
                let (mut gp, mut wp) = (Vec::new(), Vec::new());
                remote.increment(&ctx, &batch, &mut gp).unwrap();
                AutoEngine.increment(&ctx, &batch, &mut wp).unwrap();
                assert_eq!(
                    gp, wp,
                    "{kernel} {} increment, {workers} workers",
                    a.name
                );
                let start = SharedPtr::for_index(&a.layout, a.base_va, 0);
                remote.walk(&ctx, start, 3, 401, &mut got).unwrap();
                AutoEngine.walk(&ctx, start, 3, 401, &mut want).unwrap();
                assert_eq!(
                    got, want,
                    "{kernel} {} walk, {workers} workers",
                    a.name
                );
            }
        }
    }
    assert!(
        saw_nonpow2,
        "the NPB set must include a non-pow2 layout (CG's 112-byte rows)"
    );
}

#[test]
fn worker_death_fails_loud_and_the_pool_recovers() {
    let remote = spawn(2).with_min_shard_len(1);
    let layout = ArrayLayout::new(3, 112, 5);
    let table = BaseTable::regular(5, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 2).unwrap();
    let mut batch = PtrBatch::new();
    for i in 0..333u64 {
        batch.push(SharedPtr::for_index(&layout, 0, i * 3), i % 41);
    }
    let mut want = BatchOut::new();
    SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();

    // warm request: the pool works
    let mut out = BatchOut::new();
    remote.translate(&ctx, &batch, &mut out).unwrap();
    assert_eq!(out, want);

    // kill worker 1 behind the client's back; the next request must
    // fail loudly — and `out` must not be left holding a truncated
    // splice from the surviving shard.
    remote.kill_worker(1).unwrap();
    out.clear();
    let err = remote.translate(&ctx, &batch, &mut out).unwrap_err();
    assert!(
        matches!(&err, EngineError::Backend(m) if m.contains("NOT served")),
        "want a loud in-flight failure, got {err:?}"
    );
    assert!(out.is_empty(), "a failed request must never emit output");

    // recovery is per-connection: only the dead worker was respawned
    // (the survivor kept its stream AND its installed session), and the
    // whole-pool restart path was never taken
    assert!(remote.reconnects() >= 1, "the heal must be recorded");
    assert_eq!(remote.restarts(), 0, "no whole-pool restart for one death");
    remote.translate(&ctx, &batch, &mut out).unwrap();
    assert_eq!(out, want);
    assert_eq!(remote.workers(), 2, "the pool is back at full strength");
}

#[test]
fn extreme_stride_walks_error_identically_across_tiers() {
    // elemsize 8 at a near-u64::MAX stride: the per-step byte
    // displacement exceeds i64, so every tier must refuse — the
    // scalar cursor, the thread pool (whose checked_mul guard
    // degrades to an inline walk that then refuses), and the process
    // pool (whose worker refuses over the wire).
    let layout = ArrayLayout::new(1, 8, 4);
    let table = BaseTable::regular(4, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 0).unwrap();
    let inc = u64::MAX - 5;
    let mut out = BatchOut::new();
    let scalar = SoftwareEngine
        .walk(&ctx, SharedPtr::NULL, inc, 64, &mut out)
        .unwrap_err();
    assert!(matches!(scalar, EngineError::Backend(_)), "{scalar:?}");
    let sharded = ShardedEngine::new(SoftwareEngine, 2).with_min_shard_len(1);
    assert!(sharded.walk(&ctx, SharedPtr::NULL, inc, 64, &mut out).is_err());
    let remote = spawn(2).with_min_shard_len(1);
    let err = remote
        .walk(&ctx, SharedPtr::NULL, inc, 64, &mut out)
        .unwrap_err();
    assert!(
        matches!(&err, EngineError::Backend(m) if m.contains("out of range")),
        "worker-side stride refusal must cross the wire: {err:?}"
    );
    // an in-range stride of the same magnitude agrees across tiers
    let thin = ArrayLayout::new(1, 1, 4);
    let ctx = EngineCtx::new(thin, &table, 0).unwrap();
    let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
    SoftwareEngine.walk(&ctx, SharedPtr::NULL, 1 << 59, 8, &mut a).unwrap();
    remote.walk(&ctx, SharedPtr::NULL, 1 << 59, 8, &mut b).unwrap();
    assert_eq!(a, b);
}

#[test]
fn forced_tier_shows_up_in_engine_report_and_mix_table() {
    // A forced tier prices the pool as a dedicated service (the
    // paper's thesis: mapping behind a cheap dedicated unit), so both
    // reporting surfaces can demonstrate the tier on one host.
    let engine = Arc::new(spawn(2).with_min_shard_len(1));
    let tier = RemoteTier::from_engine(engine, true).unwrap();

    // engine_report: the remote column renders and the setup traffic
    // actually lands on the remote backend (nonzero hit row).
    let t = engine_report_with(&[Kernel::Is], 4, &Scale::quick(), Some(&tier));
    let rendered = t.render();
    assert!(
        rendered.lines().any(|l| l.contains("remote")),
        "remote column missing:\n{rendered}"
    );
    // hit rows are only emitted for counters > 0, so a setup row
    // naming `remote` is by construction a nonzero hit
    let served_remote = rendered
        .lines()
        .any(|l| l.contains("(setup served by)") && l.contains("remote"));
    assert!(
        served_remote,
        "setup hits must include a nonzero remote row:\n{rendered}"
    );

    // engine_mix_table: a real simulated sweep point with the tier
    // installed tallies remote-served lookahead windows.  (Tiny scale:
    // with forced pricing every eligible window takes a socket hop, so
    // keep the instruction count small.)
    let out = npb::run_opts(
        Kernel::Is,
        PaperVariant::Hw,
        CpuModel::Atomic,
        2,
        &Scale { factor: 1024 },
        true,
        Some(&tier),
    );
    let mix = out.engine_mix();
    assert!(
        mix.runs_label().contains("remote:"),
        "remote runs missing from the mix: {}",
        mix.runs_label()
    );
    let table = engine_mix_table(&[out]);
    let rendered = table.render();
    assert!(
        rendered.contains("remote:"),
        "engine_mix_table must render the remote backend:\n{rendered}"
    );
}

#[test]
fn selector_with_remote_measures_and_keeps_calibration() {
    // with_remote spawns + calibrates; a later cost-model write must
    // not discard the measured legs (the select.rs ordering bugfix,
    // exercised here with the real pool).
    // No env override here: set_var would race sibling tests' in-flight
    // Command::spawn (setenv/getenv is UB on glibc under threads).
    // resolve_worker_bin finds the CLI as `target/<profile>/pgas-hw`,
    // two levels up from this test binary in `deps/` — cargo built it
    // because integration tests force bin targets.
    let sel = pgas_hw::engine::EngineSelector::new()
        .with_remote_threshold(1234)
        .with_remote(2)
        .expect("spawn + calibrate remote pool")
        .with_cost_model(pgas_hw::engine::CostModel {
            remote_ns_per_ptr: 123456.0,
            remote_dispatch_ns: 654321.0,
            ..pgas_hw::engine::CostModel::default()
        });
    assert!(sel.has_remote());
    // builder order footguns: neither the threshold configured before
    // with_remote nor the measured legs may be silently reset
    assert_eq!(sel.remote_threshold(), 1234, "threshold discarded");
    let cm = sel.cost_model();
    assert_ne!(cm.remote_ns_per_ptr, 123456.0, "measurement discarded");
    assert_ne!(cm.remote_dispatch_ns, 654321.0, "measurement discarded");
    assert!(cm.remote_dispatch_ns > 0.0);
}
