//! `Leon3Engine` integration suite: the FPGA-coprocessor backend
//! against the software reference over the *real* NPB array layouts
//! (not just randomized geometry), plus the Figure-15/16 cycle-count
//! regression pins for the Leon3 microbenchmarks the backend's cost
//! model is anchored to.
//!
//! Contract under test:
//!
//! * on every layout the coprocessor supports, `translate` /
//!   `increment` / `walk` are bit-identical to [`SoftwareEngine`];
//! * every layout [`Pow2Engine`] refuses (CG's 112-byte element rows,
//!   the 56016-byte `w_tmp` struct) is refused by `Leon3Engine` with
//!   the same error shape — and the selector falls back to software
//!   for it, exactly as the compiler's `Hw` lowering does;
//! * the engine's cycle accounting and the Figure-15/16 microbench
//!   cycle counts are deterministic and stay inside pinned envelopes.

use pgas_hw::engine::{
    AddressEngine, BatchOut, CostModel, EngineChoice, EngineCtx,
    EngineSelector, Leon3Engine, Pow2Engine, PtrBatch, SoftwareEngine,
};
use pgas_hw::leon3::microbench::{
    run_matmul, run_vecadd, MatmulVariant, VecAddVariant,
};
use pgas_hw::npb::{self, Kernel, Scale};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};

/// A deterministic sample batch over the first elements of an array.
fn sample_batch(layout: &ArrayLayout, base_va: u64, nelems: u64) -> PtrBatch {
    let mut batch = PtrBatch::new();
    let n = nelems.min(257);
    for i in 0..n {
        // stay inside the array: increments never push past the end
        let inc = (nelems - 1 - i).min(i * 7 % 13);
        batch.push(SharedPtr::for_index(layout, base_va, i), inc);
    }
    batch
}

#[test]
fn leon3_matches_software_over_all_npb_layouts() {
    let leon3 = Leon3Engine::new();
    let threads = 4;
    for kernel in Kernel::ALL {
        let built = npb::build(
            kernel,
            threads,
            pgas_hw::compiler::SourceVariant::Unoptimized,
            &Scale::quick(),
        );
        let table = BaseTable::regular(threads, 1 << 32, 1 << 32);
        for a in built.rt.arrays() {
            let ctx = EngineCtx::new(a.layout, &table, 1).unwrap();
            let batch = sample_batch(&a.layout, a.base_va, a.nelems);
            let mut got = BatchOut::new();
            if leon3.supports(&a.layout) {
                let mut want = BatchOut::new();
                leon3.translate(&ctx, &batch, &mut got).unwrap();
                SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();
                assert_eq!(got, want, "{kernel} {}: translate", a.name);
                let steps = a.nelems.min(200) as usize;
                leon3
                    .walk(&ctx, batch.ptrs[0], 1, steps, &mut got)
                    .unwrap();
                SoftwareEngine
                    .walk(&ctx, batch.ptrs[0], 1, steps, &mut want)
                    .unwrap();
                assert_eq!(got, want, "{kernel} {}: walk", a.name);
            } else {
                // the hardware gate refuses; it must never answer wrongly
                assert!(
                    leon3.translate(&ctx, &batch, &mut got).is_err(),
                    "{kernel} {}: unsupported layout must refuse",
                    a.name
                );
            }
        }
    }
}

#[test]
fn leon3_refuses_cg_nonpow2_and_selector_falls_back_to_software() {
    let leon3 = Leon3Engine::new();
    // CG's 112-byte element rows and the 56016-byte w_tmp struct: the
    // layouts the paper's compiler sends down the software fallback
    for layout in [ArrayLayout::new(3, 112, 5), ArrayLayout::new(1, 56016, 8)]
    {
        assert!(!Pow2Engine.supports(&layout));
        assert!(!leon3.supports(&layout));
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::for_index(&layout, 0, 2), 3);
        let mut out = BatchOut::new();
        let e_hw = Pow2Engine.translate(&ctx, &batch, &mut out).unwrap_err();
        let e_l3 = leon3.translate(&ctx, &batch, &mut out).unwrap_err();
        // identical refusal shape (only the engine name differs)
        assert!(format!("{e_hw}").contains("pow2"));
        assert!(format!("{e_l3}").contains("leon3"));
        assert!(format!("{e_l3}").contains("does not support layout"));
        // a selector with the coprocessor installed — even priced at
        // zero — still software-falls-back on the unsupported layout
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_leon3_uncalibrated(Leon3Engine::new())
            .with_cost_model(CostModel {
                leon3_ns_per_ptr: 0.0,
                leon3_dispatch_ns: 0.0,
                ..CostModel::default()
            });
        assert_eq!(sel.choice(&layout, 1 << 12), EngineChoice::Software);
        let mut via = BatchOut::new();
        sel.translate(&ctx, &batch, &mut via).unwrap();
        let mut direct = BatchOut::new();
        SoftwareEngine.translate(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via, direct);
    }
}

#[test]
fn leon3_engine_cycle_accounting_is_pinned() {
    // 2048 minimal requests (zero pointer, small inc): each costs
    // ldi(1) + ldi(1) + pgas_incr(2) + address-generation(1) = 5.
    let layout = ArrayLayout::new(4, 4, 4);
    let table = BaseTable::regular(4, 1 << 32, 1 << 32);
    let ctx = EngineCtx::new(layout, &table, 0).unwrap();
    let leon3 = Leon3Engine::new();
    let mut batch = PtrBatch::new();
    for _ in 0..2048 {
        batch.push(SharedPtr::NULL, 1);
    }
    let mut out = BatchOut::new();
    leon3.translate(&ctx, &batch, &mut out).unwrap();
    assert_eq!(leon3.last_cycles(), 2048 * 5);
    // at 75 MHz that is 2048 * 5 / 75 µs
    let ns = leon3.last_runtime_ns();
    assert!((ns - 2048.0 * 5.0 * 1e3 / 75.0).abs() < 1.0, "{ns}");
}

/// Figure 15 (vector addition) cycle regression: deterministic counts,
/// linear scaling in n, per-element cost envelope on the hw variant,
/// and the paper's headline speedup band (~16x hw over dynamic).
#[test]
fn fig15_vecadd_cycle_pins() {
    let hw_a = run_vecadd(2, VecAddVariant::Hw, 2048).cycles;
    let hw_b = run_vecadd(2, VecAddVariant::Hw, 2048).cycles;
    assert_eq!(hw_a, hw_b, "the simulator must be deterministic");
    let hw_2x = run_vecadd(2, VecAddVariant::Hw, 4096).cycles;
    let ratio = hw_2x as f64 / hw_a as f64;
    assert!((1.7..2.3).contains(&ratio), "linear in n: {ratio:.2}");
    // per-element envelope at 1 thread: the hw inner loop is ~9
    // instructions + cache/bus time, far from the software expansion
    let n = 2048u64;
    let hw1 = run_vecadd(1, VecAddVariant::Hw, n).cycles as f64 / n as f64;
    assert!((6.0..80.0).contains(&hw1), "hw cycles/elem = {hw1:.1}");
    let dyn1 =
        run_vecadd(1, VecAddVariant::Dynamic, n).cycles as f64 / n as f64;
    let speedup = dyn1 / hw1;
    assert!(
        (6.0..40.0).contains(&speedup),
        "Fig 15 hw-over-dynamic band: {speedup:.1}x"
    );
}

/// Figure 16 (matmul) cycle regression: deterministic counts, the
/// paper's variant ordering, and a per-MAC envelope on the hw variant.
#[test]
fn fig16_matmul_cycle_pins() {
    let n = 16u64;
    let hw_a = run_matmul(2, MatmulVariant::Hw, n).cycles;
    let hw_b = run_matmul(2, MatmulVariant::Hw, n).cycles;
    assert_eq!(hw_a, hw_b, "the simulator must be deterministic");
    // n^3 multiply-accumulates over 2 cores
    let per_mac = hw_a as f64 / (n * n * n) as f64 * 2.0;
    assert!((4.0..120.0).contains(&per_mac), "hw cycles/MAC = {per_mac:.1}");
    let st = run_matmul(2, MatmulVariant::Static, n).cycles;
    assert!(
        st > hw_a,
        "static ({st}) must pay more than the coprocessor ({hw_a})"
    );
}
