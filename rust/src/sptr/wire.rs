//! Little-endian binary serialization of the shared-pointer types —
//! the wire vocabulary of the remote AddressEngine protocol
//! (`engine::remote`): everything an [`EngineCtx`](crate::engine::EngineCtx)
//! snapshot carries (layout, base table, executing thread, topology)
//! plus pointers and locality codes.
//!
//! The encoding is deliberately boring: fixed-width little-endian
//! scalars, `u32` element counts, no padding, no self-description.
//! Versioning lives one layer up in the frame header
//! (`engine::remote::PROTOCOL_VERSION`); these helpers only promise
//! that `get_*` is the exact inverse of `put_*` within one version.
//!
//! Reads are *checked*: a truncated or oversized buffer yields a
//! [`WireError`], never a panic or a silently short value — the remote
//! client maps these to loud `EngineError::Backend` failures.

use super::{ArrayLayout, BaseTable, Locality, SharedPtr, Topology};

/// Everything an [`EngineCtx`](crate::engine::EngineCtx) carries, as an
/// owned value — the payload of the remote protocol's `InstallCtx`
/// message (`engine::remote`).  A client ships one snapshot per
/// *session epoch*; steady-state requests then reference it by epoch
/// number instead of re-serializing layout + base table + topology on
/// every frame.
///
/// Wire shape (via [`WireWriter::put_ctx_snapshot`]): `layout` (20 B),
/// `mythread u32`, `topology` (8 B), `table` (4 + 8·numthreads B) — the
/// exact field order protocol v1 used inline in every request, so the
/// encoding is the same bytes, just sent once.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CtxSnapshot {
    pub layout: ArrayLayout,
    pub mythread: u32,
    pub topo: Topology,
    pub table: BaseTable,
}

impl CtxSnapshot {
    /// [`ctx_fingerprint`] over this snapshot's fields.
    pub fn fingerprint(&self) -> u64 {
        ctx_fingerprint(&self.layout, self.mythread, &self.topo, &self.table)
    }
}

/// FNV-1a over every field a [`CtxSnapshot`] serializes — the remote
/// client's cheap "did the ctx change since the installed epoch?" test
/// (callable on a borrowed `EngineCtx`'s parts without building a
/// snapshot).  Collisions would silently serve a stale ctx, so the full
/// 64-bit digest is compared (never truncated).
pub fn ctx_fingerprint(
    layout: &ArrayLayout,
    mythread: u32,
    topo: &Topology,
    table: &BaseTable,
) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    mix(layout.blocksize);
    mix(layout.elemsize);
    mix(layout.numthreads as u64);
    mix(mythread as u64);
    mix(topo.log2_threads_per_mc as u64);
    mix(topo.log2_threads_per_node as u64);
    for &b in table.bases() {
        mix(b);
    }
    h
}

/// Why a wire buffer failed to decode.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the value did.
    Truncated { need: usize, have: usize },
    /// [`WireReader::finish`] found bytes past the last value.
    Trailing(usize),
    /// A decoded value is outside its type's domain (a locality code
    /// above 3, an element count larger than the frame, ...).
    Invalid(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "wire buffer truncated: need {need} bytes, have {have}")
            }
            WireError::Trailing(n) => {
                write!(f, "wire buffer has {n} trailing bytes")
            }
            WireError::Invalid(what) => write!(f, "invalid wire value: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// The encoded bytes so far.
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Consume the writer, yielding the encoded buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bytes, appended verbatim (length framing is the caller's
    /// job — pair with a `put_u32` count and [`WireReader::get_bytes`]).
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// `thread u32, phase u64, va u64` — 20 bytes.
    pub fn put_ptr(&mut self, p: &SharedPtr) {
        self.put_u32(p.thread);
        self.put_u64(p.phase);
        self.put_u64(p.va);
    }

    /// `blocksize u64, elemsize u64, numthreads u32` — 20 bytes.
    pub fn put_layout(&mut self, l: &ArrayLayout) {
        self.put_u64(l.blocksize);
        self.put_u64(l.elemsize);
        self.put_u32(l.numthreads);
    }

    /// `log2_threads_per_mc u32, log2_threads_per_node u32`.
    pub fn put_topology(&mut self, t: &Topology) {
        self.put_u32(t.log2_threads_per_mc);
        self.put_u32(t.log2_threads_per_node);
    }

    /// `numthreads u32` then that many `u64` bases.
    pub fn put_table(&mut self, t: &BaseTable) {
        let bases = t.bases();
        self.put_u32(bases.len() as u32);
        for &b in bases {
            self.put_u64(b);
        }
    }

    /// The condition code as one byte.
    pub fn put_locality(&mut self, l: Locality) {
        self.put_u8(l as u8);
    }

    /// A full [`CtxSnapshot`]: layout, executing thread, topology, base
    /// table — the `InstallCtx` payload.
    pub fn put_ctx_snapshot(&mut self, c: &CtxSnapshot) {
        self.put_layout(&c.layout);
        self.put_u32(c.mythread);
        self.put_topology(&c.topo);
        self.put_table(&c.table);
    }
}

/// Checked little-endian decoder over a borrowed buffer.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// `n` raw bytes (checked slice, no copy).
    pub fn get_bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        self.take(n)
    }

    /// A `u32` element count, validated against the bytes actually
    /// left in the buffer (`elem_min_bytes` per element) **before**
    /// any allocation sized by it — a corrupt or hostile count must
    /// yield [`WireError::Truncated`], never a huge `reserve` that
    /// aborts the process.
    pub fn get_count(&mut self, elem_min_bytes: usize) -> Result<usize, WireError> {
        let n = self.get_u32()? as usize;
        let need = n.saturating_mul(elem_min_bytes.max(1));
        if self.remaining() < need {
            return Err(WireError::Truncated { need, have: self.remaining() });
        }
        Ok(n)
    }

    pub fn get_ptr(&mut self) -> Result<SharedPtr, WireError> {
        Ok(SharedPtr {
            thread: self.get_u32()?,
            phase: self.get_u64()?,
            va: self.get_u64()?,
        })
    }

    pub fn get_layout(&mut self) -> Result<ArrayLayout, WireError> {
        let blocksize = self.get_u64()?;
        let elemsize = self.get_u64()?;
        let numthreads = self.get_u32()?;
        if blocksize == 0 || elemsize == 0 || numthreads == 0 {
            return Err(WireError::Invalid("zero layout dimension"));
        }
        Ok(ArrayLayout { blocksize, elemsize, numthreads })
    }

    pub fn get_topology(&mut self) -> Result<Topology, WireError> {
        Ok(Topology {
            log2_threads_per_mc: self.get_u32()?,
            log2_threads_per_node: self.get_u32()?,
        })
    }

    pub fn get_table(&mut self) -> Result<BaseTable, WireError> {
        // count checked against the buffer before the allocation
        let n = self.get_count(8)?;
        if n == 0 {
            return Err(WireError::Invalid("empty base table"));
        }
        let mut bases = Vec::with_capacity(n);
        for _ in 0..n {
            bases.push(self.get_u64()?);
        }
        Ok(BaseTable::new(bases))
    }

    pub fn get_locality(&mut self) -> Result<Locality, WireError> {
        Locality::from_code(self.get_u8()?)
            .ok_or(WireError::Invalid("locality code above 3"))
    }

    /// Exact inverse of [`WireWriter::put_ctx_snapshot`].
    pub fn get_ctx_snapshot(&mut self) -> Result<CtxSnapshot, WireError> {
        let layout = self.get_layout()?;
        let mythread = self.get_u32()?;
        let topo = self.get_topology()?;
        let table = self.get_table()?;
        Ok(CtxSnapshot { layout, mythread, topo, table })
    }

    /// Assert the whole buffer was consumed (frame hygiene: trailing
    /// bytes mean the two sides disagree about the message shape).
    pub fn finish(self) -> Result<(), WireError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(WireError::Trailing(self.remaining()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_types_round_trip() {
        let layout = ArrayLayout::new(3, 56016, 5);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let topo = Topology { log2_threads_per_mc: 2, log2_threads_per_node: 4 };
        let ptr = SharedPtr { thread: 4, phase: 2, va: 0xDEAD_BEEF };
        let mut w = WireWriter::new();
        w.put_u8(0xAB);
        w.put_u16(0xCDEF);
        w.put_u32(0x0102_0304);
        w.put_u64(u64::MAX - 7);
        w.put_layout(&layout);
        w.put_table(&table);
        w.put_topology(&topo);
        w.put_ptr(&ptr);
        w.put_locality(Locality::SameNode);
        let buf = w.into_bytes();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 0xAB);
        assert_eq!(r.get_u16().unwrap(), 0xCDEF);
        assert_eq!(r.get_u32().unwrap(), 0x0102_0304);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 7);
        assert_eq!(r.get_layout().unwrap(), layout);
        assert_eq!(r.get_table().unwrap(), table);
        let t2 = r.get_topology().unwrap();
        assert_eq!(t2.log2_threads_per_mc, 2);
        assert_eq!(t2.log2_threads_per_node, 4);
        assert_eq!(r.get_ptr().unwrap(), ptr);
        assert_eq!(r.get_locality().unwrap(), Locality::SameNode);
        r.finish().unwrap();
    }

    #[test]
    fn short_reads_error_instead_of_panicking() {
        let mut w = WireWriter::new();
        w.put_u32(7);
        let buf = w.into_bytes();
        let mut r = WireReader::new(&buf);
        assert_eq!(
            r.get_u64(),
            Err(WireError::Truncated { need: 8, have: 4 })
        );
        // a corrupt table count larger than the buffer is refused
        let mut w = WireWriter::new();
        w.put_u32(1 << 30); // claims 2^30 bases
        w.put_u64(1);
        let buf = w.into_bytes();
        let mut r = WireReader::new(&buf);
        assert!(matches!(
            r.get_table(),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn counts_are_validated_before_allocation() {
        let mut w = WireWriter::new();
        w.put_u32(u32::MAX); // hostile count, no payload behind it
        let buf = w.into_bytes();
        assert!(matches!(
            WireReader::new(&buf).get_count(20),
            Err(WireError::Truncated { .. })
        ));
        // a legitimate count passes and the payload reads back
        let mut w = WireWriter::new();
        w.put_u32(3);
        w.put_bytes(b"abc");
        let buf = w.into_bytes();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_count(1).unwrap(), 3);
        assert_eq!(r.get_bytes(3).unwrap(), b"abc");
        r.finish().unwrap();
    }

    #[test]
    fn trailing_bytes_are_flagged() {
        let mut w = WireWriter::new();
        w.put_u8(1);
        w.put_u8(2);
        let buf = w.into_bytes();
        let mut r = WireReader::new(&buf);
        r.get_u8().unwrap();
        assert_eq!(r.finish(), Err(WireError::Trailing(1)));
    }

    #[test]
    fn ctx_snapshot_round_trips_and_fingerprints_every_field() {
        let snap = CtxSnapshot {
            layout: ArrayLayout::new(3, 112, 5),
            mythread: 2,
            topo: Topology { log2_threads_per_mc: 1, log2_threads_per_node: 3 },
            table: BaseTable::regular(5, 1 << 32, 1 << 32),
        };
        let mut w = WireWriter::new();
        w.put_ctx_snapshot(&snap);
        let buf = w.into_bytes();
        // same bytes as the protocol-v1 inline order: layout 20 +
        // mythread 4 + topo 8 + table 4+8n
        assert_eq!(buf.len(), 20 + 4 + 8 + 4 + 8 * 5);
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_ctx_snapshot().unwrap(), snap);
        r.finish().unwrap();

        // the fingerprint must react to every field a request's result
        // can depend on — a collision here would serve a stale ctx
        let fp = snap.fingerprint();
        let mut other = snap.clone();
        other.mythread = 3;
        assert_ne!(fp, other.fingerprint(), "mythread not fingerprinted");
        let mut other = snap.clone();
        other.layout.blocksize = 4;
        assert_ne!(fp, other.fingerprint(), "layout not fingerprinted");
        let mut other = snap.clone();
        other.topo.log2_threads_per_node = 4;
        assert_ne!(fp, other.fingerprint(), "topology not fingerprinted");
        let mut other = snap.clone();
        other.table = BaseTable::regular(5, 1 << 33, 1 << 32);
        assert_ne!(fp, other.fingerprint(), "table bases not fingerprinted");
        assert_eq!(fp, snap.clone().fingerprint(), "must be deterministic");
    }

    #[test]
    fn bad_locality_and_zero_layouts_are_invalid() {
        let buf = [9u8];
        assert!(matches!(
            WireReader::new(&buf).get_locality(),
            Err(WireError::Invalid(_))
        ));
        let mut w = WireWriter::new();
        w.put_u64(0); // blocksize 0
        w.put_u64(8);
        w.put_u32(4);
        let buf = w.into_bytes();
        assert!(matches!(
            WireReader::new(&buf).get_layout(),
            Err(WireError::Invalid(_))
        ));
    }
}
