//! O(1) stepper for constant-stride shared-pointer walks.
//!
//! [`increment_general`](super::increment_general) pays two divisions
//! and two modulos per step.  For a *walk* — the same `inc` applied
//! repeatedly — all of that division structure depends only on the
//! stride and the layout, never on the current pointer: per step the
//! phase either carries into the next block or it does not, and the
//! thread either wraps past `THREADS` or it does not.  [`WalkCursor`]
//! does the div/mod factorization once at construction and advances
//! with adds, compares and subtracts only — the host-side mirror of the
//! paper's claim that hardware support makes shared-address
//! incrementation effectively free on the hot path.
//!
//! Derivation.  Write `inc = inc_blocks·blocksize + dphase`.  Algorithm
//! 1 then reduces, per step, to two carry bits:
//!
//! * `p` — phase carry: `phase + dphase >= blocksize`;
//! * `w` — thread wrap: `thread + (inc_blocks + p) % THREADS >= THREADS`.
//!
//! The new thread is `thread + dthread[p] (mod THREADS)` and the va
//! moves by a constant `dva[p][w]` precomputed for the four `(p, w)`
//! combinations (it can be negative: stepping onto the next thread's
//! block start rewinds the local offset).  Both engines' `walk` paths
//! use this cursor; `rust/tests/engine_conformance.rs` checks it
//! differentially against `increment_general` over random strides.

use super::{ArrayLayout, SharedPtr};

/// Constant-stride walk state: the current pointer plus the
/// precomputed per-step deltas for the four (phase-carry, thread-wrap)
/// cases.
#[derive(Clone, Debug)]
pub struct WalkCursor {
    cur: SharedPtr,
    blocksize: u64,
    numthreads: u32,
    /// `inc % blocksize` — the per-step phase advance.
    dphase: u64,
    /// `(inc / blocksize + p) % numthreads` for phase carry `p`.
    dthread: [u32; 2],
    /// va delta for (phase carry `p`, thread wrap `w`).
    dva: [[i64; 2]; 2],
}

impl WalkCursor {
    /// Factor the stride through `layout` once; `start` is step 0.
    ///
    /// `start` must be well-formed for `layout` (`phase < blocksize`,
    /// `thread < numthreads`, as every pointer built by
    /// [`SharedPtr::for_index`] or Algorithm 1 is) — the single
    /// add-and-carry per step relies on it.
    pub fn new(start: SharedPtr, inc: u64, layout: &ArrayLayout) -> Self {
        debug_assert!(
            start.phase < layout.blocksize
                && start.thread < layout.numthreads,
            "malformed start pointer {start:?} for {layout:?}"
        );
        let bs = layout.blocksize;
        let nt = layout.numthreads as u64;
        let dphase = inc % bs;
        let inc_blocks = inc / bs;
        let mut dthread = [0u32; 2];
        let mut dva = [[0i64; 2]; 2];
        for p in 0..2u64 {
            let thinc = inc_blocks + p;
            let q = thinc / nt;
            dthread[p as usize] = (thinc % nt) as u32;
            for w in 0..2u64 {
                let blockinc = q + w;
                let eaddrinc = dphase as i64 - (p * bs) as i64
                    + (blockinc * bs) as i64;
                dva[p as usize][w as usize] =
                    eaddrinc * layout.elemsize as i64;
            }
        }
        Self {
            cur: start,
            blocksize: bs,
            numthreads: layout.numthreads,
            dphase,
            dthread,
            dva,
        }
    }

    /// The pointer at the current step.
    #[inline]
    pub fn current(&self) -> SharedPtr {
        self.cur
    }

    /// Advance one stride: adds, compares and subtracts — no div/mod.
    #[inline]
    pub fn advance(&mut self) {
        let mut phase = self.cur.phase + self.dphase;
        let p = usize::from(phase >= self.blocksize);
        if p == 1 {
            phase -= self.blocksize;
        }
        let mut thread = self.cur.thread + self.dthread[p];
        let w = usize::from(thread >= self.numthreads);
        if w == 1 {
            thread -= self.numthreads;
        }
        self.cur = SharedPtr {
            thread,
            phase,
            va: (self.cur.va as i64 + self.dva[p][w]) as u64,
        };
    }

    /// Advance and return the new pointer (convenience for loops that
    /// want post-increment semantics).
    #[inline]
    pub fn step(&mut self) -> SharedPtr {
        self.advance();
        self.cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sptr::increment_general;
    use crate::util::testkit::check_default;

    #[test]
    fn cursor_matches_general_increment_step_by_step() {
        check_default("WalkCursor == increment_general", |rng| {
            let layout = ArrayLayout::new(
                rng.below(64) + 1,
                rng.below(200) + 1,
                rng.below(64) as u32 + 1,
            );
            let start =
                SharedPtr::for_index(&layout, 0, rng.below(1 << 16));
            let inc = rng.below(1 << 13);
            let mut cur = WalkCursor::new(start, inc, &layout);
            let mut want = start;
            for step in 0..48 {
                assert_eq!(
                    cur.current(),
                    want,
                    "layout={layout:?} inc={inc} step={step}"
                );
                cur.advance();
                want = increment_general(&want, inc, &layout);
            }
        });
    }

    #[test]
    fn zero_stride_is_a_fixed_point() {
        let layout = ArrayLayout::new(4, 8, 4);
        let start = SharedPtr::for_index(&layout, 64, 9);
        let mut cur = WalkCursor::new(start, 0, &layout);
        for _ in 0..8 {
            cur.advance();
            assert_eq!(cur.current(), start);
        }
    }

    #[test]
    fn unit_stride_walks_the_figure2_array() {
        // shared [4] int A[..] over 4 threads (paper Fig. 2).
        let layout = ArrayLayout::new(4, 4, 4);
        let mut cur =
            WalkCursor::new(SharedPtr::for_index(&layout, 0, 0), 1, &layout);
        for i in 0..64u64 {
            assert_eq!(cur.current(), SharedPtr::for_index(&layout, 0, i));
            cur.advance();
        }
    }

    #[test]
    fn stride_larger_than_a_full_round() {
        // inc spans several blocks *and* wraps the thread ring per step.
        let layout = ArrayLayout::new(3, 24, 5);
        let inc: u64 = 3 * 5 * 2 + 7; // two full rounds + 7
        let mut cur =
            WalkCursor::new(SharedPtr::for_index(&layout, 0, 2), inc, &layout);
        for i in 0..32u64 {
            assert_eq!(
                cur.current(),
                SharedPtr::for_index(&layout, 0, 2 + i * inc)
            );
            cur.advance();
        }
    }
}
