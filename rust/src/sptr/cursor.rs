//! O(1) stepper for constant-stride shared-pointer walks.
//!
//! [`increment_general`](super::increment_general) pays two divisions
//! and two modulos per step.  For a *walk* — the same `inc` applied
//! repeatedly — all of that division structure depends only on the
//! stride and the layout, never on the current pointer: per step the
//! phase either carries into the next block or it does not, and the
//! thread either wraps past `THREADS` or it does not.  [`WalkCursor`]
//! does the div/mod factorization once at construction and advances
//! with adds, compares and subtracts only — the host-side mirror of the
//! paper's claim that hardware support makes shared-address
//! incrementation effectively free on the hot path.
//!
//! Derivation.  Write `inc = inc_blocks·blocksize + dphase`.  Algorithm
//! 1 then reduces, per step, to two carry bits:
//!
//! * `p` — phase carry: `phase + dphase >= blocksize`;
//! * `w` — thread wrap: `thread + (inc_blocks + p) % THREADS >= THREADS`.
//!
//! The new thread is `thread + dthread[p] (mod THREADS)` and the va
//! moves by a constant `dva[p][w]` precomputed for the four `(p, w)`
//! combinations (it can be negative: stepping onto the next thread's
//! block start rewinds the local offset).  Both engines' `walk` paths
//! use this cursor; `rust/tests/engine_conformance.rs` checks it
//! differentially against `increment_general` over random strides.
//!
//! ## Stride range
//!
//! The four `dva` constants are computed exactly in 128-bit arithmetic
//! at construction.  A stride is *representable* when each per-step
//! byte displacement fits an `i64`; [`WalkCursor::try_new`] returns
//! `None` for anything wider (strides around `u64::MAX` on multi-byte
//! elements), and the engines' `walk` paths surface that as a loud
//! `EngineError` instead of a silently wrapped pointer.  Within range,
//! `advance` updates `va` with wrapping two's-complement adds — exactly
//! the modulo-2⁶⁴ semantics of [`increment_general`] — so the old
//! unchecked `u64 → i64` casts (which could overflow-panic in debug and
//! wrap undetected in release) are gone.

use super::{ArrayLayout, SharedPtr};

/// Constant-stride walk state: the current pointer plus the
/// precomputed per-step deltas for the four (phase-carry, thread-wrap)
/// cases.
#[derive(Clone, Debug)]
pub struct WalkCursor {
    cur: SharedPtr,
    blocksize: u64,
    numthreads: u32,
    /// `inc % blocksize` — the per-step phase advance.
    dphase: u64,
    /// `(inc / blocksize + p) % numthreads` for phase carry `p`.
    dthread: [u32; 2],
    /// va delta for (phase carry `p`, thread wrap `w`).
    dva: [[i64; 2]; 2],
}

impl WalkCursor {
    /// Factor the stride through `layout` once; `start` is step 0.
    ///
    /// Returns `None` when the stride is out of range: some per-step
    /// byte displacement does not fit an `i64` (only reachable with
    /// strides on the order of `u64::MAX`; see the module docs).  The
    /// engines' `walk` paths map that to an `EngineError` rather than
    /// walking wrapped pointers.
    ///
    /// `start` must be well-formed for `layout` (`phase < blocksize`,
    /// `thread < numthreads`, as every pointer built by
    /// [`SharedPtr::for_index`] or Algorithm 1 is) — the single
    /// add-and-carry per step relies on it.
    pub fn try_new(start: SharedPtr, inc: u64, layout: &ArrayLayout) -> Option<Self> {
        debug_assert!(
            start.phase < layout.blocksize
                && start.thread < layout.numthreads,
            "malformed start pointer {start:?} for {layout:?}"
        );
        let bs = layout.blocksize as u128;
        let nt = layout.numthreads as u128;
        let dphase = (inc as u128 % bs) as u64;
        let inc_blocks = inc as u128 / bs;
        let mut dthread = [0u32; 2];
        let mut dva = [[0i64; 2]; 2];
        for p in 0..2u128 {
            // inc_blocks + p ≤ u64::MAX + 1: widened, cannot wrap.
            let thinc = inc_blocks + p;
            let q = thinc / nt;
            dthread[p as usize] = (thinc % nt) as u32;
            for w in 0..2u128 {
                // blockinc·bs ≤ inc/numthreads + 2·blocksize < 2^67:
                // exact in u128, then signed-widened for the phase
                // rewind term.
                let blockinc = q + w;
                let eaddrinc = dphase as i128 - (p * bs) as i128
                    + (blockinc * bs) as i128;
                let bytes = eaddrinc.checked_mul(layout.elemsize as i128)?;
                dva[p as usize][w as usize] = i64::try_from(bytes).ok()?;
            }
        }
        Some(Self {
            cur: start,
            blocksize: layout.blocksize,
            numthreads: layout.numthreads,
            dphase,
            dthread,
            dva,
        })
    }

    /// [`try_new`](Self::try_new) for in-range strides; panics (with
    /// the stride and layout) when the stride is out of range.  Walk
    /// paths that must not panic use `try_new` and report the error.
    pub fn new(start: SharedPtr, inc: u64, layout: &ArrayLayout) -> Self {
        Self::try_new(start, inc, layout).unwrap_or_else(|| {
            panic!(
                "walk stride {inc} out of range for {layout:?}: per-step \
                 byte displacement exceeds i64"
            )
        })
    }

    /// The pointer at the current step.
    #[inline]
    pub fn current(&self) -> SharedPtr {
        self.cur
    }

    /// Advance one stride: adds, compares and subtracts — no div/mod.
    /// `va` moves modulo 2⁶⁴ (two's complement), exactly like
    /// [`increment_general`](super::increment_general).
    #[inline]
    pub fn advance(&mut self) {
        // phase + dphase < 2·blocksize; the overflow flag covers
        // blocksize > 2^63, where the sum can exceed u64 — the wrapped
        // sum minus blocksize is still exact (true sum - bs < bs).
        let (mut phase, of) = self.cur.phase.overflowing_add(self.dphase);
        let p = usize::from(of || phase >= self.blocksize);
        if p == 1 {
            phase = phase.wrapping_sub(self.blocksize);
        }
        // widen: thread + dthread can exceed u32::MAX when numthreads
        // is in the billions.
        let mut thread = self.cur.thread as u64 + self.dthread[p] as u64;
        let w = usize::from(thread >= self.numthreads as u64);
        if w == 1 {
            thread -= self.numthreads as u64;
        }
        self.cur = SharedPtr {
            thread: thread as u32,
            phase,
            va: self.cur.va.wrapping_add(self.dva[p][w] as u64),
        };
    }

    /// Advance and return the new pointer (convenience for loops that
    /// want post-increment semantics).
    #[inline]
    pub fn step(&mut self) -> SharedPtr {
        self.advance();
        self.cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sptr::increment_general;
    use crate::util::testkit::check_default;

    #[test]
    fn cursor_matches_general_increment_step_by_step() {
        check_default("WalkCursor == increment_general", |rng| {
            let layout = ArrayLayout::new(
                rng.below(64) + 1,
                rng.below(200) + 1,
                rng.below(64) as u32 + 1,
            );
            let start =
                SharedPtr::for_index(&layout, 0, rng.below(1 << 16));
            let inc = rng.below(1 << 13);
            let mut cur = WalkCursor::new(start, inc, &layout);
            let mut want = start;
            for step in 0..48 {
                assert_eq!(
                    cur.current(),
                    want,
                    "layout={layout:?} inc={inc} step={step}"
                );
                cur.advance();
                want = increment_general(&want, inc, &layout);
            }
        });
    }

    #[test]
    fn zero_stride_is_a_fixed_point() {
        let layout = ArrayLayout::new(4, 8, 4);
        let start = SharedPtr::for_index(&layout, 64, 9);
        let mut cur = WalkCursor::new(start, 0, &layout);
        for _ in 0..8 {
            cur.advance();
            assert_eq!(cur.current(), start);
        }
    }

    #[test]
    fn unit_stride_walks_the_figure2_array() {
        // shared [4] int A[..] over 4 threads (paper Fig. 2).
        let layout = ArrayLayout::new(4, 4, 4);
        let mut cur =
            WalkCursor::new(SharedPtr::for_index(&layout, 0, 0), 1, &layout);
        for i in 0..64u64 {
            assert_eq!(cur.current(), SharedPtr::for_index(&layout, 0, i));
            cur.advance();
        }
    }

    #[test]
    fn stride_larger_than_a_full_round() {
        // inc spans several blocks *and* wraps the thread ring per step.
        let layout = ArrayLayout::new(3, 24, 5);
        let inc: u64 = 3 * 5 * 2 + 7; // two full rounds + 7
        let mut cur =
            WalkCursor::new(SharedPtr::for_index(&layout, 0, 2), inc, &layout);
        for i in 0..32u64 {
            assert_eq!(
                cur.current(),
                SharedPtr::for_index(&layout, 0, 2 + i * inc)
            );
            cur.advance();
        }
    }

    #[test]
    fn extreme_in_range_strides_match_the_reference() {
        // Near the top of the representable range the old u64→i64
        // casts could wrap during construction; the widened math must
        // agree with increment_general wherever the reference's own
        // arithmetic is exact.
        // Strides chosen so 8 steps stay below 2^63 total displacement
        // (the reference's own i64 arithmetic is exact there).
        for (layout, inc) in [
            (ArrayLayout::new(1, 1, 2), 1u64 << 59),
            (ArrayLayout::new(1, 2, 3), (1u64 << 58) + 12345),
            (ArrayLayout::new(7, 1, 5), (1u64 << 59) + 7),
        ] {
            let start = SharedPtr::for_index(&layout, 0, 3);
            let mut cur = WalkCursor::try_new(start, inc, &layout)
                .expect("stride is representable");
            let mut want = start;
            for step in 0..8 {
                assert_eq!(
                    cur.current(),
                    want,
                    "layout={layout:?} inc={inc} step={step}"
                );
                cur.advance();
                want = increment_general(&want, inc, &layout);
            }
        }
    }

    #[test]
    fn out_of_range_strides_are_refused_not_wrapped() {
        // blocksize 1, elemsize 8: the per-step byte displacement is
        // ≈ inc·8 ≈ 2^67 — unrepresentable in i64.
        let layout = ArrayLayout::new(1, 8, 4);
        let start = SharedPtr::for_index(&layout, 0, 0);
        assert!(WalkCursor::try_new(start, u64::MAX - 5, &layout).is_none());
        // elemsize 1 keeps the same stride in range (≈ 2^64/4 bytes
        // per step after the thread ring divides it down).
        let thin = ArrayLayout::new(1, 1, 4);
        assert!(WalkCursor::try_new(start, u64::MAX - 5, &thin).is_some());
    }
}
