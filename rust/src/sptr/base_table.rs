//! Per-thread base-address lookup table (paper 4.2).
//!
//! The paper describes two translation options: bases at regular
//! intervals (computable from the thread id) or an arbitrary LUT.  Both
//! prototypes use the LUT "for simplicity"; we support both, and
//! [`BaseTable::regular`] doubles as the interval scheme.

/// The per-thread shared-segment base-address table installed by the
/// `PGAS_SETBASE` instruction at program start.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BaseTable {
    bases: Vec<u64>,
}

impl BaseTable {
    /// Arbitrary bases (the LUT option).
    pub fn new(bases: Vec<u64>) -> Self {
        assert!(!bases.is_empty());
        Self { bases }
    }

    /// Regular-interval bases: `base0 + t * stride` (the scalable option;
    /// also how our simulated machine lays out thread segments).
    pub fn regular(numthreads: u32, base0: u64, stride: u64) -> Self {
        Self {
            bases: (0..numthreads as u64).map(|t| base0 + t * stride).collect(),
        }
    }

    #[inline]
    pub fn base(&self, thread: u32) -> u64 {
        self.bases[thread as usize]
    }

    pub fn numthreads(&self) -> u32 {
        self.bases.len() as u32
    }

    pub fn bases(&self) -> &[u64] {
        &self.bases
    }

    /// Inverse mapping: which thread's segment contains `sysva`?
    /// (Linear scan — used only by debug assertions and tests.)
    pub fn thread_of_sysva(&self, sysva: u64) -> Option<u32> {
        let mut best: Option<(u32, u64)> = None;
        for (t, &b) in self.bases.iter().enumerate() {
            if sysva >= b {
                let off = sysva - b;
                if best.map_or(true, |(_, o)| off < o) {
                    best = Some((t as u32, off));
                }
            }
        }
        best.map(|(t, _)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regular_layout() {
        let t = BaseTable::regular(4, 1 << 32, 1 << 32);
        assert_eq!(t.base(0), 1 << 32);
        assert_eq!(t.base(3), 4 << 32);
        assert_eq!(t.numthreads(), 4);
    }

    #[test]
    fn inverse_lookup() {
        let t = BaseTable::regular(8, 1 << 32, 1 << 32);
        for th in 0..8u32 {
            let mid = t.base(th) + 12345;
            assert_eq!(t.thread_of_sysva(mid), Some(th));
        }
        assert_eq!(t.thread_of_sysva(0), None);
    }
}
