//! Algorithm 1 of the paper: shared-pointer incrementation.
//!
//! Two implementations mirror the two execution paths the paper's
//! prototype compiler chooses between:
//!
//! * [`increment_general`] — divisions/modulo, valid for any layout; this
//!   is what the Berkeley runtime executes in software and what our
//!   SimAlpha `Soft` codegen expands to (~[`SOFT_INC_OP_COUNT`] ops).
//! * [`increment_pow2`] — shift/mask form, only valid when blocksize,
//!   elemsize and numthreads are all powers of two; this is the datapath
//!   the hardware pipelines over two stages (and what the Pallas kernel
//!   `python/compile/kernels/sptr_unit.py` computes batched).

use super::{ArrayLayout, SharedPtr};

/// Approximate dynamic op count of the compiled software increment on a
/// 64-bit RISC (loads of layout constants + 2 divs + 2 mods + muls/adds).
/// Used only for documentation / quick cost estimates; the simulator gets
/// its costs from the actual instruction streams the compiler emits.
pub const SOFT_INC_OP_COUNT: u32 = 31;

/// Granlund–Montgomery reciprocal: exact `n / d` (and `n % d`) for
/// **every** `u64` numerator against a runtime-constant divisor, as a
/// 64×64→128 multiply, an add and a shift — the strength reduction the
/// vectorized general path applies to Algorithm 1's two divides
/// (`blocksize`, `numthreads`), computed once per
/// [`EngineCtx`](crate::engine::EngineCtx).
///
/// Construction picks `s = ⌈log2 d⌉` and the magic multiplier
/// `m = ⌈2^(64+s) / d⌉`.  Because `2^(s-1) < d ≤ 2^s`, `m` always lies
/// in `[2^64, 2^65)`, so only its low word `a = m − 2^64` is stored and
/// the quotient falls out as
///
/// ```text
/// q = (n + mulhi(a, n)) >> s
/// ```
///
/// which is exact for all `n < 2^64` by the Granlund–Montgomery bound
/// (`m·d − 2^(64+s) < d ≤ 2^s`).  Power-of-two divisors degenerate to
/// `a = 0` — a pure shift — and `d = 1` to the identity.  The
/// exhaustive small-geometry property test below pins the constants
/// against native `/` and `%` for every layout divisor the NPB kernels
/// can produce (threads ∈ 1..=64, blocksize ∈ 1..=32) plus the u64
/// boundary numerators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Recip {
    /// The divisor.
    d: u64,
    /// Low word of the magic multiplier (`m − 2^64`).
    a: u64,
    /// Post-multiply shift: `⌈log2 d⌉`.
    s: u32,
}

impl Recip {
    /// Precompute the reciprocal of `d`.  Panics on `d == 0` — a layout
    /// with a zero divisor is unconstructible ([`ArrayLayout::new`]
    /// asserts both fields positive).
    pub fn new(d: u64) -> Self {
        assert!(d > 0, "no reciprocal for divisor 0");
        if d == 1 {
            return Self { d, a: 0, s: 0 };
        }
        let s = 64 - (d - 1).leading_zeros(); // ceil(log2 d), in 1..=64
        // e = 2^s - d (fits u64: d > 2^(s-1) so e < 2^(s-1) <= 2^63);
        // the u128 shift also handles s == 64 without overflow.
        let e = ((1u128 << s) - d as u128) as u64;
        // a = m - 2^64 = ceil(e * 2^64 / d)
        let a = (((e as u128) << 64) + d as u128 - 1) / d as u128;
        debug_assert!(a < 1u128 << 64, "magic multiplier exceeds 2^65");
        Self { d, a: a as u64, s }
    }

    /// The divisor this reciprocal encodes.
    #[inline]
    pub fn divisor(&self) -> u64 {
        self.d
    }

    /// `n / self.divisor()`, exact for every `n`.
    #[inline]
    pub fn div(&self, n: u64) -> u64 {
        let hi = ((self.a as u128 * n as u128) >> 64) as u64;
        ((n as u128 + hi as u128) >> self.s) as u64
    }

    /// `(n / d, n % d)` in one go (the remainder is a fused
    /// multiply-subtract off the exact quotient).
    #[inline]
    pub fn div_rem(&self, n: u64) -> (u64, u64) {
        let q = self.div(n);
        debug_assert_eq!(q, n / self.d);
        (q, n - q * self.d)
    }
}

/// Algorithm 1 verbatim (general path).
///
/// ```text
/// phinc         = shptr.phase + increment
/// thinc         = phinc / blocksize
/// nshptr.phase  = phinc % blocksize
/// blockinc      = (shptr.thread + thinc) / numthreads
/// nshptr.thread = (shptr.thread + thinc) % numthreads
/// eaddrinc      = (nshptr.phase - shptr.phase) + blockinc * blocksize
/// nshptr.va     = shptr.va + eaddrinc * elemsize
/// ```
#[inline]
pub fn increment_general(
    ptr: &SharedPtr,
    increment: u64,
    layout: &ArrayLayout,
) -> SharedPtr {
    debug_assert!(
        layout.blocksize > 0 && layout.numthreads > 0,
        "degenerate layout: {layout:?}"
    );
    let phinc = ptr.phase + increment;
    let thinc = phinc / layout.blocksize;
    let nphase = phinc % layout.blocksize;
    let tsum = ptr.thread as u64 + thinc;
    let blockinc = tsum / layout.numthreads as u64;
    let nthread = (tsum % layout.numthreads as u64) as u32;
    // eaddrinc can be negative in the first term; do signed math then
    // scale. (nphase - phase) in [-(blocksize-1), blocksize-1].
    let eaddrinc =
        (nphase as i64 - ptr.phase as i64) + (blockinc * layout.blocksize) as i64;
    let nva = (ptr.va as i64 + eaddrinc * layout.elemsize as i64) as u64;
    SharedPtr { thread: nthread, phase: nphase, va: nva }
}

/// Power-of-2 fast path: the hardware pipeline (shift/mask only).
///
/// `l2bs`, `l2es`, `l2nt` are log2 of blocksize / elemsize / numthreads —
/// the Figure-3 5-bit one-hot immediates plus the `threads` register.
#[inline]
pub fn increment_pow2(
    ptr: &SharedPtr,
    increment: u64,
    l2bs: u32,
    l2es: u32,
    l2nt: u32,
) -> SharedPtr {
    debug_assert!(
        l2bs < 64 && l2es < 64 && l2nt < 32,
        "log2 immediates out of datapath range: bs=2^{l2bs} es=2^{l2es} nt=2^{l2nt}"
    );
    // -- pipeline stage 1 --
    let phinc = ptr.phase + increment;
    let thinc = phinc >> l2bs;
    let nphase = phinc & ((1u64 << l2bs) - 1);
    // -- pipeline stage 2 --
    let tsum = ptr.thread as u64 + thinc;
    let blockinc = tsum >> l2nt;
    let nthread = (tsum & ((1u64 << l2nt) - 1)) as u32;
    let eaddrinc = (nphase as i64 - ptr.phase as i64) + ((blockinc << l2bs) as i64);
    let nva = (ptr.va as i64 + (eaddrinc << l2es)) as u64;
    SharedPtr { thread: nthread, phase: nphase, va: nva }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check_default;

    fn pow2_layout(l2bs: u32, l2es: u32, l2nt: u32) -> ArrayLayout {
        ArrayLayout::new(1 << l2bs, 1 << l2es, 1 << l2nt)
    }

    #[test]
    fn pow2_matches_general_on_pow2_layouts() {
        check_default("pow2 == general", |rng| {
            let l2bs = rng.below(11) as u32;
            let l2es = rng.below(7) as u32;
            let l2nt = rng.below(7) as u32;
            let layout = pow2_layout(l2bs, l2es, l2nt);
            let idx = rng.below(1 << 16);
            let ptr = SharedPtr::for_index(&layout, 0, idx);
            let inc = rng.below(1 << 14);
            let a = increment_general(&ptr, inc, &layout);
            let b = increment_pow2(&ptr, inc, l2bs, l2es, l2nt);
            assert_eq!(a, b, "layout={layout:?} ptr={ptr:?} inc={inc}");
        });
    }

    #[test]
    fn increment_matches_logical_index_walk() {
        check_default("inc == index arithmetic", |rng| {
            let layout = ArrayLayout::new(
                rng.below(64) + 1,
                rng.below(128) + 1,
                rng.below(63) as u32 + 1,
            );
            let base = rng.below(1 << 20);
            let idx = rng.below(1 << 12);
            let inc = rng.below(1 << 12);
            let p = SharedPtr::for_index(&layout, base, idx);
            let q = increment_general(&p, inc, &layout);
            let want = SharedPtr::for_index(&layout, base, idx + inc);
            assert_eq!(q, want, "layout={layout:?} idx={idx} inc={inc}");
        });
    }

    #[test]
    fn composition_law() {
        // inc(a) then inc(b) == inc(a+b)
        check_default("inc composes", |rng| {
            let layout = ArrayLayout::new(
                rng.below(32) + 1,
                rng.below(64) + 1,
                rng.below(16) as u32 + 1,
            );
            let p = SharedPtr::for_index(&layout, 0, rng.below(4096));
            let a = rng.below(2048);
            let b = rng.below(2048);
            let q1 = increment_general(&increment_general(&p, a, &layout), b, &layout);
            let q2 = increment_general(&p, a + b, &layout);
            assert_eq!(q1, q2);
        });
    }

    #[test]
    fn zero_increment_is_identity() {
        let layout = ArrayLayout::new(8, 8, 4);
        let p = SharedPtr::for_index(&layout, 128, 77);
        assert_eq!(increment_general(&p, 0, &layout), p);
        assert_eq!(increment_pow2(&p, 0, 3, 3, 2), p);
    }

    #[test]
    fn single_thread_degenerates_to_linear() {
        // With THREADS==1 the shared array is a plain local array.
        let layout = ArrayLayout::new(4, 8, 1);
        let p = SharedPtr::for_index(&layout, 0, 0);
        let q = increment_general(&p, 13, &layout);
        assert_eq!(q.thread, 0);
        assert_eq!(q.va, 13 * 8);
    }

    // ---- reciprocal constants pinned against native div/mod ----

    /// Every numerator class that can stress the `q = (n + mulhi(a,n)) >> s`
    /// rounding: small values, values straddling each multiple of `d`, and
    /// the u64 boundary where the `n + mulhi` sum approaches `2^65`.
    fn boundary_numerators(d: u64) -> Vec<u64> {
        let mut ns = vec![0, 1, 2, d - 1, d, d + 1, u64::MAX - 1, u64::MAX];
        for k in [2u64, 3, 7, 1 << 16, 1 << 32, (1 << 63) / d.max(1)] {
            let m = d.saturating_mul(k);
            ns.extend([m.saturating_sub(1), m, m.saturating_add(1)]);
        }
        ns
    }

    #[test]
    fn reciprocal_is_exact_for_every_small_geometry_divisor() {
        // Exhaustive over the satellite's full geometry envelope:
        // every thread count the simulator can configure (1..=64) and
        // every blocksize the NPB layout pool draws (1..=32), each
        // divisor checked on dense small numerators plus the boundary
        // classes above.
        for d in 1u64..=64 {
            let r = Recip::new(d);
            assert_eq!(r.divisor(), d);
            for n in 0..4096u64 {
                assert_eq!(r.div(n), n / d, "d={d} n={n}");
                assert_eq!(r.div_rem(n), (n / d, n % d), "d={d} n={n}");
            }
            for n in boundary_numerators(d) {
                assert_eq!(r.div(n), n / d, "d={d} n={n} (boundary)");
                assert_eq!(r.div_rem(n), (n / d, n % d), "d={d} n={n}");
            }
        }
    }

    #[test]
    fn reciprocal_increment_matches_native_on_every_small_layout() {
        // The full cross product threads 1..=64 x blocksize 1..=32:
        // recompute Algorithm 1's two div/mod pairs through Recip and
        // demand bit-identity with increment_general on awkward
        // phases/threads near the wrap boundaries.
        for threads in 1u32..=64 {
            let rnt = Recip::new(threads as u64);
            for blocksize in 1u64..=32 {
                let rbs = Recip::new(blocksize);
                let layout = ArrayLayout::new(blocksize, 24, threads);
                for idx in [0, 1, blocksize - 1, blocksize, 7 * blocksize + 3] {
                    let p = SharedPtr::for_index(&layout, 0, idx);
                    for inc in [0, 1, blocksize, blocksize * threads as u64 + 1, 977]
                    {
                        let want = increment_general(&p, inc, &layout);
                        let phinc = p.phase + inc;
                        let (thinc, nphase) = rbs.div_rem(phinc);
                        let tsum = p.thread as u64 + thinc;
                        let (blockinc, nthread) = rnt.div_rem(tsum);
                        let eaddrinc = (nphase as i64 - p.phase as i64)
                            + (blockinc * blocksize) as i64;
                        let got = SharedPtr {
                            thread: nthread as u32,
                            phase: nphase,
                            va: (p.va as i64 + eaddrinc * 24) as u64,
                        };
                        assert_eq!(got, want, "layout={layout:?} idx={idx} inc={inc}");
                    }
                }
            }
        }
    }

    #[test]
    fn reciprocal_pow2_divisors_degenerate_to_shifts() {
        // Pow2 divisors must produce a zero multiplier (pure shift):
        // that is what lets the vector path share one code shape for
        // both layout families without a speed cliff on pow2.
        for s in 0..=63u32 {
            let d = 1u64 << s;
            let r = Recip::new(d);
            assert_eq!(r.div(u64::MAX), u64::MAX >> s, "d=2^{s}");
            let n = d.saturating_mul(12345).saturating_add(17);
            assert_eq!(r.div_rem(n), (n / d, n % d), "d=2^{s} n={n}");
        }
    }

    #[test]
    fn blocksize_one_is_pure_cyclic() {
        let layout = ArrayLayout::new(1, 4, 4);
        let mut p = SharedPtr::for_index(&layout, 0, 0);
        for i in 1..=16u64 {
            p = increment_general(&p, 1, &layout);
            assert_eq!(p.thread as u64, i % 4);
            assert_eq!(p.phase, 0);
            assert_eq!(p.va, (i / 4) * 4);
        }
    }
}
