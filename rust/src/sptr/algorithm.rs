//! Algorithm 1 of the paper: shared-pointer incrementation.
//!
//! Two implementations mirror the two execution paths the paper's
//! prototype compiler chooses between:
//!
//! * [`increment_general`] — divisions/modulo, valid for any layout; this
//!   is what the Berkeley runtime executes in software and what our
//!   SimAlpha `Soft` codegen expands to (~[`SOFT_INC_OP_COUNT`] ops).
//! * [`increment_pow2`] — shift/mask form, only valid when blocksize,
//!   elemsize and numthreads are all powers of two; this is the datapath
//!   the hardware pipelines over two stages (and what the Pallas kernel
//!   `python/compile/kernels/sptr_unit.py` computes batched).

use super::{ArrayLayout, SharedPtr};

/// Approximate dynamic op count of the compiled software increment on a
/// 64-bit RISC (loads of layout constants + 2 divs + 2 mods + muls/adds).
/// Used only for documentation / quick cost estimates; the simulator gets
/// its costs from the actual instruction streams the compiler emits.
pub const SOFT_INC_OP_COUNT: u32 = 31;

/// Algorithm 1 verbatim (general path).
///
/// ```text
/// phinc         = shptr.phase + increment
/// thinc         = phinc / blocksize
/// nshptr.phase  = phinc % blocksize
/// blockinc      = (shptr.thread + thinc) / numthreads
/// nshptr.thread = (shptr.thread + thinc) % numthreads
/// eaddrinc      = (nshptr.phase - shptr.phase) + blockinc * blocksize
/// nshptr.va     = shptr.va + eaddrinc * elemsize
/// ```
#[inline]
pub fn increment_general(
    ptr: &SharedPtr,
    increment: u64,
    layout: &ArrayLayout,
) -> SharedPtr {
    let phinc = ptr.phase + increment;
    let thinc = phinc / layout.blocksize;
    let nphase = phinc % layout.blocksize;
    let tsum = ptr.thread as u64 + thinc;
    let blockinc = tsum / layout.numthreads as u64;
    let nthread = (tsum % layout.numthreads as u64) as u32;
    // eaddrinc can be negative in the first term; do signed math then
    // scale. (nphase - phase) in [-(blocksize-1), blocksize-1].
    let eaddrinc =
        (nphase as i64 - ptr.phase as i64) + (blockinc * layout.blocksize) as i64;
    let nva = (ptr.va as i64 + eaddrinc * layout.elemsize as i64) as u64;
    SharedPtr { thread: nthread, phase: nphase, va: nva }
}

/// Power-of-2 fast path: the hardware pipeline (shift/mask only).
///
/// `l2bs`, `l2es`, `l2nt` are log2 of blocksize / elemsize / numthreads —
/// the Figure-3 5-bit one-hot immediates plus the `threads` register.
#[inline]
pub fn increment_pow2(
    ptr: &SharedPtr,
    increment: u64,
    l2bs: u32,
    l2es: u32,
    l2nt: u32,
) -> SharedPtr {
    // -- pipeline stage 1 --
    let phinc = ptr.phase + increment;
    let thinc = phinc >> l2bs;
    let nphase = phinc & ((1u64 << l2bs) - 1);
    // -- pipeline stage 2 --
    let tsum = ptr.thread as u64 + thinc;
    let blockinc = tsum >> l2nt;
    let nthread = (tsum & ((1u64 << l2nt) - 1)) as u32;
    let eaddrinc = (nphase as i64 - ptr.phase as i64) + ((blockinc << l2bs) as i64);
    let nva = (ptr.va as i64 + (eaddrinc << l2es)) as u64;
    SharedPtr { thread: nthread, phase: nphase, va: nva }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check_default;

    fn pow2_layout(l2bs: u32, l2es: u32, l2nt: u32) -> ArrayLayout {
        ArrayLayout::new(1 << l2bs, 1 << l2es, 1 << l2nt)
    }

    #[test]
    fn pow2_matches_general_on_pow2_layouts() {
        check_default("pow2 == general", |rng| {
            let l2bs = rng.below(11) as u32;
            let l2es = rng.below(7) as u32;
            let l2nt = rng.below(7) as u32;
            let layout = pow2_layout(l2bs, l2es, l2nt);
            let idx = rng.below(1 << 16);
            let ptr = SharedPtr::for_index(&layout, 0, idx);
            let inc = rng.below(1 << 14);
            let a = increment_general(&ptr, inc, &layout);
            let b = increment_pow2(&ptr, inc, l2bs, l2es, l2nt);
            assert_eq!(a, b, "layout={layout:?} ptr={ptr:?} inc={inc}");
        });
    }

    #[test]
    fn increment_matches_logical_index_walk() {
        check_default("inc == index arithmetic", |rng| {
            let layout = ArrayLayout::new(
                rng.below(64) + 1,
                rng.below(128) + 1,
                rng.below(63) as u32 + 1,
            );
            let base = rng.below(1 << 20);
            let idx = rng.below(1 << 12);
            let inc = rng.below(1 << 12);
            let p = SharedPtr::for_index(&layout, base, idx);
            let q = increment_general(&p, inc, &layout);
            let want = SharedPtr::for_index(&layout, base, idx + inc);
            assert_eq!(q, want, "layout={layout:?} idx={idx} inc={inc}");
        });
    }

    #[test]
    fn composition_law() {
        // inc(a) then inc(b) == inc(a+b)
        check_default("inc composes", |rng| {
            let layout = ArrayLayout::new(
                rng.below(32) + 1,
                rng.below(64) + 1,
                rng.below(16) as u32 + 1,
            );
            let p = SharedPtr::for_index(&layout, 0, rng.below(4096));
            let a = rng.below(2048);
            let b = rng.below(2048);
            let q1 = increment_general(&increment_general(&p, a, &layout), b, &layout);
            let q2 = increment_general(&p, a + b, &layout);
            assert_eq!(q1, q2);
        });
    }

    #[test]
    fn zero_increment_is_identity() {
        let layout = ArrayLayout::new(8, 8, 4);
        let p = SharedPtr::for_index(&layout, 128, 77);
        assert_eq!(increment_general(&p, 0, &layout), p);
        assert_eq!(increment_pow2(&p, 0, 3, 3, 2), p);
    }

    #[test]
    fn single_thread_degenerates_to_linear() {
        // With THREADS==1 the shared array is a plain local array.
        let layout = ArrayLayout::new(4, 8, 1);
        let p = SharedPtr::for_index(&layout, 0, 0);
        let q = increment_general(&p, 13, &layout);
        assert_eq!(q.thread, 0);
        assert_eq!(q.va, 13 * 8);
    }

    #[test]
    fn blocksize_one_is_pure_cyclic() {
        let layout = ArrayLayout::new(1, 4, 4);
        let mut p = SharedPtr::for_index(&layout, 0, 0);
        for i in 1..=16u64 {
            p = increment_general(&p, 1, &layout);
            assert_eq!(p.thread as u64, i % 4);
            assert_eq!(p.phase, 0);
            assert_eq!(p.va, (i / 4) * 4);
        }
    }
}
