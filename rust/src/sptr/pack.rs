//! 64-bit packed representation of a shared pointer.
//!
//! "Current implementations of UPC usually use 64 bits to represent a
//! shared pointer" (paper Section 2).  The PGAS instructions operate on
//! pointers held in ordinary 64-bit integer registers, so the simulator
//! needs a canonical packing.  We use the Berkeley-style split:
//!
//! ```text
//!  63          48 47        38 37                              0
//! +--------------+------------+----------------------------------+
//! |  phase (16)  | thread(10) |         va offset (38)           |
//! +--------------+------------+----------------------------------+
//! ```
//!
//! 10 thread bits cover the paper's 64-core BigTsunami limit with room;
//! 38 va bits address 256 GiB per thread segment.

use super::SharedPtr;

pub const PHASE_BITS: u32 = 16;
pub const THREAD_BITS: u32 = 10;
pub const VA_BITS: u32 = 38;

const VA_MASK: u64 = (1 << VA_BITS) - 1;
const THREAD_MASK: u64 = (1 << THREAD_BITS) - 1;
const PHASE_MASK: u64 = (1 << PHASE_BITS) - 1;

/// A shared pointer packed into one integer register.
pub type PackedPtr = u64;

/// Pack. Fields out of range are a programming error (debug-asserted),
/// matching real compilers which reject oversized block sizes.
#[inline]
pub fn pack(p: &SharedPtr) -> PackedPtr {
    debug_assert!(p.phase <= PHASE_MASK, "phase {} overflows", p.phase);
    debug_assert!((p.thread as u64) <= THREAD_MASK);
    debug_assert!(p.va <= VA_MASK, "va {:#x} overflows", p.va);
    (p.phase << (THREAD_BITS + VA_BITS))
        | ((p.thread as u64) << VA_BITS)
        | (p.va & VA_MASK)
}

/// Unpack.
#[inline]
pub fn unpack(bits: PackedPtr) -> SharedPtr {
    SharedPtr {
        phase: (bits >> (THREAD_BITS + VA_BITS)) & PHASE_MASK,
        thread: ((bits >> VA_BITS) & THREAD_MASK) as u32,
        va: bits & VA_MASK,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check_default;

    #[test]
    fn roundtrip() {
        check_default("pack/unpack roundtrip", |rng| {
            let p = SharedPtr {
                thread: rng.below(1 << THREAD_BITS) as u32,
                phase: rng.below(1 << PHASE_BITS),
                va: rng.below(1 << VA_BITS),
            };
            assert_eq!(unpack(pack(&p)), p);
        });
    }

    #[test]
    fn null_is_zero() {
        assert_eq!(pack(&SharedPtr::NULL), 0);
        assert_eq!(unpack(0), SharedPtr::NULL);
    }

    #[test]
    fn field_isolation() {
        let p = SharedPtr { thread: 63, phase: 0, va: 0 };
        let bits = pack(&p);
        assert_eq!(bits, 63 << VA_BITS);
        let q = SharedPtr { thread: 0, phase: 5, va: 0 };
        assert_eq!(pack(&q), 5 << (THREAD_BITS + VA_BITS));
    }
}
