//! UPC shared-pointer algebra — the paper's Section 2 memory model and
//! Section 4 Algorithm 1, in both the general (divide/modulo) software
//! form and the power-of-2 shift/mask form the hardware implements.
//!
//! A UPC shared pointer has three fields (paper Fig. 2):
//!
//! * `thread` — affinity of the pointed element,
//! * `phase`  — position inside the current block,
//! * `va`     — address of the element in that thread's local space
//!   (stored here as an offset into the thread's shared segment).
//!
//! A `shared [B] T A[N]` array distributes elements round-robin in blocks
//! of `B` over `THREADS` threads; each thread stores its blocks
//! contiguously from the array's local base offset.

mod algorithm;
mod base_table;
mod cursor;
mod pack;
mod wire;

pub use algorithm::{increment_general, increment_pow2, Recip, SOFT_INC_OP_COUNT};
pub use base_table::BaseTable;
pub use cursor::WalkCursor;
pub use pack::{pack, unpack, PackedPtr, PHASE_BITS, THREAD_BITS, VA_BITS};
pub use wire::{ctx_fingerprint, CtxSnapshot, WireError, WireReader, WireWriter};

use crate::util::{is_pow2, log2_exact};

/// Distribution geometry of one shared array (+ element size in bytes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArrayLayout {
    /// Block size in elements (the `[B]` in `shared [B] int A[..]`).
    pub blocksize: u64,
    /// Element size in bytes.
    pub elemsize: u64,
    /// Number of UPC threads.
    pub numthreads: u32,
}

impl ArrayLayout {
    pub fn new(blocksize: u64, elemsize: u64, numthreads: u32) -> Self {
        assert!(blocksize > 0 && elemsize > 0 && numthreads > 0);
        Self { blocksize, elemsize, numthreads }
    }

    /// The hardware fast path requires all three parameters to be powers
    /// of two (paper 4.2); the compiler falls back to software otherwise.
    pub fn hw_supported(&self) -> bool {
        is_pow2(self.blocksize)
            && is_pow2(self.elemsize)
            && is_pow2(self.numthreads as u64)
    }

    /// (log2 blocksize, log2 elemsize, log2 numthreads) when pow2.
    pub fn log2s(&self) -> Option<(u32, u32, u32)> {
        Some((
            log2_exact(self.blocksize)?,
            log2_exact(self.elemsize)?,
            log2_exact(self.numthreads as u64)?,
        ))
    }

    /// Bytes occupied on thread `t` by the first `n` elements of the
    /// array (used by the allocator to size per-thread chunks).
    pub fn bytes_on_thread(&self, n: u64, t: u32) -> u64 {
        let full_rounds = n / (self.blocksize * self.numthreads as u64);
        let rem = n % (self.blocksize * self.numthreads as u64);
        let rem_t = rem
            .saturating_sub(t as u64 * self.blocksize)
            .min(self.blocksize);
        (full_rounds * self.blocksize + rem_t) * self.elemsize
    }
}

/// A UPC shared pointer. `va` is the element's byte offset inside its
/// thread's shared segment; translation to a system virtual address adds
/// the thread's base from the [`BaseTable`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SharedPtr {
    pub thread: u32,
    pub phase: u64,
    pub va: u64,
}

impl SharedPtr {
    pub const NULL: SharedPtr = SharedPtr { thread: 0, phase: 0, va: 0 };

    /// Pointer to logical element `idx` of an array whose per-thread data
    /// starts at local offset `base_va` (identical on every thread, as in
    /// the Berkeley runtime's symmetric heaps).
    pub fn for_index(layout: &ArrayLayout, base_va: u64, idx: u64) -> Self {
        let block = idx / layout.blocksize;
        let phase = idx % layout.blocksize;
        let thread = (block % layout.numthreads as u64) as u32;
        let local_block = block / layout.numthreads as u64;
        let va = base_va
            + (local_block * layout.blocksize + phase) * layout.elemsize;
        SharedPtr { thread, phase, va }
    }

    /// Inverse of [`SharedPtr::for_index`] — the logical index this
    /// pointer refers to. Requires the pointer to be well-formed for
    /// `layout` / `base_va`.
    pub fn to_index(&self, layout: &ArrayLayout, base_va: u64) -> u64 {
        let local_off = (self.va - base_va) / layout.elemsize;
        let local_block = local_off / layout.blocksize;
        debug_assert_eq!(local_off % layout.blocksize, self.phase);
        (local_block * layout.numthreads as u64 + self.thread as u64)
            * layout.blocksize
            + self.phase
    }

    /// `upc_threadof`.
    pub fn threadof(&self) -> u32 {
        self.thread
    }

    /// `upc_phaseof`.
    pub fn phaseof(&self) -> u64 {
        self.phase
    }

    /// `upc_addrfieldof`.
    pub fn addrfieldof(&self) -> u64 {
        self.va
    }

    /// `upc_resetphase` — pointer to the start of the current block.
    pub fn resetphase(&self, layout: &ArrayLayout) -> SharedPtr {
        SharedPtr {
            thread: self.thread,
            phase: 0,
            va: self.va - self.phase * layout.elemsize,
        }
    }

    /// Translate to a system virtual address (paper 4.2: LUT + add).
    #[inline]
    pub fn translate(&self, table: &BaseTable) -> u64 {
        table.base(self.thread) + self.va
    }

    /// Increment through the array layout (general path).
    pub fn incremented(&self, inc: u64, layout: &ArrayLayout) -> SharedPtr {
        increment_general(self, inc, layout)
    }
}

/// Locality condition codes produced by the increment unit (paper 5.2),
/// consumed by the Coprocessor-Branch instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Locality {
    /// Pointed data owned by the executing thread.
    Local = 0,
    /// Same memory controller.
    SameMc = 1,
    /// Same node: reachable via the shared load/store instructions.
    SameNode = 2,
    /// Other node: requires network communication.
    Remote = 3,
}

impl Locality {
    /// Inverse of `as u8` — decodes condition codes coming back from the
    /// simulated hardware or the batched XLA unit.
    pub fn from_code(code: u8) -> Option<Locality> {
        match code {
            0 => Some(Locality::Local),
            1 => Some(Locality::SameMc),
            2 => Some(Locality::SameNode),
            3 => Some(Locality::Remote),
            _ => None,
        }
    }
}

/// Machine topology used for locality classification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub log2_threads_per_mc: u32,
    pub log2_threads_per_node: u32,
}

impl Default for Topology {
    /// Single-node SMP with 2 threads per memory controller — the
    /// Leon3 prototype shape (everything is at worst `SameNode`).
    fn default() -> Self {
        Topology { log2_threads_per_mc: 1, log2_threads_per_node: 6 }
    }
}

/// Classify `thread` relative to the executing `mythread`.
#[inline]
pub fn locality(thread: u32, mythread: u32, topo: &Topology) -> Locality {
    if thread == mythread {
        Locality::Local
    } else if thread >> topo.log2_threads_per_mc
        == mythread >> topo.log2_threads_per_mc
    {
        Locality::SameMc
    } else if thread >> topo.log2_threads_per_node
        == mythread >> topo.log2_threads_per_node
    {
        Locality::SameNode
    } else {
        Locality::Remote
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Figure 2: `shared [4] int arrayA[32]` over 4 threads.
    fn fig2() -> ArrayLayout {
        ArrayLayout::new(4, 4, 4)
    }

    #[test]
    fn figure2_element_placement() {
        let l = fig2();
        // Elements 0..3 on thread 0, 4..7 on thread 1, ..., 16..19 wrap
        // to thread 0's second block.
        for i in 0..32u64 {
            let p = SharedPtr::for_index(&l, 0, i);
            assert_eq!(p.thread as u64, (i / 4) % 4, "elem {i}");
            assert_eq!(p.phase, i % 4, "elem {i}");
            let local_block = i / 16;
            assert_eq!(p.va, (local_block * 4 + i % 4) * 4, "elem {i}");
            assert_eq!(p.to_index(&l, 0), i);
        }
    }

    #[test]
    fn index_roundtrip_nonpow2() {
        // CG's w/w_tmp-style array: elemsize 56016 (non-pow2).
        let l = ArrayLayout::new(3, 56016, 5);
        for i in 0..200u64 {
            let p = SharedPtr::for_index(&l, 4096, i);
            assert_eq!(p.to_index(&l, 4096), i);
            assert!(!l.hw_supported());
        }
    }

    #[test]
    fn accessor_functions() {
        let l = fig2();
        let p = SharedPtr::for_index(&l, 0, 9);
        assert_eq!(p.threadof(), 2);
        assert_eq!(p.phaseof(), 1);
        assert_eq!(p.addrfieldof(), 4);
        let r = p.resetphase(&l);
        assert_eq!(r.phase, 0);
        assert_eq!(r.va, 0);
        assert_eq!(r.thread, 2);
    }

    #[test]
    fn translation_uses_base_table() {
        let table = BaseTable::regular(4, 0xFF0B_0000_0000, 1 << 32);
        let p = SharedPtr { thread: 1, phase: 0, va: 0x3F00 };
        assert_eq!(p.translate(&table), 0xFF0B_0000_0000 + (1 << 32) + 0x3F00);
    }

    #[test]
    fn locality_codes() {
        let topo = Topology { log2_threads_per_mc: 1, log2_threads_per_node: 2 };
        assert_eq!(locality(0, 0, &topo), Locality::Local);
        assert_eq!(locality(1, 0, &topo), Locality::SameMc);
        assert_eq!(locality(2, 0, &topo), Locality::SameNode);
        assert_eq!(locality(3, 0, &topo), Locality::SameNode);
        assert_eq!(locality(4, 0, &topo), Locality::Remote);
    }

    #[test]
    fn locality_code_roundtrip() {
        for l in [Locality::Local, Locality::SameMc, Locality::SameNode, Locality::Remote] {
            assert_eq!(Locality::from_code(l as u8), Some(l));
        }
        assert_eq!(Locality::from_code(4), None);
    }

    #[test]
    fn bytes_on_thread_partial_rounds() {
        let l = fig2(); // 4 threads, blocks of 4 ints
        // 18 elements: threads 0..3 get 4,4,4,4 then thread 0 gets 2 more.
        assert_eq!(l.bytes_on_thread(18, 0), (4 + 2) * 4);
        assert_eq!(l.bytes_on_thread(18, 1), 4 * 4);
        assert_eq!(l.bytes_on_thread(18, 3), 4 * 4);
        assert_eq!(l.bytes_on_thread(16, 0), 16);
    }
}
