//! The `detailed` CPU model: an out-of-order core in the style of Gem5's
//! O3 (the paper's "detailed" series in Figures 11–14).
//!
//! Modeled analytically: instructions issue when (a) they have been
//! fetched (width-limited), (b) their source operands are ready, (c) a
//! functional unit of the right kind is free, and (d) the ROB window has
//! room; they retire in order.  Branches use a 1-bit dynamic predictor
//! with a fixed redirect penalty.  Loads overlap with execution through
//! their completion latency (cache time included), which is exactly the
//! mechanism the paper credits for the detailed model "bringing more
//! opportunities to reorganize the instructions to reduce the software
//! overhead of shared address manipulations".
//!
//! The prototype compiler marks PGAS stores `volatile` + memory-clobber
//! (paper 6.1).  That constrains *GCC's* scheduling, not the hardware:
//! the effect is modeled where it belongs, in codegen, as an extra
//! reload instruction after every hardware store
//! (`CompileOpts::volatile_stores`) — which is what keeps the
//! manually-privatized code ~10% ahead of HW-supported code on the
//! store-heavy IS and MG kernels.

use std::collections::VecDeque;

use super::{ArchState, CoreStats, Cpu, SharedLevel, StopReason};
use crate::cpu::exec::{step, StepEffect};
use crate::isa::latency::{FuKind, LatencyModel};
use crate::isa::{Inst, Program};
use crate::mem::MemSystem;

/// Microarchitectural parameters (defaults are 21264-class).
#[derive(Clone, Copy, Debug)]
pub struct DetailedCfg {
    pub fetch_width: u32,
    pub rob: usize,
    pub mispredict_penalty: u64,
    pub int_alus: usize,
    pub int_muldivs: usize,
    pub fp_alus: usize,
    pub fp_muldivs: usize,
    pub mem_ports: usize,
    pub pgas_units: usize,
}

impl Default for DetailedCfg {
    fn default() -> Self {
        Self {
            fetch_width: 4,
            rob: 64,
            mispredict_penalty: 7,
            int_alus: 4,
            int_muldivs: 1,
            fp_alus: 1,
            fp_muldivs: 1,
            mem_ports: 2,
            pgas_units: 1,
        }
    }
}

// virtual register ids for the scheduler: 0..31 int, 32..63 fp, 64 = the
// PGAS locality condition code, 65 = the `threads` special register.
const VREG_CC: usize = 64;
const VREGS: usize = 66;

/// (sources, nsrc, dest) without heap allocation — this runs once per
/// simulated instruction (§Perf: the Vec-per-inst version cost ~25% of
/// detailed-model wall time).
#[inline]
fn operands(inst: &Inst) -> ([usize; 2], usize, Option<usize>) {
    const NONE: usize = 0;
    let i = |r: u8| r as usize;
    let f = |r: u8| 32 + r as usize;
    match *inst {
        Inst::Opi { rd, ra, .. } => ([i(ra), NONE], 1, Some(i(rd))),
        Inst::Opr { rd, ra, rb, .. } => ([i(ra), i(rb)], 2, Some(i(rd))),
        Inst::Ldi { rd, .. } => ([NONE; 2], 0, Some(i(rd))),
        Inst::Ld { w, rd, base, .. } => {
            ([i(base), NONE], 1, Some(if w.is_float() { f(rd) } else { i(rd) }))
        }
        Inst::St { w, rs, base, .. } => {
            ([i(base), if w.is_float() { f(rs) } else { i(rs) }], 2, None)
        }
        Inst::Fop { fd, fa, fb, .. } => ([f(fa), f(fb)], 2, Some(f(fd))),
        Inst::FCmpLt { rd, fa, fb } => ([f(fa), f(fb)], 2, Some(i(rd))),
        Inst::CvtIF { fd, ra } => ([i(ra), NONE], 1, Some(f(fd))),
        Inst::CvtFI { rd, fa } => ([f(fa), NONE], 1, Some(i(rd))),
        Inst::Br { ra, .. } => ([i(ra), NONE], 1, None),
        Inst::Jmp { .. } => ([NONE; 2], 0, None),
        Inst::PgasLd { w, rd, rptr, .. } => {
            ([i(rptr), NONE], 1, Some(if w.is_float() { f(rd) } else { i(rd) }))
        }
        Inst::PgasSt { w, rs, rptr, .. } => {
            ([i(rptr), if w.is_float() { f(rs) } else { i(rs) }], 2, None)
        }
        Inst::PgasIncI { rd, ra, .. } => ([i(ra), NONE], 1, Some(i(rd))),
        Inst::PgasIncR { rd, ra, rb, .. } => ([i(ra), i(rb)], 2, Some(i(rd))),
        Inst::PgasSetThreads { ra } => ([i(ra), NONE], 1, None),
        Inst::PgasSetBase { rthread, raddr } => ([i(rthread), i(raddr)], 2, None),
        Inst::PgasBrLoc { .. } => ([VREG_CC, NONE], 1, None),
        Inst::Barrier | Inst::Halt | Inst::Nop => ([NONE; 2], 0, None),
    }
}

#[inline]
fn fu_index(kind: FuKind) -> usize {
    match kind {
        FuKind::IntAlu => 0,
        FuKind::IntMulDiv => 1,
        FuKind::FpAlu => 2,
        FuKind::FpMulDiv => 3,
        FuKind::MemPort => 4,
        FuKind::PgasUnit => 5,
        FuKind::None => 6,
    }
}

/// Out-of-order core.
pub struct DetailedCpu {
    state: ArchState,
    stats: CoreStats,
    cfg: DetailedCfg,
    lat: LatencyModel,
    core: usize,
    /// 1-bit predictor indexed by pc (sized lazily to the program).
    predictor: Vec<bool>,
}

impl DetailedCpu {
    pub fn new(mythread: u32, numthreads: u32) -> Self {
        Self {
            state: ArchState::new(mythread, numthreads),
            stats: CoreStats::default(),
            cfg: DetailedCfg::default(),
            lat: LatencyModel::default(),
            core: mythread as usize,
            predictor: Vec::new(),
        }
    }

    pub fn with_cfg(mythread: u32, numthreads: u32, cfg: DetailedCfg) -> Self {
        let mut c = Self::new(mythread, numthreads);
        c.cfg = cfg;
        c
    }

    fn fu_slots(&self, kind: FuKind) -> usize {
        match kind {
            FuKind::IntAlu => self.cfg.int_alus,
            FuKind::IntMulDiv => self.cfg.int_muldivs,
            FuKind::FpAlu => self.cfg.fp_alus,
            FuKind::FpMulDiv => self.cfg.fp_muldivs,
            FuKind::MemPort => self.cfg.mem_ports,
            FuKind::PgasUnit => self.cfg.pgas_units,
            FuKind::None => 0,
        }
    }
}

impl Cpu for DetailedCpu {
    fn run(
        &mut self,
        prog: &Program,
        mem: &mut MemSystem,
        shared: &mut SharedLevel,
        max_insts: u64,
    ) -> StopReason {
        // Scheduler state is per-quantum: the pipeline drains at barriers
        // and quantum boundaries (a small conservative approximation).
        let mut reg_ready = [0u64; VREGS];
        // per-FU-kind next-free times, flat arrays (§Perf: HashMap
        // lookup per instruction was a top-3 profile entry)
        let mut fu_free: [Vec<u64>; 7] = [
            vec![0; self.fu_slots(FuKind::IntAlu)],
            vec![0; self.fu_slots(FuKind::IntMulDiv)],
            vec![0; self.fu_slots(FuKind::FpAlu)],
            vec![0; self.fu_slots(FuKind::FpMulDiv)],
            vec![0; self.fu_slots(FuKind::MemPort)],
            vec![0; self.fu_slots(FuKind::PgasUnit)],
            Vec::new(),
        ];
        if self.predictor.len() < prog.insts.len() {
            self.predictor.resize(prog.insts.len(), false);
        }
        let mut rob: VecDeque<u64> = VecDeque::with_capacity(self.cfg.rob);
        let mut fetch_cycle = 0u64;
        let mut fetched_in_cycle = 0u32;
        let mut last_retire = 0u64;
        let mut budget = max_insts;
        let mut stop = StopReason::QuantumExpired;

        while budget > 0 {
            if self.state.halted {
                stop = StopReason::Halted;
                break;
            }
            let pc = self.state.pc;
            let inst = prog.insts[pc as usize];
            // ---- functional execution first (architectural truth) ----
            let effect = step(&mut self.state, mem, &inst);
            self.stats.instructions += 1;
            budget -= 1;

            // ---- timing: fetch ----
            if fetched_in_cycle >= self.cfg.fetch_width {
                fetch_cycle += 1;
                fetched_in_cycle = 0;
            }
            fetched_in_cycle += 1;

            // ---- ROB back-pressure ----
            if rob.len() >= self.cfg.rob {
                let oldest = rob.pop_front().unwrap();
                fetch_cycle = fetch_cycle.max(oldest);
            }

            let (srcs, nsrc, dst) = operands(&inst);
            let mut ready = fetch_cycle;
            for &s in &srcs[..nsrc] {
                ready = ready.max(reg_ready[s]);
            }

            let cost = self.lat.cost(&inst);
            let _is_mem = inst.is_mem();

            // ---- FU allocation ----
            let issue = if cost.fu == FuKind::None {
                ready
            } else {
                let slots = &mut fu_free[fu_index(cost.fu)];
                let mut best = 0;
                for (idx, &t) in slots.iter().enumerate() {
                    if t < slots[best] {
                        best = idx;
                    }
                }
                let issue = ready.max(slots[best]);
                slots[best] = issue + cost.init_interval as u64;
                issue
            };

            // ---- completion ----
            let mut complete = issue + cost.latency as u64;
            match effect {
                StepEffect::Mem { sysva, write, shared: is_shared, local, .. } => {
                    let hier = shared.access(self.core, sysva, write);
                    if write {
                        // stores retire via the store buffer
                        complete = issue + 1;
                        self.stats.mem_writes += 1;
                        // NB: the prototype's volatile-asm stores
                        // constrain GCC's scheduling (modeled as the
                        // extra reload instruction emitted by the
                        // compiler), not the OoO hardware — no runtime
                        // fence here. The store buffer absorbs `hier`.
                        let _ = hier;
                    } else {
                        complete = issue + cost.latency as u64 + hier;
                        self.stats.mem_reads += 1;
                    }
                    if is_shared {
                        if inst.is_pgas() {
                            self.stats.pgas_mems += 1;
                        }
                        if local {
                            self.stats.local_shared_accesses += 1;
                        } else {
                            self.stats.remote_shared_accesses += 1;
                        }
                    }
                }
                StepEffect::Branch { taken } => {
                    self.stats.branches += 1;
                    let predicted = self.predictor[pc as usize];
                    self.predictor[pc as usize] = taken;
                    if predicted != taken {
                        fetch_cycle = complete + self.cfg.mispredict_penalty;
                        fetched_in_cycle = 0;
                    }
                }
                StepEffect::Barrier => {
                    self.stats.barriers += 1;
                    stop = StopReason::Barrier;
                }
                StepEffect::Halt => {
                    stop = StopReason::Halted;
                }
                StepEffect::Normal => {
                    if matches!(inst, Inst::PgasIncI { .. } | Inst::PgasIncR { .. }) {
                        self.stats.pgas_incs += 1;
                        reg_ready[VREG_CC] = complete;
                    }
                }
            }

            if let Some(d) = dst {
                // zero registers are always ready
                if d != 31 && d != 63 {
                    reg_ready[d] = complete;
                }
            }
            // in-order retire
            last_retire = last_retire.max(complete);
            rob.push_back(last_retire);

            if matches!(stop, StopReason::Barrier | StopReason::Halted)
                || self.state.halted
            {
                if matches!(stop, StopReason::QuantumExpired) {
                    stop = StopReason::Halted;
                }
                break;
            }
        }
        // drain
        self.stats.cycles += last_retire.max(fetch_cycle);
        stop
    }

    fn state(&self) -> &ArchState {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ArchState {
        &mut self.state
    }

    fn stats(&self) -> &CoreStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CoreStats {
        &mut self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::HierLatency;
    use crate::isa::{Cond, IntOp};

    fn shared1() -> SharedLevel {
        SharedLevel::new(1, HierLatency::default())
    }

    fn run_cycles(prog: &Program) -> (u64, u64) {
        let mut cpu = DetailedCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        cpu.run(prog, &mut mem, &mut shared1(), u64::MAX);
        (cpu.stats().cycles, cpu.stats().instructions)
    }

    #[test]
    fn independent_ops_run_superscalar() {
        // 8 independent adds should take far fewer cycles than 8 serial.
        let indep = Program::new(
            "indep",
            (0..8)
                .map(|k| Inst::Opi { op: IntOp::Add, rd: k as u8, ra: 31, imm: k })
                .chain([Inst::Halt])
                .collect(),
        );
        let serial = Program::new(
            "serial",
            (0..8)
                .map(|_| Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: 1 })
                .chain([Inst::Halt])
                .collect(),
        );
        let (ci, _) = run_cycles(&indep);
        let (cs, _) = run_cycles(&serial);
        assert!(ci < cs, "independent {ci} should beat serial {cs}");
    }

    #[test]
    fn predictable_loop_has_high_ipc() {
        let prog = Program::new(
            "loop",
            vec![
                Inst::Ldi { rd: 1, imm: 1000 },
                Inst::Opi { op: IntOp::Add, rd: 2, ra: 2, imm: 3 }, // 1
                Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 1, target: 1 },
                Inst::Halt,
            ],
        );
        let (c, i) = run_cycles(&prog);
        let ipc = i as f64 / c as f64;
        assert!(ipc > 1.2, "OoO core should exceed 1 IPC here, got {ipc:.2}");
    }

    #[test]
    fn detailed_is_faster_than_timing_on_ilp_code() {
        use crate::cpu::{Cpu, TimingCpu};
        let prog = Program::new(
            "ilp",
            (0..64)
                .map(|k| Inst::Opi { op: IntOp::Add, rd: (k % 8) as u8, ra: 31, imm: k })
                .chain([Inst::Halt])
                .collect(),
        );
        let mut t = TimingCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        t.run(&prog, &mut mem, &mut shared1(), u64::MAX);
        let (d, _) = run_cycles(&prog);
        assert!(d < t.stats().cycles);
    }

    #[test]
    fn single_pgas_unit_serializes_increment_bursts() {
        // one coprocessor unit per core (the prototype): a burst of
        // independent increments is throughput-bound at 1/cycle, while
        // the same number of independent ALU adds spreads over 4 ALUs.
        use crate::sptr::{pack, SharedPtr};
        let incs: Vec<Inst> = (0..16)
            .map(|k| Inst::PgasIncI { rd: k as u8 % 8, ra: 8 + (k as u8 % 8), l2es: 2, l2bs: 2, l2inc: 0 })
            .chain([Inst::Halt])
            .collect();
        let adds: Vec<Inst> = (0..16)
            .map(|k| Inst::Opi { op: IntOp::Add, rd: k as u8 % 8, ra: 8 + (k as u8 % 8), imm: 4 })
            .chain([Inst::Halt])
            .collect();
        let mut p = Program::new("incs", incs);
        // seed pointer registers so increments are architecturally valid
        let seed = pack(&SharedPtr::NULL) as i64;
        for r in 8..16 {
            p.insts.insert(0, Inst::Ldi { rd: r, imm: seed });
        }
        let (ci, _) = run_cycles(&p);
        let (ca, _) = run_cycles(&Program::new("adds", adds));
        assert!(ci > ca, "single pgas unit {ci} vs 4 ALUs {ca}");
    }
}
