//! The `detailed` CPU model: an out-of-order core in the style of Gem5's
//! O3 (the paper's "detailed" series in Figures 11–14).
//!
//! Modeled analytically: instructions issue when (a) they have been
//! fetched (width-limited), (b) their source operands are ready, (c) a
//! functional unit of the right kind is free, and (d) the ROB window has
//! room; they retire in order.  Branches use a 1-bit dynamic predictor
//! with a fixed redirect penalty.  Loads overlap with execution through
//! their completion latency (cache time included), which is exactly the
//! mechanism the paper credits for the detailed model "bringing more
//! opportunities to reorganize the instructions to reduce the software
//! overhead of shared address manipulations".
//!
//! The prototype compiler marks PGAS stores `volatile` + memory-clobber
//! (paper 6.1).  That constrains *GCC's* scheduling, not the hardware:
//! the effect is modeled where it belongs, in codegen, as an extra
//! reload instruction after every hardware store
//! (`CompileOpts::volatile_stores`) — which is what keeps the
//! manually-privatized code ~10% ahead of HW-supported code on the
//! store-heavy IS and MG kernels.
//!
//! Execution runs on the shared pipeline core
//! ([`cpu::pipeline`](crate::cpu::pipeline)); this file is only the
//! OoO scheduler policy.  Batched PGAS-increment windows replay the
//! exact `(pc, inst, effect)` sequence scalar stepping would issue, so
//! the scheduler state — and therefore the cycle total — is
//! bit-identical either way.

use std::collections::VecDeque;

use super::pipeline::{run_pipeline, IssuePolicy, Lookahead};
use super::{ArchState, CoreStats, Cpu, SharedLevel, StopReason};
use crate::cpu::exec::StepEffect;
use crate::isa::latency::{FuKind, LatencyModel};
use crate::isa::{Inst, Program};
use crate::mem::MemSystem;

/// Microarchitectural parameters (defaults are 21264-class).
#[derive(Clone, Copy, Debug)]
pub struct DetailedCfg {
    pub fetch_width: u32,
    pub rob: usize,
    pub mispredict_penalty: u64,
    pub int_alus: usize,
    pub int_muldivs: usize,
    pub fp_alus: usize,
    pub fp_muldivs: usize,
    pub mem_ports: usize,
    pub pgas_units: usize,
}

impl Default for DetailedCfg {
    fn default() -> Self {
        Self {
            fetch_width: 4,
            rob: 64,
            mispredict_penalty: 7,
            int_alus: 4,
            int_muldivs: 1,
            fp_alus: 1,
            fp_muldivs: 1,
            mem_ports: 2,
            pgas_units: 1,
        }
    }
}

// virtual register ids for the scheduler: 0..31 int, 32..63 fp, 64 = the
// PGAS locality condition code, 65 = the `threads` special register.
const VREG_CC: usize = 64;
const VREGS: usize = 66;

/// (sources, nsrc, dest) without heap allocation — this runs once per
/// simulated instruction (§Perf: the Vec-per-inst version cost ~25% of
/// detailed-model wall time).
#[inline]
fn operands(inst: &Inst) -> ([usize; 2], usize, Option<usize>) {
    const NONE: usize = 0;
    let i = |r: u8| r as usize;
    let f = |r: u8| 32 + r as usize;
    match *inst {
        Inst::Opi { rd, ra, .. } => ([i(ra), NONE], 1, Some(i(rd))),
        Inst::Opr { rd, ra, rb, .. } => ([i(ra), i(rb)], 2, Some(i(rd))),
        Inst::Ldi { rd, .. } => ([NONE; 2], 0, Some(i(rd))),
        Inst::Ld { w, rd, base, .. } => {
            ([i(base), NONE], 1, Some(if w.is_float() { f(rd) } else { i(rd) }))
        }
        Inst::St { w, rs, base, .. } => {
            ([i(base), if w.is_float() { f(rs) } else { i(rs) }], 2, None)
        }
        Inst::Fop { fd, fa, fb, .. } => ([f(fa), f(fb)], 2, Some(f(fd))),
        Inst::FCmpLt { rd, fa, fb } => ([f(fa), f(fb)], 2, Some(i(rd))),
        Inst::CvtIF { fd, ra } => ([i(ra), NONE], 1, Some(f(fd))),
        Inst::CvtFI { rd, fa } => ([f(fa), NONE], 1, Some(i(rd))),
        Inst::Br { ra, .. } => ([i(ra), NONE], 1, None),
        Inst::Jmp { .. } => ([NONE; 2], 0, None),
        Inst::PgasLd { w, rd, rptr, .. } => {
            ([i(rptr), NONE], 1, Some(if w.is_float() { f(rd) } else { i(rd) }))
        }
        Inst::PgasSt { w, rs, rptr, .. } => {
            ([i(rptr), if w.is_float() { f(rs) } else { i(rs) }], 2, None)
        }
        Inst::PgasIncI { rd, ra, .. } => ([i(ra), NONE], 1, Some(i(rd))),
        Inst::PgasIncR { rd, ra, rb, .. } => ([i(ra), i(rb)], 2, Some(i(rd))),
        Inst::PgasSetThreads { ra } => ([i(ra), NONE], 1, None),
        Inst::PgasSetBase { rthread, raddr } => ([i(rthread), i(raddr)], 2, None),
        Inst::PgasBrLoc { .. } => ([VREG_CC, NONE], 1, None),
        Inst::Barrier | Inst::Halt | Inst::Nop => ([NONE; 2], 0, None),
    }
}

#[inline]
fn fu_index(kind: FuKind) -> usize {
    match kind {
        FuKind::IntAlu => 0,
        FuKind::IntMulDiv => 1,
        FuKind::FpAlu => 2,
        FuKind::FpMulDiv => 3,
        FuKind::MemPort => 4,
        FuKind::PgasUnit => 5,
        FuKind::None => 6,
    }
}

/// The OoO scheduler policy.  Scheduler state is per-quantum: the
/// pipeline drains at barriers and quantum boundaries (a small
/// conservative approximation); only the branch predictor persists.
struct DetailedPolicy {
    cfg: DetailedCfg,
    lat: LatencyModel,
    core: usize,
    /// 1-bit predictor indexed by pc (sized lazily to the program).
    predictor: Vec<bool>,
    // ---- per-quantum scheduler state (reset in `begin`) ----
    reg_ready: [u64; VREGS],
    /// per-FU-kind next-free times, flat arrays (§Perf: HashMap
    /// lookup per instruction was a top-3 profile entry)
    fu_free: [Vec<u64>; 7],
    rob: VecDeque<u64>,
    fetch_cycle: u64,
    fetched_in_cycle: u32,
    last_retire: u64,
}

impl DetailedPolicy {
    fn fu_slots(&self, kind: FuKind) -> usize {
        match kind {
            FuKind::IntAlu => self.cfg.int_alus,
            FuKind::IntMulDiv => self.cfg.int_muldivs,
            FuKind::FpAlu => self.cfg.fp_alus,
            FuKind::FpMulDiv => self.cfg.fp_muldivs,
            FuKind::MemPort => self.cfg.mem_ports,
            FuKind::PgasUnit => self.cfg.pgas_units,
            FuKind::None => 0,
        }
    }
}

impl IssuePolicy for DetailedPolicy {
    fn begin(&mut self, prog: &Program) {
        self.reg_ready = [0; VREGS];
        self.fu_free = [
            vec![0; self.fu_slots(FuKind::IntAlu)],
            vec![0; self.fu_slots(FuKind::IntMulDiv)],
            vec![0; self.fu_slots(FuKind::FpAlu)],
            vec![0; self.fu_slots(FuKind::FpMulDiv)],
            vec![0; self.fu_slots(FuKind::MemPort)],
            vec![0; self.fu_slots(FuKind::PgasUnit)],
            Vec::new(),
        ];
        self.rob.clear();
        self.fetch_cycle = 0;
        self.fetched_in_cycle = 0;
        self.last_retire = 0;
        if self.predictor.len() < prog.insts.len() {
            self.predictor.resize(prog.insts.len(), false);
        }
    }

    fn issue(
        &mut self,
        pc: u32,
        inst: &Inst,
        effect: StepEffect,
        shared: &mut SharedLevel,
        _stats: &mut CoreStats,
    ) {
        // ---- fetch (width-limited) ----
        if self.fetched_in_cycle >= self.cfg.fetch_width {
            self.fetch_cycle += 1;
            self.fetched_in_cycle = 0;
        }
        self.fetched_in_cycle += 1;

        // ---- ROB back-pressure ----
        if self.rob.len() >= self.cfg.rob {
            let oldest = self.rob.pop_front().unwrap();
            self.fetch_cycle = self.fetch_cycle.max(oldest);
        }

        let (srcs, nsrc, dst) = operands(inst);
        let mut ready = self.fetch_cycle;
        for &s in &srcs[..nsrc] {
            ready = ready.max(self.reg_ready[s]);
        }

        let cost = self.lat.cost(inst);

        // ---- FU allocation ----
        let issue = if cost.fu == FuKind::None {
            ready
        } else {
            let slots = &mut self.fu_free[fu_index(cost.fu)];
            let mut best = 0;
            for (idx, &t) in slots.iter().enumerate() {
                if t < slots[best] {
                    best = idx;
                }
            }
            let issue = ready.max(slots[best]);
            slots[best] = issue + cost.init_interval as u64;
            issue
        };

        // ---- completion ----
        let mut complete = issue + cost.latency as u64;
        match effect {
            StepEffect::Mem { sysva, write, .. } => {
                let hier = shared.access(self.core, sysva, write);
                if write {
                    // stores retire via the store buffer
                    complete = issue + 1;
                    // NB: the prototype's volatile-asm stores
                    // constrain GCC's scheduling (modeled as the
                    // extra reload instruction emitted by the
                    // compiler), not the OoO hardware — no runtime
                    // fence here. The store buffer absorbs `hier`.
                    let _ = hier;
                } else {
                    complete = issue + cost.latency as u64 + hier;
                }
            }
            StepEffect::Branch { taken } => {
                let predicted = self.predictor[pc as usize];
                self.predictor[pc as usize] = taken;
                if predicted != taken {
                    self.fetch_cycle = complete + self.cfg.mispredict_penalty;
                    self.fetched_in_cycle = 0;
                }
            }
            StepEffect::Normal => {
                if matches!(inst, Inst::PgasIncI { .. } | Inst::PgasIncR { .. }) {
                    self.reg_ready[VREG_CC] = complete;
                }
            }
            StepEffect::Barrier | StepEffect::Halt => {}
        }

        if let Some(d) = dst {
            // zero registers are always ready
            if d != 31 && d != 63 {
                self.reg_ready[d] = complete;
            }
        }
        // in-order retire
        self.last_retire = self.last_retire.max(complete);
        self.rob.push_back(self.last_retire);
    }

    fn finish(&mut self, stats: &mut CoreStats) {
        // drain
        stats.cycles += self.last_retire.max(self.fetch_cycle);
    }
}

/// Out-of-order core.
pub struct DetailedCpu {
    state: ArchState,
    stats: CoreStats,
    pipeline: Lookahead,
    policy: DetailedPolicy,
}

impl DetailedCpu {
    pub fn new(mythread: u32, numthreads: u32) -> Self {
        Self::with_cfg(mythread, numthreads, DetailedCfg::default())
    }

    pub fn with_cfg(mythread: u32, numthreads: u32, cfg: DetailedCfg) -> Self {
        Self {
            state: ArchState::new(mythread, numthreads),
            stats: CoreStats::default(),
            pipeline: Lookahead::new(),
            policy: DetailedPolicy {
                cfg,
                lat: LatencyModel::default(),
                core: mythread as usize,
                predictor: Vec::new(),
                reg_ready: [0; VREGS],
                fu_free: Default::default(),
                rob: VecDeque::with_capacity(cfg.rob),
                fetch_cycle: 0,
                fetched_in_cycle: 0,
                last_retire: 0,
            },
        }
    }
}

impl Cpu for DetailedCpu {
    fn run(
        &mut self,
        prog: &Program,
        mem: &mut MemSystem,
        shared: &mut SharedLevel,
        max_insts: u64,
    ) -> StopReason {
        run_pipeline(
            &mut self.state,
            &mut self.stats,
            &mut self.pipeline,
            &mut self.policy,
            prog,
            mem,
            shared,
            max_insts,
        )
    }

    fn state(&self) -> &ArchState {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ArchState {
        &mut self.state
    }

    fn stats(&self) -> &CoreStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CoreStats {
        &mut self.stats
    }

    fn lookahead(&self) -> &Lookahead {
        &self.pipeline
    }

    fn lookahead_mut(&mut self) -> &mut Lookahead {
        &mut self.pipeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::HierLatency;
    use crate::isa::{Cond, IntOp};

    fn shared1() -> SharedLevel {
        SharedLevel::new(1, HierLatency::default())
    }

    fn run_cycles(prog: &Program) -> (u64, u64) {
        let mut cpu = DetailedCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        cpu.run(prog, &mut mem, &mut shared1(), u64::MAX);
        (cpu.stats().cycles, cpu.stats().instructions)
    }

    #[test]
    fn independent_ops_run_superscalar() {
        // 8 independent adds should take far fewer cycles than 8 serial.
        let indep = Program::new(
            "indep",
            (0..8)
                .map(|k| Inst::Opi { op: IntOp::Add, rd: k as u8, ra: 31, imm: k })
                .chain([Inst::Halt])
                .collect(),
        );
        let serial = Program::new(
            "serial",
            (0..8)
                .map(|_| Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: 1 })
                .chain([Inst::Halt])
                .collect(),
        );
        let (ci, _) = run_cycles(&indep);
        let (cs, _) = run_cycles(&serial);
        assert!(ci < cs, "independent {ci} should beat serial {cs}");
    }

    #[test]
    fn predictable_loop_has_high_ipc() {
        let prog = Program::new(
            "loop",
            vec![
                Inst::Ldi { rd: 1, imm: 1000 },
                Inst::Opi { op: IntOp::Add, rd: 2, ra: 2, imm: 3 }, // 1
                Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 1, target: 1 },
                Inst::Halt,
            ],
        );
        let (c, i) = run_cycles(&prog);
        let ipc = i as f64 / c as f64;
        assert!(ipc > 1.2, "OoO core should exceed 1 IPC here, got {ipc:.2}");
    }

    #[test]
    fn detailed_is_faster_than_timing_on_ilp_code() {
        use crate::cpu::{Cpu, TimingCpu};
        let prog = Program::new(
            "ilp",
            (0..64)
                .map(|k| Inst::Opi { op: IntOp::Add, rd: (k % 8) as u8, ra: 31, imm: k })
                .chain([Inst::Halt])
                .collect(),
        );
        let mut t = TimingCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        t.run(&prog, &mut mem, &mut shared1(), u64::MAX);
        let (d, _) = run_cycles(&prog);
        assert!(d < t.stats().cycles);
    }

    #[test]
    fn single_pgas_unit_serializes_increment_bursts() {
        // one coprocessor unit per core (the prototype): a burst of
        // independent increments is throughput-bound at 1/cycle, while
        // the same number of independent ALU adds spreads over 4 ALUs.
        use crate::sptr::{pack, SharedPtr};
        let incs: Vec<Inst> = (0..16)
            .map(|k| Inst::PgasIncI { rd: k as u8 % 8, ra: 8 + (k as u8 % 8), l2es: 2, l2bs: 2, l2inc: 0 })
            .chain([Inst::Halt])
            .collect();
        let adds: Vec<Inst> = (0..16)
            .map(|k| Inst::Opi { op: IntOp::Add, rd: k as u8 % 8, ra: 8 + (k as u8 % 8), imm: 4 })
            .chain([Inst::Halt])
            .collect();
        let mut p = Program::new("incs", incs);
        // seed pointer registers so increments are architecturally valid
        let seed = pack(&SharedPtr::NULL) as i64;
        for r in 8..16 {
            p.insts.insert(0, Inst::Ldi { rd: r, imm: seed });
        }
        let (ci, _) = run_cycles(&p);
        let (ca, _) = run_cycles(&Program::new("adds", adds));
        assert!(ci > ca, "single pgas unit {ci} vs 4 ALUs {ca}");
    }

    #[test]
    fn batched_increment_window_is_cycle_exact_vs_scalar() {
        use crate::sptr::{pack, ArrayLayout, SharedPtr};
        let layout = ArrayLayout::new(4, 8, 4);
        // independent bumps + loop bookkeeping: the OoO scheduler sees
        // the same event sequence batched or scalar
        let prog = Program::new(
            "bump",
            vec![
                Inst::Ldi { rd: 4, imm: 20 },
                Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 }, // 1
                Inst::PgasIncI { rd: 2, ra: 2, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::PgasIncI { rd: 3, ra: 3, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::Opi { op: IntOp::Add, rd: 4, ra: 4, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 4, target: 1 },
                Inst::Halt,
            ],
        );
        let run = |lookahead: bool| {
            let mut cpu = DetailedCpu::new(0, 4);
            cpu.lookahead_mut().set_enabled(lookahead);
            cpu.state_mut().set_r(1, pack(&SharedPtr::for_index(&layout, 0, 0)));
            cpu.state_mut().set_r(2, pack(&SharedPtr::for_index(&layout, 0, 7)));
            cpu.state_mut().set_r(3, pack(&SharedPtr::for_index(&layout, 64, 2)));
            let mut mem = MemSystem::new(4);
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX);
            (cpu.stats().cycles, cpu.engine_mix().batched_incs)
        };
        let (batched_cycles, batched) = run(true);
        let (scalar_cycles, none) = run(false);
        assert_eq!(batched_cycles, scalar_cycles, "event replay is exact");
        assert!(batched >= 60, "every trip's window batched: {batched}");
        assert_eq!(none, 0);
    }
}
