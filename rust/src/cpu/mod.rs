//! CPU models — the three Gem5 models the paper evaluates with:
//!
//! * [`atomic`]   — 1 instruction per cycle, no memory timing: the model
//!   behind Figures 6–10. The HW-vs-software gap here is purely dynamic
//!   instruction count, exactly as in Gem5's atomic CPU.
//! * [`timing`]   — in-order issue plus cache-hierarchy and DRAM
//!   latencies (Figures 11–14 "timing" series).
//! * [`detailed`] — an out-of-order 7-stage-class core modeled with a
//!   dependency/functional-unit scheduler over a ROB window (Figures
//!   11–14 "detailed"/O3 series).
//!
//! All three share one *functional* executor ([`exec`]) and one
//! fetch/decode/dispatch loop ([`pipeline`]) so architectural results
//! are identical across models; each model is only an
//! [`IssuePolicy`](pipeline::IssuePolicy) — how many cycles one
//! dynamic instruction costs.  The pipeline's `Lookahead` batches
//! straight-line runs of PGAS increments through one `AddressEngine`
//! call in *every* model, replaying per-instruction timing events so
//! cycle totals match scalar stepping exactly.

pub mod atomic;
pub mod detailed;
pub mod exec;
pub mod pipeline;
pub mod timing;

pub use atomic::AtomicCpu;
pub use detailed::{DetailedCfg, DetailedCpu};
pub use exec::{ArchState, StepEffect};
pub use pipeline::{EngineMix, Lookahead};
pub use timing::{HierLatency, TimingCpu};

use crate::cache::{CacheCfg, Directory, SetAssocCache};
use crate::isa::Program;
use crate::mem::{MemSystem, Tlb};

/// Which CPU model to simulate (CLI / config selectable).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CpuModel {
    Atomic,
    Timing,
    Detailed,
}

impl CpuModel {
    pub const ALL: [CpuModel; 3] =
        [CpuModel::Atomic, CpuModel::Timing, CpuModel::Detailed];

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "atomic" => Some(CpuModel::Atomic),
            "timing" => Some(CpuModel::Timing),
            "detailed" | "o3" => Some(CpuModel::Detailed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CpuModel::Atomic => "atomic",
            CpuModel::Timing => "timing",
            CpuModel::Detailed => "detailed",
        }
    }
}

impl std::fmt::Display for CpuModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Why a core stopped running its quantum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Hit a `barrier` instruction (consumed; core must rendezvous).
    Barrier,
    /// Executed `halt`.
    Halted,
    /// Ran out of quantum budget.
    QuantumExpired,
}

/// Per-core execution statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoreStats {
    pub instructions: u64,
    pub cycles: u64,
    pub mem_reads: u64,
    pub mem_writes: u64,
    pub pgas_incs: u64,
    pub pgas_mems: u64,
    pub local_shared_accesses: u64,
    pub remote_shared_accesses: u64,
    pub branches: u64,
    pub barriers: u64,
}

impl CoreStats {
    pub fn merge(&mut self, o: &CoreStats) {
        self.instructions += o.instructions;
        self.cycles += o.cycles;
        self.mem_reads += o.mem_reads;
        self.mem_writes += o.mem_writes;
        self.pgas_incs += o.pgas_incs;
        self.pgas_mems += o.pgas_mems;
        self.local_shared_accesses += o.local_shared_accesses;
        self.remote_shared_accesses += o.remote_shared_accesses;
        self.branches += o.branches;
        self.barriers += o.barriers;
    }

    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instructions as f64 / self.cycles as f64
        }
    }
}

/// The memory-hierarchy level shared by all cores: per-core L1s (placed
/// here so the directory can invalidate across cores), the single shared
/// L2, the MESI-lite directory, TLBs, and the per-quantum bus counters
/// the machine-level contention model reads.
pub struct SharedLevel {
    pub l1d: Vec<SetAssocCache>,
    pub l1i: Vec<SetAssocCache>,
    pub tlb: Vec<Tlb>,
    pub l2: SetAssocCache,
    pub dir: Directory,
    pub lat: HierLatency,
    /// L2/bus transactions issued by each core in the current quantum.
    pub quantum_l2: Vec<u64>,
}

impl SharedLevel {
    pub fn new(cores: usize, lat: HierLatency) -> Self {
        Self {
            l1d: (0..cores).map(|_| SetAssocCache::new(CacheCfg::l1_32k())).collect(),
            l1i: (0..cores).map(|_| SetAssocCache::new(CacheCfg::l1_32k())).collect(),
            tlb: (0..cores).map(|_| Tlb::alpha_dtb()).collect(),
            l2: SetAssocCache::new(CacheCfg::l2_4m()),
            dir: Directory::default(),
            lat,
            quantum_l2: vec![0; cores],
        }
    }

    /// Data access by `core`; returns the hierarchy latency in cycles.
    /// Handles directory coherence (a write invalidates other L1 copies).
    pub fn access(&mut self, core: usize, sysva: u64, write: bool) -> u64 {
        let line = sysva & !(self.lat.line - 1);
        let mut cycles = 0;
        if !self.tlb[core].access(sysva) {
            cycles += self.lat.tlb_miss;
        }
        if write {
            let victims = self.dir.on_write(line, core);
            let mut v = victims;
            while v != 0 {
                let c = v.trailing_zeros() as usize;
                self.l1d[c].invalidate(line);
                v &= v - 1;
            }
        } else {
            self.dir.on_read(line, core);
        }
        if self.l1d[core].access(line) {
            cycles + self.lat.l1
        } else {
            self.quantum_l2[core] += 1;
            if self.l2.access(line) {
                cycles + self.lat.l1 + self.lat.l2
            } else {
                cycles + self.lat.l1 + self.lat.l2 + self.lat.mem
            }
        }
    }

    /// Instruction fetch of the line holding `pc_addr`.
    pub fn fetch(&mut self, core: usize, pc_addr: u64) -> u64 {
        let line = pc_addr & !(self.lat.line - 1);
        if self.l1i[core].access(line) {
            0 // overlapped with decode on a hit
        } else if self.l2.access(line) {
            self.quantum_l2[core] += 1;
            self.lat.l2
        } else {
            self.quantum_l2[core] += 1;
            self.lat.l2 + self.lat.mem
        }
    }

    /// Take and reset the per-quantum bus counters.
    pub fn drain_quantum(&mut self) -> Vec<u64> {
        let out = self.quantum_l2.clone();
        self.quantum_l2.iter_mut().for_each(|c| *c = 0);
        out
    }
}

/// The common interface of the three CPU models: run until barrier, halt
/// or quantum expiry; report cycles consumed via `stats().cycles`.
pub trait Cpu {
    /// Run up to `max_insts` dynamic instructions.
    fn run(
        &mut self,
        prog: &Program,
        mem: &mut MemSystem,
        shared: &mut SharedLevel,
        max_insts: u64,
    ) -> StopReason;

    fn state(&self) -> &ArchState;
    fn state_mut(&mut self) -> &mut ArchState;
    fn stats(&self) -> &CoreStats;
    fn stats_mut(&mut self) -> &mut CoreStats;

    /// The core's lookahead front end (batching knob + engine-mix
    /// telemetry) — every model runs on the shared pipeline.
    fn lookahead(&self) -> &Lookahead;
    fn lookahead_mut(&mut self) -> &mut Lookahead;

    /// How this core's dynamic PGAS increments were served so far.
    fn engine_mix(&self) -> EngineMix {
        self.lookahead().mix()
    }

    /// This core's health/degradation telemetry (selector dispatches,
    /// fallback runs, deadline misses, injected faults, breaker state).
    fn health(&self) -> crate::engine::HealthStats {
        self.lookahead().health()
    }

    /// This core's inspector/executor gather telemetry (plans,
    /// bucketed pointers, direct-serve fallbacks).
    fn gather(&self) -> crate::engine::GatherStats {
        self.lookahead().gather()
    }

    /// This core's vectorized-tier telemetry (batches served by the
    /// lane kernels, lane vs scalar-tail pointers).
    fn simd(&self) -> crate::engine::SimdStats {
        self.lookahead().simd()
    }

    /// This core's batch-planner telemetry (plans built, tiles
    /// dispatched, planned pointers, single-tile fallbacks).
    fn plan(&self) -> crate::engine::PlanStats {
        self.lookahead().plan()
    }

    /// Account `extra` stall cycles imposed from outside (bus contention
    /// computed by the machine-level contention model).
    fn add_stall_cycles(&mut self, extra: u64) {
        self.stats_mut().cycles += extra;
    }
}
