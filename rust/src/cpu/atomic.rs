//! The `atomic` CPU model: one instruction per cycle, no memory timing —
//! Gem5's AtomicSimpleCPU, the model behind Figures 6–10.
//!
//! With this model the HW-support speedup is exactly the dynamic
//! instruction-count ratio: a software Algorithm-1 expansion of ~25–45
//! ops against one `pgas_inc`, and a 3–4 op software translation against
//! one `pgas_ld`/`pgas_st`.
//!
//! Straight-line runs of independent PGAS increments (the pointer-bump
//! bursts every compiled `upc_forall` loop body emits) are served
//! through the batched [`replay_pgas_incs`] entry point — one
//! `AddressEngine` call per run instead of one scalar `increment_pow2`
//! per instruction — with identical architectural results and identical
//! 1-cycle-per-instruction accounting.

use super::{ArchState, CoreStats, Cpu, SharedLevel, StopReason};
use crate::cpu::exec::{pgas_inc_run_len, replay_pgas_incs, step, StepEffect};
use crate::engine::{Pow2Engine, PtrBatch};
use crate::isa::Program;
use crate::mem::MemSystem;
use crate::sptr::SharedPtr;

/// 1-IPC core.
pub struct AtomicCpu {
    state: ArchState,
    stats: CoreStats,
    /// Backend + reusable buffers for the batched increment replay (the
    /// instruction geometry is pow2 by construction, so the shift/mask
    /// engine is always legal).
    inc_engine: Pow2Engine,
    inc_batch: PtrBatch,
    inc_out: Vec<SharedPtr>,
    /// Latched false on the first replay refusal (base LUT covering
    /// fewer threads than the `threads` register).  Treated as
    /// permanent for simplicity: a program that later shrinks
    /// `threads_reg` via `PgasSetThreads` could make replay legal
    /// again, but it just stays on the (always-correct) serial path.
    inc_replay: bool,
}

impl AtomicCpu {
    pub fn new(mythread: u32, numthreads: u32) -> Self {
        Self {
            state: ArchState::new(mythread, numthreads),
            stats: CoreStats::default(),
            inc_engine: Pow2Engine,
            inc_batch: PtrBatch::new(),
            inc_out: Vec::new(),
            inc_replay: true,
        }
    }
}

impl Cpu for AtomicCpu {
    fn run(
        &mut self,
        prog: &Program,
        mem: &mut MemSystem,
        _shared: &mut SharedLevel,
        max_insts: u64,
    ) -> StopReason {
        let mut budget = max_insts;
        while budget > 0 {
            if self.state.halted {
                return StopReason::Halted;
            }
            // ---- batched replay path: a run of independent PGAS
            // increments is served by one AddressEngine call instead
            // of N scalar increments (the ROADMAP "simulator-side
            // batching" seam; architecturally identical, same 1-IPC
            // accounting)
            if self.inc_replay {
                let run =
                    (pgas_inc_run_len(&prog.insts, self.state.pc as usize)
                        as u64)
                        .min(budget) as usize;
                if run >= 2 {
                    match replay_pgas_incs(
                        &mut self.state,
                        mem,
                        &prog.insts,
                        run,
                        &self.inc_engine,
                        &mut self.inc_batch,
                        &mut self.inc_out,
                    ) {
                        Ok(()) => {
                            let k = run as u64;
                            self.stats.instructions += k;
                            self.stats.cycles += k;
                            self.stats.pgas_incs += k;
                            budget -= k;
                            continue;
                        }
                        // persistent refusal: fall back to serial
                        // stepping for the rest of this machine's life
                        Err(_) => self.inc_replay = false,
                    }
                }
            }
            let inst = prog.insts[self.state.pc as usize];
            let effect = step(&mut self.state, mem, &inst);
            self.stats.instructions += 1;
            self.stats.cycles += 1;
            budget -= 1;
            match effect {
                StepEffect::Mem { write, shared, local, .. } => {
                    if write {
                        self.stats.mem_writes += 1;
                    } else {
                        self.stats.mem_reads += 1;
                    }
                    if shared {
                        if inst.is_pgas() {
                            self.stats.pgas_mems += 1;
                        }
                        if local {
                            self.stats.local_shared_accesses += 1;
                        } else {
                            self.stats.remote_shared_accesses += 1;
                        }
                    }
                }
                StepEffect::Branch { .. } => self.stats.branches += 1,
                StepEffect::Barrier => {
                    self.stats.barriers += 1;
                    return StopReason::Barrier;
                }
                StepEffect::Halt => return StopReason::Halted,
                StepEffect::Normal => {
                    if matches!(
                        inst,
                        crate::isa::Inst::PgasIncI { .. } | crate::isa::Inst::PgasIncR { .. }
                    ) {
                        self.stats.pgas_incs += 1;
                    }
                }
            }
        }
        StopReason::QuantumExpired
    }

    fn state(&self) -> &ArchState {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ArchState {
        &mut self.state
    }

    fn stats(&self) -> &CoreStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CoreStats {
        &mut self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::HierLatency;
    use crate::isa::{Cond, Inst, IntOp};

    fn shared1() -> SharedLevel {
        SharedLevel::new(1, HierLatency::default())
    }

    #[test]
    fn one_cycle_per_instruction() {
        let prog = Program::new(
            "loop10",
            vec![
                Inst::Ldi { rd: 1, imm: 10 },
                Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 }, // 1
                Inst::Br { cond: Cond::Gt, ra: 1, target: 1 },
                Inst::Halt,
            ],
        );
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        let r = cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX);
        assert_eq!(r, StopReason::Halted);
        // 1 ldi + 10*(add+br) + halt = 22 dynamic instructions
        assert_eq!(cpu.stats().instructions, 22);
        assert_eq!(cpu.stats().cycles, 22);
        assert!((cpu.stats().ipc() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stops_at_barrier_and_resumes() {
        let prog = Program::new(
            "bar",
            vec![Inst::Nop, Inst::Barrier, Inst::Nop, Inst::Halt],
        );
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX),
            StopReason::Barrier
        );
        assert_eq!(cpu.state().pc, 2, "pc advanced past the barrier");
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX),
            StopReason::Halted
        );
    }

    #[test]
    fn increment_bursts_replay_batched_with_identical_results() {
        use crate::cpu::exec::step;
        use crate::sptr::{pack, ArrayLayout, SharedPtr};
        // a vecadd-style body: 3 independent pointer bumps per trip
        let layout = ArrayLayout::new(4, 8, 4);
        let prog = Program::new(
            "bump",
            vec![
                Inst::Ldi { rd: 4, imm: 10 }, // trip counter
                // loop: three self-increments (one batchable run)
                Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 }, // 1
                Inst::PgasIncI { rd: 2, ra: 2, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::PgasIncI { rd: 3, ra: 3, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::Opi { op: IntOp::Add, rd: 4, ra: 4, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 4, target: 1 },
                Inst::Halt,
            ],
        );
        let seed = |st: &mut crate::cpu::ArchState| {
            st.set_r(1, pack(&SharedPtr::for_index(&layout, 0, 0)));
            st.set_r(2, pack(&SharedPtr::for_index(&layout, 0, 7)));
            st.set_r(3, pack(&SharedPtr::for_index(&layout, 64, 2)));
        };
        // atomic model (batched replay inside)
        let mut cpu = AtomicCpu::new(1, 4);
        seed(&mut cpu.state);
        let mut mem = MemSystem::new(4);
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX),
            StopReason::Halted
        );
        // pure serial reference via step()
        let mut serial = crate::cpu::ArchState::new(1, 4);
        seed(&mut serial);
        let mut insts = 0u64;
        while !serial.halted {
            let inst = prog.insts[serial.pc as usize];
            step(&mut serial, &mut mem, &inst);
            insts += 1;
        }
        for r in 0..8 {
            assert_eq!(cpu.state().r(r), serial.r(r), "register r{r}");
        }
        assert_eq!(cpu.state().cc_loc, serial.cc_loc);
        // identical 1-IPC accounting: same dynamic instruction count
        assert_eq!(cpu.stats().instructions, insts);
        assert_eq!(cpu.stats().cycles, insts);
        assert_eq!(cpu.stats().pgas_incs, 30);
    }

    #[test]
    fn quantum_expiry() {
        let prog = Program::new("spin", vec![Inst::Jmp { target: 0 }]);
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), 100),
            StopReason::QuantumExpired
        );
        assert_eq!(cpu.stats().instructions, 100);
    }
}
