//! The `atomic` CPU model: one instruction per cycle, no memory timing —
//! Gem5's AtomicSimpleCPU, the model behind Figures 6–10.
//!
//! With this model the HW-support speedup is exactly the dynamic
//! instruction-count ratio: a software Algorithm-1 expansion of ~25–45
//! ops against one `pgas_inc`, and a 3–4 op software translation against
//! one `pgas_ld`/`pgas_st`.

use super::{ArchState, CoreStats, Cpu, SharedLevel, StopReason};
use crate::cpu::exec::{step, StepEffect};
use crate::isa::Program;
use crate::mem::MemSystem;

/// 1-IPC core.
pub struct AtomicCpu {
    state: ArchState,
    stats: CoreStats,
}

impl AtomicCpu {
    pub fn new(mythread: u32, numthreads: u32) -> Self {
        Self {
            state: ArchState::new(mythread, numthreads),
            stats: CoreStats::default(),
        }
    }
}

impl Cpu for AtomicCpu {
    fn run(
        &mut self,
        prog: &Program,
        mem: &mut MemSystem,
        _shared: &mut SharedLevel,
        max_insts: u64,
    ) -> StopReason {
        let mut budget = max_insts;
        while budget > 0 {
            if self.state.halted {
                return StopReason::Halted;
            }
            let inst = prog.insts[self.state.pc as usize];
            let effect = step(&mut self.state, mem, &inst);
            self.stats.instructions += 1;
            self.stats.cycles += 1;
            budget -= 1;
            match effect {
                StepEffect::Mem { write, shared, local, .. } => {
                    if write {
                        self.stats.mem_writes += 1;
                    } else {
                        self.stats.mem_reads += 1;
                    }
                    if shared {
                        if inst.is_pgas() {
                            self.stats.pgas_mems += 1;
                        }
                        if local {
                            self.stats.local_shared_accesses += 1;
                        } else {
                            self.stats.remote_shared_accesses += 1;
                        }
                    }
                }
                StepEffect::Branch { .. } => self.stats.branches += 1,
                StepEffect::Barrier => {
                    self.stats.barriers += 1;
                    return StopReason::Barrier;
                }
                StepEffect::Halt => return StopReason::Halted,
                StepEffect::Normal => {
                    if matches!(
                        inst,
                        crate::isa::Inst::PgasIncI { .. } | crate::isa::Inst::PgasIncR { .. }
                    ) {
                        self.stats.pgas_incs += 1;
                    }
                }
            }
        }
        StopReason::QuantumExpired
    }

    fn state(&self) -> &ArchState {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ArchState {
        &mut self.state
    }

    fn stats(&self) -> &CoreStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CoreStats {
        &mut self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::HierLatency;
    use crate::isa::{Cond, Inst, IntOp};

    fn shared1() -> SharedLevel {
        SharedLevel::new(1, HierLatency::default())
    }

    #[test]
    fn one_cycle_per_instruction() {
        let prog = Program::new(
            "loop10",
            vec![
                Inst::Ldi { rd: 1, imm: 10 },
                Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 }, // 1
                Inst::Br { cond: Cond::Gt, ra: 1, target: 1 },
                Inst::Halt,
            ],
        );
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        let r = cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX);
        assert_eq!(r, StopReason::Halted);
        // 1 ldi + 10*(add+br) + halt = 22 dynamic instructions
        assert_eq!(cpu.stats().instructions, 22);
        assert_eq!(cpu.stats().cycles, 22);
        assert!((cpu.stats().ipc() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stops_at_barrier_and_resumes() {
        let prog = Program::new(
            "bar",
            vec![Inst::Nop, Inst::Barrier, Inst::Nop, Inst::Halt],
        );
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX),
            StopReason::Barrier
        );
        assert_eq!(cpu.state().pc, 2, "pc advanced past the barrier");
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX),
            StopReason::Halted
        );
    }

    #[test]
    fn quantum_expiry() {
        let prog = Program::new("spin", vec![Inst::Jmp { target: 0 }]);
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), 100),
            StopReason::QuantumExpired
        );
        assert_eq!(cpu.stats().instructions, 100);
    }
}
