//! The `atomic` CPU model: one instruction per cycle, no memory timing —
//! Gem5's AtomicSimpleCPU, the model behind Figures 6–10.
//!
//! With this model the HW-support speedup is exactly the dynamic
//! instruction-count ratio: a software Algorithm-1 expansion of ~25–45
//! ops against one `pgas_inc`, and a 3–4 op software translation against
//! one `pgas_ld`/`pgas_st`.
//!
//! Execution runs on the shared pipeline core
//! ([`cpu::pipeline`](crate::cpu::pipeline)): straight-line windows of
//! independent PGAS increments are served by one batched
//! `AddressEngine` call and replayed event-by-event; this model's
//! entire issue policy is "every dynamic instruction costs one cycle".

use super::pipeline::{run_pipeline, IssuePolicy, Lookahead};
use super::{ArchState, CoreStats, Cpu, SharedLevel, StopReason};
use crate::cpu::exec::StepEffect;
use crate::isa::{Inst, Program};
use crate::mem::MemSystem;

/// The 1-IPC issue policy.
struct AtomicPolicy;

impl IssuePolicy for AtomicPolicy {
    fn issue(
        &mut self,
        _pc: u32,
        _inst: &Inst,
        _effect: StepEffect,
        _shared: &mut SharedLevel,
        stats: &mut CoreStats,
    ) {
        stats.cycles += 1;
    }
}

/// 1-IPC core.
pub struct AtomicCpu {
    state: ArchState,
    stats: CoreStats,
    pipeline: Lookahead,
    policy: AtomicPolicy,
}

impl AtomicCpu {
    pub fn new(mythread: u32, numthreads: u32) -> Self {
        Self {
            state: ArchState::new(mythread, numthreads),
            stats: CoreStats::default(),
            pipeline: Lookahead::new(),
            policy: AtomicPolicy,
        }
    }
}

impl Cpu for AtomicCpu {
    fn run(
        &mut self,
        prog: &Program,
        mem: &mut MemSystem,
        shared: &mut SharedLevel,
        max_insts: u64,
    ) -> StopReason {
        run_pipeline(
            &mut self.state,
            &mut self.stats,
            &mut self.pipeline,
            &mut self.policy,
            prog,
            mem,
            shared,
            max_insts,
        )
    }

    fn state(&self) -> &ArchState {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ArchState {
        &mut self.state
    }

    fn stats(&self) -> &CoreStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CoreStats {
        &mut self.stats
    }

    fn lookahead(&self) -> &Lookahead {
        &self.pipeline
    }

    fn lookahead_mut(&mut self) -> &mut Lookahead {
        &mut self.pipeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::HierLatency;
    use crate::isa::{Cond, Inst, IntOp};

    fn shared1() -> SharedLevel {
        SharedLevel::new(1, HierLatency::default())
    }

    #[test]
    fn one_cycle_per_instruction() {
        let prog = Program::new(
            "loop10",
            vec![
                Inst::Ldi { rd: 1, imm: 10 },
                Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 }, // 1
                Inst::Br { cond: Cond::Gt, ra: 1, target: 1 },
                Inst::Halt,
            ],
        );
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        let r = cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX);
        assert_eq!(r, StopReason::Halted);
        // 1 ldi + 10*(add+br) + halt = 22 dynamic instructions
        assert_eq!(cpu.stats().instructions, 22);
        assert_eq!(cpu.stats().cycles, 22);
        assert!((cpu.stats().ipc() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stops_at_barrier_and_resumes() {
        let prog = Program::new(
            "bar",
            vec![Inst::Nop, Inst::Barrier, Inst::Nop, Inst::Halt],
        );
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX),
            StopReason::Barrier
        );
        assert_eq!(cpu.state().pc, 2, "pc advanced past the barrier");
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX),
            StopReason::Halted
        );
    }

    #[test]
    fn increment_bursts_replay_batched_with_identical_results() {
        use crate::cpu::exec::step;
        use crate::sptr::{pack, ArrayLayout, SharedPtr};
        // a vecadd-style body: 3 independent pointer bumps per trip
        let layout = ArrayLayout::new(4, 8, 4);
        let prog = Program::new(
            "bump",
            vec![
                Inst::Ldi { rd: 4, imm: 10 }, // trip counter
                // loop: three self-increments (one batchable run)
                Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 }, // 1
                Inst::PgasIncI { rd: 2, ra: 2, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::PgasIncI { rd: 3, ra: 3, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::Opi { op: IntOp::Add, rd: 4, ra: 4, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 4, target: 1 },
                Inst::Halt,
            ],
        );
        let seed = |st: &mut crate::cpu::ArchState| {
            st.set_r(1, pack(&SharedPtr::for_index(&layout, 0, 0)));
            st.set_r(2, pack(&SharedPtr::for_index(&layout, 0, 7)));
            st.set_r(3, pack(&SharedPtr::for_index(&layout, 64, 2)));
        };
        // atomic model (batched replay inside)
        let mut cpu = AtomicCpu::new(1, 4);
        seed(&mut cpu.state);
        let mut mem = MemSystem::new(4);
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX),
            StopReason::Halted
        );
        // pure serial reference via step()
        let mut serial = crate::cpu::ArchState::new(1, 4);
        seed(&mut serial);
        let mut insts = 0u64;
        while !serial.halted {
            let inst = prog.insts[serial.pc as usize];
            step(&mut serial, &mut mem, &inst);
            insts += 1;
        }
        for r in 0..8 {
            assert_eq!(cpu.state().r(r), serial.r(r), "register r{r}");
        }
        assert_eq!(cpu.state().cc_loc, serial.cc_loc);
        // identical 1-IPC accounting: same dynamic instruction count
        assert_eq!(cpu.stats().instructions, insts);
        assert_eq!(cpu.stats().cycles, insts);
        assert_eq!(cpu.stats().pgas_incs, 30);
        // telemetry: the lookahead window spans the whole loop body
        // (incs + bookkeeping), so every increment was served batched
        let mix = cpu.engine_mix();
        assert_eq!(mix.batched_incs, 30);
        assert_eq!(mix.scalar_incs, 0);
        assert_eq!(mix.total_runs(), 10);
    }

    #[test]
    fn quantum_expiry() {
        let prog = Program::new("spin", vec![Inst::Jmp { target: 0 }]);
        let mut cpu = AtomicCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        assert_eq!(
            cpu.run(&prog, &mut mem, &mut shared1(), 100),
            StopReason::QuantumExpired
        );
        assert_eq!(cpu.stats().instructions, 100);
    }
}
