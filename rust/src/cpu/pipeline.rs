//! The shared CPU pipeline core: one fetch/decode/dispatch loop for all
//! three models, with a [`Lookahead`] window that batches straight-line
//! runs of PGAS increments through one
//! [`AddressEngine`](crate::engine::AddressEngine) call and then
//! *replays the per-instruction timing events* against the batch
//! results.
//!
//! The split of responsibilities after this refactor:
//!
//! * [`exec::step`](crate::cpu::exec::step) — pure architectural
//!   execution: one instruction, no cycle accounting;
//! * [`IssuePolicy`] — each model's issue/latency policy: how many
//!   cycles one dynamic instruction costs, given its pc, decoded form
//!   and architectural [`StepEffect`] (the atomic model charges 1, the
//!   timing model fetch + latency-class + hierarchy time, the detailed
//!   model runs its OoO scheduler);
//! * [`run_pipeline`] — the loop all three models share: lookahead →
//!   batched increment serve → per-instruction event replay, or scalar
//!   step; plus the per-effect statistics bookkeeping that used to be
//!   triplicated across the models.
//!
//! ## Why batching does not change cycle totals
//!
//! The batched path issues exactly the same `(pc, inst, effect)` event
//! sequence to the policy that scalar stepping would, in the same
//! order, against the same shared-hierarchy state.  Every model's cycle
//! accounting is a deterministic function of that sequence, so cycle
//! totals are **bit-identical** whether a run was served batched or
//! scalar — in all three models, not just atomic.  The differential
//! suite (`tests/cpu_pipeline.rs`) pins this across the five NPB
//! kernels; what batching buys is host-side throughput (one engine
//! call per run instead of one scalar `increment_pow2` per
//! instruction), exactly the leverage the ROADMAP's "lookahead design
//! that preserves per-instruction accounting" asked for.
//!
//! ## The window planner
//!
//! [`plan_window`] is the single definition of run eligibility (it
//! replaces the `pgas_inc_run_len` heuristic that the atomic model
//! used to wrap ad hoc).  A window starts at a PGAS increment and
//! extends over:
//!
//! * further `PgasIncI`/`PgasIncR` sharing the first increment's
//!   `(l2es, l2bs)` geometry whose source registers were not written
//!   earlier in the window — the batch reads *pre-window* register
//!   state, so a dependent increment must end the window;
//! * interleaved *neutral* ops (register-only ALU/FP work: `Opi`,
//!   `Opr`, `Ldi`, `Fop`, `FCmpLt`, `CvtIF`, `CvtFI`, `Nop`) — these
//!   are executed scalar, in program order, during event replay, so
//!   they may freely **read** earlier results (including an earlier
//!   increment's destination); their integer destinations are tracked
//!   so no later increment reads a value the batch would miss.
//!
//! Anything else — memory ops, branches, barriers, `PgasSetThreads`
//! and friends — ends the window.  Trailing neutral ops after the last
//! increment are trimmed (there is nothing to batch past it), and a
//! window must contain at least [`MIN_RUN_INCS`] increments to be
//! worth an engine dispatch.

use crate::cpu::exec::{step, StepEffect};
use crate::cpu::{ArchState, CoreStats, SharedLevel, StopReason};
use crate::engine::{EngineChoice, EngineCtx, EngineError, EngineSelector, PtrBatch};
use crate::isa::{Inst, Program, ZERO};
use crate::mem::MemSystem;
use crate::sptr::{self, pack, unpack, ArrayLayout, SharedPtr};

/// Minimum increments in a window worth one batched engine dispatch.
pub const MIN_RUN_INCS: usize = 2;

/// The `(l2es, l2bs)` geometry of a PGAS increment, `None` for any
/// other instruction.
#[inline]
fn inc_geometry(inst: &Inst) -> Option<(u8, u8)> {
    match *inst {
        Inst::PgasIncI { l2es, l2bs, .. } | Inst::PgasIncR { l2es, l2bs, .. } => {
            Some((l2es, l2bs))
        }
        _ => None,
    }
}

/// If `inst` is a register-only op the window can carry along, the
/// integer register it writes (`Some(None)` for ops that write no
/// integer register, e.g. FP arithmetic); `None` if the op cannot ride
/// in a window at all.
#[inline]
fn neutral_dst(inst: &Inst) -> Option<Option<u8>> {
    match *inst {
        Inst::Opi { rd, .. }
        | Inst::Opr { rd, .. }
        | Inst::Ldi { rd, .. }
        | Inst::FCmpLt { rd, .. }
        | Inst::CvtFI { rd, .. } => Some(Some(rd)),
        Inst::Fop { .. } | Inst::CvtIF { .. } | Inst::Nop => Some(None),
        _ => None,
    }
}

/// A batchable window found by [`plan_window`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowPlan {
    /// Total instructions in the window (it always ends at its last
    /// increment — trailing neutral ops are trimmed).
    pub len: usize,
    /// How many of them are PGAS increments (≥ [`MIN_RUN_INCS`]).
    pub incs: usize,
}

/// Find the maximal batchable window starting at `pc`, scanning at
/// most `max_len` instructions ahead (the caller bounds this by the
/// lookahead depth *and* the remaining quantum budget).  Returns
/// `None` when the instruction at `pc` is not a PGAS increment or the
/// window would contain fewer than [`MIN_RUN_INCS`] increments.
///
/// This is the one definition of run eligibility; the invariant the
/// property suite checks is that **no increment in a returned window
/// reads a register written by an earlier window member** — that is
/// what makes serving all increments from pre-window state legal.
pub fn plan_window(insts: &[Inst], pc: usize, max_len: usize) -> Option<WindowPlan> {
    let first = insts.get(pc).and_then(inc_geometry)?;
    let end = insts.len().min(pc.saturating_add(max_len));
    let mut written = [false; 32];
    let mut len = 0usize; // instructions scanned into the window so far
    let mut incs = 0usize;
    let mut last = 0usize; // window length as of the last increment
    for inst in &insts[pc..end] {
        match inc_geometry(inst) {
            Some(g) if g == first => {
                let (rd, ra, rb) = match *inst {
                    Inst::PgasIncI { rd, ra, .. } => (rd, ra, ZERO),
                    Inst::PgasIncR { rd, ra, rb, .. } => (rd, ra, rb),
                    _ => unreachable!("inc_geometry() only accepts PGAS increments"),
                };
                if written[ra as usize] || written[rb as usize] {
                    break; // dependent increment: batch would read stale state
                }
                if rd != ZERO {
                    written[rd as usize] = true;
                }
                len += 1;
                incs += 1;
                last = len;
            }
            Some(_) => break, // geometry change ends the run
            None => match neutral_dst(inst) {
                Some(dst) => {
                    if let Some(rd) = dst {
                        if rd != ZERO {
                            written[rd as usize] = true;
                        }
                    }
                    len += 1;
                }
                None => break, // memory / control / PGAS-state op
            },
        }
    }
    if incs < MIN_RUN_INCS {
        return None;
    }
    Some(WindowPlan { len: last, incs })
}

/// Per-core tallies of how dynamic PGAS increments were served —
/// threaded from each core's pipeline through
/// [`MachineResult`](crate::sim::MachineResult) into
/// [`npb::RunOutcome`](crate::npb::RunOutcome) and the coordinator's
/// engine-mix-vs-speedup report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineMix {
    /// Batched runs served, indexed by [`EngineChoice`] declaration
    /// order (`EngineChoice::ALL`).
    pub runs: [u64; EngineChoice::COUNT],
    /// Increments served through batched `AddressEngine` calls.
    pub batched_incs: u64,
    /// Increments executed scalar (no eligible window, or the pipeline
    /// latched off after an engine refusal).
    pub scalar_incs: u64,
}

impl EngineMix {
    pub fn merge(&mut self, o: &EngineMix) {
        for (a, b) in self.runs.iter_mut().zip(o.runs.iter()) {
            *a += b;
        }
        self.batched_incs += o.batched_incs;
        self.scalar_incs += o.scalar_incs;
    }

    /// Batched windows served, over all backends.
    pub fn total_runs(&self) -> u64 {
        self.runs.iter().sum()
    }

    /// Fraction of dynamic PGAS increments served batched (0 when the
    /// run executed none at all).
    pub fn batched_share(&self) -> f64 {
        let total = self.batched_incs + self.scalar_incs;
        if total == 0 {
            0.0
        } else {
            self.batched_incs as f64 / total as f64
        }
    }

    /// `(choice, batched runs)` per backend, in declaration order.
    pub fn by_choice(&self) -> [(EngineChoice, u64); EngineChoice::COUNT] {
        EngineChoice::ALL.map(|c| (c, self.runs[c.index()]))
    }

    /// Compact `pow2:12 software:3` rendering of the non-zero per-
    /// backend run counts (`-` when nothing was batched).
    pub fn runs_label(&self) -> String {
        let parts: Vec<String> = self
            .by_choice()
            .iter()
            .filter(|&&(_, n)| n > 0)
            .map(|&(c, n)| format!("{}:{n}", c.name()))
            .collect();
        if parts.is_empty() {
            "-".into()
        } else {
            parts.join(" ")
        }
    }
}

/// The lookahead front end every CPU model owns: window depth, the
/// batching engine (a per-core cost-based [`EngineSelector`]),
/// reusable request buffers, the enable knob
/// ([`MachineCfg::lookahead`](crate::sim::MachineCfg)) and the
/// [`EngineMix`] telemetry.
pub struct Lookahead {
    /// Configuration: batch at all?  (`MachineCfg::lookahead`; the
    /// scalar-reference legs of the differential suite turn this off.)
    enabled: bool,
    /// Latched false on the first engine refusal (e.g. a base LUT
    /// covering fewer threads than the `threads` register claims).
    /// Treated as permanent for simplicity: a program that later
    /// shrinks `threads_reg` via `PgasSetThreads` could make batching
    /// legal again, but it just stays on the always-correct scalar
    /// path.
    operable: bool,
    /// Maximum instructions scanned ahead per window.
    window: usize,
    /// Per-core selector, single-worker so the argmin is deterministic
    /// (no pool bookkeeping in the simulator hot loop).  The decoded
    /// geometry is pow2 by construction, so in practice this prices
    /// the shift/mask path cheapest; the per-[`EngineChoice`] tallies
    /// record whatever it actually picks.
    selector: EngineSelector,
    batch: PtrBatch,
    out: Vec<SharedPtr>,
    mix: EngineMix,
}

impl Lookahead {
    /// Default lookahead depth, in instructions.  Covers the pointer-
    /// bump bursts compiled `upc_forall` bodies emit with room for the
    /// loop-bookkeeping ALU ops interleaved between them.
    pub const DEFAULT_WINDOW: usize = 32;

    pub fn new() -> Self {
        Self {
            enabled: true,
            operable: true,
            window: Self::DEFAULT_WINDOW,
            selector: EngineSelector::new().with_shard_workers(1),
            batch: PtrBatch::new(),
            out: Vec::new(),
            mix: EngineMix::default(),
        }
    }

    /// Turn batching on/off (off = every instruction steps scalar; the
    /// differential suite's reference leg).
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The engine-mix telemetry accumulated so far.
    pub fn mix(&self) -> EngineMix {
        self.mix
    }

    /// Install the remote worker-process tier
    /// ([`RemoteTier`](crate::engine::RemoteTier)) into this core's
    /// selector: the pool is shared (`Arc`) across every core of a
    /// machine, and the tier's pricing decides when a window's batch
    /// actually takes the socket hop — with measured legs essentially
    /// never (a lookahead window is tiny), with forced service pricing
    /// every eligible window, which is how the engine-mix reports
    /// demonstrate the tier end to end.
    pub fn install_remote(&mut self, tier: &crate::engine::RemoteTier) {
        tier.apply(&mut self.selector);
    }

    /// Install a seeded engine-fault schedule (the `--chaos` flag)
    /// into this core's selector.  Injected backend errors and latency
    /// spikes are absorbed by the selector's health ladder — fallback
    /// re-serve, circuit breaker, cost-model deadline — so they never
    /// reach the pipeline; the `health()` counters prove they fired.
    pub fn install_chaos(&mut self, spec: crate::engine::FaultSpec) {
        self.selector.set_chaos(std::sync::Arc::new(
            crate::engine::FaultPlan::new(spec),
        ));
    }

    /// Health/degradation telemetry accumulated by this core's
    /// selector (dispatches, fallback runs, deadline misses, injected
    /// faults, per-tier breaker states).
    pub fn health(&self) -> crate::engine::HealthStats {
        self.selector.health_stats()
    }

    /// Inspector/executor gather telemetry accumulated by this core's
    /// selector (plans executed, pointers bucketed, eligible batches
    /// served direct) — the `gather.*` lines of `stats_txt`.
    pub fn gather(&self) -> crate::engine::GatherStats {
        self.selector.gather_stats()
    }

    /// Vectorized-tier telemetry accumulated by this core's selector
    /// (batches served by the lane kernels, lane vs scalar-tail
    /// pointers) — the `simd.*` lines of `stats_txt`.
    pub fn simd(&self) -> crate::engine::SimdStats {
        self.selector.simd_stats()
    }

    /// Cache-blocked batch-planner telemetry accumulated by this
    /// core's selector (plans built, tiles dispatched, planned
    /// pointers, single-tile fallbacks) — the `plan.*` lines of
    /// `stats_txt`.
    pub fn plan(&self) -> crate::engine::PlanStats {
        self.selector.plan_stats()
    }

    #[inline]
    fn active(&self) -> bool {
        self.enabled && self.operable
    }

    /// Serve the window's increments as one batched engine call, from
    /// pre-window register state.  On success `self.out[k]` holds the
    /// k-th increment's result (in program order) and the chosen
    /// backend is tallied; on failure state is untouched so the caller
    /// can fall back to scalar stepping.
    fn serve(
        &mut self,
        st: &ArchState,
        mem: &MemSystem,
        window: &[Inst],
    ) -> Result<(), EngineError> {
        let (l2es, l2bs) = window
            .iter()
            .find_map(inc_geometry)
            .expect("window holds at least MIN_RUN_INCS increments");
        let layout = ArrayLayout::new(1u64 << l2bs, 1u64 << l2es, st.threads_reg);
        let ctx =
            EngineCtx::new(layout, &mem.base_table, st.mythread)?.with_topology(st.topo);
        self.batch.clear();
        for inst in window {
            match *inst {
                Inst::PgasIncI { ra, l2inc, .. } => {
                    self.batch.push(unpack(st.r(ra)), 1u64 << l2inc)
                }
                Inst::PgasIncR { ra, rb, .. } => {
                    self.batch.push(unpack(st.r(ra)), st.r(rb))
                }
                _ => {} // neutral carry-along: executed scalar at replay
            }
        }
        let choice =
            self.selector.increment_choosing(&ctx, &self.batch, &mut self.out)?;
        self.mix.runs[choice.index()] += 1;
        self.mix.batched_incs += self.batch.len() as u64;
        Ok(())
    }
}

impl Default for Lookahead {
    fn default() -> Self {
        Self::new()
    }
}

/// A CPU model's issue/latency policy — everything that differs
/// between the atomic, timing and detailed models.  [`run_pipeline`]
/// drives it with one call per dynamic instruction, in program order,
/// whether that instruction executed scalar or was served from a
/// batched window.
pub trait IssuePolicy {
    /// Called once at the top of each quantum (reset per-quantum
    /// scheduler state; the OoO pipe drains at barriers and quantum
    /// boundaries).
    fn begin(&mut self, _prog: &Program) {}

    /// Account one dynamic instruction: `pc` is its address *before*
    /// execution, `effect` its architectural outcome.  Timing policies
    /// drive `shared` (instruction fetch, data-hierarchy access) from
    /// here — the pipeline core itself never touches the caches.
    fn issue(
        &mut self,
        pc: u32,
        inst: &Inst,
        effect: StepEffect,
        shared: &mut SharedLevel,
        stats: &mut CoreStats,
    );

    /// Called once when the quantum ends (pipeline drain).
    fn finish(&mut self, _stats: &mut CoreStats) {}
}

/// Per-effect statistics bookkeeping shared by all models (this used
/// to be triplicated across the three `Cpu::run` loops).
#[inline]
fn tally(stats: &mut CoreStats, inst: &Inst, effect: StepEffect) {
    match effect {
        StepEffect::Mem { write, shared, local, .. } => {
            if write {
                stats.mem_writes += 1;
            } else {
                stats.mem_reads += 1;
            }
            if shared {
                if inst.is_pgas() {
                    stats.pgas_mems += 1;
                }
                if local {
                    stats.local_shared_accesses += 1;
                } else {
                    stats.remote_shared_accesses += 1;
                }
            }
        }
        StepEffect::Branch { .. } => stats.branches += 1,
        StepEffect::Barrier => stats.barriers += 1,
        StepEffect::Halt => {}
        StepEffect::Normal => {
            if matches!(inst, Inst::PgasIncI { .. } | Inst::PgasIncR { .. }) {
                stats.pgas_incs += 1;
            }
        }
    }
}

/// The fetch/decode/dispatch loop all three CPU models share: run up
/// to `max_insts` dynamic instructions, batching eligible PGAS-
/// increment windows through the [`Lookahead`] and charging cycles via
/// the model's [`IssuePolicy`].
pub fn run_pipeline<P: IssuePolicy>(
    state: &mut ArchState,
    stats: &mut CoreStats,
    la: &mut Lookahead,
    policy: &mut P,
    prog: &Program,
    mem: &mut MemSystem,
    shared: &mut SharedLevel,
    max_insts: u64,
) -> StopReason {
    policy.begin(prog);
    let mut budget = max_insts;
    while budget > 0 {
        if state.halted {
            policy.finish(stats);
            return StopReason::Halted;
        }
        // ---- lookahead: batch a window of independent PGAS increments
        // through one AddressEngine call, then replay its events ----
        if la.active() {
            let max_len = la.window.min(budget.min(usize::MAX as u64) as usize);
            let pc0 = state.pc as usize;
            if let Some(plan) = plan_window(&prog.insts, pc0, max_len) {
                match la.serve(state, mem, &prog.insts[pc0..pc0 + plan.len]) {
                    Ok(()) => {
                        // Event replay: walk the window in program
                        // order, writing increment results back from
                        // the batch and stepping carried-along neutral
                        // ops scalar, issuing to the policy the exact
                        // per-instruction events scalar stepping would.
                        let mut out_idx = 0;
                        for k in 0..plan.len {
                            let pc = (pc0 + k) as u32;
                            let inst = prog.insts[pc0 + k];
                            let effect = match inst {
                                Inst::PgasIncI { rd, .. } | Inst::PgasIncR { rd, .. } => {
                                    let q = la.out[out_idx];
                                    out_idx += 1;
                                    state.set_r(rd, pack(&q));
                                    state.cc_loc = sptr::locality(
                                        q.thread,
                                        state.mythread,
                                        &state.topo,
                                    )
                                        as u8;
                                    state.pc = pc + 1;
                                    StepEffect::Normal
                                }
                                _ => step(state, mem, &inst),
                            };
                            stats.instructions += 1;
                            budget -= 1;
                            policy.issue(pc, &inst, effect, shared, stats);
                            tally(stats, &inst, effect);
                        }
                        continue;
                    }
                    // Engine refusal: latch off, always-correct scalar
                    // stepping from here on.
                    Err(_) => la.operable = false,
                }
            }
        }
        // ---- scalar path ----
        let pc = state.pc;
        let inst = prog.insts[pc as usize];
        let effect = step(state, mem, &inst);
        stats.instructions += 1;
        budget -= 1;
        policy.issue(pc, &inst, effect, shared, stats);
        tally(stats, &inst, effect);
        if matches!(inst, Inst::PgasIncI { .. } | Inst::PgasIncR { .. }) {
            la.mix.scalar_incs += 1;
        }
        match effect {
            StepEffect::Barrier => {
                policy.finish(stats);
                return StopReason::Barrier;
            }
            StepEffect::Halt => {
                policy.finish(stats);
                return StopReason::Halted;
            }
            _ => {}
        }
    }
    policy.finish(stats);
    StopReason::QuantumExpired
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::IntOp;
    use crate::sptr::{ArrayLayout, SharedPtr};

    /// The vecadd-HW idiom: three independent self-increments
    /// (pa += T; pb += T; pc += T), one batchable window of 3.
    fn independent_inc_run() -> Vec<Inst> {
        vec![
            Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 1 },
            Inst::PgasIncI { rd: 2, ra: 2, l2es: 3, l2bs: 2, l2inc: 1 },
            Inst::PgasIncR { rd: 3, ra: 3, rb: 4, l2es: 3, l2bs: 2 },
            Inst::Halt,
        ]
    }

    #[test]
    fn planner_accepts_self_increments_and_stops_on_chains() {
        let insts = independent_inc_run();
        assert_eq!(
            plan_window(&insts, 0, 32),
            Some(WindowPlan { len: 3, incs: 3 })
        );
        assert_eq!(
            plan_window(&insts, 1, 32),
            Some(WindowPlan { len: 2, incs: 2 })
        );
        assert_eq!(plan_window(&insts, 3, 32), None, "halt is not an inc");
        // a dependent chain (r1 -> r2 reads r1) must not batch past
        // the producer — and a single inc is not worth a dispatch
        let chain = vec![
            Inst::PgasIncI { rd: 2, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::PgasIncI { rd: 3, ra: 2, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::Halt,
        ];
        assert_eq!(plan_window(&chain, 0, 32), None);
        // a geometry change ends the run too
        let mixed = vec![
            Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::PgasIncI { rd: 2, ra: 2, l2es: 2, l2bs: 2, l2inc: 0 },
            Inst::Halt,
        ];
        assert_eq!(plan_window(&mixed, 0, 32), None);
        // a register-form inc whose rb was written earlier cannot batch
        let rb_dep = vec![
            Inst::PgasIncI { rd: 4, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::PgasIncR { rd: 5, ra: 2, rb: 4, l2es: 3, l2bs: 2 },
            Inst::Halt,
        ];
        assert_eq!(plan_window(&rb_dep, 0, 32), None);
    }

    #[test]
    fn planner_tolerates_interleaved_independent_alu_ops() {
        // pointer bumps with loop bookkeeping between them — the shape
        // a compiled upc_forall body actually has
        let insts = vec![
            Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::Opi { op: IntOp::Add, rd: 9, ra: 9, imm: -1 }, // counter
            Inst::PgasIncI { rd: 2, ra: 2, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::Opr { op: IntOp::Add, rd: 10, ra: 1, rb: 2 }, // reads incs: fine
            Inst::PgasIncI { rd: 3, ra: 3, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::Opi { op: IntOp::Add, rd: 11, ra: 9, imm: 1 }, // trailing: trimmed
            Inst::Halt,
        ];
        assert_eq!(
            plan_window(&insts, 0, 32),
            Some(WindowPlan { len: 5, incs: 3 })
        );
        // an ALU op writing a later increment's source ends the window
        // before that increment
        let alu_feeds_inc = vec![
            Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::PgasIncI { rd: 2, ra: 2, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::Opi { op: IntOp::Add, rd: 3, ra: 9, imm: 8 },
            Inst::PgasIncI { rd: 4, ra: 3, l2es: 3, l2bs: 2, l2inc: 0 },
            Inst::Halt,
        ];
        assert_eq!(
            plan_window(&alu_feeds_inc, 0, 32),
            Some(WindowPlan { len: 2, incs: 2 })
        );
        // budget truncation below MIN_RUN_INCS disables batching
        assert_eq!(plan_window(&insts, 0, 1), None);
    }

    #[test]
    fn batched_replay_is_bit_identical_to_serial_stepping() {
        let layout = ArrayLayout::new(4, 8, 4);
        let insts = vec![
            Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 1 },
            Inst::Opi { op: IntOp::Add, rd: 5, ra: 1, imm: 3 }, // reads inc result
            Inst::PgasIncI { rd: 2, ra: 2, l2es: 3, l2bs: 2, l2inc: 1 },
            Inst::PgasIncR { rd: 3, ra: 3, rb: 4, l2es: 3, l2bs: 2 },
            Inst::Halt,
        ];
        let prog = Program::new("win", insts.clone());
        let seed = |st: &mut ArchState| {
            st.set_r(1, pack(&SharedPtr::for_index(&layout, 0, 3)));
            st.set_r(2, pack(&SharedPtr::for_index(&layout, 0, 17)));
            st.set_r(3, pack(&SharedPtr::for_index(&layout, 64, 9)));
            st.set_r(4, 29); // register increment operand
        };
        // serial reference
        let mut serial = ArchState::new(2, 4);
        let mut mem = MemSystem::new(4);
        seed(&mut serial);
        while !serial.halted {
            let inst = insts[serial.pc as usize];
            step(&mut serial, &mut mem, &inst);
        }
        // the shared pipeline with batching on (atomic-style policy)
        struct OneCycle;
        impl IssuePolicy for OneCycle {
            fn issue(
                &mut self,
                _pc: u32,
                _inst: &Inst,
                _effect: StepEffect,
                _shared: &mut SharedLevel,
                stats: &mut CoreStats,
            ) {
                stats.cycles += 1;
            }
        }
        let mut st = ArchState::new(2, 4);
        seed(&mut st);
        let mut stats = CoreStats::default();
        let mut la = Lookahead::new();
        let mut shared = SharedLevel::new(1, crate::cpu::HierLatency::default());
        let stop = run_pipeline(
            &mut st, &mut stats, &mut la, &mut OneCycle, &prog, &mut mem,
            &mut shared, u64::MAX,
        );
        assert_eq!(stop, StopReason::Halted);
        assert_eq!(st.pc, serial.pc);
        assert_eq!(st.cc_loc, serial.cc_loc);
        for r in 0..8 {
            assert_eq!(st.r(r), serial.r(r), "register r{r}");
        }
        // identical accounting: every window instruction still counted
        assert_eq!(stats.instructions, 5);
        assert_eq!(stats.cycles, 5);
        assert_eq!(stats.pgas_incs, 3);
        // telemetry: one batched run of 3 increments, none scalar
        let mix = la.mix();
        assert_eq!(mix.total_runs(), 1);
        assert_eq!(mix.batched_incs, 3);
        assert_eq!(mix.scalar_incs, 0);
        assert_eq!(mix.runs[EngineChoice::Pow2.index()], 1);
        assert!(mix.runs_label().starts_with("pow2:"));
    }

    #[test]
    fn refusal_latches_off_without_corrupting_state() {
        struct OneCycle;
        impl IssuePolicy for OneCycle {
            fn issue(
                &mut self,
                _pc: u32,
                _inst: &Inst,
                _effect: StepEffect,
                _shared: &mut SharedLevel,
                stats: &mut CoreStats,
            ) {
                stats.cycles += 1;
            }
        }
        let insts = independent_inc_run();
        let prog = Program::new("lut", insts);
        let mut st = ArchState::new(0, 8); // claims 8 threads...
        st.set_r(4, 1);
        let mut mem = MemSystem::new(4); // ...but the LUT covers 4
        let mut stats = CoreStats::default();
        let mut la = Lookahead::new();
        let mut shared = SharedLevel::new(1, crate::cpu::HierLatency::default());
        let stop = run_pipeline(
            &mut st, &mut stats, &mut la, &mut OneCycle, &prog, &mut mem,
            &mut shared, u64::MAX,
        );
        // the machine fell back to (always-correct) scalar stepping
        assert_eq!(stop, StopReason::Halted);
        assert!(!la.operable, "refusal must latch the pipeline off");
        let mix = la.mix();
        assert_eq!(mix.batched_incs, 0);
        assert_eq!(mix.scalar_incs, 3);
        assert_eq!(stats.pgas_incs, 3);
    }

    #[test]
    fn engine_mix_carries_a_slot_for_every_backend() {
        // COUNT grew to 7 with the simd tier; the runs array, the
        // by_choice iteration and the label rendering must all agree.
        let mut mix = EngineMix::default();
        assert_eq!(mix.runs.len(), EngineChoice::COUNT);
        mix.runs[EngineChoice::Remote.index()] = 4;
        mix.runs[EngineChoice::Pow2.index()] = 2;
        mix.runs[EngineChoice::Simd.index()] = 3;
        assert_eq!(mix.total_runs(), 9);
        let label = mix.runs_label();
        assert!(label.contains("remote:4"), "{label}");
        assert!(label.contains("pow2:2"), "{label}");
        assert!(label.contains("simd:3"), "{label}");
        let by = mix.by_choice();
        assert_eq!(by[EngineChoice::Remote.index()], (EngineChoice::Remote, 4));
        assert_eq!(by[EngineChoice::Simd.index()], (EngineChoice::Simd, 3));
    }
}
