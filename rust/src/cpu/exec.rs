//! The shared functional executor: **pure architectural execution** of
//! every SimAlpha instruction, including the PGAS extension.
//!
//! All three CPU models call [`step`] through the shared pipeline core
//! ([`cpu::pipeline`](crate::cpu::pipeline)); they differ only in the
//! cycle accounting their `IssuePolicy` layers on the returned
//! [`StepEffect`].  Batching of straight-line PGAS-increment runs —
//! one [`AddressEngine`](crate::engine::AddressEngine) call per run
//! instead of one scalar `increment_pow2` per instruction — lives in
//! the pipeline's `Lookahead`, which *all three* models (atomic,
//! timing, detailed) now route through with per-instruction event
//! replay keeping cycle totals identical to scalar stepping.

use crate::isa::{Cond, FpOp, Inst, IntOp, MemWidth, ZERO};
use crate::mem::MemSystem;
use crate::sptr::{self, increment_pow2, pack, unpack, Topology};
use crate::util::log2_floor;

/// Architectural state of one core.
#[derive(Clone, Debug)]
pub struct ArchState {
    pub pc: u32,
    iregs: [u64; 32],
    fregs: [f64; 32],
    /// This core's UPC thread id (MYTHREAD).
    pub mythread: u32,
    /// The special `threads` register (paper 4.3) and its log2.
    pub threads_reg: u32,
    pub l2_threads: u32,
    /// Locality condition code of the most recent PGAS increment.
    pub cc_loc: u8,
    pub halted: bool,
    pub topo: Topology,
}

impl ArchState {
    pub fn new(mythread: u32, numthreads: u32) -> Self {
        assert!(numthreads.is_power_of_two(), "hw path needs pow2 THREADS");
        Self {
            pc: 0,
            iregs: [0; 32],
            fregs: [0.0; 32],
            mythread,
            threads_reg: numthreads,
            l2_threads: log2_floor(numthreads as u64),
            cc_loc: 0,
            halted: false,
            topo: Topology::default(),
        }
    }

    #[inline]
    pub fn r(&self, r: u8) -> u64 {
        if r == ZERO {
            0
        } else {
            self.iregs[r as usize]
        }
    }

    #[inline]
    pub fn set_r(&mut self, r: u8, v: u64) {
        if r != ZERO {
            self.iregs[r as usize] = v;
        }
    }

    #[inline]
    pub fn f(&self, r: u8) -> f64 {
        if r == ZERO {
            0.0
        } else {
            self.fregs[r as usize]
        }
    }

    #[inline]
    pub fn set_f(&mut self, r: u8, v: f64) {
        if r != ZERO {
            self.fregs[r as usize] = v;
        }
    }
}

/// What a dynamic instruction did — consumed by the timing models.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepEffect {
    /// Plain register op.
    Normal,
    /// Memory access at `sysva` (already performed functionally).
    Mem { sysva: u64, write: bool, width: MemWidth, shared: bool, local: bool },
    /// Control transfer; `taken` for conditional stats.
    Branch { taken: bool },
    /// Barrier rendezvous requested (pc already advanced past it).
    Barrier,
    /// Program finished.
    Halt,
}

#[inline]
fn int_op(op: IntOp, a: u64, b: u64) -> u64 {
    let (sa, sb) = (a as i64, b as i64);
    match op {
        IntOp::Add => sa.wrapping_add(sb) as u64,
        IntOp::Sub => sa.wrapping_sub(sb) as u64,
        IntOp::Mul => sa.wrapping_mul(sb) as u64,
        IntOp::Div => {
            if sb == 0 {
                0
            } else {
                sa.wrapping_div(sb) as u64
            }
        }
        IntOp::Rem => {
            if sb == 0 {
                0
            } else {
                sa.wrapping_rem(sb) as u64
            }
        }
        IntOp::And => a & b,
        IntOp::Or => a | b,
        IntOp::Xor => a ^ b,
        IntOp::Sll => a.wrapping_shl(b as u32 & 63),
        IntOp::Srl => a.wrapping_shr(b as u32 & 63),
        IntOp::Sra => (sa.wrapping_shr(b as u32 & 63)) as u64,
        IntOp::CmpEq => (a == b) as u64,
        IntOp::CmpLt => (sa < sb) as u64,
        IntOp::CmpLtU => (a < b) as u64,
        IntOp::CmpLe => (sa <= sb) as u64,
    }
}

#[inline]
fn fp_op(op: FpOp, a: f64, b: f64) -> f64 {
    match op {
        FpOp::FAdd => a + b,
        FpOp::FSub => a - b,
        FpOp::FMul => a * b,
        FpOp::FDiv => a / b,
        FpOp::FSqrt => a.sqrt(),
        FpOp::FMax => a.max(b),
        FpOp::FAbs => a.abs(),
        FpOp::FNeg => -a,
        FpOp::FMov => a,
    }
}

#[inline]
fn cond_holds(c: Cond, v: i64) -> bool {
    match c {
        Cond::Eq => v == 0,
        Cond::Ne => v != 0,
        Cond::Lt => v < 0,
        Cond::Ge => v >= 0,
        Cond::Le => v <= 0,
        Cond::Gt => v > 0,
    }
}

/// Execute one instruction functionally; advance `st.pc`; return the
/// effect for timing accounting.
pub fn step(st: &mut ArchState, mem: &mut MemSystem, inst: &Inst) -> StepEffect {
    let next = st.pc + 1;
    let mut effect = StepEffect::Normal;
    match *inst {
        Inst::Opi { op, rd, ra, imm } => {
            let v = int_op(op, st.r(ra), imm as i64 as u64);
            st.set_r(rd, v);
        }
        Inst::Opr { op, rd, ra, rb } => {
            let v = int_op(op, st.r(ra), st.r(rb));
            st.set_r(rd, v);
        }
        Inst::Ldi { rd, imm } => st.set_r(rd, imm as u64),
        Inst::Ld { w, rd, base, disp } => {
            let sysva = st.r(base).wrapping_add(disp as i64 as u64);
            if w.is_float() {
                let v = if w == MemWidth::F32 {
                    mem.read_f32(sysva) as f64
                } else {
                    mem.read_f64(sysva)
                };
                st.set_f(rd, v);
            } else {
                st.set_r(rd, mem.read(w, sysva));
            }
            effect = StepEffect::Mem { sysva, write: false, width: w, shared: false, local: true };
        }
        Inst::St { w, rs, base, disp } => {
            let sysva = st.r(base).wrapping_add(disp as i64 as u64);
            if w.is_float() {
                if w == MemWidth::F32 {
                    mem.write_f32(sysva, st.f(rs) as f32);
                } else {
                    mem.write_f64(sysva, st.f(rs));
                }
            } else {
                mem.write(w, sysva, st.r(rs));
            }
            effect = StepEffect::Mem { sysva, write: true, width: w, shared: false, local: true };
        }
        Inst::Fop { op, fd, fa, fb } => {
            let v = fp_op(op, st.f(fa), st.f(fb));
            st.set_f(fd, v);
        }
        Inst::FCmpLt { rd, fa, fb } => {
            st.set_r(rd, (st.f(fa) < st.f(fb)) as u64);
        }
        Inst::CvtIF { fd, ra } => st.set_f(fd, st.r(ra) as i64 as f64),
        Inst::CvtFI { rd, fa } => st.set_r(rd, st.f(fa) as i64 as u64),
        Inst::Br { cond, ra, target } => {
            let taken = cond_holds(cond, st.r(ra) as i64);
            st.pc = if taken { target } else { next };
            return StepEffect::Branch { taken };
        }
        Inst::Jmp { target } => {
            st.pc = target;
            return StepEffect::Branch { taken: true };
        }
        Inst::PgasLd { w, rd, rptr, disp } => {
            let p = unpack(st.r(rptr));
            let sysva = (p.translate(&mem.base_table) as i64 + disp as i64) as u64;
            if w.is_float() {
                let v = if w == MemWidth::F32 {
                    mem.read_f32(sysva) as f64
                } else {
                    mem.read_f64(sysva)
                };
                st.set_f(rd, v);
            } else {
                st.set_r(rd, mem.read(w, sysva));
            }
            effect = StepEffect::Mem {
                sysva,
                write: false,
                width: w,
                shared: true,
                local: p.thread == st.mythread,
            };
        }
        Inst::PgasSt { w, rs, rptr, disp } => {
            let p = unpack(st.r(rptr));
            let sysva = (p.translate(&mem.base_table) as i64 + disp as i64) as u64;
            if w.is_float() {
                if w == MemWidth::F32 {
                    mem.write_f32(sysva, st.f(rs) as f32);
                } else {
                    mem.write_f64(sysva, st.f(rs));
                }
            } else {
                mem.write(w, sysva, st.r(rs));
            }
            effect = StepEffect::Mem {
                sysva,
                write: true,
                width: w,
                shared: true,
                local: p.thread == st.mythread,
            };
        }
        Inst::PgasIncI { rd, ra, l2es, l2bs, l2inc } => {
            let p = unpack(st.r(ra));
            let q = increment_pow2(&p, 1u64 << l2inc, l2bs as u32, l2es as u32, st.l2_threads);
            st.cc_loc = sptr::locality(q.thread, st.mythread, &st.topo) as u8;
            st.set_r(rd, pack(&q));
        }
        Inst::PgasIncR { rd, ra, rb, l2es, l2bs } => {
            let p = unpack(st.r(ra));
            let q = increment_pow2(&p, st.r(rb), l2bs as u32, l2es as u32, st.l2_threads);
            st.cc_loc = sptr::locality(q.thread, st.mythread, &st.topo) as u8;
            st.set_r(rd, pack(&q));
        }
        Inst::PgasSetThreads { ra } => {
            let t = st.r(ra) as u32;
            assert!(t.is_power_of_two(), "threads register must be pow2 for hw");
            st.threads_reg = t;
            st.l2_threads = log2_floor(t as u64);
        }
        Inst::PgasSetBase { rthread, raddr } => {
            let t = st.r(rthread) as u32;
            let addr = st.r(raddr);
            let mut bases = mem.base_table.bases().to_vec();
            if (t as usize) < bases.len() {
                bases[t as usize] = addr;
                mem.base_table = crate::sptr::BaseTable::new(bases);
            }
        }
        Inst::PgasBrLoc { mask, target } => {
            let taken = mask & (1 << st.cc_loc) != 0;
            st.pc = if taken { target } else { next };
            return StepEffect::Branch { taken };
        }
        Inst::Barrier => {
            st.pc = next;
            return StepEffect::Barrier;
        }
        Inst::Halt => {
            st.halted = true;
            return StepEffect::Halt;
        }
        Inst::Nop => {}
    }
    st.pc = next;
    effect
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Program;
    use crate::mem::seg_base;
    use crate::sptr::{ArrayLayout, SharedPtr};

    fn run_to_halt(prog: &Program, st: &mut ArchState, mem: &mut MemSystem) {
        let mut fuel = 100_000;
        while !st.halted {
            let inst = prog.insts[st.pc as usize];
            step(st, mem, &inst);
            fuel -= 1;
            assert!(fuel > 0, "runaway program");
        }
    }

    #[test]
    fn zero_register_is_hardwired() {
        let mut st = ArchState::new(0, 4);
        st.set_r(ZERO, 99);
        assert_eq!(st.r(ZERO), 0);
    }

    #[test]
    fn arithmetic_and_branching_loop() {
        // sum 0..10 via a loop
        let prog = Program::new(
            "sum",
            vec![
                Inst::Ldi { rd: 0, imm: 0 },  // acc
                Inst::Ldi { rd: 1, imm: 10 }, // n
                // loop:
                Inst::Opr { op: IntOp::Add, rd: 0, ra: 0, rb: 1 }, // 2
                Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 1, target: 2 },
                Inst::Halt,
            ],
        );
        let mut st = ArchState::new(0, 1);
        let mut mem = MemSystem::new(1);
        run_to_halt(&prog, &mut st, &mut mem);
        assert_eq!(st.r(0), 55);
    }

    #[test]
    fn pgas_increment_and_load_walk_shared_array() {
        // shared [4] u64 A[32] over 4 threads; A[i] = i preloaded into
        // memory; core 0 sums all 32 elements via pgas_inci + pgas_ldq.
        let layout = ArrayLayout::new(4, 8, 4);
        let mut mem = MemSystem::new(4);
        for i in 0..32u64 {
            let p = SharedPtr::for_index(&layout, 0, i);
            let sysva = p.translate(&mem.base_table);
            mem.write(MemWidth::U64, sysva, i);
        }
        let prog = Program::new(
            "walk",
            vec![
                Inst::Ldi { rd: 0, imm: 0 },  // acc
                Inst::Ldi { rd: 1, imm: 0 },  // packed ptr to A[0]
                Inst::Ldi { rd: 2, imm: 32 }, // counter
                // loop:
                Inst::PgasLd { w: MemWidth::U64, rd: 3, rptr: 1, disp: 0 }, // 3
                Inst::Opr { op: IntOp::Add, rd: 0, ra: 0, rb: 3 },
                Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::Opi { op: IntOp::Add, rd: 2, ra: 2, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 2, target: 3 },
                Inst::Halt,
            ],
        );
        let mut st = ArchState::new(0, 4);
        run_to_halt(&prog, &mut st, &mut mem);
        assert_eq!(st.r(0), (0..32).sum::<u64>());
    }

    #[test]
    fn pgas_store_respects_affinity() {
        // store 7 at A[5] (thread 1) through a shared pointer from core 0
        let layout = ArrayLayout::new(4, 8, 4);
        let mut mem = MemSystem::new(4);
        let p = SharedPtr::for_index(&layout, 0, 5);
        let prog = Program::new(
            "st",
            vec![
                Inst::Ldi { rd: 1, imm: pack(&p) as i64 },
                Inst::Ldi { rd: 2, imm: 7 },
                Inst::PgasSt { w: MemWidth::U64, rs: 2, rptr: 1, disp: 0 },
                Inst::Halt,
            ],
        );
        let mut st = ArchState::new(0, 4);
        run_to_halt(&prog, &mut st, &mut mem);
        let sysva = p.translate(&mem.base_table);
        assert_eq!(mem.read(MemWidth::U64, sysva), 7);
        assert_eq!(sysva >> 32, 2, "element 5 lives on thread 1");
    }

    #[test]
    fn brloc_branches_on_locality() {
        // increment from A[3] (thread 0, local) to A[4] (thread 1):
        // cc becomes non-local; brloc mask=0b1110 must take.
        let layout = ArrayLayout::new(4, 8, 4);
        let p = SharedPtr::for_index(&layout, 0, 3);
        let prog = Program::new(
            "loc",
            vec![
                Inst::Ldi { rd: 1, imm: pack(&p) as i64 },
                Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::PgasBrLoc { mask: 0b1110, target: 4 },
                Inst::Ldi { rd: 5, imm: 111 }, // skipped when taken
                Inst::Halt,
            ],
        );
        let mut st = ArchState::new(0, 4);
        let mut mem = MemSystem::new(4);
        run_to_halt(&prog, &mut st, &mut mem);
        assert_eq!(st.r(5), 0, "branch must skip the ldi");
        assert_ne!(st.cc_loc, 0);
    }

    #[test]
    fn fp_path() {
        let mut mem = MemSystem::new(1);
        let a = seg_base(0) + 64;
        mem.write_f64(a, 2.25);
        let prog = Program::new(
            "fp",
            vec![
                Inst::Ldi { rd: 1, imm: a as i64 },
                Inst::Ld { w: MemWidth::F64, rd: 2, base: 1, disp: 0 },
                Inst::Fop { op: FpOp::FMul, fd: 3, fa: 2, fb: 2 },
                Inst::St { w: MemWidth::F64, rs: 3, base: 1, disp: 8 },
                Inst::Halt,
            ],
        );
        let mut st = ArchState::new(0, 1);
        run_to_halt(&prog, &mut st, &mut mem);
        assert_eq!(mem.read_f64(a + 8), 2.25 * 2.25);
    }

    #[test]
    fn div_by_zero_defined() {
        assert_eq!(int_op(IntOp::Div, 5, 0), 0);
        assert_eq!(int_op(IntOp::Rem, 5, 0), 0);
    }
}
