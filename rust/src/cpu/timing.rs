//! The `timing` CPU model: in-order execution with cache-hierarchy and
//! DRAM latencies — Gem5's TimingSimpleCPU plus the Classic memory model
//! (Figures 11–14 "timing" series).
//!
//! Every instruction costs its latency-class cycles (multiply and the
//! non-pipelined divide are charged in full — the ops that dominate the
//! software Algorithm 1); loads and stores additionally pay L1/L2/DRAM
//! time, and instruction fetch pays L1I misses at line granularity.

use super::{ArchState, CoreStats, Cpu, SharedLevel, StopReason};
use crate::cpu::exec::{step, StepEffect};
use crate::isa::latency::LatencyModel;
use crate::isa::{Inst, Program};
use crate::mem::MemSystem;

/// Hierarchy latencies in core cycles at 2 GHz.
#[derive(Clone, Copy, Debug)]
pub struct HierLatency {
    pub line: u64,
    /// L1 hit.
    pub l1: u64,
    /// Additional cycles for an L2 hit.
    pub l2: u64,
    /// Additional cycles for DRAM.
    pub mem: u64,
    /// TLB refill penalty.
    pub tlb_miss: u64,
    /// Shared-bus occupancy per L2 transaction (contention model).
    pub bus_per_txn: u64,
}

impl Default for HierLatency {
    fn default() -> Self {
        Self { line: 64, l1: 2, l2: 14, mem: 110, tlb_miss: 30, bus_per_txn: 8 }
    }
}

/// In-order timing core.
pub struct TimingCpu {
    state: ArchState,
    stats: CoreStats,
    lat: LatencyModel,
    core: usize,
    /// Last instruction-fetch line (fetch charged on line crossings).
    last_fetch_line: u64,
}

impl TimingCpu {
    pub fn new(mythread: u32, numthreads: u32) -> Self {
        Self {
            state: ArchState::new(mythread, numthreads),
            stats: CoreStats::default(),
            lat: LatencyModel::default(),
            core: mythread as usize,
            last_fetch_line: u64::MAX,
        }
    }

    /// Simulated code addresses: place the program at sysva 0 of the
    /// core's own segment-page for i-cache purposes (4 bytes/inst).
    #[inline]
    fn fetch_addr(&self, pc: u32) -> u64 {
        crate::mem::seg_base(self.state.mythread) + 0x4000_0000 + (pc as u64) * 4
    }
}

impl Cpu for TimingCpu {
    fn run(
        &mut self,
        prog: &Program,
        mem: &mut MemSystem,
        shared: &mut SharedLevel,
        max_insts: u64,
    ) -> StopReason {
        let mut budget = max_insts;
        while budget > 0 {
            if self.state.halted {
                return StopReason::Halted;
            }
            let pc = self.state.pc;
            let inst = prog.insts[pc as usize];

            // instruction fetch at line granularity
            let faddr = self.fetch_addr(pc);
            let fline = faddr & !(shared.lat.line - 1);
            if fline != self.last_fetch_line {
                self.stats.cycles += shared.fetch(self.core, faddr);
                self.last_fetch_line = fline;
            }

            let effect = step(&mut self.state, mem, &inst);
            self.stats.instructions += 1;
            budget -= 1;
            let cost = self.lat.cost(&inst);
            // The PGAS increment unit is fully pipelined (1/cycle issue,
            // Fig. 5) and the 7-stage in-order pipe forwards its result;
            // charge issue occupancy, not the 2-cycle result latency
            // (which only a back-to-back dependent use would expose).
            let cycles = if matches!(inst, Inst::PgasIncI { .. } | Inst::PgasIncR { .. })
            {
                cost.init_interval
            } else {
                cost.latency
            };
            self.stats.cycles += cycles as u64;

            match effect {
                StepEffect::Mem { sysva, write, shared: is_shared, local, .. } => {
                    self.stats.cycles += shared.access(self.core, sysva, write);
                    if write {
                        self.stats.mem_writes += 1;
                    } else {
                        self.stats.mem_reads += 1;
                    }
                    if is_shared {
                        if inst.is_pgas() {
                            self.stats.pgas_mems += 1;
                        }
                        if local {
                            self.stats.local_shared_accesses += 1;
                        } else {
                            self.stats.remote_shared_accesses += 1;
                        }
                    }
                }
                StepEffect::Branch { taken } => {
                    self.stats.branches += 1;
                    if taken {
                        // redirect bubble on the 7-stage in-order pipe
                        self.stats.cycles += 2;
                    }
                }
                StepEffect::Barrier => {
                    self.stats.barriers += 1;
                    return StopReason::Barrier;
                }
                StepEffect::Halt => return StopReason::Halted,
                StepEffect::Normal => {
                    if matches!(inst, Inst::PgasIncI { .. } | Inst::PgasIncR { .. }) {
                        self.stats.pgas_incs += 1;
                    }
                }
            }
        }
        StopReason::QuantumExpired
    }

    fn state(&self) -> &ArchState {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ArchState {
        &mut self.state
    }

    fn stats(&self) -> &CoreStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CoreStats {
        &mut self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{IntOp, MemWidth};
    use crate::mem::seg_base;

    fn shared1() -> SharedLevel {
        SharedLevel::new(1, HierLatency::default())
    }

    #[test]
    fn divide_costs_more_than_add() {
        let mk = |op| {
            Program::new(
                "p",
                vec![
                    Inst::Ldi { rd: 1, imm: 100 },
                    Inst::Ldi { rd: 2, imm: 7 },
                    Inst::Opr { op, rd: 3, ra: 1, rb: 2 },
                    Inst::Halt,
                ],
            )
        };
        let run = |prog: &Program| {
            let mut cpu = TimingCpu::new(0, 1);
            let mut mem = MemSystem::new(1);
            cpu.run(prog, &mut mem, &mut shared1(), u64::MAX);
            cpu.stats().cycles
        };
        let add = run(&mk(IntOp::Add));
        let div = run(&mk(IntOp::Div));
        assert!(div >= add + 19, "div {div} vs add {add}");
    }

    #[test]
    fn repeated_loads_hit_in_l1() {
        let a = seg_base(0) + 256;
        let prog = Program::new(
            "ld2",
            vec![
                Inst::Ldi { rd: 1, imm: a as i64 },
                Inst::Ld { w: MemWidth::U64, rd: 2, base: 1, disp: 0 },
                Inst::Ld { w: MemWidth::U64, rd: 3, base: 1, disp: 0 },
                Inst::Halt,
            ],
        );
        let mut cpu = TimingCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        let mut sh = shared1();
        cpu.run(&prog, &mut mem, &mut sh, u64::MAX);
        assert_eq!(sh.l1d[0].stats.misses, 1);
        assert_eq!(sh.l1d[0].stats.hits, 1);
    }

    #[test]
    fn pgas_load_costs_like_normal_load() {
        // Same line accessed: first by a pgas_ld, then normal ld — both
        // should traverse the same hierarchy path.
        use crate::sptr::{pack, ArrayLayout, SharedPtr};
        let layout = ArrayLayout::new(4, 8, 1);
        // element 2 so both programs materialize wide immediates
        let p = SharedPtr::for_index(&layout, 0, 2);
        let prog_pgas = Program::new(
            "pg",
            vec![
                Inst::Ldi { rd: 1, imm: pack(&p) as i64 },
                Inst::PgasLd { w: MemWidth::U64, rd: 2, rptr: 1, disp: 0 },
                Inst::Halt,
            ],
        );
        let prog_norm = Program::new(
            "nm",
            vec![
                Inst::Ldi { rd: 1, imm: (seg_base(0) + 16) as i64 },
                Inst::Ld { w: MemWidth::U64, rd: 2, base: 1, disp: 0 },
                Inst::Halt,
            ],
        );
        let run = |prog: &Program| {
            let mut cpu = TimingCpu::new(0, 1);
            let mut mem = MemSystem::new(1);
            cpu.run(prog, &mut mem, &mut shared1(), u64::MAX);
            cpu.stats().cycles
        };
        assert_eq!(run(&prog_pgas), run(&prog_norm));
    }
}
