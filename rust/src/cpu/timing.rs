//! The `timing` CPU model: in-order execution with cache-hierarchy and
//! DRAM latencies — Gem5's TimingSimpleCPU plus the Classic memory model
//! (Figures 11–14 "timing" series).
//!
//! Every instruction costs its latency-class cycles (multiply and the
//! non-pipelined divide are charged in full — the ops that dominate the
//! software Algorithm 1); loads and stores additionally pay L1/L2/DRAM
//! time, and instruction fetch pays L1I misses at line granularity.
//!
//! Execution runs on the shared pipeline core
//! ([`cpu::pipeline`](crate::cpu::pipeline)); this file is only the
//! in-order issue/latency policy.  Batched PGAS-increment windows are
//! replayed event-by-event through the same policy, so cycle totals
//! are bit-identical to scalar stepping (the accounting depends only
//! on the `(pc, inst, effect)` sequence and the shared hierarchy,
//! which see identical traffic either way).

use super::pipeline::{run_pipeline, IssuePolicy, Lookahead};
use super::{ArchState, CoreStats, Cpu, SharedLevel, StopReason};
use crate::cpu::exec::StepEffect;
use crate::isa::latency::LatencyModel;
use crate::isa::{Inst, Program};
use crate::mem::MemSystem;

/// Hierarchy latencies in core cycles at 2 GHz.
#[derive(Clone, Copy, Debug)]
pub struct HierLatency {
    pub line: u64,
    /// L1 hit.
    pub l1: u64,
    /// Additional cycles for an L2 hit.
    pub l2: u64,
    /// Additional cycles for DRAM.
    pub mem: u64,
    /// TLB refill penalty.
    pub tlb_miss: u64,
    /// Shared-bus occupancy per L2 transaction (contention model).
    pub bus_per_txn: u64,
}

impl Default for HierLatency {
    fn default() -> Self {
        Self { line: 64, l1: 2, l2: 14, mem: 110, tlb_miss: 30, bus_per_txn: 8 }
    }
}

/// The in-order issue/latency policy.
struct TimingPolicy {
    lat: LatencyModel,
    core: usize,
    mythread: u32,
    /// Last instruction-fetch line (fetch charged on line crossings).
    last_fetch_line: u64,
}

impl TimingPolicy {
    /// Simulated code addresses: place the program at sysva 0 of the
    /// core's own segment-page for i-cache purposes (4 bytes/inst).
    #[inline]
    fn fetch_addr(&self, pc: u32) -> u64 {
        crate::mem::seg_base(self.mythread) + 0x4000_0000 + (pc as u64) * 4
    }
}

impl IssuePolicy for TimingPolicy {
    fn issue(
        &mut self,
        pc: u32,
        inst: &Inst,
        effect: StepEffect,
        shared: &mut SharedLevel,
        stats: &mut CoreStats,
    ) {
        // instruction fetch at line granularity
        let faddr = self.fetch_addr(pc);
        let fline = faddr & !(shared.lat.line - 1);
        if fline != self.last_fetch_line {
            stats.cycles += shared.fetch(self.core, faddr);
            self.last_fetch_line = fline;
        }

        let cost = self.lat.cost(inst);
        // The PGAS increment unit is fully pipelined (1/cycle issue,
        // Fig. 5) and the 7-stage in-order pipe forwards its result;
        // charge issue occupancy, not the 2-cycle result latency
        // (which only a back-to-back dependent use would expose).
        let cycles = if matches!(inst, Inst::PgasIncI { .. } | Inst::PgasIncR { .. }) {
            cost.init_interval
        } else {
            cost.latency
        };
        stats.cycles += cycles as u64;

        match effect {
            StepEffect::Mem { sysva, write, .. } => {
                stats.cycles += shared.access(self.core, sysva, write);
            }
            StepEffect::Branch { taken } => {
                if taken {
                    // redirect bubble on the 7-stage in-order pipe
                    stats.cycles += 2;
                }
            }
            _ => {}
        }
    }
}

/// In-order timing core.
pub struct TimingCpu {
    state: ArchState,
    stats: CoreStats,
    pipeline: Lookahead,
    policy: TimingPolicy,
}

impl TimingCpu {
    pub fn new(mythread: u32, numthreads: u32) -> Self {
        Self {
            state: ArchState::new(mythread, numthreads),
            stats: CoreStats::default(),
            pipeline: Lookahead::new(),
            policy: TimingPolicy {
                lat: LatencyModel::default(),
                core: mythread as usize,
                mythread,
                last_fetch_line: u64::MAX,
            },
        }
    }
}

impl Cpu for TimingCpu {
    fn run(
        &mut self,
        prog: &Program,
        mem: &mut MemSystem,
        shared: &mut SharedLevel,
        max_insts: u64,
    ) -> StopReason {
        run_pipeline(
            &mut self.state,
            &mut self.stats,
            &mut self.pipeline,
            &mut self.policy,
            prog,
            mem,
            shared,
            max_insts,
        )
    }

    fn state(&self) -> &ArchState {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ArchState {
        &mut self.state
    }

    fn stats(&self) -> &CoreStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CoreStats {
        &mut self.stats
    }

    fn lookahead(&self) -> &Lookahead {
        &self.pipeline
    }

    fn lookahead_mut(&mut self) -> &mut Lookahead {
        &mut self.pipeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{IntOp, MemWidth};
    use crate::mem::seg_base;

    fn shared1() -> SharedLevel {
        SharedLevel::new(1, HierLatency::default())
    }

    #[test]
    fn divide_costs_more_than_add() {
        let mk = |op| {
            Program::new(
                "p",
                vec![
                    Inst::Ldi { rd: 1, imm: 100 },
                    Inst::Ldi { rd: 2, imm: 7 },
                    Inst::Opr { op, rd: 3, ra: 1, rb: 2 },
                    Inst::Halt,
                ],
            )
        };
        let run = |prog: &Program| {
            let mut cpu = TimingCpu::new(0, 1);
            let mut mem = MemSystem::new(1);
            cpu.run(prog, &mut mem, &mut shared1(), u64::MAX);
            cpu.stats().cycles
        };
        let add = run(&mk(IntOp::Add));
        let div = run(&mk(IntOp::Div));
        assert!(div >= add + 19, "div {div} vs add {add}");
    }

    #[test]
    fn repeated_loads_hit_in_l1() {
        let a = seg_base(0) + 256;
        let prog = Program::new(
            "ld2",
            vec![
                Inst::Ldi { rd: 1, imm: a as i64 },
                Inst::Ld { w: MemWidth::U64, rd: 2, base: 1, disp: 0 },
                Inst::Ld { w: MemWidth::U64, rd: 3, base: 1, disp: 0 },
                Inst::Halt,
            ],
        );
        let mut cpu = TimingCpu::new(0, 1);
        let mut mem = MemSystem::new(1);
        let mut sh = shared1();
        cpu.run(&prog, &mut mem, &mut sh, u64::MAX);
        assert_eq!(sh.l1d[0].stats.misses, 1);
        assert_eq!(sh.l1d[0].stats.hits, 1);
    }

    #[test]
    fn pgas_load_costs_like_normal_load() {
        // Same line accessed: first by a pgas_ld, then normal ld — both
        // should traverse the same hierarchy path.
        use crate::sptr::{pack, ArrayLayout, SharedPtr};
        let layout = ArrayLayout::new(4, 8, 1);
        // element 2 so both programs materialize wide immediates
        let p = SharedPtr::for_index(&layout, 0, 2);
        let prog_pgas = Program::new(
            "pg",
            vec![
                Inst::Ldi { rd: 1, imm: pack(&p) as i64 },
                Inst::PgasLd { w: MemWidth::U64, rd: 2, rptr: 1, disp: 0 },
                Inst::Halt,
            ],
        );
        let prog_norm = Program::new(
            "nm",
            vec![
                Inst::Ldi { rd: 1, imm: (seg_base(0) + 16) as i64 },
                Inst::Ld { w: MemWidth::U64, rd: 2, base: 1, disp: 0 },
                Inst::Halt,
            ],
        );
        let run = |prog: &Program| {
            let mut cpu = TimingCpu::new(0, 1);
            let mut mem = MemSystem::new(1);
            cpu.run(prog, &mut mem, &mut shared1(), u64::MAX);
            cpu.stats().cycles
        };
        assert_eq!(run(&prog_pgas), run(&prog_norm));
    }

    #[test]
    fn batched_increment_window_is_cycle_exact_vs_scalar() {
        use crate::sptr::{pack, ArrayLayout, SharedPtr};
        let layout = ArrayLayout::new(4, 8, 4);
        let prog = Program::new(
            "bump",
            vec![
                Inst::PgasIncI { rd: 1, ra: 1, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::Opi { op: IntOp::Add, rd: 5, ra: 5, imm: 1 },
                Inst::PgasIncI { rd: 2, ra: 2, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::PgasIncI { rd: 3, ra: 3, l2es: 3, l2bs: 2, l2inc: 0 },
                Inst::Halt,
            ],
        );
        let run = |lookahead: bool| {
            let mut cpu = TimingCpu::new(0, 4);
            cpu.lookahead_mut().set_enabled(lookahead);
            cpu.state_mut().set_r(1, pack(&SharedPtr::for_index(&layout, 0, 0)));
            cpu.state_mut().set_r(2, pack(&SharedPtr::for_index(&layout, 0, 7)));
            cpu.state_mut().set_r(3, pack(&SharedPtr::for_index(&layout, 64, 2)));
            let mut mem = MemSystem::new(4);
            cpu.run(&prog, &mut mem, &mut shared1(), u64::MAX);
            (cpu.stats().cycles, cpu.engine_mix().batched_incs)
        };
        let (batched_cycles, batched) = run(true);
        let (scalar_cycles, none) = run(false);
        assert_eq!(batched_cycles, scalar_cycles, "event replay is exact");
        assert_eq!(batched, 3, "the window actually batched");
        assert_eq!(none, 0);
    }
}
