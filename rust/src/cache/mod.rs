//! Set-associative cache models with LRU replacement — the Gem5
//! "Classic" memory model analogue used by the `timing` and `detailed`
//! CPU models. Caches here are *tag-only*: functional data always lives
//! in [`crate::mem::MemSystem`]; the hierarchy decides how many cycles an
//! access costs and tracks coherence traffic.
//!
//! Paper configuration (Section 5.1): per-core 32 KiB L1 I + D, shared
//! 4 MiB L2, 2 GHz.

use std::collections::HashMap;

/// Geometry of one cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheCfg {
    pub size: u64,
    pub ways: u32,
    pub line: u64,
}

impl CacheCfg {
    /// Paper L1: 32 KiB, 2-way, 64 B lines.
    pub fn l1_32k() -> Self {
        CacheCfg { size: 32 << 10, ways: 2, line: 64 }
    }

    /// Paper L2: shared 4 MiB, 8-way, 64 B lines.
    pub fn l2_4m() -> Self {
        CacheCfg { size: 4 << 20, ways: 8, line: 64 }
    }

    pub fn sets(&self) -> u64 {
        self.size / (self.line * self.ways as u64)
    }
}

/// Hit/miss/eviction counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub invalidations: u64,
}

impl CacheStats {
    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }

    pub fn merge(&mut self, o: &CacheStats) {
        self.accesses += o.accesses;
        self.hits += o.hits;
        self.misses += o.misses;
        self.evictions += o.evictions;
        self.invalidations += o.invalidations;
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct Way {
    tag: u64,
    valid: bool,
    last_use: u64,
}

/// Tag-only set-associative LRU cache.
#[derive(Clone, Debug)]
pub struct SetAssocCache {
    cfg: CacheCfg,
    ways: Vec<Way>, // sets * ways, row-major by set
    tick: u64,
    set_mask: u64,
    line_shift: u32,
    pub stats: CacheStats,
}

impl SetAssocCache {
    pub fn new(cfg: CacheCfg) -> Self {
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "sets must be pow2: {sets}");
        assert!(cfg.line.is_power_of_two());
        Self {
            cfg,
            ways: vec![Way::default(); (sets * cfg.ways as u64) as usize],
            tick: 0,
            set_mask: sets - 1,
            line_shift: cfg.line.trailing_zeros(),
            stats: CacheStats::default(),
        }
    }

    pub fn cfg(&self) -> &CacheCfg {
        &self.cfg
    }

    #[inline]
    fn set_of(&self, addr: u64) -> (usize, u64) {
        let line = addr >> self.line_shift;
        (((line & self.set_mask) as usize) * self.cfg.ways as usize, line)
    }

    /// Access a line; returns `true` on hit. On miss the line is filled
    /// (evicting LRU).
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        self.tick += 1;
        self.stats.accesses += 1;
        let (base, line) = self.set_of(addr);
        let ways = self.cfg.ways as usize;
        let set = &mut self.ways[base..base + ways];
        for w in set.iter_mut() {
            if w.valid && w.tag == line {
                w.last_use = self.tick;
                self.stats.hits += 1;
                return true;
            }
        }
        self.stats.misses += 1;
        // fill: LRU victim
        let victim = set
            .iter_mut()
            .min_by_key(|w| if w.valid { w.last_use } else { 0 })
            .unwrap();
        if victim.valid {
            self.stats.evictions += 1;
        }
        victim.valid = true;
        victim.tag = line;
        victim.last_use = self.tick;
        false
    }

    /// Probe without filling (coherence snoops).
    pub fn probe(&self, addr: u64) -> bool {
        let (base, line) = self.set_of(addr);
        self.ways[base..base + self.cfg.ways as usize]
            .iter()
            .any(|w| w.valid && w.tag == line)
    }

    /// Invalidate a line if present (returns whether it was).
    pub fn invalidate(&mut self, addr: u64) -> bool {
        let (base, line) = self.set_of(addr);
        for w in &mut self.ways[base..base + self.cfg.ways as usize] {
            if w.valid && w.tag == line {
                w.valid = false;
                self.stats.invalidations += 1;
                return true;
            }
        }
        false
    }

    pub fn flush(&mut self) {
        for w in &mut self.ways {
            w.valid = false;
        }
    }
}

/// MESI-lite directory for the shared L2: which cores hold each line, and
/// who (if anyone) holds it dirty. Granularity is the L2 line.
#[derive(Debug, Default)]
pub struct Directory {
    sharers: HashMap<u64, u64>, // line -> core bitmask
    pub invalidations_sent: u64,
}

impl Directory {
    /// Record a read by `core`; returns the set of other sharers (for
    /// stats — reads don't invalidate).
    pub fn on_read(&mut self, line: u64, core: usize) -> u64 {
        let e = self.sharers.entry(line).or_insert(0);
        let others = *e & !(1 << core);
        *e |= 1 << core;
        others
    }

    /// Record a write by `core`; returns the bitmask of cores whose L1
    /// copies must be invalidated.
    pub fn on_write(&mut self, line: u64, core: usize) -> u64 {
        let e = self.sharers.entry(line).or_insert(0);
        let victims = *e & !(1 << core);
        *e = 1 << core;
        self.invalidations_sent += victims.count_ones() as u64;
        victims
    }

    pub fn sharers_of(&self, line: u64) -> u64 {
        self.sharers.get(&line).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check_default;

    #[test]
    fn geometry() {
        let l1 = CacheCfg::l1_32k();
        assert_eq!(l1.sets(), 256);
        let l2 = CacheCfg::l2_4m();
        assert_eq!(l2.sets(), 8192);
    }

    #[test]
    fn hit_after_fill() {
        let mut c = SetAssocCache::new(CacheCfg::l1_32k());
        assert!(!c.access(0x1000));
        assert!(c.access(0x1000));
        assert!(c.access(0x1038)); // same 64B line
        assert!(!c.access(0x1040)); // next line
        assert_eq!(c.stats.hits, 2);
        assert_eq!(c.stats.misses, 2);
    }

    #[test]
    fn lru_evicts_oldest() {
        // 2-way: fill two conflicting lines, touch the first, add a third
        // — the second must be the victim.
        let cfg = CacheCfg { size: 2 * 64, ways: 2, line: 64 };
        let mut c = SetAssocCache::new(cfg);
        let stride = 64; // sets() == 1, all lines conflict
        c.access(0);
        c.access(stride);
        c.access(0); // refresh
        c.access(2 * stride); // evicts `stride`
        assert!(c.probe(0));
        assert!(!c.probe(stride));
        assert!(c.probe(2 * stride));
    }

    #[test]
    fn invalidation() {
        let mut c = SetAssocCache::new(CacheCfg::l1_32k());
        c.access(0x2000);
        assert!(c.invalidate(0x2000));
        assert!(!c.probe(0x2000));
        assert!(!c.invalidate(0x2000));
        assert_eq!(c.stats.invalidations, 1);
    }

    #[test]
    fn directory_write_invalidates_others() {
        let mut d = Directory::default();
        d.on_read(10, 0);
        d.on_read(10, 1);
        d.on_read(10, 2);
        let victims = d.on_write(10, 1);
        assert_eq!(victims, 0b101);
        assert_eq!(d.sharers_of(10), 0b010);
        assert_eq!(d.invalidations_sent, 2);
    }

    #[test]
    fn hits_never_exceed_accesses_property() {
        check_default("cache stat sanity", |rng| {
            let mut c = SetAssocCache::new(CacheCfg { size: 1024, ways: 4, line: 64 });
            for _ in 0..200 {
                c.access(rng.below(1 << 14) & !63);
            }
            assert_eq!(c.stats.hits + c.stats.misses, c.stats.accesses);
            assert!(c.stats.evictions <= c.stats.misses);
        });
    }
}
