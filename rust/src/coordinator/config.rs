//! Minimal campaign-config parser (a TOML-subset: `key = value` lines,
//! `#` comments, comma-separated lists).  The offline build environment
//! vendors no TOML crate; campaigns are simple enough for this format:
//!
//! ```text
//! # campaign.cfg
//! kernels  = EP, CG, MG
//! models   = atomic, timing
//! cores    = 1, 2, 4, 8
//! variants = unopt, manual, hw
//! scale    = 64
//! jobs     = 8
//! ```

use super::Campaign;
use crate::cpu::CpuModel;
use crate::npb::{Kernel, PaperVariant, Scale};

fn parse_variant(s: &str) -> Option<PaperVariant> {
    match s.to_ascii_lowercase().as_str() {
        "unopt" | "no-manual-opt" => Some(PaperVariant::Unopt),
        "manual" | "manual-opt" | "privatized" => Some(PaperVariant::Manual),
        "hw" | "hardware" => Some(PaperVariant::Hw),
        _ => None,
    }
}

/// Parse a campaign config; unknown keys are errors (typo safety).
pub fn parse_campaign(text: &str) -> Result<Campaign, String> {
    let mut c = Campaign::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim().to_ascii_lowercase();
        let items: Vec<&str> = value.split(',').map(|s| s.trim()).collect();
        match key.as_str() {
            "kernels" => {
                c.kernels = items
                    .iter()
                    .map(|s| {
                        Kernel::parse(s)
                            .ok_or_else(|| format!("line {}: unknown kernel `{s}`", lineno + 1))
                    })
                    .collect::<Result<_, _>>()?;
            }
            "models" => {
                c.models = items
                    .iter()
                    .map(|s| {
                        CpuModel::parse(s)
                            .ok_or_else(|| format!("line {}: unknown model `{s}`", lineno + 1))
                    })
                    .collect::<Result<_, _>>()?;
            }
            "variants" => {
                c.variants = items
                    .iter()
                    .map(|s| {
                        parse_variant(s)
                            .ok_or_else(|| format!("line {}: unknown variant `{s}`", lineno + 1))
                    })
                    .collect::<Result<_, _>>()?;
            }
            "cores" => {
                c.cores = items
                    .iter()
                    .map(|s| {
                        s.parse::<u32>()
                            .map_err(|_| format!("line {}: bad core count `{s}`", lineno + 1))
                    })
                    .collect::<Result<_, _>>()?;
            }
            "scale" => {
                let f = value
                    .trim()
                    .parse::<u32>()
                    .map_err(|_| format!("line {}: bad scale", lineno + 1))?;
                c.scale = Scale { factor: f.max(1) };
            }
            "jobs" => {
                c.jobs = value
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| format!("line {}: bad jobs", lineno + 1))?;
            }
            other => return Err(format!("line {}: unknown key `{other}`", lineno + 1)),
        }
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let c = parse_campaign(
            "# demo\nkernels = EP, cg\nmodels = atomic, detailed\n\
             cores = 1,2 , 4\nvariants = unopt, hw\nscale = 128\njobs = 3\n",
        )
        .unwrap();
        assert_eq!(c.kernels, vec![Kernel::Ep, Kernel::Cg]);
        assert_eq!(c.models.len(), 2);
        assert_eq!(c.cores, vec![1, 2, 4]);
        assert_eq!(c.variants.len(), 2);
        assert_eq!(c.scale.factor, 128);
        assert_eq!(c.jobs, 3);
    }

    #[test]
    fn rejects_unknown_keys_and_values() {
        assert!(parse_campaign("kernls = EP").is_err());
        assert!(parse_campaign("kernels = QQ").is_err());
        assert!(parse_campaign("models = riscy").is_err());
        assert!(parse_campaign("cores = four").is_err());
    }

    #[test]
    fn comments_and_blanks_ok() {
        let c = parse_campaign("\n# nothing but comments\n\n").unwrap();
        assert_eq!(c.kernels.len(), 5); // defaults
    }
}
