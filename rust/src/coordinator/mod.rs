//! The benchmark coordinator: campaign configuration, parallel sweep
//! scheduling, result collection, and the per-figure reporters that
//! regenerate the paper's tables.
//!
//! A *campaign* is the cross product kernels × variants × models ×
//! core-counts (bounded per-kernel, e.g. FT ≤ 16).  Runs are scheduled
//! over a pool of host threads (each simulation is single-threaded and
//! self-contained), results validate on the fly, and the reporters
//! lay out one table per figure: rows = simulated core count, columns =
//! the paper's three variants plus derived speedups.

pub mod config;

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::compiler::SourceVariant;
use crate::cpu::CpuModel;
use crate::engine::{
    AddressEngine, EngineChoice, EngineSelector, FaultSpec, HealthStats,
    Leon3Engine, RemoteTier,
};
use crate::npb::{self, Kernel, PaperVariant, RunOutcome, Scale};
use crate::util::table::{fnum, Table};

/// A full sweep specification.
#[derive(Clone, Debug)]
pub struct Campaign {
    pub kernels: Vec<Kernel>,
    pub models: Vec<CpuModel>,
    pub cores: Vec<u32>,
    pub variants: Vec<PaperVariant>,
    pub scale: Scale,
    /// Host worker threads.
    pub jobs: usize,
    /// Seeded fault injection: when set, every run's selectors are
    /// armed with this [`FaultSpec`] (`--chaos` on the CLI).  Transient
    /// injected faults are absorbed by the fallback ladder, so the
    /// figures are unchanged — only `health`/`degrade` telemetry moves.
    pub chaos: Option<FaultSpec>,
}

impl Default for Campaign {
    fn default() -> Self {
        Self {
            kernels: Kernel::ALL.to_vec(),
            models: vec![CpuModel::Atomic],
            cores: vec![1, 2, 4, 8, 16, 32, 64],
            variants: PaperVariant::ALL.to_vec(),
            scale: Scale::default(),
            jobs: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            chaos: None,
        }
    }
}

impl Campaign {
    /// A fast smoke campaign (examples' `--quick` mode).
    pub fn quick() -> Self {
        Self {
            kernels: Kernel::ALL.to_vec(),
            models: vec![CpuModel::Atomic],
            cores: vec![1, 4],
            variants: PaperVariant::ALL.to_vec(),
            scale: Scale::quick(),
            jobs: Self::default().jobs,
            chaos: None,
        }
    }

    /// Enumerate the concrete run points.
    pub fn points(&self) -> Vec<(Kernel, PaperVariant, CpuModel, u32)> {
        let mut pts = Vec::new();
        for &k in &self.kernels {
            for &m in &self.models {
                for &c in &self.cores {
                    if c > k.max_cores() {
                        continue; // FT's class-W slab limit
                    }
                    for &v in &self.variants {
                        pts.push((k, v, m, c));
                    }
                }
            }
        }
        pts
    }

    /// Run the whole campaign on a host-thread pool; every run validates
    /// its numerics (panics otherwise).
    pub fn run(&self, verbose: bool) -> Vec<RunOutcome> {
        self.run_with_remote(verbose, None)
    }

    /// [`run`](Self::run) with an optional remote address-mapping tier:
    /// every point's machine gets the shared worker-process pool
    /// installed (`npb::run_opts`), so the sweep's engine-mix section
    /// can show `remote`-served windows.  The tier's `Arc`-shared pool
    /// serializes its socket traffic across the job threads; cycle
    /// totals are unaffected by which backend serves a window.
    pub fn run_with_remote(
        &self,
        verbose: bool,
        remote: Option<&RemoteTier>,
    ) -> Vec<RunOutcome> {
        let points = self.points();
        let total = points.len();
        let queue = Arc::new(Mutex::new(points));
        let (tx, rx) = mpsc::channel::<RunOutcome>();
        let scale = self.scale;
        let chaos = self.chaos;
        let jobs = self.jobs.max(1);
        let mut handles = Vec::new();
        for _ in 0..jobs {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let remote = remote.cloned();
            handles.push(std::thread::spawn(move || loop {
                let pt = { queue.lock().unwrap().pop() };
                match pt {
                    Some((k, v, m, c)) => {
                        let out = npb::run_opts_with(
                            k,
                            v,
                            m,
                            c,
                            &scale,
                            true,
                            remote.as_ref(),
                            chaos.as_ref(),
                        );
                        if tx.send(out).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            }));
        }
        drop(tx);
        let mut outcomes = Vec::with_capacity(total);
        for out in rx {
            if verbose {
                eprintln!(
                    "  [{}/{}] {} {:<16} {:<8} x{:<2} -> {} cycles ({:.3} ms simulated)",
                    outcomes.len() + 1,
                    total,
                    out.kernel,
                    out.variant.label(),
                    out.model.name(),
                    out.cores,
                    out.result.cycles,
                    out.result.runtime_secs() * 1e3,
                );
            }
            outcomes.push(out);
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
        // deterministic ordering for reports
        outcomes.sort_by_key(|o| {
            (
                o.kernel.name(),
                o.model.name(),
                o.cores,
                o.variant.label(),
            )
        });
        outcomes
    }
}

/// Find one outcome.
pub fn find<'a>(
    outs: &'a [RunOutcome],
    kernel: Kernel,
    variant: PaperVariant,
    model: CpuModel,
    cores: u32,
) -> Option<&'a RunOutcome> {
    outs.iter().find(|o| {
        o.kernel == kernel && o.variant == variant && o.model == model && o.cores == cores
    })
}

/// The paper-figure table for one (kernel, model): runtime per variant
/// per core count plus the two derived ratios the text quotes.
pub fn figure_table(
    outs: &[RunOutcome],
    kernel: Kernel,
    model: CpuModel,
    fig: &str,
) -> Table {
    let mut t = Table::new(
        &format!("{fig}: NAS {kernel} class W (scaled), Gem5-like {model} model"),
        &[
            "cores",
            "no-manual-opt [Mcyc]",
            "manual-opt [Mcyc]",
            "+HW [Mcyc]",
            "HW speedup vs unopt",
            "HW vs manual",
        ],
    );
    let mut cores: Vec<u32> = outs
        .iter()
        .filter(|o| o.kernel == kernel && o.model == model)
        .map(|o| o.cores)
        .collect();
    cores.sort_unstable();
    cores.dedup();
    for c in cores {
        let get = |v| find(outs, kernel, v, model, c).map(|o| o.result.cycles);
        let (u, m, h) = (
            get(PaperVariant::Unopt),
            get(PaperVariant::Manual),
            get(PaperVariant::Hw),
        );
        if let (Some(u), Some(m), Some(h)) = (u, m, h) {
            t.row(&[
                c.to_string(),
                fnum(u as f64 / 1e6, 2),
                fnum(m as f64 / 1e6, 2),
                fnum(h as f64 / 1e6, 2),
                format!("{:.2}x", u as f64 / h as f64),
                format!("{:+.1}%", (m as f64 / h as f64 - 1.0) * 100.0),
            ]);
        }
    }
    t
}

/// The per-array backend report: for every shared array of a
/// campaign's kernels, which [`AddressEngine`](crate::engine::AddressEngine)
/// backend the runtime selector's **cost model** prices cheapest at
/// the array's init-sized batch (an argmin over batch size × layout ×
/// available backends — *not* the pre-cost-model layout-only
/// heuristic), plus the selector's per-choice hit counters after
/// driving the kernel's host-side setup traffic — so every sweep
/// archives the backend mix that *actually* served it, not just the
/// per-array policy.
///
/// Column legend (also emitted in the table title):
///
/// * `pow2`   — is the layout all powers of two (the hardware gate)?
/// * `leon3`  — can the Leon3 coprocessor model serve the layout
///   (hardware gate + Figure-2 packed-pointer field widths)?
/// * `remote` — is the remote worker-process tier installed for this
///   report (it serves every layout — the workers run `AutoEngine`)?
/// * `engine` — the backend the cost model picks for one batch of
///   `nelems` requests;
/// * `hits`   — requests served per backend during the kernel's setup
///   traffic (`-` on per-array rows; the `(setup served by)` rows
///   carry the counters).
///
/// Builds each kernel once at the given scale — array layouts (and
/// thus pow2-ness) are scale-dependent, so there is no cheaper source
/// of truth; call this once per campaign, not per point.
pub fn engine_report(kernels: &[Kernel], cores: u32, scale: &Scale) -> Table {
    engine_report_with(kernels, cores, scale, None)
}

/// [`engine_report`] with an optional remote tier: when `Some`, every
/// built kernel's runtime gets a selector with the shared worker-
/// process pool installed (at the tier's pricing), so the `engine`
/// column and the `(setup served by)` hit rows reflect a matrix that
/// includes the `remote` backend — with forced service pricing the
/// setup traffic demonstrably lands there (the acceptance differential
/// in `rust/tests/remote_engine.rs` pins a nonzero `remote` hit row).
pub fn engine_report_with(
    kernels: &[Kernel],
    cores: u32,
    scale: &Scale,
    remote: Option<&RemoteTier>,
) -> Table {
    let leon3 = Leon3Engine::new();
    let mut t = Table::new(
        "AddressEngine selection (cost-model argmin over batch size x \
         layout x backends; hits = requests served per backend during \
         setup)",
        &[
            "kernel", "array", "blocksize", "elemsize", "nelems", "pow2",
            "leon3", "remote", "engine", "hits",
        ],
    );
    for &k in kernels {
        let threads = cores.min(k.max_cores());
        let mut built = npb::build(k, threads, SourceVariant::Unoptimized, scale);
        if let Some(tier) = remote {
            let mut sel = EngineSelector::new();
            tier.apply(&mut sel);
            built.rt.install_engine(sel);
        }
        let has_remote = if remote.is_some() { "yes" } else { "-" };
        for a in built.rt.arrays() {
            let choice = built.rt.engine().choice(&a.layout, a.nelems as usize);
            let pow2 = if a.layout.hw_supported() { "yes" } else { "no" };
            let l3 = if leon3.supports(&a.layout) { "yes" } else { "no" };
            t.row(&[
                k.name().into(),
                a.name.clone(),
                a.layout.blocksize.to_string(),
                a.layout.elemsize.to_string(),
                a.nelems.to_string(),
                pow2.into(),
                l3.into(),
                has_remote.into(),
                choice.name().into(),
                "-".into(),
            ]);
        }
        // Drive the kernel's host-side init through the selector and
        // archive which backends served it (per-choice hit counters).
        let mut mem = crate::mem::MemSystem::new(threads);
        built.rt.engine().reset_hits();
        (built.setup)(&built.rt, &mut mem);
        for (choice, hits) in built.rt.engine().hit_counts() {
            if hits > 0 {
                t.row(&[
                    k.name().into(),
                    "(setup served by)".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    choice.name().into(),
                    hits.to_string(),
                ]);
            }
        }
    }
    t
}

/// The per-run engine-mix-vs-speedup section: one row per validated
/// run, showing how that run's *dynamic* PGAS increments were served —
/// batched through which [`AddressEngine`](crate::engine::AddressEngine)
/// backend (the CPU pipelines' `Lookahead` windows) vs stepped scalar —
/// next to the HW-vs-unopt speedup at the same (kernel, model, cores)
/// point.  This closes the ROADMAP "engine-aware campaign scheduling"
/// item: `RunOutcome` records the mix per run, and this table plots
/// mix against speedup across the figures.
///
/// Only variants that execute hardware increments tally non-zero
/// counts (the software lowerings never emit `pgas_inc`, so their rows
/// show zero increments and a `-` backend mix by construction).
pub fn engine_mix_table(outs: &[RunOutcome]) -> Table {
    let mut t = Table::new(
        "Engine mix vs speedup (batched = dynamic PGAS increments served \
         by one AddressEngine call per lookahead window; speedup = \
         unopt/HW cycles at the same kernel/model/cores)",
        &[
            "kernel", "variant", "model", "cores", "batched incs",
            "scalar incs", "batched%", "runs by backend", "gather", "simd",
            "plan", "HW speedup",
        ],
    );
    for o in outs {
        let mix = o.engine_mix();
        // inspector/executor tier: plans executed and pointers bucketed
        // by owner ("-" when no window was gather-eligible)
        let g = o.result.gather;
        let gather = if g.plans > 0 {
            format!("{}p/{}", g.plans, g.bucketed_ptrs)
        } else {
            "-".into()
        };
        // vectorized tier: batches served and full-lane pointers ("-"
        // when no window crossed the serial/vector cutover)
        let s = o.result.simd;
        let simd = if s.batches > 0 {
            format!("{}b/{}", s.batches, s.lane_ptrs)
        } else {
            "-".into()
        };
        // cache-blocked planner: plans built and pointers tiled
        let p = o.result.plan;
        let plan = if p.plans > 0 {
            format!("{}p/{}", p.plans, p.planned_ptrs)
        } else {
            "-".into()
        };
        let speedup = if o.variant == PaperVariant::Hw {
            find(outs, o.kernel, PaperVariant::Unopt, o.model, o.cores)
                .map(|u| {
                    format!(
                        "{:.2}x",
                        u.result.cycles as f64 / o.result.cycles.max(1) as f64
                    )
                })
                .unwrap_or_else(|| "-".into())
        } else {
            "-".into()
        };
        t.row(&[
            o.kernel.name().into(),
            o.variant.label().into(),
            o.model.name().into(),
            o.cores.to_string(),
            mix.batched_incs.to_string(),
            mix.scalar_incs.to_string(),
            format!("{:.1}%", mix.batched_share() * 100.0),
            mix.runs_label(),
            gather,
            simd,
            plan,
            speedup,
        ]);
    }
    t
}

/// CSV archival of raw outcomes.
pub fn outcomes_csv(outs: &[RunOutcome]) -> String {
    let mut t = Table::new(
        "",
        &[
            "kernel", "variant", "model", "cores", "cycles", "instructions",
            "sim_ms", "hw_incs", "soft_incs", "hw_mems", "soft_mems",
            "gather_plans", "gather_ptrs", "simd_batches", "simd_lane_ptrs",
            "plan_plans", "plan_tiles",
        ],
    );
    for o in outs {
        t.row(&[
            o.kernel.name().into(),
            o.variant.label().into(),
            o.model.name().into(),
            o.cores.to_string(),
            o.result.cycles.to_string(),
            o.result.total.instructions.to_string(),
            fnum(o.result.runtime_secs() * 1e3, 4),
            o.compile_stats.hw_incs.to_string(),
            o.compile_stats.soft_incs.to_string(),
            o.compile_stats.hw_mems.to_string(),
            o.compile_stats.soft_mems.to_string(),
            o.result.gather.plans.to_string(),
            o.result.gather.bucketed_ptrs.to_string(),
            o.result.simd.batches.to_string(),
            o.result.simd.lane_ptrs.to_string(),
            o.result.plan.plans.to_string(),
            o.result.plan.tiles.to_string(),
        ]);
    }
    t.to_csv()
}

/// Summary of headline numbers across a campaign (the abstract's
/// claims): max HW speedup, and HW-vs-manual spread.
pub fn headline_summary(outs: &[RunOutcome]) -> Table {
    let mut t = Table::new(
        "Headline summary (paper abstract: up to 5.5x speedup; up to +10% over manual)",
        &["kernel", "model", "best HW speedup", "best HW vs manual", "worst HW vs manual"],
    );
    for &k in &Kernel::ALL {
        for &m in &CpuModel::ALL {
            let pts: Vec<&RunOutcome> = outs
                .iter()
                .filter(|o| o.kernel == k && o.model == m)
                .collect();
            if pts.is_empty() {
                continue;
            }
            let mut best_speedup: f64 = 0.0;
            let mut best_vs_manual = f64::NEG_INFINITY;
            let mut worst_vs_manual = f64::INFINITY;
            let mut any = false;
            let mut cores: Vec<u32> = pts.iter().map(|o| o.cores).collect();
            cores.sort_unstable();
            cores.dedup();
            for c in cores {
                let get = |v| find(outs, k, v, m, c).map(|o| o.result.cycles);
                if let (Some(u), Some(man), Some(h)) = (
                    get(PaperVariant::Unopt),
                    get(PaperVariant::Manual),
                    get(PaperVariant::Hw),
                ) {
                    any = true;
                    best_speedup = best_speedup.max(u as f64 / h as f64);
                    let vs = (man as f64 / h as f64 - 1.0) * 100.0;
                    best_vs_manual = best_vs_manual.max(vs);
                    worst_vs_manual = worst_vs_manual.min(vs);
                }
            }
            if any {
                t.row(&[
                    k.name().into(),
                    m.name().into(),
                    format!("{best_speedup:.2}x"),
                    format!("{best_vs_manual:+.1}%"),
                    format!("{worst_vs_manual:+.1}%"),
                ]);
            }
        }
    }
    t
}

/// Per-tenant summary of a daemon run (`pgas-hw daemon` prints this on
/// exit).  The title carries the shared-infrastructure aggregates —
/// queue admission/shedding and Leon3 lease traffic — that no single
/// tenant owns; rows are one per session plus an `all` total.
pub fn daemon_table(stats: &crate::daemon::DaemonStats) -> Table {
    let q = &stats.queue;
    let l = &stats.lease;
    let title = format!(
        "Daemon sessions (queue: {} admitted, {} shed on quota, {} shed on \
         capacity, max depth {}; leon3 lease: {} acquisitions, {} priority, \
         {} contended)",
        q.admitted,
        q.shed_quota,
        q.shed_capacity,
        q.max_depth,
        l.acquisitions,
        l.priority_acquisitions,
        l.contended,
    );
    let mut t = Table::new(
        &title,
        &[
            "tenant", "prio", "served", "installs", "epoch hits", "stale",
            "shed", "ptrs", "runs by backend",
        ],
    );
    let mut all_mix = crate::cpu::EngineMix::default();
    let mut all_ptrs = 0u64;
    for tn in &stats.tenants {
        all_mix.merge(&tn.mix);
        all_ptrs += tn.ptrs;
        t.row(&[
            tn.id.to_string(),
            if tn.priority { "yes" } else { "-" }.into(),
            tn.served.to_string(),
            tn.installs.to_string(),
            tn.epoch_hits.to_string(),
            tn.stale_epochs.to_string(),
            tn.shed.to_string(),
            tn.ptrs.to_string(),
            tn.mix.runs_label(),
        ]);
    }
    t.row(&[
        "all".into(),
        "-".into(),
        stats.served.to_string(),
        stats.installs.to_string(),
        stats.epoch_hits.to_string(),
        stats.stale_epochs.to_string(),
        stats.shed.to_string(),
        all_ptrs.to_string(),
        all_mix.runs_label(),
    ]);
    t
}

/// Per-tier health report of a chaos (or plain) run: one row per
/// backend tier that saw traffic or breaker activity, with the ladder
/// aggregates in the title.  `pgas-hw run/sweep --chaos` print this
/// next to the figure tables so the degradation a seeded storm caused
/// is visible beside the (unchanged) simulated results.
pub fn health_table(h: &HealthStats) -> Table {
    let title = format!(
        "Engine health ({} dispatches, {} fallback re-serves, {} deadline \
         misses, {} injected faults, {} tier(s) quarantined)",
        h.dispatches,
        h.fallback_runs,
        h.deadline_misses,
        h.injected_faults,
        h.quarantined(),
    );
    let mut t = Table::new(
        &title,
        &[
            "tier", "successes", "failures", "fail%", "trips", "probes",
            "breaker",
        ],
    );
    for choice in EngineChoice::ALL {
        let tier = &h.tiers[choice.index()];
        let total = tier.successes + tier.failures;
        if total == 0 && tier.trips == 0 {
            continue; // never dispatched to, nothing to report
        }
        t.row(&[
            choice.name().into(),
            tier.successes.to_string(),
            tier.failures.to_string(),
            fnum(tier.failures as f64 / total.max(1) as f64 * 100.0, 1),
            tier.trips.to_string(),
            tier.probes.to_string(),
            tier.state.name().into(),
        ]);
    }
    t
}

/// The `pgas-hw lint` summary: one row per linted kernel with its
/// phase/site census, diagnostic counts, and the static engine-mix
/// prediction (the categories the differential suite checks against
/// runtime telemetry).
pub fn lint_table(reports: &[crate::analysis::LintReport]) -> Table {
    let mut t = Table::new(
        "Static PGAS access analysis (pgas-hw lint)",
        &[
            "kernel", "threads", "phases", "sites", "errors", "warnings",
            "windows", "batchable", "scalar", "gather", "codes",
        ],
    );
    for r in reports {
        let codes = r.codes().join(",");
        t.row(&[
            r.kernel.clone(),
            r.threads.to_string(),
            r.phases.to_string(),
            r.sites.to_string(),
            r.errors().to_string(),
            r.warnings().to_string(),
            r.predicted.windows.to_string(),
            r.predicted.batchable_incs.to_string(),
            r.predicted.scalar_incs.to_string(),
            r.predicted.gather_windows.to_string(),
            if codes.is_empty() { "-".into() } else { codes },
        ]);
    }
    t
}

/// Shared driver for the per-figure `cargo bench` targets: regenerate
/// the figure's table at bench scale, then wall-time the representative
/// point with the micro-bench harness.
pub fn bench_figure(
    fig: &str,
    kernel: Kernel,
    models: &[CpuModel],
    cores: &[u32],
    scale: Scale,
) {
    run_figure_campaign(fig, kernel, models, cores, scale);
    // harness timing of the representative mid-size point
    let mid = cores[cores.len() / 2].min(kernel.max_cores());
    for v in PaperVariant::ALL {
        crate::util::bench::bench(
            &format!("{kernel} {} {} x{mid}", v.label(), models[0]),
            1,
            3,
            || {
                crate::util::bench::black_box(npb::run(
                    kernel, v, models[0], mid, &scale,
                ));
            },
        );
    }
}

/// Shared prefix of the figure bench drivers: run the campaign for one
/// kernel across `models` × `cores` × all variants, print each model's
/// figure table, the engine-mix-vs-speedup section and the elapsed
/// line, and hand the validated outcomes back.
fn run_figure_campaign(
    fig: &str,
    kernel: Kernel,
    models: &[CpuModel],
    cores: &[u32],
    scale: Scale,
) -> Vec<RunOutcome> {
    let campaign = Campaign {
        kernels: vec![kernel],
        models: models.to_vec(),
        cores: cores.to_vec(),
        variants: PaperVariant::ALL.to_vec(),
        scale,
        jobs: Campaign::default().jobs,
        chaos: None,
    };
    let t0 = std::time::Instant::now();
    let outs = campaign.run(false);
    for &m in models {
        println!("{}", figure_table(&outs, kernel, m, fig).render());
    }
    println!("{}", engine_mix_table(&outs).render());
    println!(
        "figure regenerated from {} validated runs in {:.2}s\n",
        outs.len(),
        t0.elapsed().as_secs_f64()
    );
    outs
}

/// Driver for the timing/detailed figure benches (figs 11–14):
/// regenerate the figure tables and the engine-mix section, then
/// compare the representative HW point per model with lookahead
/// batching on (reused from the campaign) vs off (a fresh scalar
/// reference run), merging `sim_batched_cycles` / `sim_scalar_cycles`
/// into `BENCH_engine.json` under `json_key` and **panicking if the
/// batched and scalar cycle totals diverge in either direction** — the
/// CI bench-smoke gate: the whole point of the lookahead design is
/// that batching changes host throughput, never simulated time.
///
/// `--quick` (the CI smoke shape) shrinks the campaign to two core
/// counts and a 4x coarser scale.
pub fn bench_models_figure(
    fig: &str,
    json_key: &str,
    kernel: Kernel,
    models: &[CpuModel],
    cores: &[u32],
    scale: Scale,
) {
    let quick = std::env::args().any(|a| a == "--quick");
    let (cores, scale) = if quick {
        let hi = cores.iter().copied().max().unwrap_or(4).min(4);
        (vec![1, hi], Scale { factor: scale.factor.saturating_mul(4) })
    } else {
        (cores.to_vec(), scale)
    };
    let outs = run_figure_campaign(fig, kernel, models, &cores, scale);

    // Batched-vs-scalar differential at the representative point.  The
    // campaign above already simulated it with lookahead on (the
    // default), so the batched leg is a lookup; only the scalar
    // reference re-simulates.
    let mid = cores[cores.len() / 2].min(kernel.max_cores());
    let mut model_rows = Vec::new();
    let mut regressed = Vec::new();
    for &m in models {
        let batched = find(&outs, kernel, PaperVariant::Hw, m, mid)
            .expect("campaign covered the representative point");
        let scalar =
            npb::run_lookahead(kernel, PaperVariant::Hw, m, mid, &scale, false);
        let (b, s) = (batched.result.cycles, scalar.result.cycles);
        let mix = batched.engine_mix();
        println!(
            "  {m} x{mid}: sim_batched_cycles={b} sim_scalar_cycles={s} \
             (batched {}/{} incs, {:.1}%)",
            mix.batched_incs,
            mix.batched_incs + mix.scalar_incs,
            mix.batched_share() * 100.0
        );
        // Event replay promises *equality*, so any inequality is a bug:
        // batched > scalar means batching costs simulated time, and
        // batched < scalar means replay dropped a timing event.
        if b != s {
            regressed.push(format!("{m}: batched {b} != scalar {s}"));
        }
        model_rows.push(format!(
            "{{\"model\": \"{}\", \"sim_batched_cycles\": {b}, \
             \"sim_scalar_cycles\": {s}, \"batched_incs\": {}, \
             \"scalar_incs\": {}}}",
            m.name(),
            mix.batched_incs,
            mix.scalar_incs
        ));
    }
    let section = format!(
        "{{\"kernel\": \"{}\", \"variant\": \"hw\", \"cores\": {mid}, \
         \"scale\": {}, \"models\": [{}]}}",
        kernel.name(),
        scale.factor,
        model_rows.join(", ")
    );
    crate::util::bench::merge_bench_json("BENCH_engine.json", json_key, &section);
    println!("merged `{json_key}` into BENCH_engine.json");
    assert!(
        regressed.is_empty(),
        "batched cycle counts diverged from scalar stepping: {regressed:?}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn points_respect_ft_limit() {
        let c = Campaign {
            kernels: vec![Kernel::Ft, Kernel::Ep],
            cores: vec![16, 32],
            models: vec![CpuModel::Atomic],
            variants: vec![PaperVariant::Unopt],
            scale: Scale::quick(),
            jobs: 1,
            chaos: None,
        };
        let pts = c.points();
        assert!(pts.iter().any(|p| p.0 == Kernel::Ft && p.3 == 16));
        assert!(!pts.iter().any(|p| p.0 == Kernel::Ft && p.3 == 32));
        assert!(pts.iter().any(|p| p.0 == Kernel::Ep && p.3 == 32));
    }

    #[test]
    fn engine_report_mixes_pow2_and_software() {
        // CG carries the non-pow2 w_tmp array -> software fallback;
        // its pow2 arrays (e.g. the gsum cell) stay on the fast path.
        let t = engine_report(&[Kernel::Cg], 4, &Scale::quick());
        assert!(!t.is_empty());
        let rendered = t.render();
        // the legend describes the cost-model semantics, not the old
        // layout-only heuristic
        assert!(rendered.contains("cost-model argmin"), "{rendered}");
        assert!(rendered.contains("leon3"), "{rendered}");
        // the remote capability column renders even with no pool
        // installed (the tier-enabled legs live in remote_engine.rs)
        assert!(rendered.contains("remote"), "{rendered}");
        assert!(
            rendered
                .lines()
                .any(|l| l.contains("cg_wtmp") && l.contains("software")),
            "{rendered}"
        );
        assert!(
            rendered
                .lines()
                .any(|l| l.contains("cg_gsum") && l.contains("pow2")),
            "{rendered}"
        );
        // the hit-counter rows archive the mix that served CG's setup
        assert!(
            rendered.lines().any(|l| l.contains("(setup served by)")),
            "{rendered}"
        );
    }

    #[test]
    fn tiny_campaign_runs_and_reports() {
        let c = Campaign {
            kernels: vec![Kernel::Ep],
            cores: vec![2],
            models: vec![CpuModel::Atomic],
            variants: PaperVariant::ALL.to_vec(),
            scale: Scale { factor: 4096 },
            jobs: 2,
            chaos: None,
        };
        let outs = c.run(false);
        assert_eq!(outs.len(), 3);
        let tab = figure_table(&outs, Kernel::Ep, CpuModel::Atomic, "Fig 6");
        assert!(!tab.is_empty());
        let csv = outcomes_csv(&outs);
        assert!(csv.lines().count() == 4);
        assert!(!headline_summary(&outs).is_empty());
        // the engine-mix section has one row per run, with the HW
        // speedup resolved against the unopt row
        let mix = engine_mix_table(&outs);
        assert!(!mix.is_empty());
        let rendered = mix.render();
        assert!(
            rendered
                .lines()
                .any(|l| l.contains("no-manual-opt+HW") && l.contains('x')),
            "HW rows must resolve a speedup: {rendered}"
        );
    }
}
