//! FPGA resource model — regenerates the paper's Table 4 (area cost of
//! the PGAS hardware support on a Virtex-6 XC6VLX240T).
//!
//! The model is a structural bill of materials: each sub-unit of the
//! coprocessor (Figure 5) carries a resource vector derived from its
//! datapath widths, and the table rows are sums.  The base Leon3 4-core
//! system is taken from the paper's own synthesis numbers (it is the
//! baseline being compared against, not a contribution).

use crate::util::table::Table;

/// An FPGA resource vector.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Resources {
    pub registers: u32,
    pub luts: u32,
    pub bram18: u32,
    pub bram36: u32,
    pub dsp48: u32,
}

impl Resources {
    pub const fn new(registers: u32, luts: u32, bram18: u32, bram36: u32, dsp48: u32) -> Self {
        Self { registers, luts, bram18, bram36, dsp48 }
    }

    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            registers: self.registers + o.registers,
            luts: self.luts + o.luts,
            bram18: self.bram18 + o.bram18,
            bram36: self.bram36 + o.bram36,
            dsp48: self.dsp48 + o.dsp48,
        }
    }

    pub fn scale(&self, n: u32) -> Resources {
        Resources {
            registers: self.registers * n,
            luts: self.luts * n,
            bram18: self.bram18 * n,
            bram36: self.bram36 * n,
            dsp48: self.dsp48 * n,
        }
    }
}

/// One named sub-unit with a replication count.
#[derive(Clone, Debug)]
pub struct Component {
    pub name: String,
    pub unit: Resources,
    pub count: u32,
}

impl Component {
    pub fn new(name: &str, unit: Resources, count: u32) -> Self {
        Self { name: name.to_string(), unit, count }
    }

    pub fn total(&self) -> Resources {
        self.unit.scale(self.count)
    }
}

/// Base 4-core Leon3 SMP (paper Table 4, first row — synthesis ground
/// truth for the baseline).
pub fn leon3_base_4core() -> Resources {
    Resources::new(46_718, 59_235, 106, 34, 16)
}

/// Virtex-6 XC6VLX240T device capacity (paper Table 4, third row).
pub fn virtex6_capacity() -> Resources {
    Resources::new(301_440, 150_720, 832, 416, 768)
}

/// The PGAS support unit of one core, decomposed per Figure 5.
///
/// Derivations (64-bit datapath, 2-stage pipeline):
/// * shared-pointer register file — 32 × 64-bit, 2R1W like the Leon3
///   FPU file: 4 × RAMB18 (duplicated banks for the second read port);
/// * base-address LUT — 64 × 64-bit dual-port: 1 × RAMB18;
/// * stage 1 (phase add, /blocksize shift-mask network): 64-bit adder +
///   barrel shifter + masks, ~196 flops of inter-stage latch;
/// * stage 2 (/THREADS shift-mask, eaddr multiply-shift, va add):
///   the eaddr×elemsize product uses 2 DSP48E slices (the paper's +8
///   DSPs over 4 cores), plus the output latches;
/// * locality comparator + condition-code logic;
/// * pipeline/decode glue in the integer-unit interface.
pub fn pgas_unit_components() -> Vec<Component> {
    vec![
        Component::new(
            "shared-pointer register file (32x64b, 2R1W)",
            Resources::new(42, 96, 4, 0, 0),
            1,
        ),
        Component::new(
            "base-address LUT (64x64b dual-port)",
            Resources::new(18, 40, 1, 0, 0),
            1,
        ),
        Component::new(
            "stage 1: phase adder + blocksize shift/mask",
            Resources::new(196, 258, 0, 0, 0),
            1,
        ),
        Component::new(
            "stage 2: thread wrap + eaddr scale + va add",
            Resources::new(226, 278, 0, 0, 2),
            1,
        ),
        Component::new(
            "locality comparator + condition codes",
            Resources::new(52, 66, 0, 0, 0),
            1,
        ),
        Component::new(
            "pipeline decode/interface glue",
            Resources::new(116, 96, 0, 0, 0),
            1,
        ),
    ]
}

/// Per-core total of the PGAS unit.
pub fn pgas_unit_per_core() -> Resources {
    pgas_unit_components()
        .iter()
        .fold(Resources::default(), |acc, c| acc.add(&c.total()))
}

/// Bus-side glue shared by the 4-core system (arbiter hooks for the
/// base-table broadcast writes).
pub fn pgas_shared_glue() -> Resources {
    Resources::new(7, 1, 0, 0, 0)
}

/// Total increase for an `n`-core system.
pub fn pgas_support_total(cores: u32) -> Resources {
    pgas_unit_per_core().scale(cores).add(&pgas_shared_glue())
}

/// Render Table 4 for a 4-core system.
pub fn table4() -> Table {
    let base = leon3_base_4core();
    let inc = pgas_support_total(4);
    let with = base.add(&inc);
    let dev = virtex6_capacity();
    let pct = |a: u32, b: u32| format!("+{:.1}%", 100.0 * a as f64 / b as f64);
    let mut t = Table::new(
        "Table 4: Area cost evaluation for the hardware support (Virtex-6 XC6VLX240T)",
        &["Configuration", "Registers", "LUTs", "BRAM 18kB", "BRAM 36kB", "DSP48Es"],
    );
    let row = |t: &mut Table, name: &str, r: &Resources| {
        t.row(&[
            name.into(),
            r.registers.to_string(),
            r.luts.to_string(),
            r.bram18.to_string(),
            r.bram36.to_string(),
            r.dsp48.to_string(),
        ]);
    };
    row(&mut t, "Leon3, 4 cores", &base);
    row(&mut t, "Leon3, 4 cores + PGAS hardware support", &with);
    row(&mut t, "Virtex 6 - XC6VLX240T", &dev);
    row(&mut t, "Increase", &inc);
    t.row(&[
        "Area increase, % of base".into(),
        pct(inc.registers, base.registers),
        pct(inc.luts, base.luts),
        pct(inc.bram18, base.bram18),
        "".into(),
        pct(inc.dsp48, base.dsp48),
    ]);
    t.row(&[
        "Area % of Virtex 6".into(),
        pct(inc.registers, dev.registers),
        pct(inc.luts, dev.luts),
        pct(inc.bram18, dev.bram18),
        "".into(),
        pct(inc.dsp48, dev.dsp48),
    ]);
    t
}

/// Detailed per-component breakdown (beyond the paper: the BOM that
/// produces the Increase row).
pub fn component_breakdown() -> Table {
    let mut t = Table::new(
        "PGAS support unit: per-core component breakdown",
        &["Component", "Registers", "LUTs", "BRAM18", "DSP48"],
    );
    for c in pgas_unit_components() {
        let r = c.total();
        t.row(&[
            c.name.clone(),
            r.registers.to_string(),
            r.luts.to_string(),
            r.bram18.to_string(),
            r.dsp48.to_string(),
        ]);
    }
    let total = pgas_unit_per_core();
    t.row(&[
        "TOTAL per core".into(),
        total.registers.to_string(),
        total.luts.to_string(),
        total.bram18.to_string(),
        total.dsp48.to_string(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The BOM must reproduce the paper's Increase row exactly.
    #[test]
    fn increase_matches_table4() {
        let inc = pgas_support_total(4);
        assert_eq!(inc.registers, 2_607);
        assert_eq!(inc.luts, 3_337);
        assert_eq!(inc.bram18, 20);
        assert_eq!(inc.bram36, 0);
        assert_eq!(inc.dsp48, 8);
    }

    #[test]
    fn percentages_match_paper() {
        let base = leon3_base_4core();
        let inc = pgas_support_total(4);
        let dev = virtex6_capacity();
        // paper: +5.6% regs/LUTs, +18.9% BRAM, +50% DSP of base;
        // 0.9% / 2.2% / 2.4% / 1.0% of the chip
        let p = |a: u32, b: u32| 100.0 * a as f64 / b as f64;
        assert!((p(inc.registers, base.registers) - 5.6).abs() < 0.1);
        assert!((p(inc.luts, base.luts) - 5.6).abs() < 0.1);
        assert!((p(inc.bram18, base.bram18) - 18.9).abs() < 0.1);
        assert!((p(inc.dsp48, base.dsp48) - 50.0).abs() < 0.1);
        assert!((p(inc.registers, dev.registers) - 0.9).abs() < 0.1);
        assert!((p(inc.luts, dev.luts) - 2.2).abs() < 0.1);
        assert!((p(inc.bram18, dev.bram18) - 2.4).abs() < 0.1);
        assert!((p(inc.dsp48, dev.dsp48) - 1.0).abs() < 0.1);
    }

    #[test]
    fn under_2_4_percent_of_chip() {
        // the paper's headline area claim
        let inc = pgas_support_total(4);
        let dev = virtex6_capacity();
        // paper: "utilizes less than 2.4% of the overall FPGA chip"
        // (their own BRAM figure rounds to exactly 2.4%)
        assert!(inc.registers as f64 / dev.registers as f64 <= 0.0245);
        assert!(inc.luts as f64 / dev.luts as f64 <= 0.0245);
        assert!(inc.bram18 as f64 / dev.bram18 as f64 <= 0.0245);
        assert!(inc.dsp48 as f64 / dev.dsp48 as f64 <= 0.0245);
    }

    #[test]
    fn table_renders() {
        let s = table4().render();
        assert!(s.contains("46718") || s.contains("46,718") || s.contains("46718"));
        assert!(s.contains("+5.6%"));
        assert!(s.contains("+50.0%"));
        let b = component_breakdown().render();
        assert!(b.contains("TOTAL per core"));
    }
}
