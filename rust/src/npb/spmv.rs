//! SPMV — sparse matrix-vector product, CSR with a fixed row degree
//! (irregular gather).
//!
//! Structure follows the distributed SpMV kernels studied by the
//! inspector/executor literature (arXiv 2303.13954): the matrix rows
//! are blocked across threads together with their column-index and
//! value arrays and the output vector, while the *source* vector `x`
//! is gathered through data-dependent column indices — the one access
//! stream that crosses thread boundaries.  The manual optimization
//! privatizes the row-local streams (indices, values, output) but the
//! `x` gather stays on shared-pointer arithmetic in every variant,
//! so — as with MD — HW support beats the manual optimization.
//!
//! Each row compiles to `ROW_NZ` consecutive `sptr_at` lanes (one
//! `PgasIncR` each under HW lowering): a single multi-owner lookahead
//! window that the engine's [`GatherPlan`](crate::engine::GatherPlan)
//! buckets by owning thread.

use super::{BuiltKernel, Scale};
use crate::compiler::{IrBuilder, SourceVariant, Val};
use crate::isa::{IntOp, MemWidth};
use crate::upc::UpcRuntime;
use crate::util::rng::Xoshiro256;

/// Class-W-like row count (scaled down via `Scale`).
const CLASS_W_ROWS: u64 = 1 << 16;
/// Nonzeros per row (fixed-degree CSR keeps the IR loop regular while
/// the *indices* stay irregular; pow2 so the flattened arrays are
/// HW-mappable).
const ROW_NZ: u64 = 8;
/// Matrix/vector entries stay below this so u64 dot products never
/// wrap: ROW_NZ * VAL_RANGE^2 < 2^64.
const VAL_RANGE: u64 = 1 << 10;

fn host_data(n: u64) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
    let mut rng = Xoshiro256::new(0x59A7_0001);
    let cols: Vec<u64> = (0..n * ROW_NZ).map(|_| rng.below(n)).collect();
    let vals: Vec<u64> = (0..n * ROW_NZ).map(|_| rng.below(VAL_RANGE)).collect();
    let x: Vec<u64> = (0..n).map(|_| rng.below(VAL_RANGE)).collect();
    (cols, vals, x)
}

pub fn build(threads: u32, source: SourceVariant, scale: &Scale) -> BuiltKernel {
    let n = scale.dim(CLASS_W_ROWS, 256).next_power_of_two();
    let chunk = n / threads as u64;
    assert!(chunk >= 1, "more threads than matrix rows");

    let mut rt = UpcRuntime::new(threads);
    // row-local streams: thread t owns rows [t*chunk, (t+1)*chunk)
    let cols = rt.alloc_shared("sp_cols", chunk * ROW_NZ, 8, n * ROW_NZ);
    let vals = rt.alloc_shared("sp_vals", chunk * ROW_NZ, 8, n * ROW_NZ);
    let y = rt.alloc_shared("sp_y", chunk, 8, n);
    // the gathered source vector, same blocking as the rows
    let x = rt.alloc_shared("sp_x", chunk, 8, n);

    let mut b = IrBuilder::new(&mut rt);

    // Loop-invariant gather base: &x[0] (see md.rs).
    let bx = b.sptr_init(x, Val::I(0));

    match source {
        SourceVariant::Unoptimized => {
            let myt = b.mythread();
            let rstart = b.it();
            b.bin(IntOp::Mul, rstart, myt, Val::I(chunk as i64));
            let estart = b.it();
            b.bin(IntOp::Mul, estart, myt, Val::I((chunk * ROW_NZ) as i64));
            let pc = b.sptr_init(cols, Val::R(estart));
            let pv = b.sptr_init(vals, Val::R(estart));
            let py = b.sptr_init(y, Val::R(rstart));
            b.free_i(estart);
            b.free_i(rstart);
            b.free_i(myt);
            b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                let j: Vec<u8> = (0..ROW_NZ).map(|_| b.it()).collect();
                for (g, &jg) in j.iter().enumerate() {
                    b.sptr_ld(MemWidth::U64, jg, pc, (g * 8) as i16);
                }
                // ROW_NZ consecutive gather lanes — one batchable
                // PgasIncR run under HW lowering
                for &jg in &j {
                    b.sptr_at(jg, bx, x, Val::R(jg));
                }
                let acc = b.iconst(0);
                for (g, &jg) in j.iter().enumerate() {
                    let xv = b.it();
                    b.sptr_ld(MemWidth::U64, xv, jg, 0);
                    let av = b.it();
                    b.sptr_ld(MemWidth::U64, av, pv, (g * 8) as i16);
                    b.bin(IntOp::Mul, xv, xv, Val::R(av));
                    b.bin(IntOp::Add, acc, acc, Val::R(xv));
                    b.free_i(av);
                    b.free_i(xv);
                }
                b.sptr_st(MemWidth::U64, acc, py, 0);
                b.free_i(acc);
                for &jg in j.iter().rev() {
                    b.free_i(jg);
                }
                b.sptr_inc(py, y, Val::I(1));
                b.sptr_inc(pc, cols, Val::I(ROW_NZ as i64));
                b.sptr_inc(pv, vals, Val::I(ROW_NZ as i64));
            });
            b.free_i(py);
            b.free_i(pv);
            b.free_i(pc);
        }
        SourceVariant::Privatized => {
            // hand-optimized: row-local streams through raw pointers;
            // the x gather is data-dependent and stays shared
            let cc = b.local_addr(cols, Val::I(0));
            let cv = b.local_addr(vals, Val::I(0));
            let cy = b.local_addr(y, Val::I(0));
            b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                let j: Vec<u8> = (0..ROW_NZ).map(|_| b.it()).collect();
                for (g, &jg) in j.iter().enumerate() {
                    b.ld(MemWidth::U64, jg, cc, (g * 8) as i32);
                }
                for &jg in &j {
                    b.sptr_at(jg, bx, x, Val::R(jg));
                }
                let acc = b.iconst(0);
                for (g, &jg) in j.iter().enumerate() {
                    let xv = b.it();
                    b.sptr_ld(MemWidth::U64, xv, jg, 0);
                    let av = b.it();
                    b.ld(MemWidth::U64, av, cv, (g * 8) as i32);
                    b.bin(IntOp::Mul, xv, xv, Val::R(av));
                    b.bin(IntOp::Add, acc, acc, Val::R(xv));
                    b.free_i(av);
                    b.free_i(xv);
                }
                b.st(MemWidth::U64, acc, cy, 0);
                b.free_i(acc);
                for &jg in j.iter().rev() {
                    b.free_i(jg);
                }
                b.add(cc, cc, Val::I((ROW_NZ * 8) as i64));
                b.add(cv, cv, Val::I((ROW_NZ * 8) as i64));
                b.add(cy, cy, Val::I(8));
            });
            b.free_i(cy);
            b.free_i(cv);
            b.free_i(cc);
        }
    }
    b.free_i(bx);

    let module = b.finish("spmv");

    let (cols_h, vals_h, x_h) = host_data(n);
    let (cs, vs, xs) = (cols_h.clone(), vals_h.clone(), x_h.clone());
    let setup = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        rt.write_u64_seq(mem, cols, 0, &cs);
        rt.write_u64_seq(mem, vals, 0, &vs);
        rt.write_u64_seq(mem, x, 0, &xs);
    });

    let validate = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        let got = rt.read_u64_seq(mem, y, 0, n as usize);
        for r in 0..n as usize {
            let want: u64 = (0..ROW_NZ as usize)
                .map(|g| {
                    let e = r * ROW_NZ as usize + g;
                    vals_h[e] * x_h[cols_h[e] as usize]
                })
                .sum();
            if got[r] != want {
                return Err(format!("y[{r}]: got {}, want {want}", got[r]));
            }
        }
        Ok(())
    });

    BuiltKernel { rt, module, setup, validate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::CpuModel;
    use crate::npb::{run, Kernel, PaperVariant};

    #[test]
    fn spmv_validates_in_all_variants() {
        let scale = Scale { factor: 512 };
        for v in PaperVariant::ALL {
            let out = run(Kernel::Spmv, v, CpuModel::Atomic, 4, &scale);
            assert!(out.result.cycles > 0, "{v:?}");
        }
    }

    #[test]
    fn spmv_hw_beats_manual_on_irregular_gather() {
        let scale = Scale { factor: 512 };
        let t = 4;
        let unopt = run(Kernel::Spmv, PaperVariant::Unopt, CpuModel::Atomic, t, &scale);
        let manual = run(Kernel::Spmv, PaperVariant::Manual, CpuModel::Atomic, t, &scale);
        let hw = run(Kernel::Spmv, PaperVariant::Hw, CpuModel::Atomic, t, &scale);
        let (cu, cm, ch) = (
            unopt.result.cycles as f64,
            manual.result.cycles as f64,
            hw.result.cycles as f64,
        );
        assert!(cu / ch > 2.0, "SPMV hw speedup {:.2} too small", cu / ch);
        assert!(ch < cm, "hw ({ch}) should beat manual ({cm}) on SPMV");
    }

    #[test]
    fn spmv_hw_run_exercises_the_gather_planner() {
        let scale = Scale { factor: 512 };
        let out = run(Kernel::Spmv, PaperVariant::Hw, CpuModel::Atomic, 4, &scale);
        let g = out.result.gather;
        assert!(g.plans > 0, "multi-owner gather windows should be planned: {g:?}");
        assert!(out.result.engine_mix.batched_incs > 0);
    }
}
