//! IS — Integer Sort (bucket/counting sort of small integers).
//!
//! Structure follows the UPC NPB IS: each thread histograms its own
//! blocked chunk of the key array into its own slice of a shared
//! histogram (phase 1), then after a barrier the threads cooperatively
//! reduce the per-thread histograms into global bucket counts (phase 2,
//! inherently remote).  The manual optimization privatizes phase 1 (own
//! chunk, own histogram slice are affinity-local); phase 2 cannot be
//! privatized and stays on shared pointers in every variant.
//!
//! Paper shape (Figs. 9/13): HW ≈ 3× over unoptimized, but ~13% behind
//! the privatized code — phase 1 is store-heavy and every HW store pays
//! the volatile-asm reload (see `CompileOpts::volatile_stores`).

use super::{BuiltKernel, Scale};
use crate::compiler::{IrBuilder, SourceVariant, Val};
use crate::isa::{IntOp, MemWidth};
use crate::upc::UpcRuntime;
use crate::util::rng::Xoshiro256;

/// class W: 2^20 keys.
const CLASS_W_KEYS: u64 = 1 << 20;
/// Bucket count (scaled-down key range).
const NBUCKETS: u64 = 512;

fn host_keys(n: u64) -> Vec<u32> {
    let mut rng = Xoshiro256::new(0x15AB_0001);
    (0..n).map(|_| rng.below(NBUCKETS) as u32).collect()
}

pub fn build(threads: u32, source: SourceVariant, scale: &Scale) -> BuiltKernel {
    let n = scale.dim(CLASS_W_KEYS, 1 << 10).next_power_of_two();
    let chunk = n / threads as u64;
    assert!(chunk >= 1);
    let kb = NBUCKETS / threads as u64; // buckets ranked per thread
    assert!(kb >= 1, "too many threads for {NBUCKETS} buckets");

    let mut rt = UpcRuntime::new(threads);
    // keys: blocked so thread t owns keys[t*chunk .. (t+1)*chunk)
    let keys = rt.alloc_shared("is_keys", chunk, 4, n);
    // per-thread histograms: thread t owns hist[t*NB .. (t+1)*NB)
    let hist = rt.alloc_shared("is_hist", NBUCKETS, 8, NBUCKETS * threads as u64);
    // global bucket totals, cyclic
    let totals = rt.alloc_shared("is_totals", 1, 8, NBUCKETS);

    let mut b = IrBuilder::new(&mut rt);
    let myt = b.mythread();

    // ---- zero own histogram slice ----
    match source {
        SourceVariant::Unoptimized => {
            let base = b.it();
            b.bin(IntOp::Mul, base, myt, Val::I(NBUCKETS as i64));
            let ph = b.sptr_init(hist, Val::R(base));
            let zero = b.iconst(0);
            b.for_range(Val::I(0), Val::I(NBUCKETS as i64), 1, |b, _| {
                b.sptr_st(MemWidth::U64, zero, ph, 0);
                b.sptr_inc(ph, hist, Val::I(1));
            });
            b.free_i(zero);
            b.free_i(ph);
            b.free_i(base);
        }
        SourceVariant::Privatized => {
            let cur = b.local_addr(hist, Val::I(0));
            let zero = b.iconst(0);
            b.for_range(Val::I(0), Val::I(NBUCKETS as i64), 1, |b, _| {
                b.st(MemWidth::U64, zero, cur, 0);
                b.add(cur, cur, Val::I(8));
            });
            b.free_i(zero);
            b.free_i(cur);
        }
    }
    b.barrier();

    // ---- phase 1: histogram own chunk ----
    match source {
        SourceVariant::Unoptimized => {
            // walk own chunk through a shared pointer; update the
            // histogram through per-key shared pointer arithmetic
            let start = b.it();
            b.bin(IntOp::Mul, start, myt, Val::I(chunk as i64));
            let pk = b.sptr_init(keys, Val::R(start));
            let hbase = b.it();
            b.bin(IntOp::Mul, hbase, myt, Val::I(NBUCKETS as i64));
            b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                let key = b.it();
                b.sptr_ld(MemWidth::U32, key, pk, 0);
                b.bin(IntOp::Add, key, key, Val::R(hbase));
                // hist[myt*NB + key] += 1  (fresh pointer per access,
                // as the unoptimized `hist[idx]++` compiles)
                let ph = b.sptr_init(hist, Val::R(key));
                let c = b.it();
                b.sptr_ld(MemWidth::U64, c, ph, 0);
                b.bin(IntOp::Add, c, c, Val::I(1));
                b.sptr_st(MemWidth::U64, c, ph, 0);
                b.free_i(c);
                b.free_i(ph);
                b.free_i(key);
                b.sptr_inc(pk, keys, Val::I(1));
            });
            b.free_i(hbase);
            b.free_i(pk);
            b.free_i(start);
        }
        SourceVariant::Privatized => {
            // both the chunk and the histogram slice are local: raw
            // pointers (the hand-optimized IS)
            let ck = b.local_addr(keys, Val::I(0));
            let hb = b.local_addr(hist, Val::I(0));
            b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                let key = b.it();
                b.ld(MemWidth::U32, key, ck, 0);
                b.bin(IntOp::Sll, key, key, Val::I(3));
                let ha = b.it();
                b.bin(IntOp::Add, ha, hb, Val::R(key));
                let c = b.it();
                b.ld(MemWidth::U64, c, ha, 0);
                b.bin(IntOp::Add, c, c, Val::I(1));
                b.st(MemWidth::U64, c, ha, 0);
                b.free_i(c);
                b.free_i(ha);
                b.free_i(key);
                b.add(ck, ck, Val::I(4));
            });
            b.free_i(hb);
            b.free_i(ck);
        }
    }
    b.barrier();

    // ---- phase 2: rank my bucket range (remote reads) ----
    match source {
        SourceVariant::Unoptimized => {
            // per-bucket stride-NBUCKETS shared-pointer walk
            let kstart = b.it();
            b.bin(IntOp::Mul, kstart, myt, Val::I(kb as i64));
            let kend = b.it();
            b.bin(IntOp::Add, kend, kstart, Val::I(kb as i64));
            // running output pointer over totals[kstart..kend)
            let pt = b.sptr_init(totals, Val::R(kstart));
            let nt = b.threads();
            b.for_range(Val::R(kstart), Val::R(kend), 1, |b, k| {
                let acc = b.iconst(0);
                // sum hist[u*NB + k] over u — stride NBUCKETS walk
                let ph = b.sptr_init(hist, Val::R(k));
                b.for_range(Val::I(0), Val::R(nt), 1, |b, _| {
                    let v = b.it();
                    b.sptr_ld(MemWidth::U64, v, ph, 0);
                    b.bin(IntOp::Add, acc, acc, Val::R(v));
                    b.sptr_inc(ph, hist, Val::I(NBUCKETS as i64));
                    b.free_i(v);
                });
                b.sptr_st(MemWidth::U64, acc, pt, 0);
                b.sptr_inc(pt, totals, Val::I(1));
                b.free_i(ph);
                b.free_i(acc);
            });
            b.free_i(nt);
            b.free_i(pt);
            b.free_i(kend);
            b.free_i(kstart);
        }
        SourceVariant::Privatized => {
            // the hand-tuned IS bulk-copies each thread's histogram
            // slice (upc_memget / raw-cast on SMP) and reduces in
            // private memory; even the totals stores go through raw
            // per-thread base pointers — no per-element Algorithm 1.
            let hist_va = b.rt.array(hist).base_va as i64;
            let totals_va = b.rt.array(totals).base_va as i64;
            let acc_off = b.rt.alloc_private(kb * 8) as i32;
            let pb = b.priv_base();
            // zero the private accumulator
            let zero = b.iconst(0);
            let pa = b.it();
            b.bin(IntOp::Add, pa, pb, Val::I(acc_off as i64));
            b.for_range(Val::I(0), Val::I(kb as i64), 1, |b, _| {
                b.st(MemWidth::U64, zero, pa, 0);
                b.add(pa, pa, Val::I(8));
            });
            b.free_i(pa);
            b.free_i(zero);
            let kstart = b.it();
            b.bin(IntOp::Mul, kstart, myt, Val::I(kb as i64));
            // accumulate each thread's slice
            b.for_range(Val::I(0), Val::I(threads as i64), 1, |b, u| {
                // raw = seg_base(u) + hist_va + kstart*8
                let raw = b.it();
                b.bin(IntOp::Add, raw, u, Val::I(1));
                b.bin(IntOp::Sll, raw, raw, Val::I(32));
                b.bin(IntOp::Add, raw, raw, Val::I(hist_va));
                let ks8 = b.it();
                b.bin(IntOp::Sll, ks8, kstart, Val::I(3));
                b.bin(IntOp::Add, raw, raw, Val::R(ks8));
                b.free_i(ks8);
                let acc = b.it();
                b.bin(IntOp::Add, acc, pb, Val::I(acc_off as i64));
                b.for_range(Val::I(0), Val::I(kb as i64), 1, |b, _| {
                    let v = b.it();
                    b.ld(MemWidth::U64, v, raw, 0);
                    let s = b.it();
                    b.ld(MemWidth::U64, s, acc, 0);
                    b.bin(IntOp::Add, s, s, Val::R(v));
                    b.st(MemWidth::U64, s, acc, 0);
                    b.free_i(s);
                    b.free_i(v);
                    b.add(raw, raw, Val::I(8));
                    b.add(acc, acc, Val::I(8));
                });
                b.free_i(acc);
                b.free_i(raw);
            });
            // write totals[kstart+i] via raw per-thread bases:
            // thread(k) = k & (T-1), local offset = (k >> l2t)*8
            let l2t = (threads as u64).trailing_zeros() as i64;
            let acc = b.it();
            b.bin(IntOp::Add, acc, pb, Val::I(acc_off as i64));
            b.for_range(Val::I(0), Val::I(kb as i64), 1, |b, i| {
                let k = b.it();
                b.bin(IntOp::Add, k, kstart, Val::R(i));
                let th = b.it();
                b.bin(IntOp::And, th, k, Val::I(threads as i64 - 1));
                b.bin(IntOp::Add, th, th, Val::I(1));
                b.bin(IntOp::Sll, th, th, Val::I(32));
                let off = b.it();
                b.bin(IntOp::Srl, off, k, Val::I(l2t));
                b.bin(IntOp::Sll, off, off, Val::I(3));
                b.bin(IntOp::Add, th, th, Val::R(off));
                b.free_i(off);
                let v = b.it();
                b.ld(MemWidth::U64, v, acc, 0);
                b.st(MemWidth::U64, v, th, totals_va as i32);
                b.free_i(v);
                b.free_i(th);
                b.free_i(k);
                b.add(acc, acc, Val::I(8));
            });
            b.free_i(acc);
            b.free_i(kstart);
            b.free_i(pb);
        }
    }

    let module = b.finish("is");

    let keys_data = host_keys(n);
    let keys_for_setup = keys_data.clone();
    let setup = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        // batched init through the runtime's AddressEngine walk
        let vals: Vec<u64> = keys_for_setup.iter().map(|&k| k as u64).collect();
        rt.write_u64_seq(mem, keys, 0, &vals);
    });

    let validate = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        let mut want = vec![0u64; NBUCKETS as usize];
        for &k in &keys_data {
            want[k as usize] += 1;
        }
        let got = rt.read_u64_seq(mem, totals, 0, NBUCKETS as usize);
        for (k, (&g, &w)) in got.iter().zip(&want).enumerate() {
            if g != w {
                return Err(format!("bucket {k}: got {g}, want {w}"));
            }
        }
        Ok(())
    });

    BuiltKernel { rt, module, setup, validate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::CpuModel;
    use crate::npb::{run, Kernel, PaperVariant};

    #[test]
    fn is_validates_in_all_variants() {
        let scale = Scale { factor: 512 };
        for v in PaperVariant::ALL {
            let out = run(Kernel::Is, v, CpuModel::Atomic, 4, &scale);
            assert!(out.result.cycles > 0, "{v:?}");
        }
    }

    #[test]
    fn is_paper_ordering_holds() {
        // unopt slowest; hw large gain; privatized slightly ahead of hw
        let scale = Scale { factor: 256 };
        let t = 4;
        let unopt = run(Kernel::Is, PaperVariant::Unopt, CpuModel::Atomic, t, &scale);
        let manual = run(Kernel::Is, PaperVariant::Manual, CpuModel::Atomic, t, &scale);
        let hw = run(Kernel::Is, PaperVariant::Hw, CpuModel::Atomic, t, &scale);
        let (cu, cm, ch) = (
            unopt.result.cycles as f64,
            manual.result.cycles as f64,
            hw.result.cycles as f64,
        );
        assert!(cu / ch > 2.0, "IS hw speedup {:.2} should be ~3x", cu / ch);
        assert!(cm < ch, "manual ({cm}) should edge out hw ({ch})");
        assert!(ch / cm < 1.4, "hw should trail manual by ~13%, not {:.2}x", ch / cm);
    }
}
