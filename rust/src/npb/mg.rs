//! MG — Multi-Grid V-cycle (Poisson relaxation hierarchy).
//!
//! Hardware adaptation: the paper's 3D Poisson V-cycle is realized as a
//! 1D multigrid V-cycle (relax → restrict → relax → prolong → relax)
//! over blocked shared arrays.  What the figures measure — the density
//! of shared-pointer traffic per grid point (every sweep reads three
//! neighbours and writes one point through shared pointers in the
//! unoptimized source) — is preserved; the dimensionality is not, and
//! DESIGN.md documents the substitution.
//!
//! Chunk-edge halo reads are genuinely remote and stay on shared
//! pointers even in the privatized source, exactly like the ghost-cell
//! exchanges of the hand-tuned NPB MG.
//!
//! Paper shape (Figs. 10/14): the biggest win — HW ≈ 5.5× over the
//! unoptimized code — but ~10% behind the privatized code (the sweeps
//! are store-per-point; every HW store pays the volatile-asm reload).

use super::{BuiltKernel, Scale};
use crate::compiler::{IrBuilder, SourceVariant, Val};
use crate::isa::{Cond, FpOp, MemWidth};
use crate::upc::{ArrayId, UpcRuntime};

/// class W: 64^3 grid points; scaled to a 1D grid of the same count.
const CLASS_W_POINTS: u64 = 64 * 64 * 64;
/// V-cycle depth (3 levels like the scaled-down W hierarchy).
const LEVELS: usize = 3;
/// Jacobi sweeps per level visit.
const SWEEPS: u64 = 2;

/// Host mirror of the simulated computation, bit-identical op order.
struct HostMg {
    u: Vec<Vec<f64>>,
    r: Vec<Vec<f64>>,
    v: Vec<Vec<f64>>, // sweep targets (double buffering)
    n: Vec<u64>,
}

impl HostMg {
    fn new(n0: u64) -> Self {
        let mut u = Vec::new();
        let mut r = Vec::new();
        let mut v = Vec::new();
        let mut n = Vec::new();
        let mut sz = n0;
        for _ in 0..LEVELS {
            u.push(vec![0.0; sz as usize]);
            v.push(vec![0.0; sz as usize]);
            r.push(vec![0.0; sz as usize]);
            n.push(sz);
            sz /= 2;
        }
        Self { u, r, v, n }
    }

    fn init(&mut self) {
        let n0 = self.n[0];
        for i in 0..n0 as usize {
            // deterministic "charge" pattern
            self.r[0][i] = if i % 37 == 0 { 1.0 } else { 0.0 }
                + (i % 11) as f64 * 0.01;
            self.u[0][i] = 0.0;
        }
    }

    fn sweep(&mut self, l: usize) {
        let n = self.n[l] as usize;
        for _ in 0..SWEEPS {
            for i in 0..n {
                let um = if i == 0 { 0.0 } else { self.u[l][i - 1] };
                let up = if i == n - 1 { 0.0 } else { self.u[l][i + 1] };
                self.v[l][i] = 0.25 * (um + up) + 0.5 * self.r[l][i];
            }
            std::mem::swap(&mut self.u[l], &mut self.v[l]);
        }
    }

    fn restrict(&mut self, l: usize) {
        let nc = self.n[l + 1] as usize;
        for i in 0..nc {
            self.r[l + 1][i] = 0.5 * self.r[l][2 * i]
                + 0.25 * (self.r[l][2 * i + 1] + self.u[l][2 * i]);
            self.u[l + 1][i] = 0.0;
        }
    }

    fn prolong(&mut self, l: usize) {
        let nc = self.n[l + 1] as usize;
        for i in 0..nc {
            self.u[l][2 * i] += self.u[l + 1][i];
            self.u[l][2 * i + 1] += 0.5 * self.u[l + 1][i];
        }
    }

    fn vcycle(&mut self) {
        self.sweep(0);
        self.restrict(0);
        self.sweep(1);
        self.restrict(1);
        self.sweep(2);
        self.prolong(1);
        self.sweep(1);
        self.prolong(0);
        self.sweep(0);
    }
}

pub fn build(threads: u32, source: SourceVariant, scale: &Scale) -> BuiltKernel {
    // finest grid: pow2, at least 8 points per thread at every level
    let n0 = scale
        .dim(CLASS_W_POINTS, threads as u64 * 8 << (LEVELS - 1))
        .next_power_of_two();
    let n0 = n0.max(threads as u64 * 8 << (LEVELS - 1));

    let mut rt = UpcRuntime::new(threads);
    let mut u_ids: Vec<ArrayId> = Vec::new();
    let mut v_ids: Vec<ArrayId> = Vec::new();
    let mut r_ids: Vec<ArrayId> = Vec::new();
    let mut sizes = Vec::new();
    let mut sz = n0;
    for l in 0..LEVELS {
        let chunk = sz / threads as u64;
        u_ids.push(rt.alloc_shared(&format!("mg_u{l}"), chunk, 8, sz));
        v_ids.push(rt.alloc_shared(&format!("mg_v{l}"), chunk, 8, sz));
        r_ids.push(rt.alloc_shared(&format!("mg_r{l}"), chunk, 8, sz));
        sizes.push(sz);
        sz /= 2;
    }

    let mut b = IrBuilder::new(&mut rt);
    let myt = b.mythread();

    // One Jacobi sweep at level l: v[i] = 0.25*(u[i-1]+u[i+1]) + 0.5*r[i]
    // over my chunk, then copy v back into u (second half-sweep of the
    // double buffer, also a chunk walk).  `src`/`dst` swap per sweep is
    // unrolled since SWEEPS = 2: u->v then v->u.
    let emit_sweep = |b: &mut IrBuilder,
                      myt: u8,
                      src: ArrayId,
                      dst: ArrayId,
                      rr: ArrayId,
                      nl: u64| {
        let chunk = nl / threads as u64;
        let start = b.it();
        b.bin(crate::isa::IntOp::Mul, start, myt, Val::I(chunk as i64));
        let fq = b.fconst(0.25);
        let fh = b.fconst(0.5);
        match source {
            SourceVariant::Unoptimized => {
                // three read walks (u[i-1], u[i], skipped, u[i+1]), one
                // r walk, one write walk — all shared pointers
                let pm = b.sptr_init(src, Val::R(start)); // u[i-1] lag
                let pp = b.sptr_init(src, Val::R(start)); // u[i+1] lead
                b.sptr_inc(pp, src, Val::I(1));
                let pr = b.sptr_init(rr, Val::R(start));
                let pd = b.sptr_init(dst, Val::R(start));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, i| {
                    let fm = b.ft();
                    let fp = b.ft();
                    let fr = b.ft();
                    // boundary handling: global index gidx = start + i;
                    // u[-1] and u[n] read as 0 via an edge test
                    let gidx = b.it();
                    b.bin(crate::isa::IntOp::Add, gidx, start, Val::R(i));
                    // fm = (gidx == 0) ? 0 : u[gidx-1].  The lagging
                    // pointer pm is valid from i >= 1; the chunk's first
                    // element reads its left halo through a one-off
                    // shared pointer (remote for t > 0).
                    b.if_else(
                        Cond::Eq,
                        gidx,
                        |b| {
                            let z = b.fconst(0.0);
                            b.fbin(FpOp::FMov, fm, z, z);
                            b.free_f(z);
                        },
                        |b| {
                            b.if_else(
                                Cond::Eq,
                                i,
                                |b| {
                                    let hm = b.it();
                                    b.bin(
                                        crate::isa::IntOp::Add,
                                        hm,
                                        gidx,
                                        Val::I(-1),
                                    );
                                    let ph = b.sptr_init(src, Val::R(hm));
                                    b.sptr_ld(MemWidth::F64, fm, ph, 0);
                                    b.free_i(ph);
                                    b.free_i(hm);
                                },
                                |b| {
                                    // pm trails by one: u[gidx-1]
                                    b.sptr_ld(MemWidth::F64, fm, pm, 0);
                                },
                            );
                        },
                    );
                    // fp = (gidx == nl-1) ? 0 : u[gidx+1]
                    let edge = b.it();
                    b.bin(crate::isa::IntOp::CmpEq, edge, gidx, Val::I((nl - 1) as i64));
                    b.if_else(
                        Cond::Ne,
                        edge,
                        |b| {
                            let z = b.fconst(0.0);
                            b.fbin(FpOp::FMov, fp, z, z);
                            b.free_f(z);
                        },
                        |b| {
                            b.sptr_ld(MemWidth::F64, fp, pp, 0);
                        },
                    );
                    b.free_i(edge);
                    b.free_i(gidx);
                    b.sptr_ld(MemWidth::F64, fr, pr, 0);
                    b.fbin(FpOp::FAdd, fm, fm, fp);
                    b.fbin(FpOp::FMul, fm, fm, fq);
                    b.fbin(FpOp::FMul, fr, fr, fh);
                    b.fbin(FpOp::FAdd, fm, fm, fr);
                    b.sptr_st(MemWidth::F64, fm, pd, 0);
                    // advance all walks (pm lags: skip its first inc)
                    b.iff(Cond::Ne, i, |b| {
                        b.sptr_inc(pm, src, Val::I(1));
                    });
                    b.sptr_inc(pp, src, Val::I(1));
                    b.sptr_inc(pr, rr, Val::I(1));
                    b.sptr_inc(pd, dst, Val::I(1));
                    b.free_f(fr);
                    b.free_f(fp);
                    b.free_f(fm);
                });
                b.free_i(pd);
                b.free_i(pr);
                b.free_i(pp);
                b.free_i(pm);
            }
            SourceVariant::Privatized => {
                // interior via raw local cursors; the two chunk-edge
                // neighbours via shared pointers (the halo)
                let cu = b.local_addr(src, Val::I(0));
                let cr = b.local_addr(rr, Val::I(0));
                let cd = b.local_addr(dst, Val::I(0));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, i| {
                    let fm = b.ft();
                    let fp = b.ft();
                    let fr = b.ft();
                    let gidx = b.it();
                    b.bin(crate::isa::IntOp::Add, gidx, start, Val::R(i));
                    // left neighbour
                    b.if_else(
                        Cond::Eq,
                        i,
                        |b| {
                            // chunk edge: u[gidx-1] remote (or 0 at wall)
                            b.if_else(
                                Cond::Eq,
                                gidx,
                                |b| {
                                    let z = b.fconst(0.0);
                                    b.fbin(FpOp::FMov, fm, z, z);
                                    b.free_f(z);
                                },
                                |b| {
                                    let hm = b.it();
                                    b.bin(
                                        crate::isa::IntOp::Add,
                                        hm,
                                        gidx,
                                        Val::I(-1),
                                    );
                                    let ph = b.sptr_init(src, Val::R(hm));
                                    b.sptr_ld(MemWidth::F64, fm, ph, 0);
                                    b.free_i(ph);
                                    b.free_i(hm);
                                },
                            );
                        },
                        |b| {
                            b.ld(MemWidth::F64, fm, cu, -8);
                        },
                    );
                    // right neighbour
                    let last = b.it();
                    b.bin(
                        crate::isa::IntOp::CmpEq,
                        last,
                        i,
                        Val::I((chunk - 1) as i64),
                    );
                    b.if_else(
                        Cond::Ne,
                        last,
                        |b| {
                            let wall = b.it();
                            b.bin(
                                crate::isa::IntOp::CmpEq,
                                wall,
                                gidx,
                                Val::I((nl - 1) as i64),
                            );
                            b.if_else(
                                Cond::Ne,
                                wall,
                                |b| {
                                    let z = b.fconst(0.0);
                                    b.fbin(FpOp::FMov, fp, z, z);
                                    b.free_f(z);
                                },
                                |b| {
                                    let hp = b.it();
                                    b.bin(
                                        crate::isa::IntOp::Add,
                                        hp,
                                        gidx,
                                        Val::I(1),
                                    );
                                    let ph = b.sptr_init(src, Val::R(hp));
                                    b.sptr_ld(MemWidth::F64, fp, ph, 0);
                                    b.free_i(ph);
                                    b.free_i(hp);
                                },
                            );
                            b.free_i(wall);
                        },
                        |b| {
                            b.ld(MemWidth::F64, fp, cu, 8);
                        },
                    );
                    b.free_i(last);
                    b.free_i(gidx);
                    b.ld(MemWidth::F64, fr, cr, 0);
                    b.fbin(FpOp::FAdd, fm, fm, fp);
                    b.fbin(FpOp::FMul, fm, fm, fq);
                    b.fbin(FpOp::FMul, fr, fr, fh);
                    b.fbin(FpOp::FAdd, fm, fm, fr);
                    b.st(MemWidth::F64, fm, cd, 0);
                    b.add(cu, cu, Val::I(8));
                    b.add(cr, cr, Val::I(8));
                    b.add(cd, cd, Val::I(8));
                    b.free_f(fr);
                    b.free_f(fp);
                    b.free_f(fm);
                });
                b.free_i(cd);
                b.free_i(cr);
                b.free_i(cu);
            }
        }
        b.free_f(fh);
        b.free_f(fq);
        b.free_i(start);
        b.barrier();
    };

    // copy dst -> src over my chunk (the swap half of double buffering)
    let emit_copy = |b: &mut IrBuilder, myt: u8, from: ArrayId, to: ArrayId, nl: u64| {
        let chunk = nl / threads as u64;
        let start = b.it();
        b.bin(crate::isa::IntOp::Mul, start, myt, Val::I(chunk as i64));
        match source {
            SourceVariant::Unoptimized => {
                let pf = b.sptr_init(from, Val::R(start));
                let pt = b.sptr_init(to, Val::R(start));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                    let f = b.ft();
                    b.sptr_ld(MemWidth::F64, f, pf, 0);
                    b.sptr_st(MemWidth::F64, f, pt, 0);
                    b.free_f(f);
                    b.sptr_inc(pf, from, Val::I(1));
                    b.sptr_inc(pt, to, Val::I(1));
                });
                b.free_i(pt);
                b.free_i(pf);
            }
            SourceVariant::Privatized => {
                let cf = b.local_addr(from, Val::I(0));
                let ct = b.local_addr(to, Val::I(0));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                    let f = b.ft();
                    b.ld(MemWidth::F64, f, cf, 0);
                    b.st(MemWidth::F64, f, ct, 0);
                    b.free_f(f);
                    b.add(cf, cf, Val::I(8));
                    b.add(ct, ct, Val::I(8));
                });
                b.free_i(ct);
                b.free_i(cf);
            }
        }
        b.free_i(start);
        b.barrier();
    };

    // restriction: r[l+1][i] = 0.5*r[l][2i] + 0.25*(r[l][2i+1] + u[l][2i])
    // walking the fine arrays with stride 2 and the coarse with stride 1.
    let emit_restrict = |b: &mut IrBuilder, myt: u8, l: usize| {
        let nc = sizes[l + 1];
        let chunk = nc / threads as u64;
        let startc = b.it();
        b.bin(crate::isa::IntOp::Mul, startc, myt, Val::I(chunk as i64));
        let startf = b.it();
        b.bin(crate::isa::IntOp::Sll, startf, startc, Val::I(1));
        let fh = b.fconst(0.5);
        let fq = b.fconst(0.25);
        let zero = b.fconst(0.0);
        match source {
            SourceVariant::Unoptimized => {
                let prf = b.sptr_init(r_ids[l], Val::R(startf));
                let puf = b.sptr_init(u_ids[l], Val::R(startf));
                let prc = b.sptr_init(r_ids[l + 1], Val::R(startc));
                let puc = b.sptr_init(u_ids[l + 1], Val::R(startc));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                    let f0 = b.ft();
                    let f1 = b.ft();
                    let fu = b.ft();
                    b.sptr_ld(MemWidth::F64, f0, prf, 0);
                    b.sptr_ld(MemWidth::F64, f1, prf, 8); // r[2i+1]: same block
                    b.sptr_ld(MemWidth::F64, fu, puf, 0);
                    b.fbin(FpOp::FAdd, f1, f1, fu);
                    b.fbin(FpOp::FMul, f1, f1, fq);
                    b.fbin(FpOp::FMul, f0, f0, fh);
                    b.fbin(FpOp::FAdd, f0, f0, f1);
                    b.sptr_st(MemWidth::F64, f0, prc, 0);
                    b.sptr_st(MemWidth::F64, zero, puc, 0);
                    b.sptr_inc(prf, r_ids[l], Val::I(2));
                    b.sptr_inc(puf, u_ids[l], Val::I(2));
                    b.sptr_inc(prc, r_ids[l + 1], Val::I(1));
                    b.sptr_inc(puc, u_ids[l + 1], Val::I(1));
                    b.free_f(fu);
                    b.free_f(f1);
                    b.free_f(f0);
                });
                b.free_i(puc);
                b.free_i(prc);
                b.free_i(puf);
                b.free_i(prf);
            }
            SourceVariant::Privatized => {
                let crf = b.local_addr(r_ids[l], Val::I(0));
                let cuf = b.local_addr(u_ids[l], Val::I(0));
                let crc = b.local_addr(r_ids[l + 1], Val::I(0));
                let cuc = b.local_addr(u_ids[l + 1], Val::I(0));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                    let f0 = b.ft();
                    let f1 = b.ft();
                    let fu = b.ft();
                    b.ld(MemWidth::F64, f0, crf, 0);
                    b.ld(MemWidth::F64, f1, crf, 8);
                    b.ld(MemWidth::F64, fu, cuf, 0);
                    b.fbin(FpOp::FAdd, f1, f1, fu);
                    b.fbin(FpOp::FMul, f1, f1, fq);
                    b.fbin(FpOp::FMul, f0, f0, fh);
                    b.fbin(FpOp::FAdd, f0, f0, f1);
                    b.st(MemWidth::F64, f0, crc, 0);
                    b.st(MemWidth::F64, zero, cuc, 0);
                    b.add(crf, crf, Val::I(16));
                    b.add(cuf, cuf, Val::I(16));
                    b.add(crc, crc, Val::I(8));
                    b.add(cuc, cuc, Val::I(8));
                    b.free_f(fu);
                    b.free_f(f1);
                    b.free_f(f0);
                });
                b.free_i(cuc);
                b.free_i(crc);
                b.free_i(cuf);
                b.free_i(crf);
            }
        }
        b.free_f(zero);
        b.free_f(fq);
        b.free_f(fh);
        b.free_i(startf);
        b.free_i(startc);
        b.barrier();
    };

    // prolongation: u[l][2i] += u[l+1][i]; u[l][2i+1] += 0.5*u[l+1][i]
    let emit_prolong = |b: &mut IrBuilder, myt: u8, l: usize| {
        let nc = sizes[l + 1];
        let chunk = nc / threads as u64;
        let startc = b.it();
        b.bin(crate::isa::IntOp::Mul, startc, myt, Val::I(chunk as i64));
        let startf = b.it();
        b.bin(crate::isa::IntOp::Sll, startf, startc, Val::I(1));
        let fh = b.fconst(0.5);
        match source {
            SourceVariant::Unoptimized => {
                let puc = b.sptr_init(u_ids[l + 1], Val::R(startc));
                let puf = b.sptr_init(u_ids[l], Val::R(startf));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                    let fc = b.ft();
                    let f0 = b.ft();
                    b.sptr_ld(MemWidth::F64, fc, puc, 0);
                    b.sptr_ld(MemWidth::F64, f0, puf, 0);
                    b.fbin(FpOp::FAdd, f0, f0, fc);
                    b.sptr_st(MemWidth::F64, f0, puf, 0);
                    b.sptr_ld(MemWidth::F64, f0, puf, 8);
                    b.fbin(FpOp::FMul, fc, fc, fh);
                    b.fbin(FpOp::FAdd, f0, f0, fc);
                    b.sptr_st(MemWidth::F64, f0, puf, 8);
                    b.sptr_inc(puc, u_ids[l + 1], Val::I(1));
                    b.sptr_inc(puf, u_ids[l], Val::I(2));
                    b.free_f(f0);
                    b.free_f(fc);
                });
                b.free_i(puf);
                b.free_i(puc);
            }
            SourceVariant::Privatized => {
                let cuc = b.local_addr(u_ids[l + 1], Val::I(0));
                let cuf = b.local_addr(u_ids[l], Val::I(0));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                    let fc = b.ft();
                    let f0 = b.ft();
                    b.ld(MemWidth::F64, fc, cuc, 0);
                    b.ld(MemWidth::F64, f0, cuf, 0);
                    b.fbin(FpOp::FAdd, f0, f0, fc);
                    b.st(MemWidth::F64, f0, cuf, 0);
                    b.ld(MemWidth::F64, f0, cuf, 8);
                    b.fbin(FpOp::FMul, fc, fc, fh);
                    b.fbin(FpOp::FAdd, f0, f0, fc);
                    b.st(MemWidth::F64, f0, cuf, 8);
                    b.add(cuc, cuc, Val::I(8));
                    b.add(cuf, cuf, Val::I(16));
                    b.free_f(f0);
                    b.free_f(fc);
                });
                b.free_i(cuf);
                b.free_i(cuc);
            }
        }
        b.free_f(fh);
        b.free_i(startf);
        b.free_i(startc);
        b.barrier();
    };

    // sweep twice with explicit copy-back (u->v, v copied to u)
    let full_sweep = |b: &mut IrBuilder, myt: u8, l: usize| {
        for _ in 0..SWEEPS {
            emit_sweep(b, myt, u_ids[l], v_ids[l], r_ids[l], sizes[l]);
            emit_copy(b, myt, v_ids[l], u_ids[l], sizes[l]);
        }
    };

    // ---- the V-cycle ----
    full_sweep(&mut b, myt, 0);
    emit_restrict(&mut b, myt, 0);
    full_sweep(&mut b, myt, 1);
    emit_restrict(&mut b, myt, 1);
    full_sweep(&mut b, myt, 2);
    emit_prolong(&mut b, myt, 1);
    full_sweep(&mut b, myt, 1);
    emit_prolong(&mut b, myt, 0);
    full_sweep(&mut b, myt, 0);

    let module = b.finish("mg");

    let u0 = u_ids[0];
    let r0 = r_ids[0];
    let setup = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        // batched init through the runtime's AddressEngine walk
        let rv: Vec<f64> = (0..n0)
            .map(|i| (if i % 37 == 0 { 1.0 } else { 0.0 }) + (i % 11) as f64 * 0.01)
            .collect();
        rt.write_f64_seq(mem, r0, 0, &rv);
        rt.write_f64_seq(mem, u0, 0, &vec![0.0; n0 as usize]);
    });

    let validate = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        let mut host = HostMg::new(n0);
        host.init();
        host.vcycle();
        let got = rt.read_f64_seq(mem, u0, 0, n0 as usize);
        for (i, (&g, &want)) in got.iter().zip(&host.u[0]).enumerate() {
            if (g - want).abs() > 1e-12 * want.abs().max(1.0) {
                return Err(format!("u[{i}] = {g}, want {want}"));
            }
        }
        Ok(())
    });

    BuiltKernel { rt, module, setup, validate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::CpuModel;
    use crate::npb::{run, Kernel, PaperVariant};

    #[test]
    fn mg_validates_in_all_variants() {
        let scale = Scale { factor: 1024 };
        for v in PaperVariant::ALL {
            let out = run(Kernel::Mg, v, CpuModel::Atomic, 4, &scale);
            assert!(out.result.cycles > 0, "{v:?}");
        }
    }

    #[test]
    fn mg_paper_ordering_holds() {
        // the headline: large hw speedup, but manual keeps ~10% edge
        let scale = Scale { factor: 512 };
        let t = 4;
        let unopt = run(Kernel::Mg, PaperVariant::Unopt, CpuModel::Atomic, t, &scale);
        let manual = run(Kernel::Mg, PaperVariant::Manual, CpuModel::Atomic, t, &scale);
        let hw = run(Kernel::Mg, PaperVariant::Hw, CpuModel::Atomic, t, &scale);
        let (cu, cm, ch) = (
            unopt.result.cycles as f64,
            manual.result.cycles as f64,
            hw.result.cycles as f64,
        );
        assert!(cu / ch > 3.0, "MG hw speedup {:.2} should be ~5.5x", cu / ch);
        assert!(cm < ch, "manual ({cm}) should edge out hw ({ch}) on MG");
        assert!(ch / cm < 1.5, "hw should trail manual by ~10%, got {:.2}x", ch / cm);
    }
}
