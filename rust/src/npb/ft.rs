//! FT — Fourier Transform kernel.
//!
//! Hardware adaptation: the paper's 3D FFT is realized as the canonical
//! distributed 2-step FFT — radix-2 FFTs over the rows each thread owns,
//! a global transpose (the all-to-all that dominates shared traffic),
//! then FFTs over the transposed rows.  Twiddle factors and bit-reversal
//! tables are precomputed into private memory, as the real FT does.
//!
//! The slab distribution limits the run to `N1 = 16` threads, exactly
//! the paper's class-W constraint ("The FT kernel runs were limited to
//! 16 cores due to the data distribution of the W class", Fig. 8).
//!
//! Paper shape (Figs. 8/12): HW ≈ 2.3× over unoptimized and ~17% ahead
//! of the privatized code — the transpose's scattered remote stores
//! cannot be privatized, so the hand-tuned source still pays software
//! translation there.

use super::{BuiltKernel, Scale};
use crate::compiler::{IrBuilder, SourceVariant, Val};
use crate::isa::{FpOp, IntOp, MemWidth};
use crate::upc::{ArrayId, UpcRuntime};
use crate::util::rng::Xoshiro256;

/// Slab count (rows of the first FFT): the paper's 16-core cap.
const N1: u64 = 16;
/// class W second dimension: 128·128 columns, scaled.
const CLASS_W_N2: u64 = 128 * 128;

/// Complex values as (re, im) f64 pairs; element size 16 bytes.
type Cpx = (f64, f64);

fn bitrev(i: u64, bits: u32) -> u64 {
    i.reverse_bits() >> (64 - bits)
}

/// In-place radix-2 DIT FFT, mirroring the simulated op order exactly.
fn host_fft_row(x: &mut [Cpx], tw: &[Cpx]) {
    let n = x.len() as u64;
    let bits = n.trailing_zeros();
    for i in 0..n {
        let r = bitrev(i, bits);
        if i < r {
            x.swap(i as usize, r as usize);
        }
    }
    let mut half = 1u64;
    while half < n {
        let step = n / (2 * half);
        let mut k = 0u64;
        while k < n {
            for j in 0..half {
                let (wr, wi) = tw[(j * step) as usize];
                let (ar, ai) = x[(k + j) as usize];
                let (br, bi) = x[(k + j + half) as usize];
                let tr = br * wr - bi * wi;
                let ti = br * wi + bi * wr;
                x[(k + j) as usize] = (ar + tr, ai + ti);
                x[(k + j + half) as usize] = (ar - tr, ai - ti);
            }
            k += 2 * half;
        }
        half *= 2;
    }
}

fn twiddles(n: u64) -> Vec<Cpx> {
    (0..n / 2)
        .map(|i| {
            let ang = -2.0 * std::f64::consts::PI * i as f64 / n as f64;
            (ang.cos(), ang.sin())
        })
        .collect()
}

fn input_data(n1: u64, n2: u64) -> Vec<Cpx> {
    let mut rng = Xoshiro256::new(0xF7_0001);
    (0..n1 * n2)
        .map(|_| (rng.f64() - 0.5, rng.f64() - 0.5))
        .collect()
}

/// Full host mirror: FFT rows of x (N1 x N2), transpose into y
/// (N2 x N1), FFT rows of y.
fn host_reference(n2: u64) -> Vec<Cpx> {
    let mut x = input_data(N1, n2);
    let twx = twiddles(n2);
    for r in 0..N1 {
        host_fft_row(&mut x[(r * n2) as usize..((r + 1) * n2) as usize], &twx);
    }
    let mut y = vec![(0.0, 0.0); (N1 * n2) as usize];
    for r in 0..N1 {
        for c in 0..n2 {
            y[(c * N1 + r) as usize] = x[(r * n2 + c) as usize];
        }
    }
    let twy = twiddles(N1);
    for r in 0..n2 {
        host_fft_row(&mut y[(r * N1) as usize..((r + 1) * N1) as usize], &twy);
    }
    y
}

pub fn build(threads: u32, source: SourceVariant, scale: &Scale) -> BuiltKernel {
    assert!(threads as u64 <= N1, "FT slab distribution caps at {N1} threads");
    let n2 = scale.dim(CLASS_W_N2, 64).next_power_of_two();
    let rows_per = N1 / threads as u64; // rows of x per thread
    let yrows_per = n2 / threads as u64; // rows of y per thread

    let mut rt = UpcRuntime::new(threads);
    // x: N1 x N2 complex, blocked so each thread owns its slab
    let x = rt.alloc_shared("ft_x", rows_per * n2, 16, N1 * n2);
    // y: N2 x N1 complex (transposed), blocked by y-rows
    let y = rt.alloc_shared("ft_y", yrows_per * N1, 16, N1 * n2);

    // private tables: twiddles for n2-point and N1-point FFTs, and
    // bit-reversal tables for both lengths
    let twx_off = rt.alloc_private(n2 / 2 * 16);
    let twy_off = rt.alloc_private(N1 / 2 * 16);
    let revx_off = rt.alloc_private(n2 * 8);
    let revy_off = rt.alloc_private(N1 * 8);

    let mut b = IrBuilder::new(&mut rt);
    let myt = b.mythread();

    /// Emit the FFT of `nrows` rows of `arr` (row length `n`, power of
    /// 2), rows starting at `rowstart_mul * MYTHREAD`.  tw/rev are
    /// private-table offsets.
    #[allow(clippy::too_many_arguments)]
    fn emit_fft_rows(
        b: &mut IrBuilder,
        source: SourceVariant,
        myt: u8,
        arr: ArrayId,
        nrows: u64,
        n: u64,
        tw_off: u64,
        rev_off: u64,
    ) {
        let l2n = n.trailing_zeros() as i64;
        let pb = b.priv_base();
        // row loop
        b.for_range(Val::I(0), Val::I(nrows as i64), 1, |b, row| {
            // global row = MYTHREAD * nrows + row;
            // base element index of this row within arr
            let rowbase = b.it();
            b.bin(IntOp::Mul, rowbase, myt, Val::I(nrows as i64));
            b.bin(IntOp::Add, rowbase, rowbase, Val::R(row));
            b.bin(IntOp::Sll, rowbase, rowbase, Val::I(l2n));

            // helper to produce the address/pointer of element
            // rowbase + idx and read/write (re, im)
            // -- bit-reversal permutation --
            b.for_range(Val::I(0), Val::I(n as i64), 1, |b, i| {
                // ri = rev[i]
                let ri = b.it();
                b.bin(IntOp::Sll, ri, i, Val::I(3));
                b.bin(IntOp::Add, ri, ri, Val::R(pb));
                b.ld(MemWidth::U64, ri, ri, rev_off as i32);
                // if i < ri: swap elements rowbase+i, rowbase+ri
                let cmp = b.it();
                b.bin(IntOp::CmpLt, cmp, i, Val::R(ri));
                b.iff(crate::isa::Cond::Ne, cmp, |b| {
                    let ia = b.it();
                    b.bin(IntOp::Add, ia, rowbase, Val::R(i));
                    let ib = b.it();
                    b.bin(IntOp::Add, ib, rowbase, Val::R(ri));
                    let (fr1, fi1, fr2, fi2) = (b.ft(), b.ft(), b.ft(), b.ft());
                    match source {
                        SourceVariant::Unoptimized => {
                            let pa = b.sptr_init(arr, Val::R(ia));
                            let pc = b.sptr_init(arr, Val::R(ib));
                            b.sptr_ld(MemWidth::F64, fr1, pa, 0);
                            b.sptr_ld(MemWidth::F64, fi1, pa, 8);
                            b.sptr_ld(MemWidth::F64, fr2, pc, 0);
                            b.sptr_ld(MemWidth::F64, fi2, pc, 8);
                            b.sptr_st(MemWidth::F64, fr2, pa, 0);
                            b.sptr_st(MemWidth::F64, fi2, pa, 8);
                            b.sptr_st(MemWidth::F64, fr1, pc, 0);
                            b.sptr_st(MemWidth::F64, fi1, pc, 8);
                            b.free_i(pc);
                            b.free_i(pa);
                        }
                        SourceVariant::Privatized => {
                            // own row: raw cursor arithmetic off the
                            // thread-local base of arr
                            let la = b.local_addr(arr, Val::I(0));
                            // local element offset = ia - MYTHREAD*rows*n
                            let loff = b.it();
                            b.bin(IntOp::Mul, loff, myt, Val::I((nrows * n) as i64));
                            let aa = b.it();
                            b.bin(IntOp::Sub, aa, ia, Val::R(loff));
                            b.bin(IntOp::Sll, aa, aa, Val::I(4));
                            b.bin(IntOp::Add, aa, aa, Val::R(la));
                            let ab = b.it();
                            b.bin(IntOp::Sub, ab, ib, Val::R(loff));
                            b.bin(IntOp::Sll, ab, ab, Val::I(4));
                            b.bin(IntOp::Add, ab, ab, Val::R(la));
                            b.ld(MemWidth::F64, fr1, aa, 0);
                            b.ld(MemWidth::F64, fi1, aa, 8);
                            b.ld(MemWidth::F64, fr2, ab, 0);
                            b.ld(MemWidth::F64, fi2, ab, 8);
                            b.st(MemWidth::F64, fr2, aa, 0);
                            b.st(MemWidth::F64, fi2, aa, 8);
                            b.st(MemWidth::F64, fr1, ab, 0);
                            b.st(MemWidth::F64, fi1, ab, 8);
                            b.free_i(ab);
                            b.free_i(aa);
                            b.free_i(loff);
                            b.free_i(la);
                        }
                    }
                    b.free_f(fi2);
                    b.free_f(fr2);
                    b.free_f(fi1);
                    b.free_f(fr1);
                    b.free_i(ib);
                    b.free_i(ia);
                });
                b.free_i(cmp);
                b.free_i(ri);
            });

            // -- butterfly levels --
            let half = b.it();
            b.mov(half, Val::I(1));
            let level_count = b.iconst(l2n);
            b.do_while(crate::isa::Cond::Gt, level_count, |b| {
                // step = n / (2*half): tw stride for this level
                let step = b.it();
                b.mov(step, Val::I(n as i64));
                b.bin(IntOp::Srl, step, step, Val::I(1));
                let tmp = b.it();
                // step = (n/2) / half via divide-by-shift: half is pow2
                // but its log2 is dynamic → use Div (cheap once/level)
                b.bin(IntOp::Div, step, step, Val::R(half));
                b.free_i(tmp);
                // k loop: k += 2*half
                let k = b.it();
                b.mov(k, Val::I(0));
                let nreg = b.iconst(n as i64);
                let kcond = b.it();
                b.do_while(crate::isa::Cond::Ne, kcond, |b| {
                    b.for_range(Val::I(0), Val::R(half), 1, |b, j| {
                        // twiddle = tw[j * step]
                        let ti = b.it();
                        b.bin(IntOp::Mul, ti, j, Val::R(step));
                        b.bin(IntOp::Sll, ti, ti, Val::I(4));
                        b.bin(IntOp::Add, ti, ti, Val::R(pb));
                        let (fwr, fwi) = (b.ft(), b.ft());
                        b.ld(MemWidth::F64, fwr, ti, tw_off as i32);
                        b.ld(MemWidth::F64, fwi, ti, tw_off as i32 + 8);
                        b.free_i(ti);
                        // element indices a = rowbase+k+j, c = a+half
                        let ia = b.it();
                        b.bin(IntOp::Add, ia, rowbase, Val::R(k));
                        b.bin(IntOp::Add, ia, ia, Val::R(j));
                        let ib = b.it();
                        b.bin(IntOp::Add, ib, ia, Val::R(half));
                        let (far, fai, fbr, fbi) = (b.ft(), b.ft(), b.ft(), b.ft());
                        let (ftr, fti) = (b.ft(), b.ft());
                        // load a and b elements
                        let do_rw = |b: &mut IrBuilder,
                                     load: bool,
                                     idx: u8,
                                     fr: u8,
                                     fi: u8| {
                            match source {
                                SourceVariant::Unoptimized => {
                                    let pp = b.sptr_init(arr, Val::R(idx));
                                    if load {
                                        b.sptr_ld(MemWidth::F64, fr, pp, 0);
                                        b.sptr_ld(MemWidth::F64, fi, pp, 8);
                                    } else {
                                        b.sptr_st(MemWidth::F64, fr, pp, 0);
                                        b.sptr_st(MemWidth::F64, fi, pp, 8);
                                    }
                                    b.free_i(pp);
                                }
                                SourceVariant::Privatized => {
                                    let la = b.local_addr(arr, Val::I(0));
                                    let loff = b.it();
                                    b.bin(
                                        IntOp::Mul,
                                        loff,
                                        myt,
                                        Val::I((nrows * n) as i64),
                                    );
                                    let aa = b.it();
                                    b.bin(IntOp::Sub, aa, idx, Val::R(loff));
                                    b.bin(IntOp::Sll, aa, aa, Val::I(4));
                                    b.bin(IntOp::Add, aa, aa, Val::R(la));
                                    if load {
                                        b.ld(MemWidth::F64, fr, aa, 0);
                                        b.ld(MemWidth::F64, fi, aa, 8);
                                    } else {
                                        b.st(MemWidth::F64, fr, aa, 0);
                                        b.st(MemWidth::F64, fi, aa, 8);
                                    }
                                    b.free_i(aa);
                                    b.free_i(loff);
                                    b.free_i(la);
                                }
                            }
                        };
                        do_rw(b, true, ia, far, fai);
                        do_rw(b, true, ib, fbr, fbi);
                        // t = b * w (complex)
                        let fs = b.ft();
                        b.fbin(FpOp::FMul, ftr, fbr, fwr);
                        b.fbin(FpOp::FMul, fs, fbi, fwi);
                        b.fbin(FpOp::FSub, ftr, ftr, fs);
                        b.fbin(FpOp::FMul, fti, fbr, fwi);
                        b.fbin(FpOp::FMul, fs, fbi, fwr);
                        b.fbin(FpOp::FAdd, fti, fti, fs);
                        b.free_f(fs);
                        // a' = a + t ; b' = a - t
                        b.fbin(FpOp::FSub, fbr, far, ftr);
                        b.fbin(FpOp::FSub, fbi, fai, fti);
                        b.fbin(FpOp::FAdd, far, far, ftr);
                        b.fbin(FpOp::FAdd, fai, fai, fti);
                        do_rw(b, false, ia, far, fai);
                        do_rw(b, false, ib, fbr, fbi);
                        b.free_f(fti);
                        b.free_f(ftr);
                        b.free_f(fbi);
                        b.free_f(fbr);
                        b.free_f(fai);
                        b.free_f(far);
                        b.free_i(ib);
                        b.free_i(ia);
                        b.free_f(fwi);
                        b.free_f(fwr);
                    });
                    // k += 2*half ; continue while k != n
                    b.bin(IntOp::Add, k, k, Val::R(half));
                    b.bin(IntOp::Add, k, k, Val::R(half));
                    b.bin(IntOp::Sub, kcond, k, Val::R(nreg));
                });
                b.free_i(kcond);
                b.free_i(nreg);
                b.free_i(k);
                b.free_i(step);
                // half *= 2 ; level_count -= 1
                b.bin(IntOp::Sll, half, half, Val::I(1));
                b.bin(IntOp::Add, level_count, level_count, Val::I(-1));
            });
            b.free_i(level_count);
            b.free_i(half);
            b.free_i(rowbase);
        });
        b.free_i(pb);
    }

    // ---- step 1: FFT my rows of x (length n2) ----
    emit_fft_rows(&mut b, source, myt, x, rows_per, n2, twx_off, revx_off);
    b.barrier();

    // ---- step 2: transpose x -> y (scattered remote stores) ----
    // y[c*N1 + r] = x[r*n2 + c] for my rows r.  Reads of x are local
    // (privatizable); writes to y land on every thread — they stay on
    // shared pointers in all source variants.
    {
        let r0 = b.it();
        b.bin(IntOp::Mul, r0, myt, Val::I(rows_per as i64));
        b.for_range(Val::I(0), Val::I(rows_per as i64), 1, |b, rr| {
            let rg = b.it();
            b.bin(IntOp::Add, rg, r0, Val::R(rr));
            b.for_range(Val::I(0), Val::I(n2 as i64), 1, |b, c| {
                let (fr, fi) = (b.ft(), b.ft());
                // read x[rg*n2 + c]
                let ix = b.it();
                b.bin(IntOp::Mul, ix, rg, Val::I(n2 as i64));
                b.bin(IntOp::Add, ix, ix, Val::R(c));
                match source {
                    SourceVariant::Unoptimized => {
                        let px = b.sptr_init(x, Val::R(ix));
                        b.sptr_ld(MemWidth::F64, fr, px, 0);
                        b.sptr_ld(MemWidth::F64, fi, px, 8);
                        b.free_i(px);
                    }
                    SourceVariant::Privatized => {
                        let la = b.local_addr(x, Val::I(0));
                        let loff = b.it();
                        b.bin(IntOp::Mul, loff, myt, Val::I((rows_per * n2) as i64));
                        let aa = b.it();
                        b.bin(IntOp::Sub, aa, ix, Val::R(loff));
                        b.bin(IntOp::Sll, aa, aa, Val::I(4));
                        b.bin(IntOp::Add, aa, aa, Val::R(la));
                        b.ld(MemWidth::F64, fr, aa, 0);
                        b.ld(MemWidth::F64, fi, aa, 8);
                        b.free_i(aa);
                        b.free_i(loff);
                        b.free_i(la);
                    }
                }
                b.free_i(ix);
                // write y[c*N1 + rg] — the remote scatter
                let iy = b.it();
                b.bin(IntOp::Mul, iy, c, Val::I(N1 as i64));
                b.bin(IntOp::Add, iy, iy, Val::R(rg));
                match source {
                    SourceVariant::Unoptimized => {
                        let py = b.sptr_init(y, Val::R(iy));
                        b.sptr_st(MemWidth::F64, fr, py, 0);
                        b.sptr_st(MemWidth::F64, fi, py, 8);
                        b.free_i(py);
                    }
                    SourceVariant::Privatized => {
                        // hand-tuned scatter: raw cast address — the
                        // software translation the hardware eliminates,
                        // but without Algorithm 1's divisions
                        let y_va = b.rt.array(y).base_va as i64;
                        let blk = yrows_per * N1; // elems per thread
                        let l2blk = blk.trailing_zeros() as i64;
                        let th = b.it();
                        b.bin(IntOp::Srl, th, iy, Val::I(l2blk));
                        b.bin(IntOp::Add, th, th, Val::I(1));
                        b.bin(IntOp::Sll, th, th, Val::I(32));
                        let off = b.it();
                        b.bin(IntOp::And, off, iy, Val::I(blk as i64 - 1));
                        b.bin(IntOp::Sll, off, off, Val::I(4));
                        b.bin(IntOp::Add, th, th, Val::R(off));
                        b.free_i(off);
                        b.bin(IntOp::Add, th, th, Val::I(y_va));
                        b.st(MemWidth::F64, fr, th, 0);
                        b.st(MemWidth::F64, fi, th, 8);
                        b.free_i(th);
                    }
                }
                b.free_i(iy);
                b.free_f(fi);
                b.free_f(fr);
            });
            b.free_i(rg);
        });
        b.free_i(r0);
    }
    b.barrier();

    // ---- step 3: FFT my rows of y (length N1) ----
    emit_fft_rows(&mut b, source, myt, y, yrows_per, N1, twy_off, revy_off);

    let module = b.finish("ft");

    let data = input_data(N1, n2);
    let setup = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        // batched address generation through the AddressEngine walk;
        // each 16-byte complex element stores (re, im) at (a, a+8)
        let addrs = rt.sysva_seq(mem, x, 0, data.len());
        for (&a, &(re, im)) in addrs.iter().zip(&data) {
            mem.write_f64(a, re);
            mem.write_f64(a + 8, im);
        }
        // private tables, identical on every thread
        let twx = twiddles(n2);
        let twy = twiddles(N1);
        for t in 0..threads {
            for (i, &(re, im)) in twx.iter().enumerate() {
                let a = rt.priv_sysva(t, twx_off + i as u64 * 16);
                mem.write_f64(a, re);
                mem.write_f64(a + 8, im);
            }
            for (i, &(re, im)) in twy.iter().enumerate() {
                let a = rt.priv_sysva(t, twy_off + i as u64 * 16);
                mem.write_f64(a, re);
                mem.write_f64(a + 8, im);
            }
            for i in 0..n2 {
                let a = rt.priv_sysva(t, revx_off + i * 8);
                mem.write(MemWidth::U64, a, bitrev(i, n2.trailing_zeros()));
            }
            for i in 0..N1 {
                let a = rt.priv_sysva(t, revy_off + i * 8);
                mem.write(MemWidth::U64, a, bitrev(i, N1.trailing_zeros()));
            }
        }
    });

    let validate = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        let want = host_reference(n2);
        let addrs = rt.sysva_seq(mem, y, 0, (N1 * n2) as usize);
        for (i, &a) in addrs.iter().enumerate() {
            let gr = mem.read_f64(a);
            let gi = mem.read_f64(a + 8);
            let (wr, wi) = want[i];
            if (gr - wr).abs() > 1e-9 * wr.abs().max(1.0)
                || (gi - wi).abs() > 1e-9 * wi.abs().max(1.0)
            {
                return Err(format!("y[{i}] = ({gr},{gi}), want ({wr},{wi})"));
            }
        }
        Ok(())
    });

    BuiltKernel { rt, module, setup, validate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::CpuModel;
    use crate::npb::{run, Kernel, PaperVariant};

    #[test]
    fn host_fft_parseval() {
        let n = 64;
        let mut x: Vec<Cpx> = (0..n).map(|i| ((i % 5) as f64 - 2.0, 0.0)).collect();
        let energy_t: f64 = x.iter().map(|(r, i)| r * r + i * i).sum();
        let tw = twiddles(n as u64);
        host_fft_row(&mut x, &tw);
        let energy_f: f64 = x.iter().map(|(r, i)| r * r + i * i).sum();
        assert!(
            ((energy_f / n as f64) - energy_t).abs() < 1e-9 * energy_t,
            "Parseval violated: {energy_f} vs {energy_t}"
        );
    }

    #[test]
    fn ft_validates_in_all_variants() {
        let scale = Scale { factor: 256 };
        for v in PaperVariant::ALL {
            let out = run(Kernel::Ft, v, CpuModel::Atomic, 4, &scale);
            assert!(out.result.cycles > 0, "{v:?}");
        }
    }

    #[test]
    fn ft_hw_beats_manual() {
        let scale = Scale { factor: 256 };
        let t = 4;
        let unopt = run(Kernel::Ft, PaperVariant::Unopt, CpuModel::Atomic, t, &scale);
        let manual = run(Kernel::Ft, PaperVariant::Manual, CpuModel::Atomic, t, &scale);
        let hw = run(Kernel::Ft, PaperVariant::Hw, CpuModel::Atomic, t, &scale);
        let (cu, cm, ch) = (
            unopt.result.cycles as f64,
            manual.result.cycles as f64,
            hw.result.cycles as f64,
        );
        assert!(cu / ch > 1.5, "FT hw speedup {:.2} should be ~2.3x", cu / ch);
        assert!(ch < cm, "hw ({ch}) should beat manual ({cm}) on FT");
    }
}
