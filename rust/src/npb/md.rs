//! MD — molecular-dynamics neighbor-list force pass (irregular gather).
//!
//! Structure follows the UPC MD mini-apps (arXiv 1603.03888): particles
//! are blocked across threads, each particle carries a fixed-degree
//! neighbor list, and the force pass reads `NBR` *data-dependent*
//! remote positions per particle — the canonical inspector/executor
//! workload.  Unlike the affine NPB kernels, the gather indices are
//! only known at run time, so the hand-optimized variant can privatize
//! the neighbor lists and the force output (both affinity-local) but
//! **not** the position gathers: those stay on shared-pointer
//! arithmetic in every variant.
//!
//! The compiled inner loop emits `NBR` consecutive `sptr_at` lanes
//! (one `PgasIncR` each under HW lowering), which the pipeline's
//! lookahead batches into a single multi-owner window — exactly the
//! shape the engine's [`GatherPlan`](crate::engine::GatherPlan)
//! inspector buckets by owner.  Expected paper shape: HW beats the
//! manual optimization here (the reverse of IS), because the dominant
//! cost is the non-privatizable gather.

use super::{BuiltKernel, Scale};
use crate::compiler::{IrBuilder, SourceVariant, Val};
use crate::isa::{IntOp, MemWidth};
use crate::upc::UpcRuntime;
use crate::util::rng::Xoshiro256;

/// Class-W-like particle count (scaled down via `Scale`).
const CLASS_W_PARTICLES: u64 = 1 << 16;
/// Fixed neighbor-list degree (pow2 so the list array is HW-mappable;
/// also the gather-lane count per particle, sized to fill one
/// lookahead window at the selector's default gather threshold).
const NBR: u64 = 8;
/// Position values stay below this so integer force sums never wrap.
const POS_RANGE: u64 = 1 << 10;

fn host_data(n: u64) -> (Vec<u64>, Vec<u64>) {
    let mut rng = Xoshiro256::new(0x3D00_0001);
    let pos: Vec<u64> = (0..n).map(|_| rng.below(POS_RANGE)).collect();
    let nbr: Vec<u64> = (0..n * NBR).map(|_| rng.below(n)).collect();
    (pos, nbr)
}

pub fn build(threads: u32, source: SourceVariant, scale: &Scale) -> BuiltKernel {
    let n = scale.dim(CLASS_W_PARTICLES, 256).next_power_of_two();
    let chunk = n / threads as u64;
    assert!(chunk >= 1, "more threads than particles");

    let mut rt = UpcRuntime::new(threads);
    // positions: blocked so thread t owns x[t*chunk .. (t+1)*chunk)
    let x = rt.alloc_shared("md_x", chunk, 8, n);
    // neighbor lists: thread t owns its particles' lists contiguously
    let nbr = rt.alloc_shared("md_nbr", chunk * NBR, 8, n * NBR);
    // force accumulators, same distribution as positions
    let f = rt.alloc_shared("md_f", chunk, 8, n);

    let mut b = IrBuilder::new(&mut rt);

    // Loop-invariant gather base: &x[0].  Every lane below computes
    // &x[j] from it without disturbing the cursor, so consecutive
    // lanes stay independent and window-batchable.
    let bx = b.sptr_init(x, Val::I(0));

    match source {
        SourceVariant::Unoptimized => {
            // everything through shared pointers, as plain UPC compiles
            let myt = b.mythread();
            let start = b.it();
            b.bin(IntOp::Mul, start, myt, Val::I(chunk as i64));
            let nstart = b.it();
            b.bin(IntOp::Mul, nstart, myt, Val::I((chunk * NBR) as i64));
            let pnb = b.sptr_init(nbr, Val::R(nstart));
            let pf = b.sptr_init(f, Val::R(start));
            b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                let j: Vec<u8> = (0..NBR).map(|_| b.it()).collect();
                // read this particle's whole neighbor list (own block,
                // consecutive elements: byte displacements off the
                // list cursor)
                for (g, &jg) in j.iter().enumerate() {
                    b.sptr_ld(MemWidth::U64, jg, pnb, (g * 8) as i16);
                }
                // NBR consecutive gather lanes — one batchable
                // PgasIncR run under HW lowering
                for &jg in &j {
                    b.sptr_at(jg, bx, x, Val::R(jg));
                }
                let acc = b.iconst(0);
                for &jg in &j {
                    let v = b.it();
                    b.sptr_ld(MemWidth::U64, v, jg, 0);
                    b.bin(IntOp::Add, acc, acc, Val::R(v));
                    b.free_i(v);
                }
                b.sptr_st(MemWidth::U64, acc, pf, 0);
                b.free_i(acc);
                for &jg in j.iter().rev() {
                    b.free_i(jg);
                }
                b.sptr_inc(pf, f, Val::I(1));
                b.sptr_inc(pnb, nbr, Val::I(NBR as i64));
            });
            b.free_i(pf);
            b.free_i(pnb);
            b.free_i(nstart);
            b.free_i(start);
            b.free_i(myt);
        }
        SourceVariant::Privatized => {
            // the hand-optimized MD: neighbor lists and force output
            // are affinity-local → raw pointers; the position gather
            // is data-dependent and cross-thread → cannot be
            // privatized, stays on shared-pointer arithmetic
            let cn = b.local_addr(nbr, Val::I(0));
            let cf = b.local_addr(f, Val::I(0));
            b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                let j: Vec<u8> = (0..NBR).map(|_| b.it()).collect();
                for (g, &jg) in j.iter().enumerate() {
                    b.ld(MemWidth::U64, jg, cn, (g * 8) as i32);
                }
                for &jg in &j {
                    b.sptr_at(jg, bx, x, Val::R(jg));
                }
                let acc = b.iconst(0);
                for &jg in &j {
                    let v = b.it();
                    b.sptr_ld(MemWidth::U64, v, jg, 0);
                    b.bin(IntOp::Add, acc, acc, Val::R(v));
                    b.free_i(v);
                }
                b.st(MemWidth::U64, acc, cf, 0);
                b.free_i(acc);
                for &jg in j.iter().rev() {
                    b.free_i(jg);
                }
                b.add(cn, cn, Val::I((NBR * 8) as i64));
                b.add(cf, cf, Val::I(8));
            });
            b.free_i(cf);
            b.free_i(cn);
        }
    }
    b.free_i(bx);

    let module = b.finish("md");

    let (pos, lists) = host_data(n);
    let pos_for_setup = pos.clone();
    let lists_for_setup = lists.clone();
    let setup = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        rt.write_u64_seq(mem, x, 0, &pos_for_setup);
        rt.write_u64_seq(mem, nbr, 0, &lists_for_setup);
    });

    let validate = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        let got = rt.read_u64_seq(mem, f, 0, n as usize);
        for i in 0..n as usize {
            let want: u64 = (0..NBR as usize)
                .map(|g| pos[lists[i * NBR as usize + g] as usize])
                .sum();
            if got[i] != want {
                return Err(format!("force[{i}]: got {}, want {want}", got[i]));
            }
        }
        Ok(())
    });

    BuiltKernel { rt, module, setup, validate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::CpuModel;
    use crate::npb::{run, Kernel, PaperVariant};

    #[test]
    fn md_validates_in_all_variants() {
        let scale = Scale { factor: 512 };
        for v in PaperVariant::ALL {
            let out = run(Kernel::Md, v, CpuModel::Atomic, 4, &scale);
            assert!(out.result.cycles > 0, "{v:?}");
        }
    }

    #[test]
    fn md_hw_beats_manual_on_irregular_gather() {
        // The gather dominates and cannot be privatized, so — unlike
        // IS — HW support beats the manual optimization outright.
        let scale = Scale { factor: 512 };
        let t = 4;
        let unopt = run(Kernel::Md, PaperVariant::Unopt, CpuModel::Atomic, t, &scale);
        let manual = run(Kernel::Md, PaperVariant::Manual, CpuModel::Atomic, t, &scale);
        let hw = run(Kernel::Md, PaperVariant::Hw, CpuModel::Atomic, t, &scale);
        let (cu, cm, ch) = (
            unopt.result.cycles as f64,
            manual.result.cycles as f64,
            hw.result.cycles as f64,
        );
        assert!(cu / ch > 2.0, "MD hw speedup {:.2} too small", cu / ch);
        assert!(ch < cm, "hw ({ch}) should beat manual ({cm}) on MD");
    }

    #[test]
    fn md_hw_run_exercises_the_gather_planner() {
        let scale = Scale { factor: 512 };
        let out = run(Kernel::Md, PaperVariant::Hw, CpuModel::Atomic, 4, &scale);
        let g = out.result.gather;
        assert!(g.plans > 0, "multi-owner gather windows should be planned: {g:?}");
        assert!(g.bucketed_ptrs >= g.plans, "{g:?}");
        assert!(out.result.engine_mix.batched_incs > 0);
    }
}
