//! EP — Embarrassingly Parallel (pair generation + acceptance counting).
//!
//! The paper's control case: the main loop contains **no shared-pointer
//! operations**, so hardware support buys nothing (Figure 6).  Each
//! thread generates its share of pseudo-random pairs with a 30-bit LCG
//! (an ISA-friendly stand-in for the NAS `randlc` whose 46-bit modular
//! product needs split arithmetic), counts pairs inside the unit circle,
//! and accumulates the coordinate sums.  The only shared traffic is the
//! final reduction of THREADS partial results.

use super::{BuiltKernel, Scale};
use crate::compiler::{IrBuilder, SourceVariant, Val};
use crate::isa::{Cond, FpOp, IntOp, MemWidth};
use crate::upc::UpcRuntime;

/// LCG parameters (Numerical Recipes 32-bit, truncated to 30 bits so
/// `a * x` never overflows the 64-bit multiply).
const LCG_A: i64 = 1664525;
const LCG_C: i64 = 1013904223;
const LCG_MASK: i64 = (1 << 30) - 1;

/// class W: 2^25 pairs.
const CLASS_W_PAIRS: u64 = 1 << 25;

fn lcg_next(x: u64) -> u64 {
    ((LCG_A as u64).wrapping_mul(x).wrapping_add(LCG_C as u64)) & LCG_MASK as u64
}

/// Host-side reference: (accepted count, sum of accepted x, sum of y).
fn host_reference(thread: u32, pairs: u64) -> (u64, f64, f64) {
    let mut x = (0x2DEAD + 0x9E37 * thread as u64) & LCG_MASK as u64;
    let (mut acc, mut sx, mut sy) = (0u64, 0.0f64, 0.0f64);
    let scale = 1.0 / (1u64 << 30) as f64;
    for _ in 0..pairs {
        x = lcg_next(x);
        let u1 = x as f64 * scale;
        x = lcg_next(x);
        let u2 = x as f64 * scale;
        let (a, b) = (2.0 * u1 - 1.0, 2.0 * u2 - 1.0);
        if a * a + b * b <= 1.0 {
            acc += 1;
            sx += a;
            sy += b;
        }
    }
    (acc, sx, sy)
}

pub fn build(threads: u32, source: SourceVariant, scale: &Scale) -> BuiltKernel {
    let pairs_total = scale.dim(CLASS_W_PAIRS, 1 << 10);
    let pairs_per = pairs_total / threads as u64;

    let mut rt = UpcRuntime::new(threads);
    // results: counts (u64) and sums (f64), cyclically distributed so
    // slot t has affinity to thread t
    let counts = rt.alloc_shared("ep_counts", 1, 8, threads as u64);
    let sums_x = rt.alloc_shared("ep_sx", 1, 8, threads as u64);
    let sums_y = rt.alloc_shared("ep_sy", 1, 8, threads as u64);
    // reduced outputs (affinity thread 0)
    let out = rt.alloc_shared("ep_out", 4, 8, 4);

    let mut b = IrBuilder::new(&mut rt);
    // ---- per-thread generation loop (no shared ops) ----
    let myt = b.mythread();
    let seed = b.it();
    b.bin(IntOp::Mul, seed, myt, Val::I(0x9E37));
    b.bin(IntOp::Add, seed, seed, Val::I(0x2DEAD));
    b.bin(IntOp::And, seed, seed, Val::I(LCG_MASK));
    let acc = b.iconst(0);
    let fsx = b.fconst(0.0);
    let fsy = b.fconst(0.0);
    let fone = b.fconst(1.0);
    let ftwo = b.fconst(2.0);
    let fscale = b.fconst(1.0 / (1u64 << 30) as f64);

    b.for_range(Val::I(0), Val::I(pairs_per as i64), 1, |b, _i| {
        let fa = b.ft();
        let fb = b.ft();
        let ft = b.ft();
        // u1
        b.bin(IntOp::Mul, seed, seed, Val::I(LCG_A));
        b.bin(IntOp::Add, seed, seed, Val::I(LCG_C));
        b.bin(IntOp::And, seed, seed, Val::I(LCG_MASK));
        b.cvt_if(fa, seed);
        b.fbin(FpOp::FMul, fa, fa, fscale);
        b.fbin(FpOp::FMul, fa, fa, ftwo);
        b.fbin(FpOp::FSub, fa, fa, fone);
        // u2
        b.bin(IntOp::Mul, seed, seed, Val::I(LCG_A));
        b.bin(IntOp::Add, seed, seed, Val::I(LCG_C));
        b.bin(IntOp::And, seed, seed, Val::I(LCG_MASK));
        b.cvt_if(fb, seed);
        b.fbin(FpOp::FMul, fb, fb, fscale);
        b.fbin(FpOp::FMul, fb, fb, ftwo);
        b.fbin(FpOp::FSub, fb, fb, fone);
        // t = a*a + b*b ; accept if t <= 1 (i.e. !(1 < t))
        let fa2 = b.ft();
        b.fbin(FpOp::FMul, fa2, fa, fa);
        b.fbin(FpOp::FMul, ft, fb, fb);
        b.fbin(FpOp::FAdd, ft, ft, fa2);
        let cmp = b.it();
        b.fcmplt(cmp, fone, ft); // 1 < t → reject
        b.iff(Cond::Eq, cmp, |b| {
            b.bin(IntOp::Add, acc, acc, Val::I(1));
            b.fbin(FpOp::FAdd, fsx, fsx, fa);
            b.fbin(FpOp::FAdd, fsy, fsy, fb);
        });
        b.free_i(cmp);
        b.free_f(fa2);
        b.free_f(ft);
        b.free_f(fb);
        b.free_f(fa);
    });

    // ---- publish partial results (tiny shared traffic) ----
    match source {
        SourceVariant::Unoptimized => {
            let pc = b.sptr_init(counts, Val::R(myt));
            let px = b.sptr_init(sums_x, Val::R(myt));
            let py = b.sptr_init(sums_y, Val::R(myt));
            b.sptr_st(MemWidth::U64, acc, pc, 0);
            b.sptr_st(MemWidth::F64, fsx, px, 0);
            b.sptr_st(MemWidth::F64, fsy, py, 0);
            b.free_i(py);
            b.free_i(px);
            b.free_i(pc);
        }
        SourceVariant::Privatized => {
            // own slot is affinity-local: store through a raw cursor
            let ac = b.local_addr(counts, Val::I(0));
            let ax = b.local_addr(sums_x, Val::I(0));
            let ay = b.local_addr(sums_y, Val::I(0));
            b.st(MemWidth::U64, acc, ac, 0);
            b.st(MemWidth::F64, fsx, ax, 0);
            b.st(MemWidth::F64, fsy, ay, 0);
            b.free_i(ay);
            b.free_i(ax);
            b.free_i(ac);
        }
    }
    b.barrier();

    // ---- thread 0 reduces ----
    b.iff(Cond::Eq, myt, |b| {
        let tot = b.iconst(0);
        let ftx = b.fconst(0.0);
        let fty = b.fconst(0.0);
        let pc = b.sptr_init(counts, Val::I(0));
        let px = b.sptr_init(sums_x, Val::I(0));
        let py = b.sptr_init(sums_y, Val::I(0));
        let nt = b.threads();
        b.for_range(Val::I(0), Val::R(nt), 1, |b, _t| {
            let v = b.it();
            b.sptr_ld(MemWidth::U64, v, pc, 0);
            b.bin(IntOp::Add, tot, tot, Val::R(v));
            let fv = b.ft();
            b.sptr_ld(MemWidth::F64, fv, px, 0);
            b.fbin(FpOp::FAdd, ftx, ftx, fv);
            b.sptr_ld(MemWidth::F64, fv, py, 0);
            b.fbin(FpOp::FAdd, fty, fty, fv);
            b.sptr_inc(pc, counts, Val::I(1));
            b.sptr_inc(px, sums_x, Val::I(1));
            b.sptr_inc(py, sums_y, Val::I(1));
            b.free_f(fv);
            b.free_i(v);
        });
        let po = b.sptr_init(out, Val::I(0));
        b.sptr_st(MemWidth::U64, tot, po, 0);
        b.sptr_st(MemWidth::F64, ftx, po, 8);
        b.sptr_st(MemWidth::F64, fty, po, 16);
        b.free_i(po);
        b.free_i(nt);
        b.free_i(py);
        b.free_i(px);
        b.free_i(pc);
        b.free_f(fty);
        b.free_f(ftx);
        b.free_i(tot);
    });

    let module = b.finish("ep");

    let setup = Box::new(move |_rt: &UpcRuntime, _mem: &mut crate::mem::MemSystem| {});
    let validate = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        let (mut want_n, mut want_x, mut want_y) = (0u64, 0.0, 0.0);
        for t in 0..threads {
            let (n, x, y) = host_reference(t, pairs_per);
            want_n += n;
            want_x += x;
            want_y += y;
        }
        let got_n = rt.read_u64(mem, out, 0);
        let a0 = rt.sysva(mem, out, 0);
        let got_x = mem.read_f64(a0 + 8);
        let got_y = mem.read_f64(a0 + 16);
        if got_n != want_n {
            return Err(format!("count {got_n} != {want_n}"));
        }
        if (got_x - want_x).abs() > 1e-9 * want_x.abs().max(1.0) {
            return Err(format!("sx {got_x} != {want_x}"));
        }
        if (got_y - want_y).abs() > 1e-9 * want_y.abs().max(1.0) {
            return Err(format!("sy {got_y} != {want_y}"));
        }
        Ok(())
    });

    BuiltKernel { rt, module, setup, validate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::CpuModel;
    use crate::npb::{run, Kernel, PaperVariant};

    #[test]
    fn ep_validates_in_all_variants() {
        let scale = Scale { factor: 2048 };
        for v in PaperVariant::ALL {
            let out = run(Kernel::Ep, v, CpuModel::Atomic, 4, &scale);
            assert!(out.result.cycles > 0);
        }
    }

    #[test]
    fn ep_hw_gains_are_negligible() {
        // the paper's control: no shared pointers in the main loop
        let scale = Scale { factor: 1024 };
        let unopt = run(Kernel::Ep, PaperVariant::Unopt, CpuModel::Atomic, 4, &scale);
        let hw = run(Kernel::Ep, PaperVariant::Hw, CpuModel::Atomic, 4, &scale);
        let speedup = unopt.result.cycles as f64 / hw.result.cycles as f64;
        assert!(
            (0.95..1.10).contains(&speedup),
            "EP speedup should be ~1.0, got {speedup:.3}"
        );
    }

    #[test]
    fn host_reference_acceptance_rate_sane() {
        // ~π/4 of pairs fall in the unit circle
        let (n, _, _) = host_reference(0, 10_000);
        let rate = n as f64 / 10_000.0;
        assert!((rate - std::f64::consts::FRAC_PI_4).abs() < 0.02, "{rate}");
    }
}
