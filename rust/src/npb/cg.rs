//! CG — Conjugate Gradient kernel (sparse matrix–vector iteration).
//!
//! Structure follows the UPC NPB CG inner loop: repeated `q = A·p` with a
//! fixed-degree sparse matrix (8 nonzeros/row), a global reduction of q,
//! and a vector update — plus the paper's famous non-power-of-2 detail:
//! a struct array with **elemsize 56016** (scaled here to a 112-byte
//! struct, still non-pow2) whose pointer increments the HW variant must
//! execute in software ("the generated code contained 309 shared address
//! incrementations but 20 of those were using a non-power of 2 element
//! size (the arrays w and w_tmp)").
//!
//! Paper shape (Figs. 7/11): HW ≈ 2.6× over unoptimized and ~17% *ahead*
//! of the manually-privatized code, because the random-column accesses
//! `p[colidx[j]]` cannot be privatized — the hand-tuned source still pays
//! the software translation there, while the hardware does not.

use super::{BuiltKernel, Scale};
use crate::compiler::{IrBuilder, SourceVariant, Val};
use crate::isa::{Cond, FpOp, IntOp, MemWidth};
use crate::upc::UpcRuntime;
use crate::util::rng::Xoshiro256;

/// class W: na = 7000 rows; scaled, rounded to a pow2 multiple of T.
const CLASS_W_ROWS: u64 = 7000;
const NNZ_PER_ROW: u64 = 8;
const NITER: u64 = 3;
/// The w/w_tmp struct size, scaled from 56016 (non-pow2: 112 = 16·7).
const WTMP_ELEMSIZE: u64 = 112;

fn gen_matrix(n: u64, seed: u64) -> (Vec<u32>, Vec<f64>) {
    let mut rng = Xoshiro256::new(seed);
    let mut colidx = Vec::with_capacity((n * NNZ_PER_ROW) as usize);
    let mut aval = Vec::with_capacity((n * NNZ_PER_ROW) as usize);
    for r in 0..n {
        for j in 0..NNZ_PER_ROW {
            // one diagonal element per row keeps the iteration stable
            let c = if j == 0 { r } else { rng.below(n) };
            colidx.push(c as u32);
            aval.push(if j == 0 { 1.5 } else { (rng.f64() - 0.5) * 0.25 });
        }
    }
    (colidx, aval)
}

/// Host mirror of the exact simulated computation (same op order).
fn host_reference(n: u64, threads: u32, colidx: &[u32], aval: &[f64]) -> Vec<f64> {
    let chunk = n / threads as u64;
    let mut p: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64 * 0.125).collect();
    let mut q = vec![0.0f64; n as usize];
    for _ in 0..NITER {
        for r in 0..n as usize {
            let mut acc = 0.0f64;
            for j in 0..NNZ_PER_ROW as usize {
                let k = r * NNZ_PER_ROW as usize + j;
                acc += aval[k] * p[colidx[k] as usize];
            }
            q[r] = acc;
        }
        // thread-0 sequential global sum (the kernel's reduction order)
        let mut s = 0.0f64;
        for r in 0..n as usize {
            s += q[r];
        }
        let scale = 1.0 / (1.0 + (s / n as f64).abs());
        let _ = chunk;
        for r in 0..n as usize {
            p[r] = q[r] * scale;
        }
    }
    p
}

pub fn build(threads: u32, source: SourceVariant, scale: &Scale) -> BuiltKernel {
    // rows: pow2-per-thread chunks (blocked layout stays hw-supported)
    let chunk = scale.dim(CLASS_W_ROWS, 64).next_power_of_two() / threads as u64;
    let chunk = chunk.max(8);
    let n = chunk * threads as u64;

    let mut rt = UpcRuntime::new(threads);
    let colidx = rt.alloc_shared("cg_colidx", chunk * NNZ_PER_ROW, 4, n * NNZ_PER_ROW);
    let aval = rt.alloc_shared("cg_aval", chunk * NNZ_PER_ROW, 8, n * NNZ_PER_ROW);
    let p = rt.alloc_shared("cg_p", chunk, 8, n);
    let q = rt.alloc_shared("cg_q", chunk, 8, n);
    // global sum cell + the non-pow2 w_tmp struct array (1 per thread)
    let gsum = rt.alloc_shared("cg_gsum", 1, 8, 1);
    let wtmp = rt.alloc_shared("cg_wtmp", 1, WTMP_ELEMSIZE, threads as u64);

    let (colidx_data, aval_data) = gen_matrix(n, 0xC6_0001);

    let mut b = IrBuilder::new(&mut rt);
    let myt = b.mythread();
    let rowstart = b.it();
    b.bin(IntOp::Mul, rowstart, myt, Val::I(chunk as i64));

    let fone = b.fconst(1.0);
    let fninv = b.fconst(1.0 / n as f64);

    // NITER outer iterations as a countdown do-while
    let iter = b.it();
    b.mov(iter, Val::I(NITER as i64));
    b.do_while(Cond::Gt, iter, |b| {
        // ---------- q = A·p over my rows ----------
        match source {
            SourceVariant::Unoptimized => {
                let nzstart = b.it();
                b.bin(IntOp::Mul, nzstart, rowstart, Val::I(NNZ_PER_ROW as i64));
                let pa = b.sptr_init(aval, Val::R(nzstart));
                let pc = b.sptr_init(colidx, Val::R(nzstart));
                let pq = b.sptr_init(q, Val::R(rowstart));
                b.free_i(nzstart);
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _r| {
                    let facc = b.fconst(0.0);
                    b.for_range(Val::I(0), Val::I(NNZ_PER_ROW as i64), 1, |b, _j| {
                        let col = b.it();
                        b.sptr_ld(MemWidth::U32, col, pc, 0);
                        // p[col]: fresh shared pointer per access — the
                        // unoptimized `p[colidx[k]]`
                        let pp = b.sptr_init(p, Val::R(col));
                        let fv = b.ft();
                        let fa = b.ft();
                        b.sptr_ld(MemWidth::F64, fv, pp, 0);
                        b.sptr_ld(MemWidth::F64, fa, pa, 0);
                        b.fbin(FpOp::FMul, fv, fv, fa);
                        b.fbin(FpOp::FAdd, facc, facc, fv);
                        b.free_f(fa);
                        b.free_f(fv);
                        b.free_i(pp);
                        b.free_i(col);
                        b.sptr_inc(pa, aval, Val::I(1));
                        b.sptr_inc(pc, colidx, Val::I(1));
                    });
                    b.sptr_st(MemWidth::F64, facc, pq, 0);
                    b.sptr_inc(pq, q, Val::I(1));
                    b.free_f(facc);
                });
                b.free_i(pq);
                b.free_i(pc);
                b.free_i(pa);
            }
            SourceVariant::Privatized => {
                // own-chunk walks privatized; p[col] is random-access,
                // so the hand-tuned SMP code reaches it through a raw
                // cast address (thread = col/chunk, offset = col%chunk)
                // — cheaper than Algorithm 1 but still 6 extra ops per
                // access that the hardware does in zero
                let p_va = b.rt.array(p).base_va as i64;
                let l2chunk = chunk.trailing_zeros() as i64;
                let ca = b.local_addr(aval, Val::I(0));
                let cc = b.local_addr(colidx, Val::I(0));
                let cq = b.local_addr(q, Val::I(0));
                b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _r| {
                    let facc = b.fconst(0.0);
                    b.for_range(Val::I(0), Val::I(NNZ_PER_ROW as i64), 1, |b, _j| {
                        let col = b.it();
                        b.ld(MemWidth::U32, col, cc, 0);
                        // raw addr of p[col]
                        let th = b.it();
                        b.bin(IntOp::Srl, th, col, Val::I(l2chunk));
                        b.bin(IntOp::Add, th, th, Val::I(1));
                        b.bin(IntOp::Sll, th, th, Val::I(32));
                        let off = b.it();
                        b.bin(IntOp::And, off, col, Val::I(chunk as i64 - 1));
                        b.bin(IntOp::Sll, off, off, Val::I(3));
                        b.bin(IntOp::Add, th, th, Val::R(off));
                        b.free_i(off);
                        let fv = b.ft();
                        let fa = b.ft();
                        b.ld(MemWidth::F64, fv, th, p_va as i32);
                        b.free_i(th);
                        b.ld(MemWidth::F64, fa, ca, 0);
                        b.fbin(FpOp::FMul, fv, fv, fa);
                        b.fbin(FpOp::FAdd, facc, facc, fv);
                        b.free_f(fa);
                        b.free_f(fv);
                        b.free_i(col);
                        b.add(ca, ca, Val::I(8));
                        b.add(cc, cc, Val::I(4));
                    });
                    b.st(MemWidth::F64, facc, cq, 0);
                    b.add(cq, cq, Val::I(8));
                    b.free_f(facc);
                });
                b.free_i(cq);
                b.free_i(cc);
                b.free_i(ca);
            }
        }

        // record my partial into the non-pow2 w_tmp struct (first f64
        // field) — HW must fall back to software increments here
        {
            let pw = b.sptr_init(wtmp, Val::I(0));
            b.sptr_inc(pw, wtmp, Val::R(myt));
            b.sptr_st(MemWidth::F64, fone, pw, 0);
            b.free_i(pw);
        }
        b.barrier();

        // ---------- thread 0: s = Σ q[i] (remote-heavy) ----------
        b.iff(Cond::Eq, myt, |b| {
            let fs = b.fconst(0.0);
            match source {
                SourceVariant::Unoptimized => {
                    let pqa = b.sptr_init(q, Val::I(0));
                    b.for_range(Val::I(0), Val::I(n as i64), 1, |b, _| {
                        let fv = b.ft();
                        b.sptr_ld(MemWidth::F64, fv, pqa, 0);
                        b.fbin(FpOp::FAdd, fs, fs, fv);
                        b.free_f(fv);
                        b.sptr_inc(pqa, q, Val::I(1));
                    });
                    b.free_i(pqa);
                }
                SourceVariant::Privatized => {
                    // hand-tuned reduction: raw cursor per remote chunk
                    // (the blocked layout is contiguous per thread).
                    // NB: summation order over q is identical to the
                    // shared-pointer walk (thread-major), so the f64
                    // result is bit-identical.
                    let q_va = b.rt.array(q).base_va as i64;
                    b.for_range(Val::I(0), Val::I(threads as i64), 1, |b, u| {
                        let raw = b.it();
                        b.bin(IntOp::Add, raw, u, Val::I(1));
                        b.bin(IntOp::Sll, raw, raw, Val::I(32));
                        b.bin(IntOp::Add, raw, raw, Val::I(q_va));
                        b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                            let fv = b.ft();
                            b.ld(MemWidth::F64, fv, raw, 0);
                            b.fbin(FpOp::FAdd, fs, fs, fv);
                            b.free_f(fv);
                            b.add(raw, raw, Val::I(8));
                        });
                        b.free_i(raw);
                    });
                }
            }
            let pg = b.sptr_init(gsum, Val::I(0));
            b.sptr_st(MemWidth::F64, fs, pg, 0);
            b.free_i(pg);
            b.free_f(fs);
        });
        b.barrier();

        // ---------- p = q * 1/(1 + |s|/n) over my rows ----------
        {
            let pg = b.sptr_init(gsum, Val::I(0));
            let fs = b.ft();
            b.sptr_ld(MemWidth::F64, fs, pg, 0);
            b.free_i(pg);
            b.fbin(FpOp::FMul, fs, fs, fninv);
            b.fbin(FpOp::FAbs, fs, fs, fs);
            b.fbin(FpOp::FAdd, fs, fs, fone);
            let fscale = b.ft();
            b.fbin(FpOp::FDiv, fscale, fone, fs);
            b.free_f(fs);
            match source {
                SourceVariant::Unoptimized => {
                    let pq2 = b.sptr_init(q, Val::R(rowstart));
                    let pp2 = b.sptr_init(p, Val::R(rowstart));
                    b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                        let fv = b.ft();
                        b.sptr_ld(MemWidth::F64, fv, pq2, 0);
                        b.fbin(FpOp::FMul, fv, fv, fscale);
                        b.sptr_st(MemWidth::F64, fv, pp2, 0);
                        b.free_f(fv);
                        b.sptr_inc(pq2, q, Val::I(1));
                        b.sptr_inc(pp2, p, Val::I(1));
                    });
                    b.free_i(pp2);
                    b.free_i(pq2);
                }
                SourceVariant::Privatized => {
                    let cq = b.local_addr(q, Val::I(0));
                    let cp = b.local_addr(p, Val::I(0));
                    b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
                        let fv = b.ft();
                        b.ld(MemWidth::F64, fv, cq, 0);
                        b.fbin(FpOp::FMul, fv, fv, fscale);
                        b.st(MemWidth::F64, fv, cp, 0);
                        b.free_f(fv);
                        b.add(cq, cq, Val::I(8));
                        b.add(cp, cp, Val::I(8));
                    });
                    b.free_i(cp);
                    b.free_i(cq);
                }
            }
            b.free_f(fscale);
        }
        b.barrier();

        b.bin(IntOp::Sub, iter, iter, Val::I(1));
    });
    b.free_i(iter);
    let module = b.finish("cg");

    let colidx_setup = colidx_data.clone();
    let aval_setup = aval_data.clone();
    let setup = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        // batched init through the runtime's AddressEngine walk
        let cols: Vec<u64> = colidx_setup.iter().map(|&c| c as u64).collect();
        rt.write_u64_seq(mem, colidx, 0, &cols);
        rt.write_f64_seq(mem, aval, 0, &aval_setup);
        let pv: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64 * 0.125).collect();
        rt.write_f64_seq(mem, p, 0, &pv);
        rt.write_f64_seq(mem, q, 0, &vec![0.0; n as usize]);
    });

    let validate = Box::new(move |rt: &UpcRuntime, mem: &mut crate::mem::MemSystem| {
        let want = host_reference(n, threads, &colidx_data, &aval_data);
        let got = rt.read_f64_seq(mem, p, 0, n as usize);
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            if (g - w).abs() > 1e-9 * w.abs().max(1.0) {
                return Err(format!("p[{i}] = {g}, want {w}"));
            }
        }
        Ok(())
    });

    BuiltKernel { rt, module, setup, validate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::CpuModel;
    use crate::npb::{compile_only, run, Kernel, PaperVariant};

    #[test]
    fn cg_validates_in_all_variants() {
        let scale = Scale { factor: 64 };
        for v in PaperVariant::ALL {
            let out = run(Kernel::Cg, v, CpuModel::Atomic, 4, &scale);
            assert!(out.result.cycles > 0, "{v:?}");
        }
    }

    #[test]
    fn cg_hw_beats_manual_and_has_soft_fallback() {
        let scale = Scale { factor: 64 };
        let t = 4;
        let unopt = run(Kernel::Cg, PaperVariant::Unopt, CpuModel::Atomic, t, &scale);
        let manual = run(Kernel::Cg, PaperVariant::Manual, CpuModel::Atomic, t, &scale);
        let hw = run(Kernel::Cg, PaperVariant::Hw, CpuModel::Atomic, t, &scale);
        let (cu, cm, ch) = (
            unopt.result.cycles as f64,
            manual.result.cycles as f64,
            hw.result.cycles as f64,
        );
        assert!(cu / ch > 1.8, "CG hw speedup {:.2} should be ~2.6x", cu / ch);
        assert!(ch < cm, "hw ({ch}) should beat manual ({cm}) on CG");
        // the non-pow2 w_tmp array forces software fallback increments
        assert!(hw.compile_stats.soft_incs > 0, "w_tmp must fall back");
        assert!(hw.compile_stats.hw_incs > 0);
    }

    #[test]
    fn cg_census_mixes_hw_and_soft() {
        let (_, stats) = compile_only(
            Kernel::Cg,
            4,
            PaperVariant::Hw,
            &Scale { factor: 64 },
        );
        assert!(stats.hw_mems > 0);
        assert!(stats.soft_incs > 0 && stats.hw_incs > stats.soft_incs);
    }
}
