//! The five NAS Parallel Benchmark kernels of the paper's evaluation
//! (EP, IS, CG, MG, FT), expressed against the UPC runtime and compiled
//! by the mini-UPC compiler in the paper's three configurations — plus
//! two irregular-gather workloads (MD neighbor-list traversal, SPMV
//! CSR gather) that exercise the engine's inspector/executor tier
//! ([`Kernel::IRREGULAR`]).
//!
//! Class-W problem shapes are preserved structurally but scaled down by
//! a configurable factor (cycle-level simulation of full class W takes
//! days even in the paper); every kernel validates its numerical output
//! against a host-side reference, in every variant.
//!
//! Hardware adaptation notes (also in DESIGN.md): MG's 3D Poisson
//! V-cycle is realized as a 1D multigrid V-cycle and FT's 3D FFT as the
//! distributed row-FFT + transpose + row-FFT structure; both preserve
//! the property the figures measure — the density and locality mix of
//! shared-pointer operations per unit of computation.

pub mod cg;
pub mod ep;
pub mod ft;
pub mod is;
pub mod md;
pub mod mg;
pub mod spmv;

use crate::compiler::{
    compile, CompileOpts, CompileStats, IrModule, Lowering, SourceVariant,
};
use crate::cpu::CpuModel;
use crate::mem::MemSystem;
use crate::sim::{Machine, MachineCfg, MachineResult};
use crate::upc::UpcRuntime;

/// The five paper kernels plus the two irregular-gather workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kernel {
    Ep,
    Is,
    Cg,
    Mg,
    Ft,
    Md,
    Spmv,
}

impl Kernel {
    /// The paper's five NPB kernels — the figure sweeps iterate these.
    pub const ALL: [Kernel; 5] =
        [Kernel::Ep, Kernel::Is, Kernel::Cg, Kernel::Mg, Kernel::Ft];

    /// The irregular-gather workloads (data-dependent indices; they
    /// exercise the engine's inspector/executor gather tier and ride
    /// along in the chaos soak, not in the paper figures).
    pub const IRREGULAR: [Kernel; 2] = [Kernel::Md, Kernel::Spmv];

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Ep => "EP",
            Kernel::Is => "IS",
            Kernel::Cg => "CG",
            Kernel::Mg => "MG",
            Kernel::Ft => "FT",
            Kernel::Md => "MD",
            Kernel::Spmv => "SPMV",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "EP" => Some(Kernel::Ep),
            "IS" => Some(Kernel::Is),
            "CG" => Some(Kernel::Cg),
            "MG" => Some(Kernel::Mg),
            "FT" => Some(Kernel::Ft),
            "MD" => Some(Kernel::Md),
            "SPMV" => Some(Kernel::Spmv),
            _ => None,
        }
    }

    /// Core-count ceiling (FT's class-W slab distribution caps at 16,
    /// as in the paper's Figure 8).
    pub fn max_cores(&self) -> u32 {
        match self {
            Kernel::Ft => 16,
            _ => 64,
        }
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The paper's three measured configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PaperVariant {
    /// "Without Manual Optimizations": plain source, software pointers.
    Unopt,
    /// "Manual Optimization": privatized source, software pointers.
    Manual,
    /// "Without Manual Optimizations, but with HW support".
    Hw,
}

impl PaperVariant {
    pub const ALL: [PaperVariant; 3] =
        [PaperVariant::Unopt, PaperVariant::Manual, PaperVariant::Hw];

    pub fn source(&self) -> SourceVariant {
        match self {
            PaperVariant::Manual => SourceVariant::Privatized,
            _ => SourceVariant::Unoptimized,
        }
    }

    pub fn lowering(&self) -> Lowering {
        match self {
            PaperVariant::Hw => Lowering::Hw,
            _ => Lowering::Soft,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PaperVariant::Unopt => "no-manual-opt",
            PaperVariant::Manual => "manual-opt",
            PaperVariant::Hw => "no-manual-opt+HW",
        }
    }
}

/// Problem-size scaling: class-W dimensions divided by `factor`
/// (factor 1 = full class W; the default keeps atomic-model 64-core
/// sweeps in seconds).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub factor: u32,
}

impl Default for Scale {
    fn default() -> Self {
        Scale { factor: 64 }
    }
}

impl Scale {
    pub fn quick() -> Self {
        Scale { factor: 256 }
    }

    /// Scale a class-W dimension down, keeping a floor.
    pub fn dim(&self, class_w: u64, floor: u64) -> u64 {
        (class_w / self.factor as u64).max(floor)
    }
}

/// A kernel instance ready to run: runtime, IR, setup and validation.
pub struct BuiltKernel {
    pub rt: UpcRuntime,
    pub module: IrModule,
    /// Write workload inputs into simulated memory.
    pub setup: Box<dyn Fn(&UpcRuntime, &mut MemSystem)>,
    /// Check outputs against the host reference.
    pub validate: Box<dyn Fn(&UpcRuntime, &mut MemSystem) -> Result<(), String>>,
}

/// Build `kernel` for `threads` UPC threads in the given source variant.
pub fn build(
    kernel: Kernel,
    threads: u32,
    source: SourceVariant,
    scale: &Scale,
) -> BuiltKernel {
    assert!(
        threads <= kernel.max_cores(),
        "{kernel} supports at most {} cores (class-W data distribution)",
        kernel.max_cores()
    );
    match kernel {
        Kernel::Ep => ep::build(threads, source, scale),
        Kernel::Is => is::build(threads, source, scale),
        Kernel::Cg => cg::build(threads, source, scale),
        Kernel::Mg => mg::build(threads, source, scale),
        Kernel::Ft => ft::build(threads, source, scale),
        Kernel::Md => md::build(threads, source, scale),
        Kernel::Spmv => spmv::build(threads, source, scale),
    }
}

/// Outcome of one simulated benchmark run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub kernel: Kernel,
    pub variant: PaperVariant,
    pub model: CpuModel,
    pub cores: u32,
    pub result: MachineResult,
    pub compile_stats: CompileStats,
}

impl RunOutcome {
    pub fn mops(&self, ops: u64) -> f64 {
        ops as f64 / self.result.runtime_secs() / 1e6
    }

    /// How this run's dynamic PGAS increments were served: batched
    /// through which `AddressEngine` backend vs stepped scalar
    /// (recorded per run for the coordinator's engine-mix-vs-speedup
    /// report).
    pub fn engine_mix(&self) -> &crate::cpu::EngineMix {
        &self.result.engine_mix
    }
}

/// Build, compile, setup, run and validate one configuration.
/// Panics on validation failure — a wrong answer invalidates the figure.
pub fn run(
    kernel: Kernel,
    variant: PaperVariant,
    model: CpuModel,
    cores: u32,
    scale: &Scale,
) -> RunOutcome {
    run_lookahead(kernel, variant, model, cores, scale, true)
}

/// Like [`run`], with explicit control over the CPU pipelines'
/// lookahead batching — the batched-vs-scalar differential legs of
/// the test suite and the fig11–14 benches run each point both ways
/// (cycle totals must match exactly).
pub fn run_lookahead(
    kernel: Kernel,
    variant: PaperVariant,
    model: CpuModel,
    cores: u32,
    scale: &Scale,
    lookahead: bool,
) -> RunOutcome {
    run_opts(kernel, variant, model, cores, scale, lookahead, None)
}

/// The fully-optioned run: lookahead control plus an optional remote
/// address-mapping tier ([`RemoteTier`](crate::engine::RemoteTier))
/// installed into every core's selector before the run — cycle totals
/// are unaffected by *which* backend serves a window (event replay is
/// per instruction either way), so the tier only changes host-side
/// serving and the recorded engine mix (`RunOutcome::engine_mix`,
/// `coordinator::engine_mix_table`).
#[allow(clippy::too_many_arguments)]
pub fn run_opts(
    kernel: Kernel,
    variant: PaperVariant,
    model: CpuModel,
    cores: u32,
    scale: &Scale,
    lookahead: bool,
    remote: Option<&crate::engine::RemoteTier>,
) -> RunOutcome {
    run_opts_with(
        kernel, variant, model, cores, scale, lookahead, remote, None,
    )
}

/// [`run_opts`] plus seeded fault injection: when `chaos` is given,
/// every core's selector is armed with a decorrelated
/// [`FaultPlan`](crate::engine::FaultPlan) stream before the run.
/// Transient injected faults are absorbed by the selector's fallback
/// ladder, so the architectural results (cycles, validation) are
/// bit-identical to the fault-free run — only the `health`/`degrade`
/// telemetry in [`MachineResult`] records the storm (the chaos soak in
/// `tests/chaos.rs` asserts exactly this).
#[allow(clippy::too_many_arguments)]
pub fn run_opts_with(
    kernel: Kernel,
    variant: PaperVariant,
    model: CpuModel,
    cores: u32,
    scale: &Scale,
    lookahead: bool,
    remote: Option<&crate::engine::RemoteTier>,
    chaos: Option<&crate::engine::FaultSpec>,
) -> RunOutcome {
    let built = build(kernel, cores, variant.source(), scale);
    let opts = CompileOpts {
        lowering: variant.lowering(),
        static_threads: false,
        numthreads: cores,
        volatile_stores: true,
    };
    let ck = compile(&built.module, &built.rt, &opts);
    let mut cfg = MachineCfg::new(cores, model);
    cfg.lookahead = lookahead;
    let mut machine = Machine::new(cfg);
    if let Some(tier) = remote {
        machine.install_remote(tier);
    }
    if let Some(spec) = chaos {
        machine.install_chaos(*spec);
    }
    (built.setup)(&built.rt, machine.mem_mut());
    let result = machine.run(&ck.program);
    if let Err(e) = (built.validate)(&built.rt, machine.mem_mut()) {
        panic!(
            "{kernel} [{}] x{cores} {model}: validation failed: {e}",
            variant.label()
        );
    }
    RunOutcome {
        kernel,
        variant,
        model,
        cores,
        result,
        compile_stats: ck.stats,
    }
}

/// Compile a kernel only (for instruction-census reports).
pub fn compile_only(
    kernel: Kernel,
    threads: u32,
    variant: PaperVariant,
    scale: &Scale,
) -> (IrModule, CompileStats) {
    let built = build(kernel, threads, variant.source(), scale);
    let opts = CompileOpts {
        lowering: variant.lowering(),
        static_threads: false,
        numthreads: threads,
        volatile_stores: true,
    };
    let ck = compile(&built.module, &built.rt, &opts);
    (built.module, ck.stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_parse_and_limits() {
        assert_eq!(Kernel::parse("mg"), Some(Kernel::Mg));
        assert_eq!(Kernel::parse("xx"), None);
        assert_eq!(Kernel::Ft.max_cores(), 16);
        assert_eq!(Kernel::Ep.max_cores(), 64);
    }

    #[test]
    fn scale_dims() {
        let s = Scale { factor: 64 };
        assert_eq!(s.dim(1 << 20, 1 << 10), 1 << 14);
        assert_eq!(s.dim(64, 128), 128); // floor applies
    }

    #[test]
    fn paper_variant_mapping() {
        assert_eq!(PaperVariant::Manual.source(), SourceVariant::Privatized);
        assert_eq!(PaperVariant::Manual.lowering(), Lowering::Soft);
        assert_eq!(PaperVariant::Hw.lowering(), Lowering::Hw);
        assert_eq!(PaperVariant::Hw.source(), SourceVariant::Unoptimized);
    }
}
