//! The full-system simulator: an N-core SPMD machine (the paper's Gem5
//! BigTsunami analogue, up to 64 cores) running one SimAlpha program on
//! every core with UPC barrier semantics.
//!
//! Execution is quantum-based: each core runs up to `quantum` dynamic
//! instructions, then the machine applies shared-bus/L2 contention for
//! the quantum (timing models only) and handles barrier rendezvous.
//! Functional shared-memory visibility follows the UPC discipline the
//! NPB kernels obey: remote data read in a phase was written before the
//! preceding barrier.

use crate::cpu::{
    AtomicCpu, CoreStats, Cpu, CpuModel, DetailedCpu, EngineMix, HierLatency,
    SharedLevel, StopReason, TimingCpu,
};
use crate::isa::Program;
use crate::mem::{seg_base, MemSystem, PRIV_OFF};

/// Register conventions the compiler and the machine agree on.
pub mod abi {
    /// Private-space base pointer for this thread.
    pub const R_PRIV: u8 = 26;
    /// Scratch (assembler temporaries).
    pub const R_SCRATCH: u8 = 27;
    /// MYTHREAD.
    pub const R_MYTHREAD: u8 = 28;
    /// THREADS.
    pub const R_THREADS: u8 = 29;
    /// Secondary scratch.
    pub const R_SCRATCH2: u8 = 30;
}

/// Machine configuration.
#[derive(Clone, Copy, Debug)]
pub struct MachineCfg {
    pub cores: u32,
    pub model: CpuModel,
    /// Dynamic instructions per scheduling quantum.
    pub quantum: u64,
    pub lat: HierLatency,
    /// Core clock, for converting cycles to seconds (paper: 2 GHz).
    pub freq_ghz: f64,
    /// Lookahead batching of PGAS-increment runs in the CPU pipelines
    /// (on by default; cycle totals are identical either way — the
    /// differential suite and the fig11–14 benches run both legs).
    pub lookahead: bool,
}

impl MachineCfg {
    pub fn new(cores: u32, model: CpuModel) -> Self {
        assert!(cores.is_power_of_two() && cores <= 64, "1..=64 pow2 cores");
        Self {
            cores,
            model,
            quantum: 20_000,
            lat: HierLatency::default(),
            freq_ghz: 2.0,
            lookahead: true,
        }
    }
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct MachineResult {
    /// Wall-clock of the simulated program: max cycles over cores.
    pub cycles: u64,
    pub per_core: Vec<CoreStats>,
    pub total: CoreStats,
    pub l1d_misses: u64,
    pub l2_misses: u64,
    pub invalidations: u64,
    pub freq_ghz: f64,
    /// How the machine's dynamic PGAS increments were served (batched
    /// through which `AddressEngine` backend vs scalar), summed over
    /// cores — recorded per run by `npb::RunOutcome`.
    pub engine_mix: EngineMix,
    /// Client-side session/recovery counters of the installed remote
    /// tier (`None` when the run had no remote pool): installs,
    /// epoch hits, stale-epoch re-installs, per-connection reconnects
    /// and whole-pool restarts.
    pub remote_client: Option<crate::engine::RemoteClientStats>,
    /// Selector health/degradation telemetry summed over cores:
    /// dispatches, backend failures absorbed by the fallback ladder,
    /// deadline misses, injected faults, and per-tier breaker activity.
    pub health: crate::engine::HealthStats,
    /// Inspector/executor gather telemetry summed over cores: plans
    /// executed, pointers routed through per-owner buckets, and
    /// gather-eligible batches served direct.
    pub gather: crate::engine::GatherStats,
    /// Vectorized-tier telemetry summed over cores: batches served by
    /// the lane kernels, lane vs scalar-tail pointers.
    pub simd: crate::engine::SimdStats,
    /// Cache-blocked batch-planner telemetry summed over cores: plans
    /// built, tiles dispatched, planned pointers, single-tile
    /// fallbacks.
    pub plan: crate::engine::PlanStats,
}

impl MachineResult {
    /// Simulated seconds at the configured clock.
    pub fn runtime_secs(&self) -> f64 {
        self.cycles as f64 / (self.freq_ghz * 1e9)
    }

    /// Gem5-style `stats.txt` dump: one `key  value  # comment` line per
    /// statistic, global then per-core.
    pub fn stats_txt(&self) -> String {
        let mut s = String::new();
        let mut put = |k: &str, v: String, c: &str| {
            s.push_str(&format!("{k:<44} {v:>16}  # {c}\n"));
        };
        put("sim.cycles", self.cycles.to_string(), "max cycles over cores");
        put(
            "sim.seconds",
            format!("{:.9}", self.runtime_secs()),
            "simulated seconds",
        );
        put(
            "sim.insts",
            self.total.instructions.to_string(),
            "total dynamic instructions",
        );
        put(
            "sim.ipc",
            format!("{:.4}", self.total.instructions as f64 / self.cycles.max(1) as f64),
            "aggregate instructions per (max) cycle",
        );
        put("mem.reads", self.total.mem_reads.to_string(), "data reads");
        put("mem.writes", self.total.mem_writes.to_string(), "data writes");
        put(
            "pgas.incs",
            self.total.pgas_incs.to_string(),
            "hardware shared-address increments",
        );
        put(
            "pgas.mem_accesses",
            self.total.pgas_mems.to_string(),
            "hardware shared loads/stores",
        );
        put(
            "pgas.local_shared",
            self.total.local_shared_accesses.to_string(),
            "shared accesses with local affinity",
        );
        put(
            "pgas.remote_shared",
            self.total.remote_shared_accesses.to_string(),
            "shared accesses to other threads",
        );
        put(
            "pgas.batched_incs",
            self.engine_mix.batched_incs.to_string(),
            "increments served via batched AddressEngine calls",
        );
        put(
            "pgas.scalar_incs",
            self.engine_mix.scalar_incs.to_string(),
            "increments stepped scalar",
        );
        put(
            "pgas.batched_runs",
            self.engine_mix.total_runs().to_string(),
            "lookahead windows served batched",
        );
        // one line per backend that actually served windows (the
        // remote tier shows up here when a pool was installed)
        for (choice, runs) in self.engine_mix.by_choice() {
            if runs > 0 {
                put(
                    &format!("pgas.runs.{}", choice.name()),
                    runs.to_string(),
                    "windows served by this backend",
                );
            }
        }
        // client-side service counters, present only when a remote
        // tier (worker pool or daemon) was installed for the run
        if let Some(rc) = &self.remote_client {
            put(
                "remote.ctx_installs",
                rc.installs.to_string(),
                "InstallCtx messages sent (ctx changes)",
            );
            put(
                "remote.epoch_hits",
                rc.epoch_hits.to_string(),
                "requests served against an installed epoch",
            );
            put(
                "remote.epoch_reinstalls",
                rc.reinstalls.to_string(),
                "stale-epoch replies answered by re-install",
            );
            put(
                "remote.reconnects",
                rc.reconnects.to_string(),
                "individual worker connections healed",
            );
            put(
                "remote.restarts",
                rc.restarts.to_string(),
                "whole-pool rebuilds after failed heals",
            );
            put(
                "remote.stale_failures",
                rc.stale_failures.to_string(),
                "requests failed after the re-install budget",
            );
        }
        // health/degradation telemetry: always present, so fault-free
        // runs prove their zeros and chaos runs show the ladder at work
        put(
            "health.dispatches",
            self.health.dispatches.to_string(),
            "batched windows routed by the selectors",
        );
        put(
            "health.failures",
            self.health.failures().to_string(),
            "backend failures absorbed across tiers",
        );
        put(
            "health.trips",
            self.health.trips().to_string(),
            "circuit-breaker trips (tier quarantined)",
        );
        put(
            "health.probes",
            self.health.probes().to_string(),
            "half-open probes sent to tripped tiers",
        );
        put(
            "degrade.fallback_runs",
            self.health.fallback_runs.to_string(),
            "windows re-served by a lower tier",
        );
        put(
            "degrade.deadline_misses",
            self.health.deadline_misses.to_string(),
            "dispatches over the cost-model deadline",
        );
        put(
            "degrade.injected_faults",
            self.health.injected_faults.to_string(),
            "chaos-injected engine faults absorbed",
        );
        // inspector/executor gather telemetry: always present, so
        // affine-only runs prove their zeros and irregular runs show
        // the per-owner bucketing at work
        put(
            "gather.plans",
            self.gather.plans.to_string(),
            "inspector/executor plans executed",
        );
        put(
            "gather.bucketed_ptrs",
            self.gather.bucketed_ptrs.to_string(),
            "pointers routed through per-owner buckets",
        );
        put(
            "gather.fallback",
            self.gather.fallback.to_string(),
            "gather-eligible batches served direct",
        );
        // vectorized-tier telemetry: always present, so scalar-only
        // runs prove their zeros and batched runs show the lane mix
        put(
            "simd.batches",
            self.simd.batches.to_string(),
            "batches served by the vectorized tier",
        );
        put(
            "simd.lane_ptrs",
            self.simd.lane_ptrs.to_string(),
            "pointers processed in full SIMD lanes",
        );
        put(
            "simd.tail_ptrs",
            self.simd.tail_ptrs.to_string(),
            "pointers processed by the scalar tail",
        );
        // cache-blocked batch-planner telemetry
        put(
            "plan.plans",
            self.plan.plans.to_string(),
            "cache-blocked tile plans executed",
        );
        put(
            "plan.tiles",
            self.plan.tiles.to_string(),
            "tiles dispatched across all plans",
        );
        put(
            "plan.planned_ptrs",
            self.plan.planned_ptrs.to_string(),
            "pointers routed through planned tiles",
        );
        put(
            "plan.fallback",
            self.plan.fallback.to_string(),
            "plan-eligible batches served unplanned",
        );
        put("cache.l1d_misses", self.l1d_misses.to_string(), "sum over cores");
        put("cache.l2_misses", self.l2_misses.to_string(), "shared L2");
        put(
            "coherence.invalidations",
            self.invalidations.to_string(),
            "directory-initiated L1 invalidations",
        );
        put("barriers", self.total.barriers.to_string(), "barrier arrivals");
        for (i, c) in self.per_core.iter().enumerate() {
            put(
                &format!("core{i}.cycles"),
                c.cycles.to_string(),
                "including barrier + bus stalls",
            );
            put(&format!("core{i}.insts"), c.instructions.to_string(), "");
            put(
                &format!("core{i}.ipc"),
                format!("{:.4}", c.ipc()),
                "",
            );
        }
        s
    }
}

enum CoreStateTag {
    Running,
    AtBarrier,
    Halted,
}

/// The machine: cores + memory + shared hierarchy.
pub struct Machine {
    pub cfg: MachineCfg,
    cpus: Vec<Box<dyn Cpu>>,
    pub mem: MemSystem,
    shared: SharedLevel,
    /// The installed remote tier, kept so `run` can snapshot its
    /// client-side counters into `MachineResult::remote_client`.
    remote: Option<crate::engine::RemoteTier>,
}

impl Machine {
    pub fn new(cfg: MachineCfg) -> Self {
        let cpus: Vec<Box<dyn Cpu>> = (0..cfg.cores)
            .map(|t| -> Box<dyn Cpu> {
                match cfg.model {
                    CpuModel::Atomic => Box::new(AtomicCpu::new(t, cfg.cores)),
                    CpuModel::Timing => Box::new(TimingCpu::new(t, cfg.cores)),
                    CpuModel::Detailed => Box::new(DetailedCpu::new(t, cfg.cores)),
                }
            })
            .collect();
        let mut m = Self {
            cfg,
            cpus,
            mem: MemSystem::new(cfg.cores),
            shared: SharedLevel::new(cfg.cores as usize, cfg.lat),
            remote: None,
        };
        for cpu in &mut m.cpus {
            cpu.lookahead_mut().set_enabled(cfg.lookahead);
        }
        m.install_abi();
        m
    }

    fn install_abi(&mut self) {
        for t in 0..self.cfg.cores {
            let st = self.cpus[t as usize].state_mut();
            st.set_r(abi::R_MYTHREAD, t as u64);
            st.set_r(abi::R_THREADS, self.cfg.cores as u64);
            st.set_r(abi::R_PRIV, seg_base(t) + PRIV_OFF);
        }
    }

    /// Access the memory for pre-run initialization / post-run checks.
    pub fn mem_mut(&mut self) -> &mut MemSystem {
        &mut self.mem
    }

    /// Install the remote address-mapping tier into every core's
    /// lookahead selector.  The pool itself is shared (`Arc`) — one set
    /// of worker processes serves all cores — and each selector prices
    /// it with the tier's calibrated (or forced) legs, so whether any
    /// simulated window actually takes the socket hop stays a
    /// cost-model decision.  Call before [`run`](Self::run).
    pub fn install_remote(&mut self, tier: &crate::engine::RemoteTier) {
        for cpu in &mut self.cpus {
            cpu.lookahead_mut().install_remote(tier);
        }
        self.remote = Some(tier.clone());
    }

    /// Arm every core's selector with a seeded fault plan.  Cores get
    /// decorrelated streams (the seed is offset per core by a large odd
    /// constant) so a machine-wide chaos run does not fault all cores
    /// in lockstep, yet the whole schedule replays from one seed.
    /// Call before [`run`](Self::run).
    pub fn install_chaos(&mut self, spec: crate::engine::FaultSpec) {
        for (core, cpu) in self.cpus.iter_mut().enumerate() {
            let stream =
                (core as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            cpu.lookahead_mut()
                .install_chaos(spec.with_seed(spec.seed ^ stream));
        }
    }

    /// Run `prog` SPMD on all cores to completion.
    pub fn run(&mut self, prog: &Program) -> MachineResult {
        let n = self.cfg.cores as usize;
        let mut tags: Vec<CoreStateTag> =
            (0..n).map(|_| CoreStateTag::Running).collect();
        let quantum = self.cfg.quantum;
        let is_timing = !matches!(self.cfg.model, CpuModel::Atomic);

        loop {
            let mut all_halted = true;
            let mut progressed = false;
            for c in 0..n {
                if let CoreStateTag::Running = tags[c] {
                    let before = self.cpus[c].stats().instructions;
                    let reason = self.cpus[c].run(
                        prog,
                        &mut self.mem,
                        &mut self.shared,
                        quantum,
                    );
                    progressed |= self.cpus[c].stats().instructions > before;
                    tags[c] = match reason {
                        StopReason::Barrier => CoreStateTag::AtBarrier,
                        StopReason::Halted => CoreStateTag::Halted,
                        StopReason::QuantumExpired => CoreStateTag::Running,
                    };
                }
                if !matches!(tags[c], CoreStateTag::Halted) {
                    all_halted = false;
                }
            }

            // --- shared bus / L2 contention for this quantum ---
            if is_timing {
                let counts = self.shared.drain_quantum();
                let total: u64 = counts.iter().sum();
                if total > 0 {
                    let bus_time = total * self.cfg.lat.bus_per_txn;
                    // utilization of the shared bus in this quantum
                    let rho = (bus_time as f64 / quantum as f64).min(1.0);
                    for (c, &txns) in counts.iter().enumerate() {
                        // queueing delay ~ own transactions * occupancy
                        // of everyone else's traffic
                        let others = total - txns;
                        let stall = (others as f64
                            * self.cfg.lat.bus_per_txn as f64
                            * rho
                            * (txns as f64 / total.max(1) as f64))
                            as u64;
                        self.cpus[c].add_stall_cycles(stall);
                    }
                }
            }

            if all_halted {
                break;
            }

            // --- barrier rendezvous ---
            let any_running = tags.iter().any(|t| matches!(t, CoreStateTag::Running));
            if !any_running {
                let at_barrier: Vec<usize> = (0..n)
                    .filter(|&c| matches!(tags[c], CoreStateTag::AtBarrier))
                    .collect();
                if at_barrier.is_empty() {
                    break; // everyone halted
                }
                // release: all waiters advance to the max arrival cycle
                let max_cycles = at_barrier
                    .iter()
                    .map(|&c| self.cpus[c].stats().cycles)
                    .max()
                    .unwrap();
                for &c in &at_barrier {
                    let own = self.cpus[c].stats().cycles;
                    self.cpus[c].add_stall_cycles(max_cycles - own);
                    tags[c] = CoreStateTag::Running;
                }
            } else if !progressed {
                panic!("machine deadlock: no core made progress");
            }
        }

        let per_core: Vec<CoreStats> =
            self.cpus.iter().map(|c| *c.stats()).collect();
        let mut total = CoreStats::default();
        for s in &per_core {
            total.merge(s);
        }
        let cycles = per_core.iter().map(|s| s.cycles).max().unwrap_or(0);
        let mut engine_mix = EngineMix::default();
        let mut health = crate::engine::HealthStats::default();
        let mut gather = crate::engine::GatherStats::default();
        let mut simd = crate::engine::SimdStats::default();
        let mut plan = crate::engine::PlanStats::default();
        for c in &self.cpus {
            engine_mix.merge(&c.engine_mix());
            health.merge(&c.health());
            gather.merge(&c.gather());
            simd.merge(&c.simd());
            plan.merge(&c.plan());
        }
        MachineResult {
            cycles,
            total,
            l1d_misses: self.shared.l1d.iter().map(|c| c.stats.misses).sum(),
            l2_misses: self.shared.l2.stats.misses,
            invalidations: self.shared.dir.invalidations_sent,
            per_core,
            freq_ghz: self.cfg.freq_ghz,
            engine_mix,
            remote_client: self
                .remote
                .as_ref()
                .map(|tier| tier.engine.client_stats()),
            health,
            gather,
            simd,
            plan,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Cond, Inst, IntOp, MemWidth};
    use crate::sptr::{pack, ArrayLayout, SharedPtr};

    /// Each thread writes MYTHREAD into its own slot of a cyclic shared
    /// array, barriers, then thread 0 checks by reading all slots.
    fn spmd_exchange_prog(threads: u32) -> Program {
        let layout = ArrayLayout::new(1, 8, threads);
        let _l2nt = threads.trailing_zeros() as u8;
        // ptr to A[MYTHREAD]: start at A[0], increment by MYTHREAD (reg)
        Program::new(
            "exchange",
            vec![
                // r1 = packed &A[0]; r2 = ptr to own slot
                Inst::Ldi { rd: 1, imm: pack(&SharedPtr::for_index(&layout, 0, 0)) as i64 },
                Inst::PgasIncR { rd: 2, ra: 1, rb: super::abi::R_MYTHREAD, l2es: 3, l2bs: 0 },
                Inst::PgasSt { w: MemWidth::U64, rs: super::abi::R_MYTHREAD, rptr: 2, disp: 0 },
                Inst::Barrier, // 3
                // only thread 0 sums: others jump to halt
                Inst::Br { cond: Cond::Ne, ra: super::abi::R_MYTHREAD, target: 12 },
                // r3 = acc, r4 = ptr, r5 = counter
                Inst::Ldi { rd: 3, imm: 0 },
                Inst::Opr { op: IntOp::Add, rd: 4, ra: 1, rb: 31 },
                Inst::Opr { op: IntOp::Add, rd: 5, ra: super::abi::R_THREADS, rb: 31 },
                // loop: 8
                Inst::PgasLd { w: MemWidth::U64, rd: 6, rptr: 4, disp: 0 },
                Inst::Opr { op: IntOp::Add, rd: 3, ra: 3, rb: 6 },
                Inst::PgasIncI { rd: 4, ra: 4, l2es: 3, l2bs: 0, l2inc: 0 },
                Inst::Opi { op: IntOp::Add, rd: 5, ra: 5, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 5, target: 8 },
                // 13: store result at private base
                Inst::St { w: MemWidth::U64, rs: 3, base: super::abi::R_PRIV, disp: 0 },
                Inst::Halt,
            ]
            .into_iter()
            .map(|i| i)
            .collect::<Vec<_>>(),
        )
    }

    // NB: target indices in the program above are brittle by design —
    // the real kernels use the assembler with labels; this test keeps
    // the machine test free of compiler dependencies.
    fn fixed_exchange_prog(threads: u32) -> Program {
        let mut p = spmd_exchange_prog(threads);
        // fix up: Br Ne target -> index of St (14-1=13? compute):
        // layout: 0..=2 store, 3 barrier, 4 br, 5..7 init, 8..12 loop,
        // 13 st, 14 halt. The `Br Ne` should target 14 (halt) for
        // non-zero threads; loop-exit falls through to 13.
        if let Inst::Br { target, .. } = &mut p.insts[4] {
            *target = 14;
        }
        if let Inst::Br { target, .. } = &mut p.insts[12] {
            *target = 8;
        }
        p.validate().unwrap();
        p
    }

    #[test]
    fn spmd_exchange_all_models() {
        for model in CpuModel::ALL {
            for threads in [1u32, 4, 8] {
                let prog = fixed_exchange_prog(threads);
                let mut m = Machine::new(MachineCfg::new(threads, model));
                let res = m.run(&prog);
                let want: u64 = (0..threads as u64).sum();
                let got = m
                    .mem
                    .read(MemWidth::U64, seg_base(0) + PRIV_OFF);
                assert_eq!(got, want, "{model} x{threads}");
                assert!(res.cycles > 0);
                assert_eq!(res.total.barriers as u32, threads);
            }
        }
    }

    #[test]
    fn barrier_synchronizes_cycles() {
        // thread 0 does extra work before the barrier; after the barrier
        // all cores' cycle counts must be >= the max arrival.
        let prog = Program::new(
            "skew",
            vec![
                // r1 = MYTHREAD == 0 ? 1000 : 10 iterations
                Inst::Ldi { rd: 1, imm: 10 },
                Inst::Br { cond: Cond::Ne, ra: abi::R_MYTHREAD, target: 3 },
                Inst::Ldi { rd: 1, imm: 1000 },
                // loop: 3
                Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 1, target: 3 },
                Inst::Barrier,
                Inst::Halt,
            ],
        );
        let mut m = Machine::new(MachineCfg::new(4, CpuModel::Atomic));
        let res = m.run(&prog);
        let c0 = res.per_core[0].cycles;
        for (i, s) in res.per_core.iter().enumerate() {
            assert!(
                s.cycles >= c0 - 2,
                "core {i} cycles {} << core0 {}",
                s.cycles,
                c0
            );
        }
    }

    #[test]
    fn stats_txt_is_complete_and_parsable() {
        let prog = fixed_exchange_prog(4);
        let mut m = Machine::new(MachineCfg::new(4, CpuModel::Timing));
        let res = m.run(&prog);
        let txt = res.stats_txt();
        for key in [
            "sim.cycles",
            "sim.insts",
            "pgas.incs",
            "simd.batches",
            "simd.lane_ptrs",
            "simd.tail_ptrs",
            "plan.plans",
            "plan.tiles",
            "plan.planned_ptrs",
            "plan.fallback",
            "cache.l1d_misses",
            "core0.ipc",
            "core3.cycles",
        ] {
            assert!(txt.contains(key), "missing {key}");
        }
        // every line is `key value # comment`-shaped
        for line in txt.lines() {
            let mut parts = line.split_whitespace();
            assert!(parts.next().is_some(), "empty key: {line}");
            assert!(parts.next().is_some(), "missing value: {line}");
        }
    }

    #[test]
    fn lookahead_batching_is_cycle_exact_in_every_model() {
        let prog = fixed_exchange_prog(4);
        for model in CpuModel::ALL {
            let run = |lookahead: bool| {
                let mut cfg = MachineCfg::new(4, model);
                cfg.lookahead = lookahead;
                let mut m = Machine::new(cfg);
                let r = m.run(&prog);
                (r.cycles, r.total.instructions, r.engine_mix)
            };
            let (bc, bi, bmix) = run(true);
            let (sc, si, smix) = run(false);
            assert_eq!(bc, sc, "{model}: batched vs scalar cycles");
            assert_eq!(bi, si, "{model}: instruction counts");
            assert_eq!(smix.batched_incs, 0, "{model}: scalar leg batched");
            assert_eq!(
                bmix.batched_incs + bmix.scalar_incs,
                smix.scalar_incs,
                "{model}: every increment accounted"
            );
        }
    }

    #[test]
    fn timing_model_costs_more_cycles_than_atomic() {
        let prog = fixed_exchange_prog(4);
        let run = |model| {
            let mut m = Machine::new(MachineCfg::new(4, model));
            m.run(&prog).cycles
        };
        assert!(run(CpuModel::Timing) > run(CpuModel::Atomic));
    }
}
