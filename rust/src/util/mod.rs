//! Small shared utilities: deterministic RNG, stats helpers, table
//! rendering, and a tiny randomized-property-test kit (`testkit`).
//!
//! The offline build environment vendors only the `xla` closure, so the
//! usual suspects (rand, proptest, criterion, prettytable) are hand-rolled
//! here at the size this crate actually needs.

pub mod bench;
pub mod rng;
pub mod table;
pub mod testkit;

/// `true` iff `x` is a power of two (0 is not).
#[inline]
pub fn is_pow2(x: u64) -> bool {
    x != 0 && (x & (x - 1)) == 0
}

/// floor(log2(x)) for x > 0.
#[inline]
pub fn log2_floor(x: u64) -> u32 {
    63 - x.leading_zeros()
}

/// Exact log2 for powers of two.
#[inline]
pub fn log2_exact(x: u64) -> Option<u32> {
    if is_pow2(x) {
        Some(log2_floor(x))
    } else {
        None
    }
}

/// Round `n` up to a multiple of `m`.
#[inline]
pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// Geometric mean of a slice (used for speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_predicates() {
        assert!(is_pow2(1));
        assert!(is_pow2(64));
        assert!(!is_pow2(0));
        assert!(!is_pow2(56016)); // CG's w/w_tmp element size
        assert_eq!(log2_exact(1024), Some(10));
        assert_eq!(log2_exact(56016), None);
        assert_eq!(log2_floor(7), 2);
    }

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8192), 0);
        assert_eq!(round_up(1, 8192), 8192);
        assert_eq!(round_up(8192, 8192), 8192);
        assert_eq!(round_up(8193, 8192), 16384);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }
}
