//! Minimal fixed-width ASCII table renderer for figure/table reporters.

/// A simple left-aligned-text / right-aligned-number table.
#[derive(Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_of(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let sep: String = width
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    // numbers right-aligned, text left-aligned
                    let numeric = c
                        .chars()
                        .all(|ch| ch.is_ascii_digit() || ".%x+-eE".contains(ch))
                        && !c.is_empty();
                    if numeric {
                        format!("| {:>w$} ", c, w = width[i])
                    } else {
                        format!("| {:<w$} ", c, w = width[i])
                    }
                })
                .collect::<String>()
                + "|"
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// CSV serialization for results archival.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// Format a f64 with `digits` decimals, trimming to a compact string.
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_escapes() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["alpha, beta".into(), "1.5".into()]);
        t.row(&["gamma".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("alpha, beta"));
        let csv = t.to_csv();
        assert!(csv.starts_with("name,value"));
        assert!(csv.contains("\"alpha, beta\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
