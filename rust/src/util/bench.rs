//! Tiny benchmark harness used by `cargo bench` targets (criterion is not
//! vendored offline).  Measures wall time over warmup + measured
//! iterations and prints mean / p50 / p95 plus derived throughput.

use std::time::Instant;

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p(0.5),
        p95_ns: p(0.95),
    };
    println!(
        "bench {:<44} mean {:>12} p50 {:>12} p95 {:>12}  ({} iters)",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        r.iters
    );
    r
}

/// Human duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept behind one name so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns >= 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains('s'));
    }
}
