//! Tiny benchmark harness used by `cargo bench` targets (criterion is not
//! vendored offline).  Measures wall time over warmup + measured
//! iterations and prints mean / p50 / p95 plus derived throughput.

use std::time::Instant;

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p(0.5),
        p95_ns: p(0.95),
    };
    println!(
        "bench {:<44} mean {:>12} p50 {:>12} p95 {:>12}  ({} iters)",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        r.iters
    );
    r
}

/// Human duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept behind one name so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Byte range of the JSON value owned by the **top-level** key `key`
/// in the object `text` — a single balanced scan that respects nested
/// objects/arrays and quoted strings, so keys of the same name inside
/// nested sections (or inside string values) are never matched, and
/// replacing the returned range swaps the whole value.  `None` when
/// the top level has no such key (or `text` is not an object).
fn json_value_range(text: &str, key: &str) -> Option<(usize, usize)> {
    let bytes = text.as_bytes();
    let obj_open = text.find('{')?;
    // depth relative to the top-level object's braces: 1 = top level
    let (mut depth, mut in_str, mut esc) = (1usize, false, false);
    // byte range of the most recent depth-1 string (a candidate key)
    let mut str_start = 0usize;
    let mut pending_key: Option<(usize, usize)> = None;
    let mut i = obj_open + 1;
    while i < bytes.len() {
        let b = bytes[i];
        if in_str {
            if esc {
                esc = false;
            } else if b == b'\\' {
                esc = true;
            } else if b == b'"' {
                in_str = false;
                if depth == 1 {
                    pending_key = Some((str_start, i));
                }
            }
            i += 1;
            continue;
        }
        match b {
            b'"' => {
                in_str = true;
                str_start = i + 1;
            }
            b':' if depth == 1 => {
                if let Some((ks, ke)) = pending_key.take() {
                    if &text[ks..ke] == key {
                        // value starts after the colon + whitespace
                        let mut start = i + 1;
                        while start < bytes.len()
                            && bytes[start].is_ascii_whitespace()
                        {
                            start += 1;
                        }
                        return json_scan_value(text, start);
                    }
                }
            }
            b'{' | b'[' => depth += 1,
            b'}' | b']' => {
                if depth == 1 {
                    return None; // top-level object closed: key absent
                }
                depth -= 1;
            }
            _ => {}
        }
        i += 1;
    }
    None
}

/// End of the balanced JSON value starting at `start` (string, number,
/// object or array); returns the `(start, end)` byte range.
fn json_scan_value(text: &str, start: usize) -> Option<(usize, usize)> {
    let bytes = text.as_bytes();
    let (mut depth, mut in_str, mut esc) = (0usize, false, false);
    for (i, &b) in bytes[start..].iter().enumerate() {
        let pos = start + i;
        if in_str {
            if esc {
                esc = false;
            } else if b == b'\\' {
                esc = true;
            } else if b == b'"' {
                in_str = false;
                if depth == 0 {
                    return Some((start, pos + 1)); // bare string value
                }
            }
            continue;
        }
        match b {
            b'"' => in_str = true,
            b'{' | b'[' => depth += 1,
            b'}' | b']' => {
                if depth == 0 {
                    return Some((start, pos)); // enclosing close: bare scalar
                }
                depth -= 1;
                if depth == 0 {
                    return Some((start, pos + 1)); // container value closed
                }
            }
            b',' if depth == 0 => return Some((start, pos)), // bare scalar
            _ => {}
        }
    }
    None
}

/// Merge `"key": value` into the JSON-object trajectory file the bench
/// targets share (`BENCH_engine.json`): replace the key's value in
/// place when it is already present, insert before the final `}`
/// otherwise, or create `{ "key": value }` from scratch.  Hand-rolled
/// (no serde in the offline build) so any bench target can run in any
/// order — `hotpath_engine` and the fig11–14 model benches all merge
/// their sections instead of clobbering each other's.
pub fn merge_bench_json(path: &str, key: &str, value: &str) {
    let fresh = || format!("{{\n  \"{key}\": {value}\n}}\n");
    let merged = match std::fs::read_to_string(path) {
        Ok(text) => {
            if let Some((start, end)) = json_value_range(&text, key) {
                format!("{}{}{}", &text[..start], value, &text[end..])
            } else {
                let head = text.trim_end();
                match head.strip_suffix('}') {
                    Some(body) => {
                        let body = body.trim_end();
                        let sep = if body.ends_with('{') { "" } else { "," };
                        format!("{body}{sep}\n  \"{key}\": {value}\n}}\n")
                    }
                    // not an object: start over rather than corrupt it
                    None => fresh(),
                }
            }
        }
        Err(_) => fresh(),
    };
    std::fs::write(path, merged)
        .unwrap_or_else(|e| panic!("write {path}: {e}"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns >= 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains('s'));
    }

    #[test]
    fn merge_bench_json_creates_extends_and_replaces() {
        let path = std::env::temp_dir().join(format!(
            "merge_bench_json_test_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        merge_bench_json(&path, "first", "{\"a\": 1}");
        let one = std::fs::read_to_string(&path).unwrap();
        assert!(one.contains("\"first\""), "{one}");
        merge_bench_json(&path, "second", "{\"b\": [1, 2], \"s\": \"x}y\"}");
        let two = std::fs::read_to_string(&path).unwrap();
        assert!(two.contains("\"first\"") && two.contains("\"second\""), "{two}");
        // still one object: balanced braces (the brace inside the
        // string literal is the deliberate odd one out), comma inserted
        assert!(two.contains("},\n  \"second\""), "{two}");
        // re-merging an existing key replaces its value in place —
        // no duplicate keys, nested containers and strings skipped
        merge_bench_json(&path, "second", "{\"b\": 9}");
        merge_bench_json(&path, "first", "{\"a\": 7}");
        let three = std::fs::read_to_string(&path).unwrap();
        assert_eq!(three.matches("\"first\"").count(), 1, "{three}");
        assert_eq!(three.matches("\"second\"").count(), 1, "{three}");
        assert!(three.contains("{\"a\": 7}"), "{three}");
        assert!(three.contains("{\"b\": 9}"), "{three}");
        assert!(!three.contains("x}y"), "old value fully replaced: {three}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn json_value_range_handles_scalars_and_containers() {
        let text = r#"{"a": 1, "b": "str,}", "c": {"d": [1, 2]}, "e": 5}"#;
        let slice = |k| {
            let (s, e) = json_value_range(text, k).unwrap();
            &text[s..e]
        };
        assert_eq!(slice("a"), "1");
        assert_eq!(slice("b"), "\"str,}\"");
        assert_eq!(slice("c"), "{\"d\": [1, 2]}");
        assert_eq!(slice("e"), "5");
        assert!(json_value_range(text, "zz").is_none());
        // nested keys and string contents are NOT top-level matches
        assert!(json_value_range(text, "d").is_none());
        assert!(json_value_range(text, "str,}").is_none());
    }
}
