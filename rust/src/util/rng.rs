//! Deterministic xoshiro256** RNG — reproducible workloads without the
//! `rand` crate. Also carries the NPB-style linear congruential generator
//! used by the EP kernel (the NAS `randlc` generator, a=5^13, 2^46 mod).

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 so any u64 gives a full-entropy state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift range reduction (Lemire), bias negligible here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random bool with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// NAS `randlc`: x_{k+1} = a * x_k mod 2^46, returning x/2^46 in [0,1).
/// This is the exact generator the EP kernel validates against.
#[derive(Clone, Debug)]
pub struct NasRandlc {
    x: u64,
    a: u64,
}

const M46: u64 = (1 << 46) - 1;

impl NasRandlc {
    pub const A: u64 = 1220703125; // 5^13
    pub const SEED: u64 = 271828183;

    pub fn new(seed: u64) -> Self {
        Self {
            x: seed & M46,
            a: Self::A,
        }
    }

    #[inline]
    pub fn next(&mut self) -> f64 {
        // 46-bit modular product fits in u128.
        self.x = ((self.x as u128 * self.a as u128) & M46 as u128) as u64;
        self.x as f64 / (1u64 << 46) as f64
    }

    /// Raw 46-bit state (used by the SimAlpha EP kernel for int math).
    #[inline]
    pub fn next_raw(&mut self) -> u64 {
        self.x = ((self.x as u128 * self.a as u128) & M46 as u128) as u64;
        self.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        let mut c = Xoshiro256::new(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn below_in_range() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
            let v = r.range(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::new(11);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn randlc_matches_known_series_properties() {
        // First values of the NAS generator from seed 271828183 stay in
        // (0,1) and the generator is 46-bit periodic-free for our lengths.
        let mut g = NasRandlc::new(NasRandlc::SEED);
        let mut prev = -1.0;
        for _ in 0..1000 {
            let v = g.next();
            assert!(v > 0.0 && v < 1.0);
            assert_ne!(v, prev);
            prev = v;
        }
    }
}
