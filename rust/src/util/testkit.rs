//! A small randomized property-test kit (proptest is not vendored in this
//! offline environment).  Properties run over many seeded random cases;
//! on failure the failing seed is printed so the case can be replayed.

use super::rng::Xoshiro256;

/// Number of cases per property (override with `PGAS_PROPTEST_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("PGAS_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(96)
}

/// Run `prop` over `cases` seeded RNGs; panic with the seed on failure.
///
/// ```
/// use pgas_hw::util::testkit::check;
/// check("addition commutes", 64, |rng| {
///     let (a, b) = (rng.below(1000) as u64, rng.below(1000) as u64);
///     assert_eq!(a + b, b + a);
/// });
/// ```
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Xoshiro256),
{
    let base = std::env::var("PGAS_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Xoshiro256::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut rng),
        ));
        if let Err(err) = result {
            eprintln!(
                "property `{name}` failed at case {case} \
                 (replay with PGAS_PROPTEST_SEED={seed} and cases=1)"
            );
            std::panic::resume_unwind(err);
        }
    }
}

/// Convenience: run with the default number of cases.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Xoshiro256),
{
    check(name, default_cases(), prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 10, |_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check("always-fails", 3, |_| panic!("boom"));
    }
}
