//! A small direct-mapped TLB model.
//!
//! The paper's translation flow ends with "the conventional translation
//! lookaside buffer (TLB) hardware"; the timing models charge a refill
//! penalty on misses.  The functional path never depends on it.

/// TLB hit/miss statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TlbStats {
    pub hits: u64,
    pub misses: u64,
}

impl TlbStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses() as f64
        }
    }
}

/// Direct-mapped TLB with `entries` slots over `1 << page_shift`-byte
/// pages (Alpha's 8 KiB by default).
#[derive(Clone, Debug)]
pub struct Tlb {
    tags: Vec<u64>,
    page_shift: u32,
    index_mask: u64,
    pub stats: TlbStats,
}

impl Tlb {
    pub fn new(entries: usize, page_shift: u32) -> Self {
        assert!(entries.is_power_of_two());
        Self {
            tags: vec![u64::MAX; entries],
            page_shift,
            index_mask: entries as u64 - 1,
            stats: TlbStats::default(),
        }
    }

    /// Alpha-21264-like data TLB: 128 entries, 8 KiB pages.
    pub fn alpha_dtb() -> Self {
        Self::new(128, 13)
    }

    /// Look up `sysva`; returns `true` on hit and refills on miss.
    #[inline]
    pub fn access(&mut self, sysva: u64) -> bool {
        let vpn = sysva >> self.page_shift;
        let idx = (vpn & self.index_mask) as usize;
        if self.tags[idx] == vpn {
            self.stats.hits += 1;
            true
        } else {
            self.tags[idx] = vpn;
            self.stats.misses += 1;
            false
        }
    }

    pub fn flush(&mut self) {
        self.tags.fill(u64::MAX);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeat_access_hits() {
        let mut t = Tlb::new(16, 13);
        assert!(!t.access(0x4000));
        assert!(t.access(0x4000));
        assert!(t.access(0x4008)); // same page
        assert_eq!(t.stats.misses, 1);
        assert_eq!(t.stats.hits, 2);
    }

    #[test]
    fn conflicting_pages_evict() {
        let mut t = Tlb::new(2, 13);
        let a = 0u64;
        let b = 2 << 13; // same index as a (stride = entries * page)
        assert!(!t.access(a));
        assert!(!t.access(b));
        assert!(!t.access(a)); // evicted by b
        assert_eq!(t.stats.misses, 3);
    }

    #[test]
    fn flush_resets() {
        let mut t = Tlb::new(4, 13);
        t.access(0x2000);
        t.flush();
        assert!(!t.access(0x2000));
        assert!((t.stats.miss_rate() - 1.0).abs() < 1e-9);
    }
}
