//! Simulated physical memory and address-space layout.
//!
//! The machine gives every UPC thread a segment at a regular interval:
//! thread `t`'s shared segment starts at sysva `(t+1) << SEG_SHIFT`.
//! This realizes the paper's first translation option (base computable
//! from the thread number) while the executing programs still go through
//! the base-address LUT — the option both prototypes implement — so the
//! two schemes can be cross-checked against each other.
//!
//! Layout of one 4 GiB segment:
//! ```text
//!   +0x0000_0000  shared heap of thread t (UPC shared space, affinity t)
//!   +0xC000_0000  private space of thread t (stack, temporaries, tables)
//! ```

mod tlb;

pub use tlb::{Tlb, TlbStats};

use crate::isa::MemWidth;
use crate::sptr::BaseTable;

/// log2 of the per-thread segment stride.
pub const SEG_SHIFT: u32 = 32;
/// Offset of the private space inside a segment.
pub const PRIV_OFF: u64 = 0xC000_0000;
/// Maximum bytes backed per segment (shared + private)
pub const SEG_CAP: u64 = 1 << SEG_SHIFT;

/// sysva of the start of thread `t`'s segment.
#[inline]
pub fn seg_base(t: u32) -> u64 {
    ((t as u64) + 1) << SEG_SHIFT
}

/// One thread segment, stored sparsely as two lazily-grown regions:
/// the shared heap (offset 0..) and the private space (PRIV_OFF..).
/// Sparseness matters: a dense 4 GiB vector per thread would zero-fill
/// gigabytes on the first private-space access (measured at ~7 s per
/// simulation before this split — see EXPERIMENTS.md §Perf).
#[derive(Default)]
struct Segment {
    shared: Vec<u8>,
    private: Vec<u8>,
}

/// The simulated memory. All values little-endian; floats as IEEE bits.
pub struct MemSystem {
    segs: Vec<Segment>,
    /// The PGAS base-address LUT (installed by `pgas_setbase`).
    pub base_table: BaseTable,
    numthreads: u32,
}

impl MemSystem {
    pub fn new(numthreads: u32) -> Self {
        Self {
            segs: (0..numthreads).map(|_| Segment::default()).collect(),
            base_table: BaseTable::regular(numthreads, seg_base(0), 1 << SEG_SHIFT),
            numthreads,
        }
    }

    pub fn numthreads(&self) -> u32 {
        self.numthreads
    }

    /// Mutable window of `n` bytes at `sysva`; grows the containing
    /// region. Panics on unmapped addresses — an unmapped access is a
    /// simulator bug, not a workload condition.
    #[inline]
    fn window(&mut self, sysva: u64, n: usize) -> &mut [u8] {
        let seg = (sysva >> SEG_SHIFT) as usize;
        assert!(
            seg >= 1 && seg <= self.numthreads as usize,
            "sysva {sysva:#x} outside all thread segments"
        );
        let off = (sysva & (SEG_CAP - 1)) as usize;
        let s = &mut self.segs[seg - 1];
        let (region, roff) = if off as u64 >= PRIV_OFF {
            (&mut s.private, off - PRIV_OFF as usize)
        } else {
            assert!(
                (off + n) as u64 <= PRIV_OFF,
                "shared-heap access {off:#x} crosses into private space"
            );
            (&mut s.shared, off)
        };
        if region.len() < roff + n {
            // grow geometrically to amortize
            let want = (roff + n).next_power_of_two().max(4096);
            region.resize(want, 0);
        }
        &mut region[roff..roff + n]
    }

    /// Raw read of `w.bytes()` little-endian bytes, zero-extended.
    /// Float widths return the raw bit pattern.
    pub fn read(&mut self, w: MemWidth, sysva: u64) -> u64 {
        let n = w.bytes() as usize;
        let win = self.window(sysva, n);
        let mut buf = [0u8; 8];
        buf[..n].copy_from_slice(win);
        u64::from_le_bytes(buf)
    }

    /// Raw write of the low `w.bytes()` bytes of `val`.
    pub fn write(&mut self, w: MemWidth, sysva: u64, val: u64) {
        let n = w.bytes() as usize;
        let win = self.window(sysva, n);
        win.copy_from_slice(&val.to_le_bytes()[..n]);
    }

    /// f64 view (T_float).
    pub fn read_f64(&mut self, sysva: u64) -> f64 {
        f64::from_bits(self.read(MemWidth::F64, sysva))
    }

    pub fn write_f64(&mut self, sysva: u64, val: f64) {
        self.write(MemWidth::F64, sysva, val.to_bits());
    }

    /// f32 view (S_float).
    pub fn read_f32(&mut self, sysva: u64) -> f32 {
        f32::from_bits(self.read(MemWidth::F32, sysva) as u32)
    }

    pub fn write_f32(&mut self, sysva: u64, val: f32) {
        self.write(MemWidth::F32, sysva, val.to_bits() as u64);
    }

    /// Bytes currently backed (for footprint reporting).
    pub fn resident_bytes(&self) -> u64 {
        self.segs
            .iter()
            .map(|s| (s.shared.len() + s.private.len()) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_bases_are_regular() {
        assert_eq!(seg_base(0), 1 << 32);
        assert_eq!(seg_base(3), 4 << 32);
        let m = MemSystem::new(4);
        for t in 0..4 {
            assert_eq!(m.base_table.base(t), seg_base(t));
        }
    }

    #[test]
    fn rw_roundtrip_all_widths() {
        let mut m = MemSystem::new(2);
        let a = seg_base(1) + 0x100;
        m.write(MemWidth::U8, a, 0xAB);
        assert_eq!(m.read(MemWidth::U8, a), 0xAB);
        m.write(MemWidth::U16, a, 0xBEEF);
        assert_eq!(m.read(MemWidth::U16, a), 0xBEEF);
        m.write(MemWidth::U32, a, 0xDEAD_BEEF);
        assert_eq!(m.read(MemWidth::U32, a), 0xDEAD_BEEF);
        m.write(MemWidth::U64, a, 0x0123_4567_89AB_CDEF);
        assert_eq!(m.read(MemWidth::U64, a), 0x0123_4567_89AB_CDEF);
        m.write_f64(a, -3.25);
        assert_eq!(m.read_f64(a), -3.25);
        m.write_f32(a, 1.5);
        assert_eq!(m.read_f32(a), 1.5);
    }

    #[test]
    fn widths_zero_extend() {
        let mut m = MemSystem::new(1);
        let a = seg_base(0) + 8;
        m.write(MemWidth::U64, a, u64::MAX);
        assert_eq!(m.read(MemWidth::U8, a), 0xFF);
        assert_eq!(m.read(MemWidth::U32, a), 0xFFFF_FFFF);
    }

    #[test]
    fn private_and_shared_disjoint() {
        let mut m = MemSystem::new(1);
        m.write(MemWidth::U64, seg_base(0), 1);
        m.write(MemWidth::U64, seg_base(0) + PRIV_OFF, 2);
        assert_eq!(m.read(MemWidth::U64, seg_base(0)), 1);
        assert_eq!(m.read(MemWidth::U64, seg_base(0) + PRIV_OFF), 2);
    }

    #[test]
    #[should_panic]
    fn unmapped_access_is_a_bug() {
        let mut m = MemSystem::new(1);
        m.read(MemWidth::U8, 0x10); // below all segments
    }
}
