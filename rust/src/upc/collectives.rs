//! UPC language constructs as reusable IR-emitting helpers:
//! `upc_forall` affinity loops and the collectives (`upc_all_reduce`,
//! `upc_all_broadcast`) the NPB kernels hand-roll.
//!
//! These generate the same shared-pointer traffic the Berkeley
//! translations produce, so they inherit the Soft/Hw lowering split —
//! a collective compiled with `Lowering::Hw` uses the PGAS instructions
//! for its internal traversals.

use crate::compiler::{IrBuilder, Val};
use crate::isa::{Cond, FpOp, IntOp, MemWidth};
use crate::upc::ArrayId;

/// `upc_forall(i = 0; i < n; i++; &A[i])` over a **cyclic** array
/// (blocksize 1): each thread visits i ≡ MYTHREAD (mod THREADS),
/// walking a shared pointer with stride THREADS.  The closure receives
/// the pointer register positioned at the current element.
pub fn forall_cyclic<F>(b: &mut IrBuilder, arr: ArrayId, n: u64, f: F)
where
    F: FnOnce(&mut IrBuilder, u8) + Copy,
{
    let layout = b.rt.array(arr).layout;
    assert_eq!(layout.blocksize, 1, "forall_cyclic requires blocksize 1");
    let threads = layout.numthreads as i64;
    let myt = b.mythread();
    let p = b.sptr_init(arr, Val::R(myt));
    b.free_i(myt);
    let iters = (n / layout.numthreads as u64) as i64;
    b.for_range(Val::I(0), Val::I(iters), 1, |b, _| {
        f(b, p);
        b.sptr_inc(p, arr, Val::I(threads));
    });
    b.free_i(p);
}

/// `upc_forall` over a **blocked** array (blocksize = n/THREADS): each
/// thread walks its contiguous chunk with stride 1.
pub fn forall_blocked<F>(b: &mut IrBuilder, arr: ArrayId, n: u64, f: F)
where
    F: FnOnce(&mut IrBuilder, u8) + Copy,
{
    let layout = b.rt.array(arr).layout;
    let chunk = n / layout.numthreads as u64;
    assert_eq!(layout.blocksize, chunk, "forall_blocked: blocksize must equal n/THREADS");
    let myt = b.mythread();
    let start = b.it();
    b.bin(IntOp::Mul, start, myt, Val::I(chunk as i64));
    b.free_i(myt);
    let p = b.sptr_init(arr, Val::R(start));
    b.free_i(start);
    b.for_range(Val::I(0), Val::I(chunk as i64), 1, |b, _| {
        f(b, p);
        b.sptr_inc(p, arr, Val::I(1));
    });
    b.free_i(p);
}

/// `upc_all_reduce(UPC_ADD, double)`: every thread contributes the f64
/// in `fval` via `contrib` (a cyclic THREADS-element array); after the
/// barrier, thread 0 sums and stores into `out[0]`; a second barrier
/// publishes. Afterwards every thread loads the result into `fdst`.
pub fn all_reduce_sum_f64(
    b: &mut IrBuilder,
    contrib: ArrayId,
    out: ArrayId,
    fval: u8,
    fdst: u8,
) {
    assert_eq!(b.rt.array(contrib).layout.blocksize, 1);
    // publish my contribution to my affinity slot
    let myt = b.mythread();
    let pc = b.sptr_init(contrib, Val::R(myt));
    b.sptr_st(MemWidth::F64, fval, pc, 0);
    b.free_i(pc);
    b.barrier();
    // thread 0 reduces
    b.iff(Cond::Eq, myt, |b| {
        let facc = b.fconst(0.0);
        let p = b.sptr_init(contrib, Val::I(0));
        let nt = b.threads();
        b.for_range(Val::I(0), Val::R(nt), 1, |b, _| {
            let fv = b.ft();
            b.sptr_ld(MemWidth::F64, fv, p, 0);
            b.fbin(FpOp::FAdd, facc, facc, fv);
            b.free_f(fv);
            b.sptr_inc(p, contrib, Val::I(1));
        });
        b.free_i(nt);
        b.free_i(p);
        let po = b.sptr_init(out, Val::I(0));
        b.sptr_st(MemWidth::F64, facc, po, 0);
        b.free_i(po);
        b.free_f(facc);
    });
    b.free_i(myt);
    b.barrier();
    // everyone reads the result
    let po = b.sptr_init(out, Val::I(0));
    b.sptr_ld(MemWidth::F64, fdst, po, 0);
    b.free_i(po);
}

/// `upc_all_broadcast`: thread `root` writes `fval` to `out[0]`;
/// everyone reads it into `fdst` after the barrier.
pub fn all_broadcast_f64(
    b: &mut IrBuilder,
    out: ArrayId,
    root: i64,
    fval: u8,
    fdst: u8,
) {
    let myt = b.mythread();
    let cmp = b.it();
    b.bin(IntOp::CmpEq, cmp, myt, Val::I(root));
    b.free_i(myt);
    b.iff(Cond::Ne, cmp, |b| {
        let po = b.sptr_init(out, Val::I(0));
        b.sptr_st(MemWidth::F64, fval, po, 0);
        b.free_i(po);
    });
    b.free_i(cmp);
    b.barrier();
    let po = b.sptr_init(out, Val::I(0));
    b.sptr_ld(MemWidth::F64, fdst, po, 0);
    b.free_i(po);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOpts, Lowering};
    use crate::cpu::CpuModel;
    use crate::mem::{seg_base, PRIV_OFF};
    use crate::sim::{Machine, MachineCfg};
    use crate::upc::UpcRuntime;

    fn run_collective(lowering: Lowering, threads: u32) -> (f64, Vec<f64>) {
        let mut rt = UpcRuntime::new(threads);
        let contrib = rt.alloc_shared("contrib", 1, 8, threads as u64);
        let out = rt.alloc_shared("out", 1, 8, 1);
        let data = rt.alloc_shared("data", 1, 8, threads as u64 * 8);

        let mut b = IrBuilder::new(&mut rt);
        // every thread: val = MYTHREAD + 1 (as f64)
        let myt = b.mythread();
        let v1 = b.it();
        b.bin(IntOp::Add, v1, myt, Val::I(1));
        let fval = b.ft();
        b.cvt_if(fval, v1);
        b.free_i(v1);
        b.free_i(myt);
        let fsum = b.ft();
        all_reduce_sum_f64(&mut b, contrib, out, fval, fsum);
        // broadcast double the sum from thread 0
        let ftwo = b.fconst(2.0);
        b.fbin(FpOp::FMul, fval, fsum, ftwo);
        b.free_f(ftwo);
        let fbc = b.ft();
        all_broadcast_f64(&mut b, out, 0, fval, fbc);
        // forall over the cyclic data array: data[i] = broadcast value
        forall_cyclic(&mut b, data, threads as u64 * 8, |b, p| {
            b.sptr_st(MemWidth::F64, fbc, p, 0);
        });
        // each thread writes its received broadcast to private space
        let pb = b.priv_base();
        b.st(MemWidth::F64, fbc, pb, 0);
        b.free_i(pb);
        let m = b.finish("collectives");

        let ck = compile(
            &m,
            &rt,
            &CompileOpts {
                lowering,
                static_threads: false,
                numthreads: threads,
                volatile_stores: false,
            },
        );
        let mut machine = Machine::new(MachineCfg::new(threads, CpuModel::Atomic));
        machine.run(&ck.program);
        let bc0 = machine.mem.read_f64(seg_base(0) + PRIV_OFF);
        let data_vals: Vec<f64> = (0..threads as u64 * 8)
            .map(|i| rt.read_f64(machine.mem_mut(), data, i))
            .collect();
        (bc0, data_vals)
    }

    #[test]
    fn reduce_broadcast_forall_roundtrip() {
        for threads in [1u32, 2, 8] {
            let want = 2.0 * (1..=threads as u64).sum::<u64>() as f64;
            for lowering in [Lowering::Soft, Lowering::Hw] {
                let (bc, data) = run_collective(lowering, threads);
                assert_eq!(bc, want, "{lowering:?} x{threads}");
                assert!(
                    data.iter().all(|&v| v == want),
                    "{lowering:?} x{threads}: forall must cover every element"
                );
            }
        }
    }

    #[test]
    fn forall_blocked_covers_all_elements() {
        let threads = 4u32;
        let n = 64u64;
        let mut rt = UpcRuntime::new(threads);
        let arr = rt.alloc_shared("a", n / threads as u64, 8, n);
        let mut b = IrBuilder::new(&mut rt);
        let one = b.iconst(1);
        forall_blocked(&mut b, arr, n, |b, p| {
            b.sptr_st(MemWidth::U64, one, p, 0);
        });
        b.free_i(one);
        let m = b.finish("blocked");
        let ck = compile(&m, &rt, &CompileOpts::hw(threads));
        let mut machine = Machine::new(MachineCfg::new(threads, CpuModel::Atomic));
        machine.run(&ck.program);
        for i in 0..n {
            assert_eq!(rt.read_u64(machine.mem_mut(), arr, i), 1, "elem {i}");
        }
    }
}
