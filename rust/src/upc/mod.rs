//! The UPC runtime model: symmetric shared heaps with block-cyclic
//! arrays (paper Fig. 1/2), a symmetric private-space allocator, and
//! host-side element access for workload initialization and validation.
//!
//! The runtime is *symmetric*: every shared allocation starts at the same
//! local offset in every thread's shared segment (as in the Berkeley
//! runtime), which is what makes the single `va` field of a shared
//! pointer meaningful on all threads.
//!
//! All host-side address mapping goes through the runtime's
//! [`EngineSelector`]: scalar accesses use the selected backend's
//! scalar path, and the `*_seq` bulk initialization/validation helpers
//! batch whole array traversals through one engine `walk`.

pub mod collectives;

use crate::engine::{BatchOut, EngineCtx, EngineSelector};
use crate::isa::MemWidth;
use crate::mem::{MemSystem, PRIV_OFF};
use crate::sptr::{ArrayLayout, SharedPtr};

/// Identifier of a shared array within a [`UpcRuntime`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ArrayId(pub usize);

/// One `shared [B] T name[N]` declaration.
#[derive(Clone, Debug)]
pub struct SharedArray {
    pub name: String,
    pub layout: ArrayLayout,
    pub nelems: u64,
    /// Local offset of the array's data in every thread's shared segment.
    pub base_va: u64,
}

impl SharedArray {
    /// Shared pointer to logical element `idx`, which must be an actual
    /// element (`idx < nelems`).  The one-past-the-end pointer UPC
    /// arithmetic may legally form is *not* an element access; use
    /// [`SharedArray::end_ptr`] for it.
    pub fn ptr(&self, idx: u64) -> SharedPtr {
        debug_assert!(
            idx < self.nelems,
            "{}[{idx}] out of bounds (nelems {})",
            self.name,
            self.nelems
        );
        SharedPtr::for_index(&self.layout, self.base_va, idx)
    }

    /// The one-past-the-end pointer (`&A[nelems]` in UPC terms): legal
    /// to form and compare against, never to dereference.
    pub fn end_ptr(&self) -> SharedPtr {
        SharedPtr::for_index(&self.layout, self.base_va, self.nelems)
    }

    /// Can the PGAS hardware traverse this array (pow2 geometry)?
    pub fn hw_supported(&self) -> bool {
        self.layout.hw_supported()
    }
}

/// The per-program UPC runtime state: allocators + array directory +
/// the address-mapping engine serving host-side accesses.
pub struct UpcRuntime {
    pub numthreads: u32,
    arrays: Vec<SharedArray>,
    shared_top: u64,
    priv_top: u64,
    engine: EngineSelector,
}

/// Alignment of every allocation (one cache line).
const ALIGN: u64 = 64;

impl UpcRuntime {
    pub fn new(numthreads: u32) -> Self {
        Self {
            numthreads,
            arrays: Vec::new(),
            shared_top: 0,
            // private space starts after the compiler's reserved area
            // (fp-constant pool + spill slots, see compiler::emit)
            priv_top: 0x1000,
            engine: EngineSelector::new(),
        }
    }

    /// The address-mapping engine serving host-side accesses.
    pub fn engine(&self) -> &EngineSelector {
        &self.engine
    }

    /// Replace the engine selector (e.g. one with the XLA batch
    /// backend installed).
    pub fn install_engine(&mut self, engine: EngineSelector) {
        self.engine = engine;
    }

    /// Declare + allocate `shared [blocksize] T name[nelems]` with
    /// `elemsize = sizeof(T)`. Returns the array id.
    pub fn alloc_shared(
        &mut self,
        name: &str,
        blocksize: u64,
        elemsize: u64,
        nelems: u64,
    ) -> ArrayId {
        let layout = ArrayLayout::new(blocksize, elemsize, self.numthreads);
        // symmetric allocation: every thread reserves the worst-case
        // (thread-0) footprint so base_va is identical everywhere.
        let worst = (0..self.numthreads)
            .map(|t| layout.bytes_on_thread(nelems, t))
            .max()
            .unwrap_or(0);
        let base_va = self.shared_top;
        self.shared_top += worst.div_ceil(ALIGN) * ALIGN;
        let id = ArrayId(self.arrays.len());
        self.arrays.push(SharedArray {
            name: name.to_string(),
            layout,
            nelems,
            base_va,
        });
        id
    }

    /// Allocate `bytes` of per-thread private space; returns the offset
    /// from the private base (identical on every thread).
    pub fn alloc_private(&mut self, bytes: u64) -> u64 {
        let off = self.priv_top;
        self.priv_top += bytes.div_ceil(ALIGN) * ALIGN;
        assert!(self.priv_top < 0x3000_0000, "private space exhausted");
        off
    }

    pub fn array(&self, id: ArrayId) -> &SharedArray {
        &self.arrays[id.0]
    }

    pub fn arrays(&self) -> &[SharedArray] {
        &self.arrays
    }

    pub fn shared_bytes_per_thread(&self) -> u64 {
        self.shared_top
    }

    // ---------- host-side access (init / validation only) ----------
    //
    // Every address below is produced by the AddressEngine the selector
    // picks for the array's layout — the same contract the simulated
    // hardware implements — never by ad-hoc pointer arithmetic.

    /// Engine context for one array's accesses.  The checked
    /// constructor cannot fail here: the memory system's base table is
    /// sized to the runtime's thread count, which every array layout
    /// inherits.
    fn ctx<'a>(&self, mem: &'a MemSystem, id: ArrayId) -> EngineCtx<'a> {
        EngineCtx::new(self.array(id).layout, &mem.base_table, 0)
            .expect("runtime base table covers all threads")
    }

    /// sysva of element `idx` of `id`.
    pub fn sysva(&self, mem: &MemSystem, id: ArrayId, idx: u64) -> u64 {
        let ctx = self.ctx(mem, id);
        let (_, sysva, _) = self
            .engine
            .translate_one(&ctx, self.array(id).ptr(idx), 0)
            .expect("host-side translate");
        sysva
    }

    /// sysvas of `n` consecutive elements starting at `start` — one
    /// batched engine walk instead of `n` scalar translations.
    pub fn sysva_seq(
        &self,
        mem: &MemSystem,
        id: ArrayId,
        start: u64,
        n: usize,
    ) -> Vec<u64> {
        if n == 0 {
            return Vec::new();
        }
        let ctx = self.ctx(mem, id);
        let mut out = BatchOut::new();
        self.engine
            .walk(&ctx, self.array(id).ptr(start), 1, n, &mut out)
            .expect("host-side walk");
        out.sysva
    }

    /// Bulk-write `vals` to consecutive elements starting at `start`.
    pub fn write_u64_seq(
        &self,
        mem: &mut MemSystem,
        id: ArrayId,
        start: u64,
        vals: &[u64],
    ) {
        let w = self.elem_width(id);
        let addrs = self.sysva_seq(mem, id, start, vals.len());
        for (&a, &v) in addrs.iter().zip(vals) {
            mem.write(w, a, v);
        }
    }

    /// Bulk-read `n` consecutive elements starting at `start`.
    pub fn read_u64_seq(
        &self,
        mem: &mut MemSystem,
        id: ArrayId,
        start: u64,
        n: usize,
    ) -> Vec<u64> {
        let w = self.elem_width(id);
        let addrs = self.sysva_seq(mem, id, start, n);
        addrs.iter().map(|&a| mem.read(w, a)).collect()
    }

    /// Bulk-write `vals` to consecutive f64 elements starting at `start`.
    pub fn write_f64_seq(
        &self,
        mem: &mut MemSystem,
        id: ArrayId,
        start: u64,
        vals: &[f64],
    ) {
        let addrs = self.sysva_seq(mem, id, start, vals.len());
        for (&a, &v) in addrs.iter().zip(vals) {
            mem.write_f64(a, v);
        }
    }

    /// Bulk-read `n` consecutive f64 elements starting at `start`.
    pub fn read_f64_seq(
        &self,
        mem: &mut MemSystem,
        id: ArrayId,
        start: u64,
        n: usize,
    ) -> Vec<f64> {
        let addrs = self.sysva_seq(mem, id, start, n);
        addrs.iter().map(|&a| mem.read_f64(a)).collect()
    }

    fn elem_width(&self, id: ArrayId) -> MemWidth {
        match self.array(id).layout.elemsize {
            1 => MemWidth::U8,
            2 => MemWidth::U16,
            4 => MemWidth::U32,
            _ => MemWidth::U64,
        }
    }

    pub fn write_u64(&self, mem: &mut MemSystem, id: ArrayId, idx: u64, v: u64) {
        let a = self.sysva(mem, id, idx);
        mem.write(self.elem_width(id), a, v);
    }

    pub fn read_u64(&self, mem: &mut MemSystem, id: ArrayId, idx: u64) -> u64 {
        let a = self.sysva(mem, id, idx);
        mem.read(self.elem_width(id), a)
    }

    pub fn write_f64(&self, mem: &mut MemSystem, id: ArrayId, idx: u64, v: f64) {
        let a = self.sysva(mem, id, idx);
        mem.write_f64(a, v);
    }

    pub fn read_f64(&self, mem: &mut MemSystem, id: ArrayId, idx: u64) -> f64 {
        let a = self.sysva(mem, id, idx);
        mem.read_f64(a)
    }

    /// Private-space sysva for thread `t` at offset `off`.
    pub fn priv_sysva(&self, t: u32, off: u64) -> u64 {
        crate::mem::seg_base(t) + PRIV_OFF + off
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_allocation() {
        let mut rt = UpcRuntime::new(4);
        let a = rt.alloc_shared("a", 4, 8, 32);
        let b = rt.alloc_shared("b", 2, 4, 100);
        assert_eq!(rt.array(a).base_va, 0);
        // a occupies 8 elems * 8B = 64B per thread (32 elems/4 threads)
        assert_eq!(rt.array(b).base_va, 64);
        assert!(rt.array(a).hw_supported());
    }

    #[test]
    fn nonpow2_array_not_hw_supported() {
        let mut rt = UpcRuntime::new(4);
        // the CG w/w_tmp case: elemsize 56016
        let w = rt.alloc_shared("w", 1, 56016, 8);
        assert!(!rt.array(w).hw_supported());
    }

    #[test]
    fn host_rw_roundtrip_follows_layout() {
        let mut rt = UpcRuntime::new(4);
        let a = rt.alloc_shared("a", 4, 8, 32);
        let mut mem = MemSystem::new(4);
        for i in 0..32 {
            rt.write_u64(&mut mem, a, i, i * i);
        }
        for i in 0..32 {
            assert_eq!(rt.read_u64(&mut mem, a, i), i * i);
        }
        // element 5 must live in thread 1's segment
        let sysva = rt.sysva(&mem, a, 5);
        assert_eq!(sysva >> 32, 2);
    }

    #[test]
    fn f64_elements() {
        let mut rt = UpcRuntime::new(2);
        let a = rt.alloc_shared("x", 8, 8, 64);
        let mut mem = MemSystem::new(2);
        rt.write_f64(&mut mem, a, 63, 2.5);
        assert_eq!(rt.read_f64(&mut mem, a, 63), 2.5);
    }

    #[test]
    fn seq_helpers_match_scalar_access() {
        let mut rt = UpcRuntime::new(4);
        let a = rt.alloc_shared("a", 4, 8, 64);
        let mut mem = MemSystem::new(4);
        let vals: Vec<u64> = (0..64u64).map(|i| i * 3 + 1).collect();
        rt.write_u64_seq(&mut mem, a, 0, &vals);
        for i in 0..64 {
            assert_eq!(rt.read_u64(&mut mem, a, i), vals[i as usize]);
        }
        assert_eq!(rt.read_u64_seq(&mut mem, a, 0, 64), vals);
        // the batched walk and the scalar translate agree address-for-address
        let addrs = rt.sysva_seq(&mem, a, 5, 20);
        for (k, &addr) in addrs.iter().enumerate() {
            assert_eq!(addr, rt.sysva(&mem, a, 5 + k as u64));
        }
        assert!(rt.sysva_seq(&mem, a, 0, 0).is_empty());
    }

    #[test]
    fn f64_seq_roundtrip_nonpow2_layout() {
        // non-pow2 geometry: the selector must fall back to software
        let mut rt = UpcRuntime::new(3);
        let a = rt.alloc_shared("x", 5, 8, 41);
        let mut mem = MemSystem::new(3);
        let vals: Vec<f64> = (0..41).map(|i| i as f64 * 0.5 - 3.0).collect();
        rt.write_f64_seq(&mut mem, a, 0, &vals);
        assert_eq!(rt.read_f64_seq(&mut mem, a, 0, 41), vals);
        assert_eq!(rt.read_f64(&mut mem, a, 40), vals[40]);
    }

    #[test]
    fn end_ptr_is_one_past_the_last_element() {
        let mut rt = UpcRuntime::new(4);
        let a = rt.alloc_shared("a", 4, 4, 32);
        let arr = rt.array(a);
        let end = arr.end_ptr();
        assert_eq!(end, SharedPtr::for_index(&arr.layout, arr.base_va, 32));
        // incrementing off the last element lands exactly on end_ptr
        assert_eq!(arr.ptr(31).incremented(1, &arr.layout), end);
    }

    #[test]
    fn engine_choice_follows_layout_geometry() {
        use crate::engine::EngineChoice;
        let mut rt = UpcRuntime::new(4);
        let w = rt.alloc_shared("w", 1, 56016, 8);
        let g = rt.alloc_shared("g", 4, 8, 64);
        assert_eq!(
            rt.engine().choice(&rt.array(w).layout, 8),
            EngineChoice::Software
        );
        assert_eq!(rt.engine().choice(&rt.array(g).layout, 8), EngineChoice::Pow2);
    }

    #[test]
    fn private_allocator_is_symmetric() {
        let mut rt = UpcRuntime::new(2);
        let o1 = rt.alloc_private(100);
        let o2 = rt.alloc_private(8);
        assert!(o2 >= o1 + 100);
        assert_eq!(rt.priv_sysva(0, o1) + (1 << 32), rt.priv_sysva(1, o1));
    }
}
