//! # pgas-hw — Hardware Support for Address Mapping in PGAS Languages
//!
//! A full-system reproduction of Serres et al., *"Hardware Support for
//! Address Mapping in PGAS Languages; a UPC Case Study"* (CS.DC 2013).
//!
//! The paper proposes ISA-level hardware for UPC shared pointers: an
//! address-increment instruction implementing the block-cyclic traversal
//! (their Algorithm 1) in a 2-stage pipeline, and shared load/store
//! instructions that translate `(thread, phase, va)` pointers through a
//! per-thread base-address LUT at the cost of an ordinary memory access.
//!
//! ## The `AddressEngine` contract
//!
//! The paper's core claim is that this address-mapping contract —
//! Algorithm 1 + LUT translation + locality classification — is **one
//! interface** that interchangeable implementations can serve without
//! the program changing.  This crate makes that literal: the [`engine`]
//! module defines the [`AddressEngine`] trait with a batched
//! request/response API (`translate`, `increment`, `walk` over a
//! reusable [`PtrBatch`]), five first-class backends
//! (`SoftwareEngine` for any layout, `Pow2Engine` for the shift/mask
//! hardware datapath, `ShardedEngine` partitioning batches over a
//! persistent worker-thread pool, `Leon3Engine` replaying batches as
//! coprocessor instruction sequences on the FPGA-prototype model,
//! `XlaBatchEngine` for the PJRT batch unit behind the `xla-unit`
//! feature), and an [`EngineSelector`] that
//! prices every legal backend per `(layout, batch size)` request and
//! serves the cheapest — the runtime mirror of the compiler's
//! `Soft`/`Hw` lowering choice, with per-choice hit counters so sweeps
//! archive the mix that actually served them.  Walks advance O(1) per
//! step via `sptr::WalkCursor` (add-and-carry, no per-step div/mod).
//! Every host-side consumer (the UPC runtime, NPB workload
//! init/validation, the campaign coordinator, the CLI) goes through it.
//!
//! ```no_run
//! use pgas_hw::engine::{AddressEngine, BatchOut, EngineCtx, EngineSelector};
//! use pgas_hw::{ArrayLayout, BaseTable, SharedPtr};
//!
//! // shared [4] int A[...] over 4 threads (the paper's Figure 2)
//! let layout = ArrayLayout::new(4, 4, 4);
//! let table = BaseTable::regular(4, 1 << 32, 1 << 32);
//! let sel = EngineSelector::new();
//! let engine = sel.select(&layout, 32); // pow2 geometry -> "pow2"
//! let ctx = EngineCtx::new(layout, &table, 0).unwrap();
//! let mut out = BatchOut::new();
//! engine
//!     .walk(&ctx, SharedPtr::NULL, 1, 32, &mut out)
//!     .unwrap();
//! assert_eq!(out.ptrs[5].thread, 1); // elements 4..7 live on thread 1
//! ```
//!
//! ## The full evaluation stack
//!
//! * [`engine`] — the unified `AddressEngine` API described above.
//! * [`sptr`] — UPC shared-pointer algebra: Algorithm 1 (general and
//!   power-of-2 paths), LUT translation, locality codes, packing.
//! * [`isa`] — *SimAlpha*: a 64-bit RISC ISA plus the paper's Table-1
//!   PGAS extension with Figure-3 instruction formats.
//! * [`mem`] / [`cache`] — memory system and L1/L2 hierarchy with
//!   MESI-lite snooping (the Gem5 "classic" memory model analogue).
//! * [`cpu`] — the three Gem5 CPU models: `atomic`, `timing`, `detailed`.
//! * [`sim`] — an N-core SPMD machine (up to 64 cores, the paper's
//!   BigTsunami limit) with UPC barriers.
//! * [`upc`] — the UPC runtime model: block-cyclic shared arrays,
//!   per-thread heaps, affinity; host-side access is served by the
//!   engine selector.
//! * [`compiler`] — a mini Berkeley-UPC-like code generator lowering a
//!   kernel IR to SimAlpha in three variants: `Soft` (software Algorithm
//!   1), `Privatized` (manual pointer privatization), `Hw` (the new
//!   instructions, with software fallback for non-power-of-2 layouts).
//! * [`npb`] — the five NAS Parallel Benchmark kernels of the paper
//!   (EP, IS, CG, MG, FT) expressed against the UPC runtime.
//! * [`leon3`] — the FPGA prototype: SPARC-V8-class 7-stage in-order
//!   pipeline with the Table-3 coprocessor, AMBA AHB bus contention and
//!   DDR3 timing; vector-add and matmul microbenchmarks (Figs 15/16).
//!   Its functional core also backs `engine::Leon3Engine`, putting the
//!   FPGA datapath behind the same `AddressEngine` trait.
//! * [`area`] — the FPGA resource model regenerating Table 4.
//! * [`runtime`] — artifact geometry + scalar oracle for the batched
//!   unit; the PJRT/XLA executor itself is behind the `xla-unit`
//!   cargo feature.
//! * [`analysis`] — the static PGAS access analyzer behind `pgas-hw
//!   lint`: barrier-phase race detection over affine footprints, a
//!   static shared-bounds check, and a compile-time engine-mix
//!   prediction differentially validated against runtime telemetry.
//! * [`coordinator`] — campaign configuration, sweep scheduling, result
//!   collection and the figure/table reporters.
//! * [`daemon`] — the multi-tenant address-mapping service (`pgas-hw
//!   daemon`): many concurrent epoch sessions over one socket, fair
//!   round-robin admission control with loud load shedding, and the
//!   Leon3 unit behind an exclusive priority-aware lease;
//!   `RemoteEngine::connect` is the client.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! simulator and benchmarks never touch it at run time.

#[warn(missing_docs)]
pub mod analysis;
pub mod area;
pub mod cache;
pub mod compiler;
pub mod coordinator;
pub mod cpu;
pub mod daemon;
pub mod engine;
pub mod isa;
pub mod leon3;
pub mod mem;
pub mod npb;
pub mod runtime;
pub mod sim;
pub mod sptr;
pub mod upc;
pub mod util;

pub use engine::{AddressEngine, EngineSelector, PtrBatch};
pub use sptr::{ArrayLayout, BaseTable, Locality, SharedPtr};
