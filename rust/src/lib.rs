//! # pgas-hw — Hardware Support for Address Mapping in PGAS Languages
//!
//! A full-system reproduction of Serres et al., *"Hardware Support for
//! Address Mapping in PGAS Languages; a UPC Case Study"* (CS.DC 2013).
//!
//! The paper proposes ISA-level hardware for UPC shared pointers: an
//! address-increment instruction implementing the block-cyclic traversal
//! (their Algorithm 1) in a 2-stage pipeline, and shared load/store
//! instructions that translate `(thread, phase, va)` pointers through a
//! per-thread base-address LUT at the cost of an ordinary memory access.
//!
//! This crate rebuilds the paper's entire evaluation stack:
//!
//! * [`sptr`] — UPC shared-pointer algebra: Algorithm 1 (general and
//!   power-of-2 paths), LUT translation, locality codes, packing.
//! * [`isa`] — *SimAlpha*: a 64-bit RISC ISA plus the paper's Table-1
//!   PGAS extension with Figure-3 instruction formats.
//! * [`mem`] / [`cache`] — memory system and L1/L2 hierarchy with
//!   MESI-lite snooping (the Gem5 "classic" memory model analogue).
//! * [`cpu`] — the three Gem5 CPU models: `atomic`, `timing`, `detailed`.
//! * [`sim`] — an N-core SPMD machine (up to 64 cores, the paper's
//!   BigTsunami limit) with UPC barriers.
//! * [`upc`] — the UPC runtime model: block-cyclic shared arrays,
//!   per-thread heaps, affinity.
//! * [`compiler`] — a mini Berkeley-UPC-like code generator lowering a
//!   kernel IR to SimAlpha in three variants: `Soft` (software Algorithm
//!   1), `Privatized` (manual pointer privatization), `Hw` (the new
//!   instructions, with software fallback for non-power-of-2 layouts).
//! * [`npb`] — the five NAS Parallel Benchmark kernels of the paper
//!   (EP, IS, CG, MG, FT) expressed against the UPC runtime.
//! * [`leon3`] — the FPGA prototype: SPARC-V8-class 7-stage in-order
//!   pipeline with the Table-3 coprocessor, AMBA AHB bus contention and
//!   DDR3 timing; vector-add and matmul microbenchmarks (Figs 15/16).
//! * [`area`] — the FPGA resource model regenerating Table 4.
//! * [`runtime`] — PJRT/XLA executor for the AOT-compiled batched
//!   address-mapping unit (the L1 Pallas kernel), loaded from
//!   `artifacts/*.hlo.txt`.
//! * [`coordinator`] — campaign configuration, sweep scheduling, result
//!   collection and the figure/table reporters.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! simulator and benchmarks never touch it at run time.

pub mod area;
pub mod cache;
pub mod compiler;
pub mod coordinator;
pub mod cpu;
pub mod isa;
pub mod leon3;
pub mod mem;
pub mod npb;
pub mod runtime;
pub mod sim;
pub mod sptr;
pub mod upc;
pub mod util;

pub use sptr::{ArrayLayout, BaseTable, Locality, SharedPtr};
