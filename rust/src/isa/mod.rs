//! *SimAlpha*: the simulated 64-bit RISC ISA plus the paper's PGAS
//! extension (Table 1), with the Figure-3 instruction formats.
//!
//! The base ISA is a compact Alpha-21264-flavoured RISC: 32 integer
//! registers (`r31` reads as zero), 32 FP registers, compare-to-zero
//! branches, and explicit multiply/divide.  On top of it sit the paper's
//! new instructions:
//!
//! * shared-address loads/stores (6 widths each, short displacement),
//! * shared-address increment (immediate and register forms),
//! * the `threads` special register and base-address-LUT initialization,
//! * branch-on-locality (the SPARC/Leon3 Table-3 coprocessor branch,
//!   included in SimAlpha so both prototypes share one core ISA).
//!
//! Only the extension instructions get binary encodings here
//! ([`encoding`], Figure 3); the base ISA is executed from its decoded
//! form — the paper's contribution is the extension, and the base
//! encoding is irrelevant to every measured result.

pub mod encoding;
pub mod latency;

use std::fmt;

/// Architectural register index (0..=31). `r31`/`f31` read as zero.
pub type Reg = u8;

/// The zero register.
pub const ZERO: Reg = 31;

/// Memory access widths of the Table-1 loads/stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemWidth {
    /// Load Byte Unsigned (8 bits)
    U8,
    /// Load Word Unsigned (16 bits)
    U16,
    /// Load Long Unsigned (32 bits)
    U32,
    /// Load Quad Unsigned (64 bits)
    U64,
    /// Load S_float (32 bits, float) — targets the FP register file
    F32,
    /// Load T_float (64 bits, double) — targets the FP register file
    F64,
}

impl MemWidth {
    pub fn bytes(&self) -> u64 {
        match self {
            MemWidth::U8 => 1,
            MemWidth::U16 => 2,
            MemWidth::U32 => 4,
            MemWidth::U64 | MemWidth::F64 => 8,
            MemWidth::F32 => 4,
        }
    }

    pub fn is_float(&self) -> bool {
        matches!(self, MemWidth::F32 | MemWidth::F64)
    }

    pub const ALL: [MemWidth; 6] = [
        MemWidth::U8,
        MemWidth::U16,
        MemWidth::U32,
        MemWidth::U64,
        MemWidth::F32,
        MemWidth::F64,
    ];
}

/// Integer ALU operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IntOp {
    Add,
    Sub,
    Mul,
    /// Signed 64-bit divide (multi-cycle, non-pipelined — the expensive
    /// op in the software Algorithm 1).
    Div,
    /// Signed remainder.
    Rem,
    And,
    Or,
    Xor,
    Sll,
    Srl,
    Sra,
    /// rd = (ra == rb) ? 1 : 0
    CmpEq,
    /// rd = (ra < rb) signed ? 1 : 0
    CmpLt,
    /// rd = (ra < rb) unsigned ? 1 : 0
    CmpLtU,
    /// rd = (ra <= rb) signed ? 1 : 0
    CmpLe,
}

/// Floating-point operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FpOp {
    FAdd,
    FSub,
    FMul,
    FDiv,
    FSqrt,
    /// fd = max(fa, fb)
    FMax,
    /// fd = |fa| (fb ignored)
    FAbs,
    /// fd = -fa (fb ignored)
    FNeg,
    /// fd = fa (fb ignored)
    FMov,
}

/// Branch conditions (compare register to zero, Alpha style).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Cond {
    Eq,
    Ne,
    Lt,
    Ge,
    Le,
    Gt,
}

/// One SimAlpha instruction. Branch targets are resolved instruction
/// indices (the assembler turns labels into these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inst {
    // ---------------- base integer ----------------
    /// rd = ra `op` imm
    Opi { op: IntOp, rd: Reg, ra: Reg, imm: i32 },
    /// rd = ra `op` rb
    Opr { op: IntOp, rd: Reg, ra: Reg, rb: Reg },
    /// rd = imm (64-bit immediate materialization; counts as 1–2 ops in
    /// the timing models depending on magnitude, like lda/ldah pairs)
    Ldi { rd: Reg, imm: i64 },
    /// rd = mem[ra + disp]
    Ld { w: MemWidth, rd: Reg, base: Reg, disp: i32 },
    /// mem[ra + disp] = rs
    St { w: MemWidth, rs: Reg, base: Reg, disp: i32 },
    // ---------------- base floating point ----------------
    /// fd = fa `op` fb
    Fop { op: FpOp, fd: Reg, fa: Reg, fb: Reg },
    /// rd = (fa < fb) ? 1 : 0  (into the *integer* file, for branching)
    FCmpLt { rd: Reg, fa: Reg, fb: Reg },
    /// fd = (double) ra
    CvtIF { fd: Reg, ra: Reg },
    /// rd = (int64) fa, truncating
    CvtFI { rd: Reg, fa: Reg },
    // ---------------- control ----------------
    /// if (ra `cond` 0) pc = target
    Br { cond: Cond, ra: Reg, target: u32 },
    /// pc = target
    Jmp { target: u32 },
    // ---------------- PGAS extension (Table 1) ----------------
    /// rd = mem[translate(rptr) + disp]  — shared-address load
    PgasLd { w: MemWidth, rd: Reg, rptr: Reg, disp: i16 },
    /// mem[translate(rptr) + disp] = rs  — shared-address store.
    /// Emitted as `volatile` by the prototype compiler (paper 6.1), which
    /// the detailed model honours as a scheduling fence.
    PgasSt { w: MemWidth, rs: Reg, rptr: Reg, disp: i16 },
    /// rd = pgas_inc(ra, 1<<l2inc) with esize=1<<l2es, bsize=1<<l2bs.
    /// Immediate form: all three parameters are Figure-3 5-bit one-hot
    /// immediates (stored here as the log2 exponents).
    PgasIncI { rd: Reg, ra: Reg, l2es: u8, l2bs: u8, l2inc: u8 },
    /// rd = pgas_inc(ra, rb): register increment form.
    PgasIncR { rd: Reg, ra: Reg, rb: Reg, l2es: u8, l2bs: u8 },
    /// threads-special-register = ra (log2 numthreads is derived).
    PgasSetThreads { ra: Reg },
    /// base_table[rthread] = raddr
    PgasSetBase { rthread: Reg, raddr: Reg },
    /// Branch if the locality condition code of the most recent PGAS
    /// increment matches any bit of `mask` (Table 3 "Branch on
    /// locality"; bit i of mask = condition code i).
    PgasBrLoc { mask: u8, target: u32 },
    // ---------------- system / pseudo ----------------
    /// UPC barrier: rendezvous of all cores (runtime service in the
    /// simulated machine, a syscall in the real prototypes).
    Barrier,
    /// End of program for this thread.
    Halt,
    Nop,
}

impl Inst {
    /// Is this one of the new PGAS instructions?
    pub fn is_pgas(&self) -> bool {
        matches!(
            self,
            Inst::PgasLd { .. }
                | Inst::PgasSt { .. }
                | Inst::PgasIncI { .. }
                | Inst::PgasIncR { .. }
                | Inst::PgasSetThreads { .. }
                | Inst::PgasSetBase { .. }
                | Inst::PgasBrLoc { .. }
        )
    }

    /// Does this instruction access memory?
    pub fn is_mem(&self) -> bool {
        matches!(
            self,
            Inst::Ld { .. } | Inst::St { .. } | Inst::PgasLd { .. } | Inst::PgasSt { .. }
        )
    }

    /// Is this a store?
    pub fn is_store(&self) -> bool {
        matches!(self, Inst::St { .. } | Inst::PgasSt { .. })
    }

    /// Branch/jump?
    pub fn is_control(&self) -> bool {
        matches!(
            self,
            Inst::Br { .. } | Inst::Jmp { .. } | Inst::PgasBrLoc { .. }
        )
    }
}

impl fmt::Display for Inst {
    /// Disassembly, one instruction per line, Alpha-flavoured mnemonics.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn w_suffix(w: &MemWidth) -> &'static str {
            match w {
                MemWidth::U8 => "bu",
                MemWidth::U16 => "wu",
                MemWidth::U32 => "lu",
                MemWidth::U64 => "q",
                MemWidth::F32 => "s",
                MemWidth::F64 => "t",
            }
        }
        match self {
            Inst::Opi { op, rd, ra, imm } => {
                write!(f, "{:?} r{}, r{}, #{}", op, rd, ra, imm)
            }
            Inst::Opr { op, rd, ra, rb } => {
                write!(f, "{:?} r{}, r{}, r{}", op, rd, ra, rb)
            }
            Inst::Ldi { rd, imm } => write!(f, "ldi r{}, #{}", rd, imm),
            Inst::Ld { w, rd, base, disp } => {
                let file = if w.is_float() { "f" } else { "r" };
                write!(f, "ld{} {}{}, {}(r{})", w_suffix(w), file, rd, disp, base)
            }
            Inst::St { w, rs, base, disp } => {
                let file = if w.is_float() { "f" } else { "r" };
                write!(f, "st{} {}{}, {}(r{})", w_suffix(w), file, rs, disp, base)
            }
            Inst::Fop { op, fd, fa, fb } => {
                write!(f, "{:?} f{}, f{}, f{}", op, fd, fa, fb)
            }
            Inst::FCmpLt { rd, fa, fb } => {
                write!(f, "fcmplt r{}, f{}, f{}", rd, fa, fb)
            }
            Inst::CvtIF { fd, ra } => write!(f, "cvtif f{}, r{}", fd, ra),
            Inst::CvtFI { rd, fa } => write!(f, "cvtfi r{}, f{}", rd, fa),
            Inst::Br { cond, ra, target } => {
                write!(f, "b{:?} r{}, @{}", cond, ra, target)
            }
            Inst::Jmp { target } => write!(f, "jmp @{}", target),
            Inst::PgasLd { w, rd, rptr, disp } => {
                let file = if w.is_float() { "f" } else { "r" };
                write!(f, "pgas_ld{} {}{}, {}(r{})", w_suffix(w), file, rd, disp, rptr)
            }
            Inst::PgasSt { w, rs, rptr, disp } => {
                let file = if w.is_float() { "f" } else { "r" };
                write!(f, "pgas_st{} {}{}, {}(r{})", w_suffix(w), file, rs, disp, rptr)
            }
            Inst::PgasIncI { rd, ra, l2es, l2bs, l2inc } => write!(
                f,
                "pgas_inci r{}, r{}, es=1<<{}, bs=1<<{}, inc=1<<{}",
                rd, ra, l2es, l2bs, l2inc
            ),
            Inst::PgasIncR { rd, ra, rb, l2es, l2bs } => write!(
                f,
                "pgas_incr r{}, r{}, r{}, es=1<<{}, bs=1<<{}",
                rd, ra, rb, l2es, l2bs
            ),
            Inst::PgasSetThreads { ra } => write!(f, "pgas_setthreads r{}", ra),
            Inst::PgasSetBase { rthread, raddr } => {
                write!(f, "pgas_setbase [r{}] = r{}", rthread, raddr)
            }
            Inst::PgasBrLoc { mask, target } => {
                write!(f, "pgas_brloc mask={:#06b}, @{}", mask, target)
            }
            Inst::Barrier => write!(f, "barrier"),
            Inst::Halt => write!(f, "halt"),
            Inst::Nop => write!(f, "nop"),
        }
    }
}

/// A SimAlpha program: a flat instruction vector; branch targets index
/// into it. SPMD execution runs the same program on every core.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub name: String,
    pub insts: Vec<Inst>,
}

impl Program {
    pub fn new(name: &str, insts: Vec<Inst>) -> Self {
        let p = Self { name: name.to_string(), insts };
        p.validate().expect("invalid program");
        p
    }

    /// Check branch targets and register ranges.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.insts.len() as u32;
        for (i, inst) in self.insts.iter().enumerate() {
            let t = match inst {
                Inst::Br { target, .. }
                | Inst::Jmp { target }
                | Inst::PgasBrLoc { target, .. } => Some(*target),
                _ => None,
            };
            if let Some(t) = t {
                if t >= n {
                    return Err(format!(
                        "inst {i} `{inst}` targets {t} out of range {n}"
                    ));
                }
            }
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Count of PGAS-extension instructions (static), mirroring the
    /// paper's per-kernel counts ("309 shared address incrementations,
    /// 236 loads and stores" for CG).
    pub fn pgas_static_counts(&self) -> PgasCounts {
        let mut c = PgasCounts::default();
        for i in &self.insts {
            match i {
                Inst::PgasIncI { .. } | Inst::PgasIncR { .. } => c.increments += 1,
                Inst::PgasLd { .. } | Inst::PgasSt { .. } => c.loads_stores += 1,
                Inst::PgasBrLoc { .. } => c.branches += 1,
                Inst::PgasSetThreads { .. } | Inst::PgasSetBase { .. } => c.inits += 1,
                _ => {}
            }
        }
        c
    }

    /// Full disassembly listing.
    pub fn disassemble(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("; program `{}` ({} insts)\n", self.name, self.len()));
        for (i, inst) in self.insts.iter().enumerate() {
            out.push_str(&format!("{i:6}:  {inst}\n"));
        }
        out
    }
}

/// Static PGAS instruction census of a program.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PgasCounts {
    pub increments: u32,
    pub loads_stores: u32,
    pub branches: u32,
    pub inits: u32,
}

/// Render the paper's Table 1 (the Alpha ISA extension listing).
pub fn table1() -> String {
    let mut s = String::new();
    s.push_str("Table 1: Instructions Added to the Alpha ISA (SimAlpha)\n");
    s.push_str("  Shared Address Loads\n");
    for (w, n, b) in [
        ("bu", "Byte Unsigned", 8),
        ("wu", "Word Unsigned", 16),
        ("lu", "Long Unsigned", 32),
        ("q", "Quad Unsigned", 64),
        ("s", "S_float (float)", 32),
        ("t", "T_float (double)", 64),
    ] {
        s.push_str(&format!("    pgas_ld{w:<3} Load {n} ({b} bits)\n"));
    }
    s.push_str("  Shared Address Stores\n");
    for (w, n, b) in [
        ("bu", "Byte Unsigned", 8),
        ("wu", "Word Unsigned", 16),
        ("lu", "Long Unsigned", 32),
        ("q", "Quad Unsigned", 64),
        ("s", "S_float (float)", 32),
        ("t", "T_float (double)", 64),
    ] {
        s.push_str(&format!("    pgas_st{w:<3} Store {n} ({b} bits)\n"));
    }
    s.push_str("  Shared Address Incrementations\n");
    s.push_str("    pgas_inci  Address increment, immediate\n");
    s.push_str("    pgas_incr  Address increment, register\n");
    s.push_str("  Initialization\n");
    s.push_str("    pgas_setthreads  Initialize the 'threads' register\n");
    s.push_str("    pgas_setbase     Set the base address look-up table\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_validation_rejects_bad_targets() {
        let p = Program {
            name: "bad".into(),
            insts: vec![Inst::Jmp { target: 5 }],
        };
        assert!(p.validate().is_err());
        let ok = Program::new("ok", vec![Inst::Nop, Inst::Halt]);
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn pgas_census() {
        let p = Program::new(
            "c",
            vec![
                Inst::PgasIncI { rd: 0, ra: 0, l2es: 2, l2bs: 2, l2inc: 0 },
                Inst::PgasLd { w: MemWidth::U32, rd: 1, rptr: 0, disp: 0 },
                Inst::PgasSt { w: MemWidth::U32, rs: 1, rptr: 0, disp: 0 },
                Inst::Halt,
            ],
        );
        let c = p.pgas_static_counts();
        assert_eq!(c.increments, 1);
        assert_eq!(c.loads_stores, 2);
    }

    #[test]
    fn disassembly_is_stable() {
        let i = Inst::PgasIncI { rd: 3, ra: 4, l2es: 2, l2bs: 5, l2inc: 0 };
        assert_eq!(
            i.to_string(),
            "pgas_inci r3, r4, es=1<<2, bs=1<<5, inc=1<<0"
        );
        assert!(Inst::Barrier.to_string().contains("barrier"));
    }

    #[test]
    fn table1_lists_all_sixteen_plus_inits() {
        let t = table1();
        assert_eq!(t.matches("pgas_ld").count(), 6);
        assert_eq!(t.matches("pgas_st").count(), 6);
        assert!(t.contains("pgas_inci"));
        assert!(t.contains("pgas_setthreads"));
    }

    #[test]
    fn classifiers() {
        let ld = Inst::PgasLd { w: MemWidth::F64, rd: 0, rptr: 1, disp: 8 };
        assert!(ld.is_pgas() && ld.is_mem() && !ld.is_store());
        let st = Inst::St { w: MemWidth::U8, rs: 0, base: 1, disp: 0 };
        assert!(st.is_store() && !st.is_pgas());
        assert!(Inst::Jmp { target: 0 }.is_control());
    }
}
