//! Per-instruction latency classes for the timing models.
//!
//! Values are 21264-class (the Gem5 Alpha core the paper simulates):
//! pipelined 1-cycle ALU, 7-cycle pipelined multiply, ~20-cycle
//! *non-pipelined* integer divide (the op that makes the software
//! Algorithm 1 expensive when blocksize/threads are not compile-time
//! powers of two), 4-cycle pipelined FP, 12/15-cycle FP divide/sqrt.
//!
//! The PGAS increment is the paper's 2-stage pipelined unit: 1-cycle
//! issue (throughput 1/cycle), 2-cycle result latency for dependent uses.
//! PGAS loads/stores cost the same as ordinary loads/stores ("performed
//! as fast as the normal SPARC load and store instructions").

use super::{FpOp, Inst, IntOp};

/// Functional unit kinds for the detailed (OoO) model's port limits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FuKind {
    IntAlu,
    IntMulDiv,
    FpAlu,
    FpMulDiv,
    MemPort,
    /// The new PGAS address unit (one per core in the prototype).
    PgasUnit,
    /// No FU needed (control, pseudo-ops resolved at fetch).
    None,
}

/// Execution cost of one instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cost {
    /// Result latency in cycles (producer -> dependent consumer).
    pub latency: u32,
    /// Issue-to-issue interval on the FU (1 = fully pipelined).
    pub init_interval: u32,
    /// Which FU executes it.
    pub fu: FuKind,
}

const fn cost(latency: u32, init_interval: u32, fu: FuKind) -> Cost {
    Cost { latency, init_interval, fu }
}

/// Tunable latency model (defaults are the 21264-class values above).
#[derive(Clone, Debug)]
pub struct LatencyModel {
    pub alu: u32,
    pub mul: u32,
    pub div: u32,
    pub fp: u32,
    pub fdiv: u32,
    pub fsqrt: u32,
    /// PGAS increment dependent-use latency (2-stage pipeline).
    pub pgas_inc: u32,
    /// Extra cycles a *software* shared access pays beyond the raw loads
    /// (none — the cost is in the instruction stream itself).
    pub ldi_long: u32,
}

impl Default for LatencyModel {
    fn default() -> Self {
        Self {
            alu: 1,
            mul: 7,
            div: 20,
            fp: 4,
            fdiv: 12,
            fsqrt: 15,
            pgas_inc: 2,
            ldi_long: 2,
        }
    }
}

impl LatencyModel {
    /// Cost of `inst`, excluding memory-hierarchy time (added by the
    /// cache model for loads/stores).
    pub fn cost(&self, inst: &Inst) -> Cost {
        match inst {
            Inst::Opi { op, .. } | Inst::Opr { op, .. } => match op {
                IntOp::Mul => cost(self.mul, 1, FuKind::IntMulDiv),
                // divide is non-pipelined on 21264-class cores
                IntOp::Div | IntOp::Rem => cost(self.div, self.div, FuKind::IntMulDiv),
                _ => cost(self.alu, 1, FuKind::IntAlu),
            },
            Inst::Ldi { imm, .. } => {
                // wide immediates need an lda/ldah pair
                if *imm >= -32768 && *imm < 32768 {
                    cost(self.alu, 1, FuKind::IntAlu)
                } else {
                    cost(self.ldi_long, 1, FuKind::IntAlu)
                }
            }
            Inst::Ld { .. } | Inst::St { .. } => cost(1, 1, FuKind::MemPort),
            Inst::Fop { op, .. } => match op {
                FpOp::FDiv => cost(self.fdiv, self.fdiv, FuKind::FpMulDiv),
                FpOp::FSqrt => cost(self.fsqrt, self.fsqrt, FuKind::FpMulDiv),
                FpOp::FMul => cost(self.fp, 1, FuKind::FpMulDiv),
                _ => cost(self.fp, 1, FuKind::FpAlu),
            },
            Inst::FCmpLt { .. } => cost(self.fp, 1, FuKind::FpAlu),
            Inst::CvtIF { .. } | Inst::CvtFI { .. } => cost(self.fp, 1, FuKind::FpAlu),
            Inst::Br { .. } | Inst::Jmp { .. } | Inst::PgasBrLoc { .. } => {
                cost(1, 1, FuKind::None)
            }
            // The contribution: 2-stage pipelined increment, 1/cycle.
            Inst::PgasIncI { .. } | Inst::PgasIncR { .. } => {
                cost(self.pgas_inc, 1, FuKind::PgasUnit)
            }
            // As fast as normal loads/stores; hierarchy time added on top.
            Inst::PgasLd { .. } | Inst::PgasSt { .. } => cost(1, 1, FuKind::MemPort),
            Inst::PgasSetThreads { .. } | Inst::PgasSetBase { .. } => {
                cost(1, 1, FuKind::PgasUnit)
            }
            Inst::Barrier | Inst::Halt | Inst::Nop => cost(1, 1, FuKind::None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::MemWidth;

    #[test]
    fn divide_dominates_software_increment() {
        let m = LatencyModel::default();
        let div = m.cost(&Inst::Opr { op: IntOp::Div, rd: 0, ra: 1, rb: 2 });
        let inc = m.cost(&Inst::PgasIncI { rd: 0, ra: 1, l2es: 2, l2bs: 2, l2inc: 0 });
        assert!(div.latency >= 10 * inc.init_interval);
        assert_eq!(inc.init_interval, 1, "pipelined unit: 1/cycle");
        assert_eq!(div.init_interval, div.latency, "div non-pipelined");
    }

    #[test]
    fn pgas_mem_costs_match_normal_mem() {
        let m = LatencyModel::default();
        let ld = m.cost(&Inst::Ld { w: MemWidth::U64, rd: 0, base: 1, disp: 0 });
        let pld = m.cost(&Inst::PgasLd { w: MemWidth::U64, rd: 0, rptr: 1, disp: 0 });
        assert_eq!(ld.latency, pld.latency);
        assert_eq!(ld.fu, FuKind::MemPort);
        assert_eq!(pld.fu, FuKind::MemPort);
    }

    #[test]
    fn wide_immediates_cost_a_pair() {
        let m = LatencyModel::default();
        assert_eq!(m.cost(&Inst::Ldi { rd: 0, imm: 4 }).latency, 1);
        assert_eq!(m.cost(&Inst::Ldi { rd: 0, imm: 1 << 40 }).latency, 2);
    }
}
