//! Binary encodings of the PGAS extension — the paper's Figure 3.
//!
//! ```text
//! loads/stores:   | opcode(6) | RA(5) | RB(5) | Func(4) | ShortDisp(12) |
//! increment imm:  | opcode(6) | RA(5) | RC(5) | Esize(5) | Bsize(5) | Increm(5) | 1 |
//! increment reg:  | opcode(6) | RA(5) | RC(5) | Esize(5) | Bsize(5) | RB(5)     | 0 |
//! init:           | opcode(6) | RA(5) | RB(5) | Func(4) | 0(12) |
//! ```
//!
//! `Esize`, `Bsize` and `Increm` are the 5-bit encodings of 32-bit values
//! with exactly one bit set (1, 2, 4, 8, …) — we store the set bit's
//! index.  The base ISA keeps no binary encoding (see module docs of
//! [`crate::isa`]): only the extension's formats are architecturally
//! specified by the paper.

use super::{Inst, MemWidth};

/// Free opcodes claimed from the Alpha opcode map (paper: "Opcode is a
/// free opcode from the Alpha instruction set").
pub const OP_PGAS_MEM: u32 = 0x1A;
pub const OP_PGAS_INC: u32 = 0x1B;
pub const OP_PGAS_SYS: u32 = 0x1C;

fn func_of(w: MemWidth, store: bool) -> u32 {
    let base = match w {
        MemWidth::U8 => 0,
        MemWidth::U16 => 1,
        MemWidth::U32 => 2,
        MemWidth::U64 => 3,
        MemWidth::F32 => 4,
        MemWidth::F64 => 5,
    };
    base | if store { 8 } else { 0 }
}

fn width_of(func: u32) -> Option<(MemWidth, bool)> {
    let store = func & 8 != 0;
    let w = match func & 7 {
        0 => MemWidth::U8,
        1 => MemWidth::U16,
        2 => MemWidth::U32,
        3 => MemWidth::U64,
        4 => MemWidth::F32,
        5 => MemWidth::F64,
        _ => return None,
    };
    Some((w, store))
}

/// Encode a PGAS-extension instruction to its 32-bit word.
/// Returns `None` for base-ISA and pseudo instructions.
pub fn encode(inst: &Inst) -> Option<u32> {
    Some(match *inst {
        Inst::PgasLd { w, rd, rptr, disp } => {
            let d12 = (disp as u32) & 0xFFF;
            (OP_PGAS_MEM << 26)
                | ((rd as u32) << 21)
                | ((rptr as u32) << 16)
                | (func_of(w, false) << 12)
                | d12
        }
        Inst::PgasSt { w, rs, rptr, disp } => {
            let d12 = (disp as u32) & 0xFFF;
            (OP_PGAS_MEM << 26)
                | ((rs as u32) << 21)
                | ((rptr as u32) << 16)
                | (func_of(w, true) << 12)
                | d12
        }
        Inst::PgasIncI { rd, ra, l2es, l2bs, l2inc } => {
            (OP_PGAS_INC << 26)
                | ((ra as u32) << 21)
                | ((rd as u32) << 16)
                | ((l2es as u32) << 11)
                | ((l2bs as u32) << 6)
                | ((l2inc as u32) << 1)
                | 1
        }
        Inst::PgasIncR { rd, ra, rb, l2es, l2bs } => {
            (OP_PGAS_INC << 26)
                | ((ra as u32) << 21)
                | ((rd as u32) << 16)
                | ((l2es as u32) << 11)
                | ((l2bs as u32) << 6)
                | ((rb as u32) << 1)
        }
        Inst::PgasSetThreads { ra } => {
            (OP_PGAS_SYS << 26) | ((ra as u32) << 21) | (0 << 12)
        }
        Inst::PgasSetBase { rthread, raddr } => {
            (OP_PGAS_SYS << 26)
                | ((rthread as u32) << 21)
                | ((raddr as u32) << 16)
                | (1 << 12)
        }
        Inst::PgasBrLoc { mask, target } => {
            // branch-on-locality: RA field carries the 4-bit mask; the
            // 12-bit field carries a (word) displacement — encoded here
            // as an absolute index for simulator simplicity, asserted to
            // fit (real hardware would use pc-relative displacement).
            assert!(target < (1 << 12), "brloc target too far to encode");
            (OP_PGAS_SYS << 26) | (((mask & 0xF) as u32) << 21) | (2 << 12) | target
        }
        _ => return None,
    })
}

/// Decode a 32-bit word into a PGAS-extension instruction.
pub fn decode(word: u32) -> Option<Inst> {
    let opcode = word >> 26;
    match opcode {
        OP_PGAS_MEM => {
            let ra = ((word >> 21) & 31) as u8;
            let rb = ((word >> 16) & 31) as u8;
            let func = (word >> 12) & 0xF;
            let disp = ((word & 0xFFF) as i16) << 4 >> 4; // sign-extend 12
            let (w, store) = width_of(func)?;
            Some(if store {
                Inst::PgasSt { w, rs: ra, rptr: rb, disp }
            } else {
                Inst::PgasLd { w, rd: ra, rptr: rb, disp }
            })
        }
        OP_PGAS_INC => {
            let ra = ((word >> 21) & 31) as u8;
            let rc = ((word >> 16) & 31) as u8;
            let l2es = ((word >> 11) & 31) as u8;
            let l2bs = ((word >> 6) & 31) as u8;
            let last = ((word >> 1) & 31) as u8;
            if word & 1 == 1 {
                Some(Inst::PgasIncI { rd: rc, ra, l2es, l2bs, l2inc: last })
            } else {
                Some(Inst::PgasIncR { rd: rc, ra, rb: last, l2es, l2bs })
            }
        }
        OP_PGAS_SYS => {
            let ra = ((word >> 21) & 31) as u8;
            let rb = ((word >> 16) & 31) as u8;
            match (word >> 12) & 0xF {
                0 => Some(Inst::PgasSetThreads { ra }),
                1 => Some(Inst::PgasSetBase { rthread: ra, raddr: rb }),
                2 => Some(Inst::PgasBrLoc {
                    mask: (ra & 0xF),
                    target: word & 0xFFF,
                }),
                _ => None,
            }
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Inst, MemWidth};
    use crate::util::testkit::check_default;

    #[test]
    fn roundtrip_all_load_store_widths() {
        for w in MemWidth::ALL {
            for (store, disp) in [(false, 0i16), (true, 40), (false, -8), (true, 2047)] {
                let inst = if store {
                    Inst::PgasSt { w, rs: 7, rptr: 12, disp }
                } else {
                    Inst::PgasLd { w, rd: 7, rptr: 12, disp }
                };
                let word = encode(&inst).unwrap();
                assert_eq!(decode(word), Some(inst), "{inst}");
            }
        }
    }

    #[test]
    fn roundtrip_random_pgas_insts() {
        check_default("encode/decode roundtrip", |rng| {
            let inst = match rng.below(6) {
                0 => Inst::PgasLd {
                    w: *rng.pick(&MemWidth::ALL),
                    rd: rng.below(32) as u8,
                    rptr: rng.below(32) as u8,
                    disp: rng.range(-2048, 2048) as i16,
                },
                1 => Inst::PgasSt {
                    w: *rng.pick(&MemWidth::ALL),
                    rs: rng.below(32) as u8,
                    rptr: rng.below(32) as u8,
                    disp: rng.range(-2048, 2048) as i16,
                },
                2 => Inst::PgasIncI {
                    rd: rng.below(32) as u8,
                    ra: rng.below(32) as u8,
                    l2es: rng.below(32) as u8,
                    l2bs: rng.below(32) as u8,
                    l2inc: rng.below(32) as u8,
                },
                3 => Inst::PgasIncR {
                    rd: rng.below(32) as u8,
                    ra: rng.below(32) as u8,
                    rb: rng.below(32) as u8,
                    l2es: rng.below(32) as u8,
                    l2bs: rng.below(32) as u8,
                },
                4 => Inst::PgasSetThreads { ra: rng.below(32) as u8 },
                _ => Inst::PgasSetBase {
                    rthread: rng.below(32) as u8,
                    raddr: rng.below(32) as u8,
                },
            };
            let word = encode(&inst).expect("pgas inst encodes");
            assert_eq!(decode(word), Some(inst), "word={word:#010x}");
        });
    }

    #[test]
    fn base_isa_has_no_pgas_encoding() {
        assert_eq!(encode(&Inst::Nop), None);
        assert_eq!(
            encode(&Inst::Ld { w: MemWidth::U64, rd: 0, base: 1, disp: 0 }),
            None
        );
    }

    #[test]
    fn decode_rejects_foreign_opcodes() {
        assert_eq!(decode(0xFFFF_FFFF), None);
        assert_eq!(decode(0), None);
    }

    #[test]
    fn brloc_roundtrip() {
        let i = Inst::PgasBrLoc { mask: 0b1010, target: 33 };
        assert_eq!(decode(encode(&i).unwrap()), Some(i));
    }
}
