//! Abstract interpretation of the kernel IR: track every integer
//! register as an [`Affine`] expression (or a shared pointer with an
//! affine element index), walk the structured control flow, and record
//! one [`AccessSite`] per `SptrLd`/`SptrSt` with its index, enclosing
//! loop ranges, path constraints and barrier segment.
//!
//! Loops are analyzed with a *two-iteration induction probe*: the body
//! is walked twice from symbolic state (sites suppressed) and a
//! register qualifies as an induction variable only when both probe
//! iterations advance it by the same constant — which, for the IR's
//! affine update language, is sound (a delta that depends on any
//! modified register changes between the probes and disqualifies
//! itself).  Qualified registers are rebound to `entry + k·delta` over
//! a fresh loop counter before the recording pass; everything else
//! modified degrades to unknown (pointers keep their array, losing
//! only the index).

use crate::compiler::{IrModule, Op, Val};
use crate::isa::{Cond, IntOp};
use crate::upc::{ArrayId, UpcRuntime};

use super::footprint::{Affine, Constraint, Relation};
use super::phases::PhaseTracker;

/// Abstract value of one integer register.
#[derive(Clone, Debug, PartialEq)]
enum AbsVal {
    /// A tracked affine integer.
    Int(Affine),
    /// A pointer into `arr`; `idx` is the affine element index when it
    /// is still tracked (`None`: somewhere in `arr`).
    Ptr { arr: ArrayId, idx: Option<Affine> },
    /// The 0/1 result of an integer compare of `diff` against zero —
    /// kept symbolic so a later `If` on it recovers the relation.
    Cmp { diff: Affine, kind: CmpKind },
    /// Anything the analysis cannot model.
    Unknown,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum CmpKind {
    /// `diff == 0`
    Eq,
    /// `diff < 0` (signed)
    Lt,
}

/// One static shared-memory access: everything the race and bounds
/// checkers need to enumerate its per-thread element footprint.
#[derive(Clone, Debug)]
pub struct AccessSite {
    /// Target array.
    pub arr: ArrayId,
    /// Target array's name (for diagnostics).
    pub array: String,
    /// Target array's element count.
    pub nelems: u64,
    /// Is this a store?
    pub write: bool,
    /// Affine element index (displacement folded in), when tracked.
    pub index: Option<Affine>,
    /// Enclosing loop counters as `(var, trip)`.
    pub loops: Vec<(u32, u64)>,
    /// Path constraints the access executes under.
    pub constraints: Vec<Constraint>,
    /// Executed under at least one branch the analysis could not
    /// model — the enumerated footprint over-approximates, so the
    /// checkers must not promote findings on this site to ERROR.
    pub opaque: bool,
    /// Barrier segment the access falls into.
    pub seg: usize,
    /// Human-readable provenance (`store q at 4.for.2`).
    pub site: String,
}

/// Result of the dataflow pass over one kernel.
#[derive(Debug)]
pub struct AccessTrace {
    /// Every shared access in the kernel, in walk order.
    pub sites: Vec<AccessSite>,
    /// Segment tracker with loop wrap-around merges applied; its
    /// classes are the race checker's concurrency domains.
    pub tracker: PhaseTracker,
    /// Provenance of barriers reached under conditional control flow
    /// (a UPC consistency smell: threads may disagree on the barrier
    /// sequence).
    pub divergent_barriers: Vec<String>,
    /// Provenance of accesses through pointers the analysis lost
    /// track of entirely (no array attribution possible).
    pub untracked: Vec<String>,
}

/// Run the dataflow pass: walk `module` against `rt`'s array
/// directory with `rt.numthreads` as the concrete `THREADS`.
pub fn trace(module: &IrModule, rt: &UpcRuntime) -> AccessTrace {
    let mut interp = Interp {
        rt,
        threads: i64::from(rt.numthreads),
        regs: vec![AbsVal::Unknown; 32],
        loops: Vec::new(),
        constraints: Vec::new(),
        opaque: 0,
        branch_depth: 0,
        recording: true,
        next_var: 0,
        tracker: PhaseTracker::new(),
        sites: Vec::new(),
        divergent: Vec::new(),
        untracked: Vec::new(),
    };
    interp.walk(&module.ops, "");
    AccessTrace {
        sites: interp.sites,
        tracker: interp.tracker,
        divergent_barriers: interp.divergent,
        untracked: interp.untracked,
    }
}

/// How an `If` branch constrains the state.
enum BranchGuard {
    /// The branch adds this constraint.
    C(Constraint),
    /// The branch is always taken when reached — no information.
    Trivial,
    /// The condition register is unknown: walk the branch opaque.
    Opaque,
    /// The branch is statically unreachable.
    Dead,
}

/// Loop-register classification from the induction probe.
#[derive(Clone, Debug, PartialEq)]
enum LoopCls {
    /// Not modified by the body.
    Keep,
    /// Integer induction: advances by a constant per iteration.
    IndInt(i64),
    /// Pointer induction into `arr`: index advances by a constant.
    IndPtr(ArrayId, i64),
    /// Stays a pointer into `arr` but the index is not inductive.
    StickyPtr(ArrayId),
    /// Anything else modified.
    Clobbered,
}

struct Interp<'a> {
    rt: &'a UpcRuntime,
    threads: i64,
    regs: Vec<AbsVal>,
    loops: Vec<(u32, u64)>,
    constraints: Vec<Constraint>,
    opaque: u32,
    branch_depth: u32,
    recording: bool,
    next_var: u32,
    tracker: PhaseTracker,
    sites: Vec<AccessSite>,
    divergent: Vec<String>,
    untracked: Vec<String>,
}

impl<'a> Interp<'a> {
    fn fresh_var(&mut self) -> u32 {
        let v = self.next_var;
        self.next_var += 1;
        v
    }

    fn val_abs(&self, v: Val) -> AbsVal {
        match v {
            Val::I(c) => AbsVal::Int(Affine::konst(c)),
            Val::R(r) => self.regs[r as usize].clone(),
        }
    }

    fn val_affine(&self, v: Val) -> Option<Affine> {
        match self.val_abs(v) {
            AbsVal::Int(a) => Some(a),
            _ => None,
        }
    }

    fn walk(&mut self, ops: &[Op], path: &str) {
        for (k, op) in ops.iter().enumerate() {
            let here = format!("{path}{k}");
            self.step(op, &here);
        }
    }

    fn step(&mut self, op: &Op, here: &str) {
        match op {
            Op::Bin { op, d, a, b } => {
                let av = self.regs[*a as usize].clone();
                let bv = self.val_abs(*b);
                self.regs[*d as usize] = eval_bin(*op, &av, &bv);
            }
            Op::Mov { d, v } => {
                self.regs[*d as usize] = self.val_abs(*v);
            }
            Op::FBin { .. } | Op::FConst { .. } | Op::CvtIF { .. } | Op::St { .. } => {}
            Op::FCmpLt { d, .. } | Op::CvtFI { d, .. } => {
                self.regs[*d as usize] = AbsVal::Unknown;
            }
            Op::MyThread { d } => {
                self.regs[*d as usize] = AbsVal::Int(Affine::mythread());
            }
            Op::Threads { d } => {
                self.regs[*d as usize] = AbsVal::Int(Affine::konst(self.threads));
            }
            Op::PrivBase { d } | Op::LocalAddr { d, .. } => {
                self.regs[*d as usize] = AbsVal::Unknown;
            }
            Op::Ld { w, d, .. } => {
                if !w.is_float() {
                    self.regs[*d as usize] = AbsVal::Unknown;
                }
            }
            Op::SptrInit { d, arr, idx } => {
                let idx = self.val_affine(*idx);
                self.regs[*d as usize] = AbsVal::Ptr { arr: *arr, idx };
            }
            Op::SptrInc { p, arr, inc } => {
                let inc_a = self.val_affine(*inc);
                let new_idx = match (&self.regs[*p as usize], inc_a) {
                    (AbsVal::Ptr { idx: Some(x), .. }, Some(i)) => Some(x.add(&i)),
                    _ => None,
                };
                self.regs[*p as usize] = AbsVal::Ptr { arr: *arr, idx: new_idx };
            }
            Op::SptrAt { d, base, arr, idx } => {
                let base_idx = match &self.regs[*base as usize] {
                    AbsVal::Ptr { arr: ba, idx: Some(x) } if ba == arr => Some(x.clone()),
                    _ => None,
                };
                let idx_a = self.val_affine(*idx);
                let combined = match (base_idx, idx_a) {
                    (Some(b), Some(i)) => Some(b.add(&i)),
                    _ => None,
                };
                self.regs[*d as usize] = AbsVal::Ptr { arr: *arr, idx: combined };
            }
            Op::SptrLd { w, d, p, disp } => {
                self.record(*p, *disp, false, here);
                if !w.is_float() {
                    self.regs[*d as usize] = AbsVal::Unknown;
                }
            }
            Op::SptrSt { p, disp, .. } => {
                self.record(*p, *disp, true, here);
            }
            Op::Barrier => {
                if self.recording {
                    if self.branch_depth > 0 {
                        self.divergent.push(format!("barrier at {here}"));
                    }
                    self.tracker.barrier();
                }
            }
            Op::If { cond, r, then, els } => {
                self.do_if(*cond, *r, then, els, here);
            }
            Op::For { i, from, to, step, body } => {
                self.do_for(*i, *from, *to, *step, body, here);
            }
            Op::DoWhile { body, .. } => {
                self.loop_unknown_trip(None, body, &format!("{here}.do."));
            }
        }
    }

    // ---------------- branches ----------------

    fn do_if(&mut self, cond: Cond, r: u8, then: &[Op], els: &[Op], here: &str) {
        let rv = self.regs[r as usize].clone();
        let g_then = guard_of(cond, &rv, true);
        let g_else = guard_of(cond, &rv, false);
        let entry = self.regs.clone();
        let then_regs =
            self.walk_branch(&g_then, then, &format!("{here}.then."));
        self.regs = entry.clone();
        let else_regs =
            self.walk_branch(&g_else, els, &format!("{here}.else."));
        self.regs = match (then_regs, else_regs) {
            (Some(t), Some(e)) => merge_regs(&t, &e),
            (Some(t), None) => t,
            (None, Some(e)) => e,
            (None, None) => entry,
        };
    }

    /// Walk one branch under its guard; returns the exit register
    /// state, or `None` for a statically dead branch.
    fn walk_branch(
        &mut self,
        g: &BranchGuard,
        body: &[Op],
        path: &str,
    ) -> Option<Vec<AbsVal>> {
        match g {
            BranchGuard::Dead => None,
            BranchGuard::Trivial => {
                self.branch_depth += 1;
                self.walk(body, path);
                self.branch_depth -= 1;
                Some(self.regs.clone())
            }
            BranchGuard::C(c) => {
                self.constraints.push(c.clone());
                self.branch_depth += 1;
                self.walk(body, path);
                self.branch_depth -= 1;
                self.constraints.pop();
                Some(self.regs.clone())
            }
            BranchGuard::Opaque => {
                self.opaque += 1;
                self.branch_depth += 1;
                self.walk(body, path);
                self.branch_depth -= 1;
                self.opaque -= 1;
                Some(self.regs.clone())
            }
        }
    }

    // ---------------- loops ----------------

    /// Run the two-iteration induction probe over `body` (sites
    /// suppressed) and classify every register.  `i_sym`: the `For`
    /// counter register bound to a fresh symbol during the probe.
    fn probe_loop(&mut self, i_sym: Option<u8>, body: &[Op], path: &str) -> Vec<LoopCls> {
        let entry = self.regs.clone();
        let saved_rec = self.recording;
        self.recording = false;
        let sym = self.fresh_var();
        if let Some(i) = i_sym {
            self.regs[i as usize] = AbsVal::Int(Affine::var(sym));
        }
        self.walk(body, path);
        let s1 = self.regs.clone();
        if let Some(i) = i_sym {
            self.regs[i as usize] = AbsVal::Int(Affine::var(sym));
        }
        self.walk(body, path);
        let s2 = self.regs.clone();
        self.recording = saved_rec;
        self.regs = entry.clone();
        (0..32)
            .map(|r| {
                if Some(r as u8) == i_sym {
                    return LoopCls::Clobbered; // rebound by the caller
                }
                classify_reg(&entry[r], &s1[r], &s2[r])
            })
            .collect()
    }

    fn do_for(&mut self, i: u8, from: Val, to: Val, step: i64, body: &[Op], here: &str) {
        let from_a = self.val_affine(from);
        let to_a = self.val_affine(to);
        // trip count: known iff (to - from) is a constant (register
        // bounds like IS's `kstart = MYTHREAD*kb, kend = kstart + kb`
        // still qualify: the difference cancels the symbolic part)
        let trip = match (&from_a, &to_a) {
            (Some(f), Some(t)) if step > 0 => {
                t.sub(f).as_const().map(|span| {
                    if span <= 0 {
                        0
                    } else {
                        (span as u64).div_ceil(step as u64)
                    }
                })
            }
            _ => None,
        };
        if trip == Some(0) {
            self.regs[i as usize] = AbsVal::Unknown;
            return;
        }
        let path = format!("{here}.for.");
        let cls = self.probe_loop(Some(i), body, &path);
        match trip {
            Some(n) => {
                let entry = self.regs.clone();
                let kv = self.fresh_var();
                self.rebind(&cls, &entry, Some(kv));
                // from_a is Some whenever trip is Some
                let from_a = from_a.expect("trip known implies affine bounds");
                self.regs[i as usize] =
                    AbsVal::Int(from_a.add(&Affine::var(kv).scale(step)));
                self.loops.push((kv, n));
                let entry_seg = self.tracker.current();
                self.walk(body, &path);
                self.loops.pop();
                if self.recording && self.tracker.current() != entry_seg {
                    self.tracker.loop_wrap(entry_seg);
                }
                self.bind_exit(&cls, &entry, n as i64);
                self.regs[i as usize] = AbsVal::Unknown;
            }
            None => {
                self.loop_unknown_trip(Some(i), body, &path);
            }
        }
    }

    /// A loop whose trip count is unknown (`DoWhile`, or a `For` with
    /// non-affine bounds): every modified register degrades to its
    /// sticky classification for both the body walk and the exit.
    fn loop_unknown_trip(&mut self, for_counter: Option<u8>, body: &[Op], path: &str) {
        let cls = self.probe_loop(for_counter, body, path);
        let entry = self.regs.clone();
        self.rebind(&cls, &entry, None);
        if let Some(i) = for_counter {
            self.regs[i as usize] = AbsVal::Unknown;
        }
        let entry_seg = self.tracker.current();
        self.walk(body, path);
        if self.recording && self.tracker.current() != entry_seg {
            self.tracker.loop_wrap(entry_seg);
        }
        // exit state: same sticky degradation (already in regs for
        // non-inductive classes; induction without a trip degrades too)
        self.rebind(&cls, &entry, None);
        if let Some(i) = for_counter {
            self.regs[i as usize] = AbsVal::Unknown;
        }
    }

    /// Rebind registers at loop entry for the recording pass.  With
    /// `kv = Some(v)` induction registers become `entry + k_v·delta`;
    /// without a counter (unknown trip) they degrade sticky.
    fn rebind(&mut self, cls: &[LoopCls], entry: &[AbsVal], kv: Option<u32>) {
        for r in 0..32 {
            self.regs[r] = match (&cls[r], kv) {
                (LoopCls::Keep, _) => entry[r].clone(),
                (LoopCls::IndInt(d), Some(v)) => match &entry[r] {
                    AbsVal::Int(a) => {
                        AbsVal::Int(a.add(&Affine::var(v).scale(*d)))
                    }
                    _ => AbsVal::Unknown,
                },
                (LoopCls::IndPtr(arr, d), Some(v)) => match &entry[r] {
                    AbsVal::Ptr { idx: Some(x), .. } => AbsVal::Ptr {
                        arr: *arr,
                        idx: Some(x.add(&Affine::var(v).scale(*d))),
                    },
                    _ => AbsVal::Ptr { arr: *arr, idx: None },
                },
                (LoopCls::IndInt(_), None) => AbsVal::Unknown,
                (LoopCls::IndPtr(arr, _), None)
                | (LoopCls::StickyPtr(arr), _) => {
                    AbsVal::Ptr { arr: *arr, idx: None }
                }
                (LoopCls::Clobbered, _) => AbsVal::Unknown,
            };
        }
    }

    /// Bind registers after a known-trip loop exits (`k = trip`).
    fn bind_exit(&mut self, cls: &[LoopCls], entry: &[AbsVal], trip: i64) {
        for r in 0..32 {
            self.regs[r] = match &cls[r] {
                LoopCls::Keep => entry[r].clone(),
                LoopCls::IndInt(d) => match &entry[r] {
                    AbsVal::Int(a) => AbsVal::Int(a.add_const(d * trip)),
                    _ => AbsVal::Unknown,
                },
                LoopCls::IndPtr(arr, d) => match &entry[r] {
                    AbsVal::Ptr { idx: Some(x), .. } => AbsVal::Ptr {
                        arr: *arr,
                        idx: Some(x.add_const(d * trip)),
                    },
                    _ => AbsVal::Ptr { arr: *arr, idx: None },
                },
                LoopCls::StickyPtr(arr) => {
                    AbsVal::Ptr { arr: *arr, idx: None }
                }
                LoopCls::Clobbered => AbsVal::Unknown,
            };
        }
    }

    // ---------------- access sites ----------------

    fn record(&mut self, p: u8, disp: i16, write: bool, here: &str) {
        if !self.recording {
            return;
        }
        let kind = if write { "store" } else { "load" };
        match self.regs[p as usize].clone() {
            AbsVal::Ptr { arr, idx } => {
                let sa = self.rt.array(arr);
                let es = sa.layout.elemsize as i64;
                let delem = i64::from(disp).div_euclid(es.max(1));
                let index = idx.map(|a| a.add_const(delem));
                let disp_s = if disp == 0 {
                    String::new()
                } else {
                    format!("{disp:+}B")
                };
                self.sites.push(AccessSite {
                    arr,
                    array: sa.name.clone(),
                    nelems: sa.nelems,
                    write,
                    index,
                    loops: self.loops.clone(),
                    constraints: self.constraints.clone(),
                    opaque: self.opaque > 0,
                    seg: self.tracker.current(),
                    site: format!("{kind} {}{disp_s} at {here}", sa.name),
                });
            }
            _ => self.untracked.push(format!(
                "{kind} through r{p} at {here} (pointer not statically tracked)"
            )),
        }
    }
}

/// Evaluate one integer ALU op over abstract operands.
fn eval_bin(op: IntOp, a: &AbsVal, b: &AbsVal) -> AbsVal {
    let (aa, ba) = match (a, b) {
        (AbsVal::Int(x), AbsVal::Int(y)) => (x, y),
        _ => return AbsVal::Unknown,
    };
    match op {
        IntOp::Add => AbsVal::Int(aa.add(ba)),
        IntOp::Sub => AbsVal::Int(aa.sub(ba)),
        IntOp::Mul => {
            if let Some(c) = aa.as_const() {
                AbsVal::Int(ba.scale(c))
            } else if let Some(c) = ba.as_const() {
                AbsVal::Int(aa.scale(c))
            } else {
                AbsVal::Unknown
            }
        }
        IntOp::Sll => match ba.as_const() {
            Some(c) if (0..63).contains(&c) => AbsVal::Int(aa.scale(1i64 << c)),
            _ => AbsVal::Unknown,
        },
        IntOp::CmpEq => AbsVal::Cmp { diff: aa.sub(ba), kind: CmpKind::Eq },
        IntOp::CmpLt => AbsVal::Cmp { diff: aa.sub(ba), kind: CmpKind::Lt },
        _ => match (aa.as_const(), ba.as_const()) {
            (Some(x), Some(y)) => fold_const(op, x, y)
                .map_or(AbsVal::Unknown, |v| AbsVal::Int(Affine::konst(v))),
            _ => AbsVal::Unknown,
        },
    }
}

/// Concrete fold of the remaining integer ops on two constants.
fn fold_const(op: IntOp, x: i64, y: i64) -> Option<i64> {
    Some(match op {
        IntOp::Div => {
            if y == 0 {
                return None;
            }
            x.wrapping_div(y)
        }
        IntOp::Rem => {
            if y == 0 {
                return None;
            }
            x.wrapping_rem(y)
        }
        IntOp::And => x & y,
        IntOp::Or => x | y,
        IntOp::Xor => x ^ y,
        IntOp::Srl => {
            if !(0..64).contains(&y) {
                return None;
            }
            ((x as u64) >> y) as i64
        }
        IntOp::Sra => {
            if !(0..64).contains(&y) {
                return None;
            }
            x >> y
        }
        IntOp::CmpLtU => i64::from((x as u64) < (y as u64)),
        IntOp::CmpLe => i64::from(x <= y),
        // handled symbolically above
        IntOp::Add
        | IntOp::Sub
        | IntOp::Mul
        | IntOp::Sll
        | IntOp::CmpEq
        | IntOp::CmpLt => return None,
    })
}

/// Classify one register across the two probe iterations.
fn classify_reg(entry: &AbsVal, s1: &AbsVal, s2: &AbsVal) -> LoopCls {
    if s1 == entry && s2 == entry {
        return LoopCls::Keep;
    }
    // integer induction: both iterations advance by the same constant
    if let (AbsVal::Int(a0), AbsVal::Int(a1), AbsVal::Int(a2)) = (entry, s1, s2) {
        if let (Some(d1), Some(d2)) =
            (a1.sub(a0).as_const(), a2.sub(a1).as_const())
        {
            if d1 == d2 {
                return LoopCls::IndInt(d1);
            }
        }
        return LoopCls::Clobbered;
    }
    // pointer induction / sticky pointer: array must agree throughout
    if let (
        AbsVal::Ptr { arr: r0, idx: i0 },
        AbsVal::Ptr { arr: r1, idx: i1 },
        AbsVal::Ptr { arr: r2, idx: i2 },
    ) = (entry, s1, s2)
    {
        if r0 == r1 && r1 == r2 {
            if let (Some(x0), Some(x1), Some(x2)) = (i0, i1, i2) {
                if let (Some(d1), Some(d2)) =
                    (x1.sub(x0).as_const(), x2.sub(x1).as_const())
                {
                    if d1 == d2 {
                        return LoopCls::IndPtr(*r0, d1);
                    }
                }
            }
            return LoopCls::StickyPtr(*r0);
        }
    }
    LoopCls::Clobbered
}

/// Join the register states of two merging branches.
fn merge_regs(a: &[AbsVal], b: &[AbsVal]) -> Vec<AbsVal> {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| {
            if x == y {
                return x.clone();
            }
            match (x, y) {
                (
                    AbsVal::Ptr { arr: ax, .. },
                    AbsVal::Ptr { arr: ay, .. },
                ) if ax == ay => AbsVal::Ptr { arr: *ax, idx: None },
                _ => AbsVal::Unknown,
            }
        })
        .collect()
}

/// Constraint the `then`/`else` side of `If(cond, r)` adds, given the
/// abstract value of `r`.  The lowering branches on `negate(cond)`,
/// i.e. the `then` body runs exactly when `r cond 0` holds.
fn guard_of(cond: Cond, rv: &AbsVal, then_side: bool) -> BranchGuard {
    match rv {
        AbsVal::Int(a) => {
            let rel = match (cond, then_side) {
                (Cond::Eq, true) => Relation::Zero,
                (Cond::Eq, false) => Relation::NonZero,
                (Cond::Ne, true) => Relation::NonZero,
                (Cond::Ne, false) => Relation::Zero,
                (Cond::Lt, true) => Relation::Neg,
                (Cond::Lt, false) => Relation::NonNeg,
                (Cond::Ge, true) => Relation::NonNeg,
                (Cond::Ge, false) => Relation::Neg,
                (Cond::Le, true) => Relation::NonPos,
                (Cond::Le, false) => Relation::Pos,
                (Cond::Gt, true) => Relation::Pos,
                (Cond::Gt, false) => Relation::NonPos,
            };
            BranchGuard::C(Constraint { expr: a.clone(), rel })
        }
        AbsVal::Cmp { diff, kind } => {
            // r is the 0/1 truth value of (diff kindOp 0); `cond`
            // compares that truth value against zero.
            let truth_when_taken = match cond {
                Cond::Ne | Cond::Gt => true,  // r != 0  <=>  true
                Cond::Eq | Cond::Le => false, // r == 0  <=>  false
                // r in {0,1}: `r < 0` never holds, `r >= 0` always
                Cond::Lt if then_side => return BranchGuard::Dead,
                Cond::Lt => return BranchGuard::Trivial,
                Cond::Ge if then_side => return BranchGuard::Trivial,
                Cond::Ge => return BranchGuard::Dead,
            };
            let truth_required =
                if then_side { truth_when_taken } else { !truth_when_taken };
            let rel = match (kind, truth_required) {
                (CmpKind::Eq, true) => Relation::Zero,
                (CmpKind::Eq, false) => Relation::NonZero,
                (CmpKind::Lt, true) => Relation::Neg,
                (CmpKind::Lt, false) => Relation::NonNeg,
            };
            BranchGuard::C(Constraint { expr: diff.clone(), rel })
        }
        _ => BranchGuard::Opaque,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::IrBuilder;
    use crate::isa::MemWidth;
    use crate::upc::UpcRuntime;

    use super::super::footprint::enumerate_for_thread;

    fn fp(site: &AccessSite, myt: i64) -> Vec<i64> {
        enumerate_for_thread(
            site.index.as_ref().expect("tracked index"),
            &site.loops,
            &site.constraints,
            myt,
        )
        .expect("under cap")
        .into_iter()
        .collect()
    }

    #[test]
    fn strided_cursor_walk_is_affine() {
        let mut rt = UpcRuntime::new(4);
        let a = rt.alloc_shared("a", 1, 8, 64);
        let module = {
            let mut b = IrBuilder::new(&mut rt);
            let myt = b.mythread();
            let nt = b.threads();
            let v = b.iconst(1);
            let p = b.sptr_init(a, Val::R(myt));
            b.for_range(Val::I(0), Val::I(16), 1, |b, _i| {
                b.sptr_st(MemWidth::U64, v, p, 0);
                b.sptr_inc(p, a, Val::R(nt));
            });
            b.finish("strided")
        };
        let tr = trace(&module, &rt);
        assert_eq!(tr.sites.len(), 1);
        let s = &tr.sites[0];
        assert!(s.write && !s.opaque);
        assert_eq!(s.seg, 0);
        // thread 2 touches 2, 6, 10, ..., 62
        let set = fp(s, 2);
        assert_eq!(set.len(), 16);
        assert_eq!(set[0], 2);
        assert_eq!(set[15], 62);
    }

    #[test]
    fn guards_and_register_bounds_are_tracked() {
        let mut rt = UpcRuntime::new(4);
        let a = rt.alloc_shared("a", 4, 8, 64);
        let module = {
            let mut b = IrBuilder::new(&mut rt);
            let myt = b.mythread();
            // for k in myt*4 .. myt*4+4 under an `if (myt == 0)` guard
            let lo = b.it();
            b.bin(IntOp::Mul, lo, myt, Val::I(4));
            let hi = b.it();
            b.bin(IntOp::Add, hi, lo, Val::I(4));
            b.iff(Cond::Eq, myt, |b| {
                b.for_range(Val::R(lo), Val::R(hi), 1, |b, i| {
                    let p = b.sptr_init(a, Val::I(0));
                    b.sptr_inc(p, a, Val::R(i));
                    let t = b.it();
                    b.sptr_ld(MemWidth::U64, t, p, 0);
                    b.free_i(t);
                    b.free_i(p);
                });
            });
            b.finish("guarded")
        };
        let tr = trace(&module, &rt);
        assert_eq!(tr.sites.len(), 1);
        let s = &tr.sites[0];
        assert!(!s.write && !s.opaque);
        assert_eq!(s.constraints.len(), 1);
        // thread 0 reads 0..4; other threads are excluded by the guard
        assert_eq!(fp(s, 0), vec![0, 1, 2, 3]);
        assert!(fp(s, 1).is_empty());
    }

    #[test]
    fn barrier_bearing_loop_wraps_phases() {
        let mut rt = UpcRuntime::new(2);
        let a = rt.alloc_shared("a", 4, 8, 16);
        let module = {
            let mut b = IrBuilder::new(&mut rt);
            let myt = b.mythread();
            let v = b.iconst(3);
            b.for_range(Val::I(0), Val::I(3), 1, |b, _i| {
                let p = b.sptr_init(a, Val::R(myt));
                b.sptr_st(MemWidth::U64, v, p, 0);
                b.barrier();
                let t = b.it();
                b.sptr_ld(MemWidth::U64, t, p, 0);
                b.free_i(t);
                b.free_i(p);
            });
            b.finish("wrapped")
        };
        let tr = trace(&module, &rt);
        assert_eq!(tr.sites.len(), 2);
        let (w, r) = (&tr.sites[0], &tr.sites[1]);
        assert_ne!(w.seg, r.seg);
        // the wrap-around makes the post-barrier tail concurrent with
        // the next iteration's pre-barrier head
        assert_eq!(tr.tracker.find(w.seg), tr.tracker.find(r.seg));
        assert!(tr.divergent_barriers.is_empty());
    }

    #[test]
    fn non_inductive_update_degrades_soundly() {
        let mut rt = UpcRuntime::new(2);
        let a = rt.alloc_shared("a", 4, 8, 16);
        let module = {
            let mut b = IrBuilder::new(&mut rt);
            let acc = b.iconst(0);
            let stride = b.iconst(1);
            let p = b.sptr_init(a, Val::I(0));
            b.for_range(Val::I(0), Val::I(4), 1, |b, _i| {
                // acc += stride; stride += 1  — quadratic, not affine
                b.add(acc, acc, Val::R(stride));
                b.add(stride, stride, Val::I(1));
                b.sptr_inc(p, a, Val::R(acc));
                let t = b.it();
                b.sptr_ld(MemWidth::U64, t, p, 0);
                b.free_i(t);
            });
            b.finish("quad")
        };
        let tr = trace(&module, &rt);
        assert_eq!(tr.sites.len(), 1);
        // the cursor advanced by a non-constant stride: the analysis
        // must keep the array but drop the index
        assert!(tr.sites[0].index.is_none());
        assert_eq!(tr.sites[0].array, "a");
    }
}
