//! Static PGAS access analyzer (`pgas-hw lint`).
//!
//! Three cooperating compile-time analyses over the kernel IR and its
//! lowered [`Program`](crate::isa::Program):
//!
//! 1. **Barrier-phase race detector** — [`phases`] splits the kernel
//!    into barrier-delimited segments (loop wrap-around merges
//!    segments a back edge makes concurrent again), [`dataflow`]
//!    computes each shared access's symbolic footprint as an affine
//!    stride set over `MYTHREAD` and loop counters, and
//!    [`footprint::enumerate_for_thread`] evaluates the exact
//!    per-thread element sets so cross-thread write/write and
//!    read/write overlaps inside one phase become ERROR diagnostics
//!    with access-site provenance.
//! 2. **Shared-bounds checker** — the static twin of
//!    [`SharedArray::ptr`](crate::upc::SharedArray::ptr)'s runtime
//!    debug assertion: every tracked footprint must stay inside
//!    `[0, nelems)`; unprovable sites WARN instead of erroring.
//! 3. **Batchability / engine-mix predictor** — [`predict`] replays
//!    the pipeline's own [`plan_window`](crate::cpu::pipeline::plan_window)
//!    eligibility over the lowered instruction stream and predicts the
//!    kernel's [`EngineMix`](crate::cpu::pipeline::EngineMix)
//!    categories (batched / scalar / gather), which the differential
//!    suite checks against runtime telemetry.
//!
//! The analyses are *sound where they claim to be*: an ERROR is backed
//! by a concrete witness (element, thread pair, phase); anything the
//! abstraction loses — data-dependent indices, over-cap enumerations,
//! opaque branches — degrades to a WARN, never a guess.

pub mod dataflow;
pub mod fixtures;
pub mod footprint;
pub mod phases;
pub mod predict;

use std::collections::BTreeMap;
use std::collections::BTreeSet;
use std::fmt;

use crate::compiler::{compile, CompileOpts, IrModule, Lowering, SourceVariant};
use crate::npb::{self, Kernel, Scale};
use crate::upc::UpcRuntime;

use dataflow::{AccessSite, AccessTrace};
use footprint::enumerate_for_thread;
use predict::PredictedMix;

/// Diagnostic severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// A proven defect with a concrete witness.
    Error,
    /// Something the analysis could not prove safe.
    Warn,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Error => write!(f, "ERROR"),
            Severity::Warn => write!(f, "WARN"),
        }
    }
}

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// ERROR (witnessed) or WARN (unprovable).
    pub severity: Severity,
    /// Stable machine code, e.g. `race/ww`, `bounds/oob`.
    pub code: &'static str,
    /// Concurrency-phase class the finding lives in.
    pub phase: usize,
    /// Array involved (empty for non-array findings).
    pub array: String,
    /// Human-readable explanation with the witness when there is one.
    pub message: String,
    /// Access-site provenance strings.
    pub sites: Vec<String>,
}

/// Full lint result for one kernel.
#[derive(Debug)]
pub struct LintReport {
    /// Kernel name.
    pub kernel: String,
    /// Thread count the footprints were enumerated for.
    pub threads: u32,
    /// Concurrency-phase classes after loop wrap-around merging.
    pub phases: usize,
    /// Shared access sites the dataflow pass recorded.
    pub sites: usize,
    /// All findings, errors first.
    pub diagnostics: Vec<Diagnostic>,
    /// Static engine-mix prediction from the lowered program.
    pub predicted: PredictedMix,
}

impl LintReport {
    /// Number of ERROR diagnostics.
    pub fn errors(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    /// Number of WARN diagnostics.
    pub fn warnings(&self) -> usize {
        self.diagnostics.len() - self.errors()
    }

    /// Sorted, deduplicated diagnostic codes.
    pub fn codes(&self) -> Vec<&'static str> {
        let set: BTreeSet<&'static str> =
            self.diagnostics.iter().map(|d| d.code).collect();
        set.into_iter().collect()
    }

    /// One-line deterministic summary — the form the golden suite pins.
    pub fn summary_json(&self) -> String {
        let codes = self
            .codes()
            .iter()
            .map(|c| format!("\"{c}\""))
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"kernel\":\"{}\",\"threads\":{},\"errors\":{},\"warnings\":{},\
             \"codes\":[{}],\"batched\":{},\"scalar\":{},\"gather\":{}}}",
            json_escape(&self.kernel),
            self.threads,
            self.errors(),
            self.warnings(),
            codes,
            self.predicted.batched(),
            self.predicted.scalar(),
            self.predicted.gather(),
        )
    }

    /// Full JSON object: summary fields plus per-diagnostic detail and
    /// the raw prediction counters.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!(
            "\"kernel\":\"{}\",\"threads\":{},\"phases\":{},\"sites\":{},",
            json_escape(&self.kernel),
            self.threads,
            self.phases,
            self.sites
        ));
        let p = &self.predicted;
        out.push_str(&format!(
            "\"predicted\":{{\"windows\":{},\"batchable_incs\":{},\
             \"scalar_incs\":{},\"gather_windows\":{},\"batched\":{},\
             \"scalar\":{},\"gather\":{},\"hw_incs\":{},\"soft_incs\":{},\
             \"hw_mems\":{},\"soft_mems\":{},\"insts\":{}}},",
            p.windows,
            p.batchable_incs,
            p.scalar_incs,
            p.gather_windows,
            p.batched(),
            p.scalar(),
            p.gather(),
            p.stats.hw_incs,
            p.stats.soft_incs,
            p.stats.hw_mems,
            p.stats.soft_mems,
            p.stats.insts
        ));
        out.push_str("\"diagnostics\":[");
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let sites = d
                .sites
                .iter()
                .map(|s| format!("\"{}\"", json_escape(s)))
                .collect::<Vec<_>>()
                .join(",");
            out.push_str(&format!(
                "{{\"severity\":\"{}\",\"code\":\"{}\",\"phase\":{},\
                 \"array\":\"{}\",\"message\":\"{}\",\"sites\":[{}]}}",
                d.severity,
                d.code,
                d.phase,
                json_escape(&d.array),
                json_escape(&d.message),
                sites
            ));
        }
        out.push_str("]}");
        out
    }
}

/// Escape a string for inclusion in a JSON literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Lint one IR module against its runtime: dataflow, race and bounds
/// checks, plus the engine-mix prediction from an `Hw` lowering (the
/// variant the paper's hardware runs use, `volatile_stores` on to
/// match the prototype compiler).
pub fn lint_ir(name: &str, rt: &UpcRuntime, module: &IrModule) -> LintReport {
    let tr = dataflow::trace(module, rt);
    let (classes, nclasses) = tr.tracker.classes();
    let mut diagnostics = Vec::new();
    race_check(&tr, &classes, rt, &mut diagnostics);
    bounds_check(&tr, &classes, rt, &mut diagnostics);
    if !tr.divergent_barriers.is_empty() {
        diagnostics.push(Diagnostic {
            severity: Severity::Warn,
            code: "barrier/divergent",
            phase: 0,
            array: String::new(),
            message: "barrier under conditional control flow: threads may \
                      disagree on the barrier sequence"
                .to_string(),
            sites: tr.divergent_barriers.clone(),
        });
    }
    if !tr.untracked.is_empty() {
        diagnostics.push(Diagnostic {
            severity: Severity::Warn,
            code: "ptr/untracked",
            phase: 0,
            array: String::new(),
            message: "shared accesses through pointers the dataflow pass \
                      lost track of (no array attribution)"
                .to_string(),
            sites: tr.untracked.clone(),
        });
    }
    diagnostics.sort_by_key(|d| d.severity);
    let opts = CompileOpts {
        lowering: Lowering::Hw,
        static_threads: false,
        numthreads: rt.numthreads,
        volatile_stores: true,
    };
    let compiled = compile(module, rt, &opts);
    let predicted = predict::predict(&compiled.program, &compiled.stats);
    LintReport {
        kernel: name.to_string(),
        threads: rt.numthreads,
        phases: nclasses,
        sites: tr.sites.len(),
        diagnostics,
        predicted,
    }
}

/// Build and lint one NPB kernel (unoptimized source — the variant the
/// hardware lowering consumes).
pub fn lint_kernel(kernel: Kernel, threads: u32, scale: &Scale) -> LintReport {
    let built = npb::build(kernel, threads, SourceVariant::Unoptimized, scale);
    lint_ir(kernel.name(), &built.rt, &built.module)
}

/// Lint one fixture kernel by name.
pub fn lint_fixture(name: &str, threads: u32) -> Option<LintReport> {
    let fx = fixtures::by_name(name, threads)?;
    Some(lint_ir(fx.name, &fx.rt, &fx.module))
}

/// Per-thread footprints of one site, one entry per thread; `None`
/// when the enumeration went over [`footprint::ENUM_CAP`].
type Footprints = Vec<Option<BTreeSet<i64>>>;

fn site_footprints(site: &AccessSite, threads: u32) -> Footprints {
    (0..threads)
        .map(|t| {
            site.index.as_ref().and_then(|idx| {
                enumerate_for_thread(
                    idx,
                    &site.loops,
                    &site.constraints,
                    i64::from(t),
                )
            })
        })
        .collect()
}

/// Cross-thread race detection inside each concurrency-phase class.
fn race_check(
    tr: &AccessTrace,
    classes: &[usize],
    rt: &UpcRuntime,
    out: &mut Vec<Diagnostic>,
) {
    let threads = rt.numthreads;
    // group sites by (phase class, array)
    let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    for (i, s) in tr.sites.iter().enumerate() {
        groups.entry((classes[s.seg], s.arr.0)).or_default().push(i);
    }
    // footprint cache, computed lazily per site
    let mut cache: Vec<Option<Footprints>> = vec![None; tr.sites.len()];
    for (&(class, _), members) in &groups {
        let mut unprovable: BTreeSet<String> = BTreeSet::new();
        for (a_pos, &i) in members.iter().enumerate() {
            for &j in &members[a_pos..] {
                let (si, sj) = (&tr.sites[i], &tr.sites[j]);
                if !(si.write || sj.write) {
                    continue; // read/read never races
                }
                if i == j && !si.write {
                    continue;
                }
                let exact = si.index.is_some()
                    && sj.index.is_some()
                    && !si.opaque
                    && !sj.opaque;
                if !exact {
                    unprovable.insert(si.site.clone());
                    unprovable.insert(sj.site.clone());
                    continue;
                }
                if cache[i].is_none() {
                    cache[i] = Some(site_footprints(si, threads));
                }
                if cache[j].is_none() {
                    cache[j] = Some(site_footprints(sj, threads));
                }
                let (fi, fj) = (
                    cache[i].as_ref().expect("just filled"),
                    cache[j].as_ref().expect("just filled"),
                );
                if fi.iter().chain(fj.iter()).any(Option::is_none) {
                    unprovable.insert(si.site.clone());
                    unprovable.insert(sj.site.clone());
                    continue;
                }
                let witness = (0..threads).find_map(|t| {
                    (0..threads)
                        .filter(|&u| u != t)
                        .find_map(|u| {
                            let a = fi[t as usize].as_ref().expect("checked");
                            let b = fj[u as usize].as_ref().expect("checked");
                            a.intersection(b).next().map(|&e| (e, t, u))
                        })
                });
                if let Some((elem, t, u)) = witness {
                    let (code, what) = if si.write && sj.write {
                        ("race/ww", "both write")
                    } else {
                        ("race/rw", "read and write")
                    };
                    out.push(Diagnostic {
                        severity: Severity::Error,
                        code,
                        phase: class,
                        array: si.array.clone(),
                        message: format!(
                            "threads {t} and {u} {what} {}[{elem}] \
                             concurrently in phase {class} (no barrier \
                             between the accesses)",
                            si.array
                        ),
                        sites: if i == j {
                            vec![si.site.clone()]
                        } else {
                            vec![si.site.clone(), sj.site.clone()]
                        },
                    });
                }
            }
        }
        if !unprovable.is_empty() {
            let array = tr.sites[members[0]].array.clone();
            out.push(Diagnostic {
                severity: Severity::Warn,
                code: "race/unprovable",
                phase: class,
                array: array.clone(),
                message: format!(
                    "cannot prove phase-{class} accesses to {array} \
                     race-free (data-dependent or over-cap indices)"
                ),
                sites: unprovable.into_iter().collect(),
            });
        }
    }
}

/// Static bounds check: every tracked footprint stays in `[0, nelems)`.
fn bounds_check(
    tr: &AccessTrace,
    classes: &[usize],
    rt: &UpcRuntime,
    out: &mut Vec<Diagnostic>,
) {
    let threads = rt.numthreads;
    for s in &tr.sites {
        let class = classes[s.seg];
        if s.index.is_none() || s.opaque {
            out.push(Diagnostic {
                severity: Severity::Warn,
                code: "bounds/unprovable",
                phase: class,
                array: s.array.clone(),
                message: format!(
                    "cannot bound this access to {} (index not statically \
                     tracked); runtime nelems check is the only guard",
                    s.array
                ),
                sites: vec![s.site.clone()],
            });
            continue;
        }
        let fps = site_footprints(s, threads);
        if fps.iter().any(Option::is_none) {
            out.push(Diagnostic {
                severity: Severity::Warn,
                code: "bounds/unprovable",
                phase: class,
                array: s.array.clone(),
                message: format!(
                    "footprint of this access to {} exceeds the enumeration \
                     cap ({} elements)",
                    s.array,
                    footprint::ENUM_CAP
                ),
                sites: vec![s.site.clone()],
            });
            continue;
        }
        let oob = fps.iter().enumerate().find_map(|(t, fp)| {
            fp.as_ref()
                .expect("checked")
                .iter()
                .find(|&&e| e < 0 || e as u64 >= s.nelems)
                .map(|&e| (e, t))
        });
        if let Some((elem, t)) = oob {
            out.push(Diagnostic {
                severity: Severity::Error,
                code: "bounds/oob",
                phase: class,
                array: s.array.clone(),
                message: format!(
                    "thread {t} accesses {}[{elem}] but nelems is {} \
                     (static twin of SharedArray::ptr's runtime assert)",
                    s.array, s.nelems
                ),
                sites: vec![s.site.clone()],
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn racy_fixture_draws_one_phase_localized_race() {
        let r = lint_fixture("racy", 4).expect("known fixture");
        assert_eq!(r.errors(), 1, "diagnostics: {:?}", r.diagnostics);
        assert_eq!(r.warnings(), 0, "diagnostics: {:?}", r.diagnostics);
        let d = &r.diagnostics[0];
        assert_eq!(d.code, "race/ww");
        assert_eq!(d.phase, 0);
        assert_eq!(d.array, "racy_a");
        assert_eq!(r.phases, 2);
    }

    #[test]
    fn oob_fixture_draws_one_bounds_error() {
        let r = lint_fixture("oob", 4).expect("known fixture");
        assert_eq!(r.errors(), 1, "diagnostics: {:?}", r.diagnostics);
        assert_eq!(r.warnings(), 0, "diagnostics: {:?}", r.diagnostics);
        let d = &r.diagnostics[0];
        assert_eq!(d.code, "bounds/oob");
        assert!(d.message.contains("[64]"), "message: {}", d.message);
    }

    #[test]
    fn clean_fixture_is_silent_and_batchable() {
        let r = lint_fixture("clean", 4).expect("known fixture");
        assert!(r.diagnostics.is_empty(), "diagnostics: {:?}", r.diagnostics);
        assert!(r.predicted.batched());
        assert!(!r.predicted.gather());
    }

    #[test]
    fn summary_json_is_deterministic() {
        let r = lint_fixture("racy", 4).expect("known fixture");
        assert_eq!(
            r.summary_json(),
            "{\"kernel\":\"racy\",\"threads\":4,\"errors\":1,\"warnings\":0,\
             \"codes\":[\"race/ww\"],\"batched\":false,\"scalar\":true,\
             \"gather\":false}"
        );
    }

    #[test]
    fn json_escaping_is_safe() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
