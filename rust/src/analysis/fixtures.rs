//! Deliberately-broken (and one deliberately-clean) lint fixture
//! kernels.  These are the analyzer's own acceptance surface: `racy`
//! must draw a phase-localized write/write race ERROR, `oob` a bounds
//! ERROR, and `clean` nothing at all — the golden-file suite pins all
//! three, and CI asserts `pgas-hw lint --fixtures` exits non-zero.

use crate::compiler::{IrBuilder, IrModule, Val};
use crate::isa::MemWidth;
use crate::upc::UpcRuntime;

/// One fixture kernel: its runtime (array directory) plus IR.
pub struct Fixture {
    /// Fixture name (`racy`, `oob`, `clean`).
    pub name: &'static str,
    /// Runtime the kernel was built against.
    pub rt: UpcRuntime,
    /// The kernel IR.
    pub module: IrModule,
}

/// All fixture names, in lint order.
pub const NAMES: [&str; 3] = ["racy", "oob", "clean"];

/// Build a fixture by name; `None` for an unknown name.
pub fn by_name(name: &str, threads: u32) -> Option<Fixture> {
    match name {
        "racy" => Some(racy(threads)),
        "oob" => Some(oob(threads)),
        "clean" => Some(clean(threads)),
        _ => None,
    }
}

/// Every thread writes the *entire* array in phase 0 — a cross-thread
/// write/write race on every element — then reads its own element
/// after a barrier (phase 1, race-free).  Exactly one race ERROR,
/// localized to phase 0.
pub fn racy(threads: u32) -> Fixture {
    let mut rt = UpcRuntime::new(threads);
    let a = rt.alloc_shared("racy_a", 4, 8, 64);
    let module = {
        let mut b = IrBuilder::new(&mut rt);
        let v = b.iconst(7);
        let p = b.sptr_init(a, Val::I(0));
        b.for_range(Val::I(0), Val::I(64), 1, |b, _k| {
            b.sptr_st(MemWidth::U64, v, p, 0);
            b.sptr_inc(p, a, Val::I(1));
        });
        b.free_i(p);
        b.free_i(v);
        b.barrier();
        let myt = b.mythread();
        let q = b.sptr_init(a, Val::R(myt));
        let t = b.it();
        b.sptr_ld(MemWidth::U64, t, q, 0);
        b.free_i(t);
        b.free_i(q);
        b.free_i(myt);
        b.finish("racy")
    };
    Fixture { name: "racy", rt, module }
}

/// A cursor starts two elements before the end of a 64-element array
/// and walks four loads — the last two land on elements 64 and 65,
/// past `nelems`.  (The cursor is formed by increments, not
/// `sptr_init`, precisely because the lowering's host-side `ptr()`
/// would reject an out-of-range init at compile time.)
pub fn oob(threads: u32) -> Fixture {
    let mut rt = UpcRuntime::new(threads);
    let a = rt.alloc_shared("oob_a", 4, 8, 64);
    let module = {
        let mut b = IrBuilder::new(&mut rt);
        let p = b.sptr_init(a, Val::I(62));
        b.for_range(Val::I(0), Val::I(4), 1, |b, _k| {
            let t = b.it();
            b.sptr_ld(MemWidth::U64, t, p, 0);
            b.sptr_inc(p, a, Val::I(1));
            b.free_i(t);
        });
        b.free_i(p);
        b.finish("oob")
    };
    Fixture { name: "oob", rt, module }
}

/// The well-formed twin: two cyclic arrays written on an
/// owner-disjoint `MYTHREAD + k·THREADS` stride (the adjacent
/// increment pair makes the loop body a batchable window), then a
/// barrier, then a read of the thread's own element.  Zero
/// diagnostics.
pub fn clean(threads: u32) -> Fixture {
    assert!(
        threads > 0 && 64 % threads == 0,
        "clean fixture needs THREADS dividing 64"
    );
    let mut rt = UpcRuntime::new(threads);
    let a = rt.alloc_shared("clean_a", 1, 8, 64);
    let b_arr = rt.alloc_shared("clean_b", 1, 8, 64);
    let module = {
        let mut b = IrBuilder::new(&mut rt);
        let myt = b.mythread();
        let nt = b.threads();
        let pa = b.sptr_init(a, Val::R(myt));
        let pb = b.sptr_init(b_arr, Val::R(myt));
        let v = b.iconst(1);
        b.for_range(Val::I(0), Val::I(i64::from(64 / threads)), 1, |b, _k| {
            b.sptr_st(MemWidth::U64, v, pa, 0);
            b.sptr_st(MemWidth::U64, v, pb, 0);
            b.sptr_inc(pa, a, Val::R(nt));
            b.sptr_inc(pb, b_arr, Val::R(nt));
        });
        b.free_i(v);
        b.free_i(pb);
        b.free_i(pa);
        b.barrier();
        let q = b.sptr_init(a, Val::R(myt));
        let t = b.it();
        b.sptr_ld(MemWidth::U64, t, q, 0);
        b.free_i(t);
        b.free_i(q);
        b.free_i(nt);
        b.free_i(myt);
        b.finish("clean")
    };
    Fixture { name: "clean", rt, module }
}
