//! Compile-time engine-mix prediction: replay the batch planner's own
//! window eligibility ([`plan_window`]) over the lowered instruction
//! stream and predict, before a single simulated cycle, how many PGAS
//! increments each kernel can serve batched, which stay scalar, and
//! whether any window is large enough for the inspector/executor
//! gather leg.
//!
//! The prediction is validated *differentially* against the runtime
//! telemetry ([`EngineMix`], [`GatherStats`]) that every simulation
//! already reports — see [`PredictedMix::check_against`] for the exact
//! agreement contract and why it is boolean/one-directional rather
//! than an equality on counts.

use crate::compiler::CompileStats;
use crate::cpu::pipeline::{plan_window, EngineMix, Lookahead};
use crate::engine::{EngineSelector, GatherStats};
use crate::isa::{Inst, Program};

/// Static per-kernel engine-mix prediction from a linear scan of the
/// lowered [`Program`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PredictedMix {
    /// Batchable windows found (each ≥ `MIN_RUN_INCS` increments).
    pub windows: usize,
    /// PGAS increments inside those windows.
    pub batchable_incs: usize,
    /// PGAS increments outside any batchable window.
    pub scalar_incs: usize,
    /// Windows whose increment count meets the gather threshold
    /// (multi-owner batches there are inspector/executor candidates).
    pub gather_windows: usize,
    /// The lowering's own access-site classification, carried along
    /// for the lint report.
    pub stats: CompileStats,
}

/// Scan `program` exactly the way the pipeline's lookahead does — at
/// every PGAS increment, try [`plan_window`] with the default window
/// depth; on success skip the whole window, otherwise count the
/// increment scalar.
///
/// Jumping *into* the middle of a window at runtime is harmless for
/// the boolean agreement contract: any pc the runtime enters a window
/// at is either a static window start itself or strictly inside one
/// already counted here.
pub fn predict(program: &Program, stats: &CompileStats) -> PredictedMix {
    let insts = &program.insts;
    let mut out = PredictedMix { stats: *stats, ..PredictedMix::default() };
    let mut pc = 0usize;
    while pc < insts.len() {
        match insts[pc] {
            Inst::PgasIncI { .. } | Inst::PgasIncR { .. } => {
                match plan_window(insts, pc, Lookahead::DEFAULT_WINDOW) {
                    Some(plan) => {
                        out.windows += 1;
                        out.batchable_incs += plan.incs;
                        if plan.incs >= EngineSelector::DEFAULT_GATHER_THRESHOLD {
                            out.gather_windows += 1;
                        }
                        pc += plan.len;
                    }
                    None => {
                        out.scalar_incs += 1;
                        pc += 1;
                    }
                }
            }
            _ => pc += 1,
        }
    }
    out
}

impl PredictedMix {
    /// Does the kernel have any statically batchable window?
    pub fn batched(&self) -> bool {
        self.batchable_incs > 0
    }

    /// Does the kernel have any statically scalar increment?
    pub fn scalar(&self) -> bool {
        self.scalar_incs > 0
    }

    /// Is any window gather-eligible by size?
    pub fn gather(&self) -> bool {
        self.gather_windows > 0
    }

    /// Check the prediction against one run's telemetry.
    ///
    /// The contract is deliberately *categorical*, not count-exact:
    ///
    /// 1. batched: a static window exists **iff** the runtime served
    ///    any increment batched (the runtime window is a prefix of
    ///    the static one — `plan_window` is monotone in `max_len` —
    ///    so the booleans must agree even when quantum budgets clamp
    ///    runtime windows shorter);
    /// 2. scalar: a static scalar increment implies runtime scalar
    ///    increments (one-directional — the runtime can *add* scalar
    ///    increments by truncating windows at quantum boundaries);
    /// 3. when the prediction says *no* scalar increments at all,
    ///    runtime scalar leakage must stay under 2% of dynamic
    ///    increments (the quantum-truncation allowance);
    /// 4. gather: a gather-sized static window exists **iff** the
    ///    gather leg inspected at least one batch (`plans` when the
    ///    batch was multi-owner, `fallback` when inspection found a
    ///    single owner — both mean a ≥-threshold batch arrived).
    pub fn check_against(
        &self,
        mix: &EngineMix,
        gather: &GatherStats,
    ) -> Result<(), String> {
        if self.batched() != (mix.batched_incs > 0) {
            return Err(format!(
                "batched disagreement: predicted {} windows / {} batchable incs, \
                 runtime batched {} incs",
                self.windows, self.batchable_incs, mix.batched_incs
            ));
        }
        if self.scalar() && mix.scalar_incs == 0 {
            return Err(format!(
                "scalar disagreement: predicted {} scalar incs, runtime saw none",
                self.scalar_incs
            ));
        }
        if !self.scalar() {
            let dynamic = mix.batched_incs + mix.scalar_incs;
            if mix.scalar_incs * 50 > dynamic {
                return Err(format!(
                    "scalar leakage: predicted fully batchable, runtime ran \
                     {} of {} incs scalar (> 2% truncation allowance)",
                    mix.scalar_incs, dynamic
                ));
            }
        }
        let runtime_gather = gather.plans + gather.fallback > 0;
        if self.gather() != runtime_gather {
            return Err(format!(
                "gather disagreement: predicted {} gather-sized windows, \
                 runtime gather plans={} fallback={}",
                self.gather_windows, gather.plans, gather.fallback
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::IntOp;

    fn inc(rd: u8, ra: u8) -> Inst {
        Inst::PgasIncI { rd, ra, l2es: 3, l2bs: 2, l2inc: 0 }
    }

    #[test]
    fn adjacent_incs_form_one_window() {
        let prog = Program::new(
            "w",
            vec![inc(1, 1), inc(2, 2), inc(3, 3), Inst::Halt],
        );
        let p = predict(&prog, &CompileStats::default());
        assert_eq!(p.windows, 1);
        assert_eq!(p.batchable_incs, 3);
        assert_eq!(p.scalar_incs, 0);
        assert_eq!(p.gather_windows, 0);
        assert!(p.batched() && !p.scalar() && !p.gather());
    }

    #[test]
    fn lone_and_dependent_incs_stay_scalar() {
        // a single inc, and a pair where the second reads the first's
        // destination — both scalar by plan_window's own rules
        let prog = Program::new(
            "s",
            vec![
                inc(1, 1),
                Inst::Opi { op: IntOp::Add, rd: 9, ra: 9, imm: 1 },
                Inst::Halt,
                inc(2, 2),
                inc(3, 2), // reads r2, written by the previous inc
                Inst::Halt,
            ],
        );
        let p = predict(&prog, &CompileStats::default());
        assert_eq!(p.windows, 0);
        assert_eq!(p.scalar_incs, 3);
        assert!(p.scalar() && !p.batched());
    }

    #[test]
    fn gather_sized_window_is_flagged() {
        let mut insts: Vec<Inst> =
            (0..8).map(|r| inc(r + 1, r + 1)).collect();
        insts.push(Inst::Halt);
        let p = predict(&Program::new("g", insts), &CompileStats::default());
        assert_eq!(p.windows, 1);
        assert_eq!(p.batchable_incs, 8);
        assert_eq!(p.gather_windows, 1);
        assert!(p.gather());
    }

    #[test]
    fn categorical_agreement_contract() {
        let p = PredictedMix {
            windows: 1,
            batchable_incs: 4,
            scalar_incs: 0,
            gather_windows: 0,
            stats: CompileStats::default(),
        };
        let mut mix = EngineMix::default();
        mix.batched_incs = 400;
        mix.scalar_incs = 4; // 1% — inside the truncation allowance
        assert!(p.check_against(&mix, &GatherStats::default()).is_ok());
        mix.scalar_incs = 40; // 9% — leakage
        assert!(p.check_against(&mix, &GatherStats::default()).is_err());
        mix.scalar_incs = 0;
        mix.batched_incs = 0; // batched disagreement
        assert!(p.check_against(&mix, &GatherStats::default()).is_err());
    }
}
