//! Barrier-phase partitioning of a kernel.
//!
//! A UPC barrier separates *synchronization phases*: accesses on
//! opposite sides of a barrier can never race.  The analyzer splits a
//! kernel into barrier-delimited segments and then merges segments
//! that a loop back edge makes concurrent again — e.g. CG's
//! `do { ...; barrier; ...; barrier; } while (...)` body, where the
//! code *after* the last barrier of iteration `n` runs concurrently
//! with the code *before* the first barrier of iteration `n+1`.  The
//! union-find classes that remain are the analyzer's units of race
//! checking.

use crate::compiler::Op;

/// Segment bookkeeping for one structured walk of a kernel: a counter
/// of barrier-delimited segments plus a union-find over them (loop
/// wrap-around merges the entry segment with the exit segment of any
/// loop whose body contains a barrier).
#[derive(Clone, Debug)]
pub struct PhaseTracker {
    /// `parent[s]` for the union-find; one entry per segment.
    parent: Vec<usize>,
    /// The segment new accesses currently fall into.
    cur: usize,
}

impl Default for PhaseTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseTracker {
    /// A tracker with one open segment (id 0).
    pub fn new() -> Self {
        PhaseTracker { parent: vec![0], cur: 0 }
    }

    /// The segment currently being populated.
    pub fn current(&self) -> usize {
        self.cur
    }

    /// Total segments opened so far.
    pub fn num_segs(&self) -> usize {
        self.parent.len()
    }

    /// A barrier ends the current segment and opens the next one.
    pub fn barrier(&mut self) -> usize {
        let id = self.parent.len();
        self.parent.push(id);
        self.cur = id;
        id
    }

    /// Representative of `seg`'s concurrency class.
    pub fn find(&self, mut seg: usize) -> usize {
        while self.parent[seg] != seg {
            seg = self.parent[seg];
        }
        seg
    }

    /// Merge two segments into one concurrency class.
    pub fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            // root the larger id under the smaller so class
            // representatives are stable, earliest-segment ids
            let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            self.parent[hi] = lo;
        }
    }

    /// A loop body containing at least one barrier wrapped around:
    /// its exit segment (the current one) is concurrent with its
    /// entry segment.
    pub fn loop_wrap(&mut self, entry_seg: usize) {
        let cur = self.cur;
        self.union(entry_seg, cur);
    }

    /// Compact class ids: maps every segment to a class index in
    /// `0..classes`, numbering classes by first appearance.
    pub fn classes(&self) -> (Vec<usize>, usize) {
        let mut map = vec![usize::MAX; self.parent.len()];
        let mut next = 0;
        let mut out = Vec::with_capacity(self.parent.len());
        for seg in 0..self.parent.len() {
            let root = self.find(seg);
            if map[root] == usize::MAX {
                map[root] = next;
                next += 1;
            }
            out.push(map[root]);
        }
        (out, next)
    }
}

/// Structural phase partition of an op tree, ignoring loop
/// wrap-around: assigns every op (in pre-order) the index of the
/// barrier-delimited segment it falls into and returns the total
/// segment count.  A barrier belongs to the segment it terminates.
///
/// This is the partitioner the property suite exercises: every op is
/// covered exactly once, segment ids are non-decreasing in pre-order,
/// and the segment count is exactly `1 + number of barriers`.
pub fn flat_partition(ops: &[Op]) -> (Vec<usize>, usize) {
    fn walk(ops: &[Op], cur: &mut usize, out: &mut Vec<usize>) {
        for op in ops {
            out.push(*cur);
            match op {
                Op::Barrier => *cur += 1,
                Op::For { body, .. } | Op::DoWhile { body, .. } => {
                    walk(body, cur, out);
                }
                Op::If { then, els, .. } => {
                    walk(then, cur, out);
                    walk(els, cur, out);
                }
                _ => {}
            }
        }
    }
    let mut out = Vec::new();
    let mut cur = 0;
    walk(ops, &mut cur, &mut out);
    (out, cur + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::Val;

    #[test]
    fn tracker_segments_and_wrap() {
        let mut t = PhaseTracker::new();
        assert_eq!(t.current(), 0);
        let entry = t.current();
        t.barrier();
        t.barrier();
        assert_eq!(t.current(), 2);
        assert_eq!(t.num_segs(), 3);
        // a barrier-bearing loop wrapped: entry and exit are one class
        t.loop_wrap(entry);
        assert_eq!(t.find(2), t.find(0));
        assert_ne!(t.find(1), t.find(0));
        let (classes, n) = t.classes();
        assert_eq!(n, 2);
        assert_eq!(classes[0], classes[2]);
        assert_ne!(classes[0], classes[1]);
    }

    #[test]
    fn flat_partition_counts_every_op_once() {
        let ops = vec![
            Op::Mov { d: 0, v: Val::I(1) },
            Op::Barrier,
            Op::For {
                i: 1,
                from: Val::I(0),
                to: Val::I(4),
                step: 1,
                body: vec![Op::Mov { d: 2, v: Val::I(0) }, Op::Barrier],
            },
            Op::Mov { d: 3, v: Val::I(2) },
        ];
        let (segs, n) = flat_partition(&ops);
        // ops in pre-order: Mov, Barrier, For, Mov(body), Barrier(body), Mov
        assert_eq!(segs, vec![0, 0, 1, 1, 1, 2]);
        assert_eq!(n, 3);
    }
}
