//! Symbolic access footprints: affine index expressions over
//! `MYTHREAD` and loop counters, guard constraints collected from the
//! IR's structured branches, and the concrete per-thread enumeration
//! the race and bounds checkers query.
//!
//! An [`Affine`] is `konst + myt·MYTHREAD + Σ cᵢ·kᵢ` where each `kᵢ`
//! is a loop counter with a known trip count (`kᵢ ∈ [0, trip)`).  The
//! dataflow pass ([`super::dataflow`]) keeps shared-pointer indices in
//! this form whenever the kernel's address arithmetic allows it; the
//! checkers then *enumerate* the footprint exactly for the concrete
//! thread count being linted (the analysis is THREADS-parametric in
//! form, concrete in evaluation — the same block-cyclic element space
//! `engine/gather.rs` buckets at runtime).

use std::collections::BTreeSet;
use std::fmt;

/// Enumeration budget per access site and thread: a site whose used
/// loop ranges multiply out beyond this is reported *unprovable*
/// (WARN), never silently truncated into a wrong ERROR.
pub const ENUM_CAP: u64 = 1 << 16;

/// An affine integer expression `konst + myt·MYTHREAD + Σ cᵢ·kᵢ`.
///
/// Loop-counter terms are kept sorted by variable id so structural
/// equality is semantic equality.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Affine {
    /// Constant term.
    pub konst: i64,
    /// Coefficient on `MYTHREAD`.
    pub myt: i64,
    /// `(loop variable id, coefficient)`, sorted by id, no zeros.
    pub terms: Vec<(u32, i64)>,
}

impl Affine {
    /// The constant `c`.
    pub fn konst(c: i64) -> Self {
        Affine { konst: c, myt: 0, terms: Vec::new() }
    }

    /// The expression `MYTHREAD`.
    pub fn mythread() -> Self {
        Affine { konst: 0, myt: 1, terms: Vec::new() }
    }

    /// The loop counter `k_v` (coefficient 1).
    pub fn var(v: u32) -> Self {
        Affine { konst: 0, myt: 0, terms: vec![(v, 1)] }
    }

    /// `Some(c)` when the expression is the constant `c` (no
    /// `MYTHREAD`, no loop counters).
    pub fn as_const(&self) -> Option<i64> {
        if self.myt == 0 && self.terms.is_empty() {
            Some(self.konst)
        } else {
            None
        }
    }

    /// Loop-variable ids this expression mentions.
    pub fn vars(&self) -> impl Iterator<Item = u32> + '_ {
        self.terms.iter().map(|&(v, _)| v)
    }

    /// `self + other`.
    pub fn add(&self, other: &Affine) -> Affine {
        let mut terms = Vec::with_capacity(self.terms.len() + other.terms.len());
        let (mut i, mut j) = (0, 0);
        while i < self.terms.len() || j < other.terms.len() {
            let (v, c) = match (self.terms.get(i), other.terms.get(j)) {
                (Some(&(va, ca)), Some(&(vb, cb))) => {
                    if va == vb {
                        i += 1;
                        j += 1;
                        (va, ca.wrapping_add(cb))
                    } else if va < vb {
                        i += 1;
                        (va, ca)
                    } else {
                        j += 1;
                        (vb, cb)
                    }
                }
                (Some(&(va, ca)), None) => {
                    i += 1;
                    (va, ca)
                }
                (None, Some(&(vb, cb))) => {
                    j += 1;
                    (vb, cb)
                }
                (None, None) => unreachable!(),
            };
            if c != 0 {
                terms.push((v, c));
            }
        }
        Affine {
            konst: self.konst.wrapping_add(other.konst),
            myt: self.myt.wrapping_add(other.myt),
            terms,
        }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Affine) -> Affine {
        self.add(&other.scale(-1))
    }

    /// `self · c`.
    pub fn scale(&self, c: i64) -> Affine {
        if c == 0 {
            return Affine::konst(0);
        }
        Affine {
            konst: self.konst.wrapping_mul(c),
            myt: self.myt.wrapping_mul(c),
            terms: self
                .terms
                .iter()
                .map(|&(v, k)| (v, k.wrapping_mul(c)))
                .collect(),
        }
    }

    /// `self + c`.
    pub fn add_const(&self, c: i64) -> Affine {
        let mut out = self.clone();
        out.konst = out.konst.wrapping_add(c);
        out
    }

    /// Evaluate with `MYTHREAD = myt` and loop counters bound by `env`
    /// (`env(v)` must cover every variable the expression mentions).
    pub fn eval(&self, myt: i64, env: &dyn Fn(u32) -> i64) -> i64 {
        let mut acc = self.konst.wrapping_add(self.myt.wrapping_mul(myt));
        for &(v, c) in &self.terms {
            acc = acc.wrapping_add(c.wrapping_mul(env(v)));
        }
        acc
    }
}

impl fmt::Display for Affine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        let mut put = |f: &mut fmt::Formatter<'_>, c: i64, name: &str| -> fmt::Result {
            if c == 0 {
                return Ok(());
            }
            if first {
                first = false;
                if c == 1 {
                    write!(f, "{name}")?;
                } else {
                    write!(f, "{c}*{name}")?;
                }
            } else if c == 1 {
                write!(f, "+{name}")?;
            } else if c == -1 {
                write!(f, "-{name}")?;
            } else if c < 0 {
                write!(f, "{c}*{name}")?;
            } else {
                write!(f, "+{c}*{name}")?;
            }
            Ok(())
        };
        put(f, self.myt, "MYTHREAD")?;
        for &(v, c) in &self.terms {
            put(f, c, &format!("k{v}"))?;
        }
        if first {
            write!(f, "{}", self.konst)
        } else if self.konst > 0 {
            write!(f, "+{}", self.konst)
        } else if self.konst < 0 {
            write!(f, "{}", self.konst)
        } else {
            Ok(())
        }
    }
}

/// How a guard constrains its [`Affine`] expression against zero.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Relation {
    /// `expr == 0`
    Zero,
    /// `expr != 0`
    NonZero,
    /// `expr < 0`
    Neg,
    /// `expr >= 0`
    NonNeg,
    /// `expr > 0`
    Pos,
    /// `expr <= 0`
    NonPos,
}

impl Relation {
    /// Does a concrete value satisfy the relation?
    pub fn holds(&self, v: i64) -> bool {
        match self {
            Relation::Zero => v == 0,
            Relation::NonZero => v != 0,
            Relation::Neg => v < 0,
            Relation::NonNeg => v >= 0,
            Relation::Pos => v > 0,
            Relation::NonPos => v <= 0,
        }
    }
}

/// One path constraint an access site executes under: `expr rel 0`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Constraint {
    /// The guarded expression.
    pub expr: Affine,
    /// Its relation to zero on the taken path.
    pub rel: Relation,
}

impl fmt::Display for Constraint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let op = match self.rel {
            Relation::Zero => "==",
            Relation::NonZero => "!=",
            Relation::Neg => "<",
            Relation::NonNeg => ">=",
            Relation::Pos => ">",
            Relation::NonPos => "<=",
        };
        write!(f, "{} {op} 0", self.expr)
    }
}

/// Enumerate the exact element set `{ index | constraints hold }` for
/// one thread, iterating every *used* loop counter over its trip
/// range.  Returns `None` when the used ranges multiply out beyond
/// [`ENUM_CAP`] (the caller downgrades the site to *unprovable*).
///
/// `loops` is the site's enclosing `(var, trip)` list; counters the
/// index and constraints never mention contribute no factor.
pub fn enumerate_for_thread(
    index: &Affine,
    loops: &[(u32, u64)],
    constraints: &[Constraint],
    myt: i64,
) -> Option<BTreeSet<i64>> {
    // the odometer only spins counters the site actually uses
    let mut used: Vec<(u32, u64)> = loops
        .iter()
        .filter(|&&(v, _)| {
            index.vars().any(|u| u == v)
                || constraints.iter().any(|c| c.expr.vars().any(|u| u == v))
        })
        .copied()
        .collect();
    used.dedup_by_key(|&mut (v, _)| v);
    let mut total: u64 = 1;
    for &(_, trip) in &used {
        total = total.checked_mul(trip.max(1))?;
        if total > ENUM_CAP {
            return None;
        }
    }
    let mut out = BTreeSet::new();
    let mut odo: Vec<u64> = vec![0; used.len()];
    loop {
        let env = |v: u32| -> i64 {
            for (k, &(uv, _)) in used.iter().enumerate() {
                if uv == v {
                    return odo[k] as i64;
                }
            }
            // a constraint/index var outside `loops` cannot occur: the
            // dataflow pass records sites with their full loop context
            0
        };
        if constraints.iter().all(|c| c.rel.holds(c.expr.eval(myt, &env))) {
            out.insert(index.eval(myt, &env));
        }
        // advance the odometer
        let mut k = 0;
        loop {
            if k == used.len() {
                return Some(out);
            }
            odo[k] += 1;
            if odo[k] < used[k].1 {
                break;
            }
            odo[k] = 0;
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affine_algebra() {
        let a = Affine::mythread().scale(3).add(&Affine::var(1).scale(2));
        let b = Affine::var(1).add(&Affine::konst(5));
        let s = a.add(&b);
        assert_eq!(s.myt, 3);
        assert_eq!(s.terms, vec![(1, 3)]);
        assert_eq!(s.konst, 5);
        let d = s.sub(&b);
        assert_eq!(d, a);
        assert_eq!(Affine::konst(7).as_const(), Some(7));
        assert_eq!(Affine::mythread().as_const(), None);
        assert_eq!(s.eval(2, &|_| 10), 3 * 2 + 3 * 10 + 5);
    }

    #[test]
    fn display_is_readable() {
        let e = Affine::mythread()
            .scale(4)
            .add(&Affine::var(0))
            .add_const(-2);
        assert_eq!(e.to_string(), "4*MYTHREAD+k0-2");
        assert_eq!(Affine::konst(0).to_string(), "0");
    }

    #[test]
    fn enumeration_respects_guards_and_ranges() {
        // index = myt + 4*k, k in [0,8)
        let idx = Affine::mythread().add(&Affine::var(0).scale(4));
        let loops = [(0u32, 8u64)];
        let set = enumerate_for_thread(&idx, &loops, &[], 2).unwrap();
        assert_eq!(set.len(), 8);
        assert!(set.contains(&2) && set.contains(&30));
        // guard k != 0 removes the first element
        let g = Constraint { expr: Affine::var(0), rel: Relation::NonZero };
        let set = enumerate_for_thread(&idx, &loops, &[g], 2).unwrap();
        assert_eq!(set.len(), 7);
        assert!(!set.contains(&2));
        // a myt == 0 guard empties the set for other threads
        let g0 = Constraint { expr: Affine::mythread(), rel: Relation::Zero };
        let set = enumerate_for_thread(&idx, &loops, &[g0], 2).unwrap();
        assert!(set.is_empty());
    }

    #[test]
    fn enumeration_caps_loudly() {
        let idx = Affine::var(0).add(&Affine::var(1));
        let loops = [(0u32, 1 << 9), (1u32, 1 << 9)];
        assert!(enumerate_for_thread(&idx, &loops, &[], 0).is_none());
        // unused huge ranges cost nothing
        let idx = Affine::var(0);
        assert!(enumerate_for_thread(&idx, &loops, &[], 0).is_some());
    }
}
