//! The FPGA prototype: a Leon3-class SPARC-V8 SMP with the PGAS
//! coprocessor (paper Section 5.2), at timing fidelity sufficient for
//! Figures 15/16.
//!
//! Modeled per the paper's Table 2 configuration:
//!
//! * 4 in-order 7-stage cores @ 75 MHz, 2-cycle multiplier, ~35-cycle
//!   radix-2 divider (the op that makes the *dynamic-mode* software
//!   Algorithm 1 catastrophically slow), **no FPU** — the
//!   microbenchmarks are integer, as on the real board;
//! * write-through L1 D-cache (4 sets × 4 KB, 16 B lines): every store
//!   and every miss is an AMBA-AHB bus transaction — the shared bus is
//!   what saturates in the vector-addition benchmark as threads grow;
//! * DDR3-800 behind the AHB bridge.
//!
//! Architecturally the Table-3 SPARC coprocessor extension is the same
//! operation set as the Table-1 Alpha extension, so the model reuses the
//! SimAlpha ISA and the shared functional executor; only the cost model
//! and the bus are Leon3-specific.  The coprocessor's 64-bit shared
//! pointers live in the dedicated register file (Figure 5) — on our
//! 64-bit SimAlpha encoding they fit the integer file, which the paper
//! itself notes is the right design on 64-bit architectures.
//!
//! Two consumers sit on top of this module:
//!
//! * [`microbench`] — the Figure-15/16 vector-addition and matmul
//!   runs, compiled in the paper's exact variants and executed on the
//!   full [`Leon3Machine`] (bus contention and all);
//! * [`Leon3Engine`](crate::engine::Leon3Engine) — the address-mapping
//!   backend that replays `AddressEngine` batches as `pgas_incr`
//!   sequences on the functional core under the [`Leon3Lat`] cost
//!   model, so the FPGA datapath sits in the same differential harness
//!   (and selector cost matrix) as the host backends.

pub mod microbench;

use crate::cache::{CacheCfg, SetAssocCache};
use crate::cpu::exec::{step, StepEffect};
use crate::cpu::{ArchState, CoreStats};
use crate::isa::latency::LatencyModel;
use crate::isa::{Inst, Program};
use crate::mem::MemSystem;

/// Leon3 clock (paper: "The final design runs at a frequency of 75 MHz").
pub const FREQ_MHZ: f64 = 75.0;

/// Leon3-specific latencies.
#[derive(Clone, Debug)]
pub struct Leon3Lat {
    /// base ISA latency table (2-cycle mul, 35-cycle div, …)
    pub isa: LatencyModel,
    /// L1 D hit.
    pub l1_hit: u64,
    /// memory access over AHB + DDR3, in core cycles.
    pub mem: u64,
    /// AHB occupancy per bus transaction (arbitration + 16B burst).
    pub bus_per_txn: u64,
}

impl Default for Leon3Lat {
    fn default() -> Self {
        let isa = LatencyModel {
            alu: 1,
            mul: 2,  // Table 2: "2-cycle multiplier"
            div: 35, // radix-2 SPARC V8 divider
            fp: 1,   // FPU not implemented; unused by the microbenches
            fdiv: 1,
            fsqrt: 1,
            pgas_inc: 2, // the 2-stage coprocessor pipeline (Fig. 5)
            ldi_long: 2, // sethi/or pairs
        };
        Self { isa, l1_hit: 1, mem: 24, bus_per_txn: 6 }
    }
}

/// Table 2 of the paper (the Leon3 configuration).
pub fn table2() -> String {
    "\
Table 2: Leon3 configuration
  Cores     4x SPARC cores (SMP)
  Features  2-cycle multiplier, branch prediction
  Cache     Cache Coherent
  L1 I      2 Sets, 8 kB/set, 32 bytes/line, LRU
  L1 D      4 Sets, 4 kB/set, 16 bytes/line, LRU
  FPU       Not implemented
  BUS       AMBA AHB with fast snooping
  Memory    Xilinx MIG-3.7 DDR3-800
  Frequency 75MHz
  OS        GNU/Linux, Linux version 2.6.36\n"
        .to_string()
}

/// Table 3 of the paper (the SPARC V8 coprocessor ISA extension).
pub fn table3() -> String {
    "\
Table 3: PGAS Hardware Support SPARC V8 ISA extension
  Coprocessor Load/Store
    ldc   Load to Coproc. reg.    (32 bits)
    stc   Store from Coproc. reg. (32 bits)
  Shared Address Load/Store
    ldcm  Load Long  (32 bits)
    stcm  Store Long (32 bits)
  Branch
    cb    Branch on locality
  Shared Address Incrementation
    cpinc_i  Immediate
    cpinc_r  Register\n"
        .to_string()
}

/// Leon3 L1 D geometry: 4 sets(ways) × 4 KB, 16-byte lines.
fn l1d_cfg() -> CacheCfg {
    CacheCfg { size: 16 << 10, ways: 4, line: 16 }
}

/// Result of a Leon3 run.
#[derive(Clone, Debug)]
pub struct Leon3Result {
    /// Wall cycles: the maximum over all cores.
    pub cycles: u64,
    /// Per-core execution statistics.
    pub per_core: Vec<CoreStats>,
    /// Total AMBA AHB bus transactions (write-throughs + read misses).
    pub bus_txns: u64,
    /// Cycles lost to bus contention across all cores.
    pub bus_stall_cycles: u64,
}

impl Leon3Result {
    /// Runtime in milliseconds at 75 MHz.
    pub fn runtime_ms(&self) -> f64 {
        self.cycles as f64 / (FREQ_MHZ * 1e3)
    }
}

struct Core {
    st: ArchState,
    stats: CoreStats,
    l1d: SetAssocCache,
    at_barrier: bool,
    halted: bool,
    // bus transactions issued in the current quantum
    q_bus: u64,
}

/// The 1–4 core Leon3 SMP.
pub struct Leon3Machine {
    /// The latency model in force (Table-2 defaults).
    pub lat: Leon3Lat,
    cores: Vec<Core>,
    /// The simulated memory (shared segments + base LUT).
    pub mem: MemSystem,
    quantum: u64,
    bus_txns: u64,
    bus_stall: u64,
}

impl Leon3Machine {
    /// A machine with `threads` cores (the board carries 1–4).
    pub fn new(threads: u32) -> Self {
        assert!((1..=4).contains(&threads), "the board carries 4 cores");
        // PGAS hardware requires pow2 THREADS; the ArchState enforces
        // it. (The paper's dynamic-mode runs also use 1/2/4.)
        let cores = (0..threads)
            .map(|t| Core {
                st: ArchState::new(t, threads.next_power_of_two()),
                stats: CoreStats::default(),
                l1d: SetAssocCache::new(l1d_cfg()),
                at_barrier: false,
                halted: false,
                q_bus: 0,
            })
            .collect();
        let mut m = Self {
            lat: Leon3Lat::default(),
            cores,
            mem: MemSystem::new(threads),
            quantum: 10_000,
            bus_txns: 0,
            bus_stall: 0,
        };
        for t in 0..threads {
            let st = &mut m.cores[t as usize].st;
            st.set_r(crate::sim::abi::R_MYTHREAD, t as u64);
            st.set_r(crate::sim::abi::R_THREADS, threads as u64);
            st.set_r(
                crate::sim::abi::R_PRIV,
                crate::mem::seg_base(t) + crate::mem::PRIV_OFF,
            );
        }
        m
    }

    /// Mutable access to the simulated memory (workload setup).
    pub fn mem_mut(&mut self) -> &mut MemSystem {
        &mut self.mem
    }

    fn run_core_quantum(&mut self, c: usize, prog: &Program) {
        let core = &mut self.cores[c];
        let mut budget = self.quantum;
        while budget > 0 {
            if core.st.halted {
                core.halted = true;
                return;
            }
            let inst = prog.insts[core.st.pc as usize];
            let effect = step(&mut core.st, &mut self.mem, &inst);
            core.stats.instructions += 1;
            budget -= 1;
            let cost = self.lat.isa.cost(&inst);
            core.stats.cycles += cost.latency as u64;
            match effect {
                StepEffect::Mem { sysva, write, shared, local, .. } => {
                    let line = sysva & !15; // 16-byte L1 lines
                    if write {
                        // write-through: every store is a bus txn
                        core.l1d.access(line);
                        core.stats.cycles += self.lat.l1_hit;
                        core.q_bus += 1;
                        core.stats.mem_writes += 1;
                    } else if core.l1d.access(line) {
                        core.stats.cycles += self.lat.l1_hit;
                        core.stats.mem_reads += 1;
                    } else {
                        core.stats.cycles += self.lat.mem;
                        core.q_bus += 1;
                        core.stats.mem_reads += 1;
                    }
                    if shared {
                        if local {
                            core.stats.local_shared_accesses += 1;
                        } else {
                            core.stats.remote_shared_accesses += 1;
                        }
                    }
                }
                StepEffect::Branch { taken } => {
                    core.stats.branches += 1;
                    if taken {
                        core.stats.cycles += 2; // redirect bubble
                    }
                }
                StepEffect::Barrier => {
                    core.stats.barriers += 1;
                    core.at_barrier = true;
                    return;
                }
                StepEffect::Halt => {
                    core.halted = true;
                    return;
                }
                StepEffect::Normal => {
                    if matches!(inst, Inst::PgasIncI { .. } | Inst::PgasIncR { .. }) {
                        core.stats.pgas_incs += 1;
                    }
                }
            }
        }
    }

    /// Run `prog` SPMD to completion.
    pub fn run(&mut self, prog: &Program) -> Leon3Result {
        loop {
            let n = self.cores.len();
            let mut all_halted = true;
            for c in 0..n {
                if !self.cores[c].halted && !self.cores[c].at_barrier {
                    self.run_core_quantum(c, prog);
                }
                all_halted &= self.cores[c].halted;
            }
            // ---- AMBA AHB contention: single shared bus ----
            let total: u64 = self.cores.iter().map(|c| c.q_bus).sum();
            if total > 0 {
                self.bus_txns += total;
                let bus_time = total * self.lat.bus_per_txn;
                let rho = (bus_time as f64 / self.quantum as f64).min(1.0);
                for c in self.cores.iter_mut() {
                    let others = total - c.q_bus;
                    let stall = (others as f64
                        * self.lat.bus_per_txn as f64
                        * rho
                        * (c.q_bus as f64 / total as f64))
                        as u64;
                    c.stats.cycles += stall;
                    self.bus_stall += stall;
                    c.q_bus = 0;
                }
            }
            if all_halted {
                break;
            }
            // ---- barrier release ----
            let any_running = self
                .cores
                .iter()
                .any(|c| !c.halted && !c.at_barrier);
            if !any_running {
                let maxc = self
                    .cores
                    .iter()
                    .filter(|c| c.at_barrier)
                    .map(|c| c.stats.cycles)
                    .max()
                    .unwrap_or(0);
                for c in self.cores.iter_mut() {
                    if c.at_barrier {
                        c.stats.cycles = c.stats.cycles.max(maxc);
                        c.at_barrier = false;
                    }
                }
            }
        }
        Leon3Result {
            cycles: self.cores.iter().map(|c| c.stats.cycles).max().unwrap_or(0),
            per_core: self.cores.iter().map(|c| c.stats).collect(),
            bus_txns: self.bus_txns,
            bus_stall_cycles: self.bus_stall,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Cond, IntOp};

    #[test]
    fn tables_render() {
        assert!(table2().contains("75MHz"));
        assert!(table3().contains("Branch on locality"));
        assert!(table3().contains("cpinc_i"));
    }

    #[test]
    fn divide_is_much_slower_than_multiply() {
        let mk = |op| {
            Program::new(
                "p",
                vec![
                    Inst::Ldi { rd: 1, imm: 1000 },
                    Inst::Ldi { rd: 2, imm: 100 },
                    Inst::Ldi { rd: 3, imm: 7 },
                    // loop:
                    Inst::Opr { op, rd: 4, ra: 2, rb: 3 }, // 3
                    Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 },
                    Inst::Br { cond: Cond::Gt, ra: 1, target: 3 },
                    Inst::Halt,
                ],
            )
        };
        let run = |prog: &Program| {
            let mut m = Leon3Machine::new(1);
            m.run(prog).cycles
        };
        let mul = run(&mk(IntOp::Mul));
        let div = run(&mk(IntOp::Div));
        assert!(div > mul * 5, "div {div} vs mul {mul}");
    }

    #[test]
    fn stores_occupy_the_bus() {
        // store loop generates bus transactions (write-through L1)
        let a = crate::mem::seg_base(0) + 64;
        let prog = Program::new(
            "st",
            vec![
                Inst::Ldi { rd: 1, imm: a as i64 },
                Inst::Ldi { rd: 2, imm: 100 },
                Inst::St { w: crate::isa::MemWidth::U32, rs: 2, base: 1, disp: 0 }, // 2
                Inst::Opi { op: IntOp::Add, rd: 2, ra: 2, imm: -1 },
                Inst::Br { cond: Cond::Gt, ra: 2, target: 2 },
                Inst::Halt,
            ],
        );
        let mut m = Leon3Machine::new(1);
        let r = m.run(&prog);
        assert!(r.bus_txns >= 100, "bus txns {}", r.bus_txns);
    }
}
