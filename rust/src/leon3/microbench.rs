//! The two FPGA microbenchmarks of Section 6.2: vector addition
//! (Figure 15) and matrix multiplication (Figure 16), in the exact
//! compilation variants the paper measures.
//!
//! * Vector addition: *dynamic* (unoptimized, run-time THREADS — the
//!   compiler cannot strength-reduce the division in Algorithm 1),
//!   *static* (compile-time THREADS — divisions become shifts, ~5×
//!   faster), *privatized* (~16× over dynamic), and *hw* — which matches
//!   privatized **without** needing static compilation: the `threads`
//!   special register is set at run time, so one executable serves any
//!   thread count (the paper's productivity point).
//! * Matrix multiplication: *static*, *privatization 1* (A and C rows
//!   privatized), *privatization 2* (the non-standard-extension variant
//!   that also reaches B through raw per-thread base pointers), and
//!   *hw*, which matches the fully privatized version.
//!
//! The Leon3 prototype's HW paths were partly hand-written assembly
//! (no GCC volatile-asm reload issue), so these compile with
//! `volatile_stores: false`.

use super::{Leon3Machine, Leon3Result};
use crate::compiler::{compile, CompileOpts, IrBuilder, Lowering, Val};
use crate::isa::{IntOp, MemWidth};
use crate::upc::UpcRuntime;

/// Figure 15 variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VecAddVariant {
    /// Unoptimized, dynamic THREADS (divisions in Algorithm 1).
    Dynamic,
    /// Unoptimized, static THREADS (shifts in Algorithm 1).
    Static,
    /// Manually privatized.
    Privatized,
    /// PGAS hardware (dynamic THREADS — no static compilation needed).
    Hw,
}

impl VecAddVariant {
    /// All four Figure-15 variants, in the figure's order.
    pub const ALL: [VecAddVariant; 4] = [
        VecAddVariant::Dynamic,
        VecAddVariant::Static,
        VecAddVariant::Privatized,
        VecAddVariant::Hw,
    ];

    /// The figure's legend label for this variant.
    pub fn label(&self) -> &'static str {
        match self {
            VecAddVariant::Dynamic => "dynamic",
            VecAddVariant::Static => "static",
            VecAddVariant::Privatized => "privatized",
            VecAddVariant::Hw => "hw",
        }
    }
}

/// Figure 16 variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatmulVariant {
    /// Static compilation, all accesses through shared pointers.
    Static,
    /// A and C privatized; B through shared pointers.
    Priv1,
    /// All three matrices through private pointers (the non-standard
    /// `upc_cast`-style extension).
    Priv2,
    /// PGAS hardware.
    Hw,
}

impl MatmulVariant {
    /// All four Figure-16 variants, in the figure's order.
    pub const ALL: [MatmulVariant; 4] = [
        MatmulVariant::Static,
        MatmulVariant::Priv1,
        MatmulVariant::Priv2,
        MatmulVariant::Hw,
    ];

    /// The figure's legend label for this variant.
    pub fn label(&self) -> &'static str {
        match self {
            MatmulVariant::Static => "static",
            MatmulVariant::Priv1 => "privatization 1",
            MatmulVariant::Priv2 => "privatization 2 (ext)",
            MatmulVariant::Hw => "hw",
        }
    }
}

fn leon3_opts(lowering: Lowering, static_threads: bool, threads: u32) -> CompileOpts {
    CompileOpts {
        lowering,
        static_threads,
        numthreads: threads,
        volatile_stores: false, // hand-written assembly on the board
    }
}

/// Run `c[i] = a[i] + b[i]` over cyclic arrays of `n` u32 elements.
pub fn run_vecadd(threads: u32, variant: VecAddVariant, n: u64) -> Leon3Result {
    assert!(n % threads as u64 == 0);
    let per = n / threads as u64;
    let mut rt = UpcRuntime::new(threads);
    let a = rt.alloc_shared("va_a", 1, 4, n);
    let bb = rt.alloc_shared("va_b", 1, 4, n);
    let c = rt.alloc_shared("va_c", 1, 4, n);

    let mut b = IrBuilder::new(&mut rt);
    let myt = b.mythread();
    match variant {
        VecAddVariant::Dynamic | VecAddVariant::Static | VecAddVariant::Hw => {
            // upc_forall(i; i<n; i++; i%THREADS==MYTHREAD):
            // walk three shared pointers with stride THREADS
            let pa = b.sptr_init(a, Val::R(myt));
            let pb = b.sptr_init(bb, Val::R(myt));
            let pc = b.sptr_init(c, Val::R(myt));
            b.for_range(Val::I(0), Val::I(per as i64), 1, |b, _| {
                let (x, y) = (b.it(), b.it());
                b.sptr_ld(MemWidth::U32, x, pa, 0);
                b.sptr_ld(MemWidth::U32, y, pb, 0);
                b.bin(IntOp::Add, x, x, Val::R(y));
                b.sptr_st(MemWidth::U32, x, pc, 0);
                b.free_i(y);
                b.free_i(x);
                b.sptr_inc(pa, a, Val::I(threads as i64));
                b.sptr_inc(pb, bb, Val::I(threads as i64));
                b.sptr_inc(pc, c, Val::I(threads as i64));
            });
            b.free_i(pc);
            b.free_i(pb);
            b.free_i(pa);
        }
        VecAddVariant::Privatized => {
            // a thread's cyclic elements are locally contiguous
            let ca = b.local_addr(a, Val::I(0));
            let cb = b.local_addr(bb, Val::I(0));
            let cc = b.local_addr(c, Val::I(0));
            b.for_range(Val::I(0), Val::I(per as i64), 1, |b, _| {
                let (x, y) = (b.it(), b.it());
                b.ld(MemWidth::U32, x, ca, 0);
                b.ld(MemWidth::U32, y, cb, 0);
                b.bin(IntOp::Add, x, x, Val::R(y));
                b.st(MemWidth::U32, x, cc, 0);
                b.free_i(y);
                b.free_i(x);
                b.add(ca, ca, Val::I(4));
                b.add(cb, cb, Val::I(4));
                b.add(cc, cc, Val::I(4));
            });
            b.free_i(cc);
            b.free_i(cb);
            b.free_i(ca);
        }
    }
    let module = b.finish("vecadd");

    let (lowering, static_threads) = match variant {
        VecAddVariant::Dynamic => (Lowering::Soft, false),
        VecAddVariant::Static => (Lowering::Soft, true),
        VecAddVariant::Privatized => (Lowering::Soft, true),
        VecAddVariant::Hw => (Lowering::Hw, false),
    };
    let ck = compile(&module, &rt, &leon3_opts(lowering, static_threads, threads));

    let mut m = Leon3Machine::new(threads);
    for i in 0..n {
        rt.write_u64(m.mem_mut(), a, i, i & 0xFFFF);
        rt.write_u64(m.mem_mut(), bb, i, (3 * i + 1) & 0xFFFF);
    }
    let res = m.run(&ck.program);
    for i in 0..n {
        let got = rt.read_u64(m.mem_mut(), c, i);
        let want = ((i & 0xFFFF) + ((3 * i + 1) & 0xFFFF)) & 0xFFFF_FFFF;
        assert_eq!(got, want, "vecadd[{}] {variant:?}", i);
    }
    res
}

/// Run C = A×B over N×N u32 matrices, rows distributed cyclically.
pub fn run_matmul(threads: u32, variant: MatmulVariant, n: u64) -> Leon3Result {
    assert!(n.is_power_of_two() && n >= threads as u64);
    let mut rt = UpcRuntime::new(threads);
    // one row per block, rows cyclic over threads
    let a = rt.alloc_shared("mm_a", n, 4, n * n);
    let bmat = rt.alloc_shared("mm_b", n, 4, n * n);
    let c = rt.alloc_shared("mm_c", n, 4, n * n);
    // private per-thread base-pointer table for the Priv2 variant
    let bp_off = rt.alloc_private(threads as u64 * 8);

    let l2n = n.trailing_zeros() as i64;
    let _l2t = (threads as u64).next_power_of_two().trailing_zeros() as i64;

    let mut b = IrBuilder::new(&mut rt);
    let myt = b.mythread();

    // Priv2 prologue: bp[t] = raw base of B's data on thread t
    if variant == MatmulVariant::Priv2 {
        let pb = b.priv_base();
        let base_va = b.rt.array(bmat).base_va as i64;
        b.for_range(Val::I(0), Val::I(threads as i64), 1, |b, t| {
            let addr = b.it();
            b.bin(IntOp::Add, addr, t, Val::I(1));
            b.bin(IntOp::Sll, addr, addr, Val::I(32));
            b.bin(IntOp::Add, addr, addr, Val::I(base_va));
            let slot = b.it();
            b.bin(IntOp::Sll, slot, t, Val::I(3));
            b.bin(IntOp::Add, slot, slot, Val::R(pb));
            b.st(MemWidth::U64, addr, slot, bp_off as i32);
            b.free_i(slot);
            b.free_i(addr);
        });
        b.free_i(pb);
    }

    // rows r = myt, myt+T, ... — build as loop over local row index
    let rows_per = n / threads as u64; // assumes T divides n (pow2)
    b.for_range(Val::I(0), Val::I(rows_per as i64), 1, |b, lr| {
        // global row r = lr*T + myt
        let r = b.it();
        b.bin(IntOp::Mul, r, lr, Val::I(threads as i64));
        b.bin(IntOp::Add, r, r, Val::R(myt));
        let rbase = b.it();
        b.bin(IntOp::Sll, rbase, r, Val::I(l2n)); // r*N

        b.for_range(Val::I(0), Val::I(n as i64), 1, |b, j| {
            let acc = b.iconst(0);
            match variant {
                MatmulVariant::Static | MatmulVariant::Hw => {
                    // A row walk + B column walk via shared pointers
                    let pa = b.sptr_init(a, Val::R(rbase));
                    let pbm = b.sptr_init(bmat, Val::R(j));
                    b.for_range(Val::I(0), Val::I(n as i64), 1, |b, _k| {
                        let (x, y) = (b.it(), b.it());
                        b.sptr_ld(MemWidth::U32, x, pa, 0);
                        b.sptr_ld(MemWidth::U32, y, pbm, 0);
                        b.bin(IntOp::Mul, x, x, Val::R(y));
                        b.bin(IntOp::Add, acc, acc, Val::R(x));
                        b.free_i(y);
                        b.free_i(x);
                        b.sptr_inc(pa, a, Val::I(1));
                        b.sptr_inc(pbm, bmat, Val::I(n as i64));
                    });
                    b.free_i(pbm);
                    b.free_i(pa);
                    // C[r*N + j]
                    let idx = b.it();
                    b.bin(IntOp::Add, idx, rbase, Val::R(j));
                    let pcp = b.sptr_init(c, Val::R(idx));
                    b.sptr_st(MemWidth::U32, acc, pcp, 0);
                    b.free_i(pcp);
                    b.free_i(idx);
                }
                MatmulVariant::Priv1 | MatmulVariant::Priv2 => {
                    // A row is local: raw cursor (local row index = lr)
                    let ca = b.it();
                    b.bin(IntOp::Sll, ca, lr, Val::I(l2n + 2)); // lr*N*4
                    let la = b.local_addr(a, Val::I(0));
                    b.bin(IntOp::Add, ca, ca, Val::R(la));
                    b.free_i(la);
                    match variant {
                        MatmulVariant::Priv1 => {
                            // B column via shared pointer
                            let pbm = b.sptr_init(bmat, Val::R(j));
                            b.for_range(Val::I(0), Val::I(n as i64), 1, |b, _k| {
                                let (x, y) = (b.it(), b.it());
                                b.ld(MemWidth::U32, x, ca, 0);
                                b.sptr_ld(MemWidth::U32, y, pbm, 0);
                                b.bin(IntOp::Mul, x, x, Val::R(y));
                                b.bin(IntOp::Add, acc, acc, Val::R(x));
                                b.free_i(y);
                                b.free_i(x);
                                b.add(ca, ca, Val::I(4));
                                b.sptr_inc(pbm, bmat, Val::I(n as i64));
                            });
                            b.free_i(pbm);
                        }
                        MatmulVariant::Priv2 => {
                            // the fully hand-optimized structure: split
                            // the k loop by owner thread so every B
                            // access is a stride-N raw cursor off that
                            // thread's base pointer (exact for integer
                            // sums — reassociation is value-safe).
                            // B[k*N+j] with k = tt + T*kk lives on
                            // thread tt at local offset (kk*N + j)*4;
                            // A[r*N + k] walks stride T*4 from base+tt*4.
                            let pb = b.priv_base();
                            b.for_range(Val::I(0), Val::I(threads as i64), 1, |b, tt| {
                                // cb = bp[tt] + j*4, stride N*4
                                let cb = b.it();
                                b.bin(IntOp::Sll, cb, tt, Val::I(3));
                                b.bin(IntOp::Add, cb, cb, Val::R(pb));
                                b.ld(MemWidth::U64, cb, cb, bp_off as i32);
                                let j4 = b.it();
                                b.bin(IntOp::Sll, j4, j, Val::I(2));
                                b.bin(IntOp::Add, cb, cb, Val::R(j4));
                                b.free_i(j4);
                                // cak = ca + tt*4, stride T*4
                                let cak = b.it();
                                b.bin(IntOp::Sll, cak, tt, Val::I(2));
                                b.bin(IntOp::Add, cak, cak, Val::R(ca));
                                b.for_range(
                                    Val::I(0),
                                    Val::I((n / threads as u64) as i64),
                                    1,
                                    |b, _kk| {
                                        let (x, y) = (b.it(), b.it());
                                        b.ld(MemWidth::U32, x, cak, 0);
                                        b.ld(MemWidth::U32, y, cb, 0);
                                        b.bin(IntOp::Mul, x, x, Val::R(y));
                                        b.bin(IntOp::Add, acc, acc, Val::R(x));
                                        b.free_i(y);
                                        b.free_i(x);
                                        b.add(cak, cak, Val::I(4 * threads as i64));
                                        b.add(cb, cb, Val::I((n * 4) as i64));
                                    },
                                );
                                b.free_i(cak);
                                b.free_i(cb);
                            });
                            b.free_i(pb);
                        }
                        _ => unreachable!(),
                    }
                    b.free_i(ca);
                    // C row is local too
                    let cc = b.it();
                    b.bin(IntOp::Sll, cc, lr, Val::I(l2n + 2));
                    let lc = b.local_addr(c, Val::I(0));
                    b.bin(IntOp::Add, cc, cc, Val::R(lc));
                    b.free_i(lc);
                    let cj = b.it();
                    b.bin(IntOp::Sll, cj, j, Val::I(2));
                    b.bin(IntOp::Add, cc, cc, Val::R(cj));
                    b.free_i(cj);
                    b.st(MemWidth::U32, acc, cc, 0);
                    b.free_i(cc);
                }
            }
            b.free_i(acc);
        });
        b.free_i(rbase);
        b.free_i(r);
    });
    let module = b.finish("matmul");

    let lowering = if variant == MatmulVariant::Hw {
        Lowering::Hw
    } else {
        Lowering::Soft
    };
    // matmul was compiled in static mode in the paper
    let ck = compile(&module, &rt, &leon3_opts(lowering, true, threads));

    let mut m = Leon3Machine::new(threads);
    let av: Vec<u64> = (0..n * n).map(|i| (i * 7 + 3) % 50).collect();
    let bv: Vec<u64> = (0..n * n).map(|i| (i * 13 + 1) % 50).collect();
    for i in 0..(n * n) {
        rt.write_u64(m.mem_mut(), a, i, av[i as usize]);
        rt.write_u64(m.mem_mut(), bmat, i, bv[i as usize]);
    }
    let res = m.run(&ck.program);
    for r in 0..n {
        for j in 0..n {
            let want: u64 = (0..n)
                .map(|k| av[(r * n + k) as usize] * bv[(k * n + j) as usize])
                .sum::<u64>()
                & 0xFFFF_FFFF;
            let got = rt.read_u64(m.mem_mut(), c, r * n + j);
            assert_eq!(got, want, "matmul[{r},{j}] {variant:?}");
        }
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vecadd_variant_ordering_matches_figure15() {
        let n = 2048;
        let t = 2;
        let dy = run_vecadd(t, VecAddVariant::Dynamic, n).cycles as f64;
        let st = run_vecadd(t, VecAddVariant::Static, n).cycles as f64;
        let pv = run_vecadd(t, VecAddVariant::Privatized, n).cycles as f64;
        let hw = run_vecadd(t, VecAddVariant::Hw, n).cycles as f64;
        // static ~5x over dynamic; priv/hw ~16x over dynamic; hw ≈ priv
        assert!(dy / st > 2.0, "static speedup {:.2}", dy / st);
        assert!(dy / pv > 6.0, "priv speedup {:.2}", dy / pv);
        assert!(dy / hw > 6.0, "hw speedup {:.2}", dy / hw);
        let ratio = hw / pv;
        assert!((0.6..1.4).contains(&ratio), "hw/priv = {ratio:.2}");
    }

    #[test]
    fn matmul_hw_matches_full_privatization() {
        let n = 16;
        let t = 2;
        let st = run_matmul(t, MatmulVariant::Static, n).cycles as f64;
        let p1 = run_matmul(t, MatmulVariant::Priv1, n).cycles as f64;
        let p2 = run_matmul(t, MatmulVariant::Priv2, n).cycles as f64;
        let hw = run_matmul(t, MatmulVariant::Hw, n).cycles as f64;
        assert!(st > p1 && p1 > p2, "ordering: {st} > {p1} > {p2}");
        let ratio = hw / p2;
        assert!((0.5..1.5).contains(&ratio), "hw/priv2 = {ratio:.2}");
    }

    #[test]
    fn vecadd_single_thread_all_variants_validate() {
        for v in VecAddVariant::ALL {
            let r = run_vecadd(1, v, 512);
            assert!(r.cycles > 0, "{v:?}");
        }
    }
}
