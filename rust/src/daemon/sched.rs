//! Admission control for the daemon: a bounded, fair, round-robin
//! request queue with per-tenant quotas.
//!
//! Every decoded request frame becomes one queue item, keyed by the
//! tenant (session) that sent it.  Three rules:
//!
//! 1. **Fairness** — the executor pool drains tenants round-robin, one
//!    request per turn, with priority tenants' ring drained first.  A
//!    tenant flooding the daemon delays only itself.
//! 2. **Serialization** — at most one request per tenant is in service
//!    at a time ([`pop`](FairQueue::pop) parks the tenant until the
//!    executor calls [`done`](FairQueue::done)).  Replies therefore go
//!    out in request order even against a pipelining client, and a
//!    session's `InstallCtx` is always applied before the ops behind
//!    it.
//! 3. **Load shedding** — admission fails *loudly* (the caller sends a
//!    shed-status reply naming the reason) when the tenant is over its
//!    quota or the global queue is at capacity.  Nothing is silently
//!    dropped and nothing blocks the reader thread.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Condvar, Mutex};

/// Why a request was refused admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The tenant already has `quota` requests queued.
    Quota,
    /// The whole queue is at capacity.
    Capacity,
}

impl ShedReason {
    pub fn describe(&self, tenant: u64, limit: usize) -> String {
        match self {
            ShedReason::Quota => format!(
                "shed: tenant {tenant} exceeded its quota of {limit} queued \
                 requests"
            ),
            ShedReason::Capacity => format!(
                "shed: daemon queue at capacity ({limit}); tenant {tenant} \
                 request dropped"
            ),
        }
    }
}

/// Telemetry snapshot of the queue.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub admitted: u64,
    pub shed_quota: u64,
    pub shed_capacity: u64,
    /// High-water mark of queued (not yet popped) requests.
    pub max_depth: usize,
}

struct QInner<T> {
    /// Per-tenant FIFO of pending requests.
    pending: HashMap<u64, VecDeque<T>>,
    /// Round-robin rings of tenants with pending work and nothing in
    /// service: priority ring drains first.
    ring: VecDeque<u64>,
    ring_priority: VecDeque<u64>,
    /// Tenants with a request currently in service (parked from the
    /// rings until `done`).
    busy: HashSet<u64>,
    total: usize,
    closed: bool,
    stats: QueueStats,
}

/// The queue.  `T` is the work item (the daemon queues decoded frames
/// bundled with their session handle).
pub struct FairQueue<T> {
    inner: Mutex<QInner<T>>,
    cv: Condvar,
    capacity: usize,
    quota: usize,
}

impl<T> FairQueue<T> {
    /// `capacity` bounds the whole queue, `quota` each tenant's share.
    pub fn new(capacity: usize, quota: usize) -> Self {
        Self {
            inner: Mutex::new(QInner {
                pending: HashMap::new(),
                ring: VecDeque::new(),
                ring_priority: VecDeque::new(),
                busy: HashSet::new(),
                total: 0,
                closed: false,
                stats: QueueStats::default(),
            }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
            quota: quota.max(1),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QInner<T>> {
        self.inner.lock().expect("queue mutex")
    }

    /// Admit one request, or shed it.  Never blocks.
    pub fn push(
        &self,
        tenant: u64,
        priority: bool,
        item: T,
    ) -> Result<(), ShedReason> {
        let mut g = self.lock();
        if g.closed {
            // a closing daemon sheds like a full one: loud, bounded
            g.stats.shed_capacity += 1;
            return Err(ShedReason::Capacity);
        }
        if g.total >= self.capacity {
            g.stats.shed_capacity += 1;
            return Err(ShedReason::Capacity);
        }
        let depth = g.pending.get(&tenant).map_or(0, |q| q.len());
        if depth >= self.quota {
            g.stats.shed_quota += 1;
            return Err(ShedReason::Quota);
        }
        g.pending.entry(tenant).or_default().push_back(item);
        g.total += 1;
        g.stats.admitted += 1;
        g.stats.max_depth = g.stats.max_depth.max(g.total);
        // enter the ring unless already ringed or in service
        if depth == 0 && !g.busy.contains(&tenant) {
            if priority {
                g.ring_priority.push_back(tenant);
            } else {
                g.ring.push_back(tenant);
            }
        }
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Take the next request round-robin (priority ring first), parking
    /// its tenant until [`done`](Self::done).  Blocks while empty;
    /// returns `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<(u64, bool, T)> {
        let mut g = self.lock();
        loop {
            let from_priority = !g.ring_priority.is_empty();
            let next = if from_priority {
                g.ring_priority.pop_front()
            } else {
                g.ring.pop_front()
            };
            if let Some(tenant) = next {
                let item = g
                    .pending
                    .get_mut(&tenant)
                    .and_then(|q| q.pop_front())
                    .expect("ringed tenant has pending work");
                g.total -= 1;
                // park: the tenant rejoins a ring in `done`, keeping
                // one-request-per-tenant in service and round-robin
                // fairness in one mechanism
                g.busy.insert(tenant);
                return Some((tenant, from_priority, item));
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).expect("queue mutex");
        }
    }

    /// Mark the tenant's in-service request finished, re-ringing it if
    /// more work is pending.  Executors must call this after replying.
    pub fn done(&self, tenant: u64, priority: bool) {
        let mut g = self.lock();
        g.busy.remove(&tenant);
        if g.pending.get(&tenant).is_some_and(|q| !q.is_empty()) {
            if priority {
                g.ring_priority.push_back(tenant);
            } else {
                g.ring.push_back(tenant);
            }
            drop(g);
            self.cv.notify_one();
        }
    }

    /// Stop admitting; wake every blocked `pop` so executors can drain
    /// the backlog and exit.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    /// Requests queued right now.
    pub fn depth(&self) -> usize {
        self.lock().total
    }

    pub fn stats(&self) -> QueueStats {
        self.lock().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_interleaves_tenants() {
        // A floods 4 requests, then B adds 2: service order must
        // alternate A,B,A,B,A,A — not drain A first.
        let q = FairQueue::new(64, 16);
        for i in 0..4 {
            q.push(1, false, ("a", i)).unwrap();
        }
        for i in 0..2 {
            q.push(2, false, ("b", i)).unwrap();
        }
        let mut order = Vec::new();
        for _ in 0..6 {
            let (tenant, prio, item) = q.pop().unwrap();
            order.push(item);
            q.done(tenant, prio);
        }
        assert_eq!(
            order,
            vec![("a", 0), ("b", 0), ("a", 1), ("b", 1), ("a", 2), ("a", 3)]
        );
    }

    #[test]
    fn priority_ring_drains_first() {
        let q = FairQueue::new(64, 16);
        q.push(1, false, "normal-0").unwrap();
        q.push(9, true, "prio-0").unwrap();
        q.push(1, false, "normal-1").unwrap();
        q.push(9, true, "prio-1").unwrap();
        let mut order = Vec::new();
        for _ in 0..4 {
            let (tenant, prio, item) = q.pop().unwrap();
            order.push(item);
            q.done(tenant, prio);
        }
        assert_eq!(order, vec!["prio-0", "prio-1", "normal-0", "normal-1"]);
    }

    #[test]
    fn one_request_per_tenant_in_service() {
        let q = FairQueue::new(64, 16);
        q.push(1, false, 0).unwrap();
        q.push(1, false, 1).unwrap();
        let (t, prio, first) = q.pop().unwrap();
        assert_eq!(first, 0);
        // with tenant 1 parked the queue looks empty to a second
        // executor even though request 1 is pending — replies stay in
        // request order per session
        q.close(); // so pop() returns instead of blocking
        assert!(q.pop().is_none(), "parked tenant must not be served twice");
        q.done(t, prio);
        let (_, _, second) = q.pop().unwrap();
        assert_eq!(second, 1, "pending work resumes after done()");
    }

    #[test]
    fn quota_and_capacity_shed_loudly() {
        let q = FairQueue::new(3, 2);
        q.push(1, false, ()).unwrap();
        q.push(1, false, ()).unwrap();
        assert_eq!(q.push(1, false, ()), Err(ShedReason::Quota));
        q.push(2, false, ()).unwrap(); // fills capacity 3
        assert_eq!(q.push(3, false, ()), Err(ShedReason::Capacity));
        let s = q.stats();
        assert_eq!(s.admitted, 3);
        assert_eq!(s.shed_quota, 1);
        assert_eq!(s.shed_capacity, 1);
        assert_eq!(s.max_depth, 3);
        assert_eq!(q.depth(), 3);
        // shed messages name the tenant and the limit
        assert!(ShedReason::Quota.describe(1, 2).contains("tenant 1"));
        assert!(ShedReason::Capacity.describe(3, 3).contains("capacity"));
    }

    #[test]
    fn close_drains_then_ends() {
        let q = FairQueue::new(8, 8);
        q.push(1, false, 7).unwrap();
        q.close();
        assert_eq!(q.push(1, false, 8), Err(ShedReason::Capacity));
        let (t, prio, v) = q.pop().expect("backlog drains after close");
        assert_eq!(v, 7);
        q.done(t, prio);
        assert!(q.pop().is_none());
    }
}
