//! In-lib daemon tests: an in-process [`Daemon`] serving real
//! `RemoteEngine::connect` clients (no spawned processes), plus raw
//! socket clients for the admission-control paths — `executors: 0`
//! makes the shed behavior deterministic (nothing drains the queue).

use super::*;
use crate::engine::remote::{
    encode_install_request, encode_map_request, read_frame, write_frame, Op,
    RemoteEngine, STATUS_SHED,
};
use crate::engine::{AddressEngine, BatchOut, EngineCtx, PtrBatch, SoftwareEngine};
use crate::sptr::{ArrayLayout, BaseTable, SharedPtr, WireReader};

fn test_ctx(
    blocksize: u64,
    threads: u32,
) -> (ArrayLayout, BaseTable) {
    let layout = ArrayLayout::new(blocksize, 8, threads);
    let table = BaseTable::regular(threads, 1 << 32, 1 << 32);
    (layout, table)
}

/// Poll the daemon's live stats until `f` holds (readers and executors
/// are asynchronous; tests synchronize on telemetry, never on sleeps).
fn wait_until(daemon: &Daemon, f: impl Fn(&DaemonStats) -> bool) {
    for _ in 0..5000 {
        if f(&daemon.stats()) {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    panic!("daemon did not reach the expected state within 5s");
}

#[test]
fn daemon_serves_epoch_sessions_bit_identical_to_host() {
    let cfg = DaemonCfg::new(scratch_socket("lib-roundtrip"));
    let socket = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    {
        let remote = RemoteEngine::connect(&socket, 2)
            .expect("client connects")
            .with_min_shard_len(1); // force fan-out over both sessions
        let (layout, table) = test_ctx(3, 5); // non-pow2: software path
        let ctx = EngineCtx::new(layout, &table, 2).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..777u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 3), i % 11);
        }
        let (mut got, mut want) = (BatchOut::new(), BatchOut::new());
        remote.translate(&ctx, &batch, &mut got).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();
        assert_eq!(got, want);
        // steady state: the second request rides the installed epochs
        remote.walk(&ctx, SharedPtr::NULL, 7, 501, &mut got).unwrap();
        SoftwareEngine.walk(&ctx, SharedPtr::NULL, 7, 501, &mut want).unwrap();
        assert_eq!(got, want);
        assert!(remote.installs() >= 2, "one install per connection");
        assert!(remote.epoch_hits() >= 1, "walk reused the epochs");
        assert_eq!(remote.reinstalls(), 0);
        let live = daemon.stats();
        assert_eq!(live.sessions, 2);
        assert_eq!(live.stale_epochs, 0);
    }
    // client dropped: sessions are closed, shutdown can join readers
    let stats = daemon.shutdown().expect("clean shutdown");
    assert_eq!(stats.sessions, 2);
    assert!(stats.served >= 2);
    assert!(stats.epoch_hits >= 1);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.queue.shed_quota + stats.queue.shed_capacity, 0);
    // admitted = installs + served ops + the clients' Shutdown frames
    assert!(stats.queue.admitted >= stats.served + stats.installs);
}

#[test]
fn forced_epoch_mismatch_reinstalls_transparently() {
    let cfg = DaemonCfg::new(scratch_socket("lib-stale"));
    let socket = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    {
        let remote = RemoteEngine::connect(&socket, 1).expect("connect");
        let (layout, table) = test_ctx(4, 4);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i), i);
        }
        let mut out = Vec::new();
        remote.increment(&ctx, &batch, &mut out).unwrap();
        // desync the client's idea of its epoch: the next request draws
        // a stale-epoch reply and must re-install + retry, invisibly
        remote.force_epoch_mismatch();
        let mut again = Vec::new();
        remote.increment(&ctx, &batch, &mut again).unwrap();
        assert_eq!(out, again);
        assert_eq!(remote.reinstalls(), 1);
        assert_eq!(remote.installs(), 2);
    }
    let stats = daemon.shutdown().expect("clean shutdown");
    assert_eq!(stats.stale_epochs, 1, "the daemon counted the stale hit");
}

/// Raw-socket client for the shed paths: `RemoteEngine` is synchronous
/// per request, so only a hand-rolled pipelining client can overfill
/// the queue.
fn raw_client(socket: &std::path::Path) -> std::os::unix::net::UnixStream {
    std::os::unix::net::UnixStream::connect(socket).expect("connect")
}

fn shed_message(reply: &[u8]) -> String {
    let mut r = WireReader::new(reply);
    r.get_u32().unwrap(); // magic
    r.get_u16().unwrap(); // version
    assert_eq!(r.get_u8().unwrap(), STATUS_SHED, "expected a shed reply");
    let n = r.get_count(1).unwrap();
    String::from_utf8_lossy(r.get_bytes(n).unwrap()).into_owned()
}

#[test]
fn graceful_drain_refuses_new_frames_and_finishes_queued_work() {
    let cfg = DaemonCfg::new(scratch_socket("lib-drain"));
    let socket = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    {
        let remote = RemoteEngine::connect(&socket, 1).expect("connect");
        let (layout, table) = test_ctx(4, 4);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..32u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i), i);
        }
        let mut out = Vec::new();
        remote.increment(&ctx, &batch, &mut out).unwrap();
        daemon.begin_drain();
        assert!(daemon.draining());
        // a new frame is refused with the distinct draining status —
        // a loud per-request failure, not a hung or severed connection
        let err = remote.increment(&ctx, &batch, &mut out).unwrap_err();
        assert!(err.to_string().contains("draining"), "{err}");
    }
    let stats = daemon.shutdown().expect("clean shutdown");
    assert!(stats.drain_refusals >= 1, "the refusal was counted");
    assert!(stats.served >= 1, "pre-drain work was served normally");
}

#[test]
fn injected_shed_storm_sheds_every_op_but_sessions_survive() {
    let mut cfg = DaemonCfg::new(scratch_socket("lib-chaos-shed"));
    cfg.chaos = Some(crate::engine::FaultSpec::parse("0xFA57:shed=1.0").unwrap());
    let socket = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    {
        let remote = RemoteEngine::connect(&socket, 1).expect("connect");
        let (layout, table) = test_ctx(4, 4);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 1);
        let mut out = Vec::new();
        for _ in 0..2 {
            let err = remote.increment(&ctx, &batch, &mut out).unwrap_err();
            assert!(err.to_string().contains("shed"), "{err}");
        }
        assert_eq!(remote.reconnects(), 0, "shed replies must not cost heals");
    }
    let stats = daemon.shutdown().expect("clean shutdown");
    assert!(stats.shed >= 2, "injected sheds were counted per tenant");
}

#[test]
fn injected_stale_storm_exhausts_the_reinstall_budget_loudly() {
    let mut cfg = DaemonCfg::new(scratch_socket("lib-chaos-stale"));
    cfg.chaos =
        Some(crate::engine::FaultSpec::parse("0xFA57:stale=1.0").unwrap());
    let socket = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    {
        let remote = RemoteEngine::connect(&socket, 1).expect("connect");
        let (layout, table) = test_ctx(4, 4);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 1);
        let mut out = Vec::new();
        // every op draws an injected stale: the client re-installs up
        // to its budget (real installs — InstallCtx is never faulted),
        // then gives up loudly instead of looping forever
        let err = remote.increment(&ctx, &batch, &mut out).unwrap_err();
        assert!(err.to_string().contains("stale"), "{err}");
        assert_eq!(remote.stale_failures(), 1);
        assert_eq!(
            remote.reinstalls(),
            u64::from(RemoteEngine::MAX_STALE_REINSTALLS)
        );
    }
    let stats = daemon.shutdown().expect("clean shutdown");
    assert!(stats.stale_epochs >= 1);
}

#[test]
fn over_quota_tenant_is_shed_loudly() {
    let mut cfg = DaemonCfg::new(scratch_socket("lib-quota"));
    cfg.executors = 0; // nothing drains: queued frames stay queued
    cfg.quota = 2;
    let socket = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    {
        let mut stream = raw_client(&socket);
        let (layout, table) = test_ctx(4, 4);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let ptrs = [SharedPtr::NULL];
        let incs = [1u64];
        write_frame(&mut stream, &encode_install_request(1, false, &ctx)).unwrap();
        for _ in 0..2 {
            write_frame(
                &mut stream,
                &encode_map_request(Op::Increment, 1, &ptrs, &incs),
            )
            .unwrap();
        }
        // install + op fill the quota of 2; the second op is shed, and
        // with no executors the shed reply is the only reply coming
        let reply = read_frame(&mut stream).unwrap().expect("shed reply");
        let msg = shed_message(&reply);
        assert!(msg.contains("quota"), "{msg}");
        assert!(msg.contains("tenant 0"), "{msg}");
    }
    let stats = daemon.shutdown().expect("clean shutdown");
    assert_eq!(stats.queue.shed_quota, 1);
    assert_eq!(stats.shed, 1, "the tenant's shed counter advanced");
    assert_eq!(stats.queue.admitted, 2);
}

#[test]
fn queue_at_capacity_sheds_the_newcomer() {
    let mut cfg = DaemonCfg::new(scratch_socket("lib-capacity"));
    cfg.executors = 0;
    cfg.queue_cap = 1;
    cfg.quota = 8;
    let socket = cfg.socket.clone();
    let daemon = Daemon::spawn(cfg).expect("daemon spawns");
    {
        let mut first = raw_client(&socket);
        let (layout, table) = test_ctx(4, 4);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        write_frame(&mut first, &encode_install_request(1, false, &ctx)).unwrap();
        // readers are asynchronous: wait until the first frame is
        // actually queued before racing the second tenant against it
        wait_until(&daemon, |s| s.queue.admitted == 1);
        // the single queue slot is now taken; a second tenant is shed
        let mut second = raw_client(&socket);
        write_frame(&mut second, &encode_install_request(1, false, &ctx)).unwrap();
        let reply = read_frame(&mut second).unwrap().expect("shed reply");
        let msg = shed_message(&reply);
        assert!(msg.contains("capacity"), "{msg}");
    }
    let stats = daemon.shutdown().expect("clean shutdown");
    assert_eq!(stats.queue.shed_capacity, 1);
    assert_eq!(stats.queue.admitted, 1);
    assert_eq!(stats.sessions, 2);
}
