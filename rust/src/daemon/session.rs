//! Session state and the protocol-v2 frame handler shared by the
//! daemon's executor pool and the single-session `serve-engine` worker.
//!
//! A *session* is one client connection.  Its state machine:
//!
//! ```text
//!            InstallCtx{epoch,ctx}          op{epoch} (match)
//!  [empty] ───────────────────────▶ [epoch E installed] ─────▶ serve
//!                                       │        ▲
//!                op{epoch≠E}            │        │ InstallCtx{E'}
//!                (stale-epoch reply) ◀──┘        │ (re-install)
//! ```
//!
//! `InstallCtx` decodes and **validates** the ctx snapshot once; the
//! cached [`InstalledCtx`] then serves every steady-state request with
//! a zero-copy [`EngineCtx`] view — no per-request wire decode, no
//! per-request table allocation, and the pow2-vs-software engine choice
//! is latched at install time instead of being re-derived per frame
//! (the PR 5 per-request rebuild this replaces).  A request naming any
//! other epoch gets a *stale-epoch* reply and changes nothing; the
//! client re-installs and retries.

use std::os::unix::net::UnixStream;
use std::sync::{Arc, Mutex};

use super::lease::AccelLease;
use crate::cpu::EngineMix;
use crate::engine::remote::{
    error_body, ok_header, reply_frame_bytes, reply_status_body, Op, MAGIC,
    MAX_FRAME, PROTOCOL_VERSION, STATUS_SHED, STATUS_STALE_EPOCH,
};
use crate::engine::{
    AddressEngine, BatchOut, EngineChoice, EngineCtx, FaultPlan, Leon3Engine,
    Pow2Engine, PtrBatch, SoftwareEngine, WireFault,
};
use crate::sptr::{CtxSnapshot, WireReader};

/// Per-tenant telemetry, reported by the daemon's stats table and the
/// `daemon` bench section.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    pub id: u64,
    pub priority: bool,
    /// Map/walk requests answered OK.
    pub served: u64,
    /// `InstallCtx` messages applied.
    pub installs: u64,
    /// Requests served against an already-installed epoch (the
    /// protocol's amortization working).
    pub epoch_hits: u64,
    /// Requests refused with a stale-epoch reply.
    pub stale_epochs: u64,
    /// Requests shed by admission control (filled by the daemon layer).
    pub shed: u64,
    /// Pointers mapped across all served requests.
    pub ptrs: u64,
    /// Which backend served each request (pow2 / software / leon3).
    pub mix: EngineMix,
}

impl TenantStats {
    pub fn merge(&mut self, o: &TenantStats) {
        self.served += o.served;
        self.installs += o.installs;
        self.epoch_hits += o.epoch_hits;
        self.stale_epochs += o.stale_epochs;
        self.shed += o.shed;
        self.ptrs += o.ptrs;
        self.mix.merge(&o.mix);
    }
}

/// The decoded, validated ctx snapshot cached for one epoch.
struct InstalledCtx {
    snap: CtxSnapshot,
    /// Latched at install: does the pow2 shift/mask datapath (and the
    /// Leon3 coprocessor, same geometry contract) serve this layout?
    pow2: bool,
}

impl InstalledCtx {
    /// A borrow-view `EngineCtx` over the cached parts — O(1), no
    /// decode, no allocation.  Infallible because `install` already ran
    /// the checked constructor on these exact values.
    fn view(&self) -> EngineCtx<'_> {
        EngineCtx::new(self.snap.layout, &self.snap.table, self.snap.mythread)
            .expect("ctx was validated at install")
            .with_topology(self.snap.topo)
    }
}

/// One client session's protocol state + telemetry.
pub struct SessionState {
    epoch: Option<u64>,
    ctx: Option<InstalledCtx>,
    /// Set by `InstallCtx`; routes this tenant through the lease's
    /// priority path and the scheduler's priority ring.
    pub priority: bool,
    pub stats: TenantStats,
}

impl SessionState {
    pub fn new(id: u64) -> Self {
        Self {
            epoch: None,
            ctx: None,
            priority: false,
            stats: TenantStats { id, ..TenantStats::default() },
        }
    }
}

/// What the daemon can execute requests on: the host engines always,
/// plus (optionally) the one Leon3 coprocessor unit behind its lease.
pub struct ExecBackend {
    accel: Option<AccelBackend>,
    /// Seeded server-side fault schedule, consulted once per *map/walk*
    /// frame (never for `InstallCtx`/`Ping`, so the client's re-install
    /// machinery is exercised against real installs): shed storms,
    /// forced stale epochs, and injected execution errors.
    chaos: Option<Arc<FaultPlan>>,
}

struct AccelBackend {
    engine: Leon3Engine,
    lease: Arc<AccelLease>,
    /// Minimum batch size worth contending for the device.
    threshold: usize,
}

impl ExecBackend {
    /// Host engines only — what the single-session `serve-engine`
    /// worker uses (no device to arbitrate).
    pub fn host_only() -> Self {
        Self { accel: None, chaos: None }
    }

    /// Host engines plus the Leon3 unit, leased exclusively.  Batches
    /// of at least `threshold` pointers on pow2 layouts try the device:
    /// priority tenants block for it (jumping normal tenants), normal
    /// tenants take it only when free and uncontended.
    pub fn with_leon3(lease: Arc<AccelLease>, threshold: usize) -> Self {
        Self {
            accel: Some(AccelBackend {
                engine: Leon3Engine::new(),
                lease,
                threshold: threshold.max(1),
            }),
            chaos: None,
        }
    }

    /// Install a seeded server-side fault schedule (see the `chaos`
    /// field).  Every injected fault is answered with a well-formed
    /// non-ok reply; the session itself always survives.
    pub fn with_chaos(mut self, plan: Arc<FaultPlan>) -> Self {
        self.chaos = Some(plan);
        self
    }

    /// Draw the injected fault (if any) for one served op frame.
    fn draw_fault(&self) -> Option<WireFault> {
        self.chaos.as_deref().and_then(|p| p.wire_fault())
    }

    pub fn lease_stats(&self) -> Option<super::lease::LeaseStats> {
        self.accel.as_ref().map(|a| a.lease.stats())
    }

    /// Pick the engine for an `n`-pointer request.  The returned guard
    /// (when the accelerator won) must stay live for the call.
    fn pick(
        &self,
        priority: bool,
        pow2: bool,
        n: usize,
    ) -> (EngineChoice, &dyn AddressEngine, Option<super::lease::LeaseGuard<'_>>)
    {
        if let Some(acc) = &self.accel {
            if pow2 && n >= acc.threshold {
                let guard = if priority {
                    Some(acc.lease.acquire_priority())
                } else {
                    acc.lease.try_acquire()
                };
                if guard.is_some() {
                    return (EngineChoice::Leon3, &acc.engine, guard);
                }
            }
        }
        if pow2 {
            (EngineChoice::Pow2, &Pow2Engine, None)
        } else {
            (EngineChoice::Software, &SoftwareEngine, None)
        }
    }
}

enum HandleErr {
    /// Generic error reply (status 1).
    Error(String),
    /// Stale-epoch reply (status 2): the client should re-install.
    Stale(String),
    /// Shed reply (status 3): loud, never retried (chaos shed storms).
    Shed(String),
}

/// Map an injected server-side fault onto the protocol's refusal
/// vocabulary — always a well-formed reply, never a dead session.
fn injected_refusal(fault: WireFault, sess: &mut SessionState) -> HandleErr {
    match fault {
        WireFault::Shed => {
            HandleErr::Shed("chaos: injected shed storm".into())
        }
        WireFault::Stale => {
            sess.stats.stale_epochs += 1;
            // drop the installed ctx so the client's re-install is real
            sess.epoch = None;
            sess.ctx = None;
            HandleErr::Stale("chaos: session state injected away".into())
        }
        WireFault::Drop
        | WireFault::Kill
        | WireFault::Corrupt
        | WireFault::Truncate => {
            HandleErr::Error("chaos: injected server fault".into())
        }
    }
}

impl From<crate::sptr::WireError> for HandleErr {
    fn from(e: crate::sptr::WireError) -> Self {
        HandleErr::Error(e.to_string())
    }
}

/// Serve one request frame against one session.  Returns the response
/// body and whether the session should end (`Shutdown`).
pub fn handle_frame(
    frame: &[u8],
    sess: &mut SessionState,
    exec: &ExecBackend,
) -> (Vec<u8>, bool) {
    match try_handle(frame, sess, exec) {
        Ok(reply) => reply,
        Err(HandleErr::Error(m)) => (error_body(&m), false),
        Err(HandleErr::Stale(m)) => {
            (reply_status_body(STATUS_STALE_EPOCH, &m), false)
        }
        Err(HandleErr::Shed(m)) => {
            sess.stats.shed += 1;
            (reply_status_body(STATUS_SHED, &m), false)
        }
    }
}

fn try_handle(
    frame: &[u8],
    sess: &mut SessionState,
    exec: &ExecBackend,
) -> Result<(Vec<u8>, bool), HandleErr> {
    let mut r = WireReader::new(frame);
    let magic = r.get_u32()?;
    if magic != MAGIC {
        return Err(HandleErr::Error(format!(
            "request magic {magic:#x} != {MAGIC:#x}"
        )));
    }
    let version = r.get_u16()?;
    if version != PROTOCOL_VERSION {
        return Err(HandleErr::Error(format!(
            "client speaks protocol v{version}, server v{PROTOCOL_VERSION}"
        )));
    }
    let op = Op::from_u8(r.get_u8()?)
        .ok_or_else(|| HandleErr::Error("unknown op".into()))?;
    match op {
        Op::Ping => Ok((ok_header().into_bytes(), false)),
        Op::Shutdown => Ok((ok_header().into_bytes(), true)),
        Op::InstallCtx => {
            let epoch = r.get_u64()?;
            let priority = r.get_u8()? != 0;
            let snap = r.get_ctx_snapshot()?;
            r.finish()?;
            // the one validation per epoch: every later view() reuses it
            EngineCtx::new(snap.layout, &snap.table, snap.mythread)
                .map_err(|e| HandleErr::Error(e.to_string()))?;
            let pow2 = Pow2Engine.supports(&snap.layout);
            sess.epoch = Some(epoch);
            sess.ctx = Some(InstalledCtx { snap, pow2 });
            sess.priority = priority;
            sess.stats.priority = priority;
            sess.stats.installs += 1;
            Ok((ok_header().into_bytes(), false))
        }
        Op::Translate | Op::Increment => {
            let epoch = r.get_u64()?;
            check_epoch(sess, epoch)?;
            if let Some(fault) = exec.draw_fault() {
                return Err(injected_refusal(fault, sess));
            }
            // 28 = ptr 20 + inc 8: bound the allocation by the frame
            let n = r.get_count(28)?;
            // replies are wider than requests (29 B/result vs 28), so a
            // near-cap request could produce an over-cap reply — refuse
            // loudly instead of desyncing the stream
            if reply_frame_bytes(n) > MAX_FRAME {
                return Err(HandleErr::Error(format!(
                    "batch of {n} requests would exceed the reply frame cap"
                )));
            }
            let mut batch = PtrBatch::with_capacity(n);
            for _ in 0..n {
                batch.ptrs.push(r.get_ptr()?);
            }
            for _ in 0..n {
                batch.incs.push(r.get_u64()?);
            }
            r.finish()?;
            let installed = sess.ctx.as_ref().expect("checked epoch");
            let (choice, engine, _guard) =
                exec.pick(sess.priority, installed.pow2, n);
            let ctx = installed.view();
            let reply = if op == Op::Translate {
                let mut out = BatchOut::new();
                engine
                    .translate(&ctx, &batch, &mut out)
                    .map_err(|e| HandleErr::Error(e.to_string()))?;
                let mut w = ok_header();
                crate::engine::remote::encode_batch_out(&mut w, &out);
                w.into_bytes()
            } else {
                let mut out = Vec::new();
                engine
                    .increment(&ctx, &batch, &mut out)
                    .map_err(|e| HandleErr::Error(e.to_string()))?;
                let mut w = ok_header();
                w.put_u32(out.len() as u32);
                for p in &out {
                    w.put_ptr(p);
                }
                w.into_bytes()
            };
            record_served(sess, choice, n as u64);
            Ok((reply, false))
        }
        Op::Walk => {
            let epoch = r.get_u64()?;
            check_epoch(sess, epoch)?;
            if let Some(fault) = exec.draw_fault() {
                return Err(injected_refusal(fault, sess));
            }
            let start = r.get_ptr()?;
            let inc = r.get_u64()?;
            let steps = r.get_u64()?;
            r.finish()?;
            let steps = usize::try_from(steps).map_err(|_| {
                HandleErr::Error("walk steps exceed usize".into())
            })?;
            // the reply must fit one frame; refuse before allocating
            if reply_frame_bytes(steps) > MAX_FRAME {
                return Err(HandleErr::Error(format!(
                    "walk of {steps} steps would exceed the frame cap"
                )));
            }
            let installed = sess.ctx.as_ref().expect("checked epoch");
            let (choice, engine, _guard) =
                exec.pick(sess.priority, installed.pow2, steps);
            let ctx = installed.view();
            let mut out = BatchOut::new();
            engine
                .walk(&ctx, start, inc, steps, &mut out)
                .map_err(|e| HandleErr::Error(e.to_string()))?;
            let mut w = ok_header();
            crate::engine::remote::encode_batch_out(&mut w, &out);
            record_served(sess, choice, steps as u64);
            Ok((w.into_bytes(), false))
        }
    }
}

fn check_epoch(sess: &mut SessionState, epoch: u64) -> Result<(), HandleErr> {
    if sess.epoch == Some(epoch) && sess.ctx.is_some() {
        return Ok(());
    }
    sess.stats.stale_epochs += 1;
    Err(HandleErr::Stale(match sess.epoch {
        Some(have) => format!(
            "stale epoch: request names {epoch}, session has {have} installed"
        ),
        None => format!(
            "stale epoch: request names {epoch}, session has no ctx installed"
        ),
    }))
}

fn record_served(sess: &mut SessionState, choice: EngineChoice, ptrs: u64) {
    sess.stats.served += 1;
    sess.stats.epoch_hits += 1;
    sess.stats.ptrs += ptrs;
    sess.stats.mix.runs[choice.index()] += 1;
}

// -------------------------------------------------------------- registry

/// One live (or finished) session as the daemon tracks it: protocol
/// state behind one lock, the reply half of the socket behind another
/// (the reader thread writes shed replies, the executor writes served
/// replies — never interleaved mid-frame).
pub struct SessionHandle {
    pub id: u64,
    pub state: Mutex<SessionState>,
    pub writer: Mutex<UnixStream>,
}

/// All sessions the daemon has ever accepted, id-ordered.  Finished
/// sessions stay registered so end-of-run stats include every tenant.
#[derive(Default)]
pub struct SessionRegistry {
    sessions: Mutex<Vec<Arc<SessionHandle>>>,
}

impl SessionRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit a new connection: allocate the next session id and
    /// register its handle.
    pub fn register(&self, writer: UnixStream) -> Arc<SessionHandle> {
        let mut g = self.sessions.lock().expect("registry mutex");
        let id = g.len() as u64;
        let handle = Arc::new(SessionHandle {
            id,
            state: Mutex::new(SessionState::new(id)),
            writer: Mutex::new(writer),
        });
        g.push(Arc::clone(&handle));
        handle
    }

    pub fn len(&self) -> usize {
        self.sessions.lock().expect("registry mutex").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-tenant stats snapshot, id-ordered.
    pub fn snapshot(&self) -> Vec<TenantStats> {
        let g = self.sessions.lock().expect("registry mutex");
        g.iter()
            .map(|s| s.state.lock().expect("session mutex").stats.clone())
            .collect()
    }
}
