//! The daemon tier: address mapping as **shared, multi-tenant
//! infrastructure** — `pgas-hw daemon --socket PATH`.
//!
//! PR 5's `serve-engine` worker made the [`AddressEngine`] a
//! process-level service, but one worker serves exactly one session and
//! every request re-ships the full `EngineCtx`.  This module is the
//! paper's thesis taken to its conclusion (and the DASH stance from
//! PAPERS.md: the *runtime* adapts and arbitrates, not the user): one
//! daemon process serves **many concurrent client sessions** over one
//! Unix-domain socket, with
//!
//! * **epoch sessions** ([`session`]) — each session installs its ctx
//!   once per epoch (`InstallCtx{epoch}`) and steady-state requests
//!   carry only `epoch + PtrBatch`; the decoded ctx and the engine
//!   choice are cached per epoch, never rebuilt per request;
//! * **admission control** ([`sched`]) — a bounded, fair round-robin
//!   queue with per-tenant quotas that sheds overload *loudly*
//!   (shed-status replies naming the reason, counted per tenant);
//! * **accelerator leasing** ([`lease`]) — the one Leon3 coprocessor
//!   unit behind an exclusive lease with a priority path, so a
//!   high-priority tenant jumps the device queue while normal tenants
//!   fall back to the host engines instead of blocking.
//!
//! The client side is [`RemoteEngine::connect`](crate::engine::RemoteEngine::connect)
//! — the same scatter/gather engine that supervises spawned workers,
//! pointed at a daemon socket instead.
//!
//! [`AddressEngine`]: crate::engine::AddressEngine

pub mod lease;
pub mod sched;
pub mod session;

use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::engine::remote::{
    read_frame, reply_status_body, write_frame, Op, STATUS_DRAINING,
    STATUS_SHED,
};
use crate::engine::{FaultPlan, FaultSpec};
use lease::{AccelLease, LeaseStats};
use sched::{FairQueue, QueueStats, ShedReason};
use session::{ExecBackend, SessionHandle, SessionRegistry, TenantStats};

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct DaemonCfg {
    pub socket: PathBuf,
    /// Executor threads draining the request queue.  `0` is a test
    /// knob: nothing executes, so the shed paths are deterministic.
    pub executors: usize,
    /// Global queue capacity (requests).
    pub queue_cap: usize,
    /// Per-tenant quota of queued requests.
    pub quota: usize,
    /// Minimum batch size that contends for the Leon3 unit.
    pub accel_threshold: usize,
    /// Exit after this many sessions have been accepted and served to
    /// completion (`None` = serve forever).
    pub max_sessions: Option<u64>,
    /// Seeded server-side fault schedule (shed storms, forced stale
    /// epochs, injected execution errors) applied to every served
    /// map/walk frame.  `None` = no injection.
    pub chaos: Option<FaultSpec>,
}

impl DaemonCfg {
    pub fn new(socket: impl Into<PathBuf>) -> Self {
        Self {
            socket: socket.into(),
            executors: 2,
            queue_cap: 256,
            quota: 64,
            accel_threshold: 8192,
            max_sessions: None,
            chaos: None,
        }
    }
}

/// End-of-run (or live) telemetry snapshot.
#[derive(Clone, Debug, Default)]
pub struct DaemonStats {
    pub sessions: u64,
    /// Aggregates over all tenants.
    pub served: u64,
    pub installs: u64,
    pub epoch_hits: u64,
    pub stale_epochs: u64,
    pub shed: u64,
    /// Frames refused with `STATUS_DRAINING` during graceful drain.
    pub drain_refusals: u64,
    pub queue: QueueStats,
    pub lease: LeaseStats,
    pub tenants: Vec<TenantStats>,
}

impl DaemonStats {
    fn collect(shared: &Shared) -> Self {
        let tenants = shared.registry.snapshot();
        let mut s = DaemonStats {
            sessions: tenants.len() as u64,
            drain_refusals: shared.drain_refusals.load(Ordering::Relaxed),
            queue: shared.queue.stats(),
            lease: shared.exec.lease_stats().unwrap_or_default(),
            tenants,
            ..DaemonStats::default()
        };
        for t in &s.tenants {
            s.served += t.served;
            s.installs += t.installs;
            s.epoch_hits += t.epoch_hits;
            s.stale_epochs += t.stale_epochs;
            s.shed += t.shed;
        }
        s
    }
}

struct Job {
    sess: Arc<SessionHandle>,
    frame: Vec<u8>,
}

struct Shared {
    registry: SessionRegistry,
    queue: FairQueue<Job>,
    exec: ExecBackend,
    accepting: AtomicBool,
    /// Graceful drain: in-flight (queued) requests still finish, but
    /// every *new* frame is answered `STATUS_DRAINING` by its reader.
    draining: AtomicBool,
    drain_refusals: AtomicU64,
    quota: usize,
    queue_cap: usize,
}

/// A running daemon: accept thread + reader thread per session +
/// executor pool, all sharing one registry/queue/lease.
pub struct Daemon {
    shared: Arc<Shared>,
    socket: PathBuf,
    accept: Option<JoinHandle<Result<(), String>>>,
    executors: Vec<JoinHandle<()>>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Daemon {
    /// Bind the socket and start serving in background threads.
    pub fn spawn(cfg: DaemonCfg) -> Result<Self, String> {
        let _ = std::fs::remove_file(&cfg.socket);
        let listener = UnixListener::bind(&cfg.socket).map_err(|e| {
            format!("daemon: bind {}: {e}", cfg.socket.display())
        })?;
        let lease = Arc::new(AccelLease::new());
        let mut exec = ExecBackend::with_leon3(lease, cfg.accel_threshold);
        if let Some(spec) = cfg.chaos {
            exec = exec.with_chaos(Arc::new(FaultPlan::new(spec)));
        }
        let shared = Arc::new(Shared {
            registry: SessionRegistry::new(),
            queue: FairQueue::new(cfg.queue_cap, cfg.quota),
            exec,
            accepting: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            drain_refusals: AtomicU64::new(0),
            quota: cfg.quota,
            queue_cap: cfg.queue_cap,
        });
        let executors = (0..cfg.executors)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || executor_loop(&shared))
            })
            .collect();
        let readers = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let (shared, readers) = (Arc::clone(&shared), Arc::clone(&readers));
            let max = cfg.max_sessions;
            std::thread::spawn(move || accept_loop(&shared, listener, max, &readers))
        };
        Ok(Self {
            shared,
            socket: cfg.socket,
            accept: Some(accept),
            executors,
            readers,
        })
    }

    /// Live telemetry (sessions may still be running).
    pub fn stats(&self) -> DaemonStats {
        DaemonStats::collect(&self.shared)
    }

    /// Start a graceful drain: everything already admitted to the
    /// queue finishes and its replies go out, but every frame read
    /// *after* this call is refused with a `STATUS_DRAINING` reply —
    /// no session is ever abandoned mid-request.  Idempotent.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a graceful drain is in progress.
    pub fn draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Block until the accept loop ends (`max_sessions` reached) and
    /// every accepted session has disconnected, then drain the queue
    /// and return final stats.  With `max_sessions: None` this blocks
    /// until the process is killed.
    pub fn wait(mut self) -> Result<DaemonStats, String> {
        let accept = self.accept.take().expect("wait/shutdown called once");
        accept.join().map_err(|_| "daemon: accept thread panicked")??;
        self.teardown()
    }

    /// Graceful exit: drain (in-flight requests finish, new frames
    /// draw `STATUS_DRAINING`), stop accepting, then as
    /// [`wait`](Self::wait).  Callers must close their client sessions
    /// — reader threads are joined, and a reader lives as long as its
    /// client's connection.
    pub fn shutdown(mut self) -> Result<DaemonStats, String> {
        self.begin_drain();
        self.shared.accepting.store(false, Ordering::SeqCst);
        // wake the blocking accept() with a throwaway connection
        let _ = UnixStream::connect(&self.socket);
        let accept = self.accept.take().expect("wait/shutdown called once");
        accept.join().map_err(|_| "daemon: accept thread panicked")??;
        self.teardown()
    }

    fn teardown(self) -> Result<DaemonStats, String> {
        // readers end when their clients disconnect
        loop {
            let handles: Vec<_> =
                std::mem::take(&mut *self.readers.lock().expect("readers"));
            if handles.is_empty() {
                break;
            }
            for h in handles {
                h.join().map_err(|_| "daemon: reader thread panicked")?;
            }
        }
        // no new work can arrive: drain the backlog and stop executors
        self.shared.queue.close();
        for h in self.executors {
            h.join().map_err(|_| "daemon: executor thread panicked")?;
        }
        let stats = DaemonStats::collect(&self.shared);
        let _ = std::fs::remove_file(&self.socket);
        Ok(stats)
    }
}

/// The blocking CLI entry point: spawn, serve, return final stats.
pub fn serve(cfg: DaemonCfg) -> Result<DaemonStats, String> {
    Daemon::spawn(cfg)?.wait()
}

fn accept_loop(
    shared: &Arc<Shared>,
    listener: UnixListener,
    max_sessions: Option<u64>,
    readers: &Mutex<Vec<JoinHandle<()>>>,
) -> Result<(), String> {
    let mut accepted = 0u64;
    while max_sessions.is_none_or(|m| accepted < m) {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(e) => {
                if !shared.accepting.load(Ordering::SeqCst) {
                    break;
                }
                return Err(format!("daemon: accept: {e}"));
            }
        };
        if !shared.accepting.load(Ordering::SeqCst) {
            break; // the shutdown wake-up connection
        }
        let writer = stream
            .try_clone()
            .map_err(|e| format!("daemon: clone stream: {e}"))?;
        let sess = shared.registry.register(writer);
        let shared = Arc::clone(shared);
        let h = std::thread::spawn(move || reader_loop(&shared, &sess, stream));
        readers.lock().expect("readers").push(h);
        accepted += 1;
    }
    Ok(())
}

/// Per-session reader: decode frames off the socket and admit them to
/// the queue.  Shed replies are written here, immediately — admission
/// control must answer even (especially) when the executors are buried.
fn reader_loop(shared: &Shared, sess: &Arc<SessionHandle>, mut stream: UnixStream) {
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(Some(f)) => f,
            // clean EOF or a read error: either way the session is over
            _ => return,
        };
        // byte 6 (magic u32 + version u16) is the op: a Shutdown frame
        // is the last thing this session will send
        let ends_session = frame.get(6) == Some(&(Op::Shutdown as u8));
        // draining: whatever is already queued still finishes, but new
        // frames are refused with the distinct draining status so the
        // client can fail over instead of waiting on a dying server
        if shared.draining.load(Ordering::SeqCst) {
            shared.drain_refusals.fetch_add(1, Ordering::Relaxed);
            let body = reply_status_body(
                STATUS_DRAINING,
                "daemon draining: request refused; in-flight work is \
                 finishing, re-dispatch elsewhere",
            );
            let mut w = sess.writer.lock().expect("session writer");
            if write_frame(&mut w, &body).is_err() || ends_session {
                return;
            }
            continue;
        }
        let priority = sess
            .state
            .lock()
            .map(|st| st.priority)
            .unwrap_or(false);
        let job = Job { sess: Arc::clone(sess), frame };
        match shared.queue.push(sess.id, priority, job) {
            Ok(()) => {
                if ends_session {
                    return;
                }
            }
            Err(reason) => {
                if let Ok(mut st) = sess.state.lock() {
                    st.stats.shed += 1;
                }
                let limit = match reason {
                    ShedReason::Quota => shared.quota,
                    ShedReason::Capacity => shared.queue_cap,
                };
                let body = reply_status_body(
                    STATUS_SHED,
                    &reason.describe(sess.id, limit),
                );
                let mut w = sess.writer.lock().expect("session writer");
                if write_frame(&mut w, &body).is_err() {
                    return;
                }
            }
        }
    }
}

/// Executor: drain the fair queue.  The scheduler guarantees one
/// in-service request per session, so taking the session's state lock
/// here never contends with another executor on the same tenant and
/// replies leave in request order.
fn executor_loop(shared: &Shared) {
    while let Some((tenant, priority, job)) = shared.queue.pop() {
        let (reply, _end) = {
            let mut st = job.sess.state.lock().expect("session state");
            session::handle_frame(&job.frame, &mut st, &shared.exec)
        };
        {
            let mut w = job.sess.writer.lock().expect("session writer");
            // a vanished client is the reader thread's problem, not ours
            let _ = write_frame(&mut w, &reply);
        }
        shared.queue.done(tenant, priority);
    }
}

/// A throwaway socket path under the system temp dir (tests/benches).
pub fn scratch_socket(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "pgas-hw-daemon-{tag}-{}-{:x}.sock",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
    ))
}

#[cfg(test)]
mod tests;
