//! Exclusive lease over a scarce accelerator (the one Leon3 coprocessor
//! unit, or the XLA batch device) shared by every daemon session.
//!
//! The shape follows the GPU-lock pattern the ROADMAP names as the
//! exemplar (bellman's `GPULock`/`PriorityLock`): one exclusive lock,
//! plus a *priority path* that registers itself before waiting so the
//! normal path's [`can_lock`](AccelLease::can_lock) poll goes false the
//! moment a high-priority tenant is queued — normal tenants never
//! acquire past a waiting priority tenant, and they never *block* on
//! the device at all ([`try_acquire`](AccelLease::try_acquire) is their
//! only entry point; on contention they fall back to the host engines).
//!
//! Ordering guarantee (pinned by the lease-contention test): when the
//! holder releases with both a priority waiter and normal pollers
//! queued, the priority waiter acquires next, always.

use std::sync::{Condvar, Mutex};

/// Telemetry snapshot of one lease.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LeaseStats {
    /// Total successful acquisitions (both paths).
    pub acquisitions: u64,
    /// Acquisitions through the priority path.
    pub priority_acquisitions: u64,
    /// Normal-path `try_acquire` calls refused because the device was
    /// held or a priority tenant was waiting.
    pub contended: u64,
}

#[derive(Default)]
struct Inner {
    held: bool,
    priority_waiters: u64,
    stats: LeaseStats,
}

/// The lease itself.  `acquire`/`try_acquire` return a guard that
/// releases on drop; the device object lives outside (the daemon keeps
/// its `Leon3Engine` next to the lease and only touches it while
/// holding a guard).
#[derive(Default)]
pub struct AccelLease {
    inner: Mutex<Inner>,
    cv: Condvar,
}

/// Holding this is holding the accelerator; dropping it releases and
/// wakes every waiter (priority waiters win the race by construction —
/// normal tenants poll, they do not wait).
pub struct LeaseGuard<'a> {
    lease: &'a AccelLease,
}

impl Drop for LeaseGuard<'_> {
    fn drop(&mut self) {
        let mut g = self.lease.inner.lock().expect("lease mutex");
        g.held = false;
        drop(g);
        self.lease.cv.notify_all();
    }
}

impl AccelLease {
    pub fn new() -> Self {
        Self::default()
    }

    /// The normal path's poll: free *and* no priority tenant queued.
    pub fn can_lock(&self) -> bool {
        let g = self.inner.lock().expect("lease mutex");
        !g.held && g.priority_waiters == 0
    }

    /// Normal-tenant acquisition: succeeds only when
    /// [`can_lock`](Self::can_lock) (checked and taken under one lock —
    /// no TOCTOU window).  `None`
    /// means "use the host engines this time"; the caller must not
    /// spin on it while holding scheduler resources.
    pub fn try_acquire(&self) -> Option<LeaseGuard<'_>> {
        let mut g = self.inner.lock().expect("lease mutex");
        if g.held || g.priority_waiters > 0 {
            g.stats.contended += 1;
            return None;
        }
        g.held = true;
        g.stats.acquisitions += 1;
        Some(LeaseGuard { lease: self })
    }

    /// Priority-tenant acquisition: registers as a waiter first (which
    /// flips `can_lock` false for everyone else), then blocks until the
    /// holder releases.  Jumping the queue is the point — a priority
    /// tenant waits only for the *current* holder, never behind normal
    /// tenants.
    pub fn acquire_priority(&self) -> LeaseGuard<'_> {
        let mut g = self.inner.lock().expect("lease mutex");
        g.priority_waiters += 1;
        while g.held {
            g = self.cv.wait(g).expect("lease mutex");
        }
        g.priority_waiters -= 1;
        g.held = true;
        g.stats.acquisitions += 1;
        g.stats.priority_acquisitions += 1;
        drop(g);
        // other priority waiters may still be runnable (they re-check
        // `held` and go back to sleep; the wake keeps them live)
        self.cv.notify_all();
        LeaseGuard { lease: self }
    }

    pub fn stats(&self) -> LeaseStats {
        self.inner.lock().expect("lease mutex").stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    #[test]
    fn exclusive_and_reentrant_after_release() {
        let lease = AccelLease::new();
        let g = lease.try_acquire().expect("free lease");
        assert!(!lease.can_lock());
        assert!(lease.try_acquire().is_none(), "must be exclusive");
        drop(g);
        assert!(lease.can_lock());
        assert!(lease.try_acquire().is_some());
        let s = lease.stats();
        assert_eq!(s.acquisitions, 2);
        assert_eq!(s.contended, 1);
        assert_eq!(s.priority_acquisitions, 0);
    }

    /// The ordering the daemon relies on: with the device held, a
    /// priority tenant queues and a normal tenant polls.  On release
    /// the priority tenant acquires next — the normal poller is refused
    /// the whole time a priority waiter exists, even while the device
    /// is technically free between release and the waiter waking.
    #[test]
    fn priority_waiter_preempts_normal_pollers() {
        let lease = Arc::new(AccelLease::new());
        let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        let holder = lease.try_acquire().expect("free lease");

        let waiting = Arc::new(AtomicBool::new(false));
        let prio = {
            let (lease, order, waiting) =
                (Arc::clone(&lease), Arc::clone(&order), Arc::clone(&waiting));
            std::thread::spawn(move || {
                waiting.store(true, Ordering::SeqCst);
                let _g = lease.acquire_priority();
                order.lock().unwrap().push("priority");
                // hold long enough that a racing normal poller would be
                // caught red-handed if it could slip in first
                std::thread::sleep(Duration::from_millis(20));
            })
        };
        while !waiting.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        // give the priority thread time to park inside acquire_priority
        std::thread::sleep(Duration::from_millis(20));

        // the normal path is refused while a priority tenant waits
        assert!(!lease.can_lock());
        assert!(lease.try_acquire().is_none());

        drop(holder); // release: the priority waiter must win
        let normal = {
            let (lease, order) = (Arc::clone(&lease), Arc::clone(&order));
            std::thread::spawn(move || {
                // poll like a normal tenant until the device frees up
                loop {
                    if let Some(_g) = lease.try_acquire() {
                        order.lock().unwrap().push("normal");
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            })
        };
        prio.join().unwrap();
        normal.join().unwrap();
        assert_eq!(
            *order.lock().unwrap(),
            vec!["priority", "normal"],
            "priority tenant must acquire before any normal poller"
        );
        let s = lease.stats();
        assert_eq!(s.priority_acquisitions, 1);
        assert!(s.contended >= 1, "the refused polls must be counted");
    }
}
