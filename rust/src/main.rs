//! `pgas-hw` — CLI for the PGAS address-mapping-hardware reproduction.
//!
//! Subcommands:
//!   run          one kernel/variant/model/core-count simulation
//!   sweep        a full campaign (defaults reproduce Figs. 6–14), CSV out
//!   leon3        the FPGA prototype microbenchmarks (Figs. 15/16)
//!   area         Table 4 + the component breakdown
//!   disasm       compile a kernel and print program + PGAS census + Table 1
//!   lint         static PGAS access analysis: barrier-phase race
//!                detection, shared-bounds proof, engine-mix prediction;
//!                exits non-zero on any ERROR diagnostic
//!   verify       differential check of the AddressEngine backends
//!                (software vs pow2 vs sharded vs the Leon3 coprocessor
//!                model vs the remote worker-process pool; + the XLA
//!                batch unit with `--features xla-unit` and artifacts)
//!   walk         demo: trace a pointer walk through a layout via the
//!                selected AddressEngine backend
//!   serve-engine the worker side of the remote tier: serve one
//!                AddressEngine session on a Unix-domain socket
//!                (spawned and supervised by `RemoteEngine`; runnable
//!                by hand for debugging)
//!   daemon       the multi-tenant service tier: serve many concurrent
//!                epoch sessions over one socket with fair queueing,
//!                per-tenant quotas and the Leon3 unit behind a
//!                priority-aware lease; prints the per-tenant stats
//!                table on exit
//!
//! (Hand-rolled argument parsing: the offline environment vendors no
//! clap.)

use std::collections::HashMap;
use std::process::ExitCode;

use pgas_hw::coordinator::{self, Campaign};
use pgas_hw::cpu::CpuModel;
use pgas_hw::engine::{
    AddressEngine, BatchOut, EngineCtx, EngineSelector, FaultSpec,
    Leon3Engine, Pow2Engine, PtrBatch, RemoteEngine, RemoteTier,
    ShardedEngine, SoftwareEngine,
};
use pgas_hw::npb::{self, Kernel, PaperVariant, Scale};
use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
use pgas_hw::util::rng::Xoshiro256;
use pgas_hw::{area, isa, leon3};

fn usage() -> &'static str {
    "usage: pgas-hw <run|sweep|leon3|area|disasm|lint|verify|walk|serve-engine|daemon> [--key value ...]
  run    --kernel EP|IS|CG|MG|FT|MD|SPMV --variant unopt|manual|hw
         --model atomic|timing|detailed --cores N [--scale F]
         [--no-lookahead]  (disable batched PGAS-increment windows;
                            cycle totals are identical either way)
         [--remote N]      (spawn an N-process remote mapping pool,
                            measured pricing)
         [--daemon PATH]   (connect to a running `pgas-hw daemon`
                            instead of spawning workers; exclusive
                            with --remote; [--daemon-conns N] sessions)
         [--remote-fast]   (price the pool/daemon as a dedicated
                            service so eligible windows take the hop)
         [--chaos SEED[:SPEC]]
                           (seeded fault injection into every core's
                            selector; bare SEED uses the default
                            transient mix, SPEC tunes rates, e.g.
                            0xC0FFEE:error=0.5,spike=0.2,spike_ms=10;
                            results are unchanged — prints the engine
                            health table)
  sweep  [--kernels ..] [--models ..] [--cores 1,2,4,..] [--scale F]
                           (kernels include the irregular-gather pair
                            MD and SPMV, off the default figure set)
         [--config campaign.cfg] [--out results/]
         [--remote N | --daemon PATH] [--remote-fast]
                           (add the remote tier to the engine report
                            AND every sweep point's core selectors)
         [--chaos SEED[:SPEC]]
                           (arm every sweep point with the seeded fault
                            plan; figures must be identical, the merged
                            health table shows the absorbed storm)
  leon3  [--bench vecadd|matmul|all] [--threads 1|2|4] [--tables]
  area
  disasm --kernel K [--variant V] [--full]
  lint   [--kernel K | --all | --fixtures] [--json]
         [--threads N] [--scale F]
                           (static analyzer: --all lints the seven NPB
                            kernels, --fixtures the deliberately-broken
                            lint fixtures; exits non-zero on any ERROR
                            diagnostic, so CI can gate on it)
  verify [--batches N] [--artifacts DIR]
  walk   [--blocksize B] [--elemsize E] [--threads T] [--inc I]
  serve-engine --socket PATH   (worker: serve one engine session, exit)
  daemon --socket PATH [--executors N] [--queue-cap N] [--quota N]
         [--accel-threshold N] [--sessions N]
                           (multi-tenant service: epoch sessions, fair
                            queueing, accelerator leasing; with
                            --sessions N it exits after N sessions and
                            prints the per-tenant stats table)"
}

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i]
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got `{}`", args[i]))?;
        if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            m.insert(k.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            m.insert(k.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(m)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let flags = match parse_flags(&args[1..]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(&flags),
        "sweep" => cmd_sweep(&flags),
        "leon3" => cmd_leon3(&flags),
        "area" => cmd_area(),
        "disasm" => cmd_disasm(&flags),
        "lint" => cmd_lint(&flags),
        "verify" => cmd_verify(&flags),
        "walk" => cmd_walk(&flags),
        "serve-engine" => cmd_serve_engine(&flags),
        "daemon" => cmd_daemon(&flags),
        _ => Err(format!("unknown command `{cmd}`\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn get_scale(flags: &HashMap<String, String>) -> Result<Scale, String> {
    Ok(match flags.get("scale") {
        Some(s) => Scale {
            factor: s.parse().map_err(|_| format!("bad scale `{s}`"))?,
        },
        None => Scale::default(),
    })
}

/// Parse `--remote N | --daemon PATH` (exclusive) plus `--remote-fast`
/// into a remote tier (None when both flags are absent).  `--remote N`
/// spawns and supervises N worker processes; `--daemon PATH` opens
/// `--daemon-conns` (default 2) epoch sessions to an already-running
/// `pgas-hw daemon`.  `--remote-fast` prices either as a dedicated
/// service (zero legs, threshold 1) so the hop is actually taken on one
/// host; without it the legs are measured and the argmin decides.
fn parse_remote_tier(
    flags: &HashMap<String, String>,
) -> Result<Option<RemoteTier>, String> {
    let forced = flags.contains_key("remote-fast");
    if let Some(path) = flags.get("daemon") {
        if flags.contains_key("remote") {
            return Err("--daemon and --remote are exclusive".into());
        }
        let conns: usize = match flags.get("daemon-conns") {
            Some(c) => c.parse().map_err(|_| format!("bad daemon-conns `{c}`"))?,
            None => 2,
        };
        let tier = if forced {
            RemoteTier::connect_forced(path, conns)
        } else {
            RemoteTier::connect(path, conns)
        }
        .map_err(|e| e.to_string())?;
        return Ok(Some(tier));
    }
    let Some(n) = flags.get("remote") else {
        if forced {
            return Err("--remote-fast requires --remote N or --daemon PATH".into());
        }
        return Ok(None);
    };
    let workers: usize = n.parse().map_err(|_| format!("bad remote `{n}`"))?;
    let tier = if forced {
        RemoteTier::spawn_forced(workers)
    } else {
        RemoteTier::spawn(workers)
    }
    .map_err(|e| e.to_string())?;
    Ok(Some(tier))
}

/// Parse `--chaos SEED[:SPEC]` into a [`FaultSpec`] (None when absent).
fn parse_chaos(
    flags: &HashMap<String, String>,
) -> Result<Option<FaultSpec>, String> {
    match flags.get("chaos") {
        Some(s) => FaultSpec::parse(s).map(Some),
        None => Ok(None),
    }
}

fn parse_variant(flags: &HashMap<String, String>) -> Result<PaperVariant, String> {
    match flags.get("variant").map(|s| s.as_str()).unwrap_or("hw") {
        "unopt" => Ok(PaperVariant::Unopt),
        "manual" => Ok(PaperVariant::Manual),
        "hw" => Ok(PaperVariant::Hw),
        other => Err(format!("unknown variant `{other}`")),
    }
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<(), String> {
    let kernel = Kernel::parse(flags.get("kernel").ok_or("missing --kernel")?)
        .ok_or("unknown kernel")?;
    let variant = parse_variant(flags)?;
    let model = CpuModel::parse(flags.get("model").map(|s| s.as_str()).unwrap_or("atomic"))
        .ok_or("unknown model")?;
    let cores: u32 = flags
        .get("cores")
        .map(|s| s.parse().map_err(|_| "bad cores"))
        .unwrap_or(Ok(4))?;
    let scale = get_scale(flags)?;
    let lookahead = !flags.contains_key("no-lookahead");
    let remote = parse_remote_tier(flags)?;
    let chaos = parse_chaos(flags)?;
    let out = npb::run_opts_with(
        kernel,
        variant,
        model,
        cores,
        &scale,
        lookahead,
        remote.as_ref(),
        chaos.as_ref(),
    );
    println!(
        "{} [{}] {} x{}: {} cycles = {:.3} ms simulated @2GHz (validated OK)",
        kernel,
        variant.label(),
        model,
        cores,
        out.result.cycles,
        out.result.runtime_secs() * 1e3
    );
    println!(
        "  instructions={} ipc(core0)={:.2} pgas: {} hw incs / {} soft incs, {} hw mem / {} soft mem",
        out.result.total.instructions,
        out.result.per_core[0].ipc(),
        out.compile_stats.hw_incs,
        out.compile_stats.soft_incs,
        out.compile_stats.hw_mems,
        out.compile_stats.soft_mems,
    );
    let mix = out.engine_mix();
    println!(
        "  engine mix: {} incs batched / {} scalar ({:.1}% batched), runs: {}",
        mix.batched_incs,
        mix.scalar_incs,
        mix.batched_share() * 100.0,
        mix.runs_label(),
    );
    let g = out.result.gather;
    if g.plans + g.fallback > 0 {
        println!(
            "  gather: {} plans bucketing {} ptrs, {} eligible batches served direct",
            g.plans, g.bucketed_ptrs, g.fallback,
        );
    }
    let s = out.result.simd;
    if s.batches > 0 {
        println!(
            "  simd: {} batches, {} ptrs in full lanes / {} scalar tail",
            s.batches, s.lane_ptrs, s.tail_ptrs,
        );
    }
    let p = out.result.plan;
    if p.plans + p.fallback > 0 {
        println!(
            "  plan: {} tile plans ({} tiles) over {} ptrs, {} eligible batches unplanned",
            p.plans, p.tiles, p.planned_ptrs, p.fallback,
        );
    }
    if chaos.is_some() {
        println!(
            "{}",
            coordinator::health_table(&out.result.health).render()
        );
    }
    if flags.contains_key("stats") {
        println!("\n{}", out.result.stats_txt());
    }
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<(), String> {
    let mut campaign = if let Some(path) = flags.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        coordinator::config::parse_campaign(&text)?
    } else {
        Campaign::default()
    };
    if let Some(ks) = flags.get("kernels") {
        campaign.kernels = ks
            .split(',')
            .map(|s| Kernel::parse(s.trim()).ok_or(format!("unknown kernel {s}")))
            .collect::<Result<_, _>>()?;
    }
    if let Some(ms) = flags.get("models") {
        campaign.models = ms
            .split(',')
            .map(|s| CpuModel::parse(s.trim()).ok_or(format!("unknown model {s}")))
            .collect::<Result<_, _>>()?;
    }
    if let Some(cs) = flags.get("cores") {
        campaign.cores = cs
            .split(',')
            .map(|s| s.trim().parse().map_err(|_| format!("bad cores {s}")))
            .collect::<Result<_, _>>()?;
    }
    if let Some(s) = flags.get("scale") {
        campaign.scale = Scale {
            factor: s.parse().map_err(|_| "bad scale")?,
        };
    }
    campaign.chaos = parse_chaos(flags)?;
    eprintln!(
        "campaign: {} points, scale 1/{}, {} jobs",
        campaign.points().len(),
        campaign.scale.factor,
        campaign.jobs
    );
    let report_cores = campaign.cores.first().copied().unwrap_or(4);
    let remote = parse_remote_tier(flags)?;
    println!(
        "{}",
        coordinator::engine_report_with(
            &campaign.kernels,
            report_cores,
            &campaign.scale,
            remote.as_ref(),
        )
        .render()
    );
    let outs = campaign.run_with_remote(true, remote.as_ref());
    let figs = [
        (Kernel::Ep, "Fig 6"),
        (Kernel::Cg, "Fig 7/11"),
        (Kernel::Ft, "Fig 8/12"),
        (Kernel::Is, "Fig 9/13"),
        (Kernel::Mg, "Fig 10/14"),
    ];
    for &(k, fig) in &figs {
        for &m in &campaign.models {
            if campaign.kernels.contains(&k) {
                let t = coordinator::figure_table(&outs, k, m, fig);
                if !t.is_empty() {
                    println!("{}", t.render());
                }
            }
        }
    }
    println!("{}", coordinator::headline_summary(&outs).render());
    println!("{}", coordinator::engine_mix_table(&outs).render());
    if campaign.chaos.is_some() {
        let mut health = pgas_hw::engine::HealthStats::default();
        for o in &outs {
            health.merge(&o.result.health);
        }
        println!("{}", coordinator::health_table(&health).render());
    }
    if let Some(dir) = flags.get("out") {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        let path = format!("{dir}/outcomes.csv");
        std::fs::write(&path, coordinator::outcomes_csv(&outs))
            .map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_leon3(flags: &HashMap<String, String>) -> Result<(), String> {
    use leon3::microbench::{run_matmul, run_vecadd, MatmulVariant, VecAddVariant};
    use pgas_hw::util::table::{fnum, Table};
    if flags.contains_key("tables") {
        println!("{}", leon3::table2());
        println!("{}", leon3::table3());
    }
    let bench = flags.get("bench").map(|s| s.as_str()).unwrap_or("all");
    let threads: Vec<u32> = match flags.get("threads") {
        Some(t) => vec![t.parse().map_err(|_| "bad threads")?],
        None => vec![1, 2, 4],
    };
    if bench == "vecadd" || bench == "all" {
        let n = 8192;
        let mut t = Table::new(
            "Fig 15: Leon3 vector addition (runtime ms @75MHz)",
            &["threads", "dynamic", "static", "privatized", "hw"],
        );
        for &th in &threads {
            let ms = |v| fnum(run_vecadd(th, v, n).runtime_ms(), 3);
            t.row(&[
                th.to_string(),
                ms(VecAddVariant::Dynamic),
                ms(VecAddVariant::Static),
                ms(VecAddVariant::Privatized),
                ms(VecAddVariant::Hw),
            ]);
        }
        println!("{}", t.render());
    }
    if bench == "matmul" || bench == "all" {
        let n = 32;
        let mut t = Table::new(
            "Fig 16: Leon3 matrix multiplication (runtime ms @75MHz)",
            &["threads", "static", "privatization 1", "privatization 2", "hw"],
        );
        for &th in &threads {
            let ms = |v| fnum(run_matmul(th, v, n).runtime_ms(), 3);
            t.row(&[
                th.to_string(),
                ms(MatmulVariant::Static),
                ms(MatmulVariant::Priv1),
                ms(MatmulVariant::Priv2),
                ms(MatmulVariant::Hw),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_area() -> Result<(), String> {
    println!("{}", area::table4().render());
    println!("{}", area::component_breakdown().render());
    Ok(())
}

fn cmd_disasm(flags: &HashMap<String, String>) -> Result<(), String> {
    let kernel = Kernel::parse(flags.get("kernel").ok_or("missing --kernel")?)
        .ok_or("unknown kernel")?;
    let variant = parse_variant(flags)?;
    println!("{}", isa::table1());
    let built = npb::build(kernel, 4, variant.source(), &Scale::quick());
    let ck = pgas_hw::compiler::compile(
        &built.module,
        &built.rt,
        &pgas_hw::compiler::CompileOpts {
            lowering: variant.lowering(),
            static_threads: false,
            numthreads: 4,
            volatile_stores: true,
        },
    );
    println!(
        "kernel {kernel} [{}]: {} instructions; census: {:?}; \
         pgas static counts: {:?}",
        variant.label(),
        ck.program.len(),
        ck.stats,
        ck.program.pgas_static_counts()
    );
    if flags.contains_key("full") {
        println!("{}", ck.program.disassemble());
    } else {
        for (i, inst) in ck.program.insts.iter().take(80).enumerate() {
            println!("{i:6}:  {inst}");
        }
        if ck.program.len() > 80 {
            println!("... ({} more)", ck.program.len() - 80);
        }
    }
    Ok(())
}

/// The static analyzer: lint NPB kernels (or the fixture kernels) and
/// report race / bounds / engine-mix findings.  Any ERROR diagnostic
/// makes the command fail, which is what the CI `lint-kernels` job
/// gates on.
fn cmd_lint(flags: &HashMap<String, String>) -> Result<(), String> {
    use pgas_hw::analysis;
    let threads: u32 = flags
        .get("threads")
        .map(|s| s.parse().map_err(|_| format!("bad threads `{s}`")))
        .unwrap_or(Ok(4))?;
    // quick scale by default: lint compiles but never simulates, so
    // the small shapes are plenty
    let scale = match flags.get("scale") {
        Some(s) => Scale {
            factor: s.parse().map_err(|_| format!("bad scale `{s}`"))?,
        },
        None => Scale::quick(),
    };
    let mut reports = Vec::new();
    if flags.contains_key("fixtures") {
        for name in analysis::fixtures::NAMES {
            reports.push(
                analysis::lint_fixture(name, threads).expect("known fixture"),
            );
        }
    } else if flags.contains_key("all") {
        for k in Kernel::ALL.iter().chain(Kernel::IRREGULAR.iter()) {
            reports.push(analysis::lint_kernel(*k, threads, &scale));
        }
    } else if let Some(name) = flags.get("kernel") {
        let k = Kernel::parse(name).ok_or("unknown kernel")?;
        reports.push(analysis::lint_kernel(k, threads, &scale));
    } else {
        return Err(format!(
            "lint needs --kernel K, --all, or --fixtures\n{}",
            usage()
        ));
    }
    if flags.contains_key("json") {
        let body = reports
            .iter()
            .map(pgas_hw::analysis::LintReport::to_json)
            .collect::<Vec<_>>()
            .join(",");
        println!("[{body}]");
    } else {
        println!("{}", coordinator::lint_table(&reports).render());
        for r in &reports {
            for d in &r.diagnostics {
                println!(
                    "{} [{}] {} phase {}: {}",
                    d.severity, d.code, r.kernel, d.phase, d.message
                );
                for s in &d.sites {
                    println!("    at {s}");
                }
            }
        }
    }
    let errors: usize = reports.iter().map(analysis::LintReport::errors).sum();
    if errors > 0 {
        Err(format!("{errors} ERROR diagnostics"))
    } else {
        Ok(())
    }
}

#[cfg(feature = "xla-unit")]
fn artifacts_dir(flags: &HashMap<String, String>) -> String {
    flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string())
}

/// Differential conformance of the AddressEngine backends on randomized
/// pow2 layouts: software (general Algorithm 1) vs pow2 (shift/mask) vs
/// the sharded worker pool vs the Leon3 coprocessor model (instruction
/// replay on the FPGA-prototype functional core) vs the remote
/// worker-process pool, and — when compiled with `xla-unit` and
/// artifacts are present — the XLA batch unit as well.  All must agree
/// bit-for-bit.
fn cmd_verify(flags: &HashMap<String, String>) -> Result<(), String> {
    let batches: u32 = flags
        .get("batches")
        .map(|s| s.parse().map_err(|_| "bad batches"))
        .unwrap_or(Ok(8))?;
    let software = SoftwareEngine;
    let pow2 = Pow2Engine;
    let sharded = ShardedEngine::new(SoftwareEngine, 4).with_min_shard_len(1);
    let leon3 = Leon3Engine::new();
    // min_shard_len 1 forces real multi-process fan-out + splice even
    // on the small randomized batches.
    let remote = match RemoteEngine::spawn(2) {
        Ok(r) => Some(r.with_min_shard_len(1)),
        Err(e) => {
            eprintln!(
                "note: remote engine unavailable ({e}); skipping the \
                 process-tier differential"
            );
            None
        }
    };
    #[cfg(feature = "xla-unit")]
    let xla = match pgas_hw::engine::XlaBatchEngine::load(artifacts_dir(flags)) {
        Ok(x) => {
            println!("PJRT platform: {}", x.platform());
            Some(x)
        }
        Err(e) => {
            eprintln!("note: XLA batch engine unavailable ({e}); checking software vs pow2 only");
            None
        }
    };
    let mut rng = Xoshiro256::new(0xFEED);
    for batch in 0..batches {
        let l2bs = rng.below(8) as u32;
        let l2es = rng.below(4) as u32;
        let l2nt = rng.below(7) as u32;
        let t = 1u32 << l2nt;
        let table = BaseTable::regular(t, 1 << 32, 1 << 32);
        let layout = ArrayLayout::new(1 << l2bs, 1 << l2es, t);
        let ctx = EngineCtx::new(layout, &table, rng.below(t as u64) as u32)
            .map_err(|e| e.to_string())?;
        let n = 1 + rng.below(8192) as usize;
        let mut req = PtrBatch::with_capacity(n);
        for _ in 0..n {
            req.push(
                SharedPtr::for_index(&layout, 0, rng.below(1 << 16)),
                rng.below(4096),
            );
        }
        let mut want = BatchOut::new();
        software
            .translate(&ctx, &req, &mut want)
            .map_err(|e| e.to_string())?;
        let mut got = BatchOut::new();
        pow2.translate(&ctx, &req, &mut got).map_err(|e| e.to_string())?;
        if got != want {
            return Err(format!("batch {batch}: pow2 engine != software engine"));
        }
        sharded.translate(&ctx, &req, &mut got).map_err(|e| e.to_string())?;
        if got != want {
            return Err(format!(
                "batch {batch}: sharded engine != software engine"
            ));
        }
        leon3.translate(&ctx, &req, &mut got).map_err(|e| e.to_string())?;
        if got != want {
            return Err(format!(
                "batch {batch}: leon3 engine != software engine"
            ));
        }
        let mut engines = String::from("software == pow2 == sharded == leon3");
        if let Some(r) = &remote {
            r.translate(&ctx, &req, &mut got).map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!(
                    "batch {batch}: remote engine != software engine"
                ));
            }
            engines.push_str(" == remote");
        }
        #[cfg(feature = "xla-unit")]
        if let Some(x) = &xla {
            x.translate(&ctx, &req, &mut got).map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("batch {batch}: xla-batch engine != software engine"));
            }
            engines.push_str(" == xla-batch");
        }
        println!(
            "batch {batch}: {n} pointers OK, {engines} (T={t}, bs=2^{l2bs}, es=2^{l2es})"
        );
    }
    println!("verify: all {batches} batches agree across engines");
    Ok(())
}

/// The worker side of the remote AddressEngine tier: bind the socket,
/// serve exactly one client session, exit.  Normally spawned and
/// supervised by `RemoteEngine`; running it by hand is useful for
/// protocol debugging (`pgas-hw serve-engine --socket /tmp/e.sock`).
fn cmd_serve_engine(flags: &HashMap<String, String>) -> Result<(), String> {
    let socket = flags.get("socket").ok_or("missing --socket")?;
    pgas_hw::engine::remote::serve(std::path::Path::new(socket))
}

/// The multi-tenant service tier: serve many concurrent epoch sessions
/// over one socket.  Blocks until `--sessions N` sessions have been
/// served (forever without it), then prints the daemon + per-tenant
/// stats tables.
fn cmd_daemon(flags: &HashMap<String, String>) -> Result<(), String> {
    let mut cfg = pgas_hw::daemon::DaemonCfg::new(
        flags.get("socket").ok_or("missing --socket")?,
    );
    let num = |key: &str, into: &mut usize| -> Result<(), String> {
        if let Some(v) = flags.get(key) {
            *into = v.parse().map_err(|_| format!("bad {key} `{v}`"))?;
        }
        Ok(())
    };
    num("executors", &mut cfg.executors)?;
    num("queue-cap", &mut cfg.queue_cap)?;
    num("quota", &mut cfg.quota)?;
    num("accel-threshold", &mut cfg.accel_threshold)?;
    if let Some(v) = flags.get("sessions") {
        cfg.max_sessions =
            Some(v.parse().map_err(|_| format!("bad sessions `{v}`"))?);
    }
    eprintln!(
        "daemon: serving on {} ({} executors, queue {}, quota {}/tenant)",
        cfg.socket.display(),
        cfg.executors,
        cfg.queue_cap,
        cfg.quota
    );
    let stats = pgas_hw::daemon::serve(cfg)?;
    println!("{}", coordinator::daemon_table(&stats).render());
    Ok(())
}

/// Trace a pointer walk through a layout with whichever backend the
/// selector picks — non-pow2 geometries now work too (software engine).
fn cmd_walk(flags: &HashMap<String, String>) -> Result<(), String> {
    let bs: u64 = flags.get("blocksize").map(|s| s.parse().unwrap_or(4)).unwrap_or(4);
    let es: u64 = flags.get("elemsize").map(|s| s.parse().unwrap_or(4)).unwrap_or(4);
    let t: u32 = flags.get("threads").map(|s| s.parse().unwrap_or(4)).unwrap_or(4);
    let inc: u64 = flags.get("inc").map(|s| s.parse().unwrap_or(1)).unwrap_or(1);
    const STEPS: usize = 24;
    let layout = ArrayLayout::new(bs, es, t);
    let table = BaseTable::regular(t, 1 << 32, 1 << 32);
    let sel = EngineSelector::new();
    // walks get walk pricing (the O(1) stepper), not translate pricing
    let choice = sel.choice_walk(&layout, STEPS);
    let ctx = EngineCtx::new(layout, &table, 0).map_err(|e| e.to_string())?;
    let mut out = BatchOut::new();
    sel.walk(&ctx, SharedPtr::NULL, inc, STEPS, &mut out)
        .map_err(|e| e.to_string())?;
    println!(
        "walking shared [{bs}] (elem {es}B) over {t} threads, inc {inc} \
         — first {STEPS} steps (`{}` engine):",
        choice.name()
    );
    for i in 0..out.len() {
        println!(
            "  elem {:3}: thread {} sysva {:#x} locality {:?}",
            i as u64 * inc,
            out.ptrs[i].thread,
            out.sysva[i],
            out.loc[i]
        );
    }
    Ok(())
}
