//! The inspector/executor gather tier: plan-aware dispatch for
//! data-dependent (irregular) shared access.
//!
//! All five classic NPB kernels walk affine strides — the shape
//! `WalkCursor` and the pipeline's window planner already exploit.  The
//! hard PGAS case the paper's hardware was built for is *indirection*:
//! `x[col[k]]`-style gathers where every element needs its own address
//! translation and no stride can be factored out.  The standard
//! compiler/runtime answer (arXiv 2303.13954 for UPC++, and the
//! inspector/executor literature behind it) is to split the access into
//! two phases:
//!
//! 1. **inspect** — scan the index vector once, compute each target's
//!    owning thread with cheap block-cyclic arithmetic (one div + one
//!    mod per element, no LUT access), and bucket the requests into one
//!    aggregated [`PtrBatch`] per owner;
//! 2. **execute** — dispatch each owner's batch through any
//!    [`AddressEngine`] (one message per *owner* instead of one per
//!    *element* on the remote tiers), then splice the per-bucket
//!    results back into the original request order.
//!
//! The splice makes the plan transparent: outputs are bit-identical to
//! running the naive per-element path, for every backend
//! (`tests/gather_conformance.rs` enforces this differentially, with
//! randomized index vectors, across all backends and layouts).
//!
//! Plans refuse loudly ([`EngineError::Backend`]) when any single
//! bucket could not cross the remote tier's wire: a bucket whose reply
//! frame would exceed the 1 GiB frame cap is a planning error at
//! *build* time, never a silent truncation at dispatch time.

use std::time::Instant;

use super::remote::{reply_frame_bytes, MAX_FRAME};
use super::{AddressEngine, BatchOut, EngineCtx, EngineError, PtrBatch};
use crate::sptr::SharedPtr;

/// Counters the selector keeps for its gather leg (threaded through
/// `Lookahead` → `MachineResult` → `stats_txt` as the `gather.*`
/// lines).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GatherStats {
    /// Inspector/executor plans actually executed (multi-owner batches
    /// that met the gather threshold).
    pub plans: u64,
    /// Pointers routed through those plans' per-owner buckets.
    pub bucketed_ptrs: u64,
    /// Gather-eligible batches served directly instead (single-owner
    /// after inspection — bucketing would only add copies).
    pub fallback: u64,
}

impl GatherStats {
    /// Fold another core's counters into this one (the machine-level
    /// roll-up mirrors `EngineMix::merge`).
    pub fn merge(&mut self, other: &GatherStats) {
        self.plans += other.plans;
        self.bucketed_ptrs += other.bucketed_ptrs;
        self.fallback += other.fallback;
    }
}

/// Where element `i` of the original request landed: `(bucket,
/// position-within-bucket)`, recorded during inspection so execution
/// can splice per-bucket results back into request order.
#[derive(Clone, Copy, Debug)]
struct Slot {
    bucket: u32,
    pos: u32,
}

/// An inspected batch: one aggregated [`PtrBatch`] per owning thread,
/// plus the splice map back to the original order.
///
/// # Examples
///
/// ```
/// use pgas_hw::engine::{
///     AddressEngine, BatchOut, EngineCtx, GatherPlan, PtrBatch,
///     SoftwareEngine,
/// };
/// use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
///
/// // shared [4] int A[...] over 4 threads, gathered at indices that
/// // hit three different owners, out of order.
/// let layout = ArrayLayout::new(4, 4, 4);
/// let table = BaseTable::regular(4, 1 << 32, 1 << 32);
/// let ctx = EngineCtx::new(layout, &table, 0).unwrap();
/// let plan =
///     GatherPlan::from_indices(&ctx, SharedPtr::NULL, &[9, 1, 5, 1]).unwrap();
/// assert_eq!(plan.len(), 4);
/// assert_eq!(plan.bucket_count(), 3); // owners 2, 0, 1
///
/// // executing the plan is bit-identical to the per-element path
/// let mut planned = BatchOut::new();
/// plan.execute(&SoftwareEngine, &ctx, &mut planned).unwrap();
/// for (i, &idx) in [9u64, 1, 5, 1].iter().enumerate() {
///     let (p, sysva, loc) =
///         SoftwareEngine.translate_one(&ctx, SharedPtr::NULL, idx).unwrap();
///     assert_eq!((planned.ptrs[i], planned.sysva[i], planned.loc[i]),
///                (p, sysva, loc));
/// }
/// ```
#[derive(Clone, Debug)]
pub struct GatherPlan {
    /// Distinct owning threads, in order of first appearance.
    owners: Vec<u32>,
    /// One aggregated request batch per owner (parallel to `owners`).
    buckets: Vec<PtrBatch>,
    /// Per original element: which bucket it went to, and where.
    slots: Vec<Slot>,
}

impl GatherPlan {
    /// Owning thread of `ptr + inc` elements under `ctx`'s layout —
    /// the inspector's whole per-element cost.  Block-cyclic layouts
    /// advance one owner per block boundary crossed, so the owner falls
    /// out of one div and one mod without computing the full Algorithm 1
    /// (no local-block or va arithmetic, no LUT access).
    /// Shared with the batch planner ([`super::plan`]), which reuses
    /// this owner arithmetic as its tile-affinity bucketing key.
    #[inline]
    pub(crate) fn owner_of(ctx: &EngineCtx, ptr: &SharedPtr, inc: u64) -> u32 {
        let layout = ctx.layout();
        // u128: `phase + inc` may not fit u64 near the top of the range
        let blocks = (ptr.phase as u128 + inc as u128) / layout.blocksize as u128;
        ((ptr.thread as u128 + blocks) % layout.numthreads as u128) as u32
    }

    /// Largest per-owner bucket the remote tier can carry: the reply
    /// frame (64-byte header + 29 bytes per result) must fit the wire's
    /// 1 GiB frame cap.  Exceeding it is refused at plan-build time.
    pub fn max_bucket_len() -> usize {
        // reply_frame_bytes is monotonic; solve 64 + 29n <= MAX_FRAME
        let n = (MAX_FRAME - reply_frame_bytes(0)) / (reply_frame_bytes(1) - reply_frame_bytes(0));
        debug_assert!(reply_frame_bytes(n) <= MAX_FRAME);
        debug_assert!(reply_frame_bytes(n + 1) > MAX_FRAME);
        n
    }

    /// Inspect `batch`: bucket every request by the owning thread of
    /// its target and record the splice map.  Fails loudly when any
    /// single bucket would exceed the remote tier's frame cap
    /// ([`EngineError::Backend`]) — an executor must be able to route
    /// *any* bucket to *any* backend, including across the wire.
    pub fn from_batch(ctx: &EngineCtx, batch: &PtrBatch) -> Result<Self, EngineError> {
        Self::from_batch_with_cap(ctx, batch, Self::max_bucket_len())
    }

    /// [`from_batch`](Self::from_batch) with an explicit bucket cap —
    /// crate-internal so the wire-cap refusal path can be tested
    /// without materializing a gigabyte-scale batch.
    pub(crate) fn from_batch_with_cap(
        ctx: &EngineCtx,
        batch: &PtrBatch,
        cap: usize,
    ) -> Result<Self, EngineError> {
        batch.check()?;
        let numthreads = ctx.layout().numthreads;
        // dense owner→bucket map: layouts in this repo span at most 64
        // threads, and even pathological ones are bounded by the u32
        // thread field — fall back to linear probing past a sane size.
        let mut dense = if numthreads <= 1 << 16 {
            vec![u32::MAX; numthreads as usize]
        } else {
            Vec::new()
        };
        let mut plan = GatherPlan {
            owners: Vec::new(),
            buckets: Vec::new(),
            slots: Vec::with_capacity(batch.len()),
        };
        for (ptr, &inc) in batch.ptrs.iter().zip(&batch.incs) {
            let owner = Self::owner_of(ctx, ptr, inc);
            let b = if dense.is_empty() {
                match plan.owners.iter().position(|&o| o == owner) {
                    Some(i) => i as u32,
                    None => {
                        plan.owners.push(owner);
                        plan.buckets.push(PtrBatch::new());
                        plan.owners.len() as u32 - 1
                    }
                }
            } else if dense[owner as usize] != u32::MAX {
                dense[owner as usize]
            } else {
                plan.owners.push(owner);
                plan.buckets.push(PtrBatch::new());
                let b = plan.owners.len() as u32 - 1;
                dense[owner as usize] = b;
                b
            };
            let bucket = &mut plan.buckets[b as usize];
            if bucket.len() >= cap {
                return Err(EngineError::Backend(format!(
                    "gather plan refused: bucket for thread {owner} would \
                     hold more than {cap} pointers and its reply frame \
                     would exceed the {MAX_FRAME}-byte remote frame cap; \
                     split the index vector",
                )));
            }
            plan.slots.push(Slot { bucket: b, pos: bucket.len() as u32 });
            bucket.push(*ptr, inc);
        }
        Ok(plan)
    }

    /// Inspect a gather of `indices` off one loop-invariant `base`
    /// pointer (the `x[col[k]]` shape): element `i` of the plan is
    /// `base + indices[i]` elements.
    pub fn from_indices(
        ctx: &EngineCtx,
        base: SharedPtr,
        indices: &[u64],
    ) -> Result<Self, EngineError> {
        let mut batch = PtrBatch::with_capacity(indices.len());
        for &idx in indices {
            batch.push(base, idx);
        }
        Self::from_batch(ctx, &batch)
    }

    /// Number of requests in the inspected batch.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Is the plan empty (zero buckets, executor is a no-op)?
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// How many distinct owners the batch touches.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// The distinct owning threads, in order of first appearance.
    pub fn owners(&self) -> &[u32] {
        &self.owners
    }

    /// The aggregated per-owner request batches (parallel to
    /// [`owners`](Self::owners)).
    pub fn buckets(&self) -> &[PtrBatch] {
        &self.buckets
    }

    /// Run the fused translate executor: each bucket through
    /// `engine.translate`, results spliced back in request order.
    pub fn execute(
        &self,
        engine: &dyn AddressEngine,
        ctx: &EngineCtx,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        self.execute_with(out, &mut |bucket, scratch| {
            engine.translate(ctx, bucket, scratch)
        })
    }

    /// Run the increment-only executor: each bucket through
    /// `engine.increment`, results spliced back in request order.
    pub fn execute_increment(
        &self,
        engine: &dyn AddressEngine,
        ctx: &EngineCtx,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        self.execute_increment_with(out, &mut |bucket, scratch| {
            engine.increment(ctx, bucket, scratch)
        })
    }

    /// Closure form of [`execute`](Self::execute): `run` maps one
    /// bucket to its [`BatchOut`] — this is how the selector routes
    /// each bucket through its guarded dispatch funnel (possibly to a
    /// *different* backend per bucket).
    pub fn execute_with(
        &self,
        out: &mut BatchOut,
        run: &mut dyn FnMut(&PtrBatch, &mut BatchOut) -> Result<(), EngineError>,
    ) -> Result<(), EngineError> {
        let mut parts: Vec<BatchOut> = Vec::with_capacity(self.buckets.len());
        for bucket in &self.buckets {
            let mut scratch = BatchOut::new();
            run(bucket, &mut scratch)?;
            if scratch.len() != bucket.len() {
                return Err(EngineError::Backend(format!(
                    "gather bucket produced {} results for {} requests",
                    scratch.len(),
                    bucket.len()
                )));
            }
            parts.push(scratch);
        }
        out.clear();
        out.reserve(self.slots.len());
        for s in &self.slots {
            let part = &parts[s.bucket as usize];
            let i = s.pos as usize;
            out.push(part.ptrs[i], part.sysva[i], part.loc[i]);
        }
        Ok(())
    }

    /// Closure form of [`execute_increment`](Self::execute_increment).
    pub fn execute_increment_with(
        &self,
        out: &mut Vec<SharedPtr>,
        run: &mut dyn FnMut(&PtrBatch, &mut Vec<SharedPtr>) -> Result<(), EngineError>,
    ) -> Result<(), EngineError> {
        let mut parts: Vec<Vec<SharedPtr>> = Vec::with_capacity(self.buckets.len());
        for bucket in &self.buckets {
            let mut scratch = Vec::new();
            run(bucket, &mut scratch)?;
            if scratch.len() != bucket.len() {
                return Err(EngineError::Backend(format!(
                    "gather bucket produced {} results for {} requests",
                    scratch.len(),
                    bucket.len()
                )));
            }
            parts.push(scratch);
        }
        out.clear();
        out.reserve(self.slots.len());
        for s in &self.slots {
            out.push(parts[s.bucket as usize][s.pos as usize]);
        }
        Ok(())
    }

    /// Measure this host's actual inspection cost: `(bucket_ns_per_ptr,
    /// plan_setup_ns)` over a representative multi-owner batch.  The
    /// selector prices its `gather_threshold` off these numbers
    /// (`EngineSelector::with_gather_calibration`), the same
    /// measured-not-guessed discipline as the Leon3/remote legs.
    pub fn calibrate() -> (f64, f64) {
        use crate::sptr::{ArrayLayout, BaseTable};
        let layout = ArrayLayout::new(64, 8, 16);
        let table = BaseTable::regular(16, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0)
            .expect("calibration ctx is well-formed");
        const N: usize = 4096;
        const ROUNDS: u32 = 8;
        let mut batch = PtrBatch::with_capacity(N);
        let mut x = 0x9E37_79B9u64;
        for _ in 0..N {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            batch.push(SharedPtr::NULL, x % (64 * 16 * 8));
        }
        // large-batch leg: per-pointer bucketing cost
        let t0 = Instant::now();
        for _ in 0..ROUNDS {
            let plan = Self::from_batch(&ctx, &batch)
                .expect("calibration batch fits the frame cap");
            std::hint::black_box(plan.bucket_count());
        }
        let ns_per_ptr =
            t0.elapsed().as_nanos() as f64 / (ROUNDS as u64 * N as u64) as f64;
        // small-batch leg: fixed setup (allocation of owners/buckets)
        let mut tiny = PtrBatch::with_capacity(2);
        tiny.push(SharedPtr::NULL, 0);
        tiny.push(SharedPtr::NULL, 64);
        let t1 = Instant::now();
        for _ in 0..ROUNDS * 64 {
            let plan = Self::from_batch(&ctx, &tiny)
                .expect("calibration batch fits the frame cap");
            std::hint::black_box(plan.bucket_count());
        }
        let setup_ns = (t1.elapsed().as_nanos() as f64
            / (ROUNDS as f64 * 64.0))
            .max(1.0);
        (ns_per_ptr.max(0.01), setup_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Pow2Engine, SoftwareEngine};
    use super::*;
    use crate::sptr::{ArrayLayout, BaseTable};

    fn fig2_ctx(table: &BaseTable) -> EngineCtx<'_> {
        EngineCtx::new(ArrayLayout::new(4, 4, 4), table, 0).unwrap()
    }

    #[test]
    fn buckets_group_by_owner_and_keep_request_order() {
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        // indices 0..3 → thread 0, 4..7 → thread 1, 8..11 → thread 2
        let plan =
            GatherPlan::from_indices(&ctx, SharedPtr::NULL, &[8, 0, 9, 4, 1])
                .unwrap();
        assert_eq!(plan.len(), 5);
        assert_eq!(plan.owners(), &[2, 0, 1]); // first-appearance order
        assert_eq!(plan.bucket_count(), 3);
        let sizes: Vec<usize> =
            plan.buckets().iter().map(|b| b.len()).collect();
        assert_eq!(sizes, vec![2, 2, 1]);
    }

    #[test]
    fn executor_is_bit_identical_to_per_element_path() {
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        let idx = [9u64, 1, 5, 1, 13, 0, 2, 7, 7];
        let plan = GatherPlan::from_indices(&ctx, SharedPtr::NULL, &idx).unwrap();
        for engine in [&SoftwareEngine as &dyn AddressEngine, &Pow2Engine] {
            let mut planned = BatchOut::new();
            plan.execute(engine, &ctx, &mut planned).unwrap();
            assert_eq!(planned.len(), idx.len());
            for (i, &inc) in idx.iter().enumerate() {
                let (p, sysva, loc) =
                    engine.translate_one(&ctx, SharedPtr::NULL, inc).unwrap();
                assert_eq!(planned.ptrs[i], p, "{} elem {i}", engine.name());
                assert_eq!(planned.sysva[i], sysva);
                assert_eq!(planned.loc[i], loc);
            }
            let mut incs = Vec::new();
            plan.execute_increment(engine, &ctx, &mut incs).unwrap();
            assert_eq!(incs, planned.ptrs);
        }
    }

    #[test]
    fn empty_plan_executes_to_empty_output() {
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        let plan = GatherPlan::from_indices(&ctx, SharedPtr::NULL, &[]).unwrap();
        assert!(plan.is_empty());
        assert_eq!(plan.bucket_count(), 0);
        let mut out = BatchOut::new();
        out.push(SharedPtr::NULL, 1, crate::sptr::Locality::Local); // stale
        plan.execute(&SoftwareEngine, &ctx, &mut out).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn owner_arithmetic_matches_full_increment() {
        // non-pow2 geometry: the cheap owner arithmetic must agree with
        // Algorithm 1's thread field everywhere
        let layout = ArrayLayout::new(3, 24, 5);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 2).unwrap();
        for start in 0..30u64 {
            let p = SharedPtr::for_index(&layout, 0, start);
            for inc in [0u64, 1, 2, 3, 7, 14, 29, 1000] {
                let want = p.incremented(inc, &layout).thread;
                assert_eq!(
                    GatherPlan::owner_of(&ctx, &p, inc),
                    want,
                    "start {start} inc {inc}"
                );
            }
        }
    }

    #[test]
    fn mismatched_bucket_output_is_refused() {
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        let plan =
            GatherPlan::from_indices(&ctx, SharedPtr::NULL, &[0, 4]).unwrap();
        let mut out = BatchOut::new();
        let err = plan
            .execute_with(&mut out, &mut |_b, _s| Ok(())) // produces nothing
            .unwrap_err();
        assert!(matches!(err, EngineError::Backend(_)));
    }

    #[test]
    fn length_mismatch_propagates_from_inspection() {
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 0);
        batch.incs.push(7); // corrupt the SoA invariant
        assert!(matches!(
            GatherPlan::from_batch(&ctx, &batch),
            Err(EngineError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn over_cap_buckets_are_refused_loudly() {
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        // 3 indices landing on the same owner, cap of 2: the plan must
        // refuse (loud Backend error naming the frame cap), never drop
        // the overflow on the floor.
        let mut batch = PtrBatch::new();
        for _ in 0..3 {
            batch.push(SharedPtr::NULL, 0); // all owner 0
        }
        let err =
            GatherPlan::from_batch_with_cap(&ctx, &batch, 2).unwrap_err();
        match err {
            EngineError::Backend(msg) => {
                assert!(msg.contains("frame cap"), "{msg}");
                assert!(msg.contains("thread 0"), "{msg}");
            }
            other => panic!("expected Backend error, got {other:?}"),
        }
        // at exactly the cap the plan is legal
        batch.ptrs.pop();
        batch.incs.pop();
        assert!(GatherPlan::from_batch_with_cap(&ctx, &batch, 2).is_ok());
    }

    #[test]
    fn max_bucket_len_matches_wire_arithmetic() {
        let n = GatherPlan::max_bucket_len();
        assert!(reply_frame_bytes(n) <= MAX_FRAME);
        assert!(reply_frame_bytes(n + 1) > MAX_FRAME);
    }

    #[test]
    fn calibration_returns_positive_costs() {
        let (ns_per_ptr, setup_ns) = GatherPlan::calibrate();
        assert!(ns_per_ptr > 0.0);
        assert!(setup_ns > 0.0);
    }
}
