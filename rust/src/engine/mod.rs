//! The unified `AddressEngine` API: one pluggable backend contract for
//! UPC shared-pointer address mapping.
//!
//! The paper's central claim is that the address-mapping contract —
//! Algorithm 1 incrementation plus base-LUT translation plus locality
//! classification — is *one* interface that interchangeable
//! implementations can serve: a software divide/modulo path, a pow2
//! shift/mask hardware path, or a dedicated batched unit.  Before this
//! module the repo re-implemented that contract four times with four
//! incompatible calling conventions; every host-side consumer now goes
//! through the [`AddressEngine`] trait instead.
//!
//! * [`SoftwareEngine`] — the general Algorithm 1 (divide/modulo),
//!   legal for every layout; the Berkeley-runtime software path.
//! * [`Pow2Engine`] — the shift/mask fast path the hardware pipelines;
//!   refuses layouts whose geometry is not all powers of two.
//! * [`ShardedEngine`] — the throughput tier: wraps any inner backend
//!   and partitions a [`PtrBatch`] (or a walk's step range) across a
//!   persistent worker-thread pool, splicing shard results back in
//!   order so outputs are bit-identical to the inner engine at any
//!   shard count.
//! * [`Leon3Engine`] — the FPGA-prototype datapath: each request is
//!   lowered to the `ldi`/`pgas_incr` sequences of the Table-3 SPARC
//!   coprocessor, executed on the `leon3::` functional core, billed in
//!   75 MHz cycles, and refused on non-pow2 geometry exactly like
//!   `Pow2Engine`.
//! * [`RemoteEngine`] — address mapping as a *service*: the same
//!   scatter/gather + order-preserving splice as the thread tier, over
//!   worker **processes** speaking a length-prefixed binary protocol on
//!   Unix-domain sockets (the [`remote`] module; the worker side is the
//!   `pgas-hw serve-engine` subcommand).
//! * `XlaBatchEngine` (behind the `xla-unit` cargo feature) — the
//!   PJRT/XLA batched unit, chunking arbitrary batch sizes through the
//!   artifacts' fixed `UNIT_BATCH` shape.
//! * [`EngineSelector`] — picks the cheapest legal backend per
//!   request, the runtime mirror of the compiler's `Soft`/`Hw`
//!   lowering choice.
//! * [`GatherPlan`] (the [`gather`] module) — the inspector/executor
//!   tier for data-dependent indirection: inspect an index vector once,
//!   bucket requests by owning thread, dispatch one aggregated batch
//!   per owner through any backend above, splice results back in
//!   request order.  The selector routes multi-owner increment batches
//!   through it past `gather_threshold`.
//!
//! The full backend matrix (capabilities, layout constraints, cost
//! legs, selection rules) is documented in `ARCHITECTURE.md` at the
//! repo root.
//!
//! ## Selection cost model
//!
//! The selector prices every legal backend for a `(layout, batch_len)`
//! request and takes the argmin (see [`CostModel`]):
//!
//! * scalar paths cost `n · ns_per_ptr` — the pow2 shift/mask path is a
//!   few ns per pointer, the software divide/modulo path several times
//!   that (≈ [`SOFT_INC_OP_COUNT`](crate::sptr::SOFT_INC_OP_COUNT) ops);
//! * the sharded pool costs a fixed dispatch fee (channel round-trips)
//!   plus the scalar per-pointer cost divided by the worker count plus
//!   a per-pointer copy overhead that does not parallelize — it only
//!   wins once the batch amortizes the fee, gated by `shard_threshold`;
//! * the XLA unit (when built and loaded) costs a PJRT dispatch fee
//!   plus a tiny per-pointer cost, gated by `xla_threshold`;
//! * walks are priced off the O(1) stepper (layout-independent), so a
//!   walk only leaves the scalar path at much larger step counts than
//!   a translate batch of the same size.
//!
//! Per-choice hit counters record which backend actually served each
//! request; `coordinator::engine_report` archives that mix with every
//! sweep.
//!
//! ## Walks are O(1) per step
//!
//! Both host backends serve [`AddressEngine::walk`] through
//! [`WalkCursor`](crate::sptr::WalkCursor), which factors the stride
//! through the layout once and advances with add-and-carry only — no
//! per-step divide/modulo even on the software path.
//!
//! All backends must agree bit-for-bit on `(thread, phase, va, sysva,
//! loc)` for every layout they support; `rust/tests/engine_conformance.rs`
//! enforces this differentially (including shard-count invariance and
//! the Leon3 coprocessor replay), and `rust/tests/remote_engine.rs`
//! extends the differentials across the process boundary (NPB layouts
//! at 1/2/4 worker processes, worker-death recovery).

mod fault;
pub mod gather;
mod leon3;
pub mod plan;
mod pow2;
pub mod remote;
mod select;
mod sharded;
mod simd;
mod software;
#[cfg(feature = "xla-unit")]
mod xla_batch;

pub use fault::{ChaosEngine, EngineFault, FaultPlan, FaultSpec, WireFault};
pub use gather::{GatherPlan, GatherStats};
pub use leon3::Leon3Engine;
pub use plan::{PlanStats, TilePlan, L1_TILE_PTRS, L2_TILE_PTRS};
pub use pow2::Pow2Engine;
pub use remote::{RemoteClientStats, RemoteEngine, RemoteTier};
pub use select::{
    AutoEngine, BreakerState, CostModel, EngineChoice, EngineSelector,
    HealthStats, TierHealthStats,
};
pub use sharded::ShardedEngine;
pub use simd::{SimdEngine, SimdStats, SIMD_LANES};
pub use software::SoftwareEngine;
#[cfg(feature = "xla-unit")]
pub use xla_batch::XlaBatchEngine;

use crate::sptr::{
    locality, ArrayLayout, BaseTable, Locality, Recip, SharedPtr, Topology,
    WalkCursor,
};

/// Why an engine refused a request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The backend cannot serve this distribution geometry at all
    /// (e.g. a non-pow2 layout on the hardware fast path).
    UnsupportedLayout {
        engine: &'static str,
        layout: ArrayLayout,
    },
    /// `ptrs` and `incs` of a [`PtrBatch`] differ in length.
    LengthMismatch { ptrs: usize, incs: usize },
    /// The base table covers fewer threads than the layout distributes
    /// over — translation would index past the LUT.
    TableTooSmall {
        table_threads: u32,
        layout_threads: u32,
    },
    /// Backend-specific failure (artifact loading, PJRT execution, a
    /// value outside the artifact's lane width, ...).
    Backend(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::UnsupportedLayout { engine, layout } => write!(
                f,
                "engine `{engine}` does not support layout \
                 [blocksize {}, elemsize {}, threads {}]",
                layout.blocksize, layout.elemsize, layout.numthreads
            ),
            EngineError::LengthMismatch { ptrs, incs } => {
                write!(f, "batch has {ptrs} pointers but {incs} increments")
            }
            EngineError::TableTooSmall {
                table_threads,
                layout_threads,
            } => write!(
                f,
                "base table covers {table_threads} threads, layout needs \
                 {layout_threads}"
            ),
            EngineError::Backend(msg) => write!(f, "backend error: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Everything a backend needs besides the pointers themselves: the
/// array's distribution geometry, the per-thread base LUT, and the
/// executing thread + topology for locality classification.
///
/// Construction is checked: `table` must cover at least
/// `layout.numthreads` threads or [`EngineError::TableTooSmall`] is
/// returned — an undersized LUT would otherwise translate wrongly (or
/// panic) only at access time.  The Figure-3 log2 immediates are
/// factored once here so the pow2 per-call paths never redo the
/// power-of-two decomposition.  Fields are read-only outside the
/// engine module (accessors below): mutating `layout` or `table` after
/// construction would desync the cached immediates and bypass the
/// coverage check.
#[derive(Clone, Copy, Debug)]
pub struct EngineCtx<'a> {
    layout: ArrayLayout,
    table: &'a BaseTable,
    /// The executing thread (`MYTHREAD`) locality is classified against.
    mythread: u32,
    topo: Topology,
    /// Cached `layout.log2s()` (None for non-pow2 geometry).
    log2s: Option<(u32, u32, u32)>,
    /// Granlund–Montgomery reciprocals of the layout's two Algorithm-1
    /// divisors `(blocksize, numthreads)`, precomputed once here so the
    /// vectorized general path never divides in the lane loop.
    recips: (Recip, Recip),
}

impl<'a> EngineCtx<'a> {
    /// Checked constructor: fails with [`EngineError::TableTooSmall`]
    /// when `table` covers fewer threads than `layout` distributes
    /// over.  Precomputes the Figure-3 log2 immediates.
    pub fn new(
        layout: ArrayLayout,
        table: &'a BaseTable,
        mythread: u32,
    ) -> Result<Self, EngineError> {
        if table.numthreads() < layout.numthreads {
            return Err(EngineError::TableTooSmall {
                table_threads: table.numthreads(),
                layout_threads: layout.numthreads,
            });
        }
        Ok(Self {
            layout,
            table,
            mythread,
            topo: Topology::default(),
            log2s: layout.log2s(),
            recips: (
                Recip::new(layout.blocksize),
                Recip::new(layout.numthreads as u64),
            ),
        })
    }

    /// Replace the machine topology used for locality classification
    /// (defaults to the Leon3-prototype single-node SMP shape).
    pub fn with_topology(mut self, topo: Topology) -> Self {
        self.topo = topo;
        self
    }

    /// The Figure-3 log2 immediates, precomputed at construction
    /// (None when the layout is not all powers of two).
    #[inline]
    pub fn log2s(&self) -> Option<(u32, u32, u32)> {
        self.log2s
    }

    /// The array's distribution geometry.
    #[inline]
    pub fn layout(&self) -> &ArrayLayout {
        &self.layout
    }

    /// The per-thread base LUT.
    #[inline]
    pub fn table(&self) -> &'a BaseTable {
        self.table
    }

    /// The executing thread (`MYTHREAD`).
    #[inline]
    pub fn mythread(&self) -> u32 {
        self.mythread
    }

    /// Machine topology for locality classification.
    #[inline]
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// Precomputed reciprocals of `(blocksize, numthreads)` — the
    /// strength-reduced form of Algorithm 1's two div/mod pairs used by
    /// the vectorized general path.
    #[inline]
    pub fn recips(&self) -> (Recip, Recip) {
        self.recips
    }
}

/// A reusable structure-of-arrays request batch: pointer `i` is to be
/// incremented by `incs[i]` elements (0 = pure translation).
#[derive(Clone, Debug, Default)]
pub struct PtrBatch {
    pub ptrs: Vec<SharedPtr>,
    pub incs: Vec<u64>,
}

impl PtrBatch {
    /// An empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty batch with room for `n` requests.
    pub fn with_capacity(n: usize) -> Self {
        Self { ptrs: Vec::with_capacity(n), incs: Vec::with_capacity(n) }
    }

    /// Drop all requests, keeping the allocations.
    pub fn clear(&mut self) {
        self.ptrs.clear();
        self.incs.clear();
    }

    /// Append one request: increment `ptr` by `inc` elements (0 = pure
    /// translation).
    pub fn push(&mut self, ptr: SharedPtr, inc: u64) {
        self.ptrs.push(ptr);
        self.incs.push(inc);
    }

    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.ptrs.len()
    }

    /// Is the batch empty?
    pub fn is_empty(&self) -> bool {
        self.ptrs.is_empty()
    }

    /// Validate the SoA invariant before a backend consumes the batch.
    pub fn check(&self) -> Result<(), EngineError> {
        if self.ptrs.len() == self.incs.len() {
            Ok(())
        } else {
            Err(EngineError::LengthMismatch {
                ptrs: self.ptrs.len(),
                incs: self.incs.len(),
            })
        }
    }
}

/// Structure-of-arrays response: the post-increment pointer, its system
/// virtual address, and its locality relative to `EngineCtx::mythread`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BatchOut {
    pub ptrs: Vec<SharedPtr>,
    pub sysva: Vec<u64>,
    pub loc: Vec<Locality>,
}

impl BatchOut {
    /// An empty response buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop all results, keeping the allocations (backends call this at
    /// the top of every request so outputs can be reused across calls).
    pub fn clear(&mut self) {
        self.ptrs.clear();
        self.sysva.clear();
        self.loc.clear();
    }

    /// Reserve room for `n` more results in all three columns.
    pub fn reserve(&mut self, n: usize) {
        self.ptrs.reserve(n);
        self.sysva.reserve(n);
        self.loc.reserve(n);
    }

    /// Append one result triple.
    pub fn push(&mut self, ptr: SharedPtr, sysva: u64, loc: Locality) {
        self.ptrs.push(ptr);
        self.sysva.push(sysva);
        self.loc.push(loc);
    }

    /// Move all of `other`'s results onto the end of `self` (shard
    /// splicing: results re-assemble in shard order, keeping outputs
    /// bit-identical to an unsharded run).
    pub fn append(&mut self, other: &mut BatchOut) {
        self.ptrs.append(&mut other.ptrs);
        self.sysva.append(&mut other.sysva);
        self.loc.append(&mut other.loc);
    }

    /// Number of result triples.
    pub fn len(&self) -> usize {
        self.ptrs.len()
    }

    /// Is the response empty?
    pub fn is_empty(&self) -> bool {
        self.ptrs.is_empty()
    }
}

/// Shared walk loop: factor the stride once into a
/// [`WalkCursor`], then emit `steps` (pointer, sysva, locality)
/// triples with O(1) add-and-carry stepping.  Both host backends'
/// `walk` paths route here; they differ only in their support gate.
///
/// Strides whose per-step byte displacement exceeds `i64` (only
/// reachable near `u64::MAX`) are refused with a loud
/// [`EngineError::Backend`] — a wrapped pointer walk would be silently
/// wrong everywhere downstream.
pub(crate) fn cursor_walk(
    ctx: &EngineCtx,
    start: SharedPtr,
    inc: u64,
    steps: usize,
    out: &mut BatchOut,
) -> Result<(), EngineError> {
    let mut cur =
        WalkCursor::try_new(start, inc, &ctx.layout).ok_or_else(|| {
            EngineError::Backend(format!(
                "walk stride {inc} out of range for layout [blocksize {}, \
                 elemsize {}, threads {}]: per-step byte displacement \
                 exceeds i64",
                ctx.layout.blocksize, ctx.layout.elemsize, ctx.layout.numthreads
            ))
        })?;
    out.clear();
    out.reserve(steps);
    for _ in 0..steps {
        let p = cur.current();
        out.push(
            p,
            p.translate(ctx.table),
            locality(p.thread, ctx.mythread, &ctx.topo),
        );
        cur.advance();
    }
    Ok(())
}

/// The one address-mapping contract every backend implements.
///
/// Semantics (identical across backends, differentially tested):
///
/// * [`translate`](AddressEngine::translate) — the fused unit: each
///   pointer is incremented by its per-request element count (which may
///   be 0), translated through the base LUT, and locality-classified.
/// * [`increment`](AddressEngine::increment) — Algorithm 1 only; no
///   LUT access.
/// * [`walk`](AddressEngine::walk) — `steps` outputs starting *at*
///   `start` (step 0 is the untouched start pointer), advancing by
///   `inc` elements per step — the sequential-traversal shape host-side
///   array initialization and validation use.
pub trait AddressEngine {
    /// Stable backend name (reports, selection tables, errors).
    fn name(&self) -> &'static str;

    /// Can this backend serve `layout` at all?  Engines must return an
    /// [`EngineError::UnsupportedLayout`] from the mapping calls when
    /// this is false, never a wrong answer.
    fn supports(&self, layout: &ArrayLayout) -> bool;

    /// Fused increment + LUT translation + locality over a batch.
    ///
    /// # Examples
    ///
    /// ```
    /// use pgas_hw::engine::{
    ///     AddressEngine, BatchOut, EngineCtx, PtrBatch, SoftwareEngine,
    /// };
    /// use pgas_hw::sptr::{ArrayLayout, BaseTable, Locality, SharedPtr};
    ///
    /// // shared [4] int A[...] over 4 threads (the paper's Figure 2)
    /// let layout = ArrayLayout::new(4, 4, 4);
    /// let table = BaseTable::regular(4, 1 << 32, 1 << 32);
    /// let ctx = EngineCtx::new(layout, &table, 0).unwrap();
    /// let mut batch = PtrBatch::new();
    /// batch.push(SharedPtr::NULL, 5); // &A[0] + 5 -> A[5], on thread 1
    /// let mut out = BatchOut::new();
    /// SoftwareEngine.translate(&ctx, &batch, &mut out).unwrap();
    /// assert_eq!(out.ptrs[0], SharedPtr::for_index(&layout, 0, 5));
    /// assert_eq!(out.sysva[0], table.base(1) + out.ptrs[0].va);
    /// assert_eq!(out.loc[0], Locality::SameMc);
    /// ```
    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError>;

    /// Increment-only over a batch; `out` is cleared and refilled.
    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError>;

    /// Walk `start` for `steps` steps of `inc` elements; `out` is
    /// cleared and refilled with one entry per step (step 0 = `start`).
    ///
    /// # Examples
    ///
    /// ```
    /// use pgas_hw::engine::{AddressEngine, BatchOut, EngineCtx, SoftwareEngine};
    /// use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
    ///
    /// let layout = ArrayLayout::new(4, 4, 4);
    /// let table = BaseTable::regular(4, 1 << 32, 1 << 32);
    /// let ctx = EngineCtx::new(layout, &table, 0).unwrap();
    /// let mut out = BatchOut::new();
    /// // 8 steps of 1 element from &A[0]: step 0 is A[0] itself
    /// SoftwareEngine.walk(&ctx, SharedPtr::NULL, 1, 8, &mut out).unwrap();
    /// assert_eq!(out.len(), 8);
    /// assert_eq!(out.ptrs[0], SharedPtr::NULL);
    /// // elements 4..7 live on thread 1
    /// assert_eq!(out.ptrs[4], SharedPtr::for_index(&layout, 0, 4));
    /// assert_eq!(out.ptrs[4].thread, 1);
    /// ```
    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError>;

    /// Serve a cache-blocked [`TilePlan`]: dispatch each tile of the
    /// plan (already reordered by affinity bucket) and splice results
    /// back into request order.  The default runs tiles sequentially
    /// through [`translate`](AddressEngine::translate) — cache-blocked
    /// execution with L1/L2-resident working sets, and for the
    /// remote/daemon tiers one affinity-coherent frame per tile.  The
    /// sharded tier overrides this to shard over whole planned tiles
    /// instead of raw index ranges.  Outputs are bit-identical to an
    /// unplanned `translate` of the same batch at any tile size.
    fn translate_planned(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        plan: &TilePlan,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        plan.execute_translate(batch, out, &mut |sub, sink| {
            self.translate(ctx, sub, sink)
        })
    }

    /// Increment-only form of
    /// [`translate_planned`](AddressEngine::translate_planned).
    fn increment_planned(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        plan: &TilePlan,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        plan.execute_increment(batch, out, &mut |sub, sink| {
            self.increment(ctx, sub, sink)
        })
    }

    /// Scalar convenience for host paths that map one pointer at a
    /// time.  Backends with a cheap scalar path override this to avoid
    /// the batch round-trip.
    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        let mut batch = PtrBatch::with_capacity(1);
        batch.push(ptr, inc);
        let mut out = BatchOut::new();
        self.translate(ctx, &batch, &mut out)?;
        Ok((out.ptrs[0], out.sysva[0], out.loc[0]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_push_and_clear_keep_soa_invariant() {
        let mut b = PtrBatch::with_capacity(4);
        assert!(b.is_empty());
        b.push(SharedPtr::NULL, 3);
        b.push(SharedPtr { thread: 1, phase: 2, va: 8 }, 0);
        assert_eq!(b.len(), 2);
        assert!(b.check().is_ok());
        b.clear();
        assert!(b.is_empty());
        b.incs.push(1); // corrupt the invariant directly
        assert_eq!(
            b.check(),
            Err(EngineError::LengthMismatch { ptrs: 0, incs: 1 })
        );
    }

    #[test]
    fn ctx_rejects_undersized_tables_and_caches_log2s() {
        let small = BaseTable::regular(2, 1 << 32, 1 << 32);
        let err =
            EngineCtx::new(ArrayLayout::new(4, 4, 4), &small, 0).unwrap_err();
        assert!(matches!(
            err,
            EngineError::TableTooSmall { table_threads: 2, layout_threads: 4 }
        ));
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(ArrayLayout::new(4, 8, 4), &table, 0).unwrap();
        assert_eq!(ctx.log2s(), Some((2, 3, 2)));
        let odd = EngineCtx::new(ArrayLayout::new(3, 8, 4), &table, 0).unwrap();
        assert_eq!(odd.log2s(), None);
    }

    #[test]
    fn error_display_names_the_engine() {
        let e = EngineError::UnsupportedLayout {
            engine: "pow2",
            layout: ArrayLayout::new(3, 8, 4),
        };
        let msg = e.to_string();
        assert!(msg.contains("pow2"));
        assert!(msg.contains("blocksize 3"));
    }
}
