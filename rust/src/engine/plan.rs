//! The cache-blocked, locality-aware batch planner.
//!
//! Very large [`PtrBatch`]es defeat the memory hierarchy twice: the
//! SoA input streams plus the result triple (~40 bytes per request)
//! overflow L1/L2 so the vectorized lanes stall on memory, and the
//! requests arrive in arbitrary owner order so downstream tiers
//! (sharded pool, remote/daemon frames) see incoherent affinity.
//! [`TilePlan`] fixes both with the blocked transpose-then-work
//! discipline:
//!
//! 1. **Tile** — split the batch into contiguous index ranges of
//!    [`L1_TILE_PTRS`]/[`L2_TILE_PTRS`] requests, small enough that one
//!    tile's inputs and outputs stay cache-resident while the lane
//!    kernel runs over it.
//! 2. **Reorder** — key each tile by the owning thread of its first
//!    request (reusing [`GatherPlan`]'s owner arithmetic) and stable-
//!    sort tiles by that affinity bucket, so consecutive dispatches hit
//!    the same owner's data and the remote/daemon tiers ship
//!    affinity-coherent frames.
//! 3. **Splice** — every tile remembers its original index range;
//!    results are scattered back to exactly that range, so the planned
//!    output is bit-identical to an unplanned run at any tile size
//!    (differentially enforced in `rust/tests/engine_conformance.rs`).
//!
//! Execution goes through
//! [`AddressEngine::translate_planned`](super::AddressEngine::translate_planned):
//! the default implementation runs tiles sequentially (cache blocking),
//! while [`ShardedEngine`](super::ShardedEngine) overrides it to shard
//! over whole planned tiles — [`TilePlan::groups`] hands each worker a
//! contiguous run of affinity-sorted tiles instead of a raw index
//! range.  The selector engages the planner past `plan_threshold` and
//! tallies [`PlanStats`].

use super::gather::GatherPlan;
use super::{BatchOut, EngineCtx, EngineError, PtrBatch};
use crate::sptr::{Locality, SharedPtr};

/// Requests per L1-sized tile: a tile's SoA inputs (32 bytes/request)
/// plus its result triple (~40 bytes) must stay resident in a 32 KiB
/// L1d with room to spare.
pub const L1_TILE_PTRS: usize = 512;

/// Requests per L2-sized tile — the default planning grain: big enough
/// to amortize dispatch, small enough for a per-core L2 slice.
pub const L2_TILE_PTRS: usize = 4096;

/// Counters for the planner: plans built, tiles dispatched, pointers
/// routed through planned execution, and batches that fell back to
/// unplanned dispatch (single tile).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// Plans built and executed.
    pub plans: u64,
    /// Tiles dispatched across all plans.
    pub tiles: u64,
    /// Pointers that went through planned execution.
    pub planned_ptrs: u64,
    /// Batches past the threshold that still ran unplanned (the plan
    /// degenerated to a single tile).
    pub fallback: u64,
}

impl PlanStats {
    /// Fold another counter snapshot into this one (per-CPU merge).
    pub fn merge(&mut self, other: &PlanStats) {
        self.plans += other.plans;
        self.tiles += other.tiles;
        self.planned_ptrs += other.planned_ptrs;
        self.fallback += other.fallback;
    }
}

/// One cache-sized tile: a contiguous range of the original batch plus
/// its affinity-bucket key.
#[derive(Clone, Copy, Debug)]
pub struct Tile {
    /// First request index (inclusive) in the original batch.
    pub lo: usize,
    /// One past the last request index.
    pub hi: usize,
    /// Affinity bucket: owning thread of the tile's first request.
    pub owner: u32,
}

impl Tile {
    /// Number of requests in this tile.
    #[inline]
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// Is the tile empty?  (Never true for planner-built tiles.)
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }
}

/// A cache-blocked execution plan over one batch: tiles in affinity-
/// sorted dispatch order, each remembering its original index range.
#[derive(Clone, Debug)]
pub struct TilePlan {
    /// Tiles in dispatch order (stable-sorted by affinity bucket).
    tiles: Vec<Tile>,
    /// Total requests across all tiles (= the planned batch's length).
    len: usize,
}

impl TilePlan {
    /// Build a plan over `batch` with `tile_ptrs` requests per tile
    /// (clamped to at least 1).  Cost is O(n/tile_ptrs · log) — one
    /// owner computation per *tile*, not per element, plus the tile
    /// sort; the per-element inspector work stays with [`GatherPlan`].
    pub fn from_batch(
        ctx: &EngineCtx,
        batch: &PtrBatch,
        tile_ptrs: usize,
    ) -> Result<Self, EngineError> {
        batch.check()?;
        let tile_ptrs = tile_ptrs.max(1);
        let n = batch.len();
        let mut tiles = Vec::with_capacity(n.div_ceil(tile_ptrs).max(1));
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + tile_ptrs).min(n);
            let owner =
                GatherPlan::owner_of(ctx, &batch.ptrs[lo], batch.incs[lo]);
            tiles.push(Tile { lo, hi, owner });
            lo = hi;
        }
        // Affinity reorder: stable sort keeps same-owner tiles in
        // original order, so the splice below is order-preserving
        // within every bucket.
        tiles.sort_by_key(|t| t.owner);
        Ok(Self { tiles, len: n })
    }

    /// Tiles in dispatch order.
    #[inline]
    pub fn tiles(&self) -> &[Tile] {
        &self.tiles
    }

    /// Number of tiles.
    #[inline]
    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    /// Total requests across all tiles.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the plan empty?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of distinct affinity buckets among the tiles.
    pub fn bucket_count(&self) -> usize {
        let mut count = 0;
        let mut last: Option<u32> = None;
        for t in &self.tiles {
            if last != Some(t.owner) {
                count += 1;
                last = Some(t.owner);
            }
        }
        count
    }

    /// Split the dispatch-ordered tile list into at most `k` contiguous
    /// groups balanced by request count — the sharded tier's planned
    /// shard units.  Contiguity in dispatch order means each group is a
    /// run of affinity-sorted tiles, so a worker's frame stays
    /// owner-coherent.
    pub fn groups(&self, k: usize) -> Vec<&[Tile]> {
        let k = k.clamp(1, self.tiles.len().max(1));
        let target = self.len.div_ceil(k).max(1);
        let mut out = Vec::with_capacity(k);
        let mut start = 0usize;
        let mut acc = 0usize;
        for (i, t) in self.tiles.iter().enumerate() {
            acc += t.len();
            if acc >= target && out.len() + 1 < k {
                out.push(&self.tiles[start..=i]);
                start = i + 1;
                acc = 0;
            }
        }
        if start < self.tiles.len() {
            out.push(&self.tiles[start..]);
        }
        out
    }

    /// Run every tile through `run` (a translate-shaped closure) and
    /// scatter each tile's results back to its original index range.
    /// `run` must produce exactly one result per request or the splice
    /// refuses loudly rather than mis-assembling.
    pub fn execute_translate(
        &self,
        batch: &PtrBatch,
        out: &mut BatchOut,
        run: &mut dyn FnMut(
            &PtrBatch,
            &mut BatchOut,
        ) -> Result<(), EngineError>,
    ) -> Result<(), EngineError> {
        batch.check()?;
        if batch.len() != self.len {
            return Err(EngineError::Backend(format!(
                "plan covers {} requests but batch has {}",
                self.len,
                batch.len()
            )));
        }
        out.clear();
        out.ptrs.resize(self.len, SharedPtr::NULL);
        out.sysva.resize(self.len, 0);
        out.loc.resize(self.len, Locality::Local);
        let mut sub = PtrBatch::new();
        let mut scratch = BatchOut::new();
        for t in &self.tiles {
            sub.clear();
            sub.ptrs.extend_from_slice(&batch.ptrs[t.lo..t.hi]);
            sub.incs.extend_from_slice(&batch.incs[t.lo..t.hi]);
            run(&sub, &mut scratch)?;
            if scratch.len() != t.len() {
                return Err(EngineError::Backend(format!(
                    "planned tile [{}, {}) returned {} results for {} \
                     requests",
                    t.lo,
                    t.hi,
                    scratch.len(),
                    t.len()
                )));
            }
            out.ptrs[t.lo..t.hi].copy_from_slice(&scratch.ptrs);
            out.sysva[t.lo..t.hi].copy_from_slice(&scratch.sysva);
            out.loc[t.lo..t.hi].copy_from_slice(&scratch.loc);
        }
        Ok(())
    }

    /// Increment-only form of [`TilePlan::execute_translate`].
    pub fn execute_increment(
        &self,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
        run: &mut dyn FnMut(
            &PtrBatch,
            &mut Vec<SharedPtr>,
        ) -> Result<(), EngineError>,
    ) -> Result<(), EngineError> {
        batch.check()?;
        if batch.len() != self.len {
            return Err(EngineError::Backend(format!(
                "plan covers {} requests but batch has {}",
                self.len,
                batch.len()
            )));
        }
        out.clear();
        out.resize(self.len, SharedPtr::NULL);
        let mut sub = PtrBatch::new();
        let mut scratch = Vec::new();
        for t in &self.tiles {
            sub.clear();
            sub.ptrs.extend_from_slice(&batch.ptrs[t.lo..t.hi]);
            sub.incs.extend_from_slice(&batch.incs[t.lo..t.hi]);
            run(&sub, &mut scratch)?;
            if scratch.len() != t.len() {
                return Err(EngineError::Backend(format!(
                    "planned tile [{}, {}) returned {} results for {} \
                     requests",
                    t.lo,
                    t.hi,
                    scratch.len(),
                    t.len()
                )));
            }
            out[t.lo..t.hi].copy_from_slice(&scratch);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{AddressEngine, SoftwareEngine};
    use crate::sptr::{ArrayLayout, BaseTable};

    fn cg_case(n: usize) -> (ArrayLayout, BaseTable, PtrBatch) {
        let layout = ArrayLayout::new(3, 112, 5);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let mut batch = PtrBatch::with_capacity(n);
        for i in 0..n as u64 {
            batch.push(
                SharedPtr::for_index(&layout, 0, i.wrapping_mul(37) % 4096),
                i % 129,
            );
        }
        (layout, table, batch)
    }

    #[test]
    fn tiles_cover_the_batch_exactly_once() {
        let (layout, table, batch) = cg_case(1000);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let plan = TilePlan::from_batch(&ctx, &batch, 64).unwrap();
        assert_eq!(plan.len(), 1000);
        assert_eq!(plan.tile_count(), 16); // ceil(1000/64)
        let mut seen = vec![false; 1000];
        for t in plan.tiles() {
            assert!(!t.is_empty());
            for i in t.lo..t.hi {
                assert!(!seen[i], "index {i} covered twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // dispatch order is sorted by affinity bucket
        let owners: Vec<u32> = plan.tiles().iter().map(|t| t.owner).collect();
        let mut sorted = owners.clone();
        sorted.sort_unstable();
        assert_eq!(owners, sorted);
        assert!(plan.bucket_count() >= 2, "CG layout spreads owners");
    }

    #[test]
    fn planned_execution_is_bit_identical_and_order_preserving() {
        let (layout, table, batch) = cg_case(777);
        let ctx = EngineCtx::new(layout, &table, 2).unwrap();
        let mut want = BatchOut::new();
        SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();
        for tile_ptrs in [1, 4, 64, 4096] {
            let plan = TilePlan::from_batch(&ctx, &batch, tile_ptrs).unwrap();
            let mut got = BatchOut::new();
            SoftwareEngine
                .translate_planned(&ctx, &batch, &plan, &mut got)
                .unwrap();
            assert_eq!(got, want, "tile_ptrs={tile_ptrs}");
        }
    }

    #[test]
    fn groups_partition_dispatch_order_contiguously() {
        let (layout, table, batch) = cg_case(2048);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let plan = TilePlan::from_batch(&ctx, &batch, 64).unwrap();
        for k in [1, 2, 3, 7, 1000] {
            let groups = plan.groups(k);
            assert!(groups.len() <= k.max(1));
            assert!(!groups.is_empty());
            let total: usize =
                groups.iter().map(|g| g.iter().map(Tile::len).sum::<usize>()).sum();
            assert_eq!(total, plan.len(), "k={k}");
            let flat: usize = groups.iter().map(|g| g.len()).sum();
            assert_eq!(flat, plan.tile_count(), "k={k}");
        }
    }

    #[test]
    fn length_mismatch_is_refused_loudly() {
        let (layout, table, batch) = cg_case(100);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let plan = TilePlan::from_batch(&ctx, &batch, 16).unwrap();
        let mut out = BatchOut::new();
        // a runner that drops a result must be caught, not spliced
        let err = plan
            .execute_translate(&batch, &mut out, &mut |sub, sink| {
                SoftwareEngine.translate(&ctx, sub, sink)?;
                sink.ptrs.pop();
                sink.sysva.pop();
                sink.loc.pop();
                Ok(())
            })
            .unwrap_err();
        assert!(matches!(err, EngineError::Backend(_)));
        // and a plan built for one batch refuses another length
        let (_, _, short) = cg_case(50);
        assert!(plan
            .execute_translate(&short, &mut out, &mut |sub, sink| {
                SoftwareEngine.translate(&ctx, sub, sink)
            })
            .is_err());
    }
}
